"""AOT pipeline tests: HLO text round-trip validity + manifest integrity."""

import json
import os
import tempfile

import numpy as np
import pytest

from compile import aot, model


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    out = str(tmp_path_factory.mktemp("artifacts"))
    entries = {}
    for name in ("echo", "checksum", "mlp"):
        text, entry = aot.lower_workload(model.WORKLOADS[name])
        with open(os.path.join(out, entry["file"]), "w") as f:
            f.write(text)
        entries[name] = (text, entry)
    return out, entries


class TestHloText:
    def test_text_is_hlo_module(self, built):
        _, entries = built
        for name, (text, _) in entries.items():
            assert text.startswith("HloModule"), f"{name}: not HLO text"
            assert "ENTRY" in text

    def test_no_custom_calls(self, built):
        """interpret=True must leave no Mosaic custom-calls behind — the CPU
        PJRT client on the rust side cannot execute them."""
        _, entries = built
        for name, (text, _) in entries.items():
            assert "custom-call" not in text, f"{name}: has custom-call, CPU client will fail"

    def test_entry_returns_tuple(self, built):
        """Lowered with return_tuple=True: rust unwraps with to_tuple."""
        _, entries = built
        for name, (text, _) in entries.items():
            root_lines = [l for l in text.splitlines() if "ROOT" in l and "tuple" in l]
            assert root_lines, f"{name}: ENTRY root is not a tuple"

    def test_lowering_deterministic(self):
        t1, _ = aot.lower_workload(model.WORKLOADS["checksum"])
        t2, _ = aot.lower_workload(model.WORKLOADS["checksum"])
        assert t1 == t2


class TestManifest:
    def test_entry_schema(self, built):
        _, entries = built
        for name, (_, e) in entries.items():
            assert e["name"] == name
            assert e["inputs"][0]["dtype"] == "float32"
            assert len(e["check"]["outputs"]) == len(e["outputs"])
            for c in e["check"]["outputs"]:
                assert np.isfinite(c["sum"]) and np.isfinite(c["l2"])

    def test_echo_check_values(self, built):
        """Echo is the identity: the manifest check must equal the input stats."""
        _, entries = built
        _, e = entries["echo"]
        x = np.asarray(model.test_input((model.ECHO_N,)), dtype=np.float64)
        assert abs(e["check"]["outputs"][0]["sum"] - x.sum()) < 1e-4
        assert abs(e["check"]["outputs"][0]["l2"] - np.sqrt((x**2).sum())) < 1e-4

    def test_manifest_json_serializable(self, built):
        _, entries = built
        blob = json.dumps({"functions": [e for _, e in entries.values()]})
        assert json.loads(blob)["functions"][0]["name"]
