"""Layer-2 workload graph tests: shapes, determinism, and graph-vs-oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model


class TestRegistry:
    def test_all_workloads_present(self):
        assert set(model.WORKLOADS) == {"echo", "checksum", "thumbnail", "mlp", "transformer"}

    def test_flops_ordering_matches_complexity_experiment(self):
        """E8 relies on a strict complexity ladder."""
        f = {n: w.flops for n, w in model.WORKLOADS.items()}
        assert f["echo"] < f["thumbnail"] < f["checksum"] < f["mlp"] < f["transformer"]

    def test_test_input_deterministic_and_mirrorable(self):
        """The rust integration test recomputes this exact vector."""
        x = np.asarray(model.test_input((5,)))
        want = np.sin(0.37 * np.arange(5, dtype=np.float32)) * 0.5
        np.testing.assert_allclose(x, want, rtol=1e-6)


class TestShapes:
    @pytest.mark.parametrize("name", list(model.WORKLOADS))
    def test_output_shapes(self, name):
        w = model.WORKLOADS[name]
        outs = jax.jit(w.fn)(model.test_input(w.input_shape))
        assert isinstance(outs, tuple) and len(outs) >= 1
        for o in outs:
            assert o.dtype == jnp.float32

    def test_echo_is_identity(self):
        x = model.test_input((model.ECHO_N,))
        (y,) = model.echo(x)
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))

    def test_thumbnail_shape(self):
        (y,) = model.thumbnail(model.test_input((64, 64, 3)))
        assert y.shape == (16, 16, 3)


class TestGraphVsOracle:
    def test_mlp_matches_ref(self):
        x = model.test_input((model.MLP_BATCH, model.MLP_D_IN))
        (got,) = jax.jit(model.mlp)(x)
        (want,) = model.mlp_ref(x)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=3e-4, atol=3e-4)

    def test_transformer_matches_ref(self):
        x = model.test_input((model.TB_SEQ, model.TB_D))
        (got,) = jax.jit(model.transformer)(x)
        (want,) = model.transformer_ref(x)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=3e-4, atol=3e-4)

    def test_weights_are_baked_constants(self):
        """Same input twice -> bit-identical output (no hidden randomness)."""
        x = model.test_input((model.MLP_BATCH, model.MLP_D_IN))
        a = np.asarray(jax.jit(model.mlp)(x)[0])
        b = np.asarray(jax.jit(model.mlp)(x)[0])
        np.testing.assert_array_equal(a, b)
