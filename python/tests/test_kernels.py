"""Kernel-vs-oracle correctness: hypothesis sweeps over shapes and dtypes.

This is the CORE numeric signal for Layer 1: every Pallas kernel must match
its pure-jnp oracle (kernels.ref) to tight tolerance across ragged shapes,
tile-multiple shapes, and both f32/bf16 inputs.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

# Property sweeps need hypothesis; skip this module (not the whole
# session) in environments that don't carry it.
pytest.importorskip("hypothesis", reason="hypothesis not installed")
from hypothesis import given, settings, strategies as st

from compile import kernels
from compile.kernels import ref

jax.config.update("jax_enable_x64", False)

SETTINGS = dict(max_examples=25, deadline=None)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(rtol=3e-5, atol=3e-5)


def _rand(key, shape, dtype):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32).astype(dtype)


def _assert_close(got, want, dtype):
    np.testing.assert_allclose(
        np.asarray(got, dtype=np.float32), np.asarray(want, dtype=np.float32), **_tol(dtype)
    )


# ---------------------------------------------------------------------------
# fused_linear
# ---------------------------------------------------------------------------


class TestFusedLinear:
    @settings(**SETTINGS)
    @given(
        m=st.integers(1, 200),
        k=st.integers(1, 96),
        n=st.integers(1, 200),
        act=st.sampled_from(["gelu", "relu", "none"]),
        dtype=st.sampled_from([jnp.float32, jnp.bfloat16]),
    )
    def test_matches_ref(self, m, k, n, act, dtype):
        x, w, b = _rand(0, (m, k), dtype), _rand(1, (k, n), dtype), _rand(2, (n,), dtype)
        _assert_close(
            kernels.fused_linear(x, w, b, activation=act),
            ref.fused_linear(x, w, b, activation=act),
            dtype,
        )

    def test_exact_tile_multiple(self):
        x, w, b = _rand(0, (256, 128), jnp.float32), _rand(1, (128, 256), jnp.float32), _rand(2, (256,), jnp.float32)
        _assert_close(kernels.fused_linear(x, w, b), ref.fused_linear(x, w, b), jnp.float32)

    def test_single_row_col(self):
        x, w, b = _rand(0, (1, 7), jnp.float32), _rand(1, (7, 1), jnp.float32), _rand(2, (1,), jnp.float32)
        _assert_close(kernels.fused_linear(x, w, b), ref.fused_linear(x, w, b), jnp.float32)

    def test_output_dtype_preserved(self):
        x, w, b = _rand(0, (8, 8), jnp.bfloat16), _rand(1, (8, 8), jnp.bfloat16), _rand(2, (8,), jnp.bfloat16)
        assert kernels.fused_linear(x, w, b).dtype == jnp.bfloat16

    def test_bad_activation_raises(self):
        x, w, b = _rand(0, (8, 8), jnp.float32), _rand(1, (8, 8), jnp.float32), _rand(2, (8,), jnp.float32)
        with pytest.raises(ValueError):
            kernels.fused_linear(x, w, b, activation="tanhh")

    def test_contraction_mismatch_raises(self):
        x, w, b = _rand(0, (8, 9), jnp.float32), _rand(1, (8, 8), jnp.float32), _rand(2, (8,), jnp.float32)
        with pytest.raises(AssertionError):
            kernels.fused_linear(x, w, b)

    @settings(**SETTINGS)
    @given(bm=st.sampled_from([8, 32, 128]), bn=st.sampled_from([8, 32, 128]))
    def test_block_size_invariance(self, bm, bn):
        """Result must not depend on the tile decomposition."""
        x, w, b = _rand(0, (50, 40), jnp.float32), _rand(1, (40, 60), jnp.float32), _rand(2, (60,), jnp.float32)
        _assert_close(
            kernels.fused_linear(x, w, b, block_m=bm, block_n=bn),
            ref.fused_linear(x, w, b),
            jnp.float32,
        )


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------


class TestAttention:
    @settings(**SETTINGS)
    @given(
        sq=st.integers(1, 150),
        skv=st.integers(1, 150),
        d=st.sampled_from([8, 16, 32, 64]),
        dtype=st.sampled_from([jnp.float32, jnp.bfloat16]),
    )
    def test_matches_ref(self, sq, skv, d, dtype):
        q, k, v = _rand(0, (sq, d), dtype), _rand(1, (skv, d), dtype), _rand(2, (skv, d), dtype)
        _assert_close(kernels.attention(q, k, v), ref.attention(q, k, v), dtype)

    def test_rows_sum_property(self):
        """With v = ones, attention output must be exactly ones (softmax sums to 1)."""
        q, k = _rand(0, (33, 16), jnp.float32), _rand(1, (47, 16), jnp.float32)
        v = jnp.ones((47, 16), jnp.float32)
        np.testing.assert_allclose(np.asarray(kernels.attention(q, k, v)), 1.0, rtol=1e-5)

    def test_single_kv(self):
        """One key/value: output must equal v broadcast to every query row."""
        q = _rand(0, (9, 8), jnp.float32)
        k, v = _rand(1, (1, 8), jnp.float32), _rand(2, (1, 8), jnp.float32)
        out = kernels.attention(q, k, v)
        np.testing.assert_allclose(np.asarray(out), np.tile(np.asarray(v), (9, 1)), rtol=1e-5)

    def test_large_logit_stability(self):
        """Online softmax must stay finite when logits are huge."""
        q = 50.0 * jnp.ones((16, 32), jnp.float32)
        k = 50.0 * jnp.ones((80, 32), jnp.float32)
        v = _rand(2, (80, 32), jnp.float32)
        out = np.asarray(kernels.attention(q, k, v))
        assert np.all(np.isfinite(out))
        _assert_close(out, ref.attention(q, k, v), jnp.float32)

    @settings(**SETTINGS)
    @given(bq=st.sampled_from([8, 16, 64]), bk=st.sampled_from([8, 16, 64]))
    def test_block_size_invariance(self, bq, bk):
        q, k, v = _rand(0, (70, 16), jnp.float32), _rand(1, (90, 16), jnp.float32), _rand(2, (90, 16), jnp.float32)
        _assert_close(
            kernels.attention(q, k, v, block_q=bq, block_k=bk), ref.attention(q, k, v), jnp.float32
        )

    def test_multi_head_matches_per_head(self):
        s, d, h = 32, 64, 4
        q, k, v = _rand(0, (s, d), jnp.float32), _rand(1, (s, d), jnp.float32), _rand(2, (s, d), jnp.float32)
        got = kernels.multi_head_attention(q, k, v, h)
        dh = d // h
        split = lambda t: np.asarray(t).reshape(s, h, dh).transpose(1, 0, 2)
        want = np.stack(
            [np.asarray(ref.attention(*(jnp.asarray(t[i]) for t in map(split, (q, k, v))))) for i in range(h)]
        ).transpose(1, 0, 2).reshape(s, d)
        np.testing.assert_allclose(np.asarray(got), want, rtol=3e-5, atol=3e-5)


# ---------------------------------------------------------------------------
# checksum
# ---------------------------------------------------------------------------


class TestChecksum:
    @settings(**SETTINGS)
    @given(n=st.integers(1, 5000), dtype=st.sampled_from([jnp.float32, jnp.bfloat16]))
    def test_matches_ref(self, n, dtype):
        x = _rand(0, (n,), dtype)
        got = kernels.checksum(x)
        want = ref.checksum(x)
        np.testing.assert_allclose(float(got), float(want), rtol=1e-3, atol=1e-3)

    def test_order_sensitive(self):
        """Positional weights make the checksum detect payload reordering."""
        x = jnp.arange(128, dtype=jnp.float32)
        assert abs(float(kernels.checksum(x)) - float(kernels.checksum(x[::-1]))) > 1e-3

    def test_zero_payload(self):
        assert float(kernels.checksum(jnp.zeros(100))) == 0.0

    @settings(**SETTINGS)
    @given(block=st.sampled_from([8, 64, 512, 1024]))
    def test_block_size_invariance(self, block):
        x = _rand(0, (3000,), jnp.float32)
        np.testing.assert_allclose(
            float(kernels.checksum(x, block=block)), float(ref.checksum(x)), rtol=1e-4
        )


# ---------------------------------------------------------------------------
# avg_pool
# ---------------------------------------------------------------------------


class TestAvgPool:
    @settings(**SETTINGS)
    @given(
        h_out=st.integers(1, 24),
        w_out=st.integers(1, 24),
        c=st.integers(1, 4),
        factor=st.sampled_from([1, 2, 4]),
        dtype=st.sampled_from([jnp.float32, jnp.bfloat16]),
    )
    def test_matches_ref(self, h_out, w_out, c, factor, dtype):
        img = _rand(0, (h_out * factor, w_out * factor, c), dtype)
        _assert_close(kernels.avg_pool(img, factor), ref.avg_pool(img, factor), dtype)

    def test_constant_image_is_preserved(self):
        img = jnp.full((16, 16, 3), 2.5, jnp.float32)
        out = kernels.avg_pool(img, 4)
        np.testing.assert_allclose(np.asarray(out), 2.5, rtol=1e-6)

    def test_mean_preserved(self):
        """Global mean is invariant under average pooling."""
        img = _rand(0, (32, 32, 3), jnp.float32)
        out = kernels.avg_pool(img, 4)
        np.testing.assert_allclose(
            float(jnp.mean(out)), float(jnp.mean(img)), rtol=1e-5, atol=1e-6
        )

    def test_indivisible_factor_rejected(self):
        with pytest.raises(AssertionError):
            kernels.avg_pool(_rand(0, (10, 10, 3), jnp.float32), 4)

    @settings(**SETTINGS)
    @given(br=st.sampled_from([1, 2, 8, 16]))
    def test_block_size_invariance(self, br):
        img = _rand(0, (40, 20, 3), jnp.float32)
        _assert_close(
            kernels.avg_pool(img, 2, block_rows=br), ref.avg_pool(img, 2), jnp.float32
        )


# ---------------------------------------------------------------------------
# layer_norm
# ---------------------------------------------------------------------------


class TestLayerNorm:
    @settings(**SETTINGS)
    @given(
        m=st.integers(1, 150),
        d=st.integers(2, 128),
        dtype=st.sampled_from([jnp.float32, jnp.bfloat16]),
    )
    def test_matches_ref(self, m, d, dtype):
        x = _rand(0, (m, d), dtype)
        g, b = _rand(1, (d,), dtype), _rand(2, (d,), dtype)
        _assert_close(kernels.layer_norm(x, g, b), ref.layer_norm(x, g, b), dtype)

    def test_normalized_stats(self):
        """gamma=1, beta=0 => each row has ~zero mean, ~unit variance."""
        x = _rand(0, (64, 100), jnp.float32)
        y = np.asarray(kernels.layer_norm(x, jnp.ones(100), jnp.zeros(100)))
        np.testing.assert_allclose(y.mean(axis=1), 0.0, atol=1e-5)
        np.testing.assert_allclose(y.var(axis=1), 1.0, rtol=1e-3)

    def test_shift_invariance(self):
        """LN(x + c) == LN(x) for constant row shift."""
        x = _rand(0, (16, 64), jnp.float32)
        g, b = jnp.ones(64), jnp.zeros(64)
        _assert_close(
            kernels.layer_norm(x + 100.0, g, b), kernels.layer_norm(x, g, b), jnp.float32
        )
