"""coldfaas build-time python package: L1 Pallas kernels + L2 workload
graphs + the AOT lowering pipeline.  Never imported at runtime — the rust
binary consumes only the emitted artifacts/*.hlo.txt + manifest.json."""
