"""Layer-2: the FaaS *function workloads* as JAX compute graphs.

Each deployable FaaS function in the paper's prototype (echo / date / Go
test function) is mirrored here by a real compute graph of increasing
weight, so the reproduction can also measure the paper's §IV-B claim that
platform overhead shrinks relative to function complexity (experiment E8):

  echo        -- identity over a small payload (the paper's echo app)
  checksum    -- positional-weighted reduction over a 64 KiB payload
  thumbnail   -- 4x average-pool of a 64x64 RGB image
  mlp         -- 2-layer MLP inference, Pallas fused_linear kernels
  transformer -- pre-LN transformer block (MHA + FFN), all Pallas kernels

Weights are baked in as constants from a fixed PRNG seed, so every artifact
is self-contained: the rust executor passes only the request payload.
Python never runs on the request path — these graphs are AOT-lowered to HLO
text by aot.py at build time.
"""

import os
from dataclasses import dataclass
from typing import Callable

import jax
import jax.numpy as jnp

from . import kernels
from .kernels import ref

# ---------------------------------------------------------------------------
# Workload definitions
# ---------------------------------------------------------------------------

ECHO_N = 256
CHECKSUM_N = 65536
THUMB_H, THUMB_W, THUMB_C, THUMB_FACTOR = 64, 64, 3, 4
MLP_BATCH, MLP_D_IN, MLP_D_HIDDEN = 8, 256, 512
TB_SEQ, TB_D, TB_HEADS, TB_FFN = 128, 256, 4, 1024


def _w(key: int, shape, scale: float = 0.02) -> jax.Array:
    """Deterministic baked weight (becomes an HLO constant)."""
    return scale * jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


def echo(x: jax.Array):
    """Identity over a (ECHO_N,) payload — the paper's echo/date app."""
    return (x,)


def checksum(x: jax.Array):
    """Pallas checksum reduction over a (CHECKSUM_N,) payload -> f32 scalar."""
    return (kernels.checksum(x).reshape(1),)


def thumbnail(img: jax.Array):
    """4x average-pool of a (64, 64, 3) image -> (16, 16, 3), Pallas kernel."""
    return (kernels.avg_pool(img, THUMB_FACTOR),)


def mlp(x: jax.Array):
    """2-layer MLP inference over (MLP_BATCH, MLP_D_IN), fused Pallas kernels."""
    w1, b1 = _w(10, (MLP_D_IN, MLP_D_HIDDEN)), _w(11, (MLP_D_HIDDEN,))
    w2, b2 = _w(12, (MLP_D_HIDDEN, MLP_D_IN)), _w(13, (MLP_D_IN,))
    h = kernels.fused_linear(x, w1, b1, activation="gelu")
    y = kernels.fused_linear(h, w2, b2, activation="none")
    return (y,)


def transformer(x: jax.Array):
    """Pre-LN transformer block over (TB_SEQ, TB_D): LN->MHA->res, LN->FFN->res.

    §Perf L2 optimization: the q/k/v projections are fused into ONE
    (D, 3D) matmul through the Pallas fused_linear kernel — one pass over
    the normalized activations instead of three (before/after in
    EXPERIMENTS.md §Perf)."""
    g1, be1 = jnp.ones(TB_D), jnp.zeros(TB_D)
    g2, be2 = jnp.ones(TB_D), jnp.zeros(TB_D)
    wq, wk, wv, wo = (_w(i, (TB_D, TB_D)) for i in (20, 21, 22, 23))
    w1, b1 = _w(24, (TB_D, TB_FFN)), _w(25, (TB_FFN,))
    w2, b2 = _w(26, (TB_FFN, TB_D)), _w(27, (TB_D,))

    h = kernels.layer_norm(x, g1, be1)
    if os.environ.get("COLDFAAS_UNFUSED_QKV"):
        # Pre-optimization variant kept for the §Perf A/B (three passes).
        q = kernels.fused_linear(h, wq, jnp.zeros(TB_D), activation="none")
        k = kernels.fused_linear(h, wk, jnp.zeros(TB_D), activation="none")
        v = kernels.fused_linear(h, wv, jnp.zeros(TB_D), activation="none")
    else:
        wqkv = jnp.concatenate([wq, wk, wv], axis=1)  # (D, 3D), baked constant
        qkv = kernels.fused_linear(h, wqkv, jnp.zeros(3 * TB_D), activation="none")
        q, k, v = jnp.split(qkv, 3, axis=1)
    a = kernels.multi_head_attention(q, k, v, TB_HEADS)
    a = kernels.fused_linear(a, wo, jnp.zeros(TB_D), activation="none")
    x = x + a

    h = kernels.layer_norm(x, g2, be2)
    f = kernels.fused_linear(h, w1, b1, activation="gelu")
    f = kernels.fused_linear(f, w2, b2, activation="none")
    return (x + f,)


# Pure-jnp twins used to cross-check the full graphs (not just kernels).
def mlp_ref(x: jax.Array):
    w1, b1 = _w(10, (MLP_D_IN, MLP_D_HIDDEN)), _w(11, (MLP_D_HIDDEN,))
    w2, b2 = _w(12, (MLP_D_HIDDEN, MLP_D_IN)), _w(13, (MLP_D_IN,))
    return (ref.fused_linear(ref.fused_linear(x, w1, b1, "gelu"), w2, b2, "none"),)


def transformer_ref(x: jax.Array):
    g1, be1 = jnp.ones(TB_D), jnp.zeros(TB_D)
    g2, be2 = jnp.ones(TB_D), jnp.zeros(TB_D)
    wq, wk, wv, wo = (_w(i, (TB_D, TB_D)) for i in (20, 21, 22, 23))
    w1, b1 = _w(24, (TB_D, TB_FFN)), _w(25, (TB_FFN,))
    w2, b2 = _w(26, (TB_FFN, TB_D)), _w(27, (TB_D,))
    h = ref.layer_norm(x, g1, be1)
    q, k, v = (jnp.dot(h, w) for w in (wq, wk, wv))
    dh = TB_D // TB_HEADS
    split = lambda t: t.reshape(TB_SEQ, TB_HEADS, dh).transpose(1, 0, 2)
    a = jax.vmap(ref.attention)(split(q), split(k), split(v))
    a = a.transpose(1, 0, 2).reshape(TB_SEQ, TB_D) @ wo
    x = x + a
    h = ref.layer_norm(x, g2, be2)
    f = ref.fused_linear(ref.fused_linear(h, w1, b1, "gelu"), w2, b2, "none")
    return (x + f,)


# ---------------------------------------------------------------------------
# Registry consumed by aot.py and the tests
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Workload:
    name: str
    fn: Callable
    input_shape: tuple
    flops: int  # rough per-invocation cost, used by the complexity experiment
    ref_fn: Callable | None = None
    doc: str = ""


def _mlp_flops() -> int:
    return 2 * MLP_BATCH * (MLP_D_IN * MLP_D_HIDDEN + MLP_D_HIDDEN * MLP_D_IN)


def _tb_flops() -> int:
    proj = 4 * 2 * TB_SEQ * TB_D * TB_D
    attn = 2 * 2 * TB_SEQ * TB_SEQ * TB_D
    ffn = 2 * 2 * TB_SEQ * TB_D * TB_FFN
    return proj + attn + ffn


WORKLOADS: dict[str, Workload] = {
    w.name: w
    for w in [
        Workload("echo", echo, (ECHO_N,), 0, doc="identity payload echo"),
        Workload("checksum", checksum, (CHECKSUM_N,), 2 * CHECKSUM_N, doc="payload checksum"),
        Workload(
            "thumbnail",
            thumbnail,
            (THUMB_H, THUMB_W, THUMB_C),
            THUMB_H * THUMB_W * THUMB_C,
            doc="image 4x downscale",
        ),
        Workload("mlp", mlp, (MLP_BATCH, MLP_D_IN), _mlp_flops(), ref_fn=mlp_ref, doc="MLP inference"),
        Workload(
            "transformer",
            transformer,
            (TB_SEQ, TB_D),
            _tb_flops(),
            ref_fn=transformer_ref,
            doc="transformer block inference",
        ),
    ]
}


def test_input(shape: tuple) -> jax.Array:
    """The deterministic check vector mirrored by the rust integration tests:
    flat[i] = sin(0.37 * i) * 0.5 (f32), reshaped to `shape`."""
    n = 1
    for s in shape:
        n *= s
    i = jnp.arange(n, dtype=jnp.float32)
    return (jnp.sin(0.37 * i) * 0.5).reshape(shape)
