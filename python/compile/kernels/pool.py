"""Pallas kernel: 2-D average pooling for the thumbnail workload.

Rows pool independently, so the grid tiles output rows; each program
instance loads a (block_rows * factor, W, C) stripe into VMEM, reduces the
factor x factor windows in f32, and writes the (block_rows, W/factor, C)
output tile.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_OUT_ROWS = 8


def _kernel(x_ref, o_ref, *, factor):
    x = x_ref[...].astype(jnp.float32)
    bh_in, w, c = x.shape
    bh_out = bh_in // factor
    pooled = x.reshape(bh_out, factor, w // factor, factor, c).mean(axis=(1, 3))
    o_ref[...] = pooled.astype(o_ref.dtype)


def _pad_to(n: int, block: int) -> int:
    return ((n + block - 1) // block) * block


@functools.partial(jax.jit, static_argnames=("factor", "block_rows"))
def avg_pool(img: jax.Array, factor: int, block_rows: int = BLOCK_OUT_ROWS) -> jax.Array:
    """Average-pool a (H, W, C) image by `factor` along H and W.

    H and W must be divisible by `factor` (true for the thumbnail
    workload); the output row axis is padded to the tile grid and sliced.
    """
    h, w, c = img.shape
    assert h % factor == 0 and w % factor == 0, "image dims must divide the pool factor"
    h_out, w_out = h // factor, w // factor
    br = min(block_rows, _pad_to(h_out, 1))
    h_out_p = _pad_to(h_out, br)
    img_p = jnp.pad(img, ((0, (h_out_p - h_out) * factor), (0, 0), (0, 0)))

    out = pl.pallas_call(
        functools.partial(_kernel, factor=factor),
        grid=(h_out_p // br,),
        in_specs=[pl.BlockSpec((br * factor, w, c), lambda i: (i, 0, 0))],
        out_specs=pl.BlockSpec((br, w_out, c), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((h_out_p, w_out, c), img.dtype),
        interpret=True,
    )(img_p)
    return out[:h_out]
