"""Pallas kernel: tiled fused linear layer  y = act(x @ w + b).

The FaaS "user function" hot-spot (MLP / transformer feed-forward) as a
single fused kernel: one HBM->VMEM round-trip per tile instead of three
separate matmul / bias / activation passes.

TPU mapping (see DESIGN.md §Hardware-Adaptation): the (bm, bn) output tile
and its (bm, K) / (K, bn) operand stripes are the VMEM working set; the
inner jnp.dot maps onto 128x128 MXU passes.  Lowered with interpret=True so
the CPU PJRT client (rust side) can execute the resulting HLO.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile sizes: one MXU-shaped output tile per program instance.
BLOCK_M = 128
BLOCK_N = 128


def _act(y: jax.Array, activation: str) -> jax.Array:
    if activation == "gelu":
        return jax.nn.gelu(y, approximate=True)
    if activation == "relu":
        return jnp.maximum(y, 0.0)
    if activation == "none":
        return y
    raise ValueError(f"unknown activation {activation!r}")


def _kernel(x_ref, w_ref, b_ref, o_ref, *, activation):
    x = x_ref[...].astype(jnp.float32)
    w = w_ref[...].astype(jnp.float32)
    b = b_ref[...].astype(jnp.float32)
    y = jnp.dot(x, w, preferred_element_type=jnp.float32) + b[None, :]
    o_ref[...] = _act(y, activation).astype(o_ref.dtype)


def _pad_to(n: int, block: int) -> int:
    return ((n + block - 1) // block) * block


@functools.partial(jax.jit, static_argnames=("activation", "block_m", "block_n"))
def fused_linear(
    x: jax.Array,
    w: jax.Array,
    b: jax.Array,
    activation: str = "gelu",
    block_m: int = BLOCK_M,
    block_n: int = BLOCK_N,
) -> jax.Array:
    """act(x @ w + b) with x: (M, K), w: (K, N), b: (N,).

    Arbitrary M/N/K are supported: operands are zero-padded up to the tile
    grid and the result is sliced back.  Padded output rows/cols never mix
    with real data (zero rows of x produce garbage rows that are sliced off;
    padded cols of w/b produce garbage cols that are sliced off).
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"x/w contraction mismatch: {k} vs {k2}"
    assert b.shape == (n,), f"bias shape {b.shape} != ({n},)"

    bm = min(block_m, _pad_to(m, 8))
    bn = min(block_n, _pad_to(n, 8))
    mp, np_ = _pad_to(m, bm), _pad_to(n, bn)
    xp = jnp.pad(x, ((0, mp - m), (0, 0)))
    wp = jnp.pad(w, ((0, 0), (0, np_ - n)))
    bp = jnp.pad(b, (0, np_ - n))

    out = pl.pallas_call(
        functools.partial(_kernel, activation=activation),
        grid=(mp // bm, np_ // bn),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
            pl.BlockSpec((bn,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), x.dtype),
        interpret=True,
    )(xp, wp, bp)
    return out[:m, :n]
