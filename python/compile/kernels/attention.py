"""Pallas kernel: single-head scaled-dot-product attention, online softmax.

Flash-attention structure adapted for TPU/VMEM (DESIGN.md
§Hardware-Adaptation): the grid tiles the query sequence; inside each
program instance a fori_loop streams key/value tiles through VMEM and keeps
the (running max, running denominator, accumulator) triple so the (S_q,
S_kv) score matrix never materializes in HBM — the paper-era GPU trick
(threadblock tiling of S) re-expressed as a BlockSpec + in-kernel loop.

Ragged S_kv is handled with an explicit length operand and -inf masking, so
the wrapper can zero-pad both sequence axes to tile multiples.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_Q = 64
BLOCK_K = 64


def _kernel(len_ref, q_ref, k_ref, v_ref, o_ref, *, block_k):
    d = q_ref.shape[-1]
    scale = 1.0 / (d**0.5)
    q = q_ref[...].astype(jnp.float32) * scale
    kv_len = len_ref[0]
    n_kv_blocks = k_ref.shape[0] // block_k
    bq = q.shape[0]

    def body(j, carry):
        m, l, acc = carry
        kb = pl.load(k_ref, (pl.ds(j * block_k, block_k), slice(None))).astype(jnp.float32)
        vb = pl.load(v_ref, (pl.ds(j * block_k, block_k), slice(None))).astype(jnp.float32)
        s = jnp.dot(q, kb.T, preferred_element_type=jnp.float32)  # (bq, bk)
        col = j * block_k + jax.lax.broadcasted_iota(jnp.int32, (1, block_k), 1)
        s = jnp.where(col < kv_len, s, -jnp.inf)
        m_new = jnp.maximum(m, jnp.max(s, axis=1))
        # alpha rescales the old accumulator; rows that were fully masked so
        # far have m == -inf only before the first valid column, and column 0
        # is always valid, so m_new is finite from block 0 on.
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_new = l * alpha + jnp.sum(p, axis=1)
        acc_new = acc * alpha[:, None] + jnp.dot(p, vb, preferred_element_type=jnp.float32)
        return m_new, l_new, acc_new

    m0 = jnp.full((bq,), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((bq,), jnp.float32)
    acc0 = jnp.zeros((bq, d), jnp.float32)
    m, l, acc = jax.lax.fori_loop(0, n_kv_blocks, body, (m0, l0, acc0))
    o_ref[...] = (acc / l[:, None]).astype(o_ref.dtype)


def _pad_to(n: int, block: int) -> int:
    return ((n + block - 1) // block) * block


@functools.partial(jax.jit, static_argnames=("block_q", "block_k"))
def attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    block_q: int = BLOCK_Q,
    block_k: int = BLOCK_K,
) -> jax.Array:
    """softmax(q k^T / sqrt(d)) v with q: (S_q, D), k/v: (S_kv, D)."""
    sq, d = q.shape
    skv, d2 = k.shape
    assert d == d2 and v.shape == k.shape

    bq = min(block_q, _pad_to(sq, 8))
    bk = min(block_k, _pad_to(skv, 8))
    sqp, skvp = _pad_to(sq, bq), _pad_to(skv, bk)
    qp = jnp.pad(q, ((0, sqp - sq), (0, 0)))
    kp = jnp.pad(k, ((0, skvp - skv), (0, 0)))
    vp = jnp.pad(v, ((0, skvp - skv), (0, 0)))
    kv_len = jnp.array([skv], dtype=jnp.int32)

    out = pl.pallas_call(
        functools.partial(_kernel, block_k=bk),
        grid=(sqp // bq,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((bq, d), lambda i: (i, 0)),
            pl.BlockSpec((skvp, d), lambda i: (0, 0)),
            pl.BlockSpec((skvp, d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bq, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((sqp, d), q.dtype),
        interpret=True,
    )(kv_len, qp, kp, vp)
    return out[:sq]


def multi_head_attention(q: jax.Array, k: jax.Array, v: jax.Array, n_heads: int) -> jax.Array:
    """(S, D) inputs split into n_heads of D//n_heads, single-head kernel per head."""
    s, d = q.shape
    assert d % n_heads == 0
    dh = d // n_heads
    split = lambda t: t.reshape(s, n_heads, dh).transpose(1, 0, 2)
    outs = jax.vmap(attention)(split(q), split(k), split(v))  # (H, S, dh)
    return outs.transpose(1, 0, 2).reshape(s, d)
