"""Pallas kernel: positional-weighted checksum reduction.

The 'checksum over request payload' FaaS workload: a sequential-grid
reduction that accumulates one VMEM tile at a time into a (1,1) output ref.
Demonstrates the multi-visit-output accumulation pattern (init on first
program instance, += after), with iota-derived positional weights and
tail masking so arbitrary lengths work.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 1024


def _kernel(x_ref, o_ref, *, block, n_total):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        o_ref[0, 0] = 0.0

    x = x_ref[...].astype(jnp.float32)
    idx = i * block + jax.lax.iota(jnp.int32, block)
    w = (((idx % 64) + 1).astype(jnp.float32)) / 64.0
    contrib = jnp.where(idx < n_total, x * w, 0.0)
    o_ref[0, 0] += jnp.sum(contrib)


def _pad_to(n: int, block: int) -> int:
    return ((n + block - 1) // block) * block


@functools.partial(jax.jit, static_argnames=("block",))
def checksum(x: jax.Array, block: int = BLOCK) -> jax.Array:
    """sum_i x_i * (((i % 64) + 1) / 64) over a 1-D array, any length >= 1."""
    (n,) = x.shape
    blk = min(block, _pad_to(n, 8))
    np_ = _pad_to(n, blk)
    xp = jnp.pad(x, (0, np_ - n))
    out = pl.pallas_call(
        functools.partial(_kernel, block=blk, n_total=n),
        grid=(np_ // blk,),
        in_specs=[pl.BlockSpec((blk,), lambda i: (i,))],
        out_specs=pl.BlockSpec((1, 1), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, 1), jnp.float32),
        interpret=True,
    )(xp)
    return out[0, 0]
