"""Layer-1 Pallas kernels (build-time only; lowered into the AOT HLO).

All kernels use interpret=True so the lowered HLO contains plain XLA ops
executable by the CPU PJRT client on the rust side.  `ref` holds the
pure-jnp oracles used by the pytest/hypothesis correctness suite.
"""

from . import ref
from .attention import attention, multi_head_attention
from .fused_linear import fused_linear
from .norm import layer_norm
from .pool import avg_pool
from .reduce import checksum

__all__ = [
    "ref",
    "attention",
    "multi_head_attention",
    "fused_linear",
    "layer_norm",
    "avg_pool",
    "checksum",
]
