"""Pure-jnp reference oracles for the Pallas kernels.

Every kernel in this package has an oracle here with the same signature.
The pytest suite (python/tests/) sweeps shapes/dtypes with hypothesis and
asserts allclose between kernel and oracle; the AOT pipeline also embeds
oracle-derived check values into the artifact manifest so the rust side can
verify numerics end to end.
"""

import jax
import jax.numpy as jnp


def fused_linear(x: jax.Array, w: jax.Array, b: jax.Array, activation: str = "gelu") -> jax.Array:
    """y = act(x @ w + b).  x: (M, K), w: (K, N), b: (N,)."""
    y = jnp.dot(x.astype(jnp.float32), w.astype(jnp.float32)) + b.astype(jnp.float32)
    if activation == "gelu":
        y = jax.nn.gelu(y, approximate=True)
    elif activation == "relu":
        y = jnp.maximum(y, 0.0)
    elif activation == "none":
        pass
    else:
        raise ValueError(f"unknown activation {activation!r}")
    return y.astype(x.dtype)


def attention(q: jax.Array, k: jax.Array, v: jax.Array) -> jax.Array:
    """Scaled dot-product attention for a single head.

    q: (S_q, D), k/v: (S_kv, D).  Numerically stable softmax in f32.
    """
    d = q.shape[-1]
    logits = jnp.einsum("sd,td->st", q.astype(jnp.float32), k.astype(jnp.float32))
    logits = logits / jnp.sqrt(jnp.float32(d))
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("st,td->sd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)


def checksum(x: jax.Array) -> jax.Array:
    """Positional weighted sum: sum_i x_i * w_i with w_i = ((i % 64) + 1) / 64.

    A cheap, order-sensitive reduction standing in for the 'checksum over the
    request payload' FaaS workload.  Returns a f32 scalar.
    """
    n = x.shape[0]
    w = ((jnp.arange(n, dtype=jnp.float32) % 64.0) + 1.0) / 64.0
    return jnp.sum(x.astype(jnp.float32) * w)


def layer_norm(x: jax.Array, gamma: jax.Array, beta: jax.Array, eps: float = 1e-5) -> jax.Array:
    """Row-wise layer norm.  x: (..., D)."""
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * gamma.astype(jnp.float32) + beta.astype(jnp.float32)).astype(x.dtype)


def avg_pool(img: jax.Array, factor: int) -> jax.Array:
    """Average-pool a (H, W, C) image by `factor` along H and W."""
    h, w, c = img.shape
    assert h % factor == 0 and w % factor == 0
    y = img.astype(jnp.float32).reshape(h // factor, factor, w // factor, factor, c)
    return jnp.mean(y, axis=(1, 3)).astype(img.dtype)
