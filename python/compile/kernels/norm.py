"""Pallas kernel: row-tiled layer normalization.

Rows are independent, so the grid tiles the row axis; gamma/beta stay
resident in VMEM across all program instances (BlockSpec pins them to
block 0).  Statistics are computed in f32 regardless of input dtype.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_ROWS = 128


def _kernel(x_ref, g_ref, b_ref, o_ref, *, eps):
    x = x_ref[...].astype(jnp.float32)
    mu = jnp.mean(x, axis=1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    g = g_ref[...].astype(jnp.float32)
    b = b_ref[...].astype(jnp.float32)
    o_ref[...] = (y * g[None, :] + b[None, :]).astype(o_ref.dtype)


def _pad_to(n: int, block: int) -> int:
    return ((n + block - 1) // block) * block


@functools.partial(jax.jit, static_argnames=("eps", "block_rows"))
def layer_norm(
    x: jax.Array,
    gamma: jax.Array,
    beta: jax.Array,
    eps: float = 1e-5,
    block_rows: int = BLOCK_ROWS,
) -> jax.Array:
    """Row-wise layer norm over the last axis of a (M, D) array."""
    m, d = x.shape
    assert gamma.shape == (d,) and beta.shape == (d,)
    br = min(block_rows, _pad_to(m, 8))
    mp = _pad_to(m, br)
    xp = jnp.pad(x, ((0, mp - m), (0, 0)))
    out = pl.pallas_call(
        functools.partial(_kernel, eps=eps),
        grid=(mp // br,),
        in_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((mp, d), x.dtype),
        interpret=True,
    )(xp, gamma, beta)
    return out[:m]
