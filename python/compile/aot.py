"""AOT pipeline: lower every workload graph to HLO *text* + manifest.

HLO text (not `.serialize()`) is the interchange format: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids that xla_extension 0.5.1 (the
version behind the rust `xla` 0.1.6 crate) rejects; the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Outputs, per workload:
  artifacts/<name>.hlo.txt   -- the lowered module (return_tuple=True)
  artifacts/manifest.json    -- input/output specs + numeric check values

The manifest embeds oracle-computed check sums over the deterministic test
input (model.test_input) so the rust runtime tests can verify end-to-end
numerics without any python on the request path.

Usage:  python -m compile.aot [--out-dir ../artifacts] [--only name,...]
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (ids reassigned by the parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_workload(w: model.Workload) -> tuple[str, dict]:
    spec = jax.ShapeDtypeStruct(w.input_shape, jnp.float32)
    lowered = jax.jit(w.fn).lower(spec)
    text = to_hlo_text(lowered)

    # Evaluate on the deterministic check vector for the rust-side test.
    x = model.test_input(w.input_shape)
    outs = jax.jit(w.fn)(x)
    out_specs = []
    checks = []
    for o in outs:
        o = np.asarray(o)
        out_specs.append({"shape": list(o.shape), "dtype": str(o.dtype)})
        checks.append(
            {
                "sum": float(np.sum(o, dtype=np.float64)),
                "l2": float(np.sqrt(np.sum(np.square(o, dtype=np.float64)))),
                "first": float(o.reshape(-1)[0]) if o.size else 0.0,
            }
        )

    entry = {
        "name": w.name,
        "file": f"{w.name}.hlo.txt",
        "doc": w.doc,
        "flops": w.flops,
        "inputs": [{"shape": list(w.input_shape), "dtype": "float32"}],
        "outputs": out_specs,
        "check": {"input": "sin037", "tol": 5e-4, "outputs": checks},
    }
    return text, entry


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", default="", help="comma-separated workload names")
    args = ap.parse_args()

    names = [n for n in args.only.split(",") if n] or list(model.WORKLOADS)
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = {"format": 1, "functions": []}
    for name in names:
        w = model.WORKLOADS[name]
        text, entry = lower_workload(w)
        path = os.path.join(args.out_dir, entry["file"])
        with open(path, "w") as f:
            f.write(text)
        manifest["functions"].append(entry)
        print(f"  {name:12s} -> {path}  ({len(text)} chars, flops={w.flops})")

    mpath = os.path.join(args.out_dir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"  manifest     -> {mpath}")


if __name__ == "__main__":
    main()
