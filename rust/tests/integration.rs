//! Cross-module integration tests: every experiment regenerates with its
//! paper checks green at reduced load, results are deterministic per seed,
//! and the cross-figure orderings the paper's argument depends on hold.

use coldfaas::experiments::{self, ExpConfig};
use coldfaas::fnplat::{run_scenario, DriverKind, Scenario};
use coldfaas::metrics::Recorder;
use coldfaas::sim::Host;
use coldfaas::virt::Tech;
use coldfaas::workload::{record, run_gateway_front};

fn quick() -> ExpConfig {
    ExpConfig::quick()
}

#[test]
fn all_experiments_pass_their_paper_checks() {
    let cfg = quick();
    for name in experiments::ALL_EXPERIMENTS {
        let report = experiments::by_name(name, &cfg).expect("known experiment");
        assert!(
            report.all_pass(),
            "experiment {name} has failing checks:\n{}",
            report.failures().join("\n")
        );
    }
}

#[test]
fn unknown_experiment_is_none() {
    assert!(experiments::by_name("fig9", &quick()).is_none());
}

#[test]
fn experiments_deterministic_per_seed() {
    let cfg = quick();
    let a = experiments::fig1(&cfg).render();
    let b = experiments::fig1(&cfg).render();
    assert_eq!(a, b, "same seed must give byte-identical reports");
    let cfg2 = ExpConfig { seed: cfg.seed + 1, ..quick() };
    let c = experiments::fig1(&cfg2).render();
    assert_ne!(a, c, "different seed must actually change samples");
}

/// The paper's §III conclusion as one cross-technology ordering, measured
/// through the full gateway + DES stack (not just nominal sums).
#[test]
fn measured_startup_ordering_across_figures() {
    let mut rec = Recorder::new();
    for tech in [
        Tech::Process,
        Tech::Solo5Spt,
        Tech::IncludeOsHvt,
        Tech::Gvisor,
        Tech::Runc,
        Tech::Firecracker,
        Tech::DockerRunc,
        Tech::Kata,
    ] {
        let r = run_gateway_front(tech.pipeline(), 5, 2000, Host::default(), 99);
        record(&mut rec, tech.name(), &r);
    }
    let p50 = |n: &str| rec.quantile(n, 0.5).unwrap();
    // unikernel land < container land < VM land, docker over everything OCI.
    assert!(p50("process") < p50("includeos-hvt"));
    assert!(p50("solo5-spt") < p50("includeos-hvt"));
    assert!(p50("includeos-hvt") < p50("gvisor") / 5.0);
    assert!(p50("gvisor") < p50("runc"));
    assert!(p50("runc") < p50("docker-runc"));
    assert!(p50("firecracker") < p50("kata") / 3.0);
    assert!(p50("docker-runc") < p50("kata") * 2.0);
}

/// Table I's rows, cross-checked against Fig 4's local numbers: cloud
/// deployment must cost more than the local lab for the same driver.
#[test]
fn cloud_costs_more_than_local() {
    let local = run_scenario(
        &Scenario::local(DriverKind::IncludeOsCold, 4, 1200, false),
        Host::default(),
    );
    let cloud = run_scenario(
        &Scenario::cloud(DriverKind::IncludeOsCold, 1200, false, 0),
        Host::default(),
    );
    assert!(
        cloud.cold_median_ms() > local.cold_median_ms() + 5.0,
        "cloud {} vs local {}",
        cloud.cold_median_ms(),
        local.cold_median_ms()
    );
}

/// The headline sentence of the abstract, end to end: the cold-only
/// prototype's latency (incl. connection setup) is in the same band as
/// AWS Lambda's *warm* path.
#[test]
fn abstract_headline_cold_matches_lambda_warm() {
    let rows = experiments::cloud::table1_rows(&quick());
    let includeos_total = rows[0].cold_ms + rows[0].conn_ms;
    let lambda_warm_total = rows[2].warm_ms.unwrap() + rows[2].conn_ms;
    assert!(
        includeos_total < 1.1 * lambda_warm_total,
        "cold unikernel {includeos_total} ms should be <= warm lambda {lambda_warm_total} ms"
    );
}

/// Fn-Docker's cold start must sit *below* standalone Docker's (the agent
/// skips the CLI) but far above IncludeOS — the three-way wedge in §IV.
#[test]
fn fn_cold_start_wedge() {
    let fn_docker = DriverKind::DockerWarm.nominal_cold_ms();
    let standalone = Tech::DockerRunc.nominal_startup_ms();
    let includeos = DriverKind::IncludeOsCold.nominal_cold_ms();
    assert!(fn_docker < standalone);
    assert!(includeos * 10.0 < fn_docker);
}

#[test]
fn waste_experiment_cold_only_is_free_and_flat() {
    for bursty in [false, true] {
        let pts = experiments::waste::waste_points(&quick(), bursty);
        let cold = pts.last().unwrap();
        assert_eq!(cold.idle_gb_seconds, 0.0);
        assert_eq!(cold.monitor_events, 0);
        assert_eq!(cold.cold_fraction, 1.0);
        assert!(cold.p99_ms / cold.p50_ms < 2.0, "cold-only tail must stay flat");
    }
}

#[test]
fn complexity_overhead_amortizes() {
    let rows = experiments::complexity::complexity_rows(&quick(), false);
    let first = rows.first().unwrap();
    let last = rows.last().unwrap();
    assert!(first.overhead_share > 0.9, "echo is all overhead: {}", first.overhead_share);
    assert!(
        last.overhead_share < 0.6,
        "transformer amortizes the platform: {}",
        last.overhead_share
    );
}

/// Artifacts + manifest + PJRT round trip — requires `make artifacts`.
#[test]
fn artifacts_manifest_matches_python_emitter() {
    let dir = coldfaas::runtime::default_artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: AOT artifacts not built (run `make artifacts`)");
        return;
    }
    let m = coldfaas::runtime::Manifest::load(&dir).unwrap();
    let names: Vec<&str> = m.functions.iter().map(|f| f.name.as_str()).collect();
    for expected in ["echo", "checksum", "thumbnail", "mlp", "transformer"] {
        assert!(names.contains(&expected), "manifest missing {expected}");
    }
    for f in &m.functions {
        assert!(m.hlo_path(f).exists(), "{} artifact file missing", f.name);
        assert_eq!(f.inputs.len(), 1);
        assert_eq!(f.outputs.len(), 1);
        assert!(f.checks[0].sum.is_finite());
    }
}

#[test]
fn pjrt_runtime_verifies_all_functions() {
    let dir = coldfaas::runtime::default_artifacts_dir();
    if !cfg!(feature = "pjrt") || !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts/pjrt backend unavailable");
        return;
    }
    let rt = coldfaas::runtime::Runtime::load(&dir).expect("run `make artifacts` first");
    for name in rt.names() {
        let rep = rt.verify(name).unwrap();
        assert!(rep.pass, "{name} numerics drifted from the jax oracle: {rep:?}");
    }
}

/// The policy lab rides the same substrate as the paper experiments:
/// E12 is part of `ALL_EXPERIMENTS` (covered above) and its cold-only x
/// unikernel row must agree with E9's cold-only conclusion.
#[test]
fn policy_lab_cold_only_matches_waste_experiment() {
    let mut cfg = experiments::policies::e12_config(&quick());
    // Reduced load: this cross-check is structural, not statistical.
    cfg.tenant.duration_s = 60.0;
    cfg.tenant.total_rps = 80.0;
    let cells = experiments::policies::policy_cells(&cfg);
    let inc = cells
        .iter()
        .find(|c| {
            c.driver == DriverKind::IncludeOsCold && c.policy == "cold-only"
        })
        .expect("cell present");
    assert_eq!(inc.idle_gb_seconds, 0.0);
    assert_eq!(inc.monitor_events, 0);
    assert_eq!(inc.cold_fraction, 1.0);
    assert!(inc.on_frontier, "zero-waste cold-only row must be Pareto-optimal");
}
