//! Observability-layer integration pins (S25): span chains must be
//! complete, and observation must stay strictly distinct from the
//! platform work it watches (`monitor_events` vs telemetry samples).

use coldfaas::experiments::chaos::ChaosConfig;
use coldfaas::experiments::replay::{replay_chaos_cell, DEFAULT_CELL};
use coldfaas::obs::ObsConfig;
use coldfaas::platform::SchedPolicy;
use coldfaas::runtime::Json;
use coldfaas::sim::Host;
use coldfaas::workload::tenants::TenantConfig;

/// A small chaos grid whose faulted leg exercises every lifecycle edge:
/// warm/spec/cold dispatches, crashes, retries, restarts.
fn cfg() -> ChaosConfig {
    ChaosConfig {
        tenant: TenantConfig {
            functions: 200,
            duration_s: 30.0,
            total_rps: 40.0,
            seed: 0x0B5,
            ..Default::default()
        },
        nodes: 4,
        cores_per_node: 4,
        schedulers: vec![SchedPolicy::LeastLoaded],
        host: Host::default(),
        timeseries: false,
    }
}

/// Every span that opens must close, and every instant must tie back to
/// a counted platform outcome — on an unwindowed, uncapped trace the
/// trace IS the ledger: `B` events = `E` events = served + killed
/// (every dispatch that reached a pool), and the fault instants match
/// the fault counters exactly.
#[test]
fn span_chains_are_complete_and_match_the_counters() {
    let obs = ObsConfig { trace: true, ..Default::default() };
    let out = replay_chaos_cell(&cfg(), DEFAULT_CELL, &obs, true).unwrap();
    let r = &out.result;
    let doc = Json::parse(r.trace_json.as_ref().expect("tracing was on")).expect("trace parses");
    let events = doc.get("traceEvents").and_then(Json::as_arr).expect("traceEvents array");

    let ph = |e: &Json| e.get("ph").and_then(Json::as_str).unwrap_or("").to_string();
    let count_ph = |want: &str| events.iter().filter(|e| ph(e) == want).count() as u64;
    let count_instant = |name: &str| {
        events
            .iter()
            .filter(|e| ph(e) == "i" && e.get("name").and_then(Json::as_str) == Some(name))
            .count() as u64
    };

    // The faulted leg must actually have exercised the fault machinery,
    // or the instant assertions below are vacuous.
    assert!(r.served > 0 && r.crashes > 0, "chaos leg too quiet to pin");

    let begins = count_ph("B");
    assert_eq!(begins, count_ph("E"), "every opened span must close");
    assert_eq!(begins, r.served + r.killed, "one span per dispatch that reached a pool");
    assert_eq!(count_instant("reject"), r.rejected);
    assert_eq!(count_instant("retry"), r.retries);
    assert_eq!(count_instant("crash"), r.crashes);
    assert_eq!(count_instant("restart"), r.restarts);
    assert_eq!(count_instant("prewarm-boot"), r.prewarm_boots);
}

/// `monitor_events` counts the keep-alive poller's billable scans of
/// idle warm slots — platform work the pool *causes* — while telemetry
/// samples are pure observation.  A cold-only cell must keep the former
/// at exactly zero even while the latter is busy sampling; a keep-alive
/// cell pays for its monitoring.
#[test]
fn monitor_events_stay_zero_under_observation() {
    let obs = ObsConfig { telemetry_interval_ns: 1_000_000_000, ..Default::default() };
    let cold =
        replay_chaos_cell(&cfg(), "includeos+cold-only+least-loaded", &obs, true).unwrap().result;
    assert_eq!(cold.monitor_events, 0, "nothing idles under cold-only");
    assert!(cold.profile.telemetry_samples > 0, "telemetry was on and sampling");
    assert!(!cold.telemetry.expect("telemetry series present").is_empty());

    let warm = replay_chaos_cell(&cfg(), DEFAULT_CELL, &obs, true).unwrap().result;
    assert!(warm.monitor_events > 0, "keep-alive pools pay for their monitor scans");
}
