//! Live-stack integration: the real HTTP gateway + coordinator + PJRT
//! engine threads under concurrent load.  Requires `make artifacts`.

// Benches and the live-stack test time real work on purpose (clippy
// disallowed-methods mirrors detlint DL001; see DESIGN.md S28).
#![allow(clippy::disallowed_methods)]

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use coldfaas::coordinator::{Config, Coordinator, SchedMode};
use coldfaas::gateway::http::http_request;
use coldfaas::runtime::Json;

/// The AOT artifacts exist and the crate was built with the real PJRT
/// backend; every live-stack test needs both and skips otherwise.
fn artifacts_ready() -> bool {
    cfg!(feature = "pjrt")
        && coldfaas::runtime::default_artifacts_dir().join("manifest.json").exists()
}

macro_rules! require_artifacts {
    () => {
        if !artifacts_ready() {
            eprintln!("skipping: artifacts/pjrt backend unavailable");
            return;
        }
    };
}

fn cfg(mode: SchedMode, functions: &[&str]) -> Config {
    Config {
        mode,
        time_scale: 0.0, // keep tests fast; model values still reported
        engine_threads: 1,
        gateway_workers: 8,
        functions: functions.iter().map(|s| s.to_string()).collect(),
        ..Config::default()
    }
}

#[test]
fn cold_only_http_under_concurrent_load() {
    require_artifacts!();
    let coord = Coordinator::start(cfg(SchedMode::ColdOnly, &["echo"])).expect("make artifacts");
    let srv = coord.serve("127.0.0.1:0").unwrap();
    let addr = srv.addr();
    let errors = Arc::new(AtomicU64::new(0));
    let handles: Vec<_> = (0..8)
        .map(|_| {
            let errors = errors.clone();
            std::thread::spawn(move || {
                for _ in 0..25 {
                    match http_request(addr, "POST", "/invoke/echo", b"") {
                        Ok((200, body)) => {
                            let text = String::from_utf8(body).unwrap();
                            if !text.contains("\"cold\":true") {
                                errors.fetch_add(1, Ordering::Relaxed);
                            }
                        }
                        _ => {
                            errors.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    assert_eq!(errors.load(Ordering::Relaxed), 0);
    assert_eq!(coord.stats.requests.load(Ordering::Relaxed), 200);
    assert_eq!(coord.stats.cold_starts.load(Ordering::Relaxed), 200);
    assert_eq!(coord.stats.warm_hits.load(Ordering::Relaxed), 0);
    srv.shutdown();
}

#[test]
fn warm_pool_mode_reuses_executors_over_http() {
    require_artifacts!();
    let coord = Coordinator::start(cfg(SchedMode::WarmPool, &["echo"])).expect("make artifacts");
    let srv = coord.serve("127.0.0.1:0").unwrap();
    // Sequential requests: first cold, rest warm.
    for i in 0..10 {
        let (status, body) = http_request(srv.addr(), "POST", "/invoke/echo", b"").unwrap();
        assert_eq!(status, 200);
        let text = String::from_utf8(body).unwrap();
        if i == 0 {
            assert!(text.contains("\"cold\":true"), "{text}");
        } else {
            assert!(text.contains("\"cold\":false"), "{text}");
        }
    }
    let (waste, _) = coord.waste_snapshot();
    assert!(waste > 0.0, "warm pool must accumulate idle waste");
    srv.shutdown();
}

#[test]
fn stats_endpoint_is_valid_json_with_counts() {
    require_artifacts!();
    let coord = Coordinator::start(cfg(SchedMode::ColdOnly, &["echo"])).expect("make artifacts");
    let srv = coord.serve("127.0.0.1:0").unwrap();
    for _ in 0..5 {
        let (s, _) = http_request(srv.addr(), "POST", "/invoke/echo", b"").unwrap();
        assert_eq!(s, 200);
    }
    let (s, body) = http_request(srv.addr(), "GET", "/stats", b"").unwrap();
    assert_eq!(s, 200);
    let json = Json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
    assert_eq!(json.get("requests").and_then(Json::as_u64), Some(5));
    assert_eq!(json.get("cold_starts").and_then(Json::as_u64), Some(5));
    assert!(json.get("total_ms").and_then(|t| t.get("p50")).is_some());
    srv.shutdown();
}

#[test]
fn functions_endpoint_lists_registry() {
    require_artifacts!();
    let coord =
        Coordinator::start(cfg(SchedMode::ColdOnly, &["echo", "checksum"])).expect("artifacts");
    let srv = coord.serve("127.0.0.1:0").unwrap();
    let (s, body) = http_request(srv.addr(), "GET", "/functions", b"").unwrap();
    assert_eq!(s, 200);
    let text = String::from_utf8(body).unwrap();
    assert!(text.contains("\"echo\"") && text.contains("\"checksum\""));
    srv.shutdown();
}

#[test]
fn invalid_requests_rejected_cleanly() {
    require_artifacts!();
    let coord = Coordinator::start(cfg(SchedMode::ColdOnly, &["echo"])).expect("make artifacts");
    let srv = coord.serve("127.0.0.1:0").unwrap();
    // Unknown function -> 404.
    let (s, _) = http_request(srv.addr(), "POST", "/invoke/nope", b"").unwrap();
    assert_eq!(s, 404);
    // Wrong payload arity -> 400.
    let (s, body) = http_request(srv.addr(), "POST", "/invoke/echo", b"1,2,3").unwrap();
    assert_eq!(s, 400, "{}", String::from_utf8_lossy(&body));
    // Garbage payload -> 400.
    let (s, _) = http_request(srv.addr(), "POST", "/invoke/echo", &[0xff, 0x00, 0x80]).unwrap();
    assert_eq!(s, 400);
    // Server still healthy afterwards.
    let (s, _) = http_request(srv.addr(), "GET", "/healthz", b"").unwrap();
    assert_eq!(s, 200);
    srv.shutdown();
}

#[test]
fn payload_values_flow_through_pjrt() {
    require_artifacts!();
    let coord = Coordinator::start(cfg(SchedMode::ColdOnly, &["echo"])).expect("make artifacts");
    // 256 explicit values; echo must return them (summary head).
    let payload: String = (0..256).map(|i| format!("{}.5", i % 3)).collect::<Vec<_>>().join(",");
    let o = coord.invoke("echo", payload.as_bytes()).unwrap();
    assert_eq!(o.output_head[0], 0.5);
    assert_eq!(o.output_head[1], 1.5);
    assert_eq!(o.output_head[2], 2.5);
    let want_sum: f64 = (0..256).map(|i| (i % 3) as f64 + 0.5).sum();
    assert!((o.output_sum - want_sum).abs() < 1e-3);
}

#[test]
fn multi_engine_pool_serves_in_parallel() {
    require_artifacts!();
    let mut c = cfg(SchedMode::ColdOnly, &["checksum"]);
    c.engine_threads = 2;
    let coord = Coordinator::start(c).expect("make artifacts");
    let handles: Vec<_> = (0..4)
        .map(|_| {
            let coord = coord.clone();
            std::thread::spawn(move || {
                for _ in 0..10 {
                    coord.invoke("checksum", b"").unwrap();
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    assert_eq!(coord.stats.requests.load(Ordering::Relaxed), 40);
}

#[test]
fn engine_pool_shutdown_fails_cleanly() {
    require_artifacts!();
    use coldfaas::coordinator::EnginePool;
    let dir = coldfaas::runtime::default_artifacts_dir();
    let pool = EnginePool::start(1, dir, &["echo".to_string()]).expect("make artifacts");
    let input = coldfaas::runtime::test_input(256);
    assert!(pool.execute("echo", input.clone()).is_ok());
    pool.shutdown();
    // A fresh pool still works (shutdown is per-instance, not global).
    let pool2 =
        EnginePool::start(1, coldfaas::runtime::default_artifacts_dir(), &["echo".to_string()])
            .unwrap();
    assert!(pool2.execute("echo", input).is_ok());
}

#[test]
fn engine_pool_rejects_missing_artifact_dir() {
    use coldfaas::coordinator::EnginePool;
    let err = EnginePool::start(1, "/nonexistent/path".into(), &["echo".to_string()]);
    assert!(err.is_err());
}

#[test]
fn deploy_route_registers_new_function() {
    require_artifacts!();
    // Start with only echo; transformer exists in the manifest but is not
    // deployed (and not compiled).
    let coord = Coordinator::start(cfg(SchedMode::ColdOnly, &["echo"])).expect("make artifacts");
    let srv = coord.serve("127.0.0.1:0").unwrap();

    // Not yet routable.
    let (s, _) = http_request(srv.addr(), "POST", "/invoke/checksum", b"").unwrap();
    assert_eq!(s, 404);

    // Deploy it (build time is scaled by time_scale = 0 in tests).
    let (s, body) = http_request(srv.addr(), "POST", "/deploy/checksum", b"").unwrap();
    assert_eq!(s, 200, "{}", String::from_utf8_lossy(&body));
    let json = Json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
    assert_eq!(json.get("deployed").and_then(Json::as_str), Some("checksum"));
    assert!(json.get("build_s").and_then(Json::as_f64).unwrap() >= 3.0);

    // Now invocable, numerics verified downstream by the engine.
    let (s, body) = http_request(srv.addr(), "POST", "/invoke/checksum", b"").unwrap();
    assert_eq!(s, 200, "{}", String::from_utf8_lossy(&body));

    // Double deploy rejected; unknown function 404.
    let (s, _) = http_request(srv.addr(), "POST", "/deploy/checksum", b"").unwrap();
    assert_eq!(s, 400);
    let (s, _) = http_request(srv.addr(), "POST", "/deploy/not_a_fn", b"").unwrap();
    assert_eq!(s, 404);
    srv.shutdown();
}

#[test]
fn lazy_compile_on_second_engine() {
    require_artifacts!();
    // Two engines, function deployed after start: both engines must be
    // able to serve it (the second compiles lazily on first use).
    let mut c = cfg(SchedMode::ColdOnly, &["echo"]);
    c.engine_threads = 2;
    let coord = Coordinator::start(c).expect("make artifacts");
    coord.deploy("thumbnail").unwrap();
    for _ in 0..8 {
        let o = coord.invoke("thumbnail", b"").unwrap();
        assert!(o.output_sum.is_finite());
    }
}

// ---------------------------------------------------------------------------
// Gateway-tier tests (S29): no PJRT artifacts needed — these drive the
// benchmark-grade HTTP server directly, so they run on every `cargo test`.
// ---------------------------------------------------------------------------

use std::io::{Read as _, Write as _};
use std::net::TcpStream;
use std::time::Duration;

use coldfaas::gateway::http::{Handler, HttpClient, Request, Response, Server, MAX_HEAD_BYTES};

fn echo_gateway(workers: usize) -> Server {
    let handler: Handler = Arc::new(|req: &Request| match req.path.as_str() {
        "/noop" => Response::ok(""),
        p if p.starts_with("/echo") => Response::ok(req.body.clone()),
        _ => Response::not_found(),
    });
    Server::start("127.0.0.1:0", workers, handler).unwrap()
}

#[test]
fn gateway_keep_alive_reuses_one_connection() {
    let srv = echo_gateway(4);
    let mut c = HttpClient::connect(srv.addr()).unwrap();
    for i in 0..25 {
        let body = format!("req-{i}");
        let (status, got) = c.request("POST", "/echo", body.as_bytes()).unwrap();
        assert_eq!(status, 200);
        assert_eq!(got, body.as_bytes());
    }
    // 25 requests, ONE accepted TCP connection: keep-alive actually held.
    assert_eq!(srv.stats.served.load(Ordering::Relaxed), 25);
    assert_eq!(srv.stats.accepted.load(Ordering::Relaxed), 1);
    assert_eq!(srv.stats.shed.load(Ordering::Relaxed), 0);
    srv.shutdown();
}

#[test]
fn gateway_malformed_requests_all_get_4xx() {
    let srv = echo_gateway(4);
    // Each raw byte blob is an unframeable request; the server must
    // answer 400 (never hang, never crash) and count a parse error.
    let blobs: Vec<Vec<u8>> = vec![
        b"G@T /noop HTTP/1.1\r\n\r\n".to_vec(),
        b"POST /echo HTTP/1.1\r\nContent-Length: 3\r\nContent-Length: 3\r\n\r\nabc".to_vec(),
        b"POST /echo HTTP/1.1\r\nContent-Length: nope\r\n\r\n".to_vec(),
        {
            let mut junk = b"GET /noop HTTP/1.1\r\nX-Filler: ".to_vec();
            junk.resize(junk.len() + MAX_HEAD_BYTES + 512, b'a');
            junk
        },
    ];
    let n_blobs = blobs.len() as u64;
    for blob in blobs {
        let mut s = TcpStream::connect(srv.addr()).unwrap();
        s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        s.write_all(&blob).unwrap();
        let mut buf = Vec::new();
        let _ = s.read_to_end(&mut buf);
        let text = String::from_utf8_lossy(&buf);
        assert!(text.starts_with("HTTP/1.1 400"), "got: {text}");
    }
    // Truncated body: promise 10 bytes, half-close after 3.
    let mut s = TcpStream::connect(srv.addr()).unwrap();
    s.write_all(b"POST /echo HTTP/1.1\r\nContent-Length: 10\r\n\r\nabc").unwrap();
    s.shutdown(std::net::Shutdown::Write).unwrap();
    let mut buf = Vec::new();
    let _ = s.read_to_end(&mut buf);
    assert!(String::from_utf8_lossy(&buf).starts_with("HTTP/1.1 400"), "{buf:?}");
    assert_eq!(srv.stats.parse_errors.load(Ordering::Relaxed), n_blobs + 1);
    // The server keeps serving clean requests afterwards.
    let (status, _) = http_request(srv.addr(), "GET", "/noop", b"").unwrap();
    assert_eq!(status, 200);
    srv.shutdown();
}

#[test]
fn gateway_accept_pool_accounts_concurrent_connections() {
    // Workers own whole persistent connections, so 8 concurrent clients
    // need 8 workers; the accept pool (capped at 4 threads) must still
    // account exactly one accept per client and shed nothing.
    let srv = echo_gateway(8);
    let addr = srv.addr();
    let handles: Vec<_> = (0..8)
        .map(|t| {
            std::thread::spawn(move || {
                let mut c = HttpClient::connect(addr).unwrap();
                for i in 0..10 {
                    let body = format!("t{t}-r{i}");
                    let (status, got) = c.request("POST", "/echo", body.as_bytes()).unwrap();
                    assert_eq!(status, 200);
                    assert_eq!(got, body.as_bytes());
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    assert_eq!(srv.stats.served.load(Ordering::Relaxed), 80);
    assert_eq!(srv.stats.accepted.load(Ordering::Relaxed), 8);
    assert_eq!(srv.stats.shed.load(Ordering::Relaxed), 0);
    assert_eq!(srv.stats.parse_errors.load(Ordering::Relaxed), 0);
    srv.shutdown();
}

#[test]
fn live_platform_handler_4xx_keeps_connection_alive() {
    // Handler-level 4xx (bad route) is not a framing error: the same
    // keep-alive connection must keep serving real invokes afterwards.
    let srv = coldfaas::live::start(coldfaas::live::LiveConfig {
        functions: 4,
        time_scale: 0.0,
        workers: 4,
        ..coldfaas::live::LiveConfig::default()
    })
    .unwrap();
    let mut c = HttpClient::connect(srv.addr()).unwrap();
    let (s, _) = c.request("POST", "/invoke/99/0", b"").unwrap();
    assert_eq!(s, 404); // function out of range
    let (s, _) = c.request("POST", "/invoke/abc/0", b"").unwrap();
    assert_eq!(s, 400); // non-numeric function id
    let (s, body) = c.request("POST", "/invoke/0/0", b"").unwrap();
    assert_eq!(s, 200);
    assert!(String::from_utf8_lossy(&body).contains("\"class\":\"cold\""));
    // All three rode one accepted connection; only the invoke counted.
    let gw = srv.gateway_stats();
    assert_eq!(gw.accepted.load(Ordering::Relaxed), 1);
    assert_eq!(gw.served.load(Ordering::Relaxed), 3);
    assert_eq!(srv.platform.stats.requests.load(Ordering::Relaxed), 1);
    srv.shutdown();
}

#[test]
fn realtime_startup_model_actually_delays() {
    require_artifacts!();
    // time_scale = 1.0 on the IncludeOS model: ~11 ms per cold start.
    let mut c = cfg(SchedMode::ColdOnly, &["echo"]);
    c.time_scale = 1.0;
    let coord = Coordinator::start(c).expect("make artifacts");
    let t0 = std::time::Instant::now();
    let o = coord.invoke("echo", b"").unwrap();
    let wall = t0.elapsed().as_secs_f64() * 1e3;
    assert!(o.startup_model_ms > 5.0, "modeled startup {}", o.startup_model_ms);
    assert!(wall >= o.startup_model_ms * 0.8, "wall {wall} vs model {}", o.startup_model_ms);
}
