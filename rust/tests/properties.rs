//! Property-based tests (testkit) on the coordinator/simulator invariants:
//! work conservation, clock monotonicity, resource serialization bounds,
//! warm-pool accounting identities, quantile monotonicity, parser
//! robustness on adversarial inputs, and the S26 shard-merge algebra
//! (histogram/partial merging is exact and order-independent; sharded
//! platform runs match the single engine bit-for-bit).

use coldfaas::fnplat::pool::{Dispatch, WarmPool};
use coldfaas::fnplat::DriverKind;
use coldfaas::metrics::Recorder;
use coldfaas::platform::{
    run_platform, DriverProfile, FaultConfig, FaultPlan, NodeState, PlatformConfig, PlatformLoad,
    SchedPolicy, Scheduler, SharingMode,
};
use coldfaas::policy::{ColdOnlyPolicy, FixedKeepAlive, LifecyclePolicy, UniversalPool};
use coldfaas::runtime::Json;
use coldfaas::sim::{Dist, Domain, Engine, Host, LockClass, ReqId, Rng, Spawn, Step};
use coldfaas::testkit::{forall, forall_vec, gen};
use coldfaas::workload::tenants::{zipf_weights, TenantConfig, TenantTrace};

struct Collect {
    done: u64,
    last_now: u64,
}
impl Domain for Collect {
    fn done(&mut self, _r: ReqId, _c: u32, _s: u64, now: u64) -> Vec<Spawn> {
        assert!(now >= self.last_now, "completion times must be monotone");
        self.last_now = now;
        self.done += 1;
        Vec::new()
    }
}

/// Work conservation: every spawned request completes exactly once, for
/// arbitrary mixes of step kinds and host sizes.
#[test]
fn prop_engine_work_conservation() {
    forall(
        0xA11CE,
        60,
        |rng| {
            let cores = gen::u64_in(rng, 1, 8) as u32;
            let n = gen::u64_in(rng, 1, 80);
            let kinds = gen::u64_in(rng, 0, 3);
            (cores, n, kinds, rng.next_u64())
        },
        |&(cores, n, kinds, seed)| {
            let mut e = Engine::new(
                Collect { done: 0, last_now: 0 },
                Host { cores, disk_bw_bytes_per_s: 1e9 },
                seed,
            );
            for i in 0..n {
                let step = match (kinds + i) % 4 {
                    0 => Step::cpu("c", Dist::ms(1.0, 0.3)),
                    1 => Step::lock("l", LockClass::Netns, Dist::ms(0.5, 0.3)),
                    2 => Step::delay("d", Dist::ms(2.0, 0.3)),
                    _ => Step::disk("k", 100_000),
                };
                e.spawn_at(i * 1000, 0, vec![step, Step::delay("t", Dist::ms(0.1, 0.1))]);
            }
            e.run(n * 64 + 1024);
            e.domain.done == n
        },
    );
}

/// A serializing lock's makespan is at least the sum of its hold times
/// and at most sum + max-gap slack; cores never run more jobs than exist.
#[test]
fn prop_lock_serialization_lower_bound() {
    forall(
        0xB0B,
        40,
        |rng| (gen::u64_in(rng, 1, 30), rng.next_u64()),
        |&(n, seed)| {
            let hold_ms = 2.0;
            let mut e = Engine::new(Collect { done: 0, last_now: 0 }, Host::default(), seed);
            for _ in 0..n {
                e.spawn_at(0, 0, vec![Step::lock("l", LockClass::Mount, Dist::const_ms(hold_ms))]);
            }
            e.run(n * 16);
            let makespan_ms = e.now() as f64 / 1e6;
            (makespan_ms - n as f64 * hold_ms).abs() < 1e-6
        },
    );
}

/// CPU pool: with c cores and n identical jobs, makespan = ceil(n/c)*d.
#[test]
fn prop_cpu_pool_makespan_exact() {
    forall(
        0xC0DE,
        50,
        |rng| (gen::u64_in(rng, 1, 6) as u32, gen::u64_in(rng, 1, 40), rng.next_u64()),
        |&(cores, n, seed)| {
            let mut e = Engine::new(
                Collect { done: 0, last_now: 0 },
                Host { cores, disk_bw_bytes_per_s: 1e9 },
                seed,
            );
            for _ in 0..n {
                e.spawn_at(0, 0, vec![Step::cpu("c", Dist::const_ms(3.0))]);
            }
            e.run(n * 16);
            let want = n.div_ceil(cores as u64) as f64 * 3.0;
            (e.now() as f64 / 1e6 - want).abs() < 1e-6
        },
    );
}

/// Warm-pool identity: dispatches = warm_hits + cold_starts, and the pool
/// never reports more idle slots than releases minus claims.
#[test]
fn prop_pool_accounting_identity() {
    forall_vec(0xD00D, 80, 60, 3, |ops| {
        // ops: 0/1 => dispatch, 2 => release, 3 => time jump
        let mut pool = WarmPool::new(5_000_000_000, 1 << 20);
        let mut now = 0u64;
        let mut dispatches = 0u64;
        let mut outstanding = 0i64; // claimed-or-cold executors not yet released
        for &op in ops {
            match op {
                0 | 1 => {
                    let d = pool.dispatch("f", now);
                    dispatches += 1;
                    if d == Dispatch::Warm || d == Dispatch::Cold {
                        outstanding += 1;
                    }
                }
                2 => {
                    if outstanding > 0 {
                        pool.release("f", now);
                        outstanding -= 1;
                    }
                }
                _ => now += 1_000_000_000,
            }
        }
        pool.warm_hits + pool.cold_starts == dispatches
    });
}

/// Waste monotonicity: a strictly longer idle timeout never yields *less*
/// idle memory waste on the same dispatch/release schedule.
#[test]
fn prop_pool_waste_monotone_in_timeout() {
    forall_vec(0xE66, 60, 40, 2, |ops| {
        let run = |timeout_s: u64| -> u128 {
            let mut pool = WarmPool::new(timeout_s * 1_000_000_000, 1 << 20);
            let mut now = 0u64;
            let mut outstanding = 0i64;
            for &op in ops {
                match op {
                    0 => {
                        pool.dispatch("f", now);
                        outstanding += 1;
                    }
                    1 => {
                        if outstanding > 0 {
                            pool.release("f", now);
                            outstanding -= 1;
                        }
                    }
                    _ => now += 2_000_000_000,
                }
            }
            pool.finalize(now);
            pool.idle_mem_byte_ns
        };
        run(1) <= run(10) && run(10) <= run(1000)
    });
}

/// Quantiles are monotone in q and bounded by min/max for arbitrary data.
#[test]
fn prop_recorder_quantiles_monotone() {
    forall(
        0xF00,
        80,
        |rng| gen::vec_f64(rng, 200, 0.0, 1e6),
        |v| {
            if v.is_empty() {
                return true;
            }
            let mut rec = Recorder::new();
            for &x in v {
                rec.record_ms("s", x);
            }
            let qs: Vec<f64> =
                [0.0, 0.01, 0.25, 0.5, 0.75, 0.99, 1.0].iter().map(|&q| rec.quantile("s", q).unwrap()).collect();
            let sorted_ok = qs.windows(2).all(|w| w[0] <= w[1]);
            let min = v.iter().cloned().fold(f64::INFINITY, f64::min);
            let max = v.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
            sorted_ok && qs[0] >= min - 1e-9 && *qs.last().unwrap() <= max + 1e-9
        },
    );
}

/// Histogram quantiles stay within one bucket (<6%) of exact quantiles.
#[test]
fn prop_histogram_quantile_error_bounded() {
    forall(
        0xAB,
        40,
        |rng| {
            (0..500)
                .map(|_| gen::u64_in(rng, 1_000, 5_000_000_000))
                .collect::<Vec<u64>>()
        },
        |v| {
            let mut h = coldfaas::metrics::Histogram::new();
            let mut exact: Vec<u64> = v.clone();
            for &ns in v {
                h.record_ns(ns);
            }
            exact.sort_unstable();
            [0.5, 0.9, 0.99].iter().all(|&q| {
                let approx = h.quantile_ms(q);
                let idx = ((q * exact.len() as f64).ceil() as usize).saturating_sub(1);
                let want = exact[idx.min(exact.len() - 1)] as f64 / 1e6;
                approx >= want * 0.94 && approx <= want * 1.06
            })
        },
    );
}

/// The JSON parser never panics on arbitrary byte soup and accepts
/// everything the generator can emit.
#[test]
fn prop_json_parser_total() {
    forall(
        0xCAFE,
        300,
        |rng| {
            let len = rng.below(60) as usize;
            (0..len).map(|_| (rng.below(96) + 32) as u8 as char).collect::<String>()
        },
        |s| {
            let _ = Json::parse(s); // must not panic; Err is fine
            true
        },
    );
    // Round-trip-ish: generated numeric arrays parse to the same values.
    forall(
        0xCAFF,
        100,
        |rng| (0..rng.below(20)).map(|_| rng.below(1_000_000) as i64).collect::<Vec<i64>>(),
        |v| {
            let doc = format!("[{}]", v.iter().map(i64::to_string).collect::<Vec<_>>().join(","));
            match Json::parse(&doc) {
                Ok(Json::Arr(a)) => {
                    a.len() == v.len()
                        && a.iter().zip(v).all(|(j, &want)| j.as_f64() == Some(want as f64))
                }
                _ => false,
            }
        },
    );
}

/// Tenant-trace generator: for arbitrary sizes/rates/seeds the trace is
/// sorted, in-horizon, in-range, and byte-identical under the same seed.
#[test]
fn prop_tenant_trace_wellformed_and_reproducible() {
    forall(
        0x7E4A47,
        25,
        |rng| {
            (
                gen::u64_in(rng, 1, 300) as u32,         // functions
                gen::f64_in(rng, 5.0, 60.0),             // duration_s
                gen::f64_in(rng, 1.0, 80.0),             // total_rps
                gen::f64_in(rng, 0.0, 0.9),              // diurnal depth
                rng.next_u64(),                          // seed
            )
        },
        |&(functions, duration_s, total_rps, depth, seed)| {
            let cfg = TenantConfig {
                functions,
                duration_s,
                total_rps,
                diurnal_depth: depth,
                seed,
                ..Default::default()
            };
            let a = TenantTrace::generate(&cfg);
            let b = TenantTrace::generate(&cfg);
            let horizon = (duration_s * 1e9) as u64;
            a.arrivals == b.arrivals
                && a.arrivals.windows(2).all(|w| w[0] <= w[1])
                && a.arrivals.iter().all(|&(at, f)| at < horizon && f < functions)
        },
    );
}

/// Zipf mass ordering: across seeds, the head decile of functions always
/// collects more invocations than the bottom half combined (s > 1).
#[test]
fn prop_tenant_zipf_mass_ordering() {
    forall(
        0x21FF,
        12,
        |rng| rng.next_u64(),
        |&seed| {
            let cfg = TenantConfig {
                functions: 100,
                duration_s: 80.0,
                total_rps: 50.0,
                bursty_fraction: 0.0,
                seed,
                ..Default::default()
            };
            let counts = TenantTrace::generate(&cfg).per_function_counts();
            let head: u64 = counts[..10].iter().sum();
            let tail: u64 = counts[50..].iter().sum();
            head > tail
        },
    );
}

/// Zipf weights: normalized, strictly decreasing, and heavier-tailed as
/// the exponent shrinks.
#[test]
fn prop_zipf_weights_shape() {
    forall(
        0x21F0,
        40,
        |rng| (gen::u64_in(rng, 2, 2000) as u32, gen::f64_in(rng, 0.5, 2.0)),
        |&(n, s)| {
            let w = zipf_weights(n, s);
            let normalized = (w.iter().sum::<f64>() - 1.0).abs() < 1e-6;
            let decreasing = w.windows(2).all(|p| p[0] > p[1]);
            normalized && decreasing
        },
    );
}

/// Per-slot deadline pool: on arbitrary op sequences the accounting
/// identity (dispatches = warm + cold) holds and waste is monotone in the
/// per-release keep window.
#[test]
fn prop_pool_policy_deadlines_accounting() {
    forall_vec(0xD0D0, 60, 50, 3, |ops| {
        let run = |keep_s: u64| -> (u64, u128) {
            let mut pool = WarmPool::new(3600 * 1_000_000_000, 1 << 20);
            let mut now = 0u64;
            let mut outstanding = 0i64;
            let mut dispatches = 0u64;
            for &op in ops {
                match op {
                    0 => {
                        pool.dispatch("f", now);
                        dispatches += 1;
                        outstanding += 1;
                    }
                    1 => {
                        if outstanding > 0 {
                            pool.release_until("f", now, now + keep_s * 1_000_000_000);
                            outstanding -= 1;
                        }
                    }
                    _ => now += 2_000_000_000,
                }
            }
            pool.finalize(now);
            (pool.warm_hits + pool.cold_starts, pool.idle_mem_byte_ns)
        };
        let (d1, w1) = run(1);
        let (d10, w10) = run(10);
        let (d100, w100) = run(100);
        d1 == d10 && d10 == d100 && w1 <= w10 && w10 <= w100
    });
}

/// Universal-pool sharing never serves a request from a mismatched
/// sharing key (S23): per-key claims never exceed per-key releases (a
/// claim cannot cross buckets however warm the others are), an owner
/// that never released under a key is never handed a same-owner Warm
/// hit there (a mismatched claim is always Specialized), and the
/// dispatch-class identity `warm + specialized + cold == dispatches`
/// holds over arbitrary op interleavings.
#[test]
fn prop_shared_pool_never_serves_mismatched_sharing_key() {
    const S: u64 = 1_000_000_000;
    const KEYS: [&str; 3] = ["rt0", "rt1", "rt2"];
    forall_vec(0x5AE_16, 60, 80, 9, |ops| {
        let mut pool = WarmPool::new(30 * S, 1 << 20);
        let mut now = 0u64;
        let mut dispatches = 0u64;
        let mut released = [0u64; 3];
        let mut claimed = [0u64; 3];
        for (i, &op) in ops.iter().enumerate() {
            let k = (op % 3) as usize;
            // Owners are partitioned per key: key k's native owners are
            // 100k..100k+3; owner 999 is foreign to every key.
            let native = 100 * k as u32 + (i as u32 % 3);
            match op / 3 {
                0 => {
                    pool.prewarm_shared_until(KEYS[k], native, 1, now, now + 20 * S);
                    released[k] += 1;
                }
                1 => {
                    let owner = if i % 5 == 0 { 999 } else { native };
                    let d = pool.dispatch_shared(KEYS[k], owner, now);
                    dispatches += 1;
                    if d != Dispatch::Cold {
                        claimed[k] += 1;
                        if claimed[k] > released[k] {
                            return false; // claim crossed a bucket
                        }
                    }
                    if owner == 999 && d == Dispatch::Warm {
                        return false; // foreign owner got a warm hit
                    }
                    if d == Dispatch::Cold {
                        // Keep alive accounting sane for later expiry.
                        pool.retire(KEYS[k]);
                    }
                }
                _ => now += S / 2,
            }
        }
        pool.warm_hits + pool.specializations + pool.cold_starts == dispatches
    });
}

/// Universal sharing at the platform level conserves everything under
/// random traces, sharing modes, and fault plans: every arrival is
/// served or rejected, and every pool dispatch (served + killed
/// attempts) is exactly one of warm / specialized / cold.  Debug builds
/// additionally re-run the linear-scan router on every decision, so this
/// also pins sharing-aware indexed routing to the scan reference.
#[test]
fn prop_universal_sharing_conserves_under_random_traces_and_faults() {
    const S: u64 = 1_000_000_000;
    forall(
        0x5AE_FA17,
        6,
        |rng| {
            (
                gen::u64_in(rng, 2, 6) as usize,  // nodes
                gen::u64_in(rng, 0, 1),           // mode pick
                gen::u64_in(rng, 1, 5) as u32,    // runtimes
                gen::u64_in(rng, 0, 1),           // policy pick
                rng.next_u64(),                   // seed
            )
        },
        |&(nodes, mode_pick, runtimes, policy_pick, seed)| {
            let trace = TenantTrace::generate(&TenantConfig {
                functions: 40,
                duration_s: 25.0,
                total_rps: 30.0,
                seed,
                ..Default::default()
            });
            let plan = FaultPlan::generate(&FaultConfig {
                nodes,
                horizon_ns: 25 * S,
                mttf_ns: 12 * S,
                mttr_ns: 4 * S,
                flush_cache: true,
                straggler_mult: 2.0,
                straggler_ns: 3 * S,
                max_retries: 3,
                retry_backoff_ns: 100_000_000,
                spike_window_ns: 5 * S,
                seed: seed ^ 0x5AE,
            });
            let mode = if mode_pick == 0 {
                SharingMode::PerRuntime { runtimes }
            } else {
                SharingMode::Promiscuous
            };
            let mut cfg = PlatformConfig {
                load: PlatformLoad::Tenants(trace.clone()),
                functions: 40,
                nodes,
                faults: plan,
                ..PlatformConfig::single_node(
                    DriverProfile::from_kind(DriverKind::DockerWarm),
                    8,
                )
            };
            cfg.sharing = mode;
            cfg.universal_prewarm = 3;
            let mut universal = UniversalPool::new(runtimes, 4.0);
            let mut keep = FixedKeepAlive::default();
            let policy: &mut dyn LifecyclePolicy =
                if policy_pick == 0 { &mut universal } else { &mut keep };
            let r = run_platform(&cfg, policy, Host::default());
            r.injected == trace.len() as u64
                && r.injected == r.served + r.rejected
                && r.warm_hits + r.specializations + r.cold_starts == r.served + r.killed
        },
    );
}

/// Request conservation under random fault plans: for every lifecycle
/// policy x scheduler draw, every injected request ends served or
/// rejected (`served + rejected == injected`), every kill is either
/// retried or rejected, and the platform never invents requests — even
/// when the random plan takes the whole cluster down at once.
#[test]
fn prop_platform_conserves_requests_under_random_fault_plans() {
    const S: u64 = 1_000_000_000;
    forall(
        0xFA17_7E57,
        8,
        |rng| {
            (
                gen::u64_in(rng, 2, 6) as usize,          // nodes
                gen::u64_in(rng, 8, 40),                  // mttf_s
                gen::u64_in(rng, 2, 10),                  // mttr_s
                gen::u64_in(rng, 0, 3) as usize,          // scheduler
                gen::u64_in(rng, 0, 1),                   // policy pick
                rng.next_u64(),                           // seed
            )
        },
        |&(nodes, mttf_s, mttr_s, sched, policy_pick, seed)| {
            let trace = TenantTrace::generate(&TenantConfig {
                functions: 40,
                duration_s: 30.0,
                total_rps: 30.0,
                seed,
                ..Default::default()
            });
            let plan = FaultPlan::generate(&FaultConfig {
                nodes,
                horizon_ns: 30 * S,
                mttf_ns: mttf_s * S,
                mttr_ns: mttr_s * S,
                flush_cache: true,
                straggler_mult: 2.0,
                straggler_ns: 5 * S,
                max_retries: 3,
                retry_backoff_ns: 100_000_000,
                spike_window_ns: 5 * S,
                seed: seed ^ 0xFA17,
            });
            let driver = if policy_pick == 0 {
                DriverKind::IncludeOsCold
            } else {
                DriverKind::DockerWarm
            };
            let cfg = PlatformConfig {
                load: PlatformLoad::Tenants(trace.clone()),
                functions: 40,
                nodes,
                scheduler: SchedPolicy::ALL[sched],
                faults: plan,
                ..PlatformConfig::single_node(DriverProfile::from_kind(driver), 8)
            };
            let mut cold = ColdOnlyPolicy;
            let mut keep = FixedKeepAlive::default();
            let policy: &mut dyn LifecyclePolicy =
                if policy_pick == 0 { &mut cold } else { &mut keep };
            let r = run_platform(&cfg, policy, Host::default());
            r.injected == trace.len() as u64
                && r.injected == r.served + r.rejected
                && r.served == r.requests
                && r.retries <= r.killed
                && r.killed <= r.retries + r.rejected
        },
    );
}

/// A crashed node never yields a warm slot: routing skips down nodes
/// outright (even if a buggy pool still held slots), and the crash drain
/// leaves nothing warm behind for when the node returns.
#[test]
fn prop_warm_pool_never_yields_slot_on_crashed_node() {
    const S: u64 = 1_000_000_000;
    forall(
        0xDEAD_0DE,
        40,
        |rng| {
            (
                gen::u64_in(rng, 2, 6) as usize, // nodes
                gen::u64_in(rng, 1, 5),          // warm slots per node
                rng.next_u64(),                  // which node crashes
            )
        },
        |&(n_nodes, slots, pick)| {
            let mut nodes: Vec<NodeState> = (0..n_nodes)
                .map(|id| NodeState::new(id, 4, 32, 30 * S, 1 << 20))
                .collect();
            for n in nodes.iter_mut() {
                n.pool.prewarm_until("f0", slots, 0, 100 * S);
            }
            let mut sched = Scheduler::for_nodes(SchedPolicy::LeastLoaded, &nodes);
            let down = (pick % n_nodes as u64) as usize;
            sched.node_down(&nodes[down]);
            nodes[down].up = false;
            let drained = nodes[down].pool.crash(S);
            let routed_ok = (0..2 * n_nodes).all(|_| {
                // Repeated routing claims slots but must never pick the
                // crashed node, with or without slots left in its pool.
                match sched.route_warm(&mut nodes, "f0", 2 * S) {
                    Some(id) => id != down,
                    None => true,
                }
            });
            drained == slots
                && nodes[down].pool.warm_available("f0", 2 * S) == 0
                && routed_ok
        },
    );
}

/// The scheduler's warm/load/replica indexes must pick the *identical*
/// node the pre-index linear scans picked, op for op, under random
/// prewarm/claim/complete/crash/restart histories for every policy.
/// (`route_warm_scan`/`place_cold_scan` are the original O(nodes)
/// implementations, kept as the behavioural reference.)
#[test]
fn prop_indexed_scheduler_matches_linear_scan() {
    const S: u64 = 1_000_000_000;
    forall(
        0x1DE7_5CA9,
        40,
        |rng| {
            (
                gen::u64_in(rng, 2, 10) as usize,  // nodes
                gen::u64_in(rng, 0, 3) as usize,   // scheduler policy
                gen::u64_in(rng, 40, 120),         // ops
                rng.next_u64(),                    // seed
            )
        },
        |&(n_nodes, policy_idx, ops, seed)| {
            let img =
                coldfaas::image::Image::for_function("f0", coldfaas::virt::Tech::IncludeOsHvt);
            let mut nodes: Vec<NodeState> = (0..n_nodes)
                .map(|id| NodeState::new(id, 4, 8, 30 * S, 1 << 20))
                .collect();
            let _ = nodes[0].cache.fetch(&img);
            let mut sched = Scheduler::for_nodes(SchedPolicy::ALL[policy_idx], &nodes);
            let mut rng = coldfaas::sim::Rng::new(seed);
            let mut claimed: Vec<usize> = Vec::new();
            let mut now = 0u64;
            for _ in 0..ops {
                match rng.below(10) {
                    // Release a warm slot somewhere (random deadline).
                    0 | 1 => {
                        let id = rng.below(n_nodes as u64) as usize;
                        let keep = (1 + rng.below(40)) * S;
                        nodes[id].pool.prewarm_until("f0", 1, now, now + keep);
                        sched.warm_added("f0", id);
                    }
                    // Warm-route: indexed pick must equal the scan pick.
                    2 | 3 | 4 => {
                        let want = Scheduler::route_warm_scan(&mut nodes, "f0", now);
                        let got = sched.route_warm(&mut nodes, "f0", now);
                        if got != want {
                            return false;
                        }
                        if let Some(id) = got {
                            claimed.push(id);
                        }
                    }
                    // Cold-place: same comparison (clone the RNG so the
                    // reference consumes the same draw).
                    5 | 6 | 7 => {
                        let want = Scheduler::place_cold_scan(
                            sched.policy,
                            &nodes,
                            &img,
                            &mut rng.clone(),
                        );
                        let got = sched.place_cold(&mut nodes, &img, &mut rng);
                        if got.map(|p| p.node) != want {
                            return false;
                        }
                        if let Some(p) = got {
                            claimed.push(p.node);
                        }
                    }
                    // Finish an in-flight executor.
                    8 => {
                        if !claimed.is_empty() {
                            let i = rng.below(claimed.len() as u64) as usize;
                            let id = claimed.swap_remove(i);
                            if nodes[id].up {
                                sched.complete(&mut nodes, id);
                            }
                        }
                    }
                    // Crash or restart a random node.
                    _ => {
                        let id = rng.below(n_nodes as u64) as usize;
                        if nodes[id].up {
                            sched.node_down(&nodes[id]);
                            nodes[id].up = false;
                            nodes[id].inflight = 0;
                            nodes[id].pool.crash(now);
                            claimed.retain(|&c| c != id);
                        } else {
                            nodes[id].up = true;
                            sched.node_up(&nodes[id]);
                        }
                    }
                }
                now += rng.below(5 * S) + 1;
            }
            true
        },
    );
}

/// End-to-end index parity under random traces and fault plans: debug
/// builds re-run the pre-index linear scans inside `route_warm`/
/// `place_cold` on every single dispatch and assert the identical pick,
/// so replaying random multi-tenant traces through random chaos plans
/// across every scheduler exercises the equivalence millions of times —
/// any divergence panics the run.  Release builds still verify the
/// observable outcome (full service, conservation).
#[test]
fn prop_indexed_routing_matches_scan_under_random_traces_and_faults() {
    const S: u64 = 1_000_000_000;
    forall(
        0x5CA0_F417,
        6,
        |rng| {
            (
                gen::u64_in(rng, 2, 8) as usize,  // nodes
                gen::u64_in(rng, 0, 3) as usize,  // scheduler
                gen::u64_in(rng, 0, 1),           // policy pick
                rng.next_u64(),                   // seed
            )
        },
        |&(nodes, sched, policy_pick, seed)| {
            let trace = TenantTrace::generate(&TenantConfig {
                functions: 60,
                duration_s: 25.0,
                total_rps: 40.0,
                seed,
                ..Default::default()
            });
            let plan = FaultPlan::generate(&FaultConfig {
                nodes,
                horizon_ns: 25 * S,
                mttf_ns: 12 * S,
                mttr_ns: 4 * S,
                flush_cache: true,
                straggler_mult: 2.0,
                straggler_ns: 3 * S,
                max_retries: 3,
                retry_backoff_ns: 100_000_000,
                spike_window_ns: 5 * S,
                seed: seed ^ 0x1DE7,
            });
            let driver = if policy_pick == 0 {
                DriverKind::IncludeOsCold
            } else {
                DriverKind::DockerWarm
            };
            let cfg = PlatformConfig {
                load: PlatformLoad::Tenants(trace.clone()),
                functions: 60,
                nodes,
                scheduler: SchedPolicy::ALL[sched],
                faults: plan,
                ..PlatformConfig::single_node(DriverProfile::from_kind(driver), 8)
            };
            let mut cold = ColdOnlyPolicy;
            let mut keep = FixedKeepAlive::default();
            let policy: &mut dyn LifecyclePolicy =
                if policy_pick == 0 { &mut cold } else { &mut keep };
            let r = run_platform(&cfg, policy, Host::default());
            r.injected == trace.len() as u64 && r.injected == r.served + r.rejected
        },
    );
}

/// S26 merge algebra, histogram layer: `Histogram::merge` over any
/// round-robin partition of any sample stream reproduces the
/// unpartitioned histogram exactly — forward, reversed, and pairwise
/// (associativity) merge orders all land on the same bits, which is
/// what makes the sharded report independent of shard count.  Exactness
/// relies on `sum_ns` being an integer; an f64 accumulator would drift
/// with grouping.
#[test]
fn prop_histogram_merge_is_exact_and_order_independent() {
    use coldfaas::metrics::Histogram;
    forall(
        0x4157_5843,
        40,
        |rng| {
            let n = gen::u64_in(rng, 0, 400) as usize;
            let k = gen::u64_in(rng, 1, 8) as usize;
            let ns: Vec<u64> =
                (0..n).map(|_| gen::u64_in(rng, 1_000, 10_000_000_000)).collect();
            (k, ns)
        },
        |(k, ns)| {
            let mut whole = Histogram::new();
            for &x in ns {
                whole.record_ns(x);
            }
            let mut parts = vec![Histogram::new(); *k];
            for (i, &x) in ns.iter().enumerate() {
                parts[i % k].record_ns(x);
            }
            let mut fwd = Histogram::new();
            for p in &parts {
                fwd.merge(p);
            }
            let mut rev = Histogram::new();
            for p in parts.iter().rev() {
                rev.merge(p);
            }
            // Associativity: fold pairwise from the right instead of
            // accumulating left-to-right.
            let mut tree = parts.clone();
            while tree.len() > 1 {
                let b = tree.pop().expect("nonempty");
                tree.last_mut().expect("nonempty").merge(&b);
            }
            fwd == whole && rev == whole && tree[0] == whole
        },
    );
}

/// S26 merge algebra, counter layer: applying any message stream to one
/// `ShardPartial` equals round-robin-scattering it over K partials and
/// merging them back, in any merge order and for any K.  This is the
/// identity the platform's finalize step leans on when it folds
/// per-shard partials into the report.
#[test]
fn prop_shard_partial_merge_matches_unpartitioned() {
    use coldfaas::platform::{HeatClass, ShardMsg, ShardPartial};
    forall_vec(0x526_AB, 60, 80, 10, |ops| {
        let msg = |op: u64, i: usize| -> ShardMsg {
            let lat_ns = 1_000_000 + (i as u64) * 37_000;
            match op {
                0 => ShardMsg::Injected,
                1 => ShardMsg::Dispatched { cold: i % 2 == 0, in_window: i % 3 == 0 },
                2 => ShardMsg::Served { heat: HeatClass::Cold, lat_ns },
                3 => ShardMsg::Served { heat: HeatClass::Warm, lat_ns },
                4 => ShardMsg::Served { heat: HeatClass::Specialized, lat_ns },
                5 => ShardMsg::Killed,
                6 => ShardMsg::Retry,
                7 => ShardMsg::Rejected,
                8 => ShardMsg::Crashed { slots_lost: (i % 5) as u64 },
                9 => ShardMsg::PrewarmBoot,
                _ => ShardMsg::Restarted,
            }
        };
        let msgs: Vec<ShardMsg> = ops.iter().enumerate().map(|(i, &op)| msg(op, i)).collect();
        let mut whole = ShardPartial::default();
        for &m in &msgs {
            whole.apply(m);
        }
        (1..=4).all(|k| {
            let mut parts = vec![ShardPartial::default(); k];
            for (i, &m) in msgs.iter().enumerate() {
                parts[i % k].apply(m);
            }
            let mut fwd = ShardPartial::default();
            for p in &parts {
                fwd.merge(p);
            }
            let mut rev = ShardPartial::default();
            for p in parts.iter().rev() {
                rev.merge(p);
            }
            fwd == whole && rev == whole
        })
    });
}

/// S26 end to end: for random traces, seeds, cluster sizes, drivers, and
/// shard counts (including counts past the node count, which the plan
/// clamps), the sharded platform reproduces the single-engine run
/// bit-for-bit — exact latency streams, float waste bits, event and
/// mailbox counts and all.
#[test]
fn prop_sharded_run_matches_single_engine() {
    forall(
        0x5A2D_E17,
        6,
        |rng| {
            (
                gen::u64_in(rng, 2, 8) as usize,   // nodes
                gen::u64_in(rng, 2, 12) as usize,  // shards (clamped to nodes)
                gen::u64_in(rng, 0, 1),            // driver pick
                rng.next_u64(),                    // seed
            )
        },
        |&(nodes, shards, driver_pick, seed)| {
            let trace = TenantTrace::generate(&TenantConfig {
                functions: 40,
                duration_s: 25.0,
                total_rps: 30.0,
                seed,
                ..Default::default()
            });
            let driver = if driver_pick == 0 {
                DriverKind::IncludeOsCold
            } else {
                DriverKind::DockerWarm
            };
            let run = |k: usize| {
                let cfg = PlatformConfig {
                    load: PlatformLoad::Tenants(trace.clone()),
                    functions: 40,
                    nodes,
                    shards: k,
                    exact_latencies: true,
                    ..PlatformConfig::single_node(DriverProfile::from_kind(driver), 8)
                };
                run_platform(&cfg, &mut FixedKeepAlive::default(), Host::default())
            };
            let single = run(1);
            let sharded = run(shards);
            sharded.latencies_ns == single.latencies_ns
                && sharded.requests == single.requests
                && sharded.cold_starts == single.cold_starts
                && sharded.warm_hits == single.warm_hits
                && sharded.specializations == single.specializations
                && sharded.idle_gb_seconds.to_bits() == single.idle_gb_seconds.to_bits()
                && sharded.monitor_events == single.monitor_events
                && sharded.events == single.events
                && sharded.elapsed_ns == single.elapsed_ns
                && sharded.shard_msgs == single.shard_msgs
                && sharded.shard_barriers == single.shard_barriers
        },
    );
}

/// Engine determinism under arbitrary workload shapes: same seed, same
/// event count and final clock.
#[test]
fn prop_engine_deterministic() {
    forall(
        0x5EED,
        30,
        |rng| (gen::u64_in(rng, 1, 50), rng.next_u64()),
        |&(n, seed)| {
            let run = || {
                let mut e =
                    Engine::new(Collect { done: 0, last_now: 0 }, Host::default(), seed);
                for i in 0..n {
                    e.spawn_at(
                        i * 500_000,
                        0,
                        vec![
                            Step::cpu("c", Dist::ms(1.0, 0.4)),
                            Step::lock("l", LockClass::Kvm, Dist::ms(0.3, 0.4)),
                        ],
                    );
                }
                e.run(n * 32);
                (e.now(), e.events_processed())
            };
            run() == run()
        },
    );
}
