//! Process-level CLI tests: spawn the real `coldfaas` binary.

use std::process::Command;

fn coldfaas() -> Command {
    Command::new(env!("CARGO_BIN_EXE_coldfaas"))
}

fn run(args: &[&str]) -> (i32, String, String) {
    let out = coldfaas().args(args).output().expect("spawn coldfaas");
    (
        out.status.code().unwrap_or(-1),
        String::from_utf8_lossy(&out.stdout).into_owned(),
        String::from_utf8_lossy(&out.stderr).into_owned(),
    )
}

/// The AOT artifacts exist (python `make artifacts` ran) and the crate was
/// built with the real PJRT backend.  Tests that need the live runtime
/// skip otherwise instead of failing the offline build.
fn artifacts_ready() -> bool {
    cfg!(feature = "pjrt")
        && std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
            .join("artifacts/manifest.json")
            .exists()
}

#[test]
fn help_lists_subcommands() {
    let (code, stdout, _) = run(&["help"]);
    assert_eq!(code, 0);
    for sub in [
        "experiment",
        "policies",
        "fleet",
        "chaos",
        "planet",
        "sharing",
        "compare",
        "serve",
        "invoke",
        "verify",
        "measure-exec",
        "list",
    ] {
        assert!(stdout.contains(sub), "help missing {sub}");
    }
}

#[test]
fn unknown_subcommand_fails_with_usage() {
    let (code, _, stderr) = run(&["frobnicate"]);
    assert_eq!(code, 2);
    assert!(stderr.contains("unknown subcommand"));
}

#[test]
fn experiment_quick_passes_and_prints_checks() {
    let (code, stdout, _) = run(&["experiment", "fig3", "--quick"]);
    assert_eq!(code, 0, "{stdout}");
    assert!(stdout.contains("ALL CHECKS PASS"));
    assert!(stdout.contains("includeos-hvt"));
}

#[test]
fn experiment_unknown_name_fails() {
    let (code, _, stderr) = run(&["experiment", "fig99"]);
    assert_eq!(code, 2);
    assert!(stderr.contains("unknown experiment"));
}

#[test]
fn experiment_requires_name() {
    let (code, _, stderr) = run(&["experiment"]);
    assert_eq!(code, 2);
    assert!(stderr.contains("usage"));
}

#[test]
fn policies_quick_passes_and_prints_frontier() {
    let (code, stdout, stderr) = run(&["policies", "--quick"]);
    assert_eq!(code, 0, "{stdout}{stderr}");
    assert!(stdout.contains("ALL CHECKS PASS"), "{stdout}");
    for label in ["includeos+cold-only", "docker+fixed-600s", "docker+histogram", "docker+ewma"] {
        assert!(stdout.contains(label), "policies output missing {label}");
    }
    assert!(stdout.contains("frontier"));
}

#[test]
fn policies_rejects_bad_arguments() {
    let (code, _, stderr) = run(&["policies", "--functions", "0"]);
    assert_eq!(code, 2);
    assert!(stderr.contains("positive"));
}

#[test]
fn fleet_small_sweep_passes_and_prints_frontier() {
    // A deliberately tiny trace: the checks are structural, not
    // statistical, and the grid is 32 cells.
    let (code, stdout, stderr) =
        run(&["fleet", "--quick", "--duration", "10", "--rps", "20", "--nodes", "8"]);
    assert_eq!(code, 0, "{stdout}{stderr}");
    assert!(stdout.contains("ALL CHECKS PASS"), "{stdout}");
    assert!(stdout.contains("E13"));
    for label in ["includeos+cold-only+least-loaded", "docker+fixed-600s+co-locate"] {
        assert!(stdout.contains(label), "fleet output missing {label}");
    }
    assert!(stdout.contains("frontier"));
}

#[test]
fn fleet_rejects_bad_node_counts() {
    let (code, _, stderr) = run(&["fleet", "--nodes", "0"]);
    assert_eq!(code, 2, "{stderr}");
    assert!(stderr.contains("--nodes"));
    let (code, _, stderr) = run(&["fleet", "--nodes", "1025"]);
    assert_eq!(code, 2, "{stderr}");
    assert!(stderr.contains("--nodes"));
}

#[test]
fn malformed_numeric_flags_are_hard_errors() {
    // The old getters fell back to defaults on parse failure, so a typo
    // like `--requests 10k` silently ran the paper-default load.
    for argv in [
        &["experiment", "fig3", "--requests", "10k"][..],
        &["experiment", "fig3", "--seed", "0xNOPE"][..],
        &["experiment", "fig3", "--parallelism", "1,x,3"][..],
        &["policies", "--rps", "fast"][..],
        &["fleet", "--nodes", "many"][..],
        &["chaos", "--duration", "1m"][..],
        &["planet", "--functions", "10_000"][..],
        &["measure-exec", "--iters", "ten"][..],
    ] {
        let (code, _, stderr) = run(argv);
        assert_eq!(code, 2, "{argv:?} must be rejected: {stderr}");
        assert!(stderr.contains("not a valid"), "{argv:?}: {stderr}");
    }
}

#[test]
fn out_of_range_cores_is_an_error_not_a_zero_core_cluster() {
    // u32::try_from(...).unwrap_or(0) used to turn this into --cores 0.
    let (code, _, stderr) = run(&["fleet", "--cores", "5000000000"]);
    assert_eq!(code, 2, "{stderr}");
    assert!(stderr.contains("out of range"), "{stderr}");
}

#[test]
fn planet_quick_passes_and_reports_throughput() {
    let path = std::env::temp_dir().join(format!("coldfaas_planet_{}.json", std::process::id()));
    let path_s = path.to_str().unwrap().to_string();
    // A deliberately small trace: CI's release smoke runs the full
    // --quick load; this test only checks the report plumbing.
    let (code, stdout, stderr) = run(&[
        "planet", "--rps", "400", "--duration", "30", "--functions", "2000", "--json",
        path_s.as_str(),
    ]);
    assert_eq!(code, 0, "{stdout}{stderr}");
    assert!(stdout.contains("ALL CHECKS PASS"), "{stdout}");
    assert!(stdout.contains("E15"));
    assert!(stdout.contains("includeos+cold-only"));
    assert!(stdout.contains("Mevents/s"));
    let doc = std::fs::read_to_string(&path).expect("json file written");
    let _ = std::fs::remove_file(&path);
    assert!(doc.starts_with("{\"generator\":\"coldfaas\""), "{doc}");
    assert!(doc.contains("\"id\":\"planet\""));
    assert!(doc.contains("\"all_pass\":true"));
}

#[test]
fn chaos_quick_passes_and_writes_json() {
    let path = std::env::temp_dir().join(format!("coldfaas_chaos_{}.json", std::process::id()));
    let path_s = path.to_str().unwrap().to_string();
    let (code, stdout, stderr) = run(&["chaos", "--quick", "--json", path_s.as_str()]);
    assert_eq!(code, 0, "{stdout}{stderr}");
    assert!(stdout.contains("ALL CHECKS PASS"), "{stdout}");
    assert!(stdout.contains("E14"));
    for label in ["includeos+cold-only+least-loaded", "docker+fixed-600s+co-locate"] {
        assert!(stdout.contains(label), "chaos output missing {label}");
    }
    let doc = std::fs::read_to_string(&path).expect("json file written");
    let _ = std::fs::remove_file(&path);
    assert!(doc.starts_with("{\"generator\":\"coldfaas\""), "{doc}");
    assert!(doc.contains("\"id\":\"chaos\""));
    assert!(doc.contains("\"all_pass\":true"));
}

#[test]
fn sharing_small_sweep_passes_and_reports_break_even() {
    // A deliberately small trace and a two-point cost sweep: the checks
    // are structural; the full --quick grid runs in the library tests.
    let (code, stdout, stderr) = run(&[
        "sharing",
        "--duration",
        "20",
        "--rps",
        "40",
        "--spec-costs",
        "1,64",
    ]);
    assert_eq!(code, 0, "{stdout}{stderr}");
    assert!(stdout.contains("ALL CHECKS PASS"), "{stdout}");
    assert!(stdout.contains("E16"));
    for label in [
        "includeos+cold-only+exclusive",
        "docker+fixed-600s+exclusive",
        "docker+universal-t8+runtime-4+spec1ms",
        "docker+universal-t8+promiscuous+spec64ms",
    ] {
        assert!(stdout.contains(label), "sharing output missing {label}: {stdout}");
    }
    assert!(stdout.contains("break-even"), "{stdout}");
}

#[test]
fn sharing_rejects_bad_arguments() {
    let (code, _, stderr) = run(&["sharing", "--runtimes", "0"]);
    assert_eq!(code, 2, "{stderr}");
    assert!(stderr.contains("positive"), "{stderr}");
    let (code, _, stderr) = run(&["sharing", "--spec-costs", "1,x"]);
    assert_eq!(code, 2, "{stderr}");
    assert!(stderr.contains("not a valid"), "{stderr}");
    let (code, _, stderr) = run(&["sharing", "--spec-costs", "-5"]);
    assert_eq!(code, 2, "{stderr}");
    assert!(stderr.contains("non-negative"), "{stderr}");
}

#[test]
fn compare_gate_round_trips_matches_drifts_and_bootstraps() {
    let dir = std::env::temp_dir();
    let pid = std::process::id();
    let write = |name: &str, body: &str| {
        let p = dir.join(format!("coldfaas_cmp_{pid}_{name}"));
        std::fs::write(&p, body).expect("write compare fixture");
        p.to_str().unwrap().to_string()
    };
    let base = "{\"generator\":\"coldfaas\",\"total_wall_s\":1,\"experiments\":[\
                {\"id\":\"fig9\",\"title\":\"t\",\"wall_s\":0.5,\"all_pass\":true,\
                \"series\":[],\"checks\":[{\"label\":\"a\",\"metric\":\"p50\",\
                \"paper\":10,\"measured\":10,\"tol\":0.25,\"pass\":true}],\
                \"bands\":[],\"notes\":[]}]}";
    let run_path = write("run.json", base);
    let base_path = write("base.json", base);
    let drift_doc = base.replace("\"measured\":10", "\"measured\":20");
    let drift_path = write("drift.json", &drift_doc);
    let flipped = base.replace("\"all_pass\":true", "\"all_pass\":false");
    let flip_path = write("flip.json", &flipped);
    let boot_path = write(
        "boot.json",
        "{\"generator\":\"coldfaas\",\"bootstrap\":true,\"experiments\":[]}",
    );

    // Identical documents: exit 0 and a MATCH verdict.
    let (code, stdout, _) = run(&["compare", &run_path, &base_path]);
    assert_eq!(code, 0, "{stdout}");
    assert!(stdout.contains("BASELINE MATCH"), "{stdout}");
    // Metric drift beyond tolerance: exit 1 with the offending check named.
    let (code, stdout, _) = run(&["compare", &drift_path, &base_path]);
    assert_eq!(code, 1, "{stdout}");
    assert!(stdout.contains("BENCH DRIFT") && stdout.contains("fig9"), "{stdout}");
    // ...but a wide --tol waves the same delta through.
    let (code, stdout, _) = run(&["compare", &drift_path, &base_path, "--tol", "2.0"]);
    assert_eq!(code, 0, "{stdout}");
    // Paper-check booleans are exact regardless of tolerance.
    let (code, stdout, _) = run(&["compare", &flip_path, &base_path, "--tol", "2.0"]);
    assert_eq!(code, 1, "{stdout}");
    // A bootstrap baseline passes with the refresh notice.
    let (code, stdout, _) = run(&["compare", &run_path, &boot_path]);
    assert_eq!(code, 0, "{stdout}");
    assert!(stdout.contains("BOOTSTRAP"), "{stdout}");
    // Usage errors: missing args, unreadable file, bad tolerance.
    let (code, _, stderr) = run(&["compare", &run_path]);
    assert_eq!(code, 2, "{stderr}");
    let (code, _, stderr) = run(&["compare", &run_path, "/nonexistent/base.json"]);
    assert_eq!(code, 2, "{stderr}");
    let (code, _, stderr) = run(&["compare", &run_path, &base_path, "--tol", "-1"]);
    assert_eq!(code, 2, "{stderr}");

    for p in [run_path, base_path, drift_path, flip_path, boot_path] {
        let _ = std::fs::remove_file(p);
    }
}

#[test]
fn compare_gate_accepts_a_real_experiment_report_against_itself() {
    // The gate must round-trip the real BENCH format: a fresh quick run
    // compared against its own bytes is a MATCH (and the committed
    // bootstrap baselines pass with a notice until refreshed).
    let dir = std::env::temp_dir();
    let a = dir.join(format!("coldfaas_gate_{}.json", std::process::id()));
    let a_s = a.to_str().unwrap().to_string();
    let (code, _, stderr) = run(&["experiment", "fig3", "--quick", "--json", &a_s]);
    assert_eq!(code, 0, "{stderr}");
    let (code, stdout, stderr) = run(&["compare", &a_s, &a_s]);
    let _ = std::fs::remove_file(&a);
    assert_eq!(code, 0, "{stdout}{stderr}");
    assert!(stdout.contains("BASELINE MATCH"), "{stdout}");
}

#[test]
fn chaos_rejects_bad_node_counts() {
    // The scripted fault plan needs a surviving node: 1 is too few.
    let (code, _, stderr) = run(&["chaos", "--nodes", "1"]);
    assert_eq!(code, 2, "{stderr}");
    assert!(stderr.contains("--nodes"));
    let (code, _, stderr) = run(&["chaos", "--nodes", "1025"]);
    assert_eq!(code, 2, "{stderr}");
    assert!(stderr.contains("--nodes"));
}

/// Every machine-readable report — `experiment`, `policies`, `fleet`,
/// `chaos`, and `sharing` — shares the `report::json_document` shape:
/// generator + wall time at the top, and per-experiment
/// id/series/checks/wall time.
#[test]
fn json_documents_share_one_shape_across_subcommands() {
    let invocations: [&[&str]; 5] = [
        &["experiment", "fig3", "--quick"],
        &["policies", "--quick"],
        &["fleet", "--quick", "--duration", "10", "--rps", "20"],
        &["chaos", "--quick"],
        &["sharing", "--duration", "20", "--rps", "40", "--spec-costs", "1,64"],
    ];
    for (i, argv) in invocations.iter().enumerate() {
        let path = std::env::temp_dir()
            .join(format!("coldfaas_shape_{}_{i}.json", std::process::id()));
        let path_s = path.to_str().unwrap().to_string();
        let mut args: Vec<&str> = argv.to_vec();
        args.push("--json");
        args.push(path_s.as_str());
        let (code, stdout, stderr) = run(&args);
        assert_eq!(code, 0, "{argv:?}: {stdout}{stderr}");
        let doc = std::fs::read_to_string(&path).expect("json file written");
        let _ = std::fs::remove_file(&path);
        for key in [
            "{\"generator\":\"coldfaas\"",
            "\"total_wall_s\":",
            "\"experiments\":[",
            "\"id\":",
            "\"title\":",
            "\"wall_s\":",
            "\"all_pass\":",
            "\"series\":[",
            "\"checks\":[",
            "\"bands\":[",
            "\"notes\":[",
        ] {
            assert!(doc.contains(key), "{argv:?}: json missing {key}: {doc}");
        }
    }
}

#[test]
fn experiment_json_writes_machine_readable_report() {
    let path = std::env::temp_dir().join(format!("coldfaas_bench_{}.json", std::process::id()));
    let path_s = path.to_str().unwrap().to_string();
    let (code, stdout, _) = run(&["experiment", "fig3", "--quick", "--json", path_s.as_str()]);
    assert_eq!(code, 0, "{stdout}");
    let doc = std::fs::read_to_string(&path).expect("json file written");
    let _ = std::fs::remove_file(&path);
    assert!(doc.starts_with("{\"generator\":\"coldfaas\""), "{doc}");
    assert!(doc.contains("\"id\":\"fig3\""));
    assert!(doc.contains("\"all_pass\":true"));
    assert!(doc.contains("\"total_wall_s\":"));
    assert!(doc.contains("\"checks\":["));
}

#[test]
fn list_shows_manifest_functions() {
    // `list` needs only the manifest file, not the PJRT backend.
    let manifest = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("artifacts/manifest.json");
    if !manifest.exists() {
        eprintln!("skipping: AOT artifacts not built (run `make artifacts`)");
        return;
    }
    let (code, stdout, stderr) = run(&["list"]);
    assert_eq!(code, 0, "{stderr}");
    for f in ["echo", "checksum", "thumbnail", "mlp", "transformer"] {
        assert!(stdout.contains(f), "list missing {f}: {stdout}");
    }
}

#[test]
fn verify_all_artifacts_pass() {
    if !artifacts_ready() {
        eprintln!("skipping: artifacts/pjrt backend unavailable");
        return;
    }
    let (code, stdout, stderr) = run(&["verify"]);
    assert_eq!(code, 0, "{stdout}{stderr}");
    assert!(stdout.matches("PASS").count() >= 5);
    assert!(!stdout.contains("FAIL"));
}

#[test]
fn invoke_echo_end_to_end() {
    if !artifacts_ready() {
        eprintln!("skipping: artifacts/pjrt backend unavailable");
        return;
    }
    let (code, stdout, stderr) =
        run(&["invoke", "echo", "--time-scale", "0", "--payload", ""]);
    assert_eq!(code, 0, "{stdout}{stderr}");
    assert!(stdout.contains("cold=true"));
    assert!(stdout.contains("output: sum="));
}

#[test]
fn invoke_unknown_function_fails() {
    let (code, _, stderr) = run(&["invoke", "nope", "--time-scale", "0"]);
    assert_eq!(code, 1, "{stderr}");
}

#[test]
fn experiment_seed_changes_output() {
    let (_, a, _) = run(&["experiment", "fig3", "--quick", "--seed", "1"]);
    let (_, b, _) = run(&["experiment", "fig3", "--quick", "--seed", "2"]);
    let (_, a2, _) = run(&["experiment", "fig3", "--quick", "--seed", "1"]);
    let strip = |s: &str| {
        s.lines()
            .filter(|l| !l.contains(" in ") /* timing line */)
            .collect::<Vec<_>>()
            .join("\n")
    };
    assert_eq!(strip(&a), strip(&a2), "same seed must reproduce");
    assert_ne!(strip(&a), strip(&b), "different seed must differ");
}
