//! Regression tests for the unified platform layer: every migrated
//! experiment (E4/E5/E9/E11/E12) plus the new E13 must (a) render
//! byte-identical reports per seed — the determinism property the DES
//! substrate guarantees — and (b) stay inside the pre-refactor tolerance
//! bands its report encodes as paper-vs-measured checks.

use coldfaas::experiments::{self, ExpConfig};

/// Every preset over the unified layer, one per migrated wiring + E13.
const MIGRATED: [&str; 6] = ["fig4", "table1", "waste", "scaleout", "policies", "fleet"];

fn small() -> ExpConfig {
    // Smaller than `quick`: determinism is scale-independent, so keep the
    // double-run cheap.
    ExpConfig { requests: 400, parallelisms: vec![1, 10], ..Default::default() }
}

#[test]
fn same_seed_gives_byte_identical_reports_for_every_preset() {
    let cfg = small();
    for name in MIGRATED {
        let a = experiments::by_name(name, &cfg).expect("known experiment").render();
        let b = experiments::by_name(name, &cfg).expect("known experiment").render();
        assert_eq!(a, b, "{name}: same seed must reproduce byte-identically");
    }
}

#[test]
fn different_seed_actually_changes_the_samples() {
    let cfg = small();
    let other = ExpConfig { seed: cfg.seed ^ 0x5EED, ..small() };
    // Experiments whose reports surface per-sample statistics (the image/
    // deploy tables are seed-independent by construction).
    for name in ["fig4", "table1", "waste", "policies", "fleet"] {
        let a = experiments::by_name(name, &cfg).expect("known experiment").render();
        let b = experiments::by_name(name, &other).expect("known experiment").render();
        assert_ne!(a, b, "{name}: a different seed must change the measurement");
    }
}

/// The pre-refactor tolerance bands, re-asserted through the unified
/// layer at the same reduced load the test suite always used.  (The raw
/// per-preset pins — Fig 4 bands, Table I medians, burst-tail ratios —
/// live with the presets themselves in `platform::presets`' unit tests;
/// this covers the report plumbing end to end without re-running those
/// simulations a second time here.)
#[test]
fn migrated_experiments_stay_inside_their_tolerance_bands() {
    let cfg = ExpConfig::quick();
    for name in MIGRATED {
        let report = experiments::by_name(name, &cfg).expect("known experiment");
        assert!(
            report.all_pass(),
            "{name} left its pre-refactor tolerance band:\n{}",
            report.failures().join("\n")
        );
    }
}
