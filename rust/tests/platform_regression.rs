//! Regression tests for the unified platform layer: every migrated
//! experiment (E4/E5/E9/E11/E12) plus E13/E14 must (a) render
//! byte-identical reports per seed — the determinism property the DES
//! substrate guarantees, now including the fault-injection layer — and
//! (b) stay inside the pre-refactor tolerance bands its report encodes
//! as paper-vs-measured checks.

use coldfaas::experiments::{self, ExpConfig};

/// Every preset over the unified layer, one per migrated wiring + E13.
/// These all run with faults disabled: the empty `FaultPlan` must leave
/// them byte-identical across the fault-layer refactor (double-run pins
/// below; the calibrated paper bands pin the absolute values).
const MIGRATED: [&str; 6] = ["fig4", "table1", "waste", "scaleout", "policies", "fleet"];

fn small() -> ExpConfig {
    // Smaller than `quick`: determinism is scale-independent, so keep the
    // double-run cheap.
    ExpConfig { requests: 400, parallelisms: vec![1, 10], ..Default::default() }
}

#[test]
fn same_seed_gives_byte_identical_reports_for_every_preset() {
    let cfg = small();
    for name in MIGRATED {
        let a = experiments::by_name(name, &cfg).expect("known experiment").render();
        let b = experiments::by_name(name, &cfg).expect("known experiment").render();
        assert_eq!(a, b, "{name}: same seed must reproduce byte-identically");
    }
}

#[test]
fn different_seed_actually_changes_the_samples() {
    let cfg = small();
    let other = ExpConfig { seed: cfg.seed ^ 0x5EED, ..small() };
    // Experiments whose reports surface per-sample statistics (the image/
    // deploy tables are seed-independent by construction).
    for name in ["fig4", "table1", "waste", "policies", "fleet"] {
        let a = experiments::by_name(name, &cfg).expect("known experiment").render();
        let b = experiments::by_name(name, &other).expect("known experiment").render();
        assert_ne!(a, b, "{name}: a different seed must change the measurement");
    }
}

/// The pre-refactor tolerance bands, re-asserted through the unified
/// layer at the same reduced load the test suite always used.  (The raw
/// per-preset pins — Fig 4 bands, Table I medians, burst-tail ratios —
/// live with the presets themselves in `platform::presets`' unit tests;
/// this covers the report plumbing end to end without re-running those
/// simulations a second time here.)
#[test]
fn migrated_experiments_stay_inside_their_tolerance_bands() {
    let cfg = ExpConfig::quick();
    for name in MIGRATED {
        let report = experiments::by_name(name, &cfg).expect("known experiment");
        assert!(
            report.all_pass(),
            "{name} left its pre-refactor tolerance band:\n{}",
            report.failures().join("\n")
        );
    }
}

/// E16 determinism: the sharing grid (exclusive rows + universal-worker
/// rows across mode x specialization cost) must render byte-identically
/// per seed — specializations, break-even readout and all — and a
/// different seed must actually move the measurement.
#[test]
fn sharing_report_is_byte_identical_per_seed() {
    let cfg = small();
    let a = experiments::by_name("sharing", &cfg).expect("known experiment").render();
    let b = experiments::by_name("sharing", &cfg).expect("known experiment").render();
    assert_eq!(a, b, "sharing: same seed must reproduce byte-identically");
    let other = ExpConfig { seed: cfg.seed ^ 0x5EED, ..small() };
    let c = experiments::by_name("sharing", &other).expect("known experiment").render();
    assert_ne!(a, c, "sharing: a different seed must change the measurement");
}

/// Sharing is opt-in: every pre-E16 preset runs under the exclusive mode
/// and must never record a specialized claim (the refactor guard that
/// keeps the E4–E15 byte-identical pins honest after the pool grew
/// owner-tagged slots).
#[test]
fn exclusive_presets_never_specialize() {
    use coldfaas::fnplat::DriverKind;
    use coldfaas::platform::{run_platform, DriverProfile, PlatformConfig, PlatformLoad};
    use coldfaas::policy::FixedKeepAlive;
    use coldfaas::sim::Host;
    use coldfaas::workload::tenants::{TenantConfig, TenantTrace};

    let trace = TenantTrace::generate(&TenantConfig {
        functions: 50,
        duration_s: 30.0,
        total_rps: 40.0,
        seed: 0xE16,
        ..Default::default()
    });
    let cfg = PlatformConfig {
        load: PlatformLoad::Tenants(trace.clone()),
        functions: 50,
        nodes: 4,
        ..PlatformConfig::single_node(DriverProfile::from_kind(DriverKind::DockerWarm), 8)
    };
    let r = run_platform(&cfg, &mut FixedKeepAlive::default(), Host::default());
    assert_eq!(r.specializations, 0);
    assert_eq!(r.warm_hits + r.cold_starts, trace.len() as u64);
}

/// S26 shard invariance: the sharded accounting plane is a pure
/// partition of the single engine's bookkeeping, so `run_platform` must
/// produce byte-identical results for *every* shard count — K=1 (the
/// legacy layout), K>1, and K past the node count (clamped) — over both
/// a fault-free and a crashing schedule (crash/restart messages cross
/// shards too).  The mailbox traffic itself is K-invariant: posting is
/// per-event, not per-shard.
#[test]
fn sharded_runs_are_byte_identical_for_every_shard_count() {
    use coldfaas::fnplat::DriverKind;
    use coldfaas::platform::{
        chaos_plan, run_platform, DriverProfile, FaultPlan, PlatformConfig, PlatformLoad,
    };
    use coldfaas::policy::FixedKeepAlive;
    use coldfaas::sim::Host;
    use coldfaas::workload::tenants::{TenantConfig, TenantTrace};

    let trace = TenantTrace::generate(&TenantConfig {
        functions: 60,
        duration_s: 30.0,
        total_rps: 50.0,
        seed: 0x526,
        ..Default::default()
    });
    let run = |shards: usize, faults: FaultPlan| {
        let cfg = PlatformConfig {
            load: PlatformLoad::Tenants(trace.clone()),
            functions: 60,
            nodes: 6,
            shards,
            faults,
            ..PlatformConfig::single_node(DriverProfile::from_kind(DriverKind::DockerWarm), 8)
        };
        run_platform(&cfg, &mut FixedKeepAlive::default(), Host::default())
    };
    for faults in [FaultPlan::default(), chaos_plan(6, 30 * 1_000_000_000)] {
        let single = run(1, faults.clone());
        assert_eq!(single.shards, 1);
        for shards in [2, 3, 5, 6, 64] {
            let sharded = run(shards, faults.clone());
            assert_eq!(sharded.shards, shards.min(6), "plan clamps to the node count");
            assert_eq!(sharded.latencies_ns, single.latencies_ns, "K={shards}");
            assert_eq!(sharded.requests, single.requests, "K={shards}");
            assert_eq!(sharded.cold_starts, single.cold_starts, "K={shards}");
            assert_eq!(sharded.warm_hits, single.warm_hits, "K={shards}");
            assert_eq!(sharded.specializations, single.specializations, "K={shards}");
            assert_eq!(sharded.monitor_events, single.monitor_events, "K={shards}");
            assert_eq!(
                sharded.idle_gb_seconds.to_bits(),
                single.idle_gb_seconds.to_bits(),
                "K={shards}"
            );
            assert_eq!(
                (sharded.crashes, sharded.killed, sharded.retries),
                (single.crashes, single.killed, single.retries),
                "K={shards}"
            );
            assert_eq!(sharded.events, single.events, "K={shards}");
            assert_eq!(sharded.elapsed_ns, single.elapsed_ns, "K={shards}");
            assert_eq!(sharded.shard_msgs, single.shard_msgs, "mailbox traffic is K-invariant");
            assert_eq!(sharded.shard_barriers, single.shard_barriers, "K={shards}");
        }
    }
}

/// S27: the rolling state-hash chain joins the determinism contract —
/// same seed, same chain — and folds only canonical (layout-free)
/// sections, so every shard count walks the identical hash trajectory
/// over both a fault-free and a crashing schedule.  The CI determinism
/// matrix runs this suite under `COLDFAAS_SWEEP_THREADS=1` and the
/// default, extending the pin across finalize-thread settings (the fold
/// happens in the single-threaded engine loop, so threads cannot touch
/// it — this test is what would catch that assumption breaking).
#[test]
fn state_hash_chain_is_deterministic_and_shard_invariant() {
    use coldfaas::fnplat::DriverKind;
    use coldfaas::platform::{
        chaos_plan, run_platform, DriverProfile, FaultPlan, PlatformConfig, PlatformLoad,
    };
    use coldfaas::policy::FixedKeepAlive;
    use coldfaas::sim::Host;
    use coldfaas::workload::tenants::{TenantConfig, TenantTrace};

    let trace = TenantTrace::generate(&TenantConfig {
        functions: 60,
        duration_s: 30.0,
        total_rps: 50.0,
        seed: 0x527,
        ..Default::default()
    });
    let run = |shards: usize, seed: u64, faults: FaultPlan| {
        let cfg = PlatformConfig {
            load: PlatformLoad::Tenants(trace.clone()),
            functions: 60,
            nodes: 8,
            shards,
            faults,
            state_hash: true,
            seed,
            ..PlatformConfig::single_node(DriverProfile::from_kind(DriverKind::DockerWarm), 8)
        };
        let r = run_platform(&cfg, &mut FixedKeepAlive::default(), Host::default());
        (r.state_hash.expect("armed run must produce a chain"), r.state_hash_folds)
    };
    for faults in [FaultPlan::default(), chaos_plan(8, 30 * 1_000_000_000)] {
        let pin = run(1, 0x5EED, faults.clone());
        assert!(pin.1 >= 2, "a 30s trace must cross several 10s barriers: {} folds", pin.1);
        assert_eq!(pin, run(1, 0x5EED, faults.clone()), "same seed must refold the same chain");
        for shards in [2, 8] {
            assert_eq!(pin, run(shards, 0x5EED, faults.clone()), "K={shards}");
        }
        assert_ne!(
            pin.0,
            run(1, 0x5EED ^ 1, faults.clone()).0,
            "a different seed must change the chain"
        );
    }
}

/// E14 determinism: the same seed drives the same trace *and* the same
/// fault schedule, so the chaos report must be byte-identical per run —
/// crashes, kills, retries and all.
#[test]
fn chaos_report_is_byte_identical_per_seed_and_plan() {
    let cfg = small();
    let a = experiments::by_name("chaos", &cfg).expect("known experiment").render();
    let b = experiments::by_name("chaos", &cfg).expect("known experiment").render();
    assert_eq!(a, b, "chaos: same seed + same fault plan must reproduce byte-identically");
    let other = ExpConfig { seed: cfg.seed ^ 0x5EED, ..small() };
    let c = experiments::by_name("chaos", &other).expect("known experiment").render();
    assert_ne!(a, c, "chaos: a different seed must change the measurement");
}

/// Refactor guard for the fault layer itself: running a preset-shaped
/// config through `run_platform` with an explicit empty/dry plan is
/// byte-identical to the default config — the fault machinery must be
/// observationally absent until a plan schedules real events.
#[test]
fn empty_and_dry_fault_plans_do_not_perturb_platform_runs() {
    use coldfaas::fnplat::DriverKind;
    use coldfaas::platform::{
        chaos_plan, run_platform, DriverProfile, FaultPlan, PlatformConfig, PlatformLoad,
    };
    use coldfaas::policy::FixedKeepAlive;
    use coldfaas::sim::Host;
    use coldfaas::workload::tenants::{TenantConfig, TenantTrace};

    let trace = TenantTrace::generate(&TenantConfig {
        functions: 50,
        duration_s: 30.0,
        total_rps: 40.0,
        seed: 0xD1FF,
        ..Default::default()
    });
    let run = |faults: FaultPlan| {
        let cfg = PlatformConfig {
            load: PlatformLoad::Tenants(trace.clone()),
            functions: 50,
            nodes: 4,
            exact_latencies: true,
            faults,
            ..PlatformConfig::single_node(
                DriverProfile::from_kind(DriverKind::DockerWarm),
                8,
            )
        };
        run_platform(&cfg, &mut FixedKeepAlive::default(), Host::default())
    };
    let default_plan = run(FaultPlan::default());
    let dry = run(chaos_plan(4, 30 * 1_000_000_000).dry());
    assert_eq!(default_plan.latencies_ns, dry.latencies_ns);
    assert_eq!(default_plan.cold_starts, dry.cold_starts);
    assert_eq!(default_plan.warm_hits, dry.warm_hits);
    assert_eq!(default_plan.idle_gb_seconds, dry.idle_gb_seconds);
    assert_eq!(default_plan.elapsed_ns, dry.elapsed_ns);
    assert_eq!((dry.crashes, dry.killed, dry.retries), (0, 0, 0));
}
