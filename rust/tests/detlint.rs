//! Tier-1: detlint over the live tree, plus fixture coverage proving
//! every rule fires and every suppression channel works (DESIGN.md S28).
//!
//! The compiled `Fx` struct at the bottom doubles as the snapshot-codec
//! round-trip fixture: it is encoded/decoded through the real
//! [`coldfaas::sim::snap`] codec *and* this very file is fed back
//! through the analyzer under a sim-side path, so deleting a codec arm
//! for any `Fx` field fails the suite from two directions.

use std::path::Path;

use coldfaas::analysis::{lint_source, lint_tree, render_text, Allowlist};
use coldfaas::sim::snap::{Dec, Enc};

/// Lint `src` as if it lived at `path` (no allowlist) and return the
/// surviving findings as `(code, line)` pairs.
fn findings(path: &str, src: &str) -> Vec<(&'static str, u32)> {
    let (fs, _) = lint_source(path, src, &Allowlist::default());
    fs.iter().map(|f| (f.code, f.line)).collect()
}

fn codes(path: &str, src: &str) -> Vec<&'static str> {
    findings(path, src).into_iter().map(|(c, _)| c).collect()
}

// ------------------------------------------------------------ live tree

/// The committed tree is lint-clean: every wall-clock island is in
/// `detlint.allow`, every deliberate exception carries a justified
/// pragma, and every snapshotted struct's codec is complete.  The panic
/// message is the full rendered report, so a regression names itself.
#[test]
fn live_tree_is_clean() {
    let report = lint_tree(Path::new(env!("CARGO_MANIFEST_DIR"))).expect("lint_tree");
    assert!(report.files > 50, "scanned only {} files — wrong root?", report.files);
    assert!(report.suppressed > 0, "expected allowlisted islands to register");
    assert!(
        report.findings.is_empty(),
        "detlint findings in the live tree:\n{}",
        render_text(&report)
    );
}

// --------------------------------------------------------------- DL001

#[test]
fn dl001_wall_clock_fires() {
    let src =
        "fn f() -> u64 { let t = std::time::Instant::now(); t.elapsed().as_nanos() as u64 }\n";
    assert_eq!(findings("src/sim/fx.rs", src), [("DL001", 1)]);
    let sleep = "fn f(d: Duration) { std::thread::sleep(d); }\n";
    assert_eq!(codes("src/platform/fx.rs", sleep), ["DL001"]);
    let systime = "fn f() -> std::time::SystemTime { todo!() }\n";
    assert_eq!(codes("src/policy/fx.rs", systime), ["DL001"]);
}

#[test]
fn dl001_islands_are_exempt() {
    let src = "fn f() { let _t = std::time::Instant::now(); }\n";
    // Built-in islands need no annotation at all.
    assert!(codes("src/gateway/http.rs", src).is_empty());
    assert!(codes("src/obs/profile.rs", src).is_empty());
    // Everything else does.
    assert_eq!(codes("src/obs/telemetry.rs", src), ["DL001"]);
}

#[test]
fn dl001_pragma_suppresses() {
    let trailing =
        "fn f() { let _t = std::time::Instant::now(); } // detlint: allow(DL001) fixture\n";
    let (fs, suppressed) = lint_source("src/sim/fx.rs", trailing, &Allowlist::default());
    assert!(fs.is_empty());
    assert_eq!(suppressed, 1);
    let preceding =
        "// detlint: allow(DL001) fixture\nfn f() { let _t = std::time::Instant::now(); }\n";
    assert!(codes("src/sim/fx.rs", preceding).is_empty());
    // A pragma for the wrong rule does not suppress.
    let wrong =
        "fn f() { let _t = std::time::Instant::now(); } // detlint: allow(DL002) fixture\n";
    assert_eq!(codes("src/sim/fx.rs", wrong), ["DL001"]);
}

#[test]
fn dl001_allowlist_islands() {
    let allow = Allowlist::parse("DL001 src/exec/ live timing\n").expect("parse");
    let src = "fn f() { let _t = std::time::Instant::now(); }\n";
    let (fs, suppressed) = lint_source("src/exec/fx.rs", src, &allow);
    assert!(fs.is_empty());
    assert_eq!(suppressed, 1);
    // The entry is (code, prefix)-scoped: other paths and rules still fire.
    let (fs, _) = lint_source("src/sim/fx.rs", src, &allow);
    assert_eq!(fs.len(), 1);
}

// --------------------------------------------------------------- DL002

#[test]
fn dl002_hash_iteration_fires() {
    let for_loop = "struct S { m: HashMap<String, u32> }\n\
                    impl S { fn f(&self) { for (_k, _v) in &self.m {} } }\n";
    assert_eq!(findings("src/platform/fx.rs", for_loop), [("DL002", 2)]);
    let method = "fn f(m: &HashMap<u32, u32>) -> usize { m.keys().count() }\n";
    assert_eq!(codes("src/sim/fx.rs", method), ["DL002"]);
    let set = "fn f(s: &mut HashSet<u32>) { s.retain(|x| *x > 0); }\n";
    assert_eq!(codes("src/fnplat/fx.rs", set), ["DL002"]);
}

#[test]
fn dl002_keyed_access_and_other_dirs_pass() {
    // Keyed lookup is the legal use of a HashMap in the DES core.
    let keyed = "fn f(m: &HashMap<u32, u32>) -> Option<&u32> { m.get(&1) }\n";
    assert!(codes("src/sim/fx.rs", keyed).is_empty());
    // Outside the deterministic core the rule does not apply.
    let loopy = "struct S { m: HashMap<String, u32> }\n\
                 impl S { fn f(&self) { for (_k, _v) in &self.m {} } }\n";
    assert!(codes("src/gateway/fx.rs", loopy).is_empty());
    // Iterating a *BTreeMap* is fine anywhere.
    let btree = "fn f(m: &BTreeMap<u32, u32>) { for (_k, _v) in m {} }\n";
    assert!(codes("src/sim/fx.rs", btree).is_empty());
}

#[test]
fn dl002_pragma_suppresses() {
    let src = "struct S { m: HashMap<String, u32> }\n\
               impl S { fn f(&self) -> Vec<&String> {\n\
               // detlint: allow(DL002) collected then sorted below\n\
               let mut v: Vec<&String> = self.m.keys().collect();\n\
               v.sort(); v } }\n";
    let (fs, suppressed) = lint_source("src/platform/fx.rs", src, &Allowlist::default());
    assert!(fs.is_empty(), "{fs:?}");
    assert_eq!(suppressed, 1);
}

// --------------------------------------------------------------- DL003

#[test]
fn dl003_lenient_parse_fires_and_suppresses() {
    let bad = "fn f(s: &str) -> u32 { s.parse().unwrap_or(0) }\n";
    assert_eq!(findings("src/gateway/fx.rs", bad), [("DL003", 1)]);
    let turbofish = "fn f(s: &str) -> u64 { s.parse::<u64>().unwrap_or_default() }\n";
    assert_eq!(codes("src/main.rs", turbofish), ["DL003"]);
    // `unwrap_or` on anything that is not a fresh `parse()` result is legal.
    let option = "fn f(o: Option<u32>) -> u32 { o.unwrap_or(0) }\n";
    assert!(codes("src/main.rs", option).is_empty());
    let handled =
        "fn f(s: &str) -> Result<u32, String> { s.parse().map_err(|e| format!(\"{e}\")) }\n";
    assert!(codes("src/main.rs", handled).is_empty());
    let sup = "fn f(s: &str) -> u32 { s.parse().unwrap_or(0) } // detlint: allow(DL003) fixture\n";
    assert!(codes("src/main.rs", sup).is_empty());
}

// --------------------------------------------------------------- DL004

#[test]
fn dl004_mutating_debug_assert_fires_and_suppresses() {
    let push = "fn f(v: &mut Vec<u32>) { debug_assert!(v.pop().is_some()); }\n";
    assert_eq!(findings("src/sim/fx.rs", push), [("DL004", 1)]);
    let add = "fn f(mut n: u32) { debug_assert!({ n += 1; n > 0 }); }\n";
    assert_eq!(codes("src/sim/fx.rs", add), ["DL004"]);
    let eq = "fn f(s: &mut HashSet<u32>) { debug_assert_eq!(s.insert(1), true); }\n";
    assert_eq!(codes("src/metrics/fx.rs", eq), ["DL004"]);
    // Pure reads are fine.
    let pure = "fn f(v: &[u32]) { debug_assert!(!v.is_empty()); }\n";
    assert!(codes("src/sim/fx.rs", pure).is_empty());
    let sup =
        "fn f(v: &mut Vec<u32>) { debug_assert!(v.pop().is_some()); } // detlint: allow(DL004) fx\n";
    assert!(codes("src/sim/fx.rs", sup).is_empty());
}

// --------------------------------------------------------------- DL005

/// Source-level fixture: `missing` has no codec arm.  The shape mirrors
/// the real `PlatformSim::encode_state`/`restore_state` pair.
const FX_INCOMPLETE: &str = r#"
pub struct Fx {
    a: u64,
    b: f64,
    missing: u32,
}
impl Fx {
    fn encode_state(&self, enc: &mut Enc) {
        enc.u64(self.a);
        enc.f64(self.b);
    }
    fn restore_state(&mut self, dec: &mut Dec) {
        self.a = dec.u64();
        self.b = dec.f64();
    }
}
"#;

#[test]
fn dl005_omitted_field_is_flagged() {
    let fs = findings("src/platform/fx.rs", FX_INCOMPLETE);
    // Exactly one finding, anchored to `missing`'s declaration line.
    assert_eq!(fs, [("DL005", 5)]);
    let (full, _) = lint_source("src/platform/fx.rs", FX_INCOMPLETE, &Allowlist::default());
    assert!(full[0].msg.contains("`missing`"), "{}", full[0].msg);
    assert!(full[0].msg.contains("`Fx`"), "{}", full[0].msg);
}

#[test]
fn dl005_complete_codec_passes() {
    let complete = FX_INCOMPLETE
        .replace("enc.f64(self.b);", "enc.f64(self.b);\n        enc.u32(self.missing);")
        .replace("self.b = dec.f64();", "self.b = dec.f64();\n        self.missing = dec.u32();");
    assert!(codes("src/platform/fx.rs", &complete).is_empty());
    // Covering the field in *either* direction (here: decode only) is
    // enough for the union-of-bodies check.
    let decode_only = FX_INCOMPLETE
        .replace("self.b = dec.f64();", "self.b = dec.f64();\n        self.missing = 0;");
    assert!(codes("src/platform/fx.rs", &decode_only).is_empty());
}

#[test]
fn dl005_pragma_suppresses() {
    let annotated = FX_INCOMPLETE
        .replace("missing: u32,", "missing: u32, // detlint: allow(DL005) rebuilt on attach");
    let (fs, suppressed) = lint_source("src/platform/fx.rs", &annotated, &Allowlist::default());
    assert!(fs.is_empty());
    assert_eq!(suppressed, 1);
}

#[test]
fn dl005_struct_without_codec_is_ignored() {
    let src = "pub struct Plain { a: u64, b: f64 }\n\
               impl Plain { fn sum(&self) -> f64 { self.a as f64 + self.b } }\n";
    assert!(codes("src/platform/fx.rs", src).is_empty());
}

// ----------------------------------------------- compiled codec fixture

/// Compiled round-trip fixture: encoded and decoded through the *real*
/// snapshot codec, and scanned by detlint via [`fixture_file_is_codec_complete`].
#[derive(Debug, PartialEq)]
struct Fx {
    a: u64,
    b: f64,
    s: String,
}

impl Fx {
    fn encode_state(&self, enc: &mut Enc) {
        enc.u64(self.a);
        enc.f64(self.b);
        enc.str(&self.s);
    }

    fn restore_state(dec: &mut Dec) -> Fx {
        Fx { a: dec.u64(), b: dec.f64(), s: dec.str() }
    }
}

#[test]
fn fx_round_trips_through_snapshot_codec() {
    let fx = Fx { a: 7, b: 1.5, s: "cold".into() };
    let mut enc = Enc::new();
    fx.encode_state(&mut enc);
    let mut dec = Dec::new(&enc.buf);
    let back = Fx::restore_state(&mut dec);
    dec.finish(); // every byte consumed
    assert_eq!(back, fx);
}

/// Feed this very file through the analyzer under a sim-side path: the
/// `Fx` codec above must stay complete.  Deleting any `enc.*`/`dec.*`
/// arm (while the field remains) turns this test red — the acceptance
/// property that a dropped codec arm fails the suite.
#[test]
fn fixture_file_is_codec_complete() {
    let src = include_str!("detlint.rs");
    let (fs, suppressed) = lint_source("src/sim/detlint_fixture.rs", src, &Allowlist::default());
    assert!(fs.is_empty(), "fixture findings:\n{fs:#?}");
    assert_eq!(suppressed, 0, "the compiled fixture must not need pragmas");
}

// ------------------------------------------------------------ allowlist

#[test]
fn allowlist_parse_and_match() {
    let a = Allowlist::parse("# comment\n\nDL001 src/exec/ live timing\nDL005 src/x.rs why\n")
        .expect("parse");
    assert!(a.allows("DL001", "src/exec/mod.rs"));
    assert!(a.allows("DL005", "src/x.rs"));
    assert!(!a.allows("DL001", "src/sim/engine.rs"));
    assert!(!a.allows("DL002", "src/exec/mod.rs"));
}

#[test]
fn allowlist_requires_justification() {
    let err = Allowlist::parse("DL001 src/exec/\n").expect_err("must fail");
    assert!(err.contains("justification"), "{err}");
    // And a code that does not look like a rule is rejected too.
    assert!(Allowlist::parse("XX001 src/exec/ why\n").is_err());
}
