//! Live serving coordinator (S9): the cold-only FaaS control plane, plus
//! the warm-pool baseline, over real HTTP and real PJRT execution.
//!
//! Architecture (PjRtClient is `Rc`-based, so executables cannot cross
//! threads): gateway worker threads parse requests and apply the startup
//! model; one or more dedicated **engine threads** each own a complete
//! PJRT runtime and drain a shared job queue — the same frontend/engine
//! split a serving system like vLLM uses.
//!
//! ```text
//!  HTTP workers ──(startup model: sleep)──> job queue ──> engine thread(s)
//!       ^                                                     │  PJRT
//!       └───────────────── reply channel ────────────────────┘
//! ```

mod engine;
mod stats;

pub use engine::{EnginePool, ExecReply};
pub use stats::CoordStats;

use std::sync::{Arc, Mutex, RwLock};
use std::time::Instant;

use crate::exec::{parse_payload, summarize_output, RealtimeStartup};
use crate::fnplat::pool::{Dispatch, WarmPool};
use crate::fnplat::DriverKind;
use crate::gateway::http::{Handler, Request, Response, Server};
use crate::sim::Rng;

/// Scheduling mode for the platform.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SchedMode {
    /// The paper's contribution: boot a fresh unikernel per request,
    /// let it exit afterwards.  No pool, no monitoring.
    ColdOnly,
    /// The baseline: Docker-style warm pool with an idle timeout.
    WarmPool,
}

/// Coordinator configuration.
#[derive(Clone, Debug)]
pub struct Config {
    pub mode: SchedMode,
    /// Scale factor on modeled startup sleeps (0 = off, 1 = faithful).
    pub time_scale: f64,
    pub idle_timeout_s: f64,
    pub engine_threads: usize,
    pub gateway_workers: usize,
    pub artifacts_dir: std::path::PathBuf,
    /// Compile only these functions (empty = all in the manifest).
    pub functions: Vec<String>,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            mode: SchedMode::ColdOnly,
            time_scale: 1.0,
            idle_timeout_s: 30.0,
            engine_threads: 1,
            gateway_workers: 20,
            artifacts_dir: crate::runtime::default_artifacts_dir(),
            functions: Vec::new(),
        }
    }
}

/// One function's registry entry (derived from the manifest).
#[derive(Clone, Debug)]
pub struct FuncInfo {
    pub name: String,
    pub input_elements: usize,
    pub flops: u64,
    pub doc: String,
}

pub struct Coordinator {
    cfg: Config,
    engines: EnginePool,
    registry: RwLock<Vec<FuncInfo>>,
    cold_startup: RealtimeStartup,
    warm_unpause: RealtimeStartup,
    pool: Mutex<WarmPool>,
    rng: Mutex<Rng>,
    clock: Instant,
    pub stats: Arc<CoordStats>,
}

/// The reply for one invocation.
#[derive(Debug, Clone)]
pub struct InvokeOutcome {
    pub function: String,
    pub cold: bool,
    pub startup_model_ms: f64,
    pub exec_ms: f64,
    pub total_ms: f64,
    pub output_sum: f64,
    pub output_l2: f64,
    pub output_head: Vec<f32>,
}

impl Coordinator {
    pub fn start(cfg: Config) -> anyhow::Result<Arc<Coordinator>> {
        let names: Vec<String> = if cfg.functions.is_empty() {
            let m = crate::runtime::Manifest::load(&cfg.artifacts_dir)?;
            m.functions.iter().map(|f| f.name.clone()).collect()
        } else {
            cfg.functions.clone()
        };
        let engines = EnginePool::start(cfg.engine_threads, cfg.artifacts_dir.clone(), &names)?;
        let registry = engines.registry();
        let mem = DriverKind::DockerWarm.tech().warm_memory_bytes();
        let pool = WarmPool::new((cfg.idle_timeout_s * 1e9) as u64, mem);
        let cold_steps = match cfg.mode {
            SchedMode::ColdOnly => DriverKind::IncludeOsCold.cold_start_steps(),
            SchedMode::WarmPool => DriverKind::DockerWarm.cold_start_steps(),
        };
        Ok(Arc::new(Coordinator {
            cold_startup: RealtimeStartup::new(cold_steps, cfg.time_scale),
            warm_unpause: RealtimeStartup::new(
                DriverKind::DockerWarm.warm_invoke_steps(),
                cfg.time_scale,
            ),
            engines,
            registry: RwLock::new(registry),
            pool: Mutex::new(pool),
            rng: Mutex::new(Rng::new(0xC0F_FEE)),
            clock: Instant::now(),
            stats: Arc::new(CoordStats::default()),
            cfg,
        }))
    }

    pub fn registry(&self) -> Vec<FuncInfo> {
        self.registry.read().unwrap().clone()
    }

    pub fn mode(&self) -> SchedMode {
        self.cfg.mode
    }

    fn now_ns(&self) -> u64 {
        self.clock.elapsed().as_nanos() as u64
    }

    /// The full request path: startup model -> PJRT execution -> summary.
    pub fn invoke(&self, name: &str, body: &[u8]) -> Result<InvokeOutcome, String> {
        let t0 = Instant::now();
        let input_elements = self
            .registry
            .read()
            .unwrap()
            .iter()
            .find(|f| f.name == name)
            .map(|f| f.input_elements)
            .ok_or_else(|| format!("unknown function '{name}'"))?;
        let payload = parse_payload(body, input_elements)?;

        // Dispatch: consult the pool (warm mode) or always-cold.
        let (cold, startup_ns) = match self.cfg.mode {
            SchedMode::ColdOnly => {
                let ns = {
                    let mut rng = self.rng.lock().unwrap();
                    self.cold_startup.sample_ns(&mut rng)
                };
                // Sleep outside the rng lock.
                Self::scaled_sleep(ns, self.cfg.time_scale);
                (true, ns)
            }
            SchedMode::WarmPool => {
                let d = self.pool.lock().unwrap().dispatch(name, self.now_ns());
                let model =
                    if d == Dispatch::Cold { &self.cold_startup } else { &self.warm_unpause };
                let ns = {
                    let mut rng = self.rng.lock().unwrap();
                    model.sample_ns(&mut rng)
                };
                Self::scaled_sleep(ns, self.cfg.time_scale);
                (d == Dispatch::Cold, ns)
            }
        };

        let reply = self.engines.execute(name, payload)?;
        if self.cfg.mode == SchedMode::WarmPool {
            self.pool.lock().unwrap().release(name, self.now_ns());
        }

        let (sum, l2, head) = summarize_output(&reply.output);
        let total_ms = t0.elapsed().as_secs_f64() * 1e3;
        self.stats.record(name, cold, total_ms, reply.exec_ms);
        Ok(InvokeOutcome {
            function: name.to_string(),
            cold,
            startup_model_ms: startup_ns as f64 / 1e6,
            exec_ms: reply.exec_ms,
            total_ms,
            output_sum: sum,
            output_l2: l2,
            output_head: head,
        })
    }

    fn scaled_sleep(ns: u64, scale: f64) {
        let scaled = (ns as f64 * scale) as u64;
        if scaled > 0 {
            std::thread::sleep(std::time::Duration::from_nanos(scaled));
        }
    }

    /// Waste snapshot (warm mode): idle GB·s and monitor events so far.
    pub fn waste_snapshot(&self) -> (f64, u64) {
        let pool = self.pool.lock().unwrap();
        let now = self.now_ns();
        // Non-destructive estimate: clone and finalize the clone.
        let mut snap = pool.clone();
        snap.finalize(now);
        (snap.idle_gb_seconds(), snap.monitor_events)
    }

    /// Deploy a manifest function onto the live platform: simulate the
    /// §IV-B build (IncludeOS `boot` vs Docker FDK image, scaled by
    /// time_scale), warm the engine compile, and register the route.
    /// Returns (build_seconds_modeled, compile_warmup_ms).
    pub fn deploy(&self, name: &str) -> Result<(f64, f64), String> {
        if self.registry.read().unwrap().iter().any(|f| f.name == name) {
            return Err(format!("function '{name}' already deployed"));
        }
        let manifest = crate::runtime::Manifest::load(&self.cfg.artifacts_dir)
            .map_err(|e| e.to_string())?;
        let entry = manifest
            .get(name)
            .ok_or_else(|| format!("function '{name}' not in artifact manifest"))?;

        // §IV-B deploy-time build: 3.5 s IncludeOS boot vs 9.5 s Docker FDK.
        let build = match self.cfg.mode {
            SchedMode::ColdOnly => crate::image::BuildKind::IncludeOsBoot,
            SchedMode::WarmPool => crate::image::BuildKind::DockerFdk,
        };
        let build_s = build.build_seconds();
        Self::scaled_sleep((build_s * 1e9) as u64, self.cfg.time_scale);

        let info = FuncInfo {
            name: entry.name.clone(),
            input_elements: entry.inputs[0].elements(),
            flops: entry.flops,
            doc: entry.doc.clone(),
        };
        // Warm one engine's compile cache so the first request isn't a
        // multi-second XLA compile (remaining engines compile lazily).
        let t0 = Instant::now();
        let warm = crate::runtime::test_input(info.input_elements);
        self.engines.execute(name, warm)?;
        let compile_ms = t0.elapsed().as_secs_f64() * 1e3;

        self.registry.write().unwrap().push(info);
        Ok((build_s, compile_ms))
    }

    /// HTTP handler wiring all routes.
    pub fn handler(self: &Arc<Self>) -> Handler {
        let me = self.clone();
        Arc::new(move |req: &Request| me.route(req))
    }

    fn route(&self, req: &Request) -> Response {
        match (req.method.as_str(), req.path.as_str()) {
            ("GET", "/noop") => Response::ok(""),
            ("GET", "/healthz") => Response::ok("ok"),
            ("POST", p) if p.starts_with("/deploy/") => {
                let name = &p["/deploy/".len()..];
                match self.deploy(name) {
                    Ok((build_s, compile_ms)) => Response::json(format!(
                        "{{\"deployed\":\"{name}\",\"build_s\":{build_s:.1},\"compile_warmup_ms\":{compile_ms:.1}}}"
                    )),
                    Err(e) if e.contains("not in artifact manifest") => Response::not_found(),
                    Err(e) => Response::bad_request(&e),
                }
            }
            ("GET", "/functions") => {
                let mut out = String::new();
                for f in self.registry.read().unwrap().iter() {
                    out.push_str(&format!(
                        "{{\"name\":\"{}\",\"inputs\":{},\"flops\":{},\"doc\":\"{}\"}}\n",
                        f.name, f.input_elements, f.flops, f.doc
                    ));
                }
                Response::json(out)
            }
            ("GET", "/stats") => Response::json(self.stats.render_json(self.cfg.mode)),
            ("POST", p) if p.starts_with("/invoke/") => {
                let name = &p["/invoke/".len()..];
                match self.invoke(name, &req.body) {
                    Ok(o) => Response::json(format!(
                        "{{\"fn\":\"{}\",\"cold\":{},\"startup_model_ms\":{:.3},\"exec_ms\":{:.3},\
                         \"total_ms\":{:.3},\"output_sum\":{:.6},\"output_l2\":{:.6},\"output_head\":{:?}}}",
                        o.function,
                        o.cold,
                        o.startup_model_ms,
                        o.exec_ms,
                        o.total_ms,
                        o.output_sum,
                        o.output_l2,
                        o.output_head
                    )),
                    Err(e) if e.starts_with("unknown function") => Response::not_found(),
                    // Backend gone (pool shut down mid-drain): overload-path
                    // semantics, not a client error.
                    Err(e) if e == engine::ERR_POOL_DOWN || e == engine::ERR_REPLY_DROPPED => {
                        Response::unavailable(&e)
                    }
                    Err(e) => Response::bad_request(&e),
                }
            }
            _ => Response::not_found(),
        }
    }

    /// Start the HTTP gateway for this coordinator.
    pub fn serve(self: &Arc<Self>, bind: &str) -> std::io::Result<Server> {
        Server::start(bind, self.cfg.gateway_workers, self.handler())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_ready() -> bool {
        crate::runtime::default_artifacts_dir().join("manifest.json").exists()
    }

    fn test_config(mode: SchedMode) -> Config {
        Config {
            mode,
            time_scale: 0.0, // no sleeps in unit tests
            engine_threads: 1,
            gateway_workers: 4,
            functions: vec!["echo".into(), "checksum".into()],
            ..Config::default()
        }
    }

    #[test]
    fn cold_only_invoke_roundtrip() {
        if !artifacts_ready() {
            return;
        }
        let c = Coordinator::start(test_config(SchedMode::ColdOnly)).unwrap();
        let o = c.invoke("echo", b"").unwrap();
        assert!(o.cold);
        assert_eq!(o.function, "echo");
        // echo(test_input): sum must match the manifest oracle value.
        let want: f64 = crate::runtime::test_input(256).iter().map(|&x| x as f64).sum();
        assert!((o.output_sum - want).abs() < 1e-3);
    }

    #[test]
    fn warm_pool_second_invoke_is_warm() {
        if !artifacts_ready() {
            return;
        }
        let c = Coordinator::start(test_config(SchedMode::WarmPool)).unwrap();
        assert!(c.invoke("echo", b"").unwrap().cold);
        assert!(!c.invoke("echo", b"").unwrap().cold);
        let (waste, _) = c.waste_snapshot();
        assert!(waste >= 0.0);
    }

    #[test]
    fn unknown_function_rejected() {
        if !artifacts_ready() {
            return;
        }
        let c = Coordinator::start(test_config(SchedMode::ColdOnly)).unwrap();
        assert!(c.invoke("nope", b"").is_err());
    }

    #[test]
    fn bad_payload_rejected() {
        if !artifacts_ready() {
            return;
        }
        let c = Coordinator::start(test_config(SchedMode::ColdOnly)).unwrap();
        assert!(c.invoke("echo", b"1,2,3").is_err());
    }

    #[test]
    fn http_end_to_end() {
        if !artifacts_ready() {
            return;
        }
        let c = Coordinator::start(test_config(SchedMode::ColdOnly)).unwrap();
        let srv = c.serve("127.0.0.1:0").unwrap();
        let (status, body) =
            crate::gateway::http::http_request(srv.addr(), "POST", "/invoke/echo", b"").unwrap();
        assert_eq!(status, 200, "{}", String::from_utf8_lossy(&body));
        let text = String::from_utf8(body).unwrap();
        assert!(text.contains("\"cold\":true"));
        let (status, _) = crate::gateway::http::http_request(srv.addr(), "GET", "/stats", b"").unwrap();
        assert_eq!(status, 200);
        srv.shutdown();
    }
}
