//! Engine threads: each owns a full PJRT [`Runtime`] (the `xla` client is
//! `Rc`-based and cannot cross threads) and drains a shared job queue.

use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::Result;

use super::FuncInfo;

/// Backend-loss error messages: the HTTP layer classifies these as 503
/// (service unavailable) rather than 400 — keep the constants shared so
/// rewording can't silently downgrade them.
pub const ERR_POOL_DOWN: &str = "engine pool shut down";
pub const ERR_REPLY_DROPPED: &str = "engine dropped reply";

/// Result of one engine execution.
pub struct ExecReply {
    pub output: Vec<f32>,
    pub exec_ms: f64,
}

struct Job {
    name: String,
    payload: Vec<f32>,
    reply: mpsc::Sender<Result<ExecReply, String>>,
}

/// Fixed pool of engine threads sharing one job queue.
pub struct EnginePool {
    tx: mpsc::Sender<Job>,
    registry: Vec<FuncInfo>,
    threads: Vec<std::thread::JoinHandle<()>>,
}

impl EnginePool {
    /// Spawn `n` engine threads; each builds its own [`Runtime`] *inside*
    /// the thread (the PJRT client is `Rc`-based and cannot be moved in).
    /// Fails fast if the first engine cannot load.
    pub fn start(n: usize, dir: std::path::PathBuf, names: &[String]) -> Result<EnginePool> {
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let mut threads = Vec::new();
        let mut registry: Option<Vec<FuncInfo>> = None;

        for i in 0..n.max(1) {
            let dir = dir.clone();
            let names: Vec<String> = names.to_vec();
            let rx = rx.clone();
            // The first thread reports its load result (and the registry)
            // so startup errors surface synchronously.
            let (ready_tx, ready_rx) = mpsc::channel::<Result<Vec<FuncInfo>, String>>();
            threads.push(std::thread::spawn(move || {
                let name_refs: Vec<&str> = names.iter().map(String::as_str).collect();
                match crate::runtime::Runtime::load_only(&dir, &name_refs) {
                    Ok(rt) => {
                        let reg = rt
                            .names()
                            .iter()
                            .map(|&n| {
                                let e = rt.entry(n).expect("loaded entry");
                                FuncInfo {
                                    name: n.to_string(),
                                    input_elements: e.inputs[0].elements(),
                                    flops: e.flops,
                                    doc: e.doc.clone(),
                                }
                            })
                            .collect();
                        let _ = ready_tx.send(Ok(reg));
                        Self::engine_loop(rt, rx);
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e.to_string()));
                        eprintln!("engine thread failed to load runtime: {e}");
                    }
                }
            }));
            if i == 0 {
                match ready_rx.recv() {
                    Ok(Ok(reg)) => registry = Some(reg),
                    Ok(Err(e)) => return Err(anyhow::anyhow!("engine 0 failed: {e}")),
                    Err(_) => return Err(anyhow::anyhow!("engine 0 died during load")),
                }
            }
        }
        Ok(EnginePool { tx, registry: registry.expect("first engine ready"), threads })
    }

    fn engine_loop(mut rt: crate::runtime::Runtime, rx: Arc<Mutex<mpsc::Receiver<Job>>>) {
        loop {
            // Hold the queue lock only while dequeuing.
            let job = {
                let guard = rx.lock().unwrap();
                guard.recv()
            };
            let Ok(job) = job else { return }; // senders dropped: shut down
            // Lazy deploy: compile manifest functions on first use, so a
            // freshly deployed function works on every engine thread.
            if rt.get(&job.name).is_none() {
                if let Err(e) = rt.ensure_loaded(&job.name) {
                    let _ = job.reply.send(Err(e.to_string()));
                    continue;
                }
            }
            let t0 = Instant::now();
            let result = rt
                .execute(&job.name, &job.payload)
                .map(|output| ExecReply { output, exec_ms: t0.elapsed().as_secs_f64() * 1e3 })
                .map_err(|e| e.to_string());
            let _ = job.reply.send(result);
        }
    }

    pub fn registry(&self) -> Vec<FuncInfo> {
        self.registry.clone()
    }

    /// Synchronously execute on some engine thread.
    pub fn execute(&self, name: &str, payload: Vec<f32>) -> Result<ExecReply, String> {
        let (reply_tx, reply_rx) = mpsc::channel();
        self.tx
            .send(Job { name: name.to_string(), payload, reply: reply_tx })
            .map_err(|_| ERR_POOL_DOWN.to_string())?;
        reply_rx.recv().map_err(|_| ERR_REPLY_DROPPED.to_string())?
    }

    /// Drop the queue and join the engine threads.
    pub fn shutdown(self) {
        drop(self.tx);
        for t in self.threads {
            let _ = t.join();
        }
    }
}
