//! Lock-cheap serving statistics: per-outcome histograms on the hot path.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::metrics::Histogram;

#[derive(Default)]
pub struct CoordStats {
    pub requests: AtomicU64,
    pub cold_starts: AtomicU64,
    pub warm_hits: AtomicU64,
    pub errors: AtomicU64,
    total: Mutex<Histogram>,
    exec: Mutex<Histogram>,
}

impl CoordStats {
    pub fn record(&self, _name: &str, cold: bool, total_ms: f64, exec_ms: f64) {
        self.requests.fetch_add(1, Ordering::Relaxed);
        if cold {
            self.cold_starts.fetch_add(1, Ordering::Relaxed);
        } else {
            self.warm_hits.fetch_add(1, Ordering::Relaxed);
        }
        self.total.lock().unwrap().record_ns((total_ms * 1e6) as u64);
        self.exec.lock().unwrap().record_ns((exec_ms * 1e6) as u64);
    }

    pub fn total_quantiles_ms(&self) -> (f64, f64, f64) {
        let h = self.total.lock().unwrap();
        (h.quantile_ms(0.5), h.quantile_ms(0.99), h.mean_ms())
    }

    pub fn exec_quantiles_ms(&self) -> (f64, f64, f64) {
        let h = self.exec.lock().unwrap();
        (h.quantile_ms(0.5), h.quantile_ms(0.99), h.mean_ms())
    }

    pub fn render_json(&self, mode: super::SchedMode) -> String {
        let (tp50, tp99, tmean) = self.total_quantiles_ms();
        let (ep50, ep99, emean) = self.exec_quantiles_ms();
        format!(
            "{{\"mode\":\"{:?}\",\"requests\":{},\"cold_starts\":{},\"warm_hits\":{},\"errors\":{},\
             \"total_ms\":{{\"p50\":{tp50:.3},\"p99\":{tp99:.3},\"mean\":{tmean:.3}}},\
             \"exec_ms\":{{\"p50\":{ep50:.3},\"p99\":{ep99:.3},\"mean\":{emean:.3}}}}}",
            mode,
            self.requests.load(Ordering::Relaxed),
            self.cold_starts.load(Ordering::Relaxed),
            self.warm_hits.load(Ordering::Relaxed),
            self.errors.load(Ordering::Relaxed),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_and_render() {
        let s = CoordStats::default();
        s.record("f", true, 10.0, 2.0);
        s.record("f", false, 5.0, 2.0);
        assert_eq!(s.requests.load(Ordering::Relaxed), 2);
        assert_eq!(s.cold_starts.load(Ordering::Relaxed), 1);
        assert_eq!(s.warm_hits.load(Ordering::Relaxed), 1);
        let json = s.render_json(crate::coordinator::SchedMode::ColdOnly);
        assert!(json.contains("\"requests\":2"));
        assert!(crate::runtime::Json::parse(&json).is_ok(), "stats must be valid json: {json}");
    }

    #[test]
    fn quantiles_reflect_samples() {
        let s = CoordStats::default();
        for i in 1..=100 {
            s.record("f", true, i as f64, 1.0);
        }
        let (p50, p99, mean) = s.total_quantiles_ms();
        assert!((p50 / 50.0 - 1.0).abs() < 0.1, "p50 {p50}");
        assert!((p99 / 99.0 - 1.0).abs() < 0.1, "p99 {p99}");
        assert!((mean / 50.5 - 1.0).abs() < 0.05, "mean {mean}");
    }
}
