//! Hand-rolled CLI (the offline registry has no clap): subcommands +
//! `--key value` / `--flag` options.

use std::collections::BTreeMap;

#[derive(Debug, Clone)]
pub struct Args {
    pub subcommand: String,
    pub positional: Vec<String>,
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    /// Parse `argv[1..]`.  Options may be `--key value` or `--key=value`;
    /// bare `--key` followed by another option (or end) is a flag.
    pub fn parse(argv: &[String]) -> Args {
        let mut it = argv.iter().peekable();
        let subcommand = it.next().cloned().unwrap_or_else(|| "help".to_string());
        let mut positional = Vec::new();
        let mut opts = BTreeMap::new();
        let mut flags = Vec::new();
        while let Some(a) = it.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    opts.insert(k.to_string(), v.to_string());
                } else if it.peek().is_some_and(|n| !n.starts_with("--")) {
                    opts.insert(rest.to_string(), it.next().unwrap().clone());
                } else {
                    flags.push(rest.to_string());
                }
            } else {
                positional.push(a.clone());
            }
        }
        Args { subcommand, positional, opts, flags }
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.opts.get(key).map(String::as_str)
    }

    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    /// Strict u64 option: absent → default, malformed → `Err` (the CLI
    /// rejects it instead of silently running with the default, which is
    /// how `--requests 10k` used to quietly mean 10 000 *paper-default*
    /// requests).
    pub fn try_get_u64(&self, key: &str, default: u64) -> Result<u64, String> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => {
                v.parse().map_err(|_| format!("--{key} {v}: not a valid non-negative integer"))
            }
        }
    }

    /// Strict u32 option: also rejects values that fit a u64 but not a
    /// u32 (`--cores 5000000000` used to truncate to a zero-core
    /// cluster).
    pub fn try_get_u32(&self, key: &str, default: u32) -> Result<u32, String> {
        let v = self.try_get_u64(key, default as u64)?;
        u32::try_from(v).map_err(|_| format!("--{key} {v}: out of range (max {})", u32::MAX))
    }

    /// Strict f64 option: absent → default, malformed or non-finite →
    /// `Err`.
    pub fn try_get_f64(&self, key: &str, default: f64) -> Result<f64, String> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => match v.parse::<f64>() {
                Ok(x) if x.is_finite() => Ok(x),
                _ => Err(format!("--{key} {v}: not a valid finite number")),
            },
        }
    }

    pub fn has_flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }

    /// Strict comma-separated u32 list: any malformed element rejects the
    /// whole option (a lenient variant that silently dropped bad elements
    /// is exactly the footgun the strict getters exist to remove).
    pub fn try_get_u32_list(&self, key: &str, default: &[u32]) -> Result<Vec<u32>, String> {
        match self.get(key) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .map(|t| {
                    let t = t.trim();
                    t.parse::<u32>().map_err(|_| format!("--{key} '{t}': not a valid u32"))
                })
                .collect(),
        }
    }

    /// Strict comma-separated f64 list: any malformed or non-finite
    /// element rejects the whole option.
    pub fn try_get_f64_list(&self, key: &str, default: &[f64]) -> Result<Vec<f64>, String> {
        match self.get(key) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .map(|t| {
                    let t = t.trim();
                    match t.parse::<f64>() {
                        Ok(x) if x.is_finite() => Ok(x),
                        _ => Err(format!("--{key} '{t}': not a valid finite number")),
                    }
                })
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &[&str]) -> Args {
        Args::parse(&s.iter().map(|x| x.to_string()).collect::<Vec<_>>())
    }

    #[test]
    fn subcommand_and_positional() {
        let a = parse(&["experiment", "fig1"]);
        assert_eq!(a.subcommand, "experiment");
        assert_eq!(a.positional, vec!["fig1"]);
    }

    #[test]
    fn key_value_both_syntaxes() {
        let a = parse(&["serve", "--port", "8080", "--mode=warm"]);
        assert_eq!(a.get("port"), Some("8080"));
        assert_eq!(a.get("mode"), Some("warm"));
    }

    #[test]
    fn trailing_flag() {
        let a = parse(&["experiment", "fig1", "--quick"]);
        assert!(a.has_flag("quick"));
        assert_eq!(a.get("quick"), None);
    }

    #[test]
    fn flag_before_option() {
        let a = parse(&["x", "--verbose", "--n", "5"]);
        assert!(a.has_flag("verbose"));
        assert_eq!(a.try_get_u64("n", 0), Ok(5));
    }

    #[test]
    fn numeric_defaults() {
        let a = parse(&["x"]);
        assert_eq!(a.try_get_f64("scale", 1.5), Ok(1.5));
        assert_eq!(a.try_get_u64("n", 7), Ok(7));
    }

    #[test]
    fn empty_argv_gives_help() {
        let a = Args::parse(&[]);
        assert_eq!(a.subcommand, "help");
    }

    #[test]
    fn strict_numeric_getters_reject_malformed_values() {
        let a = parse(&["x", "--n", "12", "--bad", "12k", "--f", "1.5", "--nan", "NaN"]);
        assert_eq!(a.try_get_u64("n", 7), Ok(12));
        assert_eq!(a.try_get_u64("missing", 7), Ok(7));
        assert!(a.try_get_u64("bad", 7).unwrap_err().contains("--bad"));
        assert_eq!(a.try_get_f64("f", 0.0), Ok(1.5));
        assert!(a.try_get_f64("nan", 0.0).is_err(), "non-finite must be rejected");
        assert!(a.try_get_f64("bad", 0.0).is_err());
    }

    #[test]
    fn strict_u32_rejects_out_of_range_instead_of_truncating() {
        let a = parse(&["x", "--cores", "5000000000", "--ok", "8"]);
        let err = a.try_get_u32("cores", 1).unwrap_err();
        assert!(err.contains("out of range"), "{err}");
        assert_eq!(a.try_get_u32("ok", 1), Ok(8));
        assert_eq!(a.try_get_u32("missing", 3), Ok(3));
    }

    #[test]
    fn strict_u32_list_rejects_any_bad_element() {
        let a = parse(&["x", "--parallelism", "1,5, 10", "--broken", "1,x,3"]);
        assert_eq!(a.try_get_u32_list("parallelism", &[2]), Ok(vec![1, 5, 10]));
        assert_eq!(a.try_get_u32_list("missing", &[2]), Ok(vec![2]));
        assert!(a.try_get_u32_list("broken", &[2]).is_err());
    }

    #[test]
    fn strict_f64_list_rejects_bad_and_non_finite_elements() {
        let a = parse(&["x", "--spec-costs", "0.5, 4,64", "--broken", "1,NaN", "--bad", "1,x"]);
        assert_eq!(a.try_get_f64_list("spec-costs", &[2.0]), Ok(vec![0.5, 4.0, 64.0]));
        assert_eq!(a.try_get_f64_list("missing", &[2.0]), Ok(vec![2.0]));
        assert!(a.try_get_f64_list("broken", &[2.0]).is_err(), "non-finite must be rejected");
        assert!(a.try_get_f64_list("bad", &[2.0]).is_err());
    }
}
