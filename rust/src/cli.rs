//! Hand-rolled CLI (the offline registry has no clap): subcommands +
//! `--key value` / `--flag` options.

use std::collections::BTreeMap;

#[derive(Debug, Clone)]
pub struct Args {
    pub subcommand: String,
    pub positional: Vec<String>,
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    /// Parse `argv[1..]`.  Options may be `--key value` or `--key=value`;
    /// bare `--key` followed by another option (or end) is a flag.
    pub fn parse(argv: &[String]) -> Args {
        let mut it = argv.iter().peekable();
        let subcommand = it.next().cloned().unwrap_or_else(|| "help".to_string());
        let mut positional = Vec::new();
        let mut opts = BTreeMap::new();
        let mut flags = Vec::new();
        while let Some(a) = it.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    opts.insert(k.to_string(), v.to_string());
                } else if it.peek().is_some_and(|n| !n.starts_with("--")) {
                    opts.insert(rest.to_string(), it.next().unwrap().clone());
                } else {
                    flags.push(rest.to_string());
                }
            } else {
                positional.push(a.clone());
            }
        }
        Args { subcommand, positional, opts, flags }
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.opts.get(key).map(String::as_str)
    }

    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_u64(&self, key: &str, default: u64) -> u64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn has_flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }

    /// Comma-separated u32 list option.
    pub fn get_u32_list(&self, key: &str, default: &[u32]) -> Vec<u32> {
        match self.get(key) {
            Some(v) => v.split(',').filter_map(|t| t.trim().parse().ok()).collect(),
            None => default.to_vec(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &[&str]) -> Args {
        Args::parse(&s.iter().map(|x| x.to_string()).collect::<Vec<_>>())
    }

    #[test]
    fn subcommand_and_positional() {
        let a = parse(&["experiment", "fig1"]);
        assert_eq!(a.subcommand, "experiment");
        assert_eq!(a.positional, vec!["fig1"]);
    }

    #[test]
    fn key_value_both_syntaxes() {
        let a = parse(&["serve", "--port", "8080", "--mode=warm"]);
        assert_eq!(a.get("port"), Some("8080"));
        assert_eq!(a.get("mode"), Some("warm"));
    }

    #[test]
    fn trailing_flag() {
        let a = parse(&["experiment", "fig1", "--quick"]);
        assert!(a.has_flag("quick"));
        assert_eq!(a.get("quick"), None);
    }

    #[test]
    fn flag_before_option() {
        let a = parse(&["x", "--verbose", "--n", "5"]);
        assert!(a.has_flag("verbose"));
        assert_eq!(a.get_u64("n", 0), 5);
    }

    #[test]
    fn numeric_defaults() {
        let a = parse(&["x"]);
        assert_eq!(a.get_f64("scale", 1.5), 1.5);
        assert_eq!(a.get_u64("n", 7), 7);
    }

    #[test]
    fn u32_list() {
        let a = parse(&["x", "--parallelism", "1,5, 10"]);
        assert_eq!(a.get_u32_list("parallelism", &[2]), vec![1, 5, 10]);
        assert_eq!(a.get_u32_list("other", &[2]), vec![2]);
    }

    #[test]
    fn empty_argv_gives_help() {
        let a = Args::parse(&[]);
        assert_eq!(a.subcommand, "help");
    }
}
