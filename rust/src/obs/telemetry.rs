//! Interval time-series telemetry: per-interval dispatch rates, cold
//! fraction, pool occupancy, and fault counters, collected into columnar
//! series for report serialization and sparkline rendering.
//!
//! Sampling is **lazy and event-driven**: the platform checks
//! [`Telemetry::pending`] at the top of every domain callback and calls
//! [`Telemetry::advance`] only when a boundary has passed — no timer
//! events are injected into the engine heap and no RNG is drawn, so a
//! run with telemetry on produces byte-identical measurements to the
//! same run with it off.  Counters recorded since the previous boundary
//! belong to the interval being closed (every event past a boundary
//! closes it before being counted); quiet periods fill forward with zero
//! counters and the gauges as last observed.

use crate::sim::snap::{Dec, Enc};

/// Instantaneous pool/cluster state sampled at interval boundaries.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Gauges {
    /// Idle warm executors live across all nodes.
    pub idle_slots: u64,
    /// Resident bytes those idle executors hold.
    pub idle_bytes: u64,
    /// User requests currently in flight across all nodes.
    pub inflight: u64,
}

/// The collected columnar series; all columns share one length (one
/// entry per closed interval).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct TelemetrySeries {
    pub interval_ns: u64,
    /// Cold dispatches / all dispatches per interval (0 when idle).
    pub cold_fraction: Vec<f64>,
    /// Warm-hit dispatches per second.
    pub warm_rate: Vec<f64>,
    /// Specialized-claim dispatches per second.
    pub spec_rate: Vec<f64>,
    /// Cold dispatches per second.
    pub cold_rate: Vec<f64>,
    /// Retry attempts spawned in the interval.
    pub retries: Vec<f64>,
    /// Chains rejected in the interval.
    pub rejected: Vec<f64>,
    /// Idle warm executors at the interval boundary.
    pub pool_slots: Vec<f64>,
    /// Idle resident memory at the boundary, in GB.
    pub idle_gb: Vec<f64>,
    /// In-flight user requests at the boundary.
    pub inflight: Vec<f64>,
}

impl TelemetrySeries {
    pub fn len(&self) -> usize {
        self.cold_fraction.len()
    }

    pub fn is_empty(&self) -> bool {
        self.cold_fraction.is_empty()
    }

    pub fn interval_s(&self) -> f64 {
        self.interval_ns as f64 / 1e9
    }

    /// `(label, points)` rows in a fixed order, for rendering.
    pub fn rows(&self) -> [(&'static str, &[f64]); 9] {
        [
            ("cold fraction", &self.cold_fraction),
            ("warm rate (1/s)", &self.warm_rate),
            ("spec rate (1/s)", &self.spec_rate),
            ("cold rate (1/s)", &self.cold_rate),
            ("retries", &self.retries),
            ("rejected", &self.rejected),
            ("pool slots", &self.pool_slots),
            ("idle GB", &self.idle_gb),
            ("in-flight", &self.inflight),
        ]
    }
}

/// The interval collector the platform domain owns.  Disabled (interval
/// 0) it is a couple of integer compares per event; enabled it closes
/// intervals lazily as virtual time passes boundaries.
#[derive(Clone, Debug, Default)]
pub struct Telemetry {
    interval_ns: u64,
    next_boundary_ns: u64,
    warm: u64,
    spec: u64,
    cold: u64,
    retry: u64,
    reject: u64,
    /// Interval samples taken — the telemetry layer's own observability
    /// cost, reported separately from pool monitor events and engine
    /// events.
    pub samples: u64,
    series: TelemetrySeries,
}

impl Telemetry {
    /// `interval_ns == 0` disables collection entirely.
    pub fn new(interval_ns: u64) -> Telemetry {
        Telemetry {
            interval_ns,
            next_boundary_ns: interval_ns,
            series: TelemetrySeries { interval_ns, ..Default::default() },
            ..Default::default()
        }
    }

    pub fn enabled(&self) -> bool {
        self.interval_ns > 0
    }

    /// Has virtual time passed the next boundary?  The hot-path check:
    /// callers only pay for gauge computation when this is true.
    pub fn pending(&self, now: u64) -> bool {
        self.interval_ns > 0 && now >= self.next_boundary_ns
    }

    /// Close every interval whose boundary is at or before `now`.  The
    /// first closed interval takes the accumulated counters (they all
    /// happened before its boundary); later ones fill forward with zero
    /// counters and the same gauges.
    pub fn advance(&mut self, now: u64, g: &Gauges) {
        while self.interval_ns > 0 && now >= self.next_boundary_ns {
            self.close_interval(g);
            self.next_boundary_ns += self.interval_ns;
        }
    }

    fn close_interval(&mut self, g: &Gauges) {
        let dispatches = self.warm + self.spec + self.cold;
        let secs = self.interval_ns as f64 / 1e9;
        let s = &mut self.series;
        s.cold_fraction.push(if dispatches == 0 {
            0.0
        } else {
            self.cold as f64 / dispatches as f64
        });
        s.warm_rate.push(self.warm as f64 / secs);
        s.spec_rate.push(self.spec as f64 / secs);
        s.cold_rate.push(self.cold as f64 / secs);
        s.retries.push(self.retry as f64);
        s.rejected.push(self.reject as f64);
        s.pool_slots.push(g.idle_slots as f64);
        s.idle_gb.push(g.idle_bytes as f64 / 1e9);
        s.inflight.push(g.inflight as f64);
        self.warm = 0;
        self.spec = 0;
        self.cold = 0;
        self.retry = 0;
        self.reject = 0;
        self.samples += 1;
    }

    pub fn on_warm(&mut self) {
        if self.interval_ns > 0 {
            self.warm += 1;
        }
    }

    pub fn on_spec(&mut self) {
        if self.interval_ns > 0 {
            self.spec += 1;
        }
    }

    pub fn on_cold(&mut self) {
        if self.interval_ns > 0 {
            self.cold += 1;
        }
    }

    pub fn on_retry(&mut self) {
        if self.interval_ns > 0 {
            self.retry += 1;
        }
    }

    pub fn on_reject(&mut self) {
        if self.interval_ns > 0 {
            self.reject += 1;
        }
    }

    /// Snapshot codec (S27): every interval counter plus the collected
    /// columnar series, floats as raw bit patterns.
    pub fn encode(&self, w: &mut Enc) {
        w.u64(self.interval_ns);
        w.u64(self.next_boundary_ns);
        w.u64(self.warm);
        w.u64(self.spec);
        w.u64(self.cold);
        w.u64(self.retry);
        w.u64(self.reject);
        w.u64(self.samples);
        w.u64(self.series.interval_ns);
        for (_, col) in self.series.rows() {
            w.len(col.len());
            for &v in col {
                w.f64(v);
            }
        }
    }

    /// Inverse of [`Self::encode`].
    pub fn decode(r: &mut Dec) -> Telemetry {
        let mut t = Telemetry {
            interval_ns: r.u64(),
            next_boundary_ns: r.u64(),
            warm: r.u64(),
            spec: r.u64(),
            cold: r.u64(),
            retry: r.u64(),
            reject: r.u64(),
            samples: r.u64(),
            series: TelemetrySeries::default(),
        };
        let col = |r: &mut Dec| -> Vec<f64> {
            let n = r.len();
            (0..n).map(|_| r.f64()).collect()
        };
        t.series.interval_ns = r.u64();
        t.series.cold_fraction = col(r);
        t.series.warm_rate = col(r);
        t.series.spec_rate = col(r);
        t.series.cold_rate = col(r);
        t.series.retries = col(r);
        t.series.rejected = col(r);
        t.series.pool_slots = col(r);
        t.series.idle_gb = col(r);
        t.series.inflight = col(r);
        t
    }

    /// End of run: close intervals up to `now`, flush a partial tail
    /// interval if it saw activity, and hand the series over (`None`
    /// when collection was disabled).
    pub fn finish(mut self, now: u64, g: &Gauges) -> Option<TelemetrySeries> {
        if self.interval_ns == 0 {
            return None;
        }
        self.advance(now, g);
        if self.warm + self.spec + self.cold + self.retry + self.reject > 0 {
            self.close_interval(g);
        }
        Some(self.series)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const S: u64 = 1_000_000_000;

    #[test]
    fn disabled_collector_takes_no_samples() {
        let mut t = Telemetry::new(0);
        assert!(!t.enabled());
        assert!(!t.pending(u64::MAX));
        t.on_warm();
        t.on_cold();
        assert!(t.finish(100 * S, &Gauges::default()).is_none());
    }

    #[test]
    fn counters_land_in_the_interval_they_occurred_in() {
        let mut t = Telemetry::new(10 * S);
        let g = Gauges { idle_slots: 2, idle_bytes: 3_000_000_000, inflight: 1 };
        // Two colds and a warm before the first boundary.
        t.on_cold();
        t.on_cold();
        t.on_warm();
        // First event past 10 s closes interval 0.
        assert!(t.pending(12 * S));
        t.advance(12 * S, &g);
        t.on_warm();
        let s = t.finish(15 * S, &g).unwrap();
        assert_eq!(s.len(), 2, "one full interval + the active tail");
        assert!((s.cold_fraction[0] - 2.0 / 3.0).abs() < 1e-12);
        assert_eq!(s.cold_rate[0], 0.2); // 2 colds / 10 s
        assert_eq!(s.warm_rate[1], 0.1);
        assert_eq!(s.pool_slots[0], 2.0);
        assert_eq!(s.idle_gb[0], 3.0);
        assert_eq!(s.inflight[0], 1.0);
    }

    #[test]
    fn quiet_periods_fill_forward_with_zero_counters() {
        let mut t = Telemetry::new(S);
        t.on_cold();
        // Next event 5 intervals later: intervals 0..=4 close at once.
        t.advance(5 * S + 1, &Gauges { idle_slots: 7, ..Default::default() });
        let s = t.finish(5 * S + 1, &Gauges::default()).unwrap();
        assert_eq!(s.len(), 5);
        assert_eq!(s.cold_rate[0], 1.0);
        assert!(s.cold_rate[1..].iter().all(|&r| r == 0.0));
        assert!(s.pool_slots.iter().all(|&p| p == 7.0), "gauges fill forward");
    }

    #[test]
    fn finish_flushes_partial_tail_only_when_active() {
        let mut t = Telemetry::new(10 * S);
        t.on_warm();
        let s = t.finish(3 * S, &Gauges::default()).unwrap();
        assert_eq!(s.len(), 1, "active tail flushed");
        let t2 = Telemetry::new(10 * S);
        let s2 = t2.finish(3 * S, &Gauges::default()).unwrap();
        assert!(s2.is_empty(), "idle tail is not an interval");
    }

    #[test]
    fn samples_count_closed_intervals() {
        let mut t = Telemetry::new(S);
        for i in 1..=10u64 {
            t.on_cold();
            t.advance(i * S, &Gauges::default());
        }
        assert_eq!(t.samples, 10);
    }

    #[test]
    fn rows_cover_every_column() {
        let mut t = Telemetry::new(S);
        t.on_cold();
        let s = t.finish(2 * S, &Gauges::default()).unwrap();
        for (label, points) in s.rows() {
            assert!(!label.is_empty());
            assert_eq!(points.len(), s.len());
        }
    }
}
