//! Deterministic observability (S25): lifecycle trace sinks, interval
//! time-series telemetry, and coarse simulator self-profiling.
//!
//! Everything in this module observes the simulation without perturbing
//! it: no sink or collector ever schedules an engine event, draws from an
//! RNG, or changes a counter the metrics read — so a run with tracing or
//! telemetry enabled produces byte-identical *measurements* to the same
//! run with the default [`NullSink`], and the trace/telemetry output
//! itself is byte-identical per seed (timestamps are virtual time).
//!
//! Three layers:
//!
//! * **Lifecycle spans** ([`trace`]): every placed request opens a span
//!   on its node's "thread" at dispatch and closes it at completion;
//!   faults (crash, restart, retry, reject, brown-out) land as instant /
//!   duration events.  The [`TraceSink`] trait keeps the hot path free of
//!   allocation when tracing is off ([`NullSink`] is a no-op); the
//!   [`ChromeTraceSink`] streams Chrome `trace_event` JSON that loads
//!   straight into `chrome://tracing` / Perfetto, with a bounded ring
//!   buffer and optional disruption-window filtering for planet-scale
//!   runs.
//! * **Interval telemetry** ([`telemetry`]): per-interval dispatch rates,
//!   cold fraction, pool occupancy, idle GB, in-flight and retry/reject
//!   counts, sampled lazily at event boundaries (no timer events are
//!   injected) into columnar series the report layer serializes and
//!   renders as sparklines.
//! * **Self-profiling** ([`profile`]): coarse phase accounting — how many
//!   dispatch decisions, pool effects, fault effects, and completions a
//!   run processed, its exact engine event count (compared strictly by
//!   the bench gate), and the wall-clock `events/s` throughput
//!   (informational only: it depends on the machine).

pub mod profile;
pub mod telemetry;
pub mod trace;

pub use profile::PhaseProfile;
pub use telemetry::{Gauges, Telemetry, TelemetrySeries};
pub use trace::{ChromeTraceSink, NullSink, TraceSink};

/// Per-run observability configuration.  The default is everything off:
/// the platform uses the [`NullSink`] and takes no telemetry samples, so
/// pre-existing runs stay byte-identical.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ObsConfig {
    /// Record lifecycle spans into a [`ChromeTraceSink`]; the trace JSON
    /// comes back on the platform result.
    pub trace: bool,
    /// Ring-buffer capacity for trace events (0 = unbounded).  Metadata
    /// records are never evicted; when the ring is full the *oldest*
    /// event is dropped and counted, so a capped trace keeps the most
    /// recent window of activity.
    pub trace_capacity: usize,
    /// Keep only trace events inside the fault plan's disruption windows
    /// (crash .. restart + spike window, plus fabric brown-outs) — the
    /// planet-scale capture mode.
    pub trace_window_only: bool,
    /// Telemetry sampling interval in virtual nanoseconds (0 = off).
    pub telemetry_interval_ns: u64,
}

impl ObsConfig {
    /// True when this config observes nothing (the byte-identity default).
    pub fn is_off(&self) -> bool {
        !self.trace && self.telemetry_interval_ns == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_config_observes_nothing() {
        let cfg = ObsConfig::default();
        assert!(cfg.is_off());
        assert!(!ObsConfig { trace: true, ..Default::default() }.is_off());
        assert!(!ObsConfig { telemetry_interval_ns: 1, ..Default::default() }.is_off());
    }
}
