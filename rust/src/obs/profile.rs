//! Simulator self-profiling: coarse phase accounting per platform run.
//!
//! The counters are pure functions of the seed (they count domain
//! callbacks and engine events, all deterministic); only `wall_ns` — and
//! therefore [`PhaseProfile::events_per_s`] — depends on the machine,
//! which is why the bench compare gate treats `events` as an exact field
//! and `events/s` as informational.

/// Where a platform run's work went, by callback phase.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct PhaseProfile {
    /// Routing/placement decisions (`decide` callbacks on user requests).
    pub dispatch_decisions: u64,
    /// Pool lifecycle effects: releases, retires, pre-warm fires.
    pub pool_effects: u64,
    /// Fault-control effects: crashes and restarts.
    pub fault_effects: u64,
    /// Request chains that reached `done`.
    pub completions: u64,
    /// Telemetry interval samples (lazy; not engine events).
    pub telemetry_samples: u64,
    /// Exact engine event count — strictly compared by the bench gate.
    pub engine_events: u64,
    /// Wall-clock nanoseconds spent inside `Engine::run`.  Machine
    /// dependent: never rendered, never strictly compared.
    pub wall_ns: u64,
}

impl PhaseProfile {
    /// Wall-clock simulation throughput; 0.0 when wall time was not
    /// measured (or the run finished faster than the clock resolution).
    pub fn events_per_s(&self) -> f64 {
        if self.wall_ns == 0 {
            0.0
        } else {
            self.engine_events as f64 / (self.wall_ns as f64 / 1e9)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn events_per_s_guards_zero_wall_time() {
        let mut p = PhaseProfile { engine_events: 1000, ..Default::default() };
        assert_eq!(p.events_per_s(), 0.0);
        p.wall_ns = 500_000_000; // 0.5 s
        assert_eq!(p.events_per_s(), 2000.0);
    }
}
