//! Lifecycle trace sinks: a no-op default and a Chrome `trace_event`
//! JSON writer.
//!
//! The platform emits spans through the [`TraceSink`] trait: a `B`/`E`
//! pair per placed request (begin at dispatch on the chosen node's
//! process row, end at completion), `X` duration events for pipeline
//! phases and scheduled outages, and `i` instants for faults (crash,
//! restart, retry, reject, pre-warm boot).  Timestamps are virtual
//! nanoseconds, serialized as microseconds with fixed 3-decimal
//! formatting — the trace is a pure function of the seed, so the same
//! run always writes the same bytes.
//!
//! The [`NullSink`] is the default: every method is an inherited no-op
//! and `enabled()` is false, so callers can skip even the string
//! formatting on the hot path.  The [`ChromeTraceSink`] buffers
//! pre-rendered JSON lines in a bounded ring (oldest events evicted
//! first, eviction counted) and can restrict capture to disruption
//! windows — the two knobs that keep planet-scale traces loadable.

use std::collections::VecDeque;

use crate::report::json_str;

/// Where lifecycle events go.  All methods default to no-ops so a sink
/// only implements what it records; `enabled()` lets emitters skip
/// argument construction entirely when tracing is off.
pub trait TraceSink {
    /// Does this sink record anything?  Emitters must not build event
    /// names/args when this is false (zero-cost-when-off contract).
    fn enabled(&self) -> bool {
        false
    }
    /// Name a process row (pid 0 = frontend, pid n+1 = node n).
    fn process_name(&mut self, _pid: u32, _name: &str) {}
    /// Open a span on (pid, tid) at `ts_ns`.  `args` values are raw JSON
    /// fragments (numbers, pre-quoted strings).
    fn begin(&mut self, _ts_ns: u64, _pid: u32, _tid: u32, _name: &str, _args: &[(&str, String)]) {}
    /// Close the innermost open span on (pid, tid).
    fn end(&mut self, _ts_ns: u64, _pid: u32, _tid: u32) {}
    /// A self-contained duration event over `[t0_ns, t1_ns)`.
    fn complete(&mut self, _t0_ns: u64, _t1_ns: u64, _pid: u32, _tid: u32, _name: &str) {}
    /// A process-scoped instant marker.
    fn instant(&mut self, _ts_ns: u64, _pid: u32, _name: &str) {}
    /// Events evicted by the ring buffer (0 for unbounded sinks).
    fn dropped(&self) -> u64 {
        0
    }
    /// Serialize and hand over the trace document, if this sink has one.
    fn take_trace_json(&mut self) -> Option<String> {
        None
    }
}

/// The default sink: records nothing, allocates nothing.
pub struct NullSink;

impl TraceSink for NullSink {}

/// Virtual-ns timestamp as Chrome's microsecond field, fixed 3 decimals
/// (deterministic formatting; sub-µs phases stay distinguishable).
fn us(ns: u64) -> String {
    format!("{:.3}", ns as f64 / 1000.0)
}

/// Streams Chrome `trace_event` JSON (the "JSON Array Format" with a
/// `traceEvents` wrapper) loadable in `chrome://tracing` and Perfetto.
///
/// Events are rendered to strings eagerly and kept in a ring buffer;
/// metadata (process names) lives outside the ring so labels survive
/// however much of a long run is evicted.  With a window filter, events
/// are kept only if they touch a disruption window — spans clipped at a
/// window edge may lose their `B` or `E` half, which both viewers
/// tolerate (the span renders as unterminated).
pub struct ChromeTraceSink {
    meta: Vec<String>,
    events: VecDeque<String>,
    capacity: usize,
    windows: Vec<(u64, u64)>,
    dropped: u64,
}

impl ChromeTraceSink {
    /// `capacity` bounds the event ring (0 = unbounded); `windows` is the
    /// half-open time filter (empty = capture everything).
    pub fn new(capacity: usize, windows: Vec<(u64, u64)>) -> ChromeTraceSink {
        ChromeTraceSink {
            meta: Vec::new(),
            events: VecDeque::new(),
            capacity,
            windows,
            dropped: 0,
        }
    }

    fn in_window(&self, ts_ns: u64) -> bool {
        self.windows.is_empty() || self.windows.iter().any(|&(a, b)| ts_ns >= a && ts_ns < b)
    }

    fn span_in_window(&self, t0_ns: u64, t1_ns: u64) -> bool {
        self.windows.is_empty() || self.windows.iter().any(|&(a, b)| t0_ns < b && t1_ns >= a)
    }

    fn push(&mut self, line: String) {
        if self.capacity > 0 && self.events.len() == self.capacity {
            self.events.pop_front();
            self.dropped += 1;
        }
        self.events.push_back(line);
    }

    /// The complete trace document.
    pub fn to_json(&self) -> String {
        let mut out = String::from("{\"displayTimeUnit\":\"ms\",\"traceEvents\":[");
        for (i, line) in self.meta.iter().chain(self.events.iter()).enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(line);
        }
        out.push_str("]}\n");
        out
    }

    /// Buffered event count (metadata excluded).
    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }
}

impl TraceSink for ChromeTraceSink {
    fn enabled(&self) -> bool {
        true
    }

    fn process_name(&mut self, pid: u32, name: &str) {
        self.meta.push(format!(
            "{{\"ph\":\"M\",\"pid\":{pid},\"name\":\"process_name\",\"args\":{{\"name\":{}}}}}",
            json_str(name)
        ));
    }

    fn begin(&mut self, ts_ns: u64, pid: u32, tid: u32, name: &str, args: &[(&str, String)]) {
        if !self.in_window(ts_ns) {
            return;
        }
        let mut line = format!(
            "{{\"ph\":\"B\",\"cat\":\"lifecycle\",\"ts\":{},\"pid\":{pid},\"tid\":{tid},\
             \"name\":{}",
            us(ts_ns),
            json_str(name)
        );
        if !args.is_empty() {
            line.push_str(",\"args\":{");
            for (i, (k, v)) in args.iter().enumerate() {
                if i > 0 {
                    line.push(',');
                }
                line.push_str(&format!("{}:{v}", json_str(k)));
            }
            line.push('}');
        }
        line.push('}');
        self.push(line);
    }

    fn end(&mut self, ts_ns: u64, pid: u32, tid: u32) {
        if !self.in_window(ts_ns) {
            return;
        }
        self.push(format!(
            "{{\"ph\":\"E\",\"cat\":\"lifecycle\",\"ts\":{},\"pid\":{pid},\"tid\":{tid}}}",
            us(ts_ns)
        ));
    }

    fn complete(&mut self, t0_ns: u64, t1_ns: u64, pid: u32, tid: u32, name: &str) {
        if !self.span_in_window(t0_ns, t1_ns) {
            return;
        }
        self.push(format!(
            "{{\"ph\":\"X\",\"cat\":\"lifecycle\",\"ts\":{},\"dur\":{},\"pid\":{pid},\
             \"tid\":{tid},\"name\":{}}}",
            us(t0_ns),
            us(t1_ns.saturating_sub(t0_ns)),
            json_str(name)
        ));
    }

    fn instant(&mut self, ts_ns: u64, pid: u32, name: &str) {
        if !self.in_window(ts_ns) {
            return;
        }
        self.push(format!(
            "{{\"ph\":\"i\",\"s\":\"p\",\"cat\":\"lifecycle\",\"ts\":{},\"pid\":{pid},\
             \"name\":{}}}",
            us(ts_ns),
            json_str(name)
        ));
    }

    fn dropped(&self) -> u64 {
        self.dropped
    }

    fn take_trace_json(&mut self) -> Option<String> {
        Some(self.to_json())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const MS: u64 = 1_000_000;

    #[test]
    fn null_sink_is_disabled_and_yields_nothing() {
        let mut s = NullSink;
        assert!(!s.enabled());
        s.begin(0, 1, 2, "x", &[]);
        s.end(1, 1, 2);
        assert_eq!(s.dropped(), 0);
        assert!(s.take_trace_json().is_none());
    }

    #[test]
    fn chrome_sink_renders_spans_and_instants() {
        let mut s = ChromeTraceSink::new(0, Vec::new());
        s.process_name(0, "frontend");
        s.begin(1500, 1, 7, "cold f3", &[("attempt", "0".to_string())]);
        s.end(2 * MS, 1, 7);
        s.instant(3 * MS, 2, "crash");
        s.complete(MS, 2 * MS, 1, 7, "image-pull");
        let j = s.to_json();
        assert!(j.starts_with("{\"displayTimeUnit\":\"ms\",\"traceEvents\":["));
        assert!(j.contains("\"ph\":\"M\"") && j.contains("\"frontend\""));
        assert!(j.contains("\"ph\":\"B\"") && j.contains("\"ts\":1.500"));
        assert!(j.contains("\"args\":{\"attempt\":0}"));
        assert!(j.contains("\"ph\":\"E\"") && j.contains("\"ts\":2000.000"));
        assert!(j.contains("\"ph\":\"i\"") && j.contains("\"crash\""));
        assert!(j.contains("\"ph\":\"X\"") && j.contains("\"dur\":1000.000"));
        assert!(j.ends_with("]}\n"));
    }

    #[test]
    fn trace_json_is_deterministic() {
        let render = || {
            let mut s = ChromeTraceSink::new(0, Vec::new());
            for i in 0..50u64 {
                s.begin(i * MS, 1, i as u32, "w", &[]);
                s.end(i * MS + 500, 1, i as u32);
            }
            s.to_json()
        };
        assert_eq!(render(), render());
    }

    #[test]
    fn ring_buffer_keeps_newest_and_counts_drops() {
        let mut s = ChromeTraceSink::new(10, Vec::new());
        s.process_name(3, "node 2");
        for i in 0..100u64 {
            s.instant(i * MS, 3, "tick");
        }
        assert_eq!(s.len(), 10);
        assert_eq!(s.dropped(), 90);
        let j = s.to_json();
        // Metadata survives eviction; the newest events are retained.
        assert!(j.contains("\"node 2\""));
        assert!(j.contains(&format!("\"ts\":{}", us(99 * MS))));
        assert!(!j.contains(&format!("\"ts\":{}", us(10 * MS))));
    }

    #[test]
    fn window_filter_keeps_only_overlapping_events() {
        let w = vec![(10 * MS, 20 * MS)];
        let mut s = ChromeTraceSink::new(0, w);
        s.instant(5 * MS, 0, "before");
        s.instant(15 * MS, 0, "inside");
        s.instant(25 * MS, 0, "after");
        s.complete(8 * MS, 12 * MS, 0, 0, "straddles");
        s.complete(0, 5 * MS, 0, 0, "misses");
        let j = s.to_json();
        assert!(!j.contains("before") && !j.contains("after") && !j.contains("misses"));
        assert!(j.contains("inside") && j.contains("straddles"));
        assert_eq!(s.len(), 2);
    }

    #[test]
    fn trace_json_parses_as_json() {
        let mut s = ChromeTraceSink::new(0, Vec::new());
        s.process_name(0, "frontend \"quoted\"");
        s.begin(0, 0, 1, "warm f\\0", &[("func", "0".to_string())]);
        s.end(100, 0, 1);
        let doc = crate::runtime::Json::parse(&s.to_json()).expect("valid JSON");
        let events = doc.get("traceEvents").and_then(crate::runtime::Json::as_arr).unwrap();
        assert_eq!(events.len(), 3);
    }
}
