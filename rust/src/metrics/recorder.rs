//! Exact-quantile latency recorder, keyed by a label, plus the boxplot
//! statistics the paper uses (whiskers at p1/p99, box at p25/p50/p75).

use std::cell::RefCell;
use std::collections::BTreeMap;

/// Boxplot summary in milliseconds, matching the paper's figures.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct BoxStats {
    pub n: usize,
    pub p1: f64,
    pub p25: f64,
    pub p50: f64,
    pub p75: f64,
    pub p99: f64,
    pub mean: f64,
    pub max: f64,
}

impl BoxStats {
    pub fn row(&self) -> String {
        format!(
            "n={:<6} p1={:>9.2} p25={:>9.2} p50={:>9.2} p75={:>9.2} p99={:>9.2} max={:>9.2}",
            self.n, self.p1, self.p25, self.p50, self.p75, self.p99, self.max
        )
    }
}

/// Collects raw samples per label; quantiles are exact (nearest-rank on
/// sorted samples).  BTreeMap keeps report ordering stable across runs.
///
/// Quantile/stat reads used to clone-and-sort the sample vector on every
/// call, which made report assembly quadratic-ish for callers probing
/// several quantiles per label.  Sorted copies are now memoized per label
/// behind a `RefCell` (readers keep `&self` — call sites interleave
/// closures over `&Recorder` with direct reads) and invalidated on write.
/// `Recorder` is never shared across threads, so the `!Sync` cell is fine.
#[derive(Default)]
pub struct Recorder {
    series: BTreeMap<String, Vec<f64>>,
    sorted: RefCell<BTreeMap<String, Vec<f64>>>,
}

impl Clone for Recorder {
    fn clone(&self) -> Self {
        // The memo is a pure cache; a clone starts cold.
        Recorder { series: self.series.clone(), sorted: RefCell::new(BTreeMap::new()) }
    }
}

impl Recorder {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record_ms(&mut self, label: &str, ms: f64) {
        self.sorted.get_mut().remove(label);
        self.series.entry(label.to_string()).or_default().push(ms);
    }

    /// Run `f` over the sorted samples for `label`, building (and
    /// memoizing) the sorted copy on first read after a write.
    fn with_sorted<T>(&self, label: &str, f: impl FnOnce(&[f64]) -> T) -> Option<T> {
        let v = self.series.get(label)?;
        if v.is_empty() {
            return None;
        }
        let mut cache = self.sorted.borrow_mut();
        let s = cache.entry(label.to_string()).or_insert_with(|| {
            let mut s = v.clone();
            s.sort_by(|a, b| a.partial_cmp(b).unwrap());
            s
        });
        Some(f(s))
    }

    pub fn record_ns(&mut self, label: &str, ns: u64) {
        self.record_ms(label, ns as f64 / 1e6);
    }

    pub fn labels(&self) -> impl Iterator<Item = &str> {
        self.series.keys().map(|s| s.as_str())
    }

    pub fn count(&self, label: &str) -> usize {
        self.series.get(label).map_or(0, |v| v.len())
    }

    pub fn samples(&self, label: &str) -> &[f64] {
        self.series.get(label).map_or(&[], |v| v.as_slice())
    }

    /// Exact quantile (nearest-rank on the sorted samples), q in [0, 1].
    pub fn quantile(&self, label: &str, q: f64) -> Option<f64> {
        self.with_sorted(label, |s| quantile_sorted(s, q))
    }

    pub fn stats(&self, label: &str) -> Option<BoxStats> {
        self.with_sorted(label, |s| BoxStats {
            n: s.len(),
            p1: quantile_sorted(s, 0.01),
            p25: quantile_sorted(s, 0.25),
            p50: quantile_sorted(s, 0.50),
            p75: quantile_sorted(s, 0.75),
            p99: quantile_sorted(s, 0.99),
            mean: s.iter().sum::<f64>() / s.len() as f64,
            max: *s.last().unwrap(),
        })
    }

    pub fn merge(&mut self, other: &Recorder) {
        for (k, v) in &other.series {
            self.sorted.get_mut().remove(k);
            self.series.entry(k.clone()).or_default().extend_from_slice(v);
        }
    }

    pub fn clear(&mut self) {
        self.series.clear();
        self.sorted.get_mut().clear();
    }
}

/// Nearest-rank quantile over an already-sorted slice.
pub fn quantile_sorted(sorted: &[f64], q: f64) -> f64 {
    debug_assert!(!sorted.is_empty());
    let q = q.clamp(0.0, 1.0);
    let idx = ((q * sorted.len() as f64).ceil() as usize).saturating_sub(1);
    sorted[idx.min(sorted.len() - 1)]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_label_gives_none() {
        let r = Recorder::new();
        assert!(r.stats("x").is_none());
        assert!(r.quantile("x", 0.5).is_none());
    }

    #[test]
    fn median_of_odd_count() {
        let mut r = Recorder::new();
        for x in [5.0, 1.0, 3.0] {
            r.record_ms("a", x);
        }
        assert_eq!(r.quantile("a", 0.5), Some(3.0));
    }

    #[test]
    fn quantiles_of_1_to_100() {
        let mut r = Recorder::new();
        for i in 1..=100 {
            r.record_ms("a", i as f64);
        }
        let s = r.stats("a").unwrap();
        assert_eq!(s.p1, 1.0);
        assert_eq!(s.p50, 50.0);
        assert_eq!(s.p99, 99.0);
        assert_eq!(s.max, 100.0);
        assert!((s.mean - 50.5).abs() < 1e-9);
    }

    #[test]
    fn nearest_rank_edge_cases() {
        let v = [10.0];
        assert_eq!(quantile_sorted(&v, 0.0), 10.0);
        assert_eq!(quantile_sorted(&v, 1.0), 10.0);
        let v2 = [1.0, 2.0];
        assert_eq!(quantile_sorted(&v2, 0.5), 1.0);
        assert_eq!(quantile_sorted(&v2, 0.75), 2.0);
    }

    #[test]
    fn record_ns_converts_to_ms() {
        let mut r = Recorder::new();
        r.record_ns("a", 2_500_000);
        assert_eq!(r.samples("a"), &[2.5]);
    }

    #[test]
    fn merge_combines_series() {
        let mut a = Recorder::new();
        a.record_ms("x", 1.0);
        let mut b = Recorder::new();
        b.record_ms("x", 2.0);
        b.record_ms("y", 3.0);
        a.merge(&b);
        assert_eq!(a.count("x"), 2);
        assert_eq!(a.count("y"), 1);
    }

    #[test]
    fn sorted_cache_invalidates_on_write_merge_and_clone() {
        let mut r = Recorder::new();
        r.record_ms("a", 5.0);
        assert_eq!(r.quantile("a", 1.0), Some(5.0)); // memoize
        r.record_ms("a", 9.0); // write must invalidate
        assert_eq!(r.quantile("a", 1.0), Some(9.0));
        let mut other = Recorder::new();
        other.record_ms("a", 11.0);
        r.merge(&other); // merge must invalidate too
        assert_eq!(r.quantile("a", 1.0), Some(11.0));
        let c = r.clone(); // clones read correctly from a cold cache
        assert_eq!(c.quantile("a", 1.0), Some(11.0));
        assert_eq!(c.stats("a").map(|s| s.n), Some(3));
        r.clear();
        assert!(r.quantile("a", 0.5).is_none());
    }

    #[test]
    fn labels_sorted_and_stable() {
        let mut r = Recorder::new();
        r.record_ms("z", 1.0);
        r.record_ms("a", 1.0);
        let l: Vec<&str> = r.labels().collect();
        assert_eq!(l, vec!["a", "z"]);
    }
}
