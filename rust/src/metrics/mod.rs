//! Metrics substrate (S13): latency recording, quantiles, boxplot stats,
//! and a streaming log-bucket histogram for the live coordinator hot path.

mod hist;
mod recorder;

pub use hist::Histogram;
pub use recorder::{BoxStats, Recorder};
