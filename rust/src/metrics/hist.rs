//! Streaming log-bucket histogram for hot-path latency recording in the
//! live coordinator, where keeping raw samples per request would allocate.
//!
//! Buckets grow geometrically (~4.6% width), bounding quantile error to
//! one bucket (<5%) with a fixed 512-slot footprint and O(1) record.

use crate::sim::snap::{Dec, Enc};

const BUCKETS: usize = 512;
/// Bucket boundaries: b(i) = MIN_NS * GROWTH^i, covering 100 ns .. >1000 s.
const MIN_NS: f64 = 100.0;
const GROWTH: f64 = 1.0461;

#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Histogram {
    counts: [u64; BUCKETS],
    n: u64,
    /// Exact integer sum: merges are associative and commutative bit-for-bit,
    /// which the sharded-platform merge (S26) relies on for K-invariance.
    sum_ns: u128,
    min_ns: u64,
    max_ns: u64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Self {
        Histogram {
            counts: [0; BUCKETS],
            n: 0,
            sum_ns: 0,
            min_ns: u64::MAX,
            max_ns: 0,
        }
    }

    #[inline]
    fn bucket(ns: u64) -> usize {
        if ns as f64 <= MIN_NS {
            return 0;
        }
        let b = ((ns as f64 / MIN_NS).ln() / GROWTH.ln()) as usize;
        b.min(BUCKETS - 1)
    }

    #[inline]
    pub fn record_ns(&mut self, ns: u64) {
        self.counts[Self::bucket(ns)] += 1;
        self.n += 1;
        self.sum_ns += ns as u128;
        self.min_ns = self.min_ns.min(ns);
        self.max_ns = self.max_ns.max(ns);
    }

    pub fn len(&self) -> u64 {
        self.n
    }

    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    pub fn mean_ms(&self) -> f64 {
        if self.n == 0 {
            return 0.0;
        }
        self.sum_ns as f64 / self.n as f64 / 1e6
    }

    pub fn max_ms(&self) -> f64 {
        if self.n == 0 { 0.0 } else { self.max_ns as f64 / 1e6 }
    }

    /// Approximate quantile (bucket upper edge), in ms; error < one bucket.
    pub fn quantile_ms(&self, q: f64) -> f64 {
        if self.n == 0 {
            return 0.0;
        }
        let target = ((q.clamp(0.0, 1.0) * self.n as f64).ceil() as u64).max(1);
        let mut acc = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            acc += c;
            if acc >= target {
                let upper = MIN_NS * GROWTH.powi(i as i32 + 1);
                return upper.min(self.max_ns as f64) / 1e6;
            }
        }
        self.max_ns as f64 / 1e6
    }

    /// Snapshot codec (S27): the summary fields plus the non-zero
    /// buckets in ascending index order — sparse, since most per-node
    /// histograms populate a handful of the 512 buckets.
    pub fn encode(&self, w: &mut Enc) {
        w.u64(self.n);
        w.u128(self.sum_ns);
        w.u64(self.min_ns);
        w.u64(self.max_ns);
        let nz = self.counts.iter().filter(|&&c| c != 0).count();
        w.len(nz);
        for (i, &c) in self.counts.iter().enumerate() {
            if c != 0 {
                w.u16(i as u16);
                w.u64(c);
            }
        }
    }

    /// Inverse of [`Self::encode`].
    pub fn decode(r: &mut Dec) -> Histogram {
        let mut h = Histogram::new();
        h.n = r.u64();
        h.sum_ns = r.u128();
        h.min_ns = r.u64();
        h.max_ns = r.u64();
        let nz = r.len();
        for _ in 0..nz {
            let i = r.u16() as usize;
            assert!(i < BUCKETS, "snapshot corrupt: histogram bucket {i}");
            h.counts[i] = r.u64();
        }
        h
    }

    pub fn merge(&mut self, other: &Histogram) {
        for i in 0..BUCKETS {
            self.counts[i] += other.counts[i];
        }
        self.n += other.n;
        self.sum_ns += other.sum_ns;
        self.min_ns = self.min_ns.min(other.min_ns);
        self.max_ns = self.max_ns.max(other.max_ns);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_histogram() {
        let h = Histogram::new();
        assert!(h.is_empty());
        assert_eq!(h.quantile_ms(0.5), 0.0);
        assert_eq!(h.mean_ms(), 0.0);
    }

    #[test]
    fn quantile_within_bucket_error() {
        let mut h = Histogram::new();
        // 1..=1000 ms uniform.
        for i in 1..=1000u64 {
            h.record_ns(i * 1_000_000);
        }
        let p50 = h.quantile_ms(0.5);
        assert!((p50 / 500.0 - 1.0).abs() < 0.06, "p50 {p50}");
        let p99 = h.quantile_ms(0.99);
        assert!((p99 / 990.0 - 1.0).abs() < 0.06, "p99 {p99}");
    }

    #[test]
    fn mean_is_exact() {
        let mut h = Histogram::new();
        h.record_ns(1_000_000);
        h.record_ns(3_000_000);
        assert!((h.mean_ms() - 2.0).abs() < 1e-9);
    }

    #[test]
    fn extreme_values_clamp_to_edge_buckets() {
        let mut h = Histogram::new();
        h.record_ns(1); // below MIN
        h.record_ns(u64::MAX / 2); // beyond top bucket
        assert_eq!(h.len(), 2);
        assert!(h.quantile_ms(1.0) > 0.0);
    }

    #[test]
    fn merge_accumulates() {
        let mut a = Histogram::new();
        let mut b = Histogram::new();
        a.record_ns(1_000_000);
        b.record_ns(9_000_000);
        a.merge(&b);
        assert_eq!(a.len(), 2);
        assert!((a.mean_ms() - 5.0).abs() < 1e-9);
    }

    #[test]
    fn merged_node_histograms_match_exact_quantiles() {
        // E13/E14 fleet quantiles come from per-node histograms merged at
        // the end of a run: the merge must not widen the one-bucket error
        // bound (<5%) against the exact nearest-rank quantile over the
        // same samples recorded round-robin across 8 "nodes".
        let mut nodes: Vec<Histogram> = (0..8).map(|_| Histogram::new()).collect();
        let mut samples: Vec<u64> = Vec::with_capacity(20_000);
        let mut x = 0x9E3779B97F4A7C15u64;
        for i in 0..20_000u64 {
            x = x.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            let ns = 200_000 + x % 400_000_000; // 0.2 .. 400 ms spread
            samples.push(ns);
            nodes[(i % 8) as usize].record_ns(ns);
        }
        let mut merged = Histogram::new();
        for h in &nodes {
            merged.merge(h);
        }
        assert_eq!(merged.len(), 20_000);
        for q in [0.5, 0.9, 0.99] {
            let exact = crate::platform::sim::exact_quantile_ms(&samples, q);
            let approx = merged.quantile_ms(q);
            assert!(
                (approx / exact - 1.0).abs() < 0.05,
                "q{q}: merged {approx} vs exact {exact}"
            );
        }
    }

    #[test]
    fn merge_is_order_independent_bitwise() {
        // The sharded platform merges per-shard partials in shard order,
        // which groups the same records differently than the single-engine
        // per-node fold; with integer sums the result must be bit-identical
        // regardless of grouping or order.
        let mut parts: Vec<Histogram> = (0..5).map(|_| Histogram::new()).collect();
        let mut x = 0xDEADBEEFu64;
        for i in 0..10_000u64 {
            x = x.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            parts[(i % 5) as usize].record_ns(100 + x % 2_000_000_000);
        }
        let mut fwd = Histogram::new();
        for p in &parts {
            fwd.merge(p);
        }
        let mut rev = Histogram::new();
        for p in parts.iter().rev() {
            rev.merge(p);
        }
        let mut grouped = Histogram::new();
        let mut left = Histogram::new();
        left.merge(&parts[0]);
        left.merge(&parts[1]);
        let mut right = Histogram::new();
        right.merge(&parts[2]);
        right.merge(&parts[3]);
        right.merge(&parts[4]);
        grouped.merge(&left);
        grouped.merge(&right);
        assert_eq!(fwd, rev);
        assert_eq!(fwd, grouped);
    }

    #[test]
    fn monotone_quantiles() {
        let mut h = Histogram::new();
        let mut x = 131u64;
        for _ in 0..5000 {
            x = x.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            h.record_ns(1000 + x % 50_000_000);
        }
        let qs: Vec<f64> = [0.01, 0.25, 0.5, 0.75, 0.99]
            .iter()
            .map(|&q| h.quantile_ms(q))
            .collect();
        for w in qs.windows(2) {
            assert!(w[0] <= w[1] + 1e-12, "quantiles must be monotone: {qs:?}");
        }
    }
}
