//! AWS Lambda + API Gateway model (S8): the commercial baseline of
//! Table I, with the behaviours Wang et al. [15] measured and the paper
//! cites — Firecracker-backed executors co-located per function, kept
//! alive ≈ 27 minutes, TLS-terminating API Gateway in front.

use crate::fnplat::pool::{Dispatch, WarmPool};
use crate::net::{rtt_step, Frontend, Site};
use crate::sim::{Dist, Domain, Engine, Host, ReqId, Rng, Spawn, Step};
use crate::virt::Tech;

const TAG_DISPATCH: u32 = 1;
const TAG_RELEASE: u32 = 2;

/// Wang et al.: AWS keeps idle function instances "for nearly half an
/// hour" — we use 27 minutes.
pub const KEEP_ALIVE_S: f64 = 27.0 * 60.0;
/// Default Lambda function memory (a 128 MB Go function).
pub const FUNC_MEM_BYTES: u64 = 128 << 20;

/// API Gateway request processing (auth, throttling, mapping templates) —
/// the managed-service overhead in front of every invocation.
fn api_gateway_steps() -> Vec<Step> {
    vec![
        Step::cpu("apigw-processing", Dist::ms(24.0, 0.20)),
        Step::delay("invoke-service", Dist::ms(32.0, 0.18)),
        Step::cpu("payload-marshal", Dist::ms(9.0, 0.20)),
    ]
}

/// Cold path: placement/scheduling by the invoke service, Firecracker
/// microVM boot, code fetch, and Go runtime bootstrap.
fn cold_start_steps() -> Vec<Step> {
    let mut v = vec![
        Step::delay("placement", Dist::ms(95.0, 0.30)),
        Step::delay("code-fetch-s3", Dist::ms(88.0, 0.30)),
    ];
    v.extend(Tech::Firecracker.pipeline());
    v.push(Step::cpu("go-runtime-init", Dist::ms(52.0, 0.20)));
    v
}

fn warm_invoke_steps() -> Vec<Step> {
    vec![Step::cpu("env-reuse", Dist::ms(1.2, 0.2))]
}

fn exec_steps() -> Vec<Step> {
    vec![Step::cpu("lambda-exec", Dist::ms(1.0, 0.15))]
}

/// Nominal medians, for calibration checks.
pub fn nominal_warm_ms() -> f64 {
    let all: f64 = api_gateway_steps()
        .iter()
        .chain(warm_invoke_steps().iter())
        .chain(exec_steps().iter())
        .map(|s| s.dur.median_ns() / 1e6)
        .sum();
    all
}

pub fn nominal_cold_ms() -> f64 {
    nominal_warm_ms() - 1.2
        + cold_start_steps().iter().map(|s| s.dur.median_ns() / 1e6).sum::<f64>()
}

/// Load pattern for the Lambda scenario.
#[derive(Clone, Debug)]
pub struct LambdaScenario {
    pub client: Site,
    /// Sequential requests (parallelism 1, as in the Table I methodology).
    pub total: u64,
    /// Gap between requests; > keep-alive forces cold starts.
    pub gap_ns: u64,
    pub prewarm: bool,
    pub include_conn_setup: bool,
    pub seed: u64,
}

impl LambdaScenario {
    pub fn table1(total: u64, prewarm: bool, gap_ns: u64) -> LambdaScenario {
        LambdaScenario {
            client: Site::LabStockholm,
            total,
            gap_ns,
            prewarm,
            include_conn_setup: false,
            seed: 0x1A3BDA,
        }
    }
}

struct LambdaDomain {
    pool: WarmPool,
    template: Vec<Step>,
    remaining: u64,
    gap_ns: u64,
    latencies_ns: Vec<u64>,
    cold_latencies_ns: Vec<u64>,
    warm_latencies_ns: Vec<u64>,
    cold_inflight: std::collections::HashSet<ReqId>,
}

const FUNC: &str = "lambda-fn";

impl Domain for LambdaDomain {
    fn decide(&mut self, req: ReqId, _c: u32, tag: u32, now: u64, _rng: &mut Rng) -> Vec<Step> {
        debug_assert_eq!(tag, TAG_DISPATCH);
        let mut tail = Vec::new();
        match self.pool.dispatch(FUNC, now) {
            Dispatch::Cold => {
                tail.extend(cold_start_steps());
                self.cold_inflight.insert(req);
            }
            // The single-function wrapper never specializes: any claim
            // is a plain warm hit.
            Dispatch::Warm | Dispatch::Specialized => tail.extend(warm_invoke_steps()),
        }
        tail.extend(exec_steps());
        tail.push(Step::effect("release", TAG_RELEASE));
        tail
    }

    fn effect(&mut self, _req: ReqId, _c: u32, tag: u32, now: u64) {
        debug_assert_eq!(tag, TAG_RELEASE);
        self.pool.release(FUNC, now);
    }

    fn done(&mut self, req: ReqId, class: u32, start: u64, now: u64) -> Vec<Spawn> {
        let lat = now - start;
        self.latencies_ns.push(lat);
        if self.cold_inflight.remove(&req) {
            self.cold_latencies_ns.push(lat);
        } else {
            self.warm_latencies_ns.push(lat);
        }
        if self.remaining > 0 {
            self.remaining -= 1;
            vec![Spawn { delay_ns: self.gap_ns, class, steps: self.template.clone() }]
        } else {
            Vec::new()
        }
    }
}

pub struct LambdaResult {
    pub cold_median_ms: f64,
    pub warm_median_ms: f64,
    pub conn_setup_ms: f64,
    pub idle_gb_seconds: f64,
    pub cold_starts: u64,
    pub warm_hits: u64,
}

pub fn run_lambda(sc: &LambdaScenario, host: Host) -> LambdaResult {
    let domain = LambdaDomain {
        pool: WarmPool::new((KEEP_ALIVE_S * 1e9) as u64, FUNC_MEM_BYTES),
        template: Vec::new(),
        remaining: sc.total.saturating_sub(1),
        gap_ns: sc.gap_ns,
        latencies_ns: Vec::new(),
        cold_latencies_ns: Vec::new(),
        warm_latencies_ns: Vec::new(),
        cold_inflight: std::collections::HashSet::new(),
    };
    let mut e = Engine::new(domain, host, sc.seed);
    let mut head = Vec::new();
    if sc.include_conn_setup {
        head.extend(Frontend::LAMBDA_API_GW.connect_steps(sc.client, Site::AwsStockholm));
    }
    head.push(rtt_step("req-resp-rtt", sc.client, Site::AwsStockholm));
    head.extend(api_gateway_steps());
    head.push(Step::decision("dispatch", TAG_DISPATCH));
    e.domain.template = head.clone();
    if sc.prewarm {
        e.domain.pool.prewarm(FUNC, 1, 0);
    }
    e.spawn_at(0, 0, head);
    e.run(sc.total.saturating_mul(96).max(1 << 20));
    // Remaining warm instances keep burning memory until the ~27 min
    // keep-alive expires them, long after the measurement ends.
    e.domain.pool.finalize_expiring();

    let med = |v: &Vec<u64>| -> f64 {
        if v.is_empty() {
            return f64::NAN;
        }
        let mut s = v.clone();
        s.sort_unstable();
        s[s.len() / 2] as f64 / 1e6
    };
    LambdaResult {
        cold_median_ms: med(&e.domain.cold_latencies_ns),
        warm_median_ms: med(&e.domain.warm_latencies_ns),
        conn_setup_ms: Frontend::LAMBDA_API_GW.nominal_setup_ms(sc.client, Site::AwsStockholm),
        idle_gb_seconds: e.domain.pool.idle_gb_seconds(),
        cold_starts: e.domain.pool.cold_starts,
        warm_hits: e.domain.pool.warm_hits,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nominal_medians_near_table1() {
        // Table I: Lambda cold 449.7 ms, warm 78.0 ms.
        let w = nominal_warm_ms();
        assert!((w / 78.0 - 1.0).abs() < 0.20, "warm nominal {w}");
        let c = nominal_cold_ms();
        assert!((c / 449.7 - 1.0).abs() < 0.20, "cold nominal {c}");
    }

    #[test]
    fn measured_warm_median() {
        let r = run_lambda(&LambdaScenario::table1(1000, true, 0), Host::default());
        assert!((r.warm_median_ms / 78.0 - 1.0).abs() < 0.25, "warm {}", r.warm_median_ms);
        assert_eq!(r.cold_starts, 0);
    }

    #[test]
    fn measured_cold_median() {
        // Gap > keep-alive: every request cold.
        let gap = (KEEP_ALIVE_S * 1e9) as u64 + 1_000_000_000;
        let r = run_lambda(&LambdaScenario::table1(200, false, gap), Host::default());
        assert!((r.cold_median_ms / 449.7 - 1.0).abs() < 0.25, "cold {}", r.cold_median_ms);
        assert_eq!(r.warm_hits, 0);
    }

    #[test]
    fn keep_alive_wastes_heavily() {
        // One request, then 27 min of 128 MB sitting idle ≈ 202 GB·s.
        let r = run_lambda(&LambdaScenario::table1(1, false, 0), Host::default());
        assert!(r.idle_gb_seconds > 150.0, "idle waste {}", r.idle_gb_seconds);
    }

    #[test]
    fn back_to_back_requests_stay_warm() {
        let r = run_lambda(&LambdaScenario::table1(500, false, 1_000_000_000), Host::default());
        assert_eq!(r.cold_starts, 1);
        assert_eq!(r.warm_hits, 499);
    }
}
