//! Property-testing kit (S16) — the offline registry has no proptest, so
//! this provides the 90% that matters: seeded generators over the sim's
//! own deterministic [`Rng`], a `forall` runner that reports the failing
//! seed + case, and greedy input shrinking for `Vec` cases.  Also hosts
//! the micro-bench timer used by `benches/` (no criterion offline).

use crate::sim::Rng;

/// Run `prop` on `n` generated cases; on failure, re-derives the failing
/// case's seed so the panic message is directly reproducible.
pub fn forall<T: std::fmt::Debug, G, P>(seed: u64, n: usize, gen: G, prop: P)
where
    G: Fn(&mut Rng) -> T,
    P: Fn(&T) -> bool,
{
    for i in 0..n {
        let case_seed = seed.wrapping_add(i as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Rng::new(case_seed);
        let case = gen(&mut rng);
        if !prop(&case) {
            panic!("property failed at case {i} (seed {case_seed:#x}): {case:?}");
        }
    }
}

/// `forall` over `Vec<u64>` with greedy shrinking: on failure, tries to
/// remove elements/halve values while the property still fails, then
/// reports the minimized counterexample.
pub fn forall_vec<P>(seed: u64, n: usize, max_len: usize, max_val: u64, prop: P)
where
    P: Fn(&[u64]) -> bool,
{
    for i in 0..n {
        let case_seed = seed.wrapping_add(i as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Rng::new(case_seed);
        let len = rng.below(max_len as u64 + 1) as usize;
        let case: Vec<u64> = (0..len).map(|_| rng.below(max_val.max(1))).collect();
        if !prop(&case) {
            let minimal = shrink_vec(case, &prop);
            panic!(
                "property failed at case {i} (seed {case_seed:#x}); minimized: {minimal:?}"
            );
        }
    }
}

/// Greedy shrink: drop elements, then halve values, while still failing.
pub fn shrink_vec<P: Fn(&[u64]) -> bool>(mut case: Vec<u64>, prop: &P) -> Vec<u64> {
    // Element removal.
    let mut i = 0;
    while i < case.len() {
        let mut smaller = case.clone();
        smaller.remove(i);
        if !prop(&smaller) {
            case = smaller;
        } else {
            i += 1;
        }
    }
    // Value halving.
    let mut changed = true;
    while changed {
        changed = false;
        for i in 0..case.len() {
            if case[i] == 0 {
                continue;
            }
            let mut smaller = case.clone();
            smaller[i] /= 2;
            if !prop(&smaller) {
                case = smaller;
                changed = true;
            }
        }
    }
    case
}

/// Generator helpers.
pub mod gen {
    use crate::sim::Rng;

    pub fn f64_in(rng: &mut Rng, lo: f64, hi: f64) -> f64 {
        lo + rng.next_f64() * (hi - lo)
    }

    pub fn u64_in(rng: &mut Rng, lo: u64, hi: u64) -> u64 {
        lo + rng.below(hi - lo + 1)
    }

    pub fn vec_f64(rng: &mut Rng, max_len: usize, lo: f64, hi: f64) -> Vec<f64> {
        let len = rng.below(max_len as u64 + 1) as usize;
        (0..len).map(|_| f64_in(rng, lo, hi)).collect()
    }
}

/// Minimal bench timer for `benches/` (criterion is not in the offline
/// registry): warms up, runs timed iterations, reports ns/iter stats.
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub ns_per_iter_p50: f64,
    pub ns_per_iter_mean: f64,
}

impl BenchResult {
    pub fn row(&self) -> String {
        let (unit, div) = if self.ns_per_iter_p50 > 1e6 {
            ("ms", 1e6)
        } else if self.ns_per_iter_p50 > 1e3 {
            ("us", 1e3)
        } else {
            ("ns", 1.0)
        };
        format!(
            "{:<44} {:>12.2} {unit}/iter (mean {:>12.2} {unit}, {} iters)",
            self.name,
            self.ns_per_iter_p50 / div,
            self.ns_per_iter_mean / div,
            self.iters
        )
    }
}

/// Time `f` for roughly `target_ms` of wall time (after one warmup call).
pub fn bench(name: &str, target_ms: u64, mut f: impl FnMut()) -> BenchResult {
    f(); // warmup
    let mut samples: Vec<f64> = Vec::new();
    let start = std::time::Instant::now();
    let mut iters = 0u64;
    while start.elapsed().as_millis() < target_ms as u128 || iters < 5 {
        let t0 = std::time::Instant::now();
        f();
        samples.push(t0.elapsed().as_nanos() as f64);
        iters += 1;
        if iters > 1_000_000 {
            break;
        }
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let p50 = samples[samples.len() / 2];
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    BenchResult { name: name.to_string(), iters, ns_per_iter_p50: p50, ns_per_iter_mean: mean }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_passes_trivially_true() {
        forall(1, 100, |rng| rng.below(100), |&x| x < 100);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn forall_reports_failure() {
        forall(2, 100, |rng| rng.below(100), |&x| x < 50);
    }

    #[test]
    fn shrink_finds_minimal_counterexample() {
        // Property: "no element >= 10".  Minimal failing vec: [10].
        let prop = |v: &[u64]| v.iter().all(|&x| x < 10);
        let minimal = shrink_vec(vec![3, 40, 7, 22], &prop);
        assert_eq!(minimal.len(), 1);
        assert!(minimal[0] >= 10 && minimal[0] <= 20, "{minimal:?}");
    }

    #[test]
    fn shrink_keeps_failing_property() {
        let prop = |v: &[u64]| v.iter().sum::<u64>() < 100;
        let minimal = shrink_vec(vec![60, 70, 80], &prop);
        assert!(!prop(&minimal));
        assert!(minimal.iter().sum::<u64>() >= 100);
    }

    #[test]
    fn bench_returns_sane_numbers() {
        let r = bench("noop-closure", 5, || { std::hint::black_box(1 + 1); });
        assert!(r.iters >= 5);
        assert!(r.ns_per_iter_p50 < 1e7);
        assert!(!r.row().is_empty());
    }

    #[test]
    fn gen_ranges() {
        let mut rng = crate::sim::Rng::new(3);
        for _ in 0..1000 {
            let x = gen::u64_in(&mut rng, 5, 10);
            assert!((5..=10).contains(&x));
            let f = gen::f64_in(&mut rng, -1.0, 1.0);
            assert!((-1.0..1.0).contains(&f));
        }
    }
}
