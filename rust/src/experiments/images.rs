//! E7: image sizes (§II-C), deploy-time builds (§IV-B), and the cluster
//! distribution footprint the paper's §IV-C limitations discuss.

use super::ExpConfig;
use crate::image::{cluster_footprint_bytes, BuildKind, Image, NodeCache};
use crate::net::transfer_step;
use crate::report::Report;
use crate::virt::Tech;

pub fn images(_cfg: &ExpConfig) -> Report {
    let mut report = Report::new("E7: image sizes, deploy times, distribution footprint");

    // §II-C sizes.
    let sizes = [
        (Tech::Solo5Spt, 0.2),
        (Tech::IncludeOsHvt, 2.5),
        (Tech::DockerRunc, 6.0),
        (Tech::Firecracker, 70.0),
    ];
    for (t, want_mb) in sizes {
        report.check(
            &format!("{} image", t.name()),
            "MB",
            t.image_bytes() as f64 / 1e6,
            want_mb,
            0.05,
        );
    }

    // §IV-B deploy/build times.
    report.check("includeos boot build", "s", BuildKind::IncludeOsBoot.build_seconds(), 3.5, 0.01);
    report.band("docker image build", "s", BuildKind::DockerFdk.build_seconds(), 9.0, 10.0);

    // §IV-C: pre-seeding 1000 functions on 100 nodes.
    let nodes = 100u64;
    let funcs = 1000u64;
    let uni = cluster_footprint_bytes(&[Tech::IncludeOsHvt], nodes * funcs);
    let doc = cluster_footprint_bytes(&[Tech::DockerRunc], nodes * funcs);
    report.note(format!(
        "seeding {funcs} fns x {nodes} nodes: includeos {:.1} GB vs docker {:.1} GB",
        uni as f64 / 1e9,
        doc as f64 / 1e9
    ));
    report.band("uni/docker footprint", "ratio", uni as f64 / doc as f64, 0.3, 0.5);

    // Cache-miss transfer over the 40 Gbps lab fabric.
    let t_uni = transfer_step("x", Tech::IncludeOsHvt.image_bytes(), 40.0).dur.median_ns() / 1e6;
    let t_fc = transfer_step("x", Tech::Firecracker.image_bytes(), 40.0).dur.median_ns() / 1e6;
    report.note(format!("cache-miss pull: includeos {t_uni:.2} ms vs firecracker {t_fc:.2} ms"));
    report.band("includeos pull", "ms", t_uni, 0.3, 1.0);

    // Cache behaviour: a 1 GB node cache fits 400 IncludeOS functions but
    // only ~14 Firecracker images.
    let mut cache = NodeCache::new(Some(1 << 30));
    let mut fit = 0;
    loop {
        let img = Image::for_function(&format!("f{fit}"), Tech::IncludeOsHvt);
        if cache.fetch(&img).is_err() {
            break;
        }
        fit += 1;
    }
    report.band("includeos fns per GB cache", "count", fit as f64, 400.0, 430.0);
    report
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn images_checks_pass() {
        let r = images(&ExpConfig::quick());
        assert!(r.all_pass(), "failures: {:#?}", r.failures());
    }
}
