//! Experiment harnesses (S14): one function per paper figure/table, each
//! returning a [`Report`] with measured series and paper-vs-measured
//! checks.  See DESIGN.md §5 for the experiment index (E1–E14).

pub mod chaos;
pub mod cloud;
pub mod complexity;
pub mod decompose;
pub mod fleet;
pub mod fnlocal;
pub mod images;
pub mod policies;
pub mod scaleout;
pub mod startup;
pub mod waste;

pub use chaos::chaos;
pub use cloud::{distance_sweep, table1};
pub use complexity::complexity;
pub use decompose::decompose;
pub use fleet::fleet;
pub use fnlocal::fig4;
pub use images::images;
pub use policies::policies;
pub use scaleout::scaleout;
pub use startup::{fig1, fig2, fig3};
pub use waste::waste;

/// All experiment names accepted by the CLI, with the report generator.
pub fn by_name(name: &str, cfg: &ExpConfig) -> Option<crate::report::Report> {
    Some(match name {
        "fig1" => fig1(cfg),
        "fig2" => fig2(cfg),
        "fig3" => fig3(cfg),
        "fig4" => fig4(cfg),
        "table1" => table1(cfg),
        "decompose" => decompose(cfg),
        "images" => images(cfg),
        "complexity" => complexity(cfg),
        "waste" => waste(cfg),
        "distance" => distance_sweep(cfg),
        "scaleout" => scaleout(cfg),
        "policies" => policies(cfg),
        "fleet" => fleet(cfg),
        "chaos" => chaos(cfg),
        _ => return None,
    })
}

pub const ALL_EXPERIMENTS: [&str; 14] = [
    "fig1", "fig2", "fig3", "fig4", "table1", "decompose", "images", "complexity", "waste",
    "distance", "scaleout", "policies", "fleet", "chaos",
];

use crate::sim::Host;

/// Shared experiment configuration.
#[derive(Clone, Debug)]
pub struct ExpConfig {
    /// Requests per (technology, parallelism) cell. Paper: 10 000.
    pub requests: u64,
    /// In-flight request counts. Paper: up to 40 on a 24-core host.
    pub parallelisms: Vec<u32>,
    pub host: Host,
    pub seed: u64,
}

impl Default for ExpConfig {
    fn default() -> Self {
        ExpConfig {
            requests: 10_000,
            parallelisms: vec![1, 5, 10, 20, 40],
            host: Host::default(),
            seed: 0xC01D_FAA5,
        }
    }
}

impl ExpConfig {
    /// A reduced-load configuration for unit tests and quick CI runs.
    pub fn quick() -> Self {
        ExpConfig { requests: 1_500, parallelisms: vec![1, 10, 40], ..Default::default() }
    }
}
