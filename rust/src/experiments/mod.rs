//! Experiment harnesses (S14): one function per paper figure/table, each
//! returning a [`Report`] with measured series and paper-vs-measured
//! checks.  See DESIGN.md §5 for the experiment index (E1–E18).
//!
//! E18 (`livecheck`) is the one experiment that is *not* fully
//! deterministic: its sim leg is byte-identical per seed, but its live
//! leg measures the real serving stack, so it has its own subcommand
//! (`coldfaas livecheck`) and is never part of `experiment all`.
//!
//! The grid experiments (E12–E17) run their cells through the shared
//! [`sweep`] runner: cells are self-contained, so they execute on worker
//! threads and collect in cell order — reports stay byte-identical to
//! serial execution.

pub mod chaos;
pub mod cloud;
pub mod complexity;
pub mod decompose;
pub mod fleet;
pub mod fnlocal;
pub mod hyperplanet;
pub mod images;
pub mod livecheck;
pub mod planet;
pub mod policies;
pub mod replay;
pub mod scaleout;
pub mod sharing;
pub mod startup;
pub mod sweep;
pub mod waste;

pub use chaos::chaos;
pub use cloud::{distance_sweep, table1};
pub use complexity::complexity;
pub use decompose::decompose;
pub use fleet::fleet;
pub use fnlocal::fig4;
pub use hyperplanet::hyperplanet;
pub use images::images;
pub use livecheck::livecheck;
pub use planet::planet;
pub use policies::policies;
pub use scaleout::scaleout;
pub use sharing::sharing;
pub use startup::{fig1, fig2, fig3};
pub use waste::waste;

use crate::policy::{
    ColdOnlyPolicy, EwmaPredictive, FixedKeepAlive, HistogramPrewarm, LifecyclePolicy,
};

/// Lifecycle policies every grid experiment sweeps, in report order.
pub(crate) const POLICY_COUNT: usize = 4;

/// Fresh policy instance by grid index (cells build their own so sweeps
/// can run cells concurrently): 0 cold-only, 1 fixed keep-alive,
/// 2 hybrid histogram, 3 EWMA forecast.
pub(crate) fn make_policy(idx: usize, n_funcs: u32) -> Box<dyn LifecyclePolicy> {
    match idx {
        0 => Box::new(ColdOnlyPolicy),
        1 => Box::new(FixedKeepAlive::default()),
        2 => Box::new(HistogramPrewarm::new(n_funcs)),
        _ => Box::new(EwmaPredictive::new(n_funcs)),
    }
}

/// Mark Pareto-optimal cells in a 2-D minimize/minimize plane: a cell is
/// dominated if some other cell is no worse on both axes and strictly
/// better on at least one.  Shared by the (p99, waste) frontiers of E12
/// and E15; E13 keeps its own 3-D variant.
pub(crate) fn mark_pareto2<T>(
    cells: &mut [T],
    key: impl Fn(&T) -> (f64, f64),
    set: impl Fn(&mut T, bool),
) {
    let snapshot: Vec<(f64, f64)> = cells.iter().map(&key).collect();
    for (i, c) in cells.iter_mut().enumerate() {
        let (a, b) = snapshot[i];
        let dominated = snapshot
            .iter()
            .enumerate()
            .any(|(j, &(oa, ob))| j != i && oa <= a && ob <= b && (oa < a || ob < b));
        set(c, !dominated);
    }
}

/// All experiment names accepted by the CLI, with the report generator.
pub fn by_name(name: &str, cfg: &ExpConfig) -> Option<crate::report::Report> {
    Some(match name {
        "fig1" => fig1(cfg),
        "fig2" => fig2(cfg),
        "fig3" => fig3(cfg),
        "fig4" => fig4(cfg),
        "table1" => table1(cfg),
        "decompose" => decompose(cfg),
        "images" => images(cfg),
        "complexity" => complexity(cfg),
        "waste" => waste(cfg),
        "distance" => distance_sweep(cfg),
        "scaleout" => scaleout(cfg),
        "policies" => policies(cfg),
        "fleet" => fleet(cfg),
        "chaos" => chaos(cfg),
        "planet" => planet(cfg),
        "hyperplanet" => hyperplanet(cfg),
        "sharing" => sharing(cfg),
        _ => return None,
    })
}

/// Experiments `experiment all` sweeps — E16 `sharing` included (its
/// quick grid is fleet-sized).  E15 `planet` and E17 `hyperplanet` are
/// deliberately absent: they are by far the heaviest grids and each has
/// its own subcommand and CI smoke step (`coldfaas planet`,
/// `coldfaas hyperplanet`), so including them here would run them twice
/// per CI pass for no added coverage — `by_name` still accepts both for
/// explicit `experiment planet` / `experiment hyperplanet` runs.
pub const ALL_EXPERIMENTS: [&str; 15] = [
    "fig1", "fig2", "fig3", "fig4", "table1", "decompose", "images", "complexity", "waste",
    "distance", "scaleout", "policies", "fleet", "chaos", "sharing",
];

use crate::sim::Host;

/// S27 checkpoint plumbing shared by the heavy grids (E15/E17): one
/// directory holds a snapshot file per cell, named after the cell's
/// deterministic label, so a killed and relaunched grid finds each
/// cell's last barrier.  Cells without a file (or with `resume` off)
/// start fresh; completed cells replay their tail from the last mid-run
/// barrier — wasted work, never wrong answers (the resume contract is
/// byte-identity with the uninterrupted run).
#[derive(Clone, Debug, Default)]
pub struct CheckpointPlan {
    /// Snapshot directory; `None` leaves checkpoint writing off.
    pub dir: Option<String>,
    /// Resume cells whose snapshot file already exists.
    pub resume: bool,
    /// Fold the rolling state hash even without a snapshot directory.
    pub state_hash: bool,
}

impl CheckpointPlan {
    /// The file one cell's snapshots live in (labels are sanitized so
    /// every deterministic grid label maps to a portable filename).
    pub fn cell_path(&self, exp: &str, label: &str) -> Option<String> {
        let dir = self.dir.as_ref()?;
        let safe: String = label
            .chars()
            .map(|c| if c.is_ascii_alphanumeric() || c == '-' || c == '.' { c } else { '_' })
            .collect();
        Some(format!("{dir}/{exp}_{safe}.ckpt"))
    }

    /// Arm one cell's platform config with this plan.
    pub fn apply(&self, cfg: &mut crate::platform::PlatformConfig, exp: &str, label: &str) {
        cfg.state_hash |= self.state_hash;
        if let Some(path) = self.cell_path(exp, label) {
            if self.resume && std::path::Path::new(&path).exists() {
                cfg.resume_from = Some(path.clone());
            }
            cfg.checkpoint_path = Some(path);
        }
    }
}

/// Shared experiment configuration.
#[derive(Clone, Debug)]
pub struct ExpConfig {
    /// Requests per (technology, parallelism) cell. Paper: 10 000.
    pub requests: u64,
    /// In-flight request counts. Paper: up to 40 on a 24-core host.
    pub parallelisms: Vec<u32>,
    pub host: Host,
    pub seed: u64,
    /// S27: snapshot/resume plan the heavy grids (E15/E17) thread down to
    /// their cells; inert (`Default`) everywhere else.
    pub checkpoint: CheckpointPlan,
}

impl Default for ExpConfig {
    fn default() -> Self {
        ExpConfig {
            requests: 10_000,
            parallelisms: vec![1, 5, 10, 20, 40],
            host: Host::default(),
            seed: 0xC01D_FAA5,
            checkpoint: CheckpointPlan::default(),
        }
    }
}

impl ExpConfig {
    /// A reduced-load configuration for unit tests and quick CI runs.
    pub fn quick() -> Self {
        ExpConfig { requests: 1_500, parallelisms: vec![1, 10, 40], ..Default::default() }
    }
}
