//! E5 / Table I + E10: the AWS-cloud deployment — Fn (both drivers) on
//! m5.metal vs AWS Lambda behind its TLS API Gateway, measured from the
//! Stockholm lab; plus the distance sweep (Budapest).

use super::ExpConfig;
use crate::fnplat::{run_scenario, DriverKind, Scenario};
use crate::lambda::{run_lambda, LambdaScenario, KEEP_ALIVE_S};
use crate::net::{Frontend, Site};
use crate::report::Report;

/// Table I: median cold / warm / connection-setup per environment (ms).
pub struct Table1Row {
    pub environment: &'static str,
    pub cold_ms: f64,
    pub warm_ms: Option<f64>,
    pub conn_ms: f64,
}

pub fn table1_rows(cfg: &ExpConfig) -> Vec<Table1Row> {
    let n = cfg.requests.min(2000).max(100);
    // Fn IncludeOS: cold-only by design.
    let inc = run_scenario(
        &Scenario { seed: cfg.seed, ..Scenario::cloud(DriverKind::IncludeOsCold, n, false, 0) },
        cfg.host,
    );
    // Fn Docker cold: space requests past the 30 s idle timeout.
    let dock_cold = run_scenario(
        &Scenario {
            seed: cfg.seed ^ 1,
            ..Scenario::cloud(DriverKind::DockerWarm, n.min(400), false, 31_000_000_000)
        },
        cfg.host,
    );
    // Fn Docker warm: prewarmed, back-to-back.
    let dock_warm = run_scenario(
        &Scenario { seed: cfg.seed ^ 2, ..Scenario::cloud(DriverKind::DockerWarm, n, true, 0) },
        cfg.host,
    );
    // Lambda warm + cold.
    let lam_warm = run_lambda(&LambdaScenario::table1(n, true, 0), cfg.host);
    let gap = (KEEP_ALIVE_S * 1e9) as u64 + 1_000_000_000;
    let lam_cold = run_lambda(&LambdaScenario::table1(n.min(400), false, gap), cfg.host);

    vec![
        Table1Row {
            environment: "Fn IncludeOS",
            cold_ms: inc.cold_median_ms(),
            warm_ms: None,
            conn_ms: inc.conn_setup_ms,
        },
        Table1Row {
            environment: "Fn Docker",
            cold_ms: dock_cold.cold_median_ms(),
            warm_ms: Some(dock_warm.warm_median_ms()),
            conn_ms: dock_warm.conn_setup_ms,
        },
        Table1Row {
            environment: "AWS Lambda",
            cold_ms: lam_cold.cold_median_ms,
            warm_ms: Some(lam_warm.warm_median_ms),
            conn_ms: lam_warm.conn_setup_ms,
        },
    ]
}

pub fn table1(cfg: &ExpConfig) -> Report {
    let rows = table1_rows(cfg);
    let mut report = Report::new(
        "Table I: median function execution latency, lab Stockholm -> AWS Stockholm (ms)",
    );
    for r in &rows {
        report.note(format!(
            "{:<14} cold={:>7.1}  warm={}  conn-setup={:>5.1}",
            r.environment,
            r.cold_ms,
            r.warm_ms.map_or("    -  ".into(), |w| format!("{w:>7.1}")),
            r.conn_ms
        ));
    }
    // Paper values: (cold, warm, conn) per environment.
    let want = [
        ("Fn IncludeOS", 33.4, None, 6.9),
        ("Fn Docker", 288.3, Some(13.6), 0.9),
        ("AWS Lambda", 449.7, Some(78.0), 50.1),
    ];
    for (row, (env, cold, warm, conn)) in rows.iter().zip(want) {
        assert_eq!(row.environment, env);
        report.check(env, "cold p50", row.cold_ms, cold, 0.25);
        if let (Some(got), Some(want)) = (row.warm_ms, warm) {
            report.check(env, "warm p50", got, want, 0.25);
        }
        report.check(env, "conn setup", row.conn_ms, conn, 0.25);
    }
    // Headline claim: cold IncludeOS ≈ warm Lambda once connection overhead
    // is considered (§IV-B).
    let inc_total = rows[0].cold_ms + rows[0].conn_ms;
    let lam_total = rows[2].warm_ms.unwrap() + rows[2].conn_ms;
    report.band("cold-IncludeOS / warm-Lambda (incl conn)", "ratio", inc_total / lam_total, 0.1, 1.1);
    report.note("headline: a cold unikernel start beats a warm Lambda end to end");
    report
}

/// E10: connection setup vs distance (same-region EC2, lab, Budapest).
pub fn distance_sweep(_cfg: &ExpConfig) -> Report {
    let mut report = Report::new("E10: connection setup vs client distance (TLS API Gateway)");
    let sites = [
        ("ec2 same region", Site::Ec2SameRegion),
        ("lab Stockholm", Site::LabStockholm),
        ("lab Budapest", Site::LabBudapest),
    ];
    let mut prev = 0.0;
    for (name, s) in sites {
        let setup = Frontend::LAMBDA_API_GW.nominal_setup_ms(s, Site::AwsStockholm);
        report.note(format!("{name:<18} tls-setup ≈ {setup:>6.1} ms"));
        assert!(setup >= prev, "setup must grow with distance");
        prev = setup;
        if name == "lab Budapest" {
            // §IV-B: full Budapest call ≈ 200 ms; TLS setup is the bulk.
            report.band("budapest tls setup", "ms", setup, 90.0, 140.0);
        }
    }
    report.note("re-using TCP/TLS connections is the paper's 'powerful optimization option'");
    report
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_checks_pass_quick() {
        let r = table1(&ExpConfig::quick());
        assert!(r.all_pass(), "failures: {:#?}", r.failures());
    }

    #[test]
    fn distance_sweep_passes() {
        let r = distance_sweep(&ExpConfig::quick());
        assert!(r.all_pass(), "failures: {:#?}", r.failures());
    }

    #[test]
    fn table1_ordering_matches_paper() {
        let rows = table1_rows(&ExpConfig::quick());
        // Cold: IncludeOS << Fn Docker < Lambda.
        assert!(rows[0].cold_ms * 5.0 < rows[1].cold_ms);
        assert!(rows[1].cold_ms < rows[2].cold_ms);
        // Conn: Fn Docker < IncludeOS < Lambda(TLS).
        assert!(rows[1].conn_ms < rows[0].conn_ms);
        assert!(rows[0].conn_ms < rows[2].conn_ms);
    }
}
