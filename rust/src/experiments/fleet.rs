//! E13: the fleet sweep — the policy lab taken cluster-scale on the
//! unified platform layer.  The 1000-function Zipf tenant trace (S18) is
//! replayed against an 8–32 node cluster for every lifecycle policy ×
//! placement scheduler × driver combination, reporting the
//! p50/p99-latency vs GB·s-idle-waste vs cross-node-image-transfer
//! frontier — and asserting the paper's cold-only unikernel row stays
//! Pareto-optimal when image distribution and placement enter the
//! picture.

use super::{make_policy, sweep, ExpConfig, POLICY_COUNT};
use crate::fnplat::{DriverKind, DEFAULT_EXEC_MS};
use crate::obs::ObsConfig;
use crate::platform::presets::INCLUDEOS_PAUSED_BYTES;
use crate::platform::{
    run_platform, DriverProfile, FaultPlan, ImageSeeding, PlatformConfig, PlatformLoad,
    RequestPath, SchedPolicy, SharingMode,
};
use crate::report::Report;
use crate::sim::Host;
use crate::workload::tenants::{TenantConfig, TenantTrace};

/// Full E13 configuration: the tenant trace plus the cluster shape.
#[derive(Clone, Debug)]
pub struct FleetConfig {
    pub tenant: TenantConfig,
    pub nodes: usize,
    pub cores_per_node: u32,
    pub schedulers: Vec<SchedPolicy>,
    pub host: Host,
}

/// Derive an E13 configuration from the shared experiment config: the
/// trace is sized so total invocations scale with `cfg.requests`
/// (default ~20k arrivals over 1000 functions per cell; `--quick` ~3k —
/// the grid is 32 cells, so totals multiply).
pub fn fleet_config(cfg: &ExpConfig) -> FleetConfig {
    let duration_s = (cfg.requests as f64 / 25.0).clamp(60.0, 600.0);
    let total_rps = (cfg.requests as f64 * 2.0) / duration_s;
    FleetConfig {
        tenant: TenantConfig {
            functions: 1000,
            duration_s,
            total_rps,
            seed: cfg.seed,
            ..Default::default()
        },
        nodes: 8,
        cores_per_node: 8,
        schedulers: SchedPolicy::ALL.to_vec(),
        host: cfg.host,
    }
}

/// One (driver, policy, scheduler) cell of the fleet sweep.
#[derive(Clone, Debug)]
pub struct FleetCell {
    pub driver: DriverKind,
    pub policy: String,
    pub scheduler: SchedPolicy,
    pub requests: u64,
    pub p50_ms: f64,
    pub p99_ms: f64,
    pub cold_fraction: f64,
    pub idle_gb_seconds: f64,
    pub monitor_events: u64,
    pub prewarm_boots: u64,
    pub transfers: u64,
    pub transferred_mb: f64,
    /// On the 3-way Pareto frontier of (p99, idle waste, bytes moved)?
    pub on_frontier: bool,
}

impl FleetCell {
    pub fn label(&self) -> String {
        let d = match self.driver {
            DriverKind::DockerWarm => "docker",
            DriverKind::IncludeOsCold => "includeos",
        };
        format!("{d}+{}+{}", self.policy, self.scheduler.name())
    }
}

/// Mark Pareto-optimal cells in the (p99, waste, bytes-moved) space: a
/// cell is dominated if some other cell is no worse on all three axes and
/// strictly better on at least one.
fn mark_frontier(cells: &mut [FleetCell]) {
    let snapshot: Vec<(f64, f64, f64)> = cells
        .iter()
        .map(|c| (c.p99_ms, c.idle_gb_seconds, c.transferred_mb))
        .collect();
    for (i, c) in cells.iter_mut().enumerate() {
        let (p99, waste, moved) = snapshot[i];
        c.on_frontier = !snapshot.iter().enumerate().any(|(j, &(op, ow, om))| {
            j != i
                && op <= p99
                && ow <= waste
                && om <= moved
                && (op < p99 || ow < waste || om < moved)
        });
    }
}

/// One platform cell of a fleet-shaped sweep.  Shared by E13 and E14
/// (the chaos grid is exactly this grid under a fault plan), so the two
/// experiments cannot drift apart on cluster shape or request path.
pub(crate) fn cell_config(
    nodes: usize,
    cores_per_node: u32,
    tenant: &TenantConfig,
    driver: DriverKind,
    scheduler: SchedPolicy,
    trace: &TenantTrace,
    faults: FaultPlan,
    obs: ObsConfig,
) -> PlatformConfig {
    PlatformConfig {
        driver: DriverProfile::from_kind(driver),
        nodes,
        cores_per_node,
        mem_slots_per_node: cores_per_node.saturating_mul(8),
        scheduler,
        functions: tenant.functions,
        exec_ms: DEFAULT_EXEC_MS,
        mem_bytes_per_slot: match driver {
            DriverKind::DockerWarm => driver.tech().warm_memory_bytes(),
            DriverKind::IncludeOsCold => INCLUDEOS_PAUSED_BYTES,
        },
        seeding: ImageSeeding::RoundRobin,
        fabric_gbps: 40.0,
        path: RequestPath::Agent {
            client: crate::net::Site::LabStockholm,
            server: crate::net::Site::LabStockholm,
            include_conn_setup: false,
            placement: crate::fnplat::Placement::LocalLab,
            db: crate::fnplat::DbBackend::Postgres,
        },
        load: PlatformLoad::Tenants(trace.clone()),
        sharing: SharingMode::Exclusive,
        universal_prewarm: 0,
        warmup_keep_ns: 30 * 1_000_000_000,
        // Hot path stays O(1) memory per series: quantiles come from the
        // streaming per-node histograms, not raw sample vectors.
        exact_latencies: false,
        faults,
        obs,
        shards: 1,
        checkpoint_every_ns: 0,
        checkpoint_path: None,
        resume_from: None,
        state_hash: false,
        seed: tenant.seed,
    }
}

/// Run the full driver x policy x scheduler grid over one generated
/// trace.  Cells are independent and run on the shared parallel sweep
/// runner; results collect in grid order, so the report is byte-identical
/// to serial execution.
pub fn fleet_cells(cfg: &FleetConfig) -> Vec<FleetCell> {
    fleet_cells_with(cfg, sweep::sweep_threads(2 * cfg.schedulers.len() * POLICY_COUNT))
}

/// The grid on an explicit worker-thread count (1 = serial); the
/// regression suite asserts both produce identical cells.
pub fn fleet_cells_with(cfg: &FleetConfig, threads: usize) -> Vec<FleetCell> {
    let trace = TenantTrace::generate(&cfg.tenant);
    let mut specs: Vec<(DriverKind, SchedPolicy, usize)> = Vec::new();
    for driver in [DriverKind::IncludeOsCold, DriverKind::DockerWarm] {
        for &scheduler in &cfg.schedulers {
            for policy_idx in 0..POLICY_COUNT {
                specs.push((driver, scheduler, policy_idx));
            }
        }
    }
    let mut cells = sweep::run_cells_with(threads, &specs, |_, &(driver, scheduler, pidx)| {
        let mut policy = make_policy(pidx, cfg.tenant.functions);
        let pcfg = cell_config(
            cfg.nodes,
            cfg.cores_per_node,
            &cfg.tenant,
            driver,
            scheduler,
            &trace,
            FaultPlan::default(),
            ObsConfig::default(),
        );
        let r = run_platform(&pcfg, policy.as_mut(), cfg.host);
        FleetCell {
            driver,
            policy: policy.name(),
            scheduler,
            requests: r.requests,
            p50_ms: r.quantile_ms(0.5),
            p99_ms: r.quantile_ms(0.99),
            cold_fraction: r.cold_fraction(),
            idle_gb_seconds: r.idle_gb_seconds,
            monitor_events: r.monitor_events,
            prewarm_boots: r.prewarm_boots,
            transfers: r.transfers,
            transferred_mb: r.transferred_bytes as f64 / 1e6,
            on_frontier: false,
        }
    });
    mark_frontier(&mut cells);
    cells
}

fn find<'a>(
    cells: &'a [FleetCell],
    driver: DriverKind,
    policy: &str,
    sched: SchedPolicy,
) -> &'a FleetCell {
    cells
        .iter()
        .find(|c| c.driver == driver && c.policy == policy && c.scheduler == sched)
        .expect("cell present")
}

/// E13 report over an explicit configuration (the CLI subcommand path).
pub fn fleet_with(cfg: &FleetConfig) -> Report {
    let mut report = Report::new(&format!(
        "E13: fleet sweep — policy x scheduler x driver over {} nodes \
         ({} fns, Zipf {:.1}, {:.0} rps, {:.0} s)",
        cfg.nodes,
        cfg.tenant.functions,
        cfg.tenant.zipf_exponent,
        cfg.tenant.total_rps,
        cfg.tenant.duration_s
    ));
    let cells = fleet_cells(cfg);

    report.note(format!(
        "{:<36} {:>8} {:>8} {:>10} {:>7} {:>11} {:>7} {:>9}  {}",
        "driver+policy+scheduler",
        "reqs",
        "p50 ms",
        "p99 ms",
        "cold%",
        "waste GB·s",
        "pulls",
        "moved MB",
        "frontier"
    ));
    for c in &cells {
        report.note(format!(
            "{:<36} {:>8} {:>8.2} {:>10.1} {:>6.1}% {:>11.2} {:>7} {:>9.1}  {}",
            c.label(),
            c.requests,
            c.p50_ms,
            c.p99_ms,
            c.cold_fraction * 100.0,
            c.idle_gb_seconds,
            c.transfers,
            c.transferred_mb,
            if c.on_frontier { "*" } else { "" }
        ));
    }

    let ll = SchedPolicy::LeastLoaded;
    let inc_cold_ll = find(&cells, DriverKind::IncludeOsCold, "cold-only", ll);
    let doc_cold_ll = find(&cells, DriverKind::DockerWarm, "cold-only", ll);
    let inc_cold_colo = find(&cells, DriverKind::IncludeOsCold, "cold-only", SchedPolicy::CoLocate);

    // The paper's lifecycle is still free at cluster scale: no retention,
    // no polling, on any scheduler.
    let max_inc_cold_waste = cells
        .iter()
        .filter(|c| c.driver == DriverKind::IncludeOsCold && c.policy == "cold-only")
        .map(|c| c.idle_gb_seconds)
        .fold(0.0, f64::max);
    report.band("includeos+cold-only idle waste (all scheds)", "GB·s", max_inc_cold_waste, 0.0, 0.0);
    report.band(
        "includeos+cold-only monitor events",
        "events",
        inc_cold_ll.monitor_events as f64,
        0.0,
        0.0,
    );
    // The headline: the zero-waste unikernel row stays Pareto-optimal on
    // the cluster-scale (p99, waste, bytes-moved) frontier.
    let inc_cold_on_frontier = cells.iter().any(|c| {
        c.driver == DriverKind::IncludeOsCold && c.policy == "cold-only" && c.on_frontier
    });
    report.band(
        "includeos+cold-only on (p99, waste, moved) frontier",
        "bool",
        if inc_cold_on_frontier { 1.0 } else { 0.0 },
        1.0,
        1.0,
    );
    // Docker's cold path still cannot sustain the open-loop tenant load
    // even with 8 nodes' engines in parallel: cold-only stays viable only
    // on the unikernel.
    report.band(
        "docker+cold-only p99 / includeos+cold-only p99",
        "ratio",
        doc_cold_ll.p99_ms / inc_cold_ll.p99_ms,
        3.0,
        f64::INFINITY,
    );
    // Placement economics: co-location minimizes image movement...
    report.band(
        "co-locate/least-loaded bytes moved (includeos cold)",
        "ratio",
        inc_cold_colo.transferred_mb / nonzero(inc_cold_ll.transferred_mb),
        0.0,
        0.5,
    );
    // ...and the smaller unikernel image is what makes ignoring locality
    // cheaper: same scheduler, same trace, ~2.4x fewer bytes moved than
    // the Docker driver's Alpine image (2.5 MB vs 6 MB per pull).
    report.band(
        "docker/includeos bytes moved (least-loaded, cold)",
        "ratio",
        doc_cold_ll.transferred_mb / nonzero(inc_cold_ll.transferred_mb),
        1.3,
        6.0,
    );
    // Every cell served the whole trace (no lost requests at any scale).
    let reqs = cells[0].requests;
    let all_equal = cells.iter().all(|c| c.requests == reqs);
    report.band(
        "all cells served the full trace",
        "bool",
        if all_equal { 1.0 } else { 0.0 },
        1.0,
        1.0,
    );

    report.note(
        "reading: at cluster scale the warm policies still buy p99 with resident \
         memory + monitoring, and placement adds an image-movement axis — the \
         cold-only unikernel row stays on the frontier because its 2.5 MB image \
         makes spread placement nearly free",
    );
    report
}

fn nonzero(v: f64) -> f64 {
    v.max(1e-9)
}

/// E13 via the shared experiment config (the `experiment fleet` path).
pub fn fleet(cfg: &ExpConfig) -> Report {
    fleet_with(&fleet_config(cfg))
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Reduced load for the structural unit tests; the full `--quick`
    /// grid (with its paper checks) runs once in `fleet_checks_pass_quick`.
    fn small_cfg() -> FleetConfig {
        FleetConfig {
            tenant: TenantConfig {
                functions: 1000,
                duration_s: 30.0,
                total_rps: 60.0,
                seed: 0xE13,
                ..Default::default()
            },
            nodes: 4,
            cores_per_node: 8,
            schedulers: vec![SchedPolicy::CoLocate, SchedPolicy::LeastLoaded],
            host: Host::default(),
        }
    }

    #[test]
    fn fleet_checks_pass_quick() {
        let r = fleet(&ExpConfig::quick());
        assert!(r.all_pass(), "failures: {:#?}", r.failures());
    }

    #[test]
    fn grid_covers_policy_x_scheduler_x_driver() {
        let cfg = small_cfg();
        let cells = fleet_cells(&cfg);
        assert_eq!(cells.len(), 2 * 2 * 4);
        for name in ["cold-only", "fixed-600s", "histogram", "ewma"] {
            for d in [DriverKind::DockerWarm, DriverKind::IncludeOsCold] {
                for s in &cfg.schedulers {
                    assert!(
                        cells
                            .iter()
                            .any(|c| c.driver == d && c.policy == name && c.scheduler == *s),
                        "missing cell {d:?}+{name}+{}",
                        s.name()
                    );
                }
            }
        }
        let n = cells[0].requests;
        assert!(n > 500, "trace too small: {n}");
        assert!(cells.iter().all(|c| c.requests == n));
    }

    #[test]
    fn deterministic_report_per_seed() {
        let a = fleet_with(&small_cfg()).render();
        let b = fleet_with(&small_cfg()).render();
        assert_eq!(a, b);
        let mut other = small_cfg();
        other.tenant.seed = 1;
        let c = fleet_with(&other).render();
        assert_ne!(a, c);
    }

    #[test]
    fn parallel_sweep_is_byte_identical_to_serial() {
        let cfg = small_cfg();
        let serial = fleet_cells_with(&cfg, 1);
        let parallel = fleet_cells_with(&cfg, 4);
        assert_eq!(serial.len(), parallel.len());
        for (s, p) in serial.iter().zip(&parallel) {
            assert_eq!(s.label(), p.label());
            assert_eq!(
                (s.requests, s.p99_ms.to_bits(), s.idle_gb_seconds.to_bits(), s.transfers),
                (p.requests, p.p99_ms.to_bits(), p.idle_gb_seconds.to_bits(), p.transfers),
                "{} diverged across thread counts",
                s.label()
            );
            assert_eq!(s.on_frontier, p.on_frontier);
        }
    }

    #[test]
    fn cold_only_unikernel_stays_pareto_optimal_at_cluster_scale() {
        let cells = fleet_cells(&small_cfg());
        assert!(cells
            .iter()
            .filter(|c| c.driver == DriverKind::IncludeOsCold && c.policy == "cold-only")
            .all(|c| c.idle_gb_seconds == 0.0 && c.monitor_events == 0));
        assert!(
            cells.iter().any(|c| c.driver == DriverKind::IncludeOsCold
                && c.policy == "cold-only"
                && c.on_frontier),
            "zero-waste cold-only row must stay on the cluster frontier"
        );
    }

    #[test]
    fn colocation_moves_fewer_bytes_than_spreading() {
        let cells = fleet_cells(&small_cfg());
        let colo = find(
            &cells,
            DriverKind::IncludeOsCold,
            "cold-only",
            SchedPolicy::CoLocate,
        );
        let ll = find(
            &cells,
            DriverKind::IncludeOsCold,
            "cold-only",
            SchedPolicy::LeastLoaded,
        );
        assert!(ll.transfers > 0, "spreading must pull images");
        assert!(colo.transferred_mb < ll.transferred_mb);
    }
}
