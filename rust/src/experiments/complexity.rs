//! E8 (§IV-B closing claim): "by using more complex functions the overhead
//! of Fn with IncludeOS gets less and less significant compared to the
//! execution time."  Sweeps the AOT workload ladder (echo → transformer)
//! through both drivers and reports platform-overhead share.

use super::ExpConfig;
use crate::fnplat::{run_scenario, DriverKind, Scenario};
use crate::report::Report;
use crate::runtime::static_exec_ms;

pub struct ComplexityRow {
    pub workload: &'static str,
    pub exec_ms: f64,
    pub cold_includeos_ms: f64,
    pub warm_docker_ms: f64,
    /// Fraction of the cold-IncludeOS latency that is platform overhead.
    pub overhead_share: f64,
}

/// The AOT workload ladder, ordered by rising execution cost (matches the
/// flops ordering asserted in python/tests/test_model.py).
pub const WORKLOADS: [&str; 5] = ["echo", "thumbnail", "checksum", "mlp", "transformer"];

/// Optionally measure execution medians live through PJRT; fall back to
/// the recorded constants (`runtime::static_exec_ms`).
pub fn exec_times(live: bool) -> Vec<(&'static str, f64)> {
    if live {
        if let Ok(rt) = crate::runtime::Runtime::load(crate::runtime::default_artifacts_dir()) {
            return WORKLOADS
                .iter()
                .map(|&w| (w, rt.measure_exec_ms(w, 30).unwrap_or(static_exec_ms(w))))
                .collect();
        }
    }
    WORKLOADS.iter().map(|&w| (w, static_exec_ms(w))).collect()
}

pub fn complexity_rows(cfg: &ExpConfig, live: bool) -> Vec<ComplexityRow> {
    let n = cfg.requests.min(2000);
    exec_times(live)
        .into_iter()
        .map(|(w, exec_ms)| {
            let sc = Scenario {
                exec_ms: exec_ms.max(0.01),
                seed: cfg.seed ^ w.len() as u64,
                ..Scenario::local(DriverKind::IncludeOsCold, 4, n, false)
            };
            let cold = run_scenario(&sc, cfg.host).median_ms();
            let sc = Scenario {
                exec_ms: exec_ms.max(0.01),
                seed: cfg.seed ^ (w.len() as u64) << 8,
                ..Scenario::local(DriverKind::DockerWarm, 4, n, true)
            };
            let warm = run_scenario(&sc, cfg.host).median_ms();
            ComplexityRow {
                workload: w,
                exec_ms,
                cold_includeos_ms: cold,
                warm_docker_ms: warm,
                overhead_share: (cold - exec_ms) / cold,
            }
        })
        .collect()
}

pub fn complexity(cfg: &ExpConfig) -> Report {
    let rows = complexity_rows(cfg, false);
    let mut report = Report::new(
        "E8: platform overhead vs function complexity (cold IncludeOS vs warm Docker)",
    );
    for r in &rows {
        report.note(format!(
            "{:<12} exec={:>7.2} ms  cold-includeos={:>7.2} ms  warm-docker={:>7.2} ms  overhead-share={:>5.1}%",
            r.workload,
            r.exec_ms,
            r.cold_includeos_ms,
            r.warm_docker_ms,
            r.overhead_share * 100.0
        ));
    }
    // Overhead share must fall monotonically along the complexity ladder.
    for w in rows.windows(2) {
        report.band(
            &format!("overhead share falls: {} -> {}", w[0].workload, w[1].workload),
            "delta",
            w[1].overhead_share - w[0].overhead_share,
            -1.0,
            0.001,
        );
    }
    // For the heaviest workload the cold/warm gap closes substantially.
    let last = rows.last().unwrap();
    let first = &rows[0];
    let gap_heavy = last.cold_includeos_ms / last.warm_docker_ms;
    let gap_light = first.cold_includeos_ms / first.warm_docker_ms;
    report.band("cold/warm gap shrinks with complexity", "ratio", gap_heavy / gap_light, 0.0, 0.8);
    report.note("the claim: cold-start overhead amortizes as functions do real work");
    report
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn complexity_checks_pass_quick() {
        let r = complexity(&ExpConfig::quick());
        assert!(r.all_pass(), "failures: {:#?}", r.failures());
    }

    #[test]
    fn exec_ladder_monotone() {
        let t = exec_times(false);
        for w in t.windows(2) {
            assert!(w[0].1 <= w[1].1, "exec times must rise along the ladder: {t:?}");
        }
    }
}
