//! E12: the keep-alive policy lab — every lifecycle policy real platforms
//! run (fixed keep-alive, hybrid histogram pre-warming, learned-predictor
//! stand-in) against the paper's cold-only lifecycle, over a multi-tenant
//! Zipf trace, on both Fn drivers.  Output: the p50/p99-latency vs
//! GB·s-idle-waste frontier, quantifying §IV's qualitative claim that the
//! cold-only unikernel platform can delete the warm-pool machinery.

use super::{make_policy, sweep, ExpConfig, POLICY_COUNT};
use crate::fnplat::DriverKind;
use crate::policy::{run_policy_scenario, PolicyScenario};
use crate::report::Report;
use crate::sim::Host;
use crate::workload::tenants::{TenantConfig, TenantTrace};

/// Full E12 configuration: the tenant trace plus the host model.
#[derive(Clone, Debug)]
pub struct E12Config {
    pub tenant: TenantConfig,
    pub host: Host,
}

/// Derive an E12 configuration from the shared experiment config: the
/// trace is sized so total invocations scale with `cfg.requests`
/// (default ~120k arrivals over 1000 functions; `--quick` ~18k).
pub fn e12_config(cfg: &ExpConfig) -> E12Config {
    let duration_s = (cfg.requests as f64 / 25.0).clamp(120.0, 900.0);
    let total_rps = (cfg.requests as f64 * 12.0) / duration_s;
    E12Config {
        tenant: TenantConfig {
            functions: 1000,
            duration_s,
            total_rps,
            seed: cfg.seed,
            ..Default::default()
        },
        host: cfg.host,
    }
}

/// One (driver, policy) cell of the lab.
#[derive(Clone, Debug)]
pub struct PolicyCell {
    pub driver: DriverKind,
    pub policy: String,
    pub requests: u64,
    pub p50_ms: f64,
    pub p99_ms: f64,
    pub cold_fraction: f64,
    pub idle_gb_seconds: f64,
    pub monitor_events: u64,
    pub prewarm_boots: u64,
    /// On the Pareto frontier of (p99 latency, idle waste)?
    pub on_frontier: bool,
}

impl PolicyCell {
    pub fn label(&self) -> String {
        let d = match self.driver {
            DriverKind::DockerWarm => "docker",
            DriverKind::IncludeOsCold => "includeos",
        };
        format!("{d}+{}", self.policy)
    }
}

/// Mark Pareto-optimal cells in the (p99, waste) plane.
fn mark_frontier(cells: &mut [PolicyCell]) {
    super::mark_pareto2(
        cells,
        |c| (c.p99_ms, c.idle_gb_seconds),
        |c, on| c.on_frontier = on,
    );
}

/// Run the full policy x driver grid over one generated trace.  Cells
/// run on the shared parallel sweep runner and collect in grid order, so
/// the report is byte-identical to serial execution.
pub fn policy_cells(cfg: &E12Config) -> Vec<PolicyCell> {
    let trace = TenantTrace::generate(&cfg.tenant);
    let mut specs: Vec<(DriverKind, usize)> = Vec::new();
    for driver in [DriverKind::IncludeOsCold, DriverKind::DockerWarm] {
        for policy_idx in 0..POLICY_COUNT {
            specs.push((driver, policy_idx));
        }
    }
    let mut cells = sweep::run_cells(&specs, |_, &(driver, policy_idx)| {
        let mut policy = make_policy(policy_idx, cfg.tenant.functions);
        let sc = PolicyScenario::new(driver, trace.clone(), cfg.tenant.seed);
        let r = run_policy_scenario(&sc, policy.as_mut(), cfg.host);
        PolicyCell {
            driver,
            policy: policy.name(),
            requests: r.requests(),
            p50_ms: r.quantile_ms(0.5),
            p99_ms: r.quantile_ms(0.99),
            cold_fraction: r.cold_fraction(),
            idle_gb_seconds: r.idle_gb_seconds,
            monitor_events: r.monitor_events,
            prewarm_boots: r.prewarm_boots,
            on_frontier: false,
        }
    });
    mark_frontier(&mut cells);
    cells
}

fn cell<'a>(cells: &'a [PolicyCell], driver: DriverKind, policy: &str) -> &'a PolicyCell {
    cells
        .iter()
        .find(|c| c.driver == driver && c.policy == policy)
        .expect("cell present")
}

/// E12 report over an explicit configuration (the CLI subcommand path).
pub fn policies_with(cfg: &E12Config) -> Report {
    let mut report = Report::new(&format!(
        "E12: keep-alive policy lab — latency vs idle-waste frontier \
         ({} fns, Zipf {:.1}, {:.0} rps, {:.0} s)",
        cfg.tenant.functions, cfg.tenant.zipf_exponent, cfg.tenant.total_rps, cfg.tenant.duration_s
    ));
    let cells = policy_cells(cfg);

    report.note(format!(
        "{:<22} {:>8} {:>9} {:>10} {:>7} {:>12} {:>12} {:>9}  {}",
        "driver+policy", "reqs", "p50 ms", "p99 ms", "cold%", "waste GB·s", "monitor-evt", "prewarms", "frontier"
    ));
    for c in &cells {
        report.note(format!(
            "{:<22} {:>8} {:>9.2} {:>10.1} {:>6.1}% {:>12.2} {:>12} {:>9}  {}",
            c.label(),
            c.requests,
            c.p50_ms,
            c.p99_ms,
            c.cold_fraction * 100.0,
            c.idle_gb_seconds,
            c.monitor_events,
            c.prewarm_boots,
            if c.on_frontier { "*" } else { "" }
        ));
    }

    let inc_cold = cell(&cells, DriverKind::IncludeOsCold, "cold-only");
    let doc_cold = cell(&cells, DriverKind::DockerWarm, "cold-only");
    let doc_fixed = cell(&cells, DriverKind::DockerWarm, "fixed-600s");
    let doc_hist = cell(&cells, DriverKind::DockerWarm, "histogram");
    let doc_ewma = cell(&cells, DriverKind::DockerWarm, "ewma");

    // The paper's lifecycle is genuinely free: no retention, no polling.
    report.band("includeos+cold-only idle waste", "GB·s", inc_cold.idle_gb_seconds, 0.0, 0.0);
    report.band(
        "includeos+cold-only monitor events",
        "events",
        inc_cold.monitor_events as f64,
        0.0,
        0.0,
    );
    report.band(
        "cold-only policies serve 100% cold",
        "fraction",
        inc_cold.cold_fraction.min(doc_cold.cold_fraction),
        1.0,
        1.0,
    );
    // The headline: the zero-waste unikernel row sits ON the frontier.
    report.band(
        "includeos+cold-only on (p99, waste) frontier",
        "bool",
        if inc_cold.on_frontier { 1.0 } else { 0.0 },
        1.0,
        1.0,
    );
    // ... with a p99 comparable to the best warm-pool policy (which pays
    // GB·s of idle memory and per-function monitoring for its latency).
    let best_warm_p99 =
        doc_fixed.p99_ms.min(doc_hist.p99_ms).min(doc_ewma.p99_ms);
    report.band(
        "includeos-cold p99 / best warm-policy p99",
        "ratio",
        inc_cold.p99_ms / best_warm_p99,
        0.0,
        8.0,
    );
    // Warm pools must actually pay for that latency.
    report.band(
        "docker+fixed-600s idle waste",
        "GB·s",
        doc_fixed.idle_gb_seconds,
        1e-6,
        f64::INFINITY,
    );
    report.band(
        "docker+fixed-600s monitoring load",
        "events",
        doc_fixed.monitor_events as f64,
        1.0,
        f64::INFINITY,
    );
    // Adaptive policies trim the fixed window's waste, not add to it.
    report.band(
        "histogram/fixed waste ratio",
        "ratio",
        doc_hist.idle_gb_seconds / doc_fixed.idle_gb_seconds.max(1e-12),
        0.0,
        1.25,
    );
    // Docker's cold path cannot even sustain the open-loop tenant load
    // (engine serialization): cold-only is only viable on the unikernel.
    report.band(
        "docker+cold-only p99 / includeos+cold-only p99",
        "ratio",
        doc_cold.p99_ms / inc_cold.p99_ms,
        3.0,
        f64::INFINITY,
    );

    report.note(
        "reading: every warm policy buys its p99 with resident memory and \
         monitoring; the cold-only unikernel row gets a comparable p99 for free \
         — the machinery itself is what the paper deletes",
    );
    report
}

/// E12 via the shared experiment config (the `experiment policies` path).
pub fn policies(cfg: &ExpConfig) -> Report {
    policies_with(&e12_config(cfg))
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Reduced load for the structural unit tests; the full `--quick`
    /// grid (with its paper checks) runs once in `policies_checks_pass`.
    fn small_cfg() -> E12Config {
        E12Config {
            tenant: TenantConfig {
                functions: 1000,
                duration_s: 60.0,
                total_rps: 100.0,
                seed: 0xE12,
                ..Default::default()
            },
            host: Host::default(),
        }
    }

    #[test]
    fn policies_checks_pass_quick() {
        let r = policies(&ExpConfig::quick());
        assert!(r.all_pass(), "failures: {:#?}", r.failures());
    }

    #[test]
    fn grid_covers_all_policies_on_both_drivers() {
        let cells = policy_cells(&small_cfg());
        assert_eq!(cells.len(), 8);
        for name in ["cold-only", "fixed-600s", "histogram", "ewma"] {
            for d in [DriverKind::DockerWarm, DriverKind::IncludeOsCold] {
                assert!(
                    cells.iter().any(|c| c.driver == d && c.policy == name),
                    "missing cell {d:?}+{name}"
                );
            }
        }
        // All cells served the same trace.
        let n = cells[0].requests;
        assert!(n > 1000, "trace too small: {n}");
        assert!(cells.iter().all(|c| c.requests == n));
    }

    #[test]
    fn e12_trace_is_thousand_function_scale() {
        let cfg = e12_config(&ExpConfig::quick());
        assert!(cfg.tenant.functions >= 1000);
        let trace = TenantTrace::generate(&cfg.tenant);
        let active = trace.per_function_counts().iter().filter(|&&c| c > 0).count();
        assert!(active >= 500, "tenant tail must be active: {active}");
    }

    #[test]
    fn deterministic_report_per_seed() {
        let a = policies_with(&small_cfg()).render();
        let b = policies_with(&small_cfg()).render();
        assert_eq!(a, b);
        let mut other = small_cfg();
        other.tenant.seed = 1;
        let c = policies_with(&other).render();
        assert_ne!(a, c);
    }

    #[test]
    fn frontier_marking_is_pareto() {
        let mut cells: Vec<PolicyCell> = [
            (10.0, 0.0),  // A: fast-ish, free        -> frontier
            (5.0, 100.0), // B: fastest, expensive    -> frontier
            (12.0, 50.0), // C: dominated by A
            (5.0, 120.0), // D: dominated by B
        ]
        .iter()
        .map(|&(p99, waste)| PolicyCell {
            driver: DriverKind::DockerWarm,
            policy: "x".into(),
            requests: 1,
            p50_ms: 1.0,
            p99_ms: p99,
            cold_fraction: 0.0,
            idle_gb_seconds: waste,
            monitor_events: 0,
            prewarm_boots: 0,
            on_frontier: false,
        })
        .collect();
        mark_frontier(&mut cells);
        assert!(cells[0].on_frontier);
        assert!(cells[1].on_frontier);
        assert!(!cells[2].on_frontier);
        assert!(!cells[3].on_frontier);
    }
}
