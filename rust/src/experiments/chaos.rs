//! E14: the chaos sweep — the fleet under failure.  The 1000-function
//! Zipf tenant trace is replayed against an 8–16 node cluster while a
//! scripted [`FaultPlan`](crate::platform::FaultPlan) crashes nodes
//! (flushing their image caches and
//! straggling their first cold starts back), browns out the fabric, and
//! forces killed requests through client retries — for every lifecycle
//! policy x placement scheduler x driver cell, each paired with a
//! fault-free baseline leg over the *same* trace, seed, and disruption
//! windows.
//!
//! The paper-anchored claim (§I/§IV taken to its fleet conclusion): a
//! cold-only unikernel platform has *no state to lose* — it degrades only
//! by the capacity the crash took, shows zero post-restart cold-burst
//! spike, and rebuilds nothing — while every keep-alive policy loses its
//! warm pools at the crash and pays a cold-fraction spike (plus renewed
//! GB·s of residency) to rebuild them.  And under every cell, request
//! conservation holds: killed requests are retried or reported rejected,
//! never silently lost.

use super::fleet::cell_config;
use super::{make_policy, sweep, ExpConfig, POLICY_COUNT};
use crate::fnplat::DriverKind;
use crate::obs::{ObsConfig, TelemetrySeries};
use crate::platform::{chaos_plan, run_platform, SchedPolicy};
use crate::report::Report;
use crate::sim::Host;
use crate::workload::tenants::{TenantConfig, TenantTrace};

/// Full E14 configuration: the tenant trace plus the cluster shape (the
/// fault schedule itself is derived from the trace horizon, so every
/// cell faces the same disruption).
#[derive(Clone, Debug)]
pub struct ChaosConfig {
    pub tenant: TenantConfig,
    pub nodes: usize,
    pub cores_per_node: u32,
    pub schedulers: Vec<SchedPolicy>,
    pub host: Host,
    /// Collect interval time-series (S25) on the two focus cells — the
    /// keep-alive flagship (`docker+fixed-600s+least-loaded`) and the
    /// paper's row (`includeos+cold-only+least-loaded`) — and publish
    /// them in the report.  Sampling is virtual-time pure, so every
    /// metric (and the rest of the report) stays byte-identical.
    pub timeseries: bool,
}

/// Derive an E14 configuration from the shared experiment config (same
/// trace sizing as E13; the grid is 16 cells, each run twice).
pub fn chaos_config(cfg: &ExpConfig) -> ChaosConfig {
    let duration_s = (cfg.requests as f64 / 25.0).clamp(60.0, 600.0);
    let total_rps = (cfg.requests as f64 * 2.0) / duration_s;
    ChaosConfig {
        tenant: TenantConfig {
            functions: 1000,
            duration_s,
            total_rps,
            seed: cfg.seed,
            ..Default::default()
        },
        nodes: 8,
        cores_per_node: 8,
        schedulers: vec![SchedPolicy::LeastLoaded, SchedPolicy::CoLocate],
        host: cfg.host,
        timeseries: false,
    }
}

/// One (driver, policy, scheduler) cell: the faulted run next to its
/// fault-free baseline over identical trace, seed, and windows.
#[derive(Clone, Debug)]
pub struct ChaosCell {
    pub driver: DriverKind,
    pub policy: String,
    pub scheduler: SchedPolicy,
    pub injected: u64,
    pub served: u64,
    pub killed: u64,
    pub retries: u64,
    pub rejected: u64,
    /// Idle warm executors destroyed when their node crashed.
    pub warm_slots_lost: u64,
    pub prewarm_boots: u64,
    pub idle_gb_seconds: f64,
    pub p99_ms: f64,
    pub baseline_p99_ms: f64,
    /// Cold fraction of dispatches inside disruption windows.
    pub window_cold_fraction: f64,
    pub baseline_window_cold_fraction: f64,
    pub steady_cold_fraction: f64,
    pub crashes: u64,
    pub restarts: u64,
    /// Engine events across both legs — deterministic per seed.
    pub events: u64,
    /// Wall-clock seconds across both legs (not deterministic).
    pub wall_s: f64,
    /// Faulted-leg interval time-series; `None` off the focus cells.
    pub telemetry: Option<TelemetrySeries>,
}

impl ChaosCell {
    pub fn label(&self) -> String {
        let d = match self.driver {
            DriverKind::DockerWarm => "docker",
            DriverKind::IncludeOsCold => "includeos",
        };
        format!("{d}+{}+{}", self.policy, self.scheduler.name())
    }

    /// Post-crash cold-burst spike: extra cold fraction inside the
    /// disruption windows relative to the fault-free baseline.  Zero for
    /// a platform with no warm state to rebuild.
    pub fn cold_spike(&self) -> f64 {
        self.window_cold_fraction - self.baseline_window_cold_fraction
    }
}

/// Run the driver x policy x scheduler grid, each cell as a (faulted,
/// baseline) pair over one generated trace and one scripted fault plan.
pub fn chaos_cells(cfg: &ChaosConfig) -> Vec<ChaosCell> {
    cells_over(cfg, &TenantTrace::generate(&cfg.tenant))
}

/// The grid over an already-generated trace (cells are exactly E13 fleet
/// cells — `fleet::cell_config` — under the scripted plan / its dry leg).
/// Both legs of a cell run in the same sweep-runner slot, so the pairing
/// is preserved and the collected order matches the serial grid.
fn cells_over(cfg: &ChaosConfig, trace: &TenantTrace) -> Vec<ChaosCell> {
    let horizon_ns = (cfg.tenant.duration_s * 1e9) as u64;
    let plan = chaos_plan(cfg.nodes, horizon_ns);
    let mut specs: Vec<(DriverKind, SchedPolicy, usize)> = Vec::new();
    for driver in [DriverKind::IncludeOsCold, DriverKind::DockerWarm] {
        for &scheduler in &cfg.schedulers {
            for idx in 0..POLICY_COUNT {
                specs.push((driver, scheduler, idx));
            }
        }
    }
    // ~96 samples per run regardless of horizon (sparkline-width-ish).
    let interval_ns = ((cfg.tenant.duration_s * 1e9) / 96.0).ceil().max(1.0) as u64;
    sweep::run_cells(&specs, |_, &(driver, scheduler, idx)| {
        let cell = |faults, obs| {
            cell_config(
                cfg.nodes,
                cfg.cores_per_node,
                &cfg.tenant,
                driver,
                scheduler,
                trace,
                faults,
                obs,
            )
        };
        // Telemetry rides only the faulted leg of the two focus cells:
        // the keep-alive flagship and the paper's cold-only row.
        let focus = cfg.timeseries
            && scheduler == SchedPolicy::LeastLoaded
            && matches!(
                (driver, idx),
                (DriverKind::DockerWarm, 1) | (DriverKind::IncludeOsCold, 0)
            );
        let obs = if focus {
            ObsConfig { telemetry_interval_ns: interval_ns, ..ObsConfig::default() }
        } else {
            ObsConfig::default()
        };
        let mut policy = make_policy(idx, cfg.tenant.functions);
        let fcfg = cell(plan.clone(), obs);
        let f = run_platform(&fcfg, policy.as_mut(), cfg.host);
        // Baseline leg: same trace, seed, and disruption-window
        // classification (dry plan), but nothing is injected.
        let mut baseline = make_policy(idx, cfg.tenant.functions);
        let bcfg = cell(plan.dry(), ObsConfig::default());
        let b = run_platform(&bcfg, baseline.as_mut(), cfg.host);
        ChaosCell {
            driver,
            policy: policy.name(),
            scheduler,
            injected: f.injected,
            served: f.served,
            killed: f.killed,
            retries: f.retries,
            rejected: f.rejected,
            warm_slots_lost: f.warm_slots_lost,
            prewarm_boots: f.prewarm_boots,
            idle_gb_seconds: f.idle_gb_seconds,
            p99_ms: f.quantile_ms(0.99),
            baseline_p99_ms: b.quantile_ms(0.99),
            window_cold_fraction: f.window_cold_fraction(),
            baseline_window_cold_fraction: b.window_cold_fraction(),
            steady_cold_fraction: f.steady_cold_fraction(),
            crashes: f.crashes,
            restarts: f.restarts,
            events: f.profile.engine_events + b.profile.engine_events,
            wall_s: (f.profile.wall_ns + b.profile.wall_ns) as f64 / 1e9,
            telemetry: f.telemetry,
        }
    })
}

fn cells_where<'a>(
    cells: &'a [ChaosCell],
    driver: DriverKind,
    policy: &'a str,
) -> impl Iterator<Item = &'a ChaosCell> {
    cells.iter().filter(move |c| c.driver == driver && c.policy == policy)
}

/// E14 report over an explicit configuration (the CLI subcommand path).
pub fn chaos_with(cfg: &ChaosConfig) -> Report {
    let mut report = Report::new(&format!(
        "E14: chaos sweep — node crashes + cache flushes + fabric brown-outs \
         over {} nodes ({} fns, {:.0} rps, {:.0} s; 2 staggered outages, retries on)",
        cfg.nodes, cfg.tenant.functions, cfg.tenant.total_rps, cfg.tenant.duration_s
    ));
    let trace = TenantTrace::generate(&cfg.tenant);
    let n_trace = trace.len() as u64;
    let cells = cells_over(cfg, &trace);

    // S25 self-profile: grid-total engine events are deterministic per
    // seed (gated strictly by the bench compare); events/s is wall-clock
    // and stays JSON-only informational.
    let total_events: u64 = cells.iter().map(|c| c.events).sum();
    let total_wall: f64 = cells.iter().map(|c| c.wall_s).sum();
    let eps = if total_wall > 0.0 { total_events as f64 / total_wall } else { 0.0 };
    report.set_profile(total_events, eps);
    for c in &cells {
        if let Some(t) = &c.telemetry {
            for (name, points) in t.rows() {
                report.add_timeseries(&format!("{} {name}", c.label()), t.interval_s(), points);
            }
        }
    }
    if cfg.timeseries {
        report.band(
            "focus cells sampled interval telemetry",
            "series",
            report.timeseries.iter().filter(|t| !t.points.is_empty()).count() as f64,
            1.0,
            f64::INFINITY,
        );
    }

    report.note(format!(
        "{:<36} {:>7} {:>7} {:>5} {:>5} {:>4} {:>6} {:>10} {:>9} {:>9} {:>8}",
        "driver+policy+scheduler",
        "inj",
        "served",
        "kill",
        "retry",
        "rej",
        "lost",
        "waste GB·s",
        "p99 ms",
        "base p99",
        "Δcold%"
    ));
    for c in &cells {
        report.note(format!(
            "{:<36} {:>7} {:>7} {:>5} {:>5} {:>4} {:>6} {:>10.2} {:>9.1} {:>9.1} {:>+7.1}%",
            c.label(),
            c.injected,
            c.served,
            c.killed,
            c.retries,
            c.rejected,
            c.warm_slots_lost,
            c.idle_gb_seconds,
            c.p99_ms,
            c.baseline_p99_ms,
            c.cold_spike() * 100.0
        ));
    }

    // Conservation, everywhere: nothing is silently lost under faults.
    let worst_conservation = cells
        .iter()
        .map(|c| (c.injected as i64 - c.served as i64 - c.rejected as i64).unsigned_abs())
        .max()
        .unwrap_or(0);
    report.band(
        "served + rejected == injected (worst cell)",
        "reqs",
        worst_conservation as f64,
        0.0,
        0.0,
    );
    let worst_injection = cells
        .iter()
        .map(|c| (c.injected as i64 - n_trace as i64).unsigned_abs())
        .max()
        .unwrap_or(0);
    report.band(
        "every trace arrival injected (worst cell)",
        "reqs",
        worst_injection as f64,
        0.0,
        0.0,
    );
    // With node 0 never crashing and retries on, no chain is abandoned.
    let max_rejected = cells.iter().map(|c| c.rejected).max().unwrap_or(0);
    report.band("rejected chains (worst cell)", "reqs", max_rejected as f64, 0.0, 0.0);
    // The crashes really do kill in-flight work somewhere in the grid.
    let total_killed: u64 = cells.iter().map(|c| c.killed).sum();
    report.band(
        "killed attempts across the grid",
        "reqs",
        total_killed as f64,
        1.0,
        f64::INFINITY,
    );

    // The paper's row: nothing lost at the crash, nothing rebuilt after
    // it, no cold-burst spike — the platform only lost capacity.
    let inc_cold_rebuilt = cells_where(&cells, DriverKind::IncludeOsCold, "cold-only")
        .map(|c| (c.warm_slots_lost + c.prewarm_boots) as f64 + c.idle_gb_seconds)
        .fold(0.0, f64::max);
    report.band(
        "includeos+cold-only state lost/rebuilt",
        "slots+GB·s",
        inc_cold_rebuilt,
        0.0,
        0.0,
    );
    let inc_cold_spike = cells_where(&cells, DriverKind::IncludeOsCold, "cold-only")
        .map(|c| c.cold_spike().abs())
        .fold(0.0, f64::max);
    report.band("includeos+cold-only cold-burst spike", "frac", inc_cold_spike, 0.0, 0.0);
    let inc_cold_p99_ratio = cells_where(&cells, DriverKind::IncludeOsCold, "cold-only")
        .map(|c| c.p99_ms / c.baseline_p99_ms)
        .fold(0.0, f64::max);
    report.band(
        "includeos+cold-only p99 under faults / baseline",
        "ratio",
        inc_cold_p99_ratio,
        0.5,
        2.5,
    );

    // The keep-alive platform, by contrast, loses its pools at the crash
    // and pays a post-restart cold burst (plus renewed GB·s) to rebuild.
    let fixed_slots_lost = cells_where(&cells, DriverKind::DockerWarm, "fixed-600s")
        .map(|c| c.warm_slots_lost)
        .min()
        .unwrap_or(0);
    report.band(
        "docker+fixed-600s warm slots lost at crashes",
        "slots",
        fixed_slots_lost as f64,
        1.0,
        f64::INFINITY,
    );
    let fixed_spike = cells_where(&cells, DriverKind::DockerWarm, "fixed-600s")
        .map(|c| c.cold_spike())
        .fold(f64::INFINITY, f64::min);
    report.band(
        "docker+fixed-600s post-crash cold-burst spike",
        "frac",
        fixed_spike,
        0.005,
        1.0,
    );
    let fixed_waste = cells_where(&cells, DriverKind::DockerWarm, "fixed-600s")
        .map(|c| c.idle_gb_seconds)
        .fold(f64::INFINITY, f64::min);
    report.band(
        "docker+fixed-600s re-warmed residency",
        "GB·s",
        fixed_waste,
        1e-9,
        f64::INFINITY,
    );

    report.note(
        "reading: the cold-only unikernel fleet loses only the crashed capacity — \
         zero warm state drained, zero rebuilt, no cold-burst spike, p99 within \
         noise of the fault-free baseline — while keep-alive policies lose their \
         pools at every crash and re-pay the cold starts (Δcold%) and resident \
         GB·s to rebuild them; killed requests are retried onto surviving nodes \
         (rej = 0), so conservation holds in every cell",
    );
    report
}

/// E14 via the shared experiment config (the `experiment chaos` path).
pub fn chaos(cfg: &ExpConfig) -> Report {
    chaos_with(&chaos_config(cfg))
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Reduced load for the structural unit tests; the full `--quick`
    /// grid (with its paper checks) runs once in `chaos_checks_pass_quick`.
    fn small_cfg() -> ChaosConfig {
        ChaosConfig {
            tenant: TenantConfig {
                functions: 300,
                duration_s: 40.0,
                total_rps: 50.0,
                seed: 0xE14,
                ..Default::default()
            },
            nodes: 6,
            cores_per_node: 8,
            schedulers: vec![SchedPolicy::LeastLoaded],
            host: Host::default(),
            timeseries: false,
        }
    }

    #[test]
    fn chaos_checks_pass_quick() {
        let r = chaos(&ExpConfig::quick());
        assert!(r.all_pass(), "failures: {:#?}", r.failures());
    }

    #[test]
    fn grid_covers_policy_x_scheduler_x_driver_and_conserves() {
        let cfg = small_cfg();
        let cells = chaos_cells(&cfg);
        assert_eq!(cells.len(), 2 * 4);
        let n = cells[0].injected;
        assert!(n > 500, "trace too small: {n}");
        for name in ["cold-only", "fixed-600s", "histogram", "ewma"] {
            for d in [DriverKind::DockerWarm, DriverKind::IncludeOsCold] {
                assert!(
                    cells.iter().any(|c| c.driver == d && c.policy == name),
                    "missing cell {d:?}+{name}"
                );
            }
        }
        for c in &cells {
            assert_eq!(c.injected, n, "{}", c.label());
            assert_eq!(c.injected, c.served + c.rejected, "{}", c.label());
            assert_eq!(c.rejected, 0, "{}", c.label());
            assert_eq!((c.crashes, c.restarts), (2, 2), "{}", c.label());
        }
    }

    #[test]
    fn cold_only_unikernel_is_immune_to_state_loss() {
        let cells = chaos_cells(&small_cfg());
        for c in cells_where(&cells, DriverKind::IncludeOsCold, "cold-only") {
            assert_eq!(c.warm_slots_lost, 0);
            assert_eq!(c.prewarm_boots, 0);
            assert_eq!(c.idle_gb_seconds, 0.0);
            assert_eq!(c.cold_spike(), 0.0, "all-cold cannot spike");
        }
    }

    #[test]
    fn keep_alive_loses_state_and_pays_a_cold_burst() {
        let cells = chaos_cells(&small_cfg());
        for c in cells_where(&cells, DriverKind::DockerWarm, "fixed-600s") {
            assert!(c.warm_slots_lost > 0, "{}", c.label());
            assert!(c.cold_spike() > 0.0, "{}: spike {}", c.label(), c.cold_spike());
            assert!(c.idle_gb_seconds > 0.0);
        }
    }

    #[test]
    fn timeseries_leg_publishes_focus_cell_series() {
        let mut cfg = small_cfg();
        cfg.timeseries = true;
        let r = chaos_with(&cfg);
        assert!(r.all_pass(), "failures: {:#?}", r.failures());
        // The acceptance floor: cold fraction and pool occupancy series
        // for both focus cells, every point vector non-empty.
        for cell in ["docker+fixed-600s", "includeos+cold-only"] {
            for col in ["cold fraction", "pool slots"] {
                assert!(
                    r.timeseries
                        .iter()
                        .any(|t| t.label.starts_with(cell) && t.label.ends_with(col)),
                    "missing series {cell} {col}"
                );
            }
        }
        assert!(r.timeseries.iter().all(|t| !t.points.is_empty()));
        let j = r.to_json("e14", 0.0);
        assert!(j.contains("\"timeseries\":[{"), "report JSON must carry the series");
        // Sampling is pure observation: the rest of the report (every
        // metric row and band) matches the telemetry-off run exactly.
        let off = chaos_with(&small_cfg());
        assert_eq!(off.notes, r.notes);
        assert_eq!(off.events, r.events);
        // Deterministic: same seed, same sparklines, byte for byte.
        assert_eq!(r.render(), chaos_with(&cfg).render());
    }

    #[test]
    fn deterministic_report_per_seed() {
        let a = chaos_with(&small_cfg()).render();
        let b = chaos_with(&small_cfg()).render();
        assert_eq!(a, b);
        let mut other = small_cfg();
        other.tenant.seed = 1;
        let c = chaos_with(&other).render();
        assert_ne!(a, c);
    }
}
