//! E9: the resource-waste / complexity argument of §IV quantified —
//! warm-pool platforms trade idle memory (and monitoring machinery)
//! against cold-start frequency; the cold-only unikernel platform deletes
//! the tradeoff.  Sweeps the idle timeout over Poisson and bursty traces.

use super::ExpConfig;
use crate::fnplat::{run_scenario, DriverKind, Placement, Scenario};
use crate::fnplat::sim::Load;
use crate::net::Site;
use crate::report::Report;
use crate::workload::traces::Trace;

pub struct WastePoint {
    pub label: String,
    pub idle_timeout_s: f64,
    pub p50_ms: f64,
    pub p99_ms: f64,
    pub cold_fraction: f64,
    pub idle_gb_seconds: f64,
    pub monitor_events: u64,
}

fn run_point(
    driver: DriverKind,
    timeout_s: f64,
    trace: Trace,
    seed: u64,
    host: crate::sim::Host,
) -> WastePoint {
    let sc = Scenario {
        driver,
        db: crate::fnplat::DbBackend::Postgres,
        placement: Placement::LocalLab,
        client: Site::LabStockholm,
        server: Site::LabStockholm,
        include_conn_setup: false,
        exec_ms: crate::fnplat::DEFAULT_EXEC_MS,
        idle_timeout_s: timeout_s,
        load: Load::OpenLoop(trace),
        seed,
    };
    let r = run_scenario(&sc, host);
    let mut lat = r.latencies_ns.clone();
    lat.sort_unstable();
    let q = |f: f64| lat[((f * lat.len() as f64) as usize).min(lat.len() - 1)] as f64 / 1e6;
    let total = r.warm_hits + r.cold_starts;
    WastePoint {
        label: format!("{:?}@{timeout_s}s", driver),
        idle_timeout_s: timeout_s,
        p50_ms: q(0.5),
        p99_ms: q(0.99),
        cold_fraction: if total == 0 { 0.0 } else { r.cold_starts as f64 / total as f64 },
        idle_gb_seconds: r.idle_gb_seconds,
        monitor_events: r.monitor_events,
    }
}

pub fn waste_points(cfg: &ExpConfig, bursty: bool) -> Vec<WastePoint> {
    let dur = (cfg.requests as f64 / 20.0).clamp(30.0, 600.0);
    let trace = if bursty {
        Trace::bursty(60.0, 2.0, 20.0, dur, cfg.seed)
    } else {
        Trace::poisson(20.0, dur, cfg.seed)
    };
    let mut pts = Vec::new();
    for timeout in [1.0, 10.0, 30.0, 120.0, 27.0 * 60.0] {
        pts.push(run_point(DriverKind::DockerWarm, timeout, trace.clone(), cfg.seed, cfg.host));
    }
    pts.push(run_point(DriverKind::IncludeOsCold, 0.0, trace, cfg.seed, cfg.host));
    pts.last_mut().unwrap().label = "IncludeOsCold".into();
    pts
}

pub fn waste(cfg: &ExpConfig) -> Report {
    let mut report =
        Report::new("E9: idle-timeout tradeoff — warm-pool waste vs cold-start frequency");
    for bursty in [false, true] {
        let pts = waste_points(cfg, bursty);
        report.note(format!("--- {} trace ---", if bursty { "bursty" } else { "poisson" }));
        for p in &pts {
            report.note(format!(
                "{:<24} p50={:>7.1} ms  p99={:>8.1} ms  cold={:>5.1}%  idle-waste={:>8.2} GB·s  monitor-evts={}",
                p.label,
                p.p50_ms,
                p.p99_ms,
                p.cold_fraction * 100.0,
                p.idle_gb_seconds,
                p.monitor_events
            ));
        }
        let docker = &pts[..pts.len() - 1];
        let cold_only = pts.last().unwrap();

        // Monotone tradeoff: longer timeout => fewer colds, more waste.
        for w in docker.windows(2) {
            report.band(
                &format!("{} cold-frac <= shorter timeout ({})", w[1].label, w[0].label),
                "ratio",
                if w[0].cold_fraction == 0.0 { 0.0 } else { w[1].cold_fraction / w[0].cold_fraction },
                0.0,
                1.02,
            );
            // Waste grows with timeout *approximately*: a longer timeout can
            // convert an expiry (charged `timeout`) into a warm claim
            // (charged the actual gap), so allow a small dip.
            report.band(
                &format!("{} waste >= shorter timeout", w[1].label),
                "ratio",
                if w[0].idle_gb_seconds == 0.0 { 2.0 } else { w[1].idle_gb_seconds / w[0].idle_gb_seconds },
                0.85,
                f64::INFINITY,
            );
        }
        // Cold-only: zero waste, zero monitoring, flat predictable latency.
        report.band("cold-only idle waste", "GB·s", cold_only.idle_gb_seconds, 0.0, 0.0);
        report.band(
            "cold-only p99/p50 predictability",
            "ratio",
            cold_only.p99_ms / cold_only.p50_ms,
            1.0,
            2.0,
        );
        // Warm pool at short timeouts suffers unpredictable tail: its p99
        // (a cold start) dwarfs its p50 (warm hit).
        let short = &docker[0];
        if short.cold_fraction > 0.01 && short.cold_fraction < 0.99 {
            report.band(
                "short-timeout warm-pool tail blowup",
                "p99/p50",
                short.p99_ms / short.p50_ms,
                5.0,
                f64::INFINITY,
            );
        }
    }
    report.note("the cold-only column is the paper's pitch: no waste, no monitoring, flat tail");
    report
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn waste_checks_pass_quick() {
        let r = waste(&ExpConfig::quick());
        assert!(r.all_pass(), "failures: {:#?}", r.failures());
    }

    #[test]
    fn lambda_timeout_wastes_most() {
        let pts = waste_points(&ExpConfig::quick(), false);
        let lambda_like = &pts[pts.len() - 2]; // 27 min timeout
        let short = &pts[0];
        assert!(lambda_like.idle_gb_seconds > short.idle_gb_seconds);
        assert!(lambda_like.cold_fraction <= short.cold_fraction);
    }
}
