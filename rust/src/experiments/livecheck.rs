//! E18 `livecheck`: sim-vs-live cross-validation.
//!
//! One deterministic tenant trace is replayed through both measurement
//! planes (EXPERIMENTS.md "Simulation vs. live measurement"):
//!
//! * the **DES leg** (`sim_report`) runs the platform simulator with a
//!   fixed keep-alive policy — byte-identical per seed, pinned by
//!   `sim_side_is_byte_identical_per_seed`;
//! * the **live leg** serves the same trace through the rebuilt gateway
//!   (S6) into the simulation-mirroring live platform (S29,
//!   `crate::live`) via the open-loop load generator, classifying each
//!   measured request as warm/specialized/cold from the response
//!   annotations.
//!
//! The cross-check: measured per-class latency p50s, rescaled to
//! modeled time, must land inside a tolerance band around the DES
//! prediction, and the live cold fraction must sit within an absolute
//! window of the simulated one.  Band derivation (documented in
//! EXPERIMENTS.md and enforced here by `band_for`):
//!
//! * relative term `REL_TOL` (±50%) — sampling variance of a p50 over a
//!   few hundred requests drawn from the same lognormal-ish step
//!   distributions, plus routing divergence between the two planes'
//!   independent warm-first least-loaded routers;
//! * absolute term: the loopback HTTP overhead model
//!   ([`Frontend::LIVE_LOOPBACK`]) plus `ABS_SLACK_MS` of scheduler
//!   jitter (`thread::sleep` only ever oversleeps; worker wakeups and
//!   queue hops add real milliseconds the DES does not model), both
//!   divided by `time_scale` because measured real latencies are
//!   rescaled to modeled time before comparison.
//!
//! Every live-side metric name starts with `live` — the bench-compare
//! gate (`report::compare`) treats those as verdict-only (pass/fail
//! compared, values informational), mirroring how `events/s` is
//! special-cased, so wall-clock numbers never break byte-level pins.

use crate::fnplat::{DriverKind, DEFAULT_EXEC_MS};
use crate::live::{loadgen, LiveConfig};
use crate::metrics::{BoxStats, Recorder};
use crate::net::{Frontend, Site};
use crate::obs::ObsConfig;
use crate::platform::{
    exact_quantile_ms, run_platform, DriverProfile, FaultPlan, ImageSeeding, PlatformConfig,
    PlatformLoad, PlatformResult, RequestPath, SchedPolicy, SharingMode,
};
use crate::policy::FixedKeepAlive;
use crate::report::Report;
use crate::sim::Host;
use crate::workload::tenants::{TenantConfig, TenantTrace};

/// Relative half-width of the per-class p50 band (see module docs).
pub const REL_TOL: f64 = 0.5;
/// Absolute real-time slack (ms) for scheduler jitter on the live leg.
pub const ABS_SLACK_MS: f64 = 5.0;
/// Absolute window for |live − sim| cold fraction.
pub const COLD_FRACTION_SLACK: f64 = 0.20;
/// A class participates in band checks only with this many sim samples
/// (p50s over a handful of requests are noise, not evidence).
pub const MIN_CLASS_SAMPLES: usize = 5;

/// Full E18 configuration: one cell shape shared verbatim by both legs.
#[derive(Clone, Debug)]
pub struct LivecheckConfig {
    pub nodes: usize,
    pub cores_per_node: u32,
    pub functions: u32,
    /// Universal-worker runtime buckets (S23) — `PerRuntime` sharing so
    /// all three heat classes appear.
    pub runtimes: u32,
    /// Fixed keep-alive window (modeled ns), both planes.
    pub keep_ns: u64,
    pub exec_ms: f64,
    pub duration_s: f64,
    pub total_rps: f64,
    /// Real seconds per modeled second on the live leg (1.0 =
    /// model-faithful; smaller = compressed replay with proportionally
    /// wider bands).
    pub time_scale: f64,
    /// Open-loop sender connections.
    pub senders: usize,
    /// Gateway worker threads.
    pub workers: usize,
    pub host: Host,
    pub seed: u64,
}

impl LivecheckConfig {
    /// The CI cell: ~240 requests over 8 s of trace at real-time pacing.
    pub fn quick() -> LivecheckConfig {
        LivecheckConfig {
            nodes: 2,
            cores_per_node: 8,
            functions: 12,
            runtimes: 4,
            keep_ns: 400_000_000,
            exec_ms: DEFAULT_EXEC_MS,
            duration_s: 8.0,
            total_rps: 30.0,
            time_scale: 1.0,
            senders: 8,
            workers: 8,
            host: Host::default(),
            seed: 0xE18,
        }
    }

    /// The full cell: ~1200 requests over 20 s.
    pub fn full() -> LivecheckConfig {
        LivecheckConfig { duration_s: 20.0, total_rps: 60.0, ..LivecheckConfig::quick() }
    }

    fn tenant(&self) -> TenantConfig {
        TenantConfig {
            functions: self.functions,
            duration_s: self.duration_s,
            total_rps: self.total_rps,
            zipf_exponent: 1.1,
            // Stationary arrivals: the band derivation assumes per-class
            // rates do not drift inside the (short) replay window.
            diurnal_depth: 0.0,
            diurnal_period_s: 60.0,
            bursty_fraction: 0.0,
            seed: self.seed,
        }
    }

    fn live(&self) -> LiveConfig {
        LiveConfig {
            driver: DriverKind::DockerWarm,
            nodes: self.nodes,
            functions: self.functions,
            sharing: SharingMode::PerRuntime { runtimes: self.runtimes },
            keep_ns: self.keep_ns,
            exec_ms: self.exec_ms,
            time_scale: self.time_scale,
            seed: self.seed,
            workers: self.workers,
        }
    }
}

/// The DES leg's platform config: the live cell translated into the
/// simulator's vocabulary.  `Direct` path (the live plane's HTTP hop is
/// accounted in the band's absolute term, not simulated) and
/// `FirstN(nodes)` seeding (the live plane has no image-pull pipeline,
/// so the DES must not charge one).
pub fn sim_config(cfg: &LivecheckConfig, trace: &TenantTrace) -> PlatformConfig {
    PlatformConfig {
        driver: DriverProfile::from_kind(DriverKind::DockerWarm),
        nodes: cfg.nodes,
        cores_per_node: cfg.cores_per_node,
        mem_slots_per_node: cfg.cores_per_node.saturating_mul(8),
        scheduler: SchedPolicy::LeastLoaded,
        functions: cfg.functions,
        exec_ms: cfg.exec_ms,
        mem_bytes_per_slot: DriverKind::DockerWarm.tech().warm_memory_bytes(),
        seeding: ImageSeeding::FirstN(cfg.nodes),
        fabric_gbps: 40.0,
        path: RequestPath::Direct,
        load: PlatformLoad::Tenants(trace.clone()),
        sharing: SharingMode::PerRuntime { runtimes: cfg.runtimes },
        universal_prewarm: 0,
        warmup_keep_ns: cfg.keep_ns,
        exact_latencies: true,
        faults: FaultPlan::default(),
        obs: ObsConfig::default(),
        shards: 1,
        checkpoint_every_ns: 0,
        checkpoint_path: None,
        resume_from: None,
        state_hash: false,
        seed: cfg.seed,
    }
}

/// The tolerance band around a simulated per-class p50 (modeled ms).
/// See the module docs for the derivation of each term.
pub fn band_for(sim_p50_ms: f64, time_scale: f64) -> (f64, f64) {
    let overhead_ms = Frontend::LIVE_LOOPBACK
        .nominal_setup_ms(Site::LabStockholm, Site::LabStockholm);
    let abs = (overhead_ms + ABS_SLACK_MS) / time_scale.max(1e-9);
    ((sim_p50_ms * (1.0 - REL_TOL) - abs).max(0.0), sim_p50_ms * (1.0 + REL_TOL) + abs)
}

fn stats_ns(samples: &[u64]) -> Option<BoxStats> {
    let mut rec = Recorder::new();
    for &ns in samples {
        rec.record_ns("s", ns);
    }
    rec.stats("s")
}

fn stats_ms(samples: &[f64]) -> Option<BoxStats> {
    let mut rec = Recorder::new();
    for &ms in samples {
        rec.record_ms("s", ms);
    }
    rec.stats("s")
}

/// Run the DES leg and assemble the deterministic half of the report.
/// Everything this function adds is byte-identical per seed — the pin
/// the regression test and the bench-compare gate hold.
pub fn sim_report(cfg: &LivecheckConfig) -> (TenantTrace, PlatformResult, Report) {
    let trace = TenantTrace::generate(&cfg.tenant());
    let mut policy = FixedKeepAlive::new(cfg.keep_ns);
    let r = run_platform(&sim_config(cfg, &trace), &mut policy, cfg.host);
    let mut report = Report::new(&format!(
        "E18: livecheck — sim-vs-live cross-validation ({} fns / {} runtimes, \
         {:.0} rps x {:.0} s, keep {} ms, {} nodes)",
        cfg.functions,
        cfg.runtimes,
        cfg.total_rps,
        cfg.duration_s,
        cfg.keep_ns / 1_000_000,
        cfg.nodes
    ));
    for (label, samples) in [
        ("sim warm latency (ms)", &r.warm_latencies_ns),
        ("sim specialized latency (ms)", &r.spec_latencies_ns),
        ("sim cold latency (ms)", &r.cold_latencies_ns),
    ] {
        if let Some(s) = stats_ns(samples) {
            report.add_series(label, s);
        }
    }
    // Deterministic structural gates on the DES side.
    let dispatches = r.warm_hits + r.specializations + r.cold_starts;
    report.band(
        "sim dispatch conservation (warm+spec+cold = served)",
        "bool",
        if dispatches == r.served { 1.0 } else { 0.0 },
        1.0,
        1.0,
    );
    let classes = [r.warm_hits, r.specializations, r.cold_starts]
        .iter()
        .filter(|&&c| c > 0)
        .count();
    report.band("sim heat classes present", "classes", classes as f64, 3.0, 3.0);
    report.band(
        "sim trace fully served",
        "bool",
        if r.served == r.injected { 1.0 } else { 0.0 },
        1.0,
        1.0,
    );
    report.note(format!(
        "sim: {} served — {} warm / {} specialized / {} cold (cold fraction {:.3})",
        r.served,
        r.warm_hits,
        r.specializations,
        r.cold_starts,
        r.cold_fraction()
    ));
    (trace, r, report)
}

/// Append the live leg: serve the same trace through the live stack and
/// band the measured per-class p50s against the DES predictions.  All
/// metric names start with `live` (verdict-only under the bench gate).
pub fn livecheck_with(cfg: &LivecheckConfig) -> Report {
    let (trace, sim, mut report) = sim_report(cfg);

    let srv = match crate::live::start(cfg.live()) {
        Ok(s) => s,
        Err(e) => {
            report.band("live stack started", "live bool", 0.0, 1.0, 1.0);
            report.note(format!("live stack failed to start: {e}"));
            return report;
        }
    };
    let lg = loadgen::run(srv.addr(), &trace, cfg.time_scale, cfg.senders);
    let gw = srv.gateway_stats();
    let accepted = gw.accepted.load(std::sync::atomic::Ordering::Relaxed);
    let served = gw.served.load(std::sync::atomic::Ordering::Relaxed);
    srv.shutdown();

    report.band("live request errors", "live count", lg.errors as f64, 0.0, 0.0);
    // Keep-alive actually amortized connections: far fewer accepts than
    // requests (one persistent connection per sender, plus reconnects).
    report.band(
        "live gateway accepts <= 2x senders",
        "live conns",
        accepted as f64,
        0.0,
        (cfg.senders * 2) as f64,
    );
    report.note(format!(
        "live gateway: {accepted} connections accepted, {served} requests served \
         over {} senders",
        cfg.senders
    ));

    let scale = cfg.time_scale.max(1e-9);
    let sim_classes = [
        ("warm", sim.warm_latencies_ns.len(), sim.warm_quantile_ms(0.5)),
        ("specialized", sim.spec_latencies_ns.len(), sim.spec_quantile_ms(0.5)),
        ("cold", sim.cold_latencies_ns.len(), sim.cold_quantile_ms(0.5)),
    ];
    for (class, sim_n, sim_p50) in sim_classes {
        if sim_n < MIN_CLASS_SAMPLES {
            report.note(format!(
                "class {class}: only {sim_n} sim samples — band skipped (needs {MIN_CLASS_SAMPLES})"
            ));
            continue;
        }
        // Measured real latencies, rescaled to modeled time.
        let modeled: Vec<f64> =
            lg.class_latencies_ms(class).iter().map(|ms| ms / scale).collect();
        report.band(
            &format!("live {class} requests observed"),
            "live count",
            modeled.len() as f64,
            1.0,
            f64::INFINITY,
        );
        if let Some(s) = stats_ms(&modeled) {
            report.add_series(&format!("live {class} latency (modeled ms)"), s);
        }
        if modeled.is_empty() {
            continue;
        }
        let ns: Vec<u64> = modeled.iter().map(|ms| (ms * 1e6) as u64).collect();
        let p50 = exact_quantile_ms(&ns, 0.5);
        let (lo, hi) = band_for(sim_p50, cfg.time_scale);
        report.band(&format!("live {class} p50 vs sim p50"), "live ms", p50, lo, hi);
    }

    let live_total = lg.count("warm") + lg.count("specialized") + lg.count("cold");
    if live_total > 0 {
        let live_cold = lg.count("cold") as f64 / live_total as f64;
        let sim_cold = sim.cold_fraction();
        report.band(
            "live cold fraction vs sim",
            "live frac",
            live_cold,
            (sim_cold - COLD_FRACTION_SLACK).max(0.0),
            sim_cold + COLD_FRACTION_SLACK,
        );
    }
    report.note(format!("live: {}", lg.summary()));
    report.note(
        "reading: the two planes share the pool state machine, routing rule, and \
         step distributions; the live side adds real HTTP, threads, and sleeps — \
         so its numbers are band-gated (metrics prefixed `live`, verdict-only \
         under the bench gate) while the sim side above stays byte-identical",
    );
    report
}

/// E18 entry point used by the CLI: `--quick` selects the CI cell.
pub fn livecheck(quick: bool, time_scale: f64) -> Report {
    let mut cfg = if quick { LivecheckConfig::quick() } else { LivecheckConfig::full() };
    cfg.time_scale = time_scale;
    livecheck_with(&cfg)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A miniature cell for tier-1 tests: 3 s of trace compressed 50x,
    /// so the live leg finishes in ~60 ms of real time.
    fn tiny() -> LivecheckConfig {
        LivecheckConfig {
            duration_s: 3.0,
            total_rps: 30.0,
            time_scale: 0.02,
            senders: 4,
            workers: 4,
            ..LivecheckConfig::quick()
        }
    }

    #[test]
    fn sim_side_is_byte_identical_per_seed() {
        let (_, _, a) = sim_report(&tiny());
        let (_, _, b) = sim_report(&tiny());
        assert_eq!(a.render(), b.render());
        let mut other = tiny();
        other.seed = 1;
        let (_, _, c) = sim_report(&other);
        assert_ne!(a.render(), c.render());
    }

    #[test]
    fn sim_side_gates_pass() {
        let (_, r, report) = sim_report(&tiny());
        assert!(report.all_pass(), "failures: {:#?}", report.failures());
        // All three classes must be present for the bands to mean anything.
        assert!(r.warm_hits > 0 && r.specializations > 0 && r.cold_starts > 0);
    }

    #[test]
    fn band_math_brackets_the_prediction() {
        let (lo, hi) = band_for(10.0, 1.0);
        assert!(lo < 10.0 && 10.0 < hi, "[{lo}, {hi}]");
        assert!(lo >= 0.0);
        // Compressed replays widen the absolute term proportionally.
        let (_, hi_fast) = band_for(10.0, 0.02);
        assert!(hi_fast > hi);
        // Tiny predictions keep a sane floor.
        let (lo0, hi0) = band_for(0.1, 1.0);
        assert!(lo0 == 0.0 && hi0 > 0.1);
    }

    /// Structural end-to-end: the live leg runs, every trace arrival is
    /// measured, annotations parse, and the deterministic (non-`live`)
    /// gates pass.  The tight `live *` bands are exercised strictly by
    /// the CI `livecheck` job at time_scale 1.0 — under `cargo test`
    /// the 50x-compressed replay makes real jitter dominate, so only
    /// the structural live gates are asserted here.
    #[test]
    fn livecheck_end_to_end_structural() {
        let cfg = tiny();
        let report = livecheck_with(&cfg);
        let rendered = report.render();
        assert!(rendered.contains("live"), "{rendered}");
        for b in &report.bands {
            if !b.metric.starts_with("live") {
                assert!(b.pass(), "sim-side gate failed: {}", b.row());
            }
        }
        // Error/conservation live gates are scale-independent.
        let errors = report
            .bands
            .iter()
            .find(|b| b.label == "live request errors")
            .expect("errors band present");
        assert!(errors.pass(), "{}", errors.row());
        assert!(report
            .bands
            .iter()
            .any(|b| b.label.contains("live warm requests observed")));
    }
}
