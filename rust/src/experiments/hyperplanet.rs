//! E17: the "hyperplanet" sweep — the cold-only claim at sharded scale.
//!
//! 1024 nodes (the platform's pool-id ceiling), 10 000 functions, and a
//! streamed Zipf tenant trace of 2x10^8 arrivals **per cell** in full
//! mode — 10^9 aggregate across the five-cell grid — replayed through
//! the S26 sharded platform: each cell partitions its nodes across K
//! accounting shards, routes decisions through the deterministic
//! inter-shard mailbox, and merges per-shard partials into a report that
//! is byte-identical to the single-engine layout (the regression suite
//! pins it).  The grid mirrors E15 — the cold-only unikernel row against
//! the Docker driver under every lifecycle policy on least-loaded
//! placement — because the question is whether the paper's (p99,
//! GB·s-waste) frontier claim survives another 4x in cluster size and
//! two more orders of magnitude in request volume.
//!
//! Unlike E15 (serial cells timing an uncontended engine), the E17 cells
//! run **concurrently** on the sweep runner: with the calendar-queue
//! scheduler and SoA hot path inside each engine and cells in parallel
//! outside, aggregate `events/s` is the headline — promoted to a
//! first-class gated metric (`report/compare.rs` fails a run that loses
//! more than half its throughput against the committed baseline).  The
//! parallel speedup over single-engine execution (Σ cell wall / grid
//! wall) is asserted ≥2x whenever the runner gives the sweep ≥4 threads.
//!
//! Run as `coldfaas hyperplanet` (or `experiment hyperplanet`);
//! `--quick` shrinks the trace (600k arrivals per cell), not the
//! cluster.  Full mode holds one ~3.2 GB trace plus one clone per
//! in-flight cell: budget ~32 GB of RAM and hours of wall time.

use super::fleet::cell_config;
use super::{make_policy, sweep, CheckpointPlan, ExpConfig, POLICY_COUNT};
use crate::fnplat::DriverKind;
use crate::obs::{ObsConfig, TelemetrySeries};
use crate::platform::{
    run_platform, FaultPlan, PlatformConfig, PlatformLoad, RequestPath, SchedPolicy,
};
use crate::report::Report;
use crate::sim::Host;
use crate::workload::tenants::{TenantConfig, TenantTrace};

/// Full E17 configuration: the tenant trace, the cluster shape, and the
/// accounting-shard count every cell runs under.
#[derive(Clone, Debug)]
pub struct HyperplanetConfig {
    pub tenant: TenantConfig,
    pub nodes: usize,
    pub cores_per_node: u32,
    /// Accounting shards per cell (S26).  Any value produces the same
    /// bytes; 8 keeps the per-shard finalize workers busy at 1024 nodes.
    pub shards: usize,
    pub host: Host,
    pub obs: ObsConfig,
    /// S27: per-cell snapshot/resume plan (inert by default).  A killed
    /// grid relaunched with `resume` picks every cell up from its last
    /// barrier file and still produces byte-identical reports.
    pub checkpoint: CheckpointPlan,
}

/// Derive an E17 configuration from the shared experiment config.  The
/// default request count (10 000) targets the full 2x10^8-arrivals
/// cells (10^9 aggregate over the grid); smaller counts (`--quick`'s
/// 1 500) scale linearly to a CI-sized smoke (600k per cell).  The
/// cluster stays at 1024 nodes x 10k functions in both.
pub fn hyperplanet_config(cfg: &ExpConfig) -> HyperplanetConfig {
    let arrivals = if cfg.requests >= ExpConfig::default().requests {
        cfg.requests.saturating_mul(20_000)
    } else {
        cfg.requests.saturating_mul(400).max(100_000)
    };
    let duration_s = 600.0;
    HyperplanetConfig {
        tenant: TenantConfig {
            functions: 10_000,
            duration_s,
            total_rps: arrivals as f64 / duration_s,
            seed: cfg.seed,
            ..Default::default()
        },
        nodes: 1024,
        cores_per_node: 8,
        shards: 8,
        host: cfg.host,
        obs: ObsConfig::default(),
        checkpoint: cfg.checkpoint.clone(),
    }
}

/// One (driver, policy) cell of the hyperplanet sweep.
#[derive(Clone, Debug)]
pub struct HyperplanetCell {
    pub driver: DriverKind,
    pub policy: String,
    pub requests: u64,
    pub p50_ms: f64,
    pub p99_ms: f64,
    pub cold_fraction: f64,
    pub idle_gb_seconds: f64,
    pub monitor_events: u64,
    /// Engine events the cell's run processed (deterministic per seed).
    pub events: u64,
    /// Accounting shards the cell actually ran with.
    pub shards: usize,
    /// Messages routed through the cell's inter-shard mailbox.
    pub shard_msgs: u64,
    /// Wall-clock seconds of the cell's own run (not deterministic; cells
    /// run concurrently, so these overlap and their *sum* estimates the
    /// single-engine serial cost).
    pub wall_s: f64,
    /// Interval time-series (S25); `None` unless telemetry was enabled.
    pub telemetry: Option<TelemetrySeries>,
    /// On the Pareto frontier of (p99 latency, idle waste)?
    pub on_frontier: bool,
}

impl HyperplanetCell {
    pub fn label(&self) -> String {
        let d = match self.driver {
            DriverKind::DockerWarm => "docker",
            DriverKind::IncludeOsCold => "includeos",
        };
        format!("{d}+{}", self.policy)
    }

    /// The cell's own engine events per second of its own wall clock.
    pub fn events_per_s(&self) -> f64 {
        if self.wall_s <= 0.0 {
            0.0
        } else {
            self.events as f64 / self.wall_s
        }
    }
}

/// An E15 planet cell config (itself `fleet::cell_config`, so the
/// cluster shape cannot drift from E12–E15) at hyperplanet scale, with
/// the S26 shard count applied.
pub(crate) fn cell_platform_config(
    cfg: &HyperplanetConfig,
    driver: DriverKind,
    trace: &TenantTrace,
) -> PlatformConfig {
    PlatformConfig {
        path: RequestPath::Direct,
        load: PlatformLoad::TenantsStreamed(trace.clone()),
        shards: cfg.shards,
        ..cell_config(
            cfg.nodes,
            cfg.cores_per_node,
            &cfg.tenant,
            driver,
            SchedPolicy::LeastLoaded,
            trace,
            FaultPlan::default(),
            cfg.obs.clone(),
        )
    }
}

fn mark_frontier(cells: &mut [HyperplanetCell]) {
    super::mark_pareto2(
        cells,
        |c| (c.p99_ms, c.idle_gb_seconds),
        |c, on| c.on_frontier = on,
    );
}

/// Run the hyperplanet grid over one generated trace, concurrently on
/// the shared sweep runner.  Returns the cells plus the grid's wall time
/// (the denominator of the aggregate events/s headline).
pub fn hyperplanet_cells(cfg: &HyperplanetConfig) -> (Vec<HyperplanetCell>, f64) {
    let trace = TenantTrace::generate(&cfg.tenant);
    let mut specs: Vec<(DriverKind, usize)> = vec![(DriverKind::IncludeOsCold, 0)];
    for policy_idx in 0..POLICY_COUNT {
        specs.push((DriverKind::DockerWarm, policy_idx));
    }
    // Cells run CONCURRENTLY (unlike E15's deliberately serial grid):
    // the headline here is aggregate throughput of the sharded engines,
    // so the grid wall clock is the honest denominator and each cell's
    // own wall clock estimates the serial (single-engine) cost.
    #[allow(clippy::disallowed_methods)]
    let grid_started = std::time::Instant::now(); // detlint: allow(DL001) informational grid wall clock
    let mut cells = sweep::run_cells(&specs, |_, &(driver, policy_idx)| {
        let mut policy = make_policy(policy_idx, cfg.tenant.functions);
        let mut pcfg = cell_platform_config(cfg, driver, &trace);
        cfg.checkpoint.apply(&mut pcfg, "e17", &format!("{driver:?}-{}", policy.name()));
        #[allow(clippy::disallowed_methods)]
        let t0 = std::time::Instant::now(); // detlint: allow(DL001) informational per-cell wall clock
        let r = run_platform(&pcfg, policy.as_mut(), cfg.host);
        HyperplanetCell {
            driver,
            policy: policy.name(),
            requests: r.requests,
            p50_ms: r.quantile_ms(0.5),
            p99_ms: r.quantile_ms(0.99),
            cold_fraction: r.cold_fraction(),
            idle_gb_seconds: r.idle_gb_seconds,
            monitor_events: r.monitor_events,
            events: r.events,
            shards: r.shards,
            shard_msgs: r.shard_msgs,
            wall_s: t0.elapsed().as_secs_f64(),
            telemetry: r.telemetry,
            on_frontier: false,
        }
    });
    let grid_wall_s = grid_started.elapsed().as_secs_f64();
    mark_frontier(&mut cells);
    (cells, grid_wall_s)
}

/// E17 report over an explicit configuration (the CLI subcommand path).
pub fn hyperplanet_with(cfg: &HyperplanetConfig) -> Report {
    let mut report = Report::new(&format!(
        "E17: hyperplanet sweep — {} nodes x {} fns x {} shards, ~{:.1}M streamed \
         requests per cell (Zipf {:.1}, {:.0} rps, {:.0} s), cells in parallel",
        cfg.nodes,
        cfg.tenant.functions,
        cfg.shards,
        cfg.tenant.total_rps * cfg.tenant.duration_s / 1e6,
        cfg.tenant.zipf_exponent,
        cfg.tenant.total_rps,
        cfg.tenant.duration_s
    ));
    let threads = sweep::sweep_threads(1 + POLICY_COUNT);
    let (cells, grid_wall_s) = hyperplanet_cells(cfg);

    // S25/S26 self-profile: total engine events are deterministic per
    // seed (compared exactly by the bench gate); aggregate events/s over
    // the grid's wall clock is the first-class throughput metric the
    // compare gate tracks within `EVENTS_PER_S_TOL`.
    let total_events: u64 = cells.iter().map(|c| c.events).sum();
    let aggregate_eps = if grid_wall_s > 0.0 { total_events as f64 / grid_wall_s } else { 0.0 };
    report.set_profile(total_events, aggregate_eps);
    for c in &cells {
        if let Some(t) = &c.telemetry {
            for (name, points) in t.rows() {
                report.add_timeseries(&format!("{} {name}", c.label()), t.interval_s(), points);
            }
        }
    }

    report.note(format!(
        "{:<22} {:>10} {:>8} {:>9} {:>7} {:>12} {:>12} {:>11} {:>11}  {}",
        "driver+policy",
        "reqs",
        "p50 ms",
        "p99 ms",
        "cold%",
        "waste GB·s",
        "events",
        "shard msgs",
        "Mevents/s",
        "frontier"
    ));
    for c in &cells {
        report.note(format!(
            "{:<22} {:>10} {:>8.2} {:>9.1} {:>6.1}% {:>12.2} {:>12} {:>11} {:>11.2}  {}",
            c.label(),
            c.requests,
            c.p50_ms,
            c.p99_ms,
            c.cold_fraction * 100.0,
            c.idle_gb_seconds,
            c.events,
            c.shard_msgs,
            c.events_per_s() / 1e6,
            if c.on_frontier { "*" } else { "" }
        ));
    }

    let inc_cold = cells
        .iter()
        .find(|c| c.driver == DriverKind::IncludeOsCold && c.policy == "cold-only")
        .expect("includeos cold-only cell");

    // Scale actually reached: every cell ran the full cluster, the full
    // trace, and the sharded accounting plane.
    report.band("nodes simulated", "nodes", cfg.nodes as f64, 1024.0, f64::INFINITY);
    let reqs = cells[0].requests;
    let all_equal = cells.iter().all(|c| c.requests == reqs);
    report.band(
        "all cells replayed the full trace",
        "bool",
        if all_equal { 1.0 } else { 0.0 },
        1.0,
        1.0,
    );
    let all_sharded = cells.iter().all(|c| c.shards == cfg.shards && c.shard_msgs > 0);
    report.band(
        "all cells ran the sharded accounting plane",
        "bool",
        if all_sharded { 1.0 } else { 0.0 },
        1.0,
        1.0,
    );
    // The paper's lifecycle stays free with 10k tenants on 1024 nodes.
    report.band("includeos+cold-only idle waste", "GB·s", inc_cold.idle_gb_seconds, 0.0, 0.0);
    report.band(
        "includeos+cold-only monitor events",
        "events",
        inc_cold.monitor_events as f64,
        0.0,
        0.0,
    );
    // The headline re-check at 4x the nodes and ~100x the requests.
    report.band(
        "includeos+cold-only on (p99, waste) frontier",
        "bool",
        if inc_cold.on_frontier { 1.0 } else { 0.0 },
        1.0,
        1.0,
    );
    let fixed = cells
        .iter()
        .find(|c| c.driver == DriverKind::DockerWarm && c.policy == "fixed-600s")
        .expect("docker fixed cell");
    report.band("docker+fixed-600s idle waste", "GB·s", fixed.idle_gb_seconds, 1e-6, f64::INFINITY);
    // Throughput: aggregate over the grid wall clock (sanity floor — the
    // machine-comparable regression check is the bench compare gate), and
    // the parallel speedup over single-engine serial execution.  The ≥2x
    // floor only arms when the sweep actually got ≥4 worker threads; a
    // starved runner still reports the number informationally.
    report.band("aggregate throughput (grid)", "events/s", aggregate_eps, 1.0, f64::INFINITY);
    let serial_wall_s: f64 = cells.iter().map(|c| c.wall_s).sum();
    let speedup = if grid_wall_s > 0.0 { serial_wall_s / grid_wall_s } else { 0.0 };
    let speedup_floor = if threads >= 4 { 2.0 } else { 0.0 };
    report.band(
        "parallel speedup over single engine (Σ cell wall / grid wall)",
        "x",
        speedup,
        speedup_floor,
        f64::INFINITY,
    );

    report.note(
        "reading: the S26 sharded accounting plane (contiguous node partition, \
         deterministic mailbox, barrier-drained partials) makes every cell's report \
         byte-identical to the single-engine layout while the calendar-queue + SoA \
         hot path chews each cell and the sweep runner overlaps cells — the \
         cold-only unikernel row still holds the (p99, waste) frontier with zero \
         idle waste and zero monitor events at 1024 nodes",
    );
    report
}

/// E17 via the shared experiment config (the `experiment hyperplanet`
/// path).
pub fn hyperplanet(cfg: &ExpConfig) -> Report {
    hyperplanet_with(&hyperplanet_config(cfg))
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Debug-build-sized hyperplanet: the full 1024-node grid runs in
    /// release via `coldfaas hyperplanet` / the e17 bench; unit tests
    /// keep the shape (sharded cells, parallel grid), not the scale.
    fn tiny_cfg() -> HyperplanetConfig {
        HyperplanetConfig {
            tenant: TenantConfig {
                functions: 400,
                duration_s: 30.0,
                total_rps: 150.0,
                seed: 0xE17,
                ..Default::default()
            },
            nodes: 48,
            cores_per_node: 4,
            shards: 5,
            host: Host::default(),
            obs: ObsConfig::default(),
            checkpoint: CheckpointPlan::default(),
        }
    }

    #[test]
    fn hyperplanet_config_targets_full_scale() {
        let full = hyperplanet_config(&ExpConfig::default());
        assert_eq!(full.nodes, 1024);
        assert_eq!(full.tenant.functions, 10_000);
        assert!(full.shards >= 2, "full config must exercise real sharding");
        let arrivals = full.tenant.total_rps * full.tenant.duration_s;
        assert!(
            arrivals >= 1e7,
            "full hyperplanet must be >=1e7 requests per cell: {arrivals}"
        );
        assert!(
            arrivals * (1.0 + POLICY_COUNT as f64) >= 1e9,
            "full grid must aggregate >=1e9 requests: {arrivals} per cell"
        );
        let quick = hyperplanet_config(&ExpConfig::quick());
        assert_eq!(quick.nodes, 1024, "--quick shrinks the trace, not the cluster");
        let quick_arrivals = quick.tenant.total_rps * quick.tenant.duration_s;
        assert!(
            (100_000.0..5_000_000.0).contains(&quick_arrivals),
            "quick cells must stay CI-sized: {quick_arrivals}"
        );
    }

    #[test]
    fn grid_replays_full_trace_sharded_and_cold_only_stays_free() {
        let cfg = tiny_cfg();
        let trace_len = TenantTrace::generate(&cfg.tenant).len() as u64;
        let (cells, grid_wall_s) = hyperplanet_cells(&cfg);
        assert_eq!(cells.len(), 1 + POLICY_COUNT);
        assert!(grid_wall_s > 0.0);
        for c in &cells {
            assert_eq!(c.requests, trace_len, "{}", c.label());
            assert!(c.events > 0, "{}", c.label());
            assert_eq!(c.shards, cfg.shards, "{}", c.label());
            assert!(c.shard_msgs > 0, "{}", c.label());
        }
        let inc = cells
            .iter()
            .find(|c| c.driver == DriverKind::IncludeOsCold)
            .expect("includeos row");
        assert_eq!(inc.policy, "cold-only");
        assert_eq!(inc.idle_gb_seconds, 0.0);
        assert_eq!(inc.monitor_events, 0);
        assert!((inc.cold_fraction - 1.0).abs() < 1e-12);
        assert!(
            cells
                .iter()
                .any(|c| c.driver == DriverKind::IncludeOsCold && c.on_frontier),
            "zero-waste row must sit on the (p99, waste) frontier"
        );
    }

    #[test]
    fn sharded_cells_match_the_single_engine_layout_bitwise() {
        // The whole point of S26: K shards and K=1 produce the same
        // bytes, cell for cell.
        let sharded = tiny_cfg();
        let mut single = tiny_cfg();
        single.shards = 1;
        let (a, _) = hyperplanet_cells(&sharded);
        let (b, _) = hyperplanet_cells(&single);
        for (s, u) in a.iter().zip(&b) {
            assert_eq!(s.label(), u.label());
            assert_eq!(s.requests, u.requests);
            assert_eq!(s.p50_ms.to_bits(), u.p50_ms.to_bits(), "{}", s.label());
            assert_eq!(s.p99_ms.to_bits(), u.p99_ms.to_bits(), "{}", s.label());
            assert_eq!(s.cold_fraction.to_bits(), u.cold_fraction.to_bits());
            assert_eq!(s.idle_gb_seconds.to_bits(), u.idle_gb_seconds.to_bits());
            assert_eq!(s.monitor_events, u.monitor_events);
            assert_eq!(s.events, u.events, "sharding must not add engine events");
            assert_eq!(s.shard_msgs, u.shard_msgs, "posting is shard-count independent");
            assert_eq!(s.on_frontier, u.on_frontier);
        }
    }

    #[test]
    fn deterministic_cells_per_seed_modulo_wall_clock() {
        let run = || {
            hyperplanet_cells(&tiny_cfg())
                .0
                .into_iter()
                .map(|c| {
                    (
                        c.label(),
                        c.requests,
                        c.p99_ms.to_bits(),
                        c.idle_gb_seconds.to_bits(),
                        c.events,
                        c.shard_msgs,
                        c.on_frontier,
                    )
                })
                .collect::<Vec<_>>()
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn killed_grid_resumes_cell_for_cell_bitwise() {
        // S27 end to end at grid scope: run once writing per-cell
        // snapshots, then relaunch with resume — every cell restores its
        // last barrier, replays the tail, and reports identical bytes.
        let fingerprint = |cells: &[HyperplanetCell]| {
            cells
                .iter()
                .map(|c| {
                    (
                        c.label(),
                        c.requests,
                        c.p99_ms.to_bits(),
                        c.idle_gb_seconds.to_bits(),
                        c.events,
                        c.shard_msgs,
                    )
                })
                .collect::<Vec<_>>()
        };
        let dir = std::env::temp_dir().join(format!("coldfaas-grid-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let reference = fingerprint(&hyperplanet_cells(&tiny_cfg()).0);
        let mut writer = tiny_cfg();
        writer.checkpoint.dir = Some(dir.to_string_lossy().into_owned());
        writer.checkpoint.state_hash = true;
        assert_eq!(fingerprint(&hyperplanet_cells(&writer).0), reference);
        let mut resumer = writer.clone();
        resumer.checkpoint.resume = true;
        assert_eq!(fingerprint(&hyperplanet_cells(&resumer).0), reference);
        // Every cell left exactly one snapshot file behind.
        let files = std::fs::read_dir(&dir)
            .unwrap()
            .filter_map(|e| e.ok())
            .filter(|e| e.file_name().to_string_lossy().ends_with(".ckpt"))
            .count();
        assert_eq!(files, 1 + POLICY_COUNT);
    }
}
