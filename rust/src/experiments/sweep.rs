//! Parallel sweep runner (std-only) shared by the grid experiments
//! (E12 policies, E13 fleet, E14 chaos; E15 planet uses it pinned to
//! one thread so its events/s headline times uncontended cells).
//!
//! Every grid cell is self-contained — it builds its own config, policy,
//! and RNG from its own seed, and `run_platform` touches no shared state
//! — so cells can run on worker threads with no coordination beyond a
//! work-stealing cursor.  Results land in their cell's slot, so the
//! output order (and therefore every rendered report) is byte-identical
//! to serial execution; only wall-clock time changes.
//!
//! Thread count comes from `COLDFAAS_SWEEP_THREADS` when set (`1` forces
//! serial execution), else from `std::thread::available_parallelism`.
//! A malformed value is a hard error, not a silent fallback: a typo like
//! `COLDFAAS_SWEEP_THREADS=O1` silently re-parallelizing a run that was
//! meant to be serial is exactly the failure mode the strict-CLI policy
//! exists to rule out.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Parse an explicit `COLDFAAS_SWEEP_THREADS` value: `Ok(n)` for a
/// positive integer, `Err` (with the reason) for anything else.  Pure so
/// the error paths are testable without mutating the process environment.
fn parse_sweep_threads(raw: &str) -> Result<usize, String> {
    match raw.trim().parse::<usize>() {
        Ok(0) => Err(format!(
            "COLDFAAS_SWEEP_THREADS must be >= 1, got {raw:?} \
             (use 1 to force serial execution, or unset it)"
        )),
        Ok(t) => Ok(t),
        Err(e) => Err(format!(
            "COLDFAAS_SWEEP_THREADS must be a positive integer, got {raw:?}: {e} \
             (unset it to use the machine's available parallelism)"
        )),
    }
}

/// Worker threads a sweep may use: the env override, else the machine's
/// available parallelism, never more than one per cell.  Panics on a
/// malformed override — degrading quietly would let a typo change which
/// runs are serial.
pub fn sweep_threads(cells: usize) -> usize {
    let configured = match std::env::var("COLDFAAS_SWEEP_THREADS") {
        Ok(v) => parse_sweep_threads(&v).unwrap_or_else(|e| panic!("{e}")),
        Err(std::env::VarError::NotPresent) => {
            std::thread::available_parallelism().map_or(1, |p| p.get())
        }
        Err(e) => panic!("COLDFAAS_SWEEP_THREADS is not readable: {e}"),
    };
    configured.min(cells.max(1))
}

/// Run `run` over every cell on up to `threads` scoped worker threads,
/// collecting results in cell order.  `threads <= 1` degenerates to the
/// plain serial loop.  A panicking cell propagates after the scope joins
/// (a failed paper check inside a cell still fails the sweep).
pub fn run_cells_with<C: Sync, R: Send>(
    threads: usize,
    cells: &[C],
    run: impl Fn(usize, &C) -> R + Sync,
) -> Vec<R> {
    if threads <= 1 || cells.len() <= 1 {
        return cells.iter().enumerate().map(|(i, c)| run(i, c)).collect();
    }
    let next = AtomicUsize::new(0);
    let out: Mutex<Vec<Option<R>>> = Mutex::new((0..cells.len()).map(|_| None).collect());
    std::thread::scope(|scope| {
        for _ in 0..threads.min(cells.len()) {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= cells.len() {
                    break;
                }
                let r = run(i, &cells[i]);
                out.lock().expect("no poisoned sweep slot")[i] = Some(r);
            });
        }
    });
    out.into_inner()
        .expect("sweep scope joined")
        .into_iter()
        .map(|r| r.expect("every cell ran"))
        .collect()
}

/// Run `run` over every cell with the default thread count, results in
/// cell order (byte-identical to a serial loop).
pub fn run_cells<C: Sync, R: Send>(cells: &[C], run: impl Fn(usize, &C) -> R + Sync) -> Vec<R> {
    run_cells_with(sweep_threads(cells.len()), cells, run)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn results_are_in_cell_order() {
        let cells: Vec<u64> = (0..100).collect();
        let got = run_cells_with(8, &cells, |i, &c| {
            assert_eq!(i as u64, c);
            c * 3
        });
        assert_eq!(got, (0..100).map(|c| c * 3).collect::<Vec<u64>>());
    }

    #[test]
    fn parallel_matches_serial_exactly() {
        // A cell computation with per-cell deterministic "randomness":
        // the parallel schedule must not leak into the results.
        let cells: Vec<u64> = (0..37).collect();
        let work = |_: usize, &seed: &u64| {
            let mut rng = crate::sim::Rng::new(seed);
            (0..100).map(|_| rng.next_u64()).fold(0u64, u64::wrapping_add)
        };
        let serial = run_cells_with(1, &cells, work);
        for threads in [2, 4, 16] {
            assert_eq!(run_cells_with(threads, &cells, work), serial, "{threads} threads");
        }
    }

    #[test]
    fn single_cell_and_empty_sweeps_work() {
        assert_eq!(run_cells_with(4, &[7u64], |_, &c| c + 1), vec![8]);
        let empty: Vec<u64> = Vec::new();
        assert_eq!(run_cells_with(4, &empty, |_, &c| c), Vec::<u64>::new());
    }

    #[test]
    fn thread_count_respects_env_floor_and_cells() {
        // Never more threads than cells, never fewer than one.
        assert_eq!(sweep_threads(1), 1);
        assert!(sweep_threads(64) >= 1);
    }

    #[test]
    fn explicit_thread_overrides_parse_strictly() {
        assert_eq!(parse_sweep_threads("1"), Ok(1));
        assert_eq!(parse_sweep_threads(" 8 "), Ok(8));
        // Malformed or zero values are hard errors, never silent
        // fallbacks to available parallelism.
        assert!(parse_sweep_threads("0").is_err());
        assert!(parse_sweep_threads("O1").is_err());
        assert!(parse_sweep_threads("").is_err());
        assert!(parse_sweep_threads("-2").is_err());
        assert!(parse_sweep_threads("4 threads").is_err());
        let err = parse_sweep_threads("nope").unwrap_err();
        assert!(err.contains("COLDFAAS_SWEEP_THREADS"), "{err}");
    }
}
