//! Parallel sweep runner (std-only) shared by the grid experiments
//! (E12 policies, E13 fleet, E14 chaos; E15 planet uses it pinned to
//! one thread so its events/s headline times uncontended cells).
//!
//! Every grid cell is self-contained — it builds its own config, policy,
//! and RNG from its own seed, and `run_platform` touches no shared state
//! — so cells can run on worker threads with no coordination beyond a
//! work-stealing cursor.  Results land in their cell's slot, so the
//! output order (and therefore every rendered report) is byte-identical
//! to serial execution; only wall-clock time changes.
//!
//! Thread count comes from `COLDFAAS_SWEEP_THREADS` when set (`1` forces
//! serial execution), else from `std::thread::available_parallelism`.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Worker threads a sweep may use: the env override, else the machine's
/// available parallelism, never more than one per cell.
pub fn sweep_threads(cells: usize) -> usize {
    let configured = std::env::var("COLDFAAS_SWEEP_THREADS")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .filter(|&t| t >= 1)
        .unwrap_or_else(|| std::thread::available_parallelism().map_or(1, |p| p.get()));
    configured.min(cells.max(1))
}

/// Run `run` over every cell on up to `threads` scoped worker threads,
/// collecting results in cell order.  `threads <= 1` degenerates to the
/// plain serial loop.  A panicking cell propagates after the scope joins
/// (a failed paper check inside a cell still fails the sweep).
pub fn run_cells_with<C: Sync, R: Send>(
    threads: usize,
    cells: &[C],
    run: impl Fn(usize, &C) -> R + Sync,
) -> Vec<R> {
    if threads <= 1 || cells.len() <= 1 {
        return cells.iter().enumerate().map(|(i, c)| run(i, c)).collect();
    }
    let next = AtomicUsize::new(0);
    let out: Mutex<Vec<Option<R>>> = Mutex::new((0..cells.len()).map(|_| None).collect());
    std::thread::scope(|scope| {
        for _ in 0..threads.min(cells.len()) {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= cells.len() {
                    break;
                }
                let r = run(i, &cells[i]);
                out.lock().expect("no poisoned sweep slot")[i] = Some(r);
            });
        }
    });
    out.into_inner()
        .expect("sweep scope joined")
        .into_iter()
        .map(|r| r.expect("every cell ran"))
        .collect()
}

/// Run `run` over every cell with the default thread count, results in
/// cell order (byte-identical to a serial loop).
pub fn run_cells<C: Sync, R: Send>(cells: &[C], run: impl Fn(usize, &C) -> R + Sync) -> Vec<R> {
    run_cells_with(sweep_threads(cells.len()), cells, run)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn results_are_in_cell_order() {
        let cells: Vec<u64> = (0..100).collect();
        let got = run_cells_with(8, &cells, |i, &c| {
            assert_eq!(i as u64, c);
            c * 3
        });
        assert_eq!(got, (0..100).map(|c| c * 3).collect::<Vec<u64>>());
    }

    #[test]
    fn parallel_matches_serial_exactly() {
        // A cell computation with per-cell deterministic "randomness":
        // the parallel schedule must not leak into the results.
        let cells: Vec<u64> = (0..37).collect();
        let work = |_: usize, &seed: &u64| {
            let mut rng = crate::sim::Rng::new(seed);
            (0..100).map(|_| rng.next_u64()).fold(0u64, u64::wrapping_add)
        };
        let serial = run_cells_with(1, &cells, work);
        for threads in [2, 4, 16] {
            assert_eq!(run_cells_with(threads, &cells, work), serial, "{threads} threads");
        }
    }

    #[test]
    fn single_cell_and_empty_sweeps_work() {
        assert_eq!(run_cells_with(4, &[7u64], |_, &c| c + 1), vec![8]);
        let empty: Vec<u64> = Vec::new();
        assert_eq!(run_cells_with(4, &empty, |_, &c| c), Vec::<u64>::new());
    }

    #[test]
    fn thread_count_respects_env_floor_and_cells() {
        // Never more threads than cells, never fewer than one.
        assert_eq!(sweep_threads(1), 1);
        assert!(sweep_threads(64) >= 1);
    }
}
