//! E15: the "planet" sweep — the cold-only claim at the ROADMAP's scale.
//!
//! 256 nodes, 10 000 functions, a multi-million-request Zipf tenant
//! trace, replayed through the indexed platform layer with the arrivals
//! *streamed* into the engine ([`PlatformLoad::TenantsStreamed`]) so live
//! simulator state tracks in-flight work, not trace length.  The grid is
//! deliberately narrow — the cold-only unikernel row against the Docker
//! driver under every lifecycle policy, all on least-loaded placement —
//! because the question at this scale is not which scheduler wins (E13
//! answered that) but whether the paper's frontier claim survives three
//! orders of magnitude more warm-pool state, and how fast the simulator
//! itself chews through it.  Each cell reports engine events per second
//! of wall time: the tentpole metric for the warm-index/deadline-queue
//! hot-path work (SOCK and SEUSS both argue lookup structure, not raw
//! start latency, is what dominates at scale — the same holds for the
//! DES itself).  Unlike the E12–E14 grids, the cells run serially so
//! that number is uncontended wall time, not scheduler time-slicing.
//!
//! Run as `coldfaas planet` (or `coldfaas experiment planet`); `--quick`
//! shrinks the trace, not the cluster.

use super::fleet::cell_config;
use super::{make_policy, sweep, CheckpointPlan, ExpConfig, POLICY_COUNT};
use crate::fnplat::DriverKind;
use crate::obs::{ObsConfig, TelemetrySeries};
use crate::platform::{
    run_platform, FaultPlan, PlatformConfig, PlatformLoad, RequestPath, SchedPolicy,
};
use crate::report::Report;
use crate::sim::Host;
use crate::workload::tenants::{TenantConfig, TenantTrace};

/// Full E15 configuration: the tenant trace plus the cluster shape.
#[derive(Clone, Debug)]
pub struct PlanetConfig {
    pub tenant: TenantConfig,
    pub nodes: usize,
    pub cores_per_node: u32,
    pub host: Host,
    /// Observability (S25) applied to every cell.  Time-series sampling
    /// is virtual-time pure, so enabling it leaves every metric
    /// untouched; tracing at planet scale wants `trace_window_only`.
    pub obs: ObsConfig,
    /// S27: per-cell snapshot/resume plan (inert by default).
    pub checkpoint: CheckpointPlan,
}

/// Derive an E15 configuration from the shared experiment config.  The
/// trace targets `requests x 120` arrivals: the default 10 000 yields a
/// ≥1.2M-request replay per cell (comfortably past the 1M mark even
/// with thinning noise); `--quick` (1 500) a ~180k smoke that CI can
/// afford.  The cluster stays at 256 nodes in both.
pub fn planet_config(cfg: &ExpConfig) -> PlanetConfig {
    let arrivals = cfg.requests.saturating_mul(120).max(50_000);
    let duration_s = 300.0;
    PlanetConfig {
        tenant: TenantConfig {
            functions: 10_000,
            duration_s,
            total_rps: arrivals as f64 / duration_s,
            seed: cfg.seed,
            ..Default::default()
        },
        nodes: 256,
        cores_per_node: 8,
        host: cfg.host,
        obs: ObsConfig::default(),
        checkpoint: cfg.checkpoint.clone(),
    }
}

/// One (driver, policy) cell of the planet sweep.
#[derive(Clone, Debug)]
pub struct PlanetCell {
    pub driver: DriverKind,
    pub policy: String,
    pub requests: u64,
    pub p50_ms: f64,
    pub p99_ms: f64,
    pub cold_fraction: f64,
    pub idle_gb_seconds: f64,
    pub monitor_events: u64,
    /// Engine events the cell's run processed.
    pub events: u64,
    /// Wall-clock seconds the cell's run took (not deterministic).
    pub wall_s: f64,
    /// Interval time-series (S25); `None` unless telemetry was enabled.
    pub telemetry: Option<TelemetrySeries>,
    /// On the Pareto frontier of (p99 latency, idle waste)?
    pub on_frontier: bool,
}

impl PlanetCell {
    pub fn label(&self) -> String {
        let d = match self.driver {
            DriverKind::DockerWarm => "docker",
            DriverKind::IncludeOsCold => "includeos",
        };
        format!("{d}+{}", self.policy)
    }

    /// Simulator throughput: engine events per wall-clock second.
    pub fn events_per_s(&self) -> f64 {
        if self.wall_s <= 0.0 {
            0.0
        } else {
            self.events as f64 / self.wall_s
        }
    }
}

/// An E13 fleet cell (`fleet::cell_config`, so the cluster shape cannot
/// drift from E12–E14) with two planet-specific overrides: the
/// placement-only request path — the cell measures the platform's
/// routing and pool machinery, not a shared single-frontend gateway
/// that would serialize a 256-node fleet behind one box — and the
/// streamed load.
pub(crate) fn cell_platform_config(
    cfg: &PlanetConfig,
    driver: DriverKind,
    trace: &TenantTrace,
) -> PlatformConfig {
    PlatformConfig {
        path: RequestPath::Direct,
        load: PlatformLoad::TenantsStreamed(trace.clone()),
        ..cell_config(
            cfg.nodes,
            cfg.cores_per_node,
            &cfg.tenant,
            driver,
            SchedPolicy::LeastLoaded,
            trace,
            FaultPlan::default(),
            cfg.obs.clone(),
        )
    }
}

/// Mark Pareto-optimal cells in the (p99, waste) plane.
fn mark_frontier(cells: &mut [PlanetCell]) {
    super::mark_pareto2(
        cells,
        |c| (c.p99_ms, c.idle_gb_seconds),
        |c, on| c.on_frontier = on,
    );
}

/// Run the planet grid over one generated trace: the includeos cold-only
/// row plus the Docker driver under every lifecycle policy.
pub fn planet_cells(cfg: &PlanetConfig) -> Vec<PlanetCell> {
    let trace = TenantTrace::generate(&cfg.tenant);
    let mut specs: Vec<(DriverKind, usize)> = vec![(DriverKind::IncludeOsCold, 0)];
    for policy_idx in 0..POLICY_COUNT {
        specs.push((DriverKind::DockerWarm, policy_idx));
    }
    // Cells run SERIALLY (threads = 1), unlike the E12–E14 grids: each
    // cell's wall clock is the denominator of the events/s headline, and
    // concurrent cells time-slicing the same cores would understate it
    // by up to the cell count and make it vary with machine load.
    let mut cells = sweep::run_cells_with(1, &specs, |_, &(driver, policy_idx)| {
        let mut policy = make_policy(policy_idx, cfg.tenant.functions);
        let mut pcfg = cell_platform_config(cfg, driver, &trace);
        cfg.checkpoint.apply(&mut pcfg, "e15", &format!("{driver:?}-{}", policy.name()));
        #[allow(clippy::disallowed_methods)]
        let t0 = std::time::Instant::now(); // detlint: allow(DL001) informational per-cell wall clock
        let r = run_platform(&pcfg, policy.as_mut(), cfg.host);
        PlanetCell {
            driver,
            policy: policy.name(),
            requests: r.requests,
            p50_ms: r.quantile_ms(0.5),
            p99_ms: r.quantile_ms(0.99),
            cold_fraction: r.cold_fraction(),
            idle_gb_seconds: r.idle_gb_seconds,
            monitor_events: r.monitor_events,
            events: r.events,
            wall_s: t0.elapsed().as_secs_f64(),
            telemetry: r.telemetry,
            on_frontier: false,
        }
    });
    mark_frontier(&mut cells);
    cells
}

/// E15 report over an explicit configuration (the CLI subcommand path).
pub fn planet_with(cfg: &PlanetConfig) -> Report {
    let mut report = Report::new(&format!(
        "E15: planet sweep — {} nodes x {} fns, ~{:.1}M streamed requests per cell \
         (Zipf {:.1}, {:.0} rps, {:.0} s)",
        cfg.nodes,
        cfg.tenant.functions,
        cfg.tenant.total_rps * cfg.tenant.duration_s / 1e6,
        cfg.tenant.zipf_exponent,
        cfg.tenant.total_rps,
        cfg.tenant.duration_s
    ));
    let cells = planet_cells(cfg);

    // S25 self-profile: total engine events are deterministic per seed
    // (gated strictly); the throughput quotient is wall-clock and stays
    // JSON-only informational.
    let total_events: u64 = cells.iter().map(|c| c.events).sum();
    let total_wall: f64 = cells.iter().map(|c| c.wall_s).sum();
    let eps = if total_wall > 0.0 { total_events as f64 / total_wall } else { 0.0 };
    report.set_profile(total_events, eps);
    for c in &cells {
        if let Some(t) = &c.telemetry {
            for (name, points) in t.rows() {
                report.add_timeseries(&format!("{} {name}", c.label()), t.interval_s(), points);
            }
        }
    }

    report.note(format!(
        "{:<22} {:>9} {:>8} {:>9} {:>7} {:>12} {:>10} {:>11}  {}",
        "driver+policy",
        "reqs",
        "p50 ms",
        "p99 ms",
        "cold%",
        "waste GB·s",
        "events",
        "Mevents/s",
        "frontier"
    ));
    for c in &cells {
        report.note(format!(
            "{:<22} {:>9} {:>8.2} {:>9.1} {:>6.1}% {:>12.2} {:>10} {:>11.2}  {}",
            c.label(),
            c.requests,
            c.p50_ms,
            c.p99_ms,
            c.cold_fraction * 100.0,
            c.idle_gb_seconds,
            c.events,
            c.events_per_s() / 1e6,
            if c.on_frontier { "*" } else { "" }
        ));
    }

    let inc_cold = cells
        .iter()
        .find(|c| c.driver == DriverKind::IncludeOsCold && c.policy == "cold-only")
        .expect("includeos cold-only cell");

    // Scale actually reached: the whole grid replayed the full trace on
    // the full cluster.
    report.band("nodes simulated", "nodes", cfg.nodes as f64, 256.0, f64::INFINITY);
    let reqs = cells[0].requests;
    let all_equal = cells.iter().all(|c| c.requests == reqs);
    report.band(
        "all cells replayed the full trace",
        "bool",
        if all_equal { 1.0 } else { 0.0 },
        1.0,
        1.0,
    );
    // The paper's lifecycle stays free with 10k tenants on 256 nodes.
    report.band("includeos+cold-only idle waste", "GB·s", inc_cold.idle_gb_seconds, 0.0, 0.0);
    report.band(
        "includeos+cold-only monitor events",
        "events",
        inc_cold.monitor_events as f64,
        0.0,
        0.0,
    );
    // The headline re-check: the zero-waste row holds the frontier at
    // planet scale too.
    report.band(
        "includeos+cold-only on (p99, waste) frontier",
        "bool",
        if inc_cold.on_frontier { 1.0 } else { 0.0 },
        1.0,
        1.0,
    );
    // Warm pools at this scale hold real state (what the crash pays for).
    let fixed = cells
        .iter()
        .find(|c| c.driver == DriverKind::DockerWarm && c.policy == "fixed-600s")
        .expect("docker fixed cell");
    report.band("docker+fixed-600s idle waste", "GB·s", fixed.idle_gb_seconds, 1e-6, f64::INFINITY);
    // Simulator throughput (the tentpole metric; wall-clock dependent, so
    // only a sanity floor is asserted).
    let min_eps = cells.iter().map(|c| c.events_per_s()).fold(f64::INFINITY, f64::min);
    report.band("simulator throughput (slowest cell)", "events/s", min_eps, 1.0, f64::INFINITY);

    report.note(
        "reading: with 10k functions and 256 nodes the warm policies hold tens of \
         thousands of pool slots that must be indexed, expired, and monitored — the \
         cold-only unikernel row still gets a frontier p99 with none of that \
         machinery; Mevents/s is the simulator's own hot-path number (warm index + \
         deadline-ordered pools + streamed arrivals are what make this run at all)",
    );
    report
}

/// E15 via the shared experiment config (the `experiment planet` path).
pub fn planet(cfg: &ExpConfig) -> Report {
    planet_with(&planet_config(cfg))
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Debug-build-sized planet: the full 256-node x 1M-request grid runs
    /// in release via `coldfaas planet` / the e15 bench; unit tests keep
    /// the shape, not the scale.
    fn tiny_cfg() -> PlanetConfig {
        PlanetConfig {
            tenant: TenantConfig {
                functions: 500,
                duration_s: 30.0,
                total_rps: 200.0,
                seed: 0xE15,
                ..Default::default()
            },
            nodes: 64,
            cores_per_node: 4,
            host: Host::default(),
            obs: ObsConfig::default(),
            checkpoint: CheckpointPlan::default(),
        }
    }

    #[test]
    fn planet_config_targets_full_scale() {
        let full = planet_config(&ExpConfig::default());
        assert_eq!(full.nodes, 256);
        assert_eq!(full.tenant.functions, 10_000);
        let arrivals = full.tenant.total_rps * full.tenant.duration_s;
        assert!(arrivals >= 1_000_000.0, "full planet must be >=1M requests: {arrivals}");
        let quick = planet_config(&ExpConfig::quick());
        assert_eq!(quick.nodes, 256, "--quick shrinks the trace, not the cluster");
        assert!(quick.tenant.total_rps * quick.tenant.duration_s >= 50_000.0);
    }

    #[test]
    fn grid_replays_full_trace_and_cold_only_stays_free() {
        let cfg = tiny_cfg();
        let trace_len = TenantTrace::generate(&cfg.tenant).len() as u64;
        let cells = planet_cells(&cfg);
        assert_eq!(cells.len(), 1 + POLICY_COUNT);
        for c in &cells {
            assert_eq!(c.requests, trace_len, "{}", c.label());
            assert!(c.events > 0, "{}", c.label());
        }
        let inc = cells
            .iter()
            .find(|c| c.driver == DriverKind::IncludeOsCold)
            .expect("includeos row");
        assert_eq!(inc.policy, "cold-only");
        assert_eq!(inc.idle_gb_seconds, 0.0);
        assert_eq!(inc.monitor_events, 0);
        assert!((inc.cold_fraction - 1.0).abs() < 1e-12);
        let fixed = cells.iter().find(|c| c.policy == "fixed-600s").expect("fixed row");
        assert!(fixed.idle_gb_seconds > 0.0);
    }

    #[test]
    fn deterministic_cells_per_seed_modulo_wall_clock() {
        let run = || {
            planet_cells(&tiny_cfg())
                .into_iter()
                .map(|c| {
                    (
                        c.label(),
                        c.requests,
                        c.p99_ms.to_bits(),
                        c.idle_gb_seconds.to_bits(),
                        c.events,
                        c.on_frontier,
                    )
                })
                .collect::<Vec<_>>()
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn telemetry_leg_is_metric_pure() {
        let off = planet_cells(&tiny_cfg());
        let mut cfg = tiny_cfg();
        cfg.obs.telemetry_interval_ns = 5_000_000_000;
        let on = planet_cells(&cfg);
        for (a, b) in off.iter().zip(&on) {
            assert_eq!(a.label(), b.label());
            assert!(a.telemetry.is_none());
            assert!(b.telemetry.as_ref().is_some_and(|t| !t.is_empty()), "{}", b.label());
            // Sampling is pure observation: every metric stays bit-equal.
            assert_eq!(a.p99_ms.to_bits(), b.p99_ms.to_bits(), "{}", a.label());
            assert_eq!(a.idle_gb_seconds.to_bits(), b.idle_gb_seconds.to_bits());
            assert_eq!(a.events, b.events, "telemetry must not add engine events");
        }
    }

    #[test]
    fn frontier_includes_the_cold_only_row() {
        let cells = planet_cells(&tiny_cfg());
        assert!(
            cells
                .iter()
                .any(|c| c.driver == DriverKind::IncludeOsCold && c.on_frontier),
            "zero-waste row must sit on the (p99, waste) frontier"
        );
    }
}
