//! E6: startup-cost decomposition (§III-C text numbers) — where the time
//! goes inside bare runc vs the full Docker stack, using phase tracing.

use super::ExpConfig;
use crate::report::Report;
use crate::sim::{Domain, Engine, ReqId, Spawn};
use crate::virt::Tech;

struct Sink;
impl Domain for Sink {
    fn done(&mut self, _r: ReqId, _c: u32, _s: u64, _n: u64) -> Vec<Spawn> {
        Vec::new()
    }
}

/// Average wall milliseconds spent per request in each phase tag, over `n`
/// uncontended starts of `tech`.  Per-request averages keep the
/// decomposition additive even when a tag appears twice in the pipeline
/// (Docker runs the namespace fragment once in the stack and once in runc).
pub fn phase_medians(tech: Tech, n: u64, seed: u64) -> Vec<(String, f64)> {
    let mut e = Engine::new(Sink, crate::sim::Host::default(), seed);
    e.trace_phases = true;
    for i in 0..n {
        // Spaced out: no contention, pure phase costs.
        e.spawn_at(i * 10_000_000_000, 0, tech.pipeline());
    }
    e.run(n * 64);
    let mut by_tag: std::collections::BTreeMap<&'static str, f64> = Default::default();
    for p in &e.phase_trace {
        *by_tag.entry(p.tag).or_default() += p.dur_ns as f64;
    }
    by_tag
        .into_iter()
        .map(|(tag, total)| (tag.to_string(), total / n as f64 / 1e6))
        .collect()
}

pub fn decompose(cfg: &ExpConfig) -> Report {
    let n = cfg.requests.min(500).max(50);
    let mut report = Report::new("E6: startup decomposition — runc vs Docker stack (§III-C)");

    let runc = phase_medians(Tech::Runc, n, cfg.seed);
    let docker = phase_medians(Tech::DockerRunc, n, cfg.seed ^ 9);
    let inter = phase_medians(Tech::DockerRuncInteractive, n, cfg.seed ^ 10);

    let total = |v: &[(String, f64)]| v.iter().map(|(_, ms)| ms).sum::<f64>();
    let (runc_ms, docker_ms, inter_ms) = (total(&runc), total(&docker), total(&inter));

    for (name, phases) in [("runc", &runc), ("docker-runc", &docker)] {
        for (tag, ms) in phases {
            report.note(format!("{name:<14} {tag:<22} {ms:>8.1} ms"));
        }
    }

    // §III-C: bare runc ≈ 150 ms; daemon docker ≈ 450; interactive ≈ 650.
    report.check("bare runc total", "ms", runc_ms, 150.0, 0.25);
    report.check("docker daemon total", "ms", docker_ms, 450.0, 0.25);
    report.check("docker interactive total", "ms", inter_ms, 650.0, 0.25);

    // "Adding the namespace configurations ... adds roughly 100 ms" —
    // namespaces across the two passes (docker + runc-core).
    let ns_ms: f64 = docker
        .iter()
        .filter(|(t, _)| {
            t.contains("netns") || t.contains("mountns") || t.contains("ipcns")
                || t.contains("net-config") || t.contains("cgroups")
        })
        .map(|(_, ms)| ms)
        .sum();
    report.band("namespace phases (docker)", "ms", ns_ms, 50.0, 110.0);

    // "The largest overhead comes from networking configuration, followed
    // by the mount and inter process communication namespaces."
    let phase = |needle: &str| -> f64 {
        docker
            .iter()
            .filter(|(t, _)| t.contains(needle))
            .map(|(_, ms)| ms)
            .sum()
    };
    let (net, mount, ipc) = (phase("netns") + phase("net-config"), phase("mountns"), phase("ipcns"));
    report.band("net > mount ordering", "ratio", net / mount.max(1e-9), 1.01, 1e6);
    report.band("mount > ipc ordering", "ratio", mount / ipc.max(1e-9), 1.01, 1e6);

    // Storage driver + engine serialization dominate the docker-runc gap.
    let engine: f64 = phase("engine-serial") + phase("overlay2");
    report.band("engine+storage share of docker gap", "fraction",
        engine / (docker_ms - runc_ms), 0.5, 1.0);
    report
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn decompose_checks_pass() {
        let r = decompose(&ExpConfig::quick());
        assert!(r.all_pass(), "failures: {:#?}", r.failures());
    }

    #[test]
    fn phase_medians_cover_all_tags() {
        let v = phase_medians(Tech::IncludeOsHvt, 50, 1);
        let tags: Vec<&str> = v.iter().map(|(t, _)| t.as_str()).collect();
        assert!(tags.contains(&"hvt-tender"));
        assert!(tags.contains(&"kvm-create"));
        assert!(tags.contains(&"unikernel-boot"));
    }
}
