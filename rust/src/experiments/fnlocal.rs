//! E4 / Fig 4: the Fn prototype in the local lab — cold IncludeOS vs warm
//! Docker (Go function) across parallelism, plus deployment-time numbers.

use super::ExpConfig;
use crate::fnplat::{run_scenario, DriverKind, Scenario};
use crate::image::BuildKind;
use crate::metrics::Recorder;
use crate::report::Report;

/// Fig 4: measurement in the local lab environment.
pub fn fig4(cfg: &ExpConfig) -> Report {
    let mut rec = Recorder::new();
    for &p in &cfg.parallelisms {
        let sc = Scenario {
            seed: cfg.seed ^ (p as u64) << 24,
            ..Scenario::local(DriverKind::IncludeOsCold, p, cfg.requests, false)
        };
        let r = run_scenario(&sc, cfg.host);
        for &ns in &r.latencies_ns {
            rec.record_ns(&format!("fn-includeos-cold@{p}"), ns);
        }

        let sc = Scenario {
            seed: cfg.seed ^ (p as u64) << 25,
            ..Scenario::local(DriverKind::DockerWarm, p, cfg.requests, true)
        };
        let r = run_scenario(&sc, cfg.host);
        for &ns in &r.warm_latencies_ns {
            rec.record_ns(&format!("fn-docker-warm@{p}"), ns);
        }
    }

    let mut report = Report::new("Fig 4: Fn measurement results in the local lab");
    for &p in &cfg.parallelisms {
        for series in ["fn-includeos-cold", "fn-docker-warm"] {
            let label = format!("{series}@{p}");
            if let Some(s) = rec.stats(&label) {
                report.add_series(&label, s);
            }
        }
    }

    let p50 = |l: &str| rec.quantile(l, 0.5).unwrap_or(f64::NAN);
    let moderate = if cfg.parallelisms.contains(&10) { 10 } else { cfg.parallelisms[0] };
    // §IV-B: "startup and execution of our test function with IncludeOS
    // takes around 10-20 ms".
    report.band(
        &format!("fn-includeos-cold@{moderate}"),
        "p50",
        p50(&format!("fn-includeos-cold@{moderate}")),
        10.0,
        20.0,
    );
    // "the latency with a warm Go function takes 3-5 ms".
    report.band(
        &format!("fn-docker-warm@{moderate}"),
        "p50",
        p50(&format!("fn-docker-warm@{moderate}")),
        3.0,
        5.5,
    );
    // Deployment times (§IV-B).
    report.check(
        "deploy includeos (C++ boot build)",
        "seconds",
        BuildKind::IncludeOsBoot.build_seconds(),
        3.5,
        0.01,
    );
    report.band(
        "deploy docker (FDK image build)",
        "seconds",
        BuildKind::DockerFdk.build_seconds(),
        9.0,
        10.0,
    );
    report.note("warm Docker wins on pure latency; the price is idle-reserved resources (E9)");
    report
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig4_checks_pass_quick() {
        let r = fig4(&ExpConfig::quick());
        assert!(r.all_pass(), "failures: {:#?}", r.failures());
    }
}
