//! E11 (extension): burst scale-out across a cluster — the co-location
//! behaviour Wang et al. measured on AWS ("co-location influences startup
//! times when sudden scale-out is required", §IV) against spread
//! placement, and the image-distribution economics (§IV-C) that make
//! spreading affordable for 2.5 MB unikernel images but not for 70 MB
//! Firecracker images.

use super::ExpConfig;
use crate::cluster::{run_burst, ClusterConfig, Policy};
use crate::report::Report;
use crate::virt::Tech;

pub fn scaleout(cfg: &ExpConfig) -> Report {
    let mut report =
        Report::new("E11: burst scale-out — placement policy x image size (8 nodes x 8 cores)");
    let mut results = Vec::new();
    for tech in [Tech::IncludeOsHvt, Tech::Firecracker] {
        // Burst sized to the cluster: ~0.8x total capacity, so the cluster
        // can absorb it but a single co-located node cannot.  Firecracker
        // starts are ~11x longer, so its burst window stretches likewise.
        let burst_ms = match tech {
            Tech::Firecracker => 1000.0,
            _ => 250.0,
        };
        let base = ClusterConfig {
            requests: 400,
            burst_ms,
            tech,
            seed: cfg.seed,
            ..Default::default()
        };
        for policy in Policy::ALL {
            let r = run_burst(&ClusterConfig { policy, ..base.clone() });
            report.note(format!(
                "{:<14} {:<13} p50={:>8.1} ms  p99={:>8.1} ms  pulls={:<3} moved={:>7.1} MB  footprint={:>7.1} MB",
                tech.name(),
                r.policy.name(),
                r.p50_ms,
                r.p99_ms,
                r.transfers,
                r.transferred_mb,
                r.footprint_mb
            ));
            results.push((tech, r));
        }
    }

    let get = |t: Tech, p: Policy| {
        results
            .iter()
            .find(|(tech, r)| *tech == t && r.policy == p)
            .map(|(_, r)| r)
            .unwrap()
    };

    // Co-location inflates burst tails vs spreading (both image sizes).
    for t in [Tech::IncludeOsHvt, Tech::Firecracker] {
        let colo = get(t, Policy::CoLocate);
        let spread = get(t, Policy::LeastLoaded);
        report.band(
            &format!("{} co-locate/spread p99 blowup", t.name()),
            "ratio",
            colo.p99_ms / spread.p99_ms,
            2.0,
            f64::INFINITY,
        );
    }
    // Spreading cost: unikernel images move ~28x fewer bytes.
    let uni = get(Tech::IncludeOsHvt, Policy::LeastLoaded);
    let fc = get(Tech::Firecracker, Policy::LeastLoaded);
    report.band(
        "firecracker/unikernel bytes moved",
        "ratio",
        fc.transferred_mb / uni.transferred_mb.max(1e-9),
        20.0,
        40.0,
    );
    // With unikernels, full spread still lands in the paper's cold band.
    report.band("unikernel spread p50", "ms", uni.p50_ms, 5.0, 25.0);
    report.note("conclusion: tiny unikernel images let the scheduler spread on demand — the co-location constraint (and its scale-out penalty) dissolves");
    report
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scaleout_checks_pass_quick() {
        let r = scaleout(&ExpConfig::quick());
        assert!(r.all_pass(), "failures: {:#?}", r.failures());
    }
}
