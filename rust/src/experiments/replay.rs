//! `coldfaas trace` (S25): replay one experiment cell with the
//! observability layer armed — lifecycle spans streamed into a Chrome
//! `trace_event` file, optional interval telemetry — without touching
//! the experiment's own pinned reports.
//!
//! The replayed grid is exactly E14's (fleet-shaped cells under the
//! scripted chaos plan or its dry baseline leg), so a captured trace
//! lines up one-to-one with a chaos report row: same tenant trace, same
//! seed, same disruption windows.  Because every sink is a pure observer
//! and all timestamps are virtual time, the trace file itself is
//! byte-identical per seed — a property the regression suite pins.

use super::chaos::ChaosConfig;
use super::fleet::cell_config;
use super::planet::{cell_platform_config, PlanetConfig};
use super::{make_policy, POLICY_COUNT};
use crate::fnplat::DriverKind;
use crate::obs::ObsConfig;
use crate::platform::{chaos_plan, run_platform, PlatformResult, SchedPolicy};
use crate::report::Report;
use crate::workload::tenants::TenantTrace;

/// The cell a `coldfaas trace` run replays unless told otherwise: the
/// keep-alive flagship row of the chaos grid (the busiest lifecycle —
/// warm claims, crash-drained pools, retries — all on one timeline).
pub const DEFAULT_CELL: &str = "docker+fixed-600s+least-loaded";

/// Parse an E14 cell label (`driver+policy+scheduler`, e.g.
/// `includeos+cold-only+least-loaded`) into its grid coordinates.
pub fn parse_cell(label: &str, functions: u32) -> Result<(DriverKind, usize, SchedPolicy), String> {
    let mut parts = label.splitn(3, '+');
    let (Some(d), Some(p), Some(s)) = (parts.next(), parts.next(), parts.next()) else {
        return Err(format!("cell '{label}': expected driver+policy+scheduler"));
    };
    let driver = match d {
        "docker" => DriverKind::DockerWarm,
        "includeos" => DriverKind::IncludeOsCold,
        other => return Err(format!("cell '{label}': unknown driver '{other}'")),
    };
    let policy_idx = (0..POLICY_COUNT)
        .find(|&i| make_policy(i, functions).name() == p)
        .ok_or_else(|| format!("cell '{label}': unknown policy '{p}'"))?;
    let scheduler = SchedPolicy::ALL
        .into_iter()
        .find(|sp| sp.name() == s)
        .ok_or_else(|| format!("cell '{label}': unknown scheduler '{s}'"))?;
    Ok((driver, policy_idx, scheduler))
}

/// Outcome of one traced replay; the Chrome trace JSON (if tracing was
/// on) rides on `result.trace_json`.
pub struct ReplayOutcome {
    pub label: String,
    /// Which leg/grid ran, for the report title (e.g. "faulted leg").
    pub leg: &'static str,
    /// The grid the cell came from (nodes, seed — for the report title).
    pub grid: String,
    pub result: PlatformResult,
}

/// Replay one chaos-grid cell under `obs`.  `faulted` picks the leg:
/// the scripted plan or its dry twin (same windows, nothing injected).
pub fn replay_chaos_cell(
    cfg: &ChaosConfig,
    cell: &str,
    obs: &ObsConfig,
    faulted: bool,
) -> Result<ReplayOutcome, String> {
    let (driver, policy_idx, scheduler) = parse_cell(cell, cfg.tenant.functions)?;
    let trace = TenantTrace::generate(&cfg.tenant);
    let horizon_ns = (cfg.tenant.duration_s * 1e9) as u64;
    let plan = chaos_plan(cfg.nodes, horizon_ns);
    let plan = if faulted { plan } else { plan.dry() };
    let pcfg = cell_config(
        cfg.nodes,
        cfg.cores_per_node,
        &cfg.tenant,
        driver,
        scheduler,
        &trace,
        plan,
        obs.clone(),
    );
    let mut policy = make_policy(policy_idx, cfg.tenant.functions);
    let result = run_platform(&pcfg, policy.as_mut(), cfg.host);
    Ok(ReplayOutcome {
        label: cell.to_string(),
        leg: if faulted { "faulted leg" } else { "dry baseline leg" },
        grid: format!("E14 chaos grid, {} nodes, seed {:#x}", cfg.nodes, cfg.tenant.seed),
        result,
    })
}

/// Replay one planet-grid cell (`driver+policy`, e.g. `docker+ewma`)
/// under `obs`.  Planet-scale captures want `trace_window_only` off (the
/// plan is fault-free, so windows are empty) and a `trace_capacity` cap.
pub fn replay_planet_cell(
    cfg: &PlanetConfig,
    cell: &str,
    obs: &ObsConfig,
) -> Result<ReplayOutcome, String> {
    let mut parts = cell.splitn(2, '+');
    let (Some(d), Some(p)) = (parts.next(), parts.next()) else {
        return Err(format!("cell '{cell}': expected driver+policy"));
    };
    let driver = match d {
        "docker" => DriverKind::DockerWarm,
        "includeos" => DriverKind::IncludeOsCold,
        other => return Err(format!("cell '{cell}': unknown driver '{other}'")),
    };
    let policy_idx = (0..POLICY_COUNT)
        .find(|&i| make_policy(i, cfg.tenant.functions).name() == p)
        .ok_or_else(|| format!("cell '{cell}': unknown policy '{p}'"))?;
    let mut obs_cfg = cfg.clone();
    obs_cfg.obs = obs.clone();
    let trace = TenantTrace::generate(&obs_cfg.tenant);
    let pcfg = cell_platform_config(&obs_cfg, driver, &trace);
    let mut policy = make_policy(policy_idx, obs_cfg.tenant.functions);
    let result = run_platform(&pcfg, policy.as_mut(), obs_cfg.host);
    Ok(ReplayOutcome {
        label: cell.to_string(),
        leg: "streamed replay",
        grid: format!("E15 planet grid, {} nodes, seed {:#x}", cfg.nodes, cfg.tenant.seed),
        result,
    })
}

/// Human/machine summary of a traced replay (what `coldfaas trace`
/// prints and writes next to the trace file).
pub fn replay_report(out: &ReplayOutcome) -> Report {
    let r = &out.result;
    let title = format!("TRACE: cell {} ({}; {})", out.label, out.leg, out.grid);
    let mut report = Report::new(&title);
    report.set_profile(r.profile.engine_events, r.profile.events_per_s());
    if let Some(t) = &r.telemetry {
        for (name, points) in t.rows() {
            report.add_timeseries(name, t.interval_s(), points);
        }
    }
    report.note(format!(
        "served {} / killed {} / retries {} / rejected {} / crashes {} / restarts {}",
        r.served, r.killed, r.retries, r.rejected, r.crashes, r.restarts
    ));
    if let Some(json) = &r.trace_json {
        report.note(format!(
            "trace captured: {} bytes of Chrome trace_event JSON \
             ({} events evicted by the ring buffer) — load it in \
             chrome://tracing or https://ui.perfetto.dev",
            json.len(),
            r.trace_dropped
        ));
    }
    report
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::Json;
    use crate::sim::Host;
    use crate::workload::tenants::TenantConfig;

    fn cfg() -> ChaosConfig {
        ChaosConfig {
            tenant: TenantConfig {
                functions: 200,
                duration_s: 30.0,
                total_rps: 40.0,
                seed: 0x7ACE,
                ..Default::default()
            },
            nodes: 4,
            cores_per_node: 4,
            schedulers: vec![SchedPolicy::LeastLoaded],
            host: Host::default(),
            timeseries: false,
        }
    }

    #[test]
    fn cell_labels_round_trip_the_grid() {
        for d in ["docker", "includeos"] {
            for p in ["cold-only", "fixed-600s", "histogram", "ewma"] {
                for s in SchedPolicy::ALL {
                    let label = format!("{d}+{p}+{}", s.name());
                    let (driver, idx, sched) = parse_cell(&label, 100).unwrap();
                    assert_eq!(make_policy(idx, 100).name(), p);
                    assert_eq!(sched, s);
                    let want = match d {
                        "docker" => DriverKind::DockerWarm,
                        _ => DriverKind::IncludeOsCold,
                    };
                    assert_eq!(driver, want);
                }
            }
        }
        assert!(parse_cell("docker+fixed-600s", 100).is_err());
        assert!(parse_cell("podman+cold-only+spread", 100).is_err());
        assert!(parse_cell("docker+lru+spread", 100).is_err());
        assert!(parse_cell("docker+cold-only+random", 100).is_err());
        parse_cell(DEFAULT_CELL, 100).expect("default cell must parse");
    }

    #[test]
    fn traced_chaos_replay_is_byte_identical_per_seed() {
        let obs = ObsConfig { trace: true, ..Default::default() };
        let run = || {
            replay_chaos_cell(&cfg(), DEFAULT_CELL, &obs, true)
                .unwrap()
                .result
                .trace_json
                .expect("tracing was on")
        };
        let a = run();
        assert!(!a.is_empty());
        assert_eq!(a, run(), "same seed must produce the same trace bytes");
        // And the capture is well-formed Chrome trace JSON.
        let doc = Json::parse(&a).expect("trace must parse");
        let events = doc.get("traceEvents").and_then(Json::as_arr).expect("traceEvents");
        assert!(!events.is_empty());
    }

    #[test]
    fn tracing_leaves_measurements_byte_identical() {
        let off = replay_chaos_cell(&cfg(), DEFAULT_CELL, &ObsConfig::default(), true).unwrap();
        let obs =
            ObsConfig { trace: true, telemetry_interval_ns: 1_000_000_000, ..Default::default() };
        let on = replay_chaos_cell(&cfg(), DEFAULT_CELL, &obs, true).unwrap();
        let (a, b) = (&off.result, &on.result);
        assert!(a.trace_json.is_none() && b.trace_json.is_some());
        assert_eq!(a.served, b.served);
        assert_eq!(a.killed, b.killed);
        assert_eq!(a.retries, b.retries);
        assert_eq!(a.rejected, b.rejected);
        assert_eq!(a.idle_gb_seconds.to_bits(), b.idle_gb_seconds.to_bits());
        assert_eq!(a.quantile_ms(0.99).to_bits(), b.quantile_ms(0.99).to_bits());
        assert_eq!(a.events, b.events, "observation must not add engine events");
    }

    #[test]
    fn window_capture_and_ring_cap_bound_the_trace() {
        let full = ObsConfig { trace: true, ..Default::default() };
        let windowed = ObsConfig { trace: true, trace_window_only: true, ..Default::default() };
        let capped = ObsConfig { trace: true, trace_capacity: 64, ..Default::default() };
        let size = |obs: &ObsConfig| {
            let r = replay_chaos_cell(&cfg(), DEFAULT_CELL, obs, true).unwrap().result;
            (r.trace_json.unwrap().len(), r.trace_dropped)
        };
        let (full_len, full_dropped) = size(&full);
        let (win_len, _) = size(&windowed);
        let (cap_len, cap_dropped) = size(&capped);
        assert_eq!(full_dropped, 0);
        assert!(win_len < full_len, "window capture must shrink the trace");
        assert!(cap_len < full_len, "ring cap must bound the trace");
        assert!(cap_dropped > 0, "the cap must actually have evicted events");
    }
}
