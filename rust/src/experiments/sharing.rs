//! E16: universal-worker warm sharing — the strongest keep-alive
//! counter-proposal to the paper's cold-only platform, quantified.
//!
//! Per-function keep-alive (E12/E13) wastes a warm worker per tenant; a
//! *universal* pool keys warm workers by language runtime so any function
//! can claim one, amortizing the resident memory across the whole
//! population — at the price of a **specialization** step on every
//! cross-function claim (runtime warm, function state cold).  This
//! experiment re-runs the E13 fleet question with that competitor on the
//! board: the exclusive lifecycle-policy rows, plus a `UniversalPool`
//! row per sharing mode (per-runtime / promiscuous) per swept
//! specialization cost — and reports the **break-even specialization
//! cost**: the largest swept cost at which the shared warm pool still
//! beats cold-only IncludeOS on p99.  Below it, sharing wins latency
//! (never the frontier — it still pays waste); above it, cold-only wins
//! both axes outright.

use super::fleet::cell_config;
use super::{make_policy, sweep, ExpConfig, POLICY_COUNT};
use crate::fnplat::DriverKind;
use crate::platform::{run_platform, FaultPlan, SchedPolicy, SharingMode};
use crate::policy::{LifecyclePolicy, UniversalPool};
use crate::report::Report;
use crate::sim::{Dist, Host, Step};
use crate::workload::tenants::{TenantConfig, TenantTrace};

/// Full E16 configuration: the tenant trace, the cluster shape, and the
/// sharing sweep.
#[derive(Clone, Debug)]
pub struct SharingConfig {
    pub tenant: TenantConfig,
    pub nodes: usize,
    pub cores_per_node: u32,
    /// Runtime families functions hash onto (`func % runtimes`) for the
    /// per-runtime sharing mode and the universal policy's sizing.
    pub runtimes: u32,
    /// Universal workers targeted (and pre-seeded) per sharing bucket.
    pub target_per_key: u32,
    /// Specialization-cost sweep, ms per cross-function claim.  The
    /// paper checks assume the sweep spans cheap-to-dear (the default
    /// brackets the break-even from both sides).
    pub spec_costs_ms: Vec<f64>,
    pub host: Host,
}

/// Derive an E16 configuration from the shared experiment config (same
/// trace sizing as E13: ~20k arrivals over 1000 functions at default
/// load, ~3k under `--quick`).
pub fn sharing_config(cfg: &ExpConfig) -> SharingConfig {
    let duration_s = (cfg.requests as f64 / 25.0).clamp(60.0, 600.0);
    let total_rps = (cfg.requests as f64 * 2.0) / duration_s;
    SharingConfig {
        tenant: TenantConfig {
            functions: 1000,
            duration_s,
            total_rps,
            seed: cfg.seed,
            ..Default::default()
        },
        nodes: 8,
        cores_per_node: 8,
        runtimes: 4,
        target_per_key: 8,
        // Brackets the break-even from both sides while keeping even the
        // dearest cell's offered concurrency (rate x specialized service
        // time) well under the per-bucket worker target.
        spec_costs_ms: vec![1.0, 4.0, 16.0, 64.0],
        host: cfg.host,
    }
}

/// One grid cell: an exclusive lifecycle-policy row (the E13 reference
/// column) or a universal-sharing row at one specialization cost.
#[derive(Clone, Copy, Debug)]
enum CellKind {
    Exclusive { driver: DriverKind, policy_idx: usize },
    Universal { mode: SharingMode, spec_ms: f64 },
}

/// Measured outcome of one cell.
#[derive(Clone, Debug)]
pub struct SharingCell {
    pub driver: DriverKind,
    pub policy: String,
    /// Sharing-mode name (`exclusive`, `runtime-N`, `promiscuous`).
    pub sharing: String,
    /// Specialization cost swept for this cell (0 on exclusive rows).
    pub spec_ms: f64,
    pub requests: u64,
    pub p50_ms: f64,
    pub p99_ms: f64,
    pub warm_hits: u64,
    pub specializations: u64,
    pub cold_starts: u64,
    pub cold_fraction: f64,
    pub idle_gb_seconds: f64,
    pub monitor_events: u64,
    /// On the Pareto frontier of (p99 latency, idle waste)?
    pub on_frontier: bool,
}

impl SharingCell {
    pub fn label(&self) -> String {
        let d = match self.driver {
            DriverKind::DockerWarm => "docker",
            DriverKind::IncludeOsCold => "includeos",
        };
        if self.sharing == "exclusive" {
            format!("{d}+{}+exclusive", self.policy)
        } else {
            format!("{d}+{}+{}+spec{}ms", self.policy, self.sharing, self.spec_ms)
        }
    }
}

/// Run the grid over one generated trace: both drivers x the four E13
/// lifecycle policies on exclusive slots, plus docker x `UniversalPool`
/// x sharing mode x specialization cost.  Cells run on the shared
/// parallel sweep runner and collect in grid order, so the report is
/// byte-identical to serial execution.
pub fn sharing_cells(cfg: &SharingConfig) -> Vec<SharingCell> {
    let trace = TenantTrace::generate(&cfg.tenant);
    let mut specs: Vec<CellKind> = Vec::new();
    for driver in [DriverKind::IncludeOsCold, DriverKind::DockerWarm] {
        for policy_idx in 0..POLICY_COUNT {
            specs.push(CellKind::Exclusive { driver, policy_idx });
        }
    }
    for &spec_ms in &cfg.spec_costs_ms {
        for mode in [SharingMode::PerRuntime { runtimes: cfg.runtimes }, SharingMode::Promiscuous]
        {
            specs.push(CellKind::Universal { mode, spec_ms });
        }
    }
    let mut cells = sweep::run_cells(&specs, |_, spec| {
        let (driver, mut policy, mode, spec_ms): (_, Box<dyn LifecyclePolicy>, _, f64) =
            match *spec {
                CellKind::Exclusive { driver, policy_idx } => (
                    driver,
                    make_policy(policy_idx, cfg.tenant.functions),
                    SharingMode::Exclusive,
                    0.0,
                ),
                CellKind::Universal { mode, spec_ms } => {
                    let buckets = match mode {
                        SharingMode::PerRuntime { runtimes } => runtimes,
                        _ => 1,
                    };
                    let universal = UniversalPool::new(buckets, cfg.target_per_key as f64);
                    (
                        DriverKind::DockerWarm,
                        Box::new(universal) as Box<dyn LifecyclePolicy>,
                        mode,
                        spec_ms,
                    )
                }
            };
        let mut pcfg = cell_config(
            cfg.nodes,
            cfg.cores_per_node,
            &cfg.tenant,
            driver,
            SchedPolicy::LeastLoaded,
            &trace,
            FaultPlan::default(),
            crate::obs::ObsConfig::default(),
        );
        pcfg.sharing = mode;
        if mode != SharingMode::Exclusive {
            pcfg.universal_prewarm = cfg.target_per_key;
            // The swept, deterministic specialization cost (the default
            // driver pipeline is the virt-profile-derived estimate; the
            // sweep asks where the break-even lies).
            pcfg.driver.specialize_steps =
                vec![Step::cpu("fn-specialize", Dist::const_ms(spec_ms))];
        }
        let r = run_platform(&pcfg, policy.as_mut(), cfg.host);
        SharingCell {
            driver,
            policy: policy.name(),
            sharing: mode.name(),
            spec_ms,
            requests: r.requests,
            p50_ms: r.quantile_ms(0.5),
            p99_ms: r.quantile_ms(0.99),
            warm_hits: r.warm_hits,
            specializations: r.specializations,
            cold_starts: r.cold_starts,
            cold_fraction: r.cold_fraction(),
            idle_gb_seconds: r.idle_gb_seconds,
            monitor_events: r.monitor_events,
            on_frontier: false,
        }
    });
    super::mark_pareto2(&mut cells, |c| (c.p99_ms, c.idle_gb_seconds), |c, on| {
        c.on_frontier = on
    });
    cells
}

fn exclusive<'a>(cells: &'a [SharingCell], driver: DriverKind, policy: &str) -> &'a SharingCell {
    cells
        .iter()
        .find(|c| c.driver == driver && c.policy == policy && c.sharing == "exclusive")
        .expect("exclusive cell present")
}

fn universal(cells: &[SharingCell]) -> impl Iterator<Item = &SharingCell> {
    cells.iter().filter(|c| c.sharing != "exclusive")
}

/// Smallest p99 among the universal rows at one swept cost (both modes).
fn best_universal_p99(cells: &[SharingCell], spec_ms: f64) -> f64 {
    universal(cells)
        .filter(|c| c.spec_ms == spec_ms)
        .map(|c| c.p99_ms)
        .fold(f64::INFINITY, f64::min)
}

/// E16 report over an explicit configuration (the CLI subcommand path).
pub fn sharing_with(cfg: &SharingConfig) -> Report {
    let mut report = Report::new(&format!(
        "E16: universal-worker sharing — runtime-keyed warm pools vs cold-only \
         ({} fns, {} runtimes, target {}/bucket, {} nodes, {:.0} rps, {:.0} s)",
        cfg.tenant.functions,
        cfg.runtimes,
        cfg.target_per_key,
        cfg.nodes,
        cfg.tenant.total_rps,
        cfg.tenant.duration_s
    ));
    let cells = sharing_cells(cfg);

    report.note(format!(
        "{:<44} {:>7} {:>8} {:>9} {:>7} {:>7} {:>6} {:>6} {:>11}  {}",
        "driver+policy+sharing",
        "reqs",
        "p50 ms",
        "p99 ms",
        "warm",
        "spec",
        "cold",
        "cold%",
        "waste GB·s",
        "frontier"
    ));
    for c in &cells {
        report.note(format!(
            "{:<44} {:>7} {:>8.2} {:>9.1} {:>7} {:>7} {:>6} {:>5.1}% {:>11.3}  {}",
            c.label(),
            c.requests,
            c.p50_ms,
            c.p99_ms,
            c.warm_hits,
            c.specializations,
            c.cold_starts,
            c.cold_fraction * 100.0,
            c.idle_gb_seconds,
            if c.on_frontier { "*" } else { "" }
        ));
    }

    let inc_cold = exclusive(&cells, DriverKind::IncludeOsCold, "cold-only");
    let doc_fixed = exclusive(&cells, DriverKind::DockerWarm, "fixed-600s");

    // Conservation: every dispatch is warm, specialized, or cold — the
    // sharing machinery invents and loses nothing.
    let worst_conservation = cells
        .iter()
        .map(|c| {
            (c.warm_hits + c.specializations + c.cold_starts)
                .abs_diff(c.requests)
        })
        .max()
        .unwrap_or(0);
    report.band(
        "warm + specialized + cold == served (worst cell)",
        "reqs",
        worst_conservation as f64,
        0.0,
        0.0,
    );
    // The sharing rows actually exercise cross-function claims.
    let total_spec: u64 = universal(&cells).map(|c| c.specializations).sum();
    report.band(
        "specialized claims across the sweep",
        "reqs",
        total_spec as f64,
        1.0,
        f64::INFINITY,
    );

    // The paper's row is still free, and still on the frontier: a shared
    // pool amortizes waste but cannot reach zero — it keeps workers warm.
    report.band("includeos+cold-only idle waste", "GB·s", inc_cold.idle_gb_seconds, 0.0, 0.0);
    report.band(
        "includeos+cold-only monitor events",
        "events",
        inc_cold.monitor_events as f64,
        0.0,
        0.0,
    );
    report.band(
        "includeos+cold-only on (p99, waste) frontier",
        "bool",
        if inc_cold.on_frontier { 1.0 } else { 0.0 },
        1.0,
        1.0,
    );

    // The amortization claim itself: a universal pool's residency is a
    // fraction of per-function keep-alive on the same trace and driver.
    let worst_univ_waste = universal(&cells).map(|c| c.idle_gb_seconds).fold(0.0, f64::max);
    report.band(
        "universal waste / fixed-600s waste (worst mode+cost)",
        "ratio",
        worst_univ_waste / doc_fixed.idle_gb_seconds.max(1e-12),
        0.0,
        0.8,
    );
    // Shared buckets keep the Zipf tail warm too: the cold fraction
    // collapses versus per-function pools (whose tail is all cold).
    let worst_univ_cold = universal(&cells).map(|c| c.cold_fraction).fold(0.0, f64::max);
    report.band("universal cold fraction (worst mode+cost)", "frac", worst_univ_cold, 0.0, 0.3);

    // The break-even bracket.  Cheap specialization: the shared warm
    // pool out-serves cold-only IncludeOS on the median...
    let min_cost = cfg.spec_costs_ms.iter().copied().fold(f64::INFINITY, f64::min);
    let max_cost = cfg.spec_costs_ms.iter().copied().fold(0.0, f64::max);
    let cheapest_p50 = universal(&cells)
        .filter(|c| c.spec_ms == min_cost)
        .map(|c| c.p50_ms)
        .fold(f64::INFINITY, f64::min);
    report.band(
        "cheapest-spec universal p50 / includeos p50",
        "ratio",
        cheapest_p50 / inc_cold.p50_ms,
        0.0,
        0.9,
    );
    // ...while dear specialization hands the tail back to cold-only (and
    // the universal row, still paying waste, falls off the frontier).
    report.band(
        "dearest-spec universal p99 / includeos p99",
        "ratio",
        best_universal_p99(&cells, max_cost) / inc_cold.p99_ms,
        1.05,
        f64::INFINITY,
    );
    // The headline readout: the largest swept specialization cost at
    // which some universal row still beats cold-only IncludeOS on p99.
    let mut costs = cfg.spec_costs_ms.clone();
    costs.sort_by(f64::total_cmp);
    let mut break_even = 0.0;
    for &c in &costs {
        if best_universal_p99(&cells, c) <= inc_cold.p99_ms {
            break_even = c;
        }
    }
    // 0 means no swept cost won at all (a sweep starting above the
    // break-even); the default sweep brackets it, which the p50/p99
    // bracket bands above assert from both sides.
    report.band(
        "break-even specialization cost (largest winning sweep point)",
        "ms",
        break_even,
        0.0,
        max_cost,
    );

    let verdict = if break_even > 0.0 {
        format!(
            "below ~{break_even} ms the shared pool out-serves cold-only IncludeOS \
             on p99 (at nonzero waste), above it cold-only wins both axes"
        )
    } else {
        "no swept specialization cost lets the shared pool beat cold-only \
         IncludeOS on p99 — the whole sweep sits above the break-even"
            .to_string()
    };
    report.note(format!(
        "reading: runtime-keyed universal workers amortize keep-alive across \
         {} functions — waste collapses versus fixed-600s and the Zipf tail \
         goes warm — but every cross-function claim pays specialization; \
         {verdict}, and the zero-waste row never leaves the frontier",
        cfg.tenant.functions
    ));
    report
}

/// E16 via the shared experiment config (the `experiment sharing` path).
pub fn sharing(cfg: &ExpConfig) -> Report {
    sharing_with(&sharing_config(cfg))
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Reduced load for the structural unit tests; the full `--quick`
    /// grid (with its paper checks) runs once in
    /// `sharing_checks_pass_quick`.
    fn small_cfg() -> SharingConfig {
        SharingConfig {
            tenant: TenantConfig {
                functions: 300,
                duration_s: 30.0,
                total_rps: 60.0,
                seed: 0xE16,
                ..Default::default()
            },
            nodes: 4,
            cores_per_node: 8,
            runtimes: 4,
            target_per_key: 8,
            spec_costs_ms: vec![1.0, 64.0],
            host: Host::default(),
        }
    }

    #[test]
    fn sharing_checks_pass_quick() {
        let r = sharing(&ExpConfig::quick());
        assert!(r.all_pass(), "failures: {:#?}", r.failures());
    }

    #[test]
    fn grid_covers_exclusive_rows_and_the_sharing_sweep() {
        let cfg = small_cfg();
        let cells = sharing_cells(&cfg);
        // 2 drivers x 4 policies exclusive + 2 modes x 2 costs universal.
        assert_eq!(cells.len(), 8 + 4);
        for name in ["cold-only", "fixed-600s", "histogram", "ewma"] {
            for d in [DriverKind::DockerWarm, DriverKind::IncludeOsCold] {
                assert!(
                    cells.iter().any(|c| c.driver == d
                        && c.policy == name
                        && c.sharing == "exclusive"),
                    "missing exclusive cell {d:?}+{name}"
                );
            }
        }
        for mode in ["runtime-4", "promiscuous"] {
            for &cost in &cfg.spec_costs_ms {
                assert!(
                    cells.iter().any(|c| c.sharing == mode && c.spec_ms == cost),
                    "missing universal cell {mode}+{cost}ms"
                );
            }
        }
        let n = cells[0].requests;
        assert!(n > 500, "trace too small: {n}");
        assert!(cells.iter().all(|c| c.requests == n), "every cell serves the full trace");
    }

    #[test]
    fn every_cell_conserves_dispatch_classes() {
        for c in sharing_cells(&small_cfg()) {
            assert_eq!(
                c.warm_hits + c.specializations + c.cold_starts,
                c.requests,
                "{}",
                c.label()
            );
        }
    }

    #[test]
    fn universal_rows_amortize_waste_below_fixed_keepalive() {
        let cells = sharing_cells(&small_cfg());
        let fixed = exclusive(&cells, DriverKind::DockerWarm, "fixed-600s");
        assert!(fixed.idle_gb_seconds > 0.0);
        for c in universal(&cells) {
            assert!(
                c.idle_gb_seconds < fixed.idle_gb_seconds,
                "{}: {} !< {}",
                c.label(),
                c.idle_gb_seconds,
                fixed.idle_gb_seconds
            );
            assert!(c.specializations > 0, "{}", c.label());
        }
    }

    #[test]
    fn cold_only_unikernel_stays_zero_waste_and_on_frontier() {
        let cells = sharing_cells(&small_cfg());
        let inc = exclusive(&cells, DriverKind::IncludeOsCold, "cold-only");
        assert_eq!(inc.idle_gb_seconds, 0.0);
        assert_eq!(inc.monitor_events, 0);
        assert!(inc.on_frontier, "zero-waste row must stay on the frontier");
    }

    #[test]
    fn deterministic_report_per_seed() {
        let a = sharing_with(&small_cfg()).render();
        let b = sharing_with(&small_cfg()).render();
        assert_eq!(a, b);
        let mut other = small_cfg();
        other.tenant.seed = 1;
        let c = sharing_with(&other).render();
        assert_ne!(a, c);
    }
}
