//! E1–E3: the startup-latency figures (Figs 1–3).
//!
//! Each figure is a closed-loop `hey` sweep over parallelism with the
//! CppCMS-like gateway in front of the startup technology, exactly as in
//! §III-B.  Checks pin the paper's reported medians/bands; orderings are
//! asserted in `rust/tests/` as well.

use super::ExpConfig;
use crate::metrics::Recorder;
use crate::report::Report;
use crate::virt::Tech;
use crate::workload::{record, run_gateway_front};

/// Run one technology across the parallelism sweep, recording
/// `"<tech>@<parallelism>"` series into `rec`.
pub fn sweep(tech: Tech, cfg: &ExpConfig, rec: &mut Recorder) {
    for (i, &p) in cfg.parallelisms.iter().enumerate() {
        let result = run_gateway_front(
            tech.pipeline(),
            p,
            cfg.requests,
            cfg.host,
            cfg.seed ^ ((i as u64) << 32) ^ tech.name().len() as u64,
        );
        record(rec, &format!("{}@{}", tech.name(), p), &result);
    }
}

fn add_sweep_series(report: &mut Report, rec: &Recorder, techs: &[Tech], cfg: &ExpConfig) {
    for &t in techs {
        for &p in &cfg.parallelisms {
            let label = format!("{}@{}", t.name(), p);
            if let Some(s) = rec.stats(&label) {
                report.add_series(&label, s);
            }
        }
    }
}

/// Fig 1: startup times with OCI runtimes (runc, gVisor, Kata) and
/// Firecracker under parallelism 1..40.
pub fn fig1(cfg: &ExpConfig) -> Report {
    let techs = [Tech::Runc, Tech::Gvisor, Tech::Kata, Tech::Firecracker];
    let mut rec = Recorder::new();
    for &t in &techs {
        sweep(t, cfg, &mut rec);
    }
    let mut report = Report::new(
        "Fig 1: startup times with OCI runtimes and Firecracker (boxplot p1/p99)",
    );
    add_sweep_series(&mut report, &rec, &techs, cfg);

    let p50 = |l: &str| rec.quantile(l, 0.5).unwrap_or(f64::NAN);
    let lo = cfg.parallelisms[0];
    // §III-C/D single-start medians.
    report.check(&format!("runc@{lo}"), "p50", p50(&format!("runc@{lo}")), 150.0, 0.25);
    report.check(
        &format!("firecracker@{lo}"),
        "p50",
        p50(&format!("firecracker@{lo}")),
        125.0,
        0.25,
    );
    // gVisor beats runc (Fig 1 finding).
    let g = p50(&format!("gvisor@{lo}"));
    let r = p50(&format!("runc@{lo}"));
    report.band("gvisor<runc", "p50 ratio", g / r, 0.0, 0.95);
    // Kata overload: median 2.2 s, p99 3.3 s at 40 parallel.
    if cfg.parallelisms.contains(&40) {
        report.check("kata@40", "p50", p50("kata@40"), 2200.0, 0.30);
        report.check(
            "kata@40",
            "p99",
            rec.quantile("kata@40", 0.99).unwrap_or(f64::NAN),
            3300.0,
            0.35,
        );
        // OCI options scale "fairly well" to 20, degrade at 40.
        for t in ["runc", "gvisor", "firecracker"] {
            if cfg.parallelisms.contains(&20) {
                let r20 = p50(&format!("{t}@20")) / p50(&format!("{t}@{lo}"));
                report.band(&format!("{t} 20-vs-{lo} blowup"), "p50 ratio", r20, 0.0, 2.0);
            }
            let r40 = p50(&format!("{t}@40")) / p50(&format!("{t}@{lo}"));
            report.band(&format!("{t} 40-vs-{lo} blowup"), "p50 ratio", r40, 1.15, 12.0);
        }
    }
    report.note("paper omits Kata from the overload plot; we keep it in the series");
    report
}

/// Fig 2: startup times through the full Docker stack.
pub fn fig2(cfg: &ExpConfig) -> Report {
    let techs = [Tech::DockerRunc, Tech::DockerGvisor, Tech::DockerKata];
    let mut rec = Recorder::new();
    for &t in &techs {
        sweep(t, cfg, &mut rec);
    }
    let mut report = Report::new("Fig 2: startup times with Docker (full stack)");
    add_sweep_series(&mut report, &rec, &techs, cfg);

    let p50 = |l: &str| rec.quantile(l, 0.5).unwrap_or(f64::NAN);
    let lo = cfg.parallelisms[0];
    // §III-C: Alpine via Docker daemon ≈ 450 ms.
    report.check(
        &format!("docker-runc@{lo}"),
        "p50",
        p50(&format!("docker-runc@{lo}")),
        450.0,
        0.25,
    );
    // §III-D: >10 s under the highest measured load.
    if cfg.parallelisms.contains(&40) {
        report.band("docker-runc@40", "p50", p50("docker-runc@40"), 10_000.0, 40_000.0);
    }
    // Fig 2 finding: the Docker layers hide most runtime differences —
    // the docker-kata / docker-gvisor median gap is much smaller than the
    // OCI-level kata / gvisor gap (~6x).
    let spread = p50(&format!("docker-kata@{lo}")) / p50(&format!("docker-gvisor@{lo}"));
    report.band("docker hides runtime diff", "p50 ratio", spread, 1.0, 3.5);
    report
}

/// Fig 3: processes and unikernels (+ the /noop gateway overhead).
pub fn fig3(cfg: &ExpConfig) -> Report {
    let techs = [
        Tech::Process,
        Tech::PythonProcess,
        Tech::PythonScipy,
        Tech::Solo5Spt,
        Tech::IncludeOsHvt,
    ];
    let mut rec = Recorder::new();
    for &t in &techs {
        sweep(t, cfg, &mut rec);
    }
    // /noop: gateway front with an empty startup pipeline.
    for (i, &p) in cfg.parallelisms.iter().enumerate() {
        let result =
            run_gateway_front(Vec::new(), p, cfg.requests, cfg.host, cfg.seed ^ (i as u64) << 17);
        record(&mut rec, &format!("noop@{p}"), &result);
    }

    let mut report = Report::new("Fig 3: startup times with processes and unikernels");
    add_sweep_series(&mut report, &rec, &techs, cfg);
    for &p in &cfg.parallelisms {
        if let Some(s) = rec.stats(&format!("noop@{p}")) {
            report.add_series(&format!("noop@{p}"), s);
        }
    }

    let p50 = |l: &str| rec.quantile(l, 0.5).unwrap_or(f64::NAN);
    let lo = cfg.parallelisms[0];
    // Fig 3: IncludeOS hvt 8–15 ms under moderate load (measure at 10).
    let moderate = if cfg.parallelisms.contains(&10) { 10 } else { lo };
    report.band(
        &format!("includeos-hvt@{moderate}"),
        "p50",
        p50(&format!("includeos-hvt@{moderate}")),
        8.0,
        15.0,
    );
    // §III-E: scipy adds ≈ 80 ms over bare python.
    let scipy_delta =
        p50(&format!("python+scipy@{lo}")) - p50(&format!("python@{lo}"));
    report.check("scipy import delta", "p50", scipy_delta, 80.0, 0.15);
    // spt ≈ process; both well under hvt.
    let spt = p50(&format!("solo5-spt@{lo}"));
    let proc = p50(&format!("process@{lo}"));
    report.band("spt-vs-process", "p50 ratio", spt / proc, 0.5, 2.5);
    report.band("process<hvt", "p50 ratio", proc / p50(&format!("includeos-hvt@{lo}")), 0.0, 0.8);
    // §III-E: noop ≈ 0.7 ms at low load.
    report.check(&format!("noop@{lo}"), "p50", p50(&format!("noop@{lo}")), 0.85, 0.35);
    if cfg.parallelisms.contains(&40) {
        let grow = p50("noop@40") / p50(&format!("noop@{lo}"));
        report.band("noop overload growth", "p50 ratio", grow, 1.2, 10.0);
    }
    report
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig1_checks_pass_quick() {
        let r = fig1(&ExpConfig::quick());
        assert!(r.all_pass(), "failures: {:#?}", r.failures());
    }

    #[test]
    fn fig2_checks_pass_quick() {
        let r = fig2(&ExpConfig::quick());
        assert!(r.all_pass(), "failures: {:#?}", r.failures());
    }

    #[test]
    fn fig3_checks_pass_quick() {
        let r = fig3(&ExpConfig::quick());
        assert!(r.all_pass(), "failures: {:#?}", r.failures());
    }
}
