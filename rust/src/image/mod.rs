//! Image store and build pipeline (S4): deploy-time function builds,
//! node-local image caching, and transfer costs — the paper's §IV-C
//! "distribution of function images" limitation, made measurable.

use std::collections::HashMap;

use crate::sim::snap::{Dec, Enc};
use crate::virt::Tech;

/// How a function image is produced at deploy time (§IV-B).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BuildKind {
    /// IncludeOS `boot` build: C++ compile + link into a solo5 image.
    IncludeOsBoot,
    /// Docker build: FDK wrapper image assembly + layer creation.
    DockerFdk,
}

impl BuildKind {
    /// Median deploy/build time in seconds (§IV-B: "the C++ compilation in
    /// case of IncludeOS takes about 3.5 seconds, while Docker requires
    /// 9–10 seconds to create the image").
    pub fn build_seconds(&self) -> f64 {
        match self {
            BuildKind::IncludeOsBoot => 3.5,
            BuildKind::DockerFdk => 9.5,
        }
    }
}

/// A deployable function image.
#[derive(Clone, Debug)]
pub struct Image {
    pub name: String,
    pub tech: Tech,
    pub bytes: u64,
}

impl Image {
    pub fn for_function(name: &str, tech: Tech) -> Image {
        Image { name: name.to_string(), tech, bytes: tech.image_bytes() }
    }
}

/// Per-node image cache.  In a cold-only platform the image must be local
/// to every node that may receive a request (§IV-C), so the cache-miss
/// transfer cost and the total cache footprint are first-class metrics.
#[derive(Default)]
pub struct NodeCache {
    images: HashMap<String, u64>,
    pub capacity_bytes: Option<u64>, // detlint: allow(DL005) config-derived constant
    used_bytes: u64,
    pub hits: u64,
    pub misses: u64,
}

impl NodeCache {
    pub fn new(capacity_bytes: Option<u64>) -> NodeCache {
        NodeCache { capacity_bytes, ..Default::default() }
    }

    pub fn used_bytes(&self) -> u64 {
        self.used_bytes
    }

    pub fn contains(&self, name: &str) -> bool {
        self.images.contains_key(name)
    }

    /// Names of every resident image (the cluster scheduler's replica
    /// index seeds itself from this at attach time).
    pub fn names(&self) -> impl Iterator<Item = &str> {
        // detlint: allow(DL002) consumer inserts into BTreeSets (scheduler attach)
        self.images.keys().map(String::as_str)
    }

    /// Look up an image; on miss, returns the bytes that must be fetched
    /// and inserts it (evicting nothing — capacity overflow is an error the
    /// cluster scheduler must avoid, mirroring the paper's "extreme setting
    /// on all machines" discussion).
    pub fn fetch(&mut self, img: &Image) -> Result<Option<u64>, CacheFull> {
        if self.contains(&img.name) {
            self.hits += 1;
            return Ok(None);
        }
        if let Some(cap) = self.capacity_bytes {
            if self.used_bytes + img.bytes > cap {
                return Err(CacheFull { need: img.bytes, free: cap - self.used_bytes });
            }
        }
        self.misses += 1;
        self.used_bytes += img.bytes;
        self.images.insert(img.name.clone(), img.bytes);
        Ok(Some(img.bytes))
    }

    /// Snapshot codec (S27): resident images in sorted-name order plus
    /// the counters.  `capacity_bytes` is config-derived and keeps the
    /// value the fresh construction set.
    pub fn encode(&self, w: &mut Enc) {
        // detlint: allow(DL002) collected then sorted by name below
        let mut names: Vec<(&String, &u64)> = self.images.iter().collect();
        names.sort_unstable();
        w.len(names.len());
        for (name, &bytes) in names {
            w.str(name);
            w.u64(bytes);
        }
        w.u64(self.used_bytes);
        w.u64(self.hits);
        w.u64(self.misses);
    }

    /// Inverse of [`Self::encode`], replacing the resident set.
    pub fn restore(&mut self, r: &mut Dec) {
        self.images.clear();
        let n = r.len();
        for _ in 0..n {
            let name = r.str();
            let bytes = r.u64();
            self.images.insert(name, bytes);
        }
        self.used_bytes = r.u64();
        self.hits = r.u64();
        self.misses = r.u64();
    }

    pub fn evict(&mut self, name: &str) -> bool {
        if let Some(b) = self.images.remove(name) {
            self.used_bytes -= b;
            true
        } else {
            false
        }
    }
}

#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CacheFull {
    pub need: u64,
    pub free: u64,
}

impl std::fmt::Display for CacheFull {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "image cache full: need {} bytes, {} free", self.need, self.free)
    }
}

impl std::error::Error for CacheFull {}

/// Bytes needed to pre-seed `n_nodes` with one function image of each
/// listed technology — the cluster-wide footprint comparison that makes
/// unikernel images attractive for cold-only scheduling.
pub fn cluster_footprint_bytes(techs: &[Tech], n_nodes: u64) -> u64 {
    techs.iter().map(|t| t.image_bytes()).sum::<u64>() * n_nodes
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn build_times_match_paper() {
        assert_eq!(BuildKind::IncludeOsBoot.build_seconds(), 3.5);
        assert!((9.0..=10.0).contains(&BuildKind::DockerFdk.build_seconds()));
    }

    #[test]
    fn cache_hit_after_fetch() {
        let mut c = NodeCache::new(None);
        let img = Image::for_function("f", Tech::IncludeOsHvt);
        assert_eq!(c.fetch(&img).unwrap(), Some(2_500_000));
        assert_eq!(c.fetch(&img).unwrap(), None);
        assert_eq!((c.hits, c.misses), (1, 1));
    }

    #[test]
    fn capacity_enforced() {
        let mut c = NodeCache::new(Some(3_000_000));
        let a = Image::for_function("a", Tech::IncludeOsHvt); // 2.5 MB
        let b = Image::for_function("b", Tech::IncludeOsHvt);
        assert!(c.fetch(&a).is_ok());
        let err = c.fetch(&b).unwrap_err();
        assert_eq!(err.need, 2_500_000);
        assert_eq!(err.free, 500_000);
    }

    #[test]
    fn evict_frees_space() {
        let mut c = NodeCache::new(Some(3_000_000));
        let a = Image::for_function("a", Tech::IncludeOsHvt);
        c.fetch(&a).unwrap();
        assert!(c.evict("a"));
        assert!(!c.evict("a"));
        assert_eq!(c.used_bytes(), 0);
        let b = Image::for_function("b", Tech::IncludeOsHvt);
        assert!(c.fetch(&b).is_ok());
    }

    #[test]
    fn unikernel_cluster_footprint_far_smaller() {
        // §II-C + §IV-C: caching images on *all* machines is ~28x cheaper
        // with IncludeOS (2.5 MB) than with Firecracker images (70 MB).
        let uni = cluster_footprint_bytes(&[Tech::IncludeOsHvt], 1000);
        let fc = cluster_footprint_bytes(&[Tech::Firecracker], 1000);
        assert_eq!(uni, 2_500_000_000);
        assert!(fc / uni == 28);
    }
}
