//! Simulation-mirroring live platform (S29): the DES warm-pool dispatch
//! semantics served over real HTTP, in real time.
//!
//! The repo has two measurement planes (EXPERIMENTS.md "Simulation vs.
//! live measurement").  The DES plane (`platform::run_platform`) is
//! byte-identical per seed; this module is the *live* plane: the same
//! [`WarmPool`](crate::fnplat::pool::WarmPool) claim/release state
//! machine, the same driver pipelines
//! ([`exec::heat_pipelines`](crate::exec::heat_pipelines)), the same
//! deterministic per-request RNG streams — but executed behind the
//! rebuilt gateway (S6) with real sockets, real threads, and real
//! scaled sleeps.  E18 `livecheck` replays one trace through both
//! planes and asserts the measured per-class latency distributions land
//! inside tolerance bands around the DES prediction.
//!
//! What is shared with the DES, by construction:
//! - warm/specialized/cold classification: [`WarmPool::dispatch_shared`]
//!   with the same [`SharingMode::key_for`] routing keys;
//! - keep-alive policy: a fixed window (`keep_ns`), applied through
//!   [`WarmPool::release_shared_until`] in *modeled* time;
//! - startup/exec cost: sampled from the identical `Step` distributions
//!   the DES dispatch tail composes (`platform/sim.rs`).
//!
//! What is real: connection handling, thread scheduling, lock
//! contention, and the sleeps themselves — which is why the live side
//! of E18 is band-gated, never byte-pinned.
//!
//! Wall-clock use here is the point (the modeled clock is derived from
//! `Instant::now`), so `src/live/` is a committed DL001 island in
//! `rust/detlint.allow`.

pub mod loadgen;

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::exec::{heat_pipelines, RealtimeStartup};
use crate::fnplat::pool::{Dispatch, WarmPool};
use crate::fnplat::DriverKind;
use crate::gateway::http::{Handler, Request, Response, Server};
use crate::platform::SharingMode;
use crate::sim::Rng;

/// Configuration for a live platform instance.
#[derive(Clone, Debug)]
pub struct LiveConfig {
    pub driver: DriverKind,
    /// Worker nodes; each holds its own warm pool.
    pub nodes: usize,
    /// Deployed functions, invoked as `/invoke/{func}/{index}`.
    pub functions: u32,
    pub sharing: SharingMode,
    /// Fixed keep-alive window in *modeled* ns (mirrors the DES's
    /// `FixedKeepAlive` lifecycle policy).
    pub keep_ns: u64,
    /// Function-body execution cost (ms), the DES's `exec_ms`.
    pub exec_ms: f64,
    /// Real seconds slept per modeled second: 1.0 = model-faithful,
    /// 0.0 = no sleeps (unit tests).
    pub time_scale: f64,
    pub seed: u64,
    /// Gateway worker threads.
    pub workers: usize,
}

impl Default for LiveConfig {
    fn default() -> LiveConfig {
        LiveConfig {
            driver: DriverKind::DockerWarm,
            nodes: 4,
            functions: 24,
            sharing: SharingMode::PerRuntime { runtimes: 4 },
            keep_ns: 300_000_000, // 300 ms
            exec_ms: crate::fnplat::DEFAULT_EXEC_MS,
            time_scale: 1.0,
            seed: 0xE18,
            workers: 8,
        }
    }
}

/// Per-class invocation counters (conservation:
/// `warm + specialized + cold == requests`).
#[derive(Default)]
pub struct LiveStats {
    pub requests: AtomicU64,
    pub warm: AtomicU64,
    pub specialized: AtomicU64,
    pub cold: AtomicU64,
}

/// One worker node: a warm pool guarded by a real lock (the live
/// analogue of the DES's per-node `NodeState`) plus an in-flight gauge
/// for least-loaded routing.
struct LiveNode {
    pool: Mutex<WarmPool>,
    inflight: AtomicU64,
}

/// Outcome of one live invocation.
#[derive(Clone, Copy, Debug)]
pub struct InvokeOutcome {
    pub class: Dispatch,
    /// Modeled startup+exec latency (ns, unscaled) — what the DES would
    /// have charged for this claim class.
    pub modeled_ns: u64,
    pub node: usize,
}

/// The live platform: N nodes, warm-preferring least-loaded routing,
/// scaled-realtime execution.
pub struct LivePlatform {
    cfg: LiveConfig,
    nodes: Vec<LiveNode>,
    /// `func -> sharing key`, precomputed like the DES's `route_keys`.
    route_keys: Vec<String>,
    /// `[cold, warm, specialized]` startup pipelines.
    pipelines: [RealtimeStartup; 3],
    t0: Instant,
    /// Real-ns-per-modeled-ns divisor for the modeled clock (the
    /// configured `time_scale`, floored so 0.0 test runs still get a
    /// monotonic clock).
    clock_scale: f64,
    pub stats: LiveStats,
}

/// Stable wire name for a claim class — the `"class"` annotation E18
/// classifies measured requests by.
pub fn class_name(d: Dispatch) -> &'static str {
    match d {
        Dispatch::Warm => "warm",
        Dispatch::Specialized => "specialized",
        Dispatch::Cold => "cold",
    }
}

impl LivePlatform {
    pub fn new(cfg: LiveConfig) -> LivePlatform {
        assert!(cfg.nodes >= 1, "need at least one node");
        assert!(cfg.functions >= 1, "need at least one function");
        assert!(cfg.time_scale >= 0.0);
        let mem = cfg.driver.tech().warm_memory_bytes();
        let nodes = (0..cfg.nodes)
            .map(|_| LiveNode {
                pool: Mutex::new(WarmPool::new(cfg.keep_ns, mem)),
                inflight: AtomicU64::new(0),
            })
            .collect();
        let route_keys = (0..cfg.functions)
            .map(|f| cfg.sharing.key_for(f, &format!("fn-{f}")))
            .collect();
        let pipelines = heat_pipelines(cfg.driver, cfg.exec_ms, cfg.time_scale);
        let clock_scale = if cfg.time_scale > 0.0 { cfg.time_scale } else { 1.0 };
        LivePlatform {
            cfg,
            nodes,
            route_keys,
            pipelines,
            t0: Instant::now(),
            clock_scale,
            stats: LiveStats::default(),
        }
    }

    pub fn config(&self) -> &LiveConfig {
        &self.cfg
    }

    /// The modeled clock: real elapsed ns divided by the time scale, so
    /// `keep_ns` means the same thing to this pool as to the DES's.
    pub fn now_modeled_ns(&self) -> u64 {
        (self.t0.elapsed().as_nanos() as f64 / self.clock_scale) as u64
    }

    /// Serve one invocation: route, claim, sleep out the sampled
    /// pipeline, release back into the keep-alive window.
    pub fn invoke(&self, func: u32, index: u64) -> InvokeOutcome {
        let key = &self.route_keys[func as usize];
        let now = self.now_modeled_ns();
        // Warm-preferring least-loaded routing: a node holding a warm
        // slot for this key beats any count of idle cores elsewhere
        // (the DES scheduler's warm-first placement); ties break on
        // in-flight load, then node id.
        let mut best = 0usize;
        let mut best_rank = (u8::MAX, u64::MAX, usize::MAX);
        for (id, n) in self.nodes.iter().enumerate() {
            let warm = n.pool.lock().unwrap().warm_available(key, now) > 0;
            let rank = (u8::from(!warm), n.inflight.load(Ordering::Relaxed), id);
            if rank < best_rank {
                best_rank = rank;
                best = id;
            }
        }
        let node = &self.nodes[best];
        node.inflight.fetch_add(1, Ordering::Relaxed);
        // The claim itself classifies the request (another thread may
        // have taken the warm slot since routing looked — the claim,
        // not the routing hint, is the truth the response reports).
        let class = node.pool.lock().unwrap().dispatch_shared(key, func, now);
        let pipeline = match class {
            Dispatch::Cold => &self.pipelines[0],
            Dispatch::Warm => &self.pipelines[1],
            Dispatch::Specialized => &self.pipelines[2],
        };
        // Per-request RNG stream: a pure function of (seed, index), so
        // the sampled costs are reproducible across runs regardless of
        // arrival interleaving.
        let mut root = Rng::new(self.cfg.seed);
        let mut rng = root.fork(index);
        let modeled_ns = pipeline.apply(&mut rng);
        let done = self.now_modeled_ns();
        node.pool.lock().unwrap().release_shared_until(
            key,
            func,
            done,
            done.saturating_add(self.cfg.keep_ns),
        );
        node.inflight.fetch_sub(1, Ordering::Relaxed);
        self.stats.requests.fetch_add(1, Ordering::Relaxed);
        match class {
            Dispatch::Warm => self.stats.warm.fetch_add(1, Ordering::Relaxed),
            Dispatch::Specialized => self.stats.specialized.fetch_add(1, Ordering::Relaxed),
            Dispatch::Cold => self.stats.cold.fetch_add(1, Ordering::Relaxed),
        };
        InvokeOutcome { class, modeled_ns, node: best }
    }

    pub fn stats_json(&self) -> String {
        format!(
            "{{\"requests\":{},\"warm\":{},\"specialized\":{},\"cold\":{}}}",
            self.stats.requests.load(Ordering::Relaxed),
            self.stats.warm.load(Ordering::Relaxed),
            self.stats.specialized.load(Ordering::Relaxed),
            self.stats.cold.load(Ordering::Relaxed),
        )
    }

    /// The gateway handler.  Routes:
    /// - `POST|GET /invoke/{func}/{index}` → JSON with the claim-class
    ///   annotation (`{"class":"warm",...}`) E18 classifies by;
    /// - `GET /stats` → per-class counters;
    /// - `GET /healthz` → liveness.
    pub fn handler(self: &Arc<Self>) -> Handler {
        let p = Arc::clone(self);
        Arc::new(move |req: &Request| {
            if req.path == "/healthz" {
                return Response::ok("ok");
            }
            if req.path == "/stats" {
                return Response::json(p.stats_json());
            }
            let Some(rest) = req.path.strip_prefix("/invoke/") else {
                return Response::not_found();
            };
            let mut parts = rest.splitn(2, '/');
            let Some(func) = parts.next().and_then(|s| s.parse::<u32>().ok()) else {
                return Response::bad_request("bad function id");
            };
            let Some(index) = parts.next().and_then(|s| s.parse::<u64>().ok()) else {
                return Response::bad_request("bad request index");
            };
            if func >= p.cfg.functions {
                return Response::not_found();
            }
            let out = p.invoke(func, index);
            Response::json(format!(
                "{{\"class\":\"{}\",\"modeled_ms\":{:.6},\"node\":{},\"func\":{},\"index\":{}}}",
                class_name(out.class),
                out.modeled_ns as f64 / 1e6,
                out.node,
                func,
                index
            ))
        })
    }
}

/// A running live platform behind its gateway.
pub struct LiveServer {
    pub platform: Arc<LivePlatform>,
    server: Server,
}

impl LiveServer {
    pub fn addr(&self) -> std::net::SocketAddr {
        self.server.addr()
    }

    pub fn gateway_stats(&self) -> Arc<crate::gateway::http::GatewayStats> {
        Arc::clone(&self.server.stats)
    }

    pub fn shutdown(self) {
        self.server.shutdown()
    }
}

/// Bind an ephemeral loopback port and serve `cfg`.
pub fn start(cfg: LiveConfig) -> std::io::Result<LiveServer> {
    let workers = cfg.workers.max(1);
    let platform = Arc::new(LivePlatform::new(cfg));
    let handler = platform.handler();
    let server = Server::start("127.0.0.1:0", workers, handler)?;
    Ok(LiveServer { platform, server })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gateway::http::http_request;

    fn quick_cfg() -> LiveConfig {
        LiveConfig { time_scale: 0.0, workers: 4, ..LiveConfig::default() }
    }

    #[test]
    fn heat_transitions_mirror_the_pool() {
        let p = LivePlatform::new(quick_cfg());
        // First touch of a runtime key: cold.
        assert_eq!(p.invoke(0, 0).class, Dispatch::Cold);
        // Same function inside the keep window: warm.
        assert_eq!(p.invoke(0, 1).class, Dispatch::Warm);
        // Different function, same runtime key (4 % 4 == 0): the
        // runtime is warm but the state is not — specialized.
        assert_eq!(p.invoke(4, 2).class, Dispatch::Specialized);
        let s = &p.stats;
        assert_eq!(s.requests.load(Ordering::Relaxed), 3);
        assert_eq!(
            s.warm.load(Ordering::Relaxed)
                + s.specialized.load(Ordering::Relaxed)
                + s.cold.load(Ordering::Relaxed),
            3
        );
    }

    #[test]
    fn routing_reuses_the_warm_node() {
        let p = LivePlatform::new(quick_cfg());
        let first = p.invoke(1, 0);
        let again = p.invoke(1, 1);
        assert_eq!(again.class, Dispatch::Warm);
        assert_eq!(again.node, first.node, "warm slot must attract the repeat");
    }

    #[test]
    fn modeled_cost_orders_by_class() {
        let p = LivePlatform::new(quick_cfg());
        let cold = p.invoke(2, 0);
        let warm = p.invoke(2, 1);
        assert!(cold.modeled_ns > warm.modeled_ns, "cold {} warm {}", cold.modeled_ns, warm.modeled_ns);
    }

    #[test]
    fn sampled_cost_is_reproducible_per_index() {
        let a = LivePlatform::new(quick_cfg());
        let b = LivePlatform::new(quick_cfg());
        // Same seed, same index, same class => identical modeled cost.
        assert_eq!(a.invoke(3, 7).modeled_ns, b.invoke(3, 7).modeled_ns);
    }

    #[test]
    fn http_round_trip_with_annotations() {
        let srv = start(quick_cfg()).unwrap();
        let addr = srv.addr();
        let (st, body) = http_request(addr, "POST", "/invoke/0/0", b"").unwrap();
        assert_eq!(st, 200);
        let text = String::from_utf8(body).unwrap();
        assert!(text.contains("\"class\":\"cold\""), "{text}");
        let (st, body) = http_request(addr, "POST", "/invoke/0/1", b"").unwrap();
        assert_eq!(st, 200);
        assert!(String::from_utf8(body).unwrap().contains("\"class\":\"warm\""));
        let (st, body) = http_request(addr, "GET", "/stats", b"").unwrap();
        assert_eq!(st, 200);
        assert!(String::from_utf8(body).unwrap().contains("\"requests\":2"));
        srv.shutdown();
    }

    #[test]
    fn bad_routes_are_4xx() {
        let srv = start(quick_cfg()).unwrap();
        let addr = srv.addr();
        assert_eq!(http_request(addr, "POST", "/invoke/zz/0", b"").unwrap().0, 400);
        assert_eq!(http_request(addr, "POST", "/invoke/0", b"").unwrap().0, 400);
        assert_eq!(http_request(addr, "POST", "/invoke/9999/0", b"").unwrap().0, 404);
        assert_eq!(http_request(addr, "GET", "/nope", b"").unwrap().0, 404);
        assert_eq!(http_request(addr, "GET", "/healthz", b"").unwrap().0, 200);
        srv.shutdown();
    }
}
