//! Open-loop load generator (S29): replays a deterministic tenant trace
//! (`workload::tenants`) against a live gateway over keep-alive
//! connections.
//!
//! Open-loop means arrivals fire on the trace's schedule, not on
//! response completion: sender `i % senders` owns arrival `i`, sleeps
//! until the arrival's scaled due-time, fires, and measures latency
//! from the *scheduled* send instant — so a slow server shows up as
//! latency (coordinated-omission-free), not as a quietly stretched
//! schedule.  This is the same trace representation the DES consumes
//! (`PlatformLoad::Tenants`), which is what lets E18 `livecheck` replay
//! one trace through both planes.

use std::net::SocketAddr;
use std::time::{Duration, Instant};

use crate::gateway::http::HttpClient;
use crate::workload::tenants::TenantTrace;

/// One measured request.
#[derive(Clone, Debug)]
pub struct LiveSample {
    pub func: u32,
    /// Position in the trace (the per-request RNG salt server-side).
    pub index: u64,
    /// Server-annotated claim class (`warm` / `specialized` / `cold`),
    /// or `error` when the request failed.
    pub class: String,
    /// Measured latency from the scheduled arrival to the response (ns).
    pub latency_ns: u64,
    /// Server-reported modeled (unscaled) cost for the claim class (ns).
    pub modeled_ns: u64,
    pub status: u16,
}

/// A completed replay.
#[derive(Debug, Default)]
pub struct LoadgenReport {
    /// All samples, in trace order.
    pub samples: Vec<LiveSample>,
    pub errors: u64,
}

impl LoadgenReport {
    /// Measured latencies (ms) for one class, in trace order.
    pub fn class_latencies_ms(&self, class: &str) -> Vec<f64> {
        self.samples
            .iter()
            .filter(|s| s.class == class)
            .map(|s| s.latency_ns as f64 / 1e6)
            .collect()
    }

    pub fn count(&self, class: &str) -> usize {
        self.samples.iter().filter(|s| s.class == class).count()
    }

    /// One-line per-class summary for the CLI.
    pub fn summary(&self) -> String {
        let q = |class: &str| {
            let mut v = self.class_latencies_ms(class);
            v.sort_by(|a, b| a.partial_cmp(b).unwrap());
            if v.is_empty() { 0.0 } else { v[v.len() / 2] }
        };
        format!(
            "{} requests: {} warm (p50 {:.2} ms), {} specialized (p50 {:.2} ms), {} cold (p50 {:.2} ms), {} errors",
            self.samples.len(),
            self.count("warm"),
            q("warm"),
            self.count("specialized"),
            q("specialized"),
            self.count("cold"),
            q("cold"),
            self.errors,
        )
    }
}

/// Extract a JSON string field from a flat response body (the gateway's
/// annotation objects are hand-rolled flat JSON; a full parser would be
/// overkill for `"class":"warm"`).
pub fn json_str_field(text: &str, key: &str) -> Option<String> {
    let pat = format!("\"{key}\":\"");
    let start = text.find(&pat)? + pat.len();
    let end = text[start..].find('"')?;
    Some(text[start..start + end].to_string())
}

/// Extract a JSON number field from a flat response body.
pub fn json_num_field(text: &str, key: &str) -> Option<f64> {
    let pat = format!("\"{key}\":");
    let start = text.find(&pat)? + pat.len();
    let rest = &text[start..];
    let end = rest
        .find(|c: char| !(c.is_ascii_digit() || c == '.' || c == '-' || c == 'e' || c == 'E' || c == '+'))
        .unwrap_or(rest.len());
    rest[..end].parse().ok()
}

/// Replay `trace` against `addr`, scaling arrival times by
/// `time_scale` (1.0 = trace-faithful pacing, 0.0 = as fast as the
/// senders can go).  `senders` keep-alive connections share the work
/// round-robin by trace index.
pub fn run(
    addr: SocketAddr,
    trace: &TenantTrace,
    time_scale: f64,
    senders: usize,
) -> LoadgenReport {
    let senders = senders.max(1);
    let t0 = Instant::now();
    let mut per_thread: Vec<Vec<LiveSample>> = Vec::new();
    std::thread::scope(|scope| {
        let handles: Vec<_> = (0..senders)
            .map(|id| {
                scope.spawn(move || sender_loop(addr, trace, time_scale, senders, id, t0))
            })
            .collect();
        for h in handles {
            per_thread.push(h.join().expect("sender thread panicked"));
        }
    });
    let mut samples: Vec<LiveSample> = per_thread.into_iter().flatten().collect();
    samples.sort_by_key(|s| s.index);
    let errors = samples.iter().filter(|s| s.class == "error").count() as u64;
    LoadgenReport { samples, errors }
}

fn sender_loop(
    addr: SocketAddr,
    trace: &TenantTrace,
    time_scale: f64,
    senders: usize,
    id: usize,
    t0: Instant,
) -> Vec<LiveSample> {
    let mut out = Vec::new();
    let mut client = HttpClient::connect(addr).ok();
    for (i, &(t_ns, func)) in
        trace.arrivals.iter().enumerate().filter(|(i, _)| i % senders == id)
    {
        let due = t0 + Duration::from_nanos((t_ns as f64 * time_scale) as u64);
        let now = Instant::now();
        if due > now {
            std::thread::sleep(due - now);
        }
        let path = format!("/invoke/{func}/{i}");
        let result = match client.as_mut() {
            Some(c) => c.request("POST", &path, b""),
            None => Err(std::io::Error::new(std::io::ErrorKind::NotConnected, "no connection")),
        };
        // Open-loop latency: measured from the scheduled arrival, so
        // send-side lag counts against the server, not the schedule.
        let latency_ns = Instant::now().saturating_duration_since(due).as_nanos() as u64;
        match result {
            Ok((status, body)) => {
                let text = String::from_utf8_lossy(&body);
                let class = if status == 200 {
                    json_str_field(&text, "class").unwrap_or_else(|| "error".to_string())
                } else {
                    "error".to_string()
                };
                let modeled_ns = json_num_field(&text, "modeled_ms")
                    .map_or(0, |ms| (ms * 1e6) as u64);
                out.push(LiveSample {
                    func,
                    index: i as u64,
                    class,
                    latency_ns,
                    modeled_ns,
                    status,
                });
            }
            Err(_) => {
                out.push(LiveSample {
                    func,
                    index: i as u64,
                    class: "error".to_string(),
                    latency_ns,
                    modeled_ns: 0,
                    status: 0,
                });
                // One reconnect attempt so a single dropped connection
                // does not poison the rest of this sender's share.
                client = HttpClient::connect(addr).ok();
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::live::{start, LiveConfig};

    #[test]
    fn json_field_extraction() {
        let body = "{\"class\":\"specialized\",\"modeled_ms\":42.125,\"node\":3}";
        assert_eq!(json_str_field(body, "class").as_deref(), Some("specialized"));
        assert_eq!(json_num_field(body, "modeled_ms"), Some(42.125));
        assert_eq!(json_num_field(body, "node"), Some(3.0));
        assert_eq!(json_str_field(body, "missing"), None);
        assert_eq!(json_num_field(body, "missing"), None);
    }

    #[test]
    fn replays_a_trace_end_to_end() {
        let srv = start(LiveConfig {
            functions: 4,
            time_scale: 0.0,
            workers: 4,
            ..LiveConfig::default()
        })
        .unwrap();
        let trace = TenantTrace {
            functions: 4,
            arrivals: (0..40).map(|i| (i * 1000, (i % 4) as u32)).collect(),
        };
        let report = run(srv.addr(), &trace, 0.0, 3);
        assert_eq!(report.samples.len(), 40);
        assert_eq!(report.errors, 0, "{}", report.summary());
        for s in &report.samples {
            assert!(
                matches!(s.class.as_str(), "warm" | "specialized" | "cold"),
                "unexpected class {:?}",
                s.class
            );
        }
        // Conservation against the server's own counters.
        let (st, body) =
            crate::gateway::http::http_request(srv.addr(), "GET", "/stats", b"").unwrap();
        assert_eq!(st, 200);
        let text = String::from_utf8(body).unwrap();
        assert_eq!(json_num_field(&text, "requests"), Some(40.0));
        let on_wire = report.count("warm") + report.count("specialized") + report.count("cold");
        assert_eq!(on_wire, 40);
        srv.shutdown();
    }
}
