//! Checkpoint file format (S27): a versioned, self-describing binary
//! snapshot of one platform run at a virtual-time barrier.
//!
//! A checkpoint carries four header invariants — magic, format version,
//! a fingerprint of the *configuration* that produced it, and the barrier
//! cadence — followed by the state section (the engine core plus the
//! domain's canonical encoding, exactly the bytes the rolling state hash
//! folds over) and a restore supplement (shard-layout details that are
//! deliberately excluded from the hash because they vary with the shard
//! count).  Writes are atomic (tmp + rename), so a kill mid-write leaves
//! the previous barrier's snapshot intact; each barrier overwrites the
//! last, so a checkpoint file always holds the newest complete barrier.
//!
//! The resume contract: restoring a snapshot and running to completion is
//! **byte-identical** to the uninterrupted run — same report, same hash
//! chain — for every shard count and sweep-thread setting.  The
//! fingerprint makes config drift a hard error instead of a silently
//! diverging resume; it hashes everything that shapes the event stream
//! (topology, load arrivals, fault plan, seed) and nothing that does not
//! (checkpoint paths, wall-clock knobs).

use std::fs;
use std::io::{Error, ErrorKind};

use crate::sim::snap::{Dec, Enc, Fnv};
use crate::workload::tenants::TenantTrace;

use super::{ImageSeeding, PlatformConfig, PlatformLoad};

/// File magic: "coldfaas checkpoint, layout 1".
pub const MAGIC: [u8; 8] = *b"CFAASCK1";
pub const VERSION: u32 = 1;

/// Default barrier cadence when the loop is armed without an explicit
/// interval: every 10 virtual seconds — coarse enough to stay invisible
/// in the profile, fine enough that a killed fleet sweep loses little.
pub const DEFAULT_CHECKPOINT_NS: u64 = 10_000_000_000;

fn hash_tenants(h: &mut Fnv, tt: &TenantTrace) {
    h.u64(tt.functions as u64);
    h.u64(tt.arrivals.len() as u64);
    for &(at, func) in &tt.arrivals {
        h.u64(at);
        h.u64(func as u64);
    }
}

/// FNV fingerprint of every configuration input that shapes the event
/// stream.  Two configs with equal fingerprints replay the same events
/// from the same state; resuming under a different fingerprint is a
/// config-drift error caught at restore.
pub fn config_fingerprint(cfg: &PlatformConfig) -> u64 {
    let mut h = Fnv::new();
    h.str(cfg.driver.name);
    h.u64(cfg.driver.cold_steps.len() as u64);
    h.u64(cfg.driver.warm_steps.len() as u64);
    h.u64(cfg.driver.specialize_steps.len() as u64);
    h.u64(cfg.nodes as u64);
    h.u64(cfg.cores_per_node as u64);
    h.u64(cfg.mem_slots_per_node as u64);
    h.str(cfg.scheduler.name());
    h.u64(cfg.functions as u64);
    h.f64(cfg.exec_ms);
    h.u64(cfg.mem_bytes_per_slot);
    match cfg.seeding {
        ImageSeeding::FirstN(n) => {
            h.u64(1);
            h.u64(n as u64);
        }
        ImageSeeding::RoundRobin => {
            h.u64(2);
        }
    }
    h.f64(cfg.fabric_gbps);
    // The request path is a small closed enum tree: its Debug form is a
    // faithful, cheap canonical encoding.
    h.str(&format!("{:?}", cfg.path));
    match &cfg.load {
        PlatformLoad::ClosedLoop { parallelism, total, prewarm, gap_ns } => {
            h.u64(10);
            h.u64(*parallelism as u64);
            h.u64(*total);
            h.u64(u64::from(*prewarm));
            h.u64(*gap_ns);
        }
        PlatformLoad::OpenTrace(trace) => {
            h.u64(11);
            h.u64(trace.arrivals_ns.len() as u64);
            for &at in &trace.arrivals_ns {
                h.u64(at);
            }
        }
        PlatformLoad::Tenants(tt) => {
            h.u64(12);
            hash_tenants(&mut h, tt);
        }
        PlatformLoad::TenantsStreamed(tt) => {
            h.u64(13);
            hash_tenants(&mut h, tt);
        }
        PlatformLoad::Burst { requests, burst_ms } => {
            h.u64(14);
            h.u64(*requests);
            h.f64(*burst_ms);
        }
    }
    h.str(&cfg.sharing.name());
    h.u64(cfg.universal_prewarm as u64);
    h.u64(cfg.warmup_keep_ns);
    h.u64(u64::from(cfg.exact_latencies));
    h.u64(cfg.faults.node_faults.len() as u64);
    for f in &cfg.faults.node_faults {
        h.u64(f.node as u64);
        h.u64(f.down_at_ns);
        h.u64(f.up_at_ns);
        h.u64(u64::from(f.flush_cache));
        h.f64(f.straggler_mult);
        h.u64(f.straggler_ns);
    }
    h.u64(cfg.faults.fabric_faults.len() as u64);
    for f in &cfg.faults.fabric_faults {
        h.u64(f.from_ns);
        h.u64(f.until_ns);
        h.f64(f.slowdown);
    }
    h.u64(cfg.faults.max_retries as u64);
    h.u64(cfg.faults.retry_backoff_ns);
    h.u64(cfg.faults.spike_window_ns);
    h.u64(u64::from(cfg.faults.dry_run));
    h.u64(cfg.obs.telemetry_interval_ns);
    h.u64(cfg.shards as u64);
    h.u64(cfg.seed);
    h.finish()
}

/// One barrier snapshot, as stored on disk.
pub struct Checkpoint {
    /// [`config_fingerprint`] of the producing run.
    pub fingerprint: u64,
    /// Barrier cadence of the producing run (resume must match: the hash
    /// chain folds once per barrier).
    pub every_ns: u64,
    /// The virtual-time barrier this snapshot was taken at.
    pub t_barrier_ns: u64,
    /// Rolling hash chain *after* folding this barrier's state.
    pub chain: u64,
    /// Folds executed so far (this barrier included).
    pub folds: u64,
    /// Engine core + canonical domain state — the hashed bytes.
    pub state: Vec<u8>,
    /// Shard-layout restore details, excluded from the hash.
    pub supplement: Vec<u8>,
}

impl Checkpoint {
    /// Atomic write: serialize to `<path>.tmp`, then rename over `path`.
    pub fn write(&self, path: &str) -> std::io::Result<()> {
        let mut w = Enc::new();
        w.buf.extend_from_slice(&MAGIC);
        w.u32(VERSION);
        w.u64(self.fingerprint);
        w.u64(self.every_ns);
        w.u64(self.t_barrier_ns);
        w.u64(self.chain);
        w.u64(self.folds);
        w.len(self.state.len());
        w.buf.extend_from_slice(&self.state);
        w.len(self.supplement.len());
        w.buf.extend_from_slice(&self.supplement);
        let tmp = format!("{path}.tmp");
        fs::write(&tmp, &w.buf)?;
        fs::rename(&tmp, path)
    }

    /// Read and validate the header.  Wrong magic/version is an error; a
    /// *truncated* body panics through the section reader — a corrupt
    /// snapshot must never resume silently wrong.
    pub fn read(path: &str) -> std::io::Result<Checkpoint> {
        let buf = fs::read(path)?;
        let bad =
            |msg: String| Error::new(ErrorKind::InvalidData, format!("{path}: {msg}"));
        if buf.len() < MAGIC.len() || buf[..MAGIC.len()] != MAGIC {
            return Err(bad("not a coldfaas checkpoint (bad magic)".to_string()));
        }
        let mut r = Dec::new(&buf[MAGIC.len()..]);
        let version = r.u32();
        if version != VERSION {
            return Err(bad(format!("unsupported checkpoint version {version}")));
        }
        let fingerprint = r.u64();
        let every_ns = r.u64();
        let t_barrier_ns = r.u64();
        let chain = r.u64();
        let folds = r.u64();
        let n = r.len();
        let state = r.bytes(n).to_vec();
        let m = r.len();
        let supplement = r.bytes(m).to_vec();
        r.finish();
        Ok(Checkpoint { fingerprint, every_ns, t_barrier_ns, chain, folds, state, supplement })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fnplat::DriverKind;
    use crate::platform::DriverProfile;

    fn tmp(name: &str) -> String {
        let dir = std::env::temp_dir().join(format!("coldfaas-ckpt-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name).to_string_lossy().into_owned()
    }

    #[test]
    fn write_read_round_trips_every_field() {
        let ck = Checkpoint {
            fingerprint: 0xFEEDFACE,
            every_ns: 5_000_000_000,
            t_barrier_ns: 15_000_000_000,
            chain: 0xC0FFEE,
            folds: 3,
            state: vec![1, 2, 3, 4, 5],
            supplement: vec![9, 8],
        };
        let path = tmp("roundtrip.ckpt");
        ck.write(&path).unwrap();
        let back = Checkpoint::read(&path).unwrap();
        assert_eq!(back.fingerprint, ck.fingerprint);
        assert_eq!(back.every_ns, ck.every_ns);
        assert_eq!(back.t_barrier_ns, ck.t_barrier_ns);
        assert_eq!(back.chain, ck.chain);
        assert_eq!(back.folds, ck.folds);
        assert_eq!(back.state, ck.state);
        assert_eq!(back.supplement, ck.supplement);
        // No stray tmp file left behind by the atomic write.
        assert!(!std::path::Path::new(&format!("{path}.tmp")).exists());
    }

    #[test]
    fn foreign_files_are_rejected_not_restored() {
        let path = tmp("garbage.ckpt");
        std::fs::write(&path, b"definitely not a checkpoint").unwrap();
        let err = Checkpoint::read(&path).unwrap_err();
        assert!(err.to_string().contains("bad magic"), "{err}");
        assert!(Checkpoint::read(&tmp("missing.ckpt")).is_err());
    }

    #[test]
    fn fingerprint_tracks_event_shaping_inputs_only() {
        let base = || {
            PlatformConfig::single_node(
                DriverProfile::from_kind(DriverKind::DockerWarm),
                8,
            )
        };
        let a = config_fingerprint(&base());
        // Same config, same fingerprint.
        assert_eq!(a, config_fingerprint(&base()));
        // Checkpoint plumbing does not shape events: fingerprint-neutral.
        let mut neutral = base();
        neutral.checkpoint_every_ns = 123;
        neutral.checkpoint_path = Some("x.ckpt".to_string());
        neutral.state_hash = true;
        assert_eq!(a, config_fingerprint(&neutral));
        // Seed, topology, and load all change it.
        let mut seed = base();
        seed.seed ^= 1;
        assert_ne!(a, config_fingerprint(&seed));
        let mut nodes = base();
        nodes.nodes = 2;
        assert_ne!(a, config_fingerprint(&nodes));
        let mut load = base();
        load.load =
            PlatformLoad::ClosedLoop { parallelism: 1, total: 2, prewarm: false, gap_ns: 0 };
        assert_ne!(a, config_fingerprint(&load));
    }
}
