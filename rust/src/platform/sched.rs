//! Pluggable placement for the unified platform: warm-executor routing
//! (the Fn router consults every node's pool before starting anything)
//! plus the cold-placement policies the cluster literature argues about —
//! AWS-style co-location (Wang et al.), random spread, least-loaded, and
//! image/pool affinity.  Pure logic; the DES wiring lives in
//! [`super::sim`].

use crate::image::Image;
use crate::sim::Rng;

use super::node::NodeState;

/// Cold-placement policy for new executor starts.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SchedPolicy {
    /// Pack onto the node already caching this function's image until its
    /// memory slots saturate (AWS-like co-location per Wang et al.).
    CoLocate,
    /// Uniform random over all nodes.
    Spread,
    /// Fewest in-flight executors first (power of all choices).
    LeastLoaded,
    /// Least-loaded among nodes that already cache the image; fall back
    /// to least-loaded overall (pays a transfer) if none do.
    PoolAffinity,
}

impl SchedPolicy {
    pub const ALL: [SchedPolicy; 4] = [
        SchedPolicy::CoLocate,
        SchedPolicy::Spread,
        SchedPolicy::LeastLoaded,
        SchedPolicy::PoolAffinity,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            SchedPolicy::CoLocate => "co-locate",
            SchedPolicy::Spread => "spread",
            SchedPolicy::LeastLoaded => "least-loaded",
            SchedPolicy::PoolAffinity => "pool-affinity",
        }
    }
}

/// Outcome of one cold placement: the chosen node and the bytes that must
/// be pulled before the start can proceed (0 on cache hit).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct PlacementOutcome {
    pub node: usize,
    pub fetch_bytes: u64,
}

/// Placement decisions + image-distribution bookkeeping over a node set.
pub struct Scheduler {
    pub policy: SchedPolicy,
    pub transfers: u64,
    pub transferred_bytes: u64,
}

fn least_loaded<'a>(candidates: impl Iterator<Item = &'a NodeState>) -> Option<usize> {
    candidates.min_by_key(|n| (n.inflight, n.id)).map(|n| n.id)
}

impl Scheduler {
    pub fn new(policy: SchedPolicy) -> Scheduler {
        Scheduler { policy, transfers: 0, transferred_bytes: 0 }
    }

    /// Route to a node holding a live warm executor for `func`, if any
    /// (least-loaded among them, node id as tie-break).  Claims an
    /// in-flight slot on the chosen node; every policy routes warm first —
    /// that is the platform's router, not a placement choice.  Crashed
    /// nodes are never candidates: their pools were drained at the crash
    /// and a dead node cannot serve even a (buggy) leftover slot.
    pub fn route_warm(&self, nodes: &mut [NodeState], func: &str, now: u64) -> Option<usize> {
        let mut best: Option<(u32, usize)> = None;
        for n in nodes.iter_mut() {
            if !n.up || n.pool.warm_available(func, now) == 0 {
                continue;
            }
            let better = match best {
                None => true,
                Some(b) => (n.inflight, n.id) < b,
            };
            if better {
                best = Some((n.inflight, n.id));
            }
        }
        let id = best.map(|(_, id)| id)?;
        nodes[id].inflight += 1;
        Some(id)
    }

    /// Place one cold start for `img` under the policy; claims an
    /// in-flight slot and updates the chosen node's image cache.  Only
    /// live nodes are candidates; returns `None` when the whole cluster
    /// is down (the caller rejects the request).
    pub fn place_cold(
        &mut self,
        nodes: &mut [NodeState],
        img: &Image,
        rng: &mut Rng,
    ) -> Option<PlacementOutcome> {
        let id = match self.policy {
            SchedPolicy::Spread => {
                // With every node up this draws exactly the same value
                // from the same RNG call as `below(nodes.len())` did
                // before the fault layer existed (k-th alive == node k),
                // and stays allocation-free on the per-request hot path.
                let alive = nodes.iter().filter(|n| n.up).count() as u64;
                if alive == 0 {
                    return None;
                }
                let k = rng.below(alive) as usize;
                nodes.iter().filter(|n| n.up).nth(k).map(|n| n.id).expect("k < alive")
            }
            SchedPolicy::LeastLoaded => least_loaded(nodes.iter().filter(|n| n.up))?,
            SchedPolicy::PoolAffinity => {
                least_loaded(nodes.iter().filter(|n| n.up && n.cache.contains(&img.name)))
                    .or_else(|| least_loaded(nodes.iter().filter(|n| n.up)))?
            }
            SchedPolicy::CoLocate => {
                // Stay on a cached node while executors still *fit in
                // memory* (Wang et al.), even far past the core count —
                // then spill to the least-loaded node overall.
                let home = nodes
                    .iter()
                    .filter(|n| n.up && n.cache.contains(&img.name) && n.inflight < n.mem_slots)
                    .map(|n| n.id)
                    .next();
                match home {
                    Some(id) => id,
                    None => least_loaded(nodes.iter().filter(|n| n.up))?,
                }
            }
        };
        let node = &mut nodes[id];
        node.inflight += 1;
        let fetch_bytes = match node.cache.fetch(img) {
            Ok(Some(bytes)) => {
                self.transfers += 1;
                self.transferred_bytes += bytes;
                bytes
            }
            _ => 0,
        };
        Some(PlacementOutcome { node: id, fetch_bytes })
    }

    /// An executor on `node` released its in-flight slot.
    pub fn complete(&self, nodes: &mut [NodeState], node: usize) {
        let n = &mut nodes[node];
        debug_assert!(n.inflight > 0);
        n.inflight = n.inflight.saturating_sub(1);
    }
}

/// Total bytes resident across all node caches.
pub fn footprint_bytes(nodes: &[NodeState]) -> u64 {
    nodes.iter().map(|n| n.cache.used_bytes()).sum()
}

/// How many distinct nodes ended up caching the named image.
pub fn nodes_with_image(nodes: &[NodeState], name: &str) -> usize {
    nodes.iter().filter(|n| n.cache.contains(name)).count()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::virt::Tech;

    const S: u64 = 1_000_000_000;

    fn img() -> Image {
        Image::for_function("f0", Tech::IncludeOsHvt)
    }

    fn nodes(n: usize, cores: u32) -> Vec<NodeState> {
        (0..n).map(|id| NodeState::new(id, cores, cores * 8, 30 * S, 1 << 20)).collect()
    }

    fn seeded(policy: SchedPolicy) -> (Scheduler, Vec<NodeState>) {
        let mut ns = nodes(4, 2);
        let _ = ns[0].cache.fetch(&img()); // image starts on node 0 only
        (Scheduler::new(policy), ns)
    }

    fn place(s: &mut Scheduler, ns: &mut [NodeState], rng: &mut Rng) -> PlacementOutcome {
        s.place_cold(ns, &img(), rng).expect("a node is up")
    }

    #[test]
    fn colocate_packs_past_core_count_until_memory() {
        let (mut s, mut ns) = seeded(SchedPolicy::CoLocate); // 2 cores, 16 mem slots
        let mut rng = Rng::new(1);
        // Keeps packing node 0 well beyond its 2 cores (the Wang et al.
        // behaviour that inflates scale-out startup latency)...
        for _ in 0..16 {
            assert_eq!(place(&mut s, &mut ns, &mut rng).node, 0);
        }
        // ...and only spills once memory slots are exhausted.
        let spill = place(&mut s, &mut ns, &mut rng);
        assert_ne!(spill.node, 0);
        assert_eq!(spill.fetch_bytes, img().bytes);
    }

    #[test]
    fn pool_affinity_prefers_cached_nodes() {
        let (mut s, mut ns) = seeded(SchedPolicy::PoolAffinity);
        let mut rng = Rng::new(2);
        for _ in 0..5 {
            // With only node 0 cached, affinity keeps hitting node 0 even
            // as load builds (that is its weakness under bursts).
            assert_eq!(place(&mut s, &mut ns, &mut rng).node, 0);
        }
        assert_eq!(s.transfers, 0);
    }

    #[test]
    fn least_loaded_spreads_and_transfers() {
        let (mut s, mut ns) = seeded(SchedPolicy::LeastLoaded);
        let mut rng = Rng::new(3);
        let placed: Vec<usize> =
            (0..4).map(|_| place(&mut s, &mut ns, &mut rng).node).collect();
        let mut sorted = placed.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, vec![0, 1, 2, 3], "{placed:?}");
        assert_eq!(s.transfers, 3); // 3 cache misses
        assert_eq!(nodes_with_image(&ns, "f0"), 4);
    }

    #[test]
    fn complete_releases_load() {
        let (mut s, mut ns) = seeded(SchedPolicy::LeastLoaded);
        let mut rng = Rng::new(4);
        let p = place(&mut s, &mut ns, &mut rng);
        s.complete(&mut ns, p.node);
        assert_eq!(ns[p.node].inflight, 0);
    }

    #[test]
    fn footprint_counts_all_copies() {
        let (mut s, mut ns) = seeded(SchedPolicy::LeastLoaded);
        let mut rng = Rng::new(5);
        for _ in 0..4 {
            let _ = place(&mut s, &mut ns, &mut rng);
        }
        assert_eq!(footprint_bytes(&ns), 4 * img().bytes);
    }

    #[test]
    fn spread_is_deterministic_per_seed() {
        let run = |seed| {
            let (mut s, mut ns) = seeded(SchedPolicy::Spread);
            let mut rng = Rng::new(seed);
            (0..10).map(|_| place(&mut s, &mut ns, &mut rng).node).collect::<Vec<_>>()
        };
        assert_eq!(run(7), run(7));
        assert_ne!(run(7), run(8));
    }

    #[test]
    fn warm_routing_finds_live_slots_and_skips_expired() {
        let s = Scheduler::new(SchedPolicy::LeastLoaded);
        let mut ns = nodes(3, 2);
        assert_eq!(s.route_warm(&mut ns, "f0", 0), None);
        // Node 2 holds a warm slot until t=10 s.
        ns[2].pool.prewarm_until("f0", 1, 0, 10 * S);
        let mut ns2 = ns;
        assert_eq!(s.route_warm(&mut ns2, "f0", 5 * S), Some(2));
        assert_eq!(ns2[2].inflight, 1);
        // Past the deadline the slot is gone.
        ns2[2].pool.prewarm_until("f0", 1, 20 * S, 25 * S);
        assert_eq!(s.route_warm(&mut ns2, "f0", 30 * S), None);
    }

    #[test]
    fn dead_nodes_are_never_placement_targets() {
        for policy in SchedPolicy::ALL {
            let (mut s, mut ns) = seeded(policy);
            ns[0].up = false; // the only cached node dies
            let mut rng = Rng::new(11);
            for _ in 0..8 {
                let p = place(&mut s, &mut ns, &mut rng);
                assert_ne!(p.node, 0, "{policy:?} placed on a dead node");
            }
        }
    }

    #[test]
    fn all_nodes_down_yields_no_placement() {
        for policy in SchedPolicy::ALL {
            let (mut s, mut ns) = seeded(policy);
            for n in ns.iter_mut() {
                n.up = false;
            }
            let mut rng = Rng::new(12);
            assert_eq!(s.place_cold(&mut ns, &img(), &mut rng), None, "{policy:?}");
        }
    }

    #[test]
    fn warm_routing_skips_crashed_nodes() {
        let s = Scheduler::new(SchedPolicy::LeastLoaded);
        let mut ns = nodes(2, 2);
        ns[0].pool.prewarm_until("f0", 1, 0, 100 * S);
        ns[1].pool.prewarm_until("f0", 1, 0, 100 * S);
        ns[0].up = false;
        // Even with a (stale) slot still in node 0's pool, routing must
        // pick the live node only.
        assert_eq!(s.route_warm(&mut ns, "f0", S), Some(1));
        ns[1].up = false;
        assert_eq!(s.route_warm(&mut ns, "f0", 2 * S), None);
    }

    #[test]
    fn warm_routing_prefers_least_loaded_node() {
        let s = Scheduler::new(SchedPolicy::LeastLoaded);
        let mut ns = nodes(2, 2);
        ns[0].pool.prewarm_until("f0", 1, 0, 100 * S);
        ns[1].pool.prewarm_until("f0", 1, 0, 100 * S);
        ns[0].inflight = 3;
        assert_eq!(s.route_warm(&mut ns, "f0", S), Some(1));
    }
}
