//! Pluggable placement for the unified platform: warm-executor routing
//! (the Fn router consults every node's pool before starting anything)
//! plus the cold-placement policies the cluster literature argues about —
//! AWS-style co-location (Wang et al.), random spread, least-loaded, and
//! image/pool affinity.  Pure logic; the DES wiring lives in
//! [`super::sim`].
//!
//! ## The hot-path indexes
//!
//! At fleet scale (E15: 256 nodes, 10k functions, millions of requests)
//! the per-request linear scans dominated the simulator, so the scheduler
//! keeps three indexes:
//!
//! * `warm_nodes`: **sharing key** → candidate nodes that *may* hold a
//!   live warm slot.  The key is the function name under the exclusive
//!   pool and the runtime bucket under universal-worker sharing (S23) —
//!   the index is agnostic: it routes whatever key dispatch and release
//!   agree on, so shared slots are found exactly like per-function ones
//!   and a request can never be routed to a mismatched bucket.
//!   Maintained as a **verified superset**: every release/pre-warm
//!   inserts, nothing is required to delete eagerly, and `route_warm`
//!   checks each candidate against the node's pool (which is itself
//!   deadline-indexed) and prunes the ones that come up empty.  Routing
//!   touches only nodes that ever went warm for the key instead of
//!   scanning the whole cluster.
//! * `by_load`: the exact `(inflight, node_id)` set of all *up* nodes —
//!   `LeastLoaded` (and every least-loaded fallback) is an O(log N)
//!   `first()` instead of a scan.  Every in-flight change flows through
//!   [`Scheduler::claim`]/[`Scheduler::complete`]; crashes/restarts
//!   through [`Scheduler::node_down`]/[`Scheduler::node_up`].
//! * `image_nodes`: image → candidate nodes caching it (verified superset
//!   again, pruned lazily) — `PoolAffinity` and `CoLocate` walk only the
//!   replica set.
//!
//! Tie-breaking is bit-for-bit the pre-index behaviour — candidates are
//! walked in node-id order and compared on `(inflight, id)` — and debug
//! builds re-run the original linear scans on every decision and assert
//! the indexed pick matches (see `route_warm_scan`/`place_cold_scan`),
//! which is what keeps the E12–E14 byte-identical report pins honest.

use std::collections::{BTreeSet, HashMap};

use crate::image::Image;
use crate::sim::snap::{Dec, Enc};
use crate::sim::Rng;

use super::node::NodeState;

/// Cold-placement policy for new executor starts.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SchedPolicy {
    /// Pack onto the node already caching this function's image until its
    /// memory slots saturate (AWS-like co-location per Wang et al.).
    CoLocate,
    /// Uniform random over all nodes.
    Spread,
    /// Fewest in-flight executors first (power of all choices).
    LeastLoaded,
    /// Least-loaded among nodes that already cache the image; fall back
    /// to least-loaded overall (pays a transfer) if none do.
    PoolAffinity,
}

impl SchedPolicy {
    pub const ALL: [SchedPolicy; 4] = [
        SchedPolicy::CoLocate,
        SchedPolicy::Spread,
        SchedPolicy::LeastLoaded,
        SchedPolicy::PoolAffinity,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            SchedPolicy::CoLocate => "co-locate",
            SchedPolicy::Spread => "spread",
            SchedPolicy::LeastLoaded => "least-loaded",
            SchedPolicy::PoolAffinity => "pool-affinity",
        }
    }
}

/// Outcome of one cold placement: the chosen node and the bytes that must
/// be pulled before the start can proceed (0 on cache hit).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct PlacementOutcome {
    pub node: usize,
    pub fetch_bytes: u64,
}

/// Placement decisions + image-distribution bookkeeping over a node set.
///
/// The indexes must see every state change: build them with
/// [`Scheduler::attach`] (or [`Scheduler::for_nodes`]) once the node set
/// is seeded, then report warm releases via [`Scheduler::warm_added`],
/// crashes via [`Scheduler::node_down`], and restarts via
/// [`Scheduler::node_up`].  In-flight counters are owned here: claim and
/// release go through [`Scheduler::complete`] and the routing methods.
pub struct Scheduler {
    pub policy: SchedPolicy, // detlint: allow(DL005) config-derived choice
    pub transfers: u64,
    pub transferred_bytes: u64,
    /// Exact `(inflight, node_id)` of every up node.
    by_load: BTreeSet<(u32, usize)>, // detlint: allow(DL005) index; rebuilt by attach
    /// Sharing key (function name, or runtime bucket under S23 sharing)
    /// → nodes that may hold live warm slots (verified superset).
    warm_nodes: HashMap<String, BTreeSet<usize>>, // detlint: allow(DL005) index; rebuilt by attach
    /// image → nodes that may cache it (verified superset).
    image_nodes: HashMap<String, BTreeSet<usize>>, // detlint: allow(DL005) index; rebuilt by attach
    /// Debug-only decision counter driving parity-check sampling: on
    /// clusters past 64 nodes the O(N) reference scan runs on every
    /// 64th decision instead of all of them, so E15-sized debug runs
    /// stay affordable while every pinned preset (≤32 nodes) and the
    /// property suite keep full per-decision verification.
    #[cfg(debug_assertions)]
    parity_tick: u64, // detlint: allow(DL005) debug-only sampling counter
}

fn least_loaded<'a>(candidates: impl Iterator<Item = &'a NodeState>) -> Option<usize> {
    candidates.min_by_key(|n| (n.inflight, n.id)).map(|n| n.id)
}

impl Scheduler {
    pub fn new(policy: SchedPolicy) -> Scheduler {
        Scheduler {
            policy,
            transfers: 0,
            transferred_bytes: 0,
            by_load: BTreeSet::new(),
            warm_nodes: HashMap::new(),
            image_nodes: HashMap::new(),
            #[cfg(debug_assertions)]
            parity_tick: 0,
        }
    }

    /// Debug builds re-run the pre-index linear scans and assert parity;
    /// sampled down on large clusters (see `parity_tick`).
    #[cfg(debug_assertions)]
    fn parity_check_due(&mut self, n_nodes: usize) -> bool {
        self.parity_tick = self.parity_tick.wrapping_add(1);
        n_nodes <= 64 || self.parity_tick % 64 == 0
    }

    /// A scheduler with its indexes already attached to `nodes`.
    pub fn for_nodes(policy: SchedPolicy, nodes: &[NodeState]) -> Scheduler {
        let mut s = Scheduler::new(policy);
        s.attach(nodes);
        s
    }

    /// (Re)build the indexes from the current node state: load order over
    /// up nodes, image replica sets from the caches, and warm candidates
    /// from whatever the pools already hold (pre-run seeding/warmup).
    pub fn attach(&mut self, nodes: &[NodeState]) {
        self.by_load.clear();
        self.warm_nodes.clear();
        self.image_nodes.clear();
        for n in nodes {
            if n.up {
                self.by_load.insert((n.inflight, n.id));
            }
            for img in n.cache.names() {
                self.image_nodes.entry(img.to_string()).or_default().insert(n.id);
            }
            for func in n.pool.warm_funcs() {
                self.warm_nodes.entry(func.to_string()).or_default().insert(n.id);
            }
        }
    }

    /// Serialize the scheduler's durable state (S27): only the transfer
    /// counters.  The routing indexes are verified supersets rebuilt from
    /// node state — callers run [`Scheduler::attach`] after restoring the
    /// nodes, and every decision still matches the full linear scan, so
    /// a freshly rebuilt (tighter) superset cannot change placements.
    pub fn encode(&self, w: &mut Enc) {
        w.u64(self.transfers);
        w.u64(self.transferred_bytes);
    }

    /// Inverse of [`Self::encode`]; call [`Scheduler::attach`] afterwards.
    pub fn restore(&mut self, r: &mut Dec) {
        self.transfers = r.u64();
        self.transferred_bytes = r.u64();
    }

    /// `node` may now hold a live warm slot under sharing key `key` (an
    /// executor was released into or pre-warmed in its pool).
    pub fn warm_added(&mut self, key: &str, node: usize) {
        match self.warm_nodes.get_mut(key) {
            Some(set) => {
                set.insert(node);
            }
            None => {
                self.warm_nodes.insert(key.to_string(), BTreeSet::from([node]));
            }
        }
    }

    fn image_added(&mut self, image: &str, node: usize) {
        match self.image_nodes.get_mut(image) {
            Some(set) => {
                set.insert(node);
            }
            None => {
                self.image_nodes.insert(image.to_string(), BTreeSet::from([node]));
            }
        }
    }

    /// `node` crashed: drop it from the load order.  Call *before*
    /// flipping `up`/resetting `inflight` (the index key must match).
    /// Warm/image candidates stay behind as stale entries; routing
    /// verifies against the drained pool/flushed cache and prunes them.
    pub fn node_down(&mut self, node: &NodeState) {
        self.by_load.remove(&(node.inflight, node.id));
    }

    /// `node` restarted: re-enter the load order.  Call *after* flipping
    /// `up` (with the in-flight counter already reset).
    pub fn node_up(&mut self, node: &NodeState) {
        debug_assert!(node.up);
        self.by_load.insert((node.inflight, node.id));
    }

    /// Claim an in-flight slot on `id`, keeping the load order exact.
    fn claim(&mut self, nodes: &mut [NodeState], id: usize) {
        let n = &mut nodes[id];
        if n.up {
            self.by_load.remove(&(n.inflight, n.id));
        }
        n.inflight += 1;
        if n.up {
            self.by_load.insert((n.inflight, n.id));
        }
    }

    /// An executor on `node` released its in-flight slot.
    pub fn complete(&mut self, nodes: &mut [NodeState], node: usize) {
        let n = &mut nodes[node];
        debug_assert!(n.inflight > 0);
        if n.up {
            self.by_load.remove(&(n.inflight, n.id));
        }
        n.inflight = n.inflight.saturating_sub(1);
        if n.up {
            self.by_load.insert((n.inflight, n.id));
        }
    }

    /// Route to a node holding a live warm executor under sharing key
    /// `key` — the function name in the exclusive pool, the runtime
    /// bucket under universal sharing — if any (least-loaded among them,
    /// node id as tie-break).  Claims an in-flight slot on the chosen
    /// node; every policy routes warm first — that is the platform's
    /// router, not a placement choice.  Crashed nodes are never
    /// candidates: their pools were drained at the crash and a dead node
    /// cannot serve even a (buggy) leftover slot.
    ///
    /// Only the key's candidate set is consulted; candidates whose pool
    /// comes up empty are pruned, so the set tracks the nodes actually
    /// warm for the key.
    pub fn route_warm(&mut self, nodes: &mut [NodeState], key: &str, now: u64) -> Option<usize> {
        #[cfg(debug_assertions)]
        let want: Option<Option<usize>> = if self.parity_check_due(nodes.len()) {
            Some(Self::route_warm_scan(nodes, key, now))
        } else {
            None
        };
        let mut best: Option<(u32, usize)> = None;
        let mut stale: Vec<usize> = Vec::new();
        if let Some(set) = self.warm_nodes.get_mut(key) {
            for &id in set.iter() {
                let n = &mut nodes[id];
                if !n.up {
                    // Down nodes are skipped without probing (and without
                    // pruning): the pre-index scan never touched their
                    // pools either, and a post-restart probe cleans up.
                    continue;
                }
                if n.pool.warm_available(key, now) == 0 {
                    stale.push(id);
                    continue;
                }
                let load_key = (n.inflight, n.id);
                let better = match best {
                    None => true,
                    Some(b) => load_key < b,
                };
                if better {
                    best = Some(load_key);
                }
            }
            for id in &stale {
                set.remove(id);
            }
            if set.is_empty() {
                self.warm_nodes.remove(key);
            }
        }
        #[cfg(debug_assertions)]
        if let Some(want) = want {
            debug_assert_eq!(
                best.map(|(_, id)| id),
                want,
                "warm index diverged from the linear scan for '{key}'"
            );
        }
        let id = best.map(|(_, id)| id)?;
        self.claim(nodes, id);
        Some(id)
    }

    /// The pre-index warm router: full scan over every node and pool,
    /// keyed exactly like [`Scheduler::route_warm`].  Kept as the
    /// behavioural reference — debug builds assert the indexed router
    /// picks the same node (sharing keys included), and the property
    /// suite replays random traces against it.  Does not claim.
    pub fn route_warm_scan(nodes: &mut [NodeState], key: &str, now: u64) -> Option<usize> {
        let mut best: Option<(u32, usize)> = None;
        for n in nodes.iter_mut() {
            if !n.up || n.pool.warm_available(key, now) == 0 {
                continue;
            }
            let better = match best {
                None => true,
                Some(b) => (n.inflight, n.id) < b,
            };
            if better {
                best = Some((n.inflight, n.id));
            }
        }
        best.map(|(_, id)| id)
    }

    /// Least-loaded among the (verified) nodes caching `image`; prunes
    /// candidates whose cache no longer holds it (post-restart flush).
    fn affinity_pick(&mut self, nodes: &[NodeState], image: &str) -> Option<usize> {
        let set = self.image_nodes.get_mut(image)?;
        let mut stale: Vec<usize> = Vec::new();
        let mut best: Option<(u32, usize)> = None;
        for &id in set.iter() {
            let n = &nodes[id];
            if !n.cache.contains(image) {
                stale.push(id);
                continue;
            }
            if !n.up {
                continue;
            }
            let key = (n.inflight, id);
            let better = match best {
                None => true,
                Some(b) => key < b,
            };
            if better {
                best = Some(key);
            }
        }
        for id in &stale {
            set.remove(id);
        }
        if set.is_empty() {
            self.image_nodes.remove(image);
        }
        best.map(|(_, id)| id)
    }

    /// First node in id order still caching `image` with free memory
    /// slots (the Wang et al. co-location home), pruning stale replicas.
    fn colocate_pick(&mut self, nodes: &[NodeState], image: &str) -> Option<usize> {
        let set = self.image_nodes.get_mut(image)?;
        let mut stale: Vec<usize> = Vec::new();
        let mut home: Option<usize> = None;
        for &id in set.iter() {
            let n = &nodes[id];
            if !n.cache.contains(image) {
                stale.push(id);
                continue;
            }
            if home.is_none() && n.up && n.inflight < n.mem_slots {
                home = Some(id);
            }
        }
        for id in &stale {
            set.remove(id);
        }
        if set.is_empty() {
            self.image_nodes.remove(image);
        }
        home
    }

    fn least_loaded_indexed(&self) -> Option<usize> {
        self.by_load.iter().next().map(|&(_, id)| id)
    }

    /// Place one cold start for `img` under the policy; claims an
    /// in-flight slot and updates the chosen node's image cache.  Only
    /// live nodes are candidates; returns `None` when the whole cluster
    /// is down (the caller rejects the request).
    pub fn place_cold(
        &mut self,
        nodes: &mut [NodeState],
        img: &Image,
        rng: &mut Rng,
    ) -> Option<PlacementOutcome> {
        #[cfg(debug_assertions)]
        let want: Option<Option<usize>> = if self.parity_check_due(nodes.len()) {
            let mut probe = rng.clone();
            Some(Self::place_cold_scan(self.policy, nodes, img, &mut probe))
        } else {
            None
        };
        let id = match self.policy {
            SchedPolicy::Spread => {
                // With every node up this draws exactly the same value
                // from the same RNG call as `below(nodes.len())` did
                // before the fault layer existed (k-th alive == node k),
                // and stays allocation-free on the per-request hot path.
                let alive = nodes.iter().filter(|n| n.up).count() as u64;
                if alive == 0 {
                    None
                } else {
                    let k = rng.below(alive) as usize;
                    Some(nodes.iter().filter(|n| n.up).nth(k).map(|n| n.id).expect("k < alive"))
                }
            }
            SchedPolicy::LeastLoaded => self.least_loaded_indexed(),
            SchedPolicy::PoolAffinity => {
                self.affinity_pick(nodes, &img.name).or_else(|| self.least_loaded_indexed())
            }
            SchedPolicy::CoLocate => {
                // Stay on a cached node while executors still *fit in
                // memory* (Wang et al.), even far past the core count —
                // then spill to the least-loaded node overall.
                self.colocate_pick(nodes, &img.name).or_else(|| self.least_loaded_indexed())
            }
        };
        #[cfg(debug_assertions)]
        if let Some(want) = want {
            debug_assert_eq!(
                id, want,
                "cold-placement index diverged from the linear scan ({:?})",
                self.policy
            );
        }
        let id = id?;
        self.claim(nodes, id);
        let node = &mut nodes[id];
        let fetch_bytes = match node.cache.fetch(img) {
            Ok(Some(bytes)) => {
                self.transfers += 1;
                self.transferred_bytes += bytes;
                bytes
            }
            _ => 0,
        };
        self.image_added(&img.name, id);
        Some(PlacementOutcome { node: id, fetch_bytes })
    }

    /// The pre-index cold placement: the original linear scans, kept as
    /// the behavioural reference for debug parity asserts and the
    /// property suite.  Picks only (no claim, no cache update); `rng`
    /// must be a clone when run next to the real placement.
    pub fn place_cold_scan(
        policy: SchedPolicy,
        nodes: &[NodeState],
        img: &Image,
        rng: &mut Rng,
    ) -> Option<usize> {
        match policy {
            SchedPolicy::Spread => {
                let alive = nodes.iter().filter(|n| n.up).count() as u64;
                if alive == 0 {
                    return None;
                }
                let k = rng.below(alive) as usize;
                Some(nodes.iter().filter(|n| n.up).nth(k).map(|n| n.id).expect("k < alive"))
            }
            SchedPolicy::LeastLoaded => least_loaded(nodes.iter().filter(|n| n.up)),
            SchedPolicy::PoolAffinity => {
                least_loaded(nodes.iter().filter(|n| n.up && n.cache.contains(&img.name)))
                    .or_else(|| least_loaded(nodes.iter().filter(|n| n.up)))
            }
            SchedPolicy::CoLocate => {
                let home = nodes
                    .iter()
                    .filter(|n| n.up && n.cache.contains(&img.name) && n.inflight < n.mem_slots)
                    .map(|n| n.id)
                    .next();
                home.or_else(|| least_loaded(nodes.iter().filter(|n| n.up)))
            }
        }
    }
}

/// Total bytes resident across all node caches.
pub fn footprint_bytes(nodes: &[NodeState]) -> u64 {
    nodes.iter().map(|n| n.cache.used_bytes()).sum()
}

/// How many distinct nodes ended up caching the named image.
pub fn nodes_with_image(nodes: &[NodeState], name: &str) -> usize {
    nodes.iter().filter(|n| n.cache.contains(name)).count()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::virt::Tech;

    const S: u64 = 1_000_000_000;

    fn img() -> Image {
        Image::for_function("f0", Tech::IncludeOsHvt)
    }

    fn nodes(n: usize, cores: u32) -> Vec<NodeState> {
        (0..n).map(|id| NodeState::new(id, cores, cores * 8, 30 * S, 1 << 20)).collect()
    }

    fn seeded(policy: SchedPolicy) -> (Scheduler, Vec<NodeState>) {
        let mut ns = nodes(4, 2);
        let _ = ns[0].cache.fetch(&img()); // image starts on node 0 only
        (Scheduler::for_nodes(policy, &ns), ns)
    }

    fn place(s: &mut Scheduler, ns: &mut [NodeState], rng: &mut Rng) -> PlacementOutcome {
        s.place_cold(ns, &img(), rng).expect("a node is up")
    }

    #[test]
    fn colocate_packs_past_core_count_until_memory() {
        let (mut s, mut ns) = seeded(SchedPolicy::CoLocate); // 2 cores, 16 mem slots
        let mut rng = Rng::new(1);
        // Keeps packing node 0 well beyond its 2 cores (the Wang et al.
        // behaviour that inflates scale-out startup latency)...
        for _ in 0..16 {
            assert_eq!(place(&mut s, &mut ns, &mut rng).node, 0);
        }
        // ...and only spills once memory slots are exhausted.
        let spill = place(&mut s, &mut ns, &mut rng);
        assert_ne!(spill.node, 0);
        assert_eq!(spill.fetch_bytes, img().bytes);
    }

    #[test]
    fn pool_affinity_prefers_cached_nodes() {
        let (mut s, mut ns) = seeded(SchedPolicy::PoolAffinity);
        let mut rng = Rng::new(2);
        for _ in 0..5 {
            // With only node 0 cached, affinity keeps hitting node 0 even
            // as load builds (that is its weakness under bursts).
            assert_eq!(place(&mut s, &mut ns, &mut rng).node, 0);
        }
        assert_eq!(s.transfers, 0);
    }

    #[test]
    fn least_loaded_spreads_and_transfers() {
        let (mut s, mut ns) = seeded(SchedPolicy::LeastLoaded);
        let mut rng = Rng::new(3);
        let placed: Vec<usize> =
            (0..4).map(|_| place(&mut s, &mut ns, &mut rng).node).collect();
        let mut sorted = placed.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, vec![0, 1, 2, 3], "{placed:?}");
        assert_eq!(s.transfers, 3); // 3 cache misses
        assert_eq!(nodes_with_image(&ns, "f0"), 4);
    }

    #[test]
    fn complete_releases_load() {
        let (mut s, mut ns) = seeded(SchedPolicy::LeastLoaded);
        let mut rng = Rng::new(4);
        let p = place(&mut s, &mut ns, &mut rng);
        s.complete(&mut ns, p.node);
        assert_eq!(ns[p.node].inflight, 0);
    }

    #[test]
    fn footprint_counts_all_copies() {
        let (mut s, mut ns) = seeded(SchedPolicy::LeastLoaded);
        let mut rng = Rng::new(5);
        for _ in 0..4 {
            let _ = place(&mut s, &mut ns, &mut rng);
        }
        assert_eq!(footprint_bytes(&ns), 4 * img().bytes);
    }

    #[test]
    fn spread_is_deterministic_per_seed() {
        let run = |seed| {
            let (mut s, mut ns) = seeded(SchedPolicy::Spread);
            let mut rng = Rng::new(seed);
            (0..10).map(|_| place(&mut s, &mut ns, &mut rng).node).collect::<Vec<_>>()
        };
        assert_eq!(run(7), run(7));
        assert_ne!(run(7), run(8));
    }

    #[test]
    fn warm_routing_finds_live_slots_and_skips_expired() {
        let mut ns = nodes(3, 2);
        let mut s = Scheduler::for_nodes(SchedPolicy::LeastLoaded, &ns);
        assert_eq!(s.route_warm(&mut ns, "f0", 0), None);
        // Node 2 holds a warm slot until t=10 s.
        ns[2].pool.prewarm_until("f0", 1, 0, 10 * S);
        s.warm_added("f0", 2);
        let mut ns2 = ns;
        assert_eq!(s.route_warm(&mut ns2, "f0", 5 * S), Some(2));
        assert_eq!(ns2[2].inflight, 1);
        // Past the deadline the slot is gone.
        ns2[2].pool.prewarm_until("f0", 1, 20 * S, 25 * S);
        s.warm_added("f0", 2);
        assert_eq!(s.route_warm(&mut ns2, "f0", 30 * S), None);
    }

    #[test]
    fn warm_routing_on_sharing_keys_matches_scan_and_never_crosses() {
        use crate::fnplat::NO_OWNER;
        // Universal workers pooled under a runtime key (S23) route exactly
        // like per-function slots: the index and the reference scan agree
        // pick-for-pick, and a different key never sees them.
        let mut ns = nodes(3, 2);
        ns[1].pool.prewarm_shared_until("rt0", NO_OWNER, 1, 0, 50 * S);
        ns[2].pool.prewarm_shared_until("rt0", NO_OWNER, 1, 0, 50 * S);
        ns[2].inflight = 3;
        let mut s = Scheduler::for_nodes(SchedPolicy::LeastLoaded, &ns);
        assert_eq!(s.route_warm(&mut ns, "rt1", S), None, "keys must not cross");
        let want = Scheduler::route_warm_scan(&mut ns, "rt0", S);
        assert_eq!(want, Some(1), "least-loaded candidate under the key");
        assert_eq!(s.route_warm(&mut ns, "rt0", S), want);
        // Released-back shared slots re-enter the index under their key.
        ns[0].pool.release_shared_until("rt0", 7, 2 * S, 40 * S);
        s.warm_added("rt0", 0);
        assert_eq!(s.route_warm(&mut ns, "rt0", 3 * S), Some(0));
    }

    #[test]
    fn attach_seeds_warm_candidates_from_pools() {
        // Pools pre-warmed before the scheduler exists (measurement
        // warmup): attach must pick the candidates up.
        let mut ns = nodes(2, 2);
        ns[1].pool.prewarm_until("f0", 1, 0, 50 * S);
        let mut s = Scheduler::for_nodes(SchedPolicy::LeastLoaded, &ns);
        assert_eq!(s.route_warm(&mut ns, "f0", S), Some(1));
    }

    #[test]
    fn dead_nodes_are_never_placement_targets() {
        for policy in SchedPolicy::ALL {
            let (mut s, mut ns) = seeded(policy);
            s.node_down(&ns[0]);
            ns[0].up = false; // the only cached node dies
            let mut rng = Rng::new(11);
            for _ in 0..8 {
                let p = place(&mut s, &mut ns, &mut rng);
                assert_ne!(p.node, 0, "{policy:?} placed on a dead node");
            }
        }
    }

    #[test]
    fn all_nodes_down_yields_no_placement() {
        for policy in SchedPolicy::ALL {
            let (mut s, mut ns) = seeded(policy);
            for n in ns.iter_mut() {
                s.node_down(n);
                n.up = false;
            }
            let mut rng = Rng::new(12);
            assert_eq!(s.place_cold(&mut ns, &img(), &mut rng), None, "{policy:?}");
        }
    }

    #[test]
    fn warm_routing_skips_crashed_nodes() {
        let mut ns = nodes(2, 2);
        ns[0].pool.prewarm_until("f0", 1, 0, 100 * S);
        ns[1].pool.prewarm_until("f0", 1, 0, 100 * S);
        let mut s = Scheduler::for_nodes(SchedPolicy::LeastLoaded, &ns);
        s.node_down(&ns[0]);
        ns[0].up = false;
        // Even with a (stale) slot still in node 0's pool, routing must
        // pick the live node only.
        assert_eq!(s.route_warm(&mut ns, "f0", S), Some(1));
        s.node_down(&ns[1]);
        ns[1].up = false;
        assert_eq!(s.route_warm(&mut ns, "f0", 2 * S), None);
    }

    #[test]
    fn warm_routing_prefers_least_loaded_node() {
        let mut ns = nodes(2, 2);
        ns[0].pool.prewarm_until("f0", 1, 0, 100 * S);
        ns[1].pool.prewarm_until("f0", 1, 0, 100 * S);
        ns[0].inflight = 3;
        let mut s = Scheduler::for_nodes(SchedPolicy::LeastLoaded, &ns);
        assert_eq!(s.route_warm(&mut ns, "f0", S), Some(1));
    }

    #[test]
    fn restart_rejoins_the_load_order() {
        let (mut s, mut ns) = seeded(SchedPolicy::LeastLoaded);
        let mut rng = Rng::new(21);
        // Crash node 0, place a few starts elsewhere, restart it: the
        // empty node must be the least-loaded pick again.
        s.node_down(&ns[0]);
        ns[0].up = false;
        ns[0].inflight = 0;
        for _ in 0..3 {
            assert_ne!(place(&mut s, &mut ns, &mut rng).node, 0);
        }
        ns[0].up = true;
        s.node_up(&ns[0]);
        assert_eq!(place(&mut s, &mut ns, &mut rng).node, 0);
    }

    #[test]
    fn indexed_placement_tracks_claims_and_completions() {
        // Interleave placements and completions and check the index keeps
        // matching the reference scan pick-for-pick (the debug_assert
        // inside place_cold also fires on any divergence).
        let (mut s, mut ns) = seeded(SchedPolicy::LeastLoaded);
        let mut rng = Rng::new(31);
        let mut placed: Vec<usize> = Vec::new();
        for round in 0..50 {
            let pick = Scheduler::place_cold_scan(s.policy, &ns, &img(), &mut rng.clone());
            let got = place(&mut s, &mut ns, &mut rng);
            assert_eq!(Some(got.node), pick, "round {round}");
            placed.push(got.node);
            if round % 3 == 0 {
                let n = placed.remove(0);
                s.complete(&mut ns, n);
            }
        }
    }
}
