//! The one DES wiring every platform experiment runs on.
//!
//! A request flows: optional connection setup -> client/server RTT ->
//! placement tax -> gateway/agent/DB -> **dispatch decision** (warm-route
//! or cold-place) -> optional image pull -> startup pipeline retargeted
//! onto the chosen node's core/lock pools -> execution -> **release
//! decision** (the per-function [`LifecyclePolicy`] picks Retire / KeepFor
//! / PrewarmAfter against that node's [`WarmPool`]).  Pre-warms are
//! injected back into virtual time as zero-latency control requests whose
//! only step is a pool effect at the scheduled boot time, on the node the
//! retired executor lived on.
//!
//! Latencies stream into per-node log-bucket [`Histogram`]s (O(1) memory
//! per series; `merge()`d at the end of the run), so million-request fleet
//! sweeps do not allocate per request.  Exact raw samples stay available
//! behind [`PlatformConfig::exact_latencies`] for the debug/compat paths.

use std::collections::{HashMap, VecDeque};

use crate::image::Image;
use crate::metrics::Histogram;
use crate::net::transfer_step;
use crate::policy::{IdleAction, LifecyclePolicy};
use crate::sim::{Dist, Domain, Engine, Host, ReqId, Rng, Spawn, Step, StepKind, N_LOCKS};

use super::node::NodeState;
use super::sched::{footprint_bytes, nodes_with_image, Scheduler};
use super::{ImageSeeding, PlatformConfig, PlatformLoad, RequestPath};

const TAG_DISPATCH: u32 = 1;
const TAG_RELEASE: u32 = 2;
const TAG_PREWARM: u32 = 3;

/// High bit of the request class marks policy control requests (pre-warm
/// boots) rather than user invocations.
const CONTROL_BIT: u32 = 1 << 31;

/// Where a placed request landed (kept until `done` for latency binning).
#[derive(Clone, Copy)]
struct Placed {
    node: usize,
    cold: bool,
}

/// One scheduled pre-warm boot: fires at the absolute time, on the node
/// the retired executor lived on, retained for the keep window.
#[derive(Clone, Copy)]
struct PrewarmBoot {
    fire_at_ns: u64,
    node: usize,
    keep_ns: u64,
}

/// Retarget a startup pipeline onto one node's resources: CPU phases use
/// the node's core pool, each kernel-lock class its own per-node
/// single-slot pool, and disk reads the node's local disk (a single-slot
/// pool holding for bytes/bandwidth — the same FIFO serialization the
/// engine's global disk gives one host, but per node, so spreading cold
/// starts actually buys disk parallelism).  Pure delays stay as-is.
fn retarget(steps: &[Step], node: &NodeState, disk_bw_bytes_per_s: f64) -> Vec<Step> {
    steps
        .iter()
        .map(|s| match s.kind {
            StepKind::Cpu => Step::pool(s.tag, node.cpu_pool, s.dur),
            StepKind::Lock(class) => Step::pool(s.tag, node.lock_pools[class as usize], s.dur),
            StepKind::Disk(bytes) => Step::pool(
                s.tag,
                node.disk_pool,
                Dist::Const(bytes as f64 / disk_bw_bytes_per_s * 1e9),
            ),
            _ => *s,
        })
        .collect()
}

/// The unified platform as a simulation domain.
pub struct PlatformSim<'a> {
    cold_extra: Vec<Step>,
    warm_steps: Vec<Step>,
    cold_steps: Vec<Step>,
    exec_ms: f64,
    fabric_gbps: f64,
    disk_bw_bytes_per_s: f64,
    policy: &'a mut dyn LifecyclePolicy,
    sched: Scheduler,
    pub nodes: Vec<NodeState>,
    func_names: Vec<String>,
    images: Vec<Image>,
    // --- closed-loop chaining ---
    template: Vec<Step>,
    remaining: u64,
    gap_ns: u64,
    // --- per-request bookkeeping ---
    placed: HashMap<ReqId, Placed>,
    /// Pre-warms decided during the current release effect, drained into
    /// spawns when the request completes: (func, node, delay_ns, keep_ns).
    pending_prewarms: Vec<(u32, usize, u64, u64)>,
    /// Keep windows for in-flight pre-warm control requests, per function,
    /// matched by absolute boot time (boots may fire out of schedule order
    /// when forecast delays differ).
    prewarm_keeps: Vec<VecDeque<PrewarmBoot>>,
    prewarm_boots: u64,
    // --- metrics ---
    cold_hist: Histogram,
    warm_hist: Histogram,
    exact: bool,
    latencies_ns: Vec<u64>,
    cold_latencies_ns: Vec<u64>,
    warm_latencies_ns: Vec<u64>,
}

impl PlatformSim<'_> {
    fn dispatch_tail(&mut self, req: ReqId, func: u32, now: u64, rng: &mut Rng) -> Vec<Step> {
        self.policy.on_invoke(func, now);
        let name = &self.func_names[func as usize];
        let mut tail = Vec::new();
        if let Some(node) = self.sched.route_warm(&mut self.nodes, name, now) {
            let d = self.nodes[node].pool.dispatch(name, now);
            debug_assert_eq!(d, crate::fnplat::Dispatch::Warm);
            tail.extend(retarget(&self.warm_steps, &self.nodes[node], self.disk_bw_bytes_per_s));
            tail.push(Step::pool(
                "fn-exec",
                self.nodes[node].cpu_pool,
                Dist::ms(self.exec_ms, 0.15),
            ));
            tail.push(Step::effect("release", TAG_RELEASE));
            self.placed.insert(req, Placed { node, cold: false });
        } else {
            let out = self.sched.place_cold(&mut self.nodes, &self.images[func as usize], rng);
            let node = out.node;
            let d = self.nodes[node].pool.dispatch(name, now);
            debug_assert_eq!(d, crate::fnplat::Dispatch::Cold);
            if out.fetch_bytes > 0 {
                tail.push(transfer_step("image-pull", out.fetch_bytes, self.fabric_gbps));
            }
            tail.extend(self.cold_extra.iter().copied());
            tail.extend(retarget(&self.cold_steps, &self.nodes[node], self.disk_bw_bytes_per_s));
            tail.push(Step::pool(
                "fn-exec",
                self.nodes[node].cpu_pool,
                Dist::ms(self.exec_ms, 0.15),
            ));
            tail.push(Step::effect("release", TAG_RELEASE));
            self.placed.insert(req, Placed { node, cold: true });
        }
        tail
    }
}

impl Domain for PlatformSim<'_> {
    fn decide(&mut self, req: ReqId, class: u32, tag: u32, now: u64, rng: &mut Rng) -> Vec<Step> {
        debug_assert_eq!(tag, TAG_DISPATCH);
        self.dispatch_tail(req, class & !CONTROL_BIT, now, rng)
    }

    fn effect(&mut self, req: ReqId, class: u32, tag: u32, now: u64) {
        let func = class & !CONTROL_BIT;
        match tag {
            TAG_RELEASE => {
                let p = *self.placed.get(&req).expect("released request was placed");
                let name = &self.func_names[func as usize];
                match self.policy.on_idle(func, now) {
                    IdleAction::Retire => self.nodes[p.node].pool.retire(name),
                    IdleAction::KeepFor { keep_ns } => self.nodes[p.node].pool.release_until(
                        name,
                        now,
                        now.saturating_add(keep_ns),
                    ),
                    IdleAction::PrewarmAfter { delay_ns, keep_ns } => {
                        self.nodes[p.node].pool.retire(name);
                        self.pending_prewarms.push((func, p.node, delay_ns, keep_ns));
                    }
                }
                self.sched.complete(&mut self.nodes, p.node);
            }
            TAG_PREWARM => {
                // Match this boot to its scheduled keep window by fire
                // time: boots fire at exactly their scheduled instant.
                let hit = {
                    let q = &mut self.prewarm_keeps[func as usize];
                    q.iter()
                        .position(|b| b.fire_at_ns == now)
                        .and_then(|i| q.remove(i))
                };
                if let Some(boot) = hit {
                    let name = &self.func_names[func as usize];
                    // Skip stale pre-warms: an arrival already repopulated
                    // the pool, or the keep window degenerated.  Probe via
                    // warm_available (not idle_count) so an expired-but-
                    // unpurged slot doesn't mask a scheduled boot.
                    if boot.keep_ns > 0
                        && self.nodes[boot.node].pool.warm_available(name, now) == 0
                    {
                        self.prewarm_boots += 1;
                        self.nodes[boot.node].pool.prewarm_until(
                            name,
                            1,
                            now,
                            now.saturating_add(boot.keep_ns),
                        );
                    }
                }
            }
            other => debug_assert!(false, "unexpected effect tag {other}"),
        }
    }

    fn done(&mut self, req: ReqId, class: u32, start: u64, now: u64) -> Vec<Spawn> {
        let mut spawns = Vec::new();
        for (func, node, delay_ns, keep_ns) in self.pending_prewarms.drain(..) {
            self.prewarm_keeps[func as usize].push_back(PrewarmBoot {
                fire_at_ns: now.saturating_add(delay_ns),
                node,
                keep_ns,
            });
            spawns.push(Spawn {
                delay_ns,
                class: func | CONTROL_BIT,
                steps: vec![Step::effect("prewarm-boot", TAG_PREWARM)],
            });
        }
        if class & CONTROL_BIT == 0 {
            let lat = now - start;
            if let Some(p) = self.placed.remove(&req) {
                self.nodes[p.node].hist.record_ns(lat);
                if p.cold {
                    self.cold_hist.record_ns(lat);
                } else {
                    self.warm_hist.record_ns(lat);
                }
                if self.exact {
                    self.latencies_ns.push(lat);
                    if p.cold {
                        self.cold_latencies_ns.push(lat);
                    } else {
                        self.warm_latencies_ns.push(lat);
                    }
                }
            }
            if self.remaining > 0 {
                self.remaining -= 1;
                spawns.push(Spawn {
                    delay_ns: self.gap_ns,
                    class,
                    steps: self.template.clone(),
                });
            }
        }
        spawns
    }
}

/// Aggregated outcome of one platform run.
pub struct PlatformResult {
    /// User requests served (excludes pre-warm control requests).
    pub requests: u64,
    pub elapsed_ns: u64,
    /// All-request latency histogram (per-node histograms merged).
    pub hist: Histogram,
    pub cold_hist: Histogram,
    pub warm_hist: Histogram,
    /// Per-node latency histograms (the merge sources), node order.
    pub node_hists: Vec<Histogram>,
    /// Raw samples — populated only with `exact_latencies` (debug/compat).
    pub latencies_ns: Vec<u64>,
    pub cold_latencies_ns: Vec<u64>,
    pub warm_latencies_ns: Vec<u64>,
    pub warm_hits: u64,
    pub cold_starts: u64,
    pub prewarm_boots: u64,
    pub expirations: u64,
    pub retirements: u64,
    pub idle_gb_seconds: f64,
    pub monitor_events: u64,
    /// Cross-node image distribution economics.
    pub transfers: u64,
    pub transferred_bytes: u64,
    pub footprint_bytes: u64,
    /// Nodes caching function 0's image at the end of the run.
    pub nodes_with_first_image: usize,
    /// Median connection-setup cost for the driver's frontend (reported
    /// separately, as in Table I); 0 when the run has no network path.
    pub conn_setup_ms: f64,
}

impl PlatformResult {
    pub fn cold_fraction(&self) -> f64 {
        let total = self.cold_starts + self.warm_hits;
        if total == 0 {
            0.0
        } else {
            self.cold_starts as f64 / total as f64
        }
    }

    /// Latency quantile in ms: exact (nearest rank) when raw samples were
    /// kept, streaming-histogram approximation (<5% error) otherwise.
    pub fn quantile_ms(&self, q: f64) -> f64 {
        quantile_of(&self.latencies_ns, &self.hist, q)
    }

    pub fn cold_quantile_ms(&self, q: f64) -> f64 {
        quantile_of(&self.cold_latencies_ns, &self.cold_hist, q)
    }

    pub fn warm_quantile_ms(&self, q: f64) -> f64 {
        quantile_of(&self.warm_latencies_ns, &self.warm_hist, q)
    }
}

fn quantile_of(exact: &[u64], hist: &Histogram, q: f64) -> f64 {
    if exact.is_empty() {
        if hist.is_empty() {
            return f64::NAN;
        }
        return hist.quantile_ms(q);
    }
    exact_quantile_ms(exact, q)
}

/// Exact nearest-rank quantile over raw nanosecond samples, in ms — the
/// one implementation every preset reports through.
pub fn exact_quantile_ms(samples: &[u64], q: f64) -> f64 {
    if samples.is_empty() {
        return f64::NAN;
    }
    let mut s = samples.to_vec();
    s.sort_unstable();
    let idx = ((q.clamp(0.0, 1.0) * s.len() as f64).ceil() as usize).saturating_sub(1);
    s[idx.min(s.len() - 1)] as f64 / 1e6
}

/// Head-of-request steps up to (and including) the dispatch decision.
///
/// Gateway/agent CPU runs on the engine's own cores (the front-end box);
/// everything after placement runs on the chosen node's pools.  On
/// single-node presets this gives the front-end and the node separate
/// core budgets where the old `fnplat` wiring shared one pool — the
/// difference only shows as slightly less queuing past saturation
/// (parallelism ≫ cores), well inside every calibrated band, and is the
/// honest topology once the platform has more than one node.
fn head_steps(cfg: &PlatformConfig) -> Vec<Step> {
    match &cfg.path {
        RequestPath::Direct => vec![Step::decision("dispatch", TAG_DISPATCH)],
        RequestPath::Agent { client, server, include_conn_setup, placement, db } => {
            let mut v = Vec::new();
            if *include_conn_setup {
                v.extend(cfg.driver.frontend.connect_steps(*client, *server));
            }
            v.push(crate::net::rtt_step("req-resp-rtt", *client, *server));
            v.extend(placement.request_tax_steps());
            v.extend(crate::fnplat::agent_steps(*db));
            v.push(Step::decision("dispatch", TAG_DISPATCH));
            v
        }
    }
}

/// Replay `cfg.load` through `policy` over the configured node set.
pub fn run_platform(
    cfg: &PlatformConfig,
    policy: &mut dyn LifecyclePolicy,
    host: Host,
) -> PlatformResult {
    assert!(cfg.nodes >= 1, "need at least one node");
    assert!(cfg.nodes <= super::MAX_NODES, "at most {} nodes (engine pool ids)", super::MAX_NODES);
    assert!(cfg.functions >= 1, "need at least one function");

    let func_names: Vec<String> = (0..cfg.functions).map(|f| format!("f{f}")).collect();
    let images: Vec<Image> = func_names
        .iter()
        .map(|n| Image::for_function(n, cfg.driver.tech))
        .collect();

    let (cold_extra, conn_setup_ms) = match &cfg.path {
        RequestPath::Direct => (Vec::new(), 0.0),
        RequestPath::Agent { client, server, placement, .. } => (
            placement.cold_tax_steps(),
            cfg.driver.frontend.nominal_setup_ms(*client, *server),
        ),
    };

    let domain = PlatformSim {
        cold_extra,
        warm_steps: cfg.driver.warm_steps.clone(),
        cold_steps: cfg.driver.cold_steps.clone(),
        exec_ms: cfg.exec_ms,
        fabric_gbps: cfg.fabric_gbps,
        disk_bw_bytes_per_s: host.disk_bw_bytes_per_s,
        policy,
        sched: Scheduler::new(cfg.scheduler),
        nodes: Vec::new(),
        func_names,
        images,
        template: Vec::new(),
        remaining: 0,
        gap_ns: 0,
        placed: HashMap::new(),
        pending_prewarms: Vec::new(),
        prewarm_keeps: (0..cfg.functions).map(|_| VecDeque::new()).collect(),
        prewarm_boots: 0,
        cold_hist: Histogram::new(),
        warm_hist: Histogram::new(),
        exact: cfg.exact_latencies,
        latencies_ns: Vec::new(),
        cold_latencies_ns: Vec::new(),
        warm_latencies_ns: Vec::new(),
    };

    // The placement-only path leaves the engine's own cores unused
    // (everything runs through node pools); size them out of the way.
    let engine_host = match cfg.path {
        RequestPath::Direct => Host { cores: u32::MAX, disk_bw_bytes_per_s: host.disk_bw_bytes_per_s },
        RequestPath::Agent { .. } => host,
    };
    let mut e = Engine::new(domain, engine_host, cfg.seed);
    for id in 0..cfg.nodes {
        let mut node = NodeState::new(
            id,
            cfg.cores_per_node,
            cfg.mem_slots_per_node,
            cfg.warmup_keep_ns,
            cfg.mem_bytes_per_slot,
        );
        node.cpu_pool = e.add_pool(cfg.cores_per_node);
        let mut locks = [0u8; N_LOCKS];
        for (class, slot) in locks.iter_mut().enumerate() {
            // No startup pipeline holds the metadata-DB lock (it lives on
            // the non-retargeted agent path); sharing its slot with the
            // engine-serialization pool keeps 32 nodes x 7 pools inside
            // the engine's u8 pool-id space while staying serializing if
            // a future pipeline ever does hold it.
            if class == crate::sim::LockClass::Db as usize {
                continue;
            }
            *slot = e.add_pool(1);
        }
        locks[crate::sim::LockClass::Db as usize] =
            locks[crate::sim::LockClass::DockerEngine as usize];
        node.lock_pools = locks;
        node.disk_pool = e.add_pool(1);
        e.domain.nodes.push(node);
    }
    match cfg.seeding {
        // FirstN(0) is honored: no pre-seeding, every first start pulls.
        ImageSeeding::FirstN(n) => {
            for img in &e.domain.images {
                for node in e.domain.nodes.iter_mut().take(n) {
                    let _ = node.cache.fetch(img);
                }
            }
        }
        ImageSeeding::RoundRobin => {
            let n_nodes = e.domain.nodes.len();
            for (f, img) in e.domain.images.iter().enumerate() {
                let _ = e.domain.nodes[f % n_nodes].cache.fetch(img);
            }
        }
    }

    let head = head_steps(cfg);
    match &cfg.load {
        PlatformLoad::ClosedLoop { parallelism, total, prewarm, gap_ns } => {
            assert!(*parallelism as u64 <= *total);
            if *prewarm {
                let name = e.domain.func_names[0].clone();
                e.domain.nodes[0].pool.prewarm_until(
                    &name,
                    *parallelism as u64,
                    0,
                    cfg.warmup_keep_ns,
                );
            }
            e.domain.template = head.clone();
            e.domain.remaining = total - *parallelism as u64;
            e.domain.gap_ns = *gap_ns;
            for _ in 0..*parallelism {
                e.spawn_at(0, 0, head.clone());
            }
            e.run(total.saturating_mul(192).max(1 << 20));
        }
        PlatformLoad::OpenTrace(trace) => {
            for &t in &trace.arrivals_ns {
                e.spawn_at(t, 0, head.clone());
            }
            e.run((trace.len() as u64).saturating_mul(192).max(1 << 20));
        }
        PlatformLoad::Tenants(tt) => {
            for &(at, func) in &tt.arrivals {
                e.spawn_at(at, func, head.clone());
            }
            e.run((tt.len() as u64).saturating_mul(192).max(1 << 20));
        }
        PlatformLoad::Burst { requests, burst_ms } => {
            let mut arrivals = Rng::new(cfg.seed ^ 0xA5A5);
            for _ in 0..*requests {
                let at = (arrivals.next_f64() * burst_ms * 1e6) as u64;
                e.spawn_at(at, 0, head.clone());
            }
            e.run(requests.saturating_mul(192).max(1 << 20));
        }
    }

    let now = e.now();
    let d = &mut e.domain;
    let mut hist = Histogram::new();
    let mut node_hists = Vec::with_capacity(d.nodes.len());
    let mut idle_mem_byte_ns: u128 = 0;
    let (mut warm_hits, mut cold_starts, mut expirations, mut retirements, mut monitor_events) =
        (0u64, 0u64, 0u64, 0u64, 0u64);
    for n in &mut d.nodes {
        n.pool.finalize(now);
        hist.merge(&n.hist);
        node_hists.push(n.hist.clone());
        idle_mem_byte_ns += n.pool.idle_mem_byte_ns;
        warm_hits += n.pool.warm_hits;
        cold_starts += n.pool.cold_starts;
        expirations += n.pool.expirations;
        retirements += n.pool.retirements;
        monitor_events += n.pool.monitor_events;
    }
    let nodes_with_first = nodes_with_image(&d.nodes, &d.func_names[0]);

    PlatformResult {
        requests: hist.len(),
        elapsed_ns: now,
        hist,
        cold_hist: d.cold_hist.clone(),
        warm_hist: d.warm_hist.clone(),
        node_hists,
        latencies_ns: std::mem::take(&mut d.latencies_ns),
        cold_latencies_ns: std::mem::take(&mut d.cold_latencies_ns),
        warm_latencies_ns: std::mem::take(&mut d.warm_latencies_ns),
        warm_hits,
        cold_starts,
        prewarm_boots: d.prewarm_boots,
        expirations,
        retirements,
        idle_gb_seconds: idle_mem_byte_ns as f64 / 1e9 / (1u64 << 30) as f64,
        monitor_events,
        transfers: d.sched.transfers,
        transferred_bytes: d.sched.transferred_bytes,
        footprint_bytes: footprint_bytes(&d.nodes),
        nodes_with_first_image: nodes_with_first,
        conn_setup_ms,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fnplat::DriverKind;
    use crate::policy::{ColdOnlyPolicy, FixedKeepAlive};
    use crate::platform::DriverProfile;
    use crate::workload::tenants::{TenantConfig, TenantTrace};

    fn tenant_cfg(driver: DriverKind, nodes: usize) -> (PlatformConfig, TenantTrace) {
        let trace = TenantTrace::generate(&TenantConfig {
            functions: 50,
            duration_s: 60.0,
            total_rps: 40.0,
            seed: 0x7E57,
            ..Default::default()
        });
        let cfg = PlatformConfig {
            load: PlatformLoad::Tenants(trace.clone()),
            functions: 50,
            nodes,
            ..PlatformConfig::single_node(DriverProfile::from_kind(driver), 24)
        };
        (cfg, trace)
    }

    #[test]
    fn cold_only_serves_everything_cold_with_zero_waste() {
        let (cfg, trace) = tenant_cfg(DriverKind::IncludeOsCold, 1);
        let r = run_platform(&cfg, &mut ColdOnlyPolicy, Host::default());
        let n = trace.len() as u64;
        assert_eq!(r.requests, n);
        assert_eq!(r.warm_hits, 0);
        assert_eq!(r.cold_starts, n);
        assert_eq!(r.retirements, n);
        assert_eq!(r.idle_gb_seconds, 0.0);
        assert_eq!(r.monitor_events, 0);
        assert_eq!(r.prewarm_boots, 0);
    }

    #[test]
    fn fixed_keepalive_gets_warm_hits_and_pays_waste() {
        let (cfg, _) = tenant_cfg(DriverKind::DockerWarm, 1);
        let r = run_platform(&cfg, &mut FixedKeepAlive::default(), Host::default());
        assert!(r.warm_hits > r.cold_starts, "head functions must reuse executors");
        assert!(r.idle_gb_seconds > 0.0);
        assert!(r.monitor_events > 0);
    }

    #[test]
    fn multi_node_conserves_requests_and_routes_warm() {
        for nodes in [2, 4, 8] {
            let (cfg, trace) = tenant_cfg(DriverKind::DockerWarm, nodes);
            let r = run_platform(&cfg, &mut FixedKeepAlive::default(), Host::default());
            assert_eq!(r.requests, trace.len() as u64, "{nodes} nodes");
            assert_eq!(r.cold_starts + r.warm_hits, r.requests);
            assert!(r.warm_hits > 0, "warm routing must find pooled executors");
            // Per-node histograms merge to the total.
            let per_node: u64 = r.node_hists.iter().map(|h| h.len()).sum();
            assert_eq!(per_node, r.requests);
        }
    }

    #[test]
    fn deterministic_per_seed_across_node_counts() {
        for nodes in [1, 4] {
            let run = || {
                let (cfg, _) = tenant_cfg(DriverKind::DockerWarm, nodes);
                let r = run_platform(&cfg, &mut FixedKeepAlive::default(), Host::default());
                (r.hist.quantile_ms(0.99), r.idle_gb_seconds, r.cold_starts, r.elapsed_ns)
            };
            assert_eq!(run(), run());
        }
    }

    #[test]
    fn histograms_match_exact_quantiles_within_bucket_error() {
        let (mut cfg, _) = tenant_cfg(DriverKind::IncludeOsCold, 2);
        cfg.exact_latencies = true;
        let r = run_platform(&cfg, &mut ColdOnlyPolicy, Host::default());
        for q in [0.5, 0.99] {
            let exact = r.quantile_ms(q); // exact path (raw samples kept)
            let approx = r.hist.quantile_ms(q);
            assert!(
                (approx / exact - 1.0).abs() < 0.06,
                "q{q}: hist {approx} vs exact {exact}"
            );
        }
    }
}
