//! The one DES wiring every platform experiment runs on.
//!
//! A request flows: optional connection setup -> client/server RTT ->
//! placement tax -> gateway/agent/DB -> **dispatch decision** (warm-route
//! or cold-place) -> optional image pull -> startup pipeline retargeted
//! onto the chosen node's core/lock pools -> execution -> **release
//! decision** (the per-function [`LifecyclePolicy`] picks Retire / KeepFor
//! / PrewarmAfter against that node's [`WarmPool`]).  Pre-warms are
//! injected back into virtual time as zero-latency control requests whose
//! only step is a pool effect at the scheduled boot time, on the node the
//! retired executor lived on.
//!
//! Latencies stream into per-node log-bucket [`Histogram`]s (O(1) memory
//! per series; `merge()`d at the end of the run), so million-request fleet
//! sweeps do not allocate per request.  Exact raw samples stay available
//! behind [`PlatformConfig::exact_latencies`] for the debug/compat paths.
//!
//! The dispatch decision runs against the scheduler's indexes (S22):
//! warm routing consults only the request's **sharing-key** candidate
//! node set (S23: the function name under the exclusive mode, the
//! runtime key under universal-worker sharing — a claimed slot owned by
//! a different function pays the driver's specialization pipeline), and
//! the cold schedulers their load/replica orders — every pool release,
//! pre-warm boot, crash, and restart notifies [`Scheduler`] so the
//! indexes stay exact (debug builds re-run the pre-index linear scans on
//! every decision and assert the same pick).  Open-loop tenant traces can
//! also be *streamed* ([`PlatformLoad::TenantsStreamed`]): a zero-cost
//! feeder control request injects arrivals chunk by chunk, keeping live
//! engine state proportional to in-flight work — that is what lets E15
//! replay millions of requests over 256 nodes.
//!
//! A [`FaultPlan`] (S21) weaves failures into the same event loop: crash
//! effects mark a node down, drain its warm pool, and kill its in-flight
//! requests (detected when their pipelines unwind; each killed attempt is
//! retried after a client back-off, up to the plan's retry budget, or
//! reported rejected — never silently lost); restart effects bring the
//! node back, optionally with a flushed image cache and a straggler
//! multiplier on its first cold starts.  An empty plan injects nothing
//! and leaves every run byte-identical.

use std::collections::{HashMap, VecDeque};

use crate::image::{Image, NodeCache};
use crate::metrics::Histogram;
use crate::net::transfer_step;
use crate::obs::{
    ChromeTraceSink, Gauges, NullSink, PhaseProfile, Telemetry, TelemetrySeries, TraceSink,
};
use crate::policy::{IdleAction, LifecyclePolicy};
use crate::sim::snap::{fold_chain, Dec, Enc, FNV_OFFSET};
use crate::sim::{Dist, Domain, Engine, Host, ReqId, Rng, Spawn, Step, StepKind, N_LOCKS};
use crate::workload::tenants::TenantTrace;

use super::checkpoint::{config_fingerprint, Checkpoint, DEFAULT_CHECKPOINT_NS};
use super::faults::FaultPlan;
use super::node::NodeState;
use super::sched::{footprint_bytes, nodes_with_image, Scheduler};
use super::shard::{
    HeatClass, ShardMailbox, ShardMsg, ShardPartial, ShardPlan, DEFAULT_BARRIER_NS,
};
use super::{ImageSeeding, PlatformConfig, PlatformLoad, RequestPath};

const TAG_DISPATCH: u32 = 1;
const TAG_RELEASE: u32 = 2;
const TAG_PREWARM: u32 = 3;
const TAG_CRASH: u32 = 4;
const TAG_RESTART: u32 = 5;

/// High bit of the request class marks control requests (pre-warm boots,
/// crash/restart events, arrival feeders) rather than user invocations.
const CONTROL_BIT: u32 = 1 << 31;

/// Bits 24..=30 of a user request's class carry its retry attempt number;
/// the low 24 bits carry the function id.  Crash/restart control requests
/// put the node id in the low bits instead.
const ATTEMPT_SHIFT: u32 = 24;
const FUNC_MASK: u32 = (1 << ATTEMPT_SHIFT) - 1;

/// Class of the arrival-feeder control request for streamed tenant loads
/// (all function bits set — user function ids are strictly below
/// `FUNC_MASK`, and crash/restart controls carry node ids, far smaller).
const FEED_CLASS: u32 = CONTROL_BIT | FUNC_MASK;

/// Arrivals injected per feeder firing: bounds live engine state to the
/// chunk plus whatever is actually in flight, instead of the whole trace.
const STREAM_CHUNK: usize = 4096;

fn attempt_of(class: u32) -> u32 {
    (class & !CONTROL_BIT) >> ATTEMPT_SHIFT
}

/// How warm the dispatch found its executor (latency-binning class).
#[derive(Clone, Copy, PartialEq, Eq)]
enum Heat {
    Warm,
    /// Runtime-warm slot owned by another function: paid specialization.
    Specialized,
    Cold,
}

/// Where a placed request landed (kept until `done` for latency binning).
#[derive(Clone, Copy)]
struct Placed {
    node: usize,
    heat: Heat,
    /// Set when the node crashed under the request: the attempt is lost
    /// and will be retried or rejected when its pipeline unwinds.
    killed: bool,
}

/// One scheduled pre-warm boot: fires at the absolute time, on the node
/// the retired executor lived on, retained for the keep window.
#[derive(Clone, Copy)]
struct PrewarmBoot {
    fire_at_ns: u64,
    node: usize,
    keep_ns: u64,
}

/// Retarget a startup pipeline onto one node's resources: CPU phases use
/// the node's core pool, each kernel-lock class its own per-node
/// single-slot pool, and disk reads the node's local disk (a single-slot
/// pool holding for bytes/bandwidth — the same FIFO serialization the
/// engine's global disk gives one host, but per node, so spreading cold
/// starts actually buys disk parallelism).  Pure delays stay as-is.
/// `mult` stretches every duration (post-restart straggler starts);
/// 1.0 leaves the steps bit-identical to the pre-fault-layer path.
fn retarget(steps: &[Step], node: &NodeState, disk_bw_bytes_per_s: f64, mult: f64) -> Vec<Step> {
    steps
        .iter()
        .map(|s| {
            let dur = if mult == 1.0 { s.dur } else { s.dur.scaled(mult) };
            match s.kind {
                StepKind::Cpu => Step::pool(s.tag, node.cpu_pool, dur),
                StepKind::Lock(class) => Step::pool(s.tag, node.lock_pools[class as usize], dur),
                StepKind::Disk(bytes) => Step::pool(
                    s.tag,
                    node.disk_pool,
                    Dist::Const(bytes as f64 / disk_bw_bytes_per_s * 1e9 * mult),
                ),
                _ => Step { dur, ..*s },
            }
        })
        .collect()
}

/// The unified platform as a simulation domain.
pub struct PlatformSim<'a> {
    // Step templates and rates below are config-derived: rebuilt
    // identically at construction, deliberately outside the snapshot.
    cold_extra: Vec<Step>, // detlint: allow(DL005) config-derived step template
    warm_steps: Vec<Step>, // detlint: allow(DL005) config-derived step template
    cold_steps: Vec<Step>, // detlint: allow(DL005) config-derived step template
    /// Specialization pipeline appended after the warm steps when a
    /// shared claim lands on another function's slot (S23).
    spec_steps: Vec<Step>, // detlint: allow(DL005) config-derived step template
    exec_ms: f64,          // detlint: allow(DL005) config-derived constant
    fabric_gbps: f64,      // detlint: allow(DL005) config-derived constant
    disk_bw_bytes_per_s: f64, // detlint: allow(DL005) config-derived constant
    policy: &'a mut dyn LifecyclePolicy,
    sched: Scheduler,
    pub nodes: Vec<NodeState>,
    func_names: Vec<String>, // detlint: allow(DL005) config-derived catalog
    /// Per-function sharing key (S23): equals `func_names` under the
    /// exclusive mode, the runtime bucket under universal sharing.  Every
    /// pool claim/release and every warm-index notification uses this
    /// key, so routing can never hand a request a mismatched slot.
    route_keys: Vec<String>, // detlint: allow(DL005) config-derived (sharing mode)
    images: Vec<Image>,      // detlint: allow(DL005) config-derived catalog
    faults: FaultPlan,       // detlint: allow(DL005) config-derived plan
    /// Head-of-request steps, re-spawned for client retries of killed
    /// attempts (whatever the load shape).
    head: Vec<Step>, // detlint: allow(DL005) config-derived step template
    // --- streamed open-loop arrivals (E15-scale traces) ---
    /// The trace a feeder control request injects chunk by chunk
    /// (borrowed from the config — a multi-million-entry trace is never
    /// copied into the domain), plus the cursor of the next arrival.
    stream: Option<&'a TenantTrace>, // detlint: allow(DL005) re-borrowed from config on resume
    stream_next: usize,
    // --- closed-loop chaining ---
    template: Vec<Step>, // detlint: allow(DL005) config-derived step template
    remaining: u64,
    gap_ns: u64, // detlint: allow(DL005) config-derived constant
    // --- per-request bookkeeping ---
    placed: HashMap<ReqId, Placed>,
    /// Pre-warms decided during the current release effect, drained into
    /// spawns when the request completes: (func, node, delay_ns, keep_ns).
    pending_prewarms: Vec<(u32, usize, u64, u64)>,
    /// Keep windows for in-flight pre-warm control requests, per function,
    /// matched by absolute boot time (boots may fire out of schedule order
    /// when forecast delays differ).
    prewarm_keeps: Vec<VecDeque<PrewarmBoot>>,
    prewarm_boots: u64,
    /// Chain origins for in-flight retry attempts, keyed by the retry's
    /// (class, spawn time): the original injection instant, so the
    /// latency recorded when a chain finally completes spans every killed
    /// attempt and back-off, not just the serving attempt.  (Engine event
    /// order is deterministic, so the FIFO pairing of identical keys is
    /// too.)
    retry_origins: HashMap<(u32, u64), VecDeque<u64>>,
    // --- fault accounting ---
    /// User requests injected by the load (attempt 0 of every chain).
    injected: u64,
    /// Attempts that completed and returned a response.
    served: u64,
    /// Attempts killed by a node crash (each is retried or rejected).
    killed: u64,
    /// Retry attempts spawned for killed requests.
    retries: u64,
    /// Chains abandoned: retries exhausted, or no node alive at dispatch.
    rejected: u64,
    /// Idle warm executors destroyed by crashes, summed over nodes.
    warm_slots_lost: u64,
    crashes: u64,
    restarts: u64,
    /// Dispatch counts split by disruption-window classification (the
    /// post-restart cold-fraction spike metric).
    window_cold: u64,
    window_total: u64,
    steady_cold: u64,
    steady_total: u64,
    // --- observability (S25): pure observers, never consulted by any
    // routing/pool/fault decision, so the NullSink + disabled telemetry
    // default is byte-identical to the pre-obs platform ---
    sink: Box<dyn TraceSink>, // detlint: allow(DL005) checkpointing refuses armed tracing
    telemetry: Telemetry,
    profile: PhaseProfile,
    // --- sharding (S26): the accounting plane.  Node-attributed domain
    // decisions post ordered messages into the mailbox; per-shard
    // partials absorb them at virtual-time barriers; the report is the
    // shard-order merge.  The engine-global counters below are retained
    // as the debug-parity oracle the merge is asserted against. ---
    plan: ShardPlan, // detlint: allow(DL005) config-derived partition
    mailbox: ShardMailbox,
    partials: Vec<ShardPartial>,
    // --- metrics ---
    cold_hist: Histogram,
    warm_hist: Histogram,
    spec_hist: Histogram,
    exact: bool, // detlint: allow(DL005) config flag (exact_latencies)
    latencies_ns: Vec<u64>,
    cold_latencies_ns: Vec<u64>,
    warm_latencies_ns: Vec<u64>,
    spec_latencies_ns: Vec<u64>,
}

/// Instantaneous cluster gauges for a telemetry sample: idle pool
/// occupancy/bytes and in-flight requests, summed over nodes.
fn cluster_gauges(nodes: &[NodeState]) -> Gauges {
    let mut g = Gauges::default();
    for n in nodes {
        g.idle_slots += n.pool.idle_live();
        g.idle_bytes += n.pool.idle_bytes();
        g.inflight += n.inflight as u64;
    }
    g
}

impl PlatformSim<'_> {
    /// Close any telemetry intervals virtual time has passed.  Called at
    /// the top of every domain callback; a couple of integer compares
    /// when telemetry is off or no boundary has been crossed.
    fn tick_telemetry(&mut self, now: u64) {
        if self.telemetry.pending(now) {
            let g = cluster_gauges(&self.nodes);
            self.telemetry.advance(now, &g);
        }
        // S26: drain the inter-shard mailbox when virtual time crosses a
        // barrier, bounding queued messages by the barrier interval (the
        // drain applies exact integer deltas, so timing is result-pure).
        self.mailbox.maybe_drain(now, &mut self.partials);
    }

    fn dispatch_tail(&mut self, req: ReqId, class: u32, now: u64, rng: &mut Rng) -> Vec<Step> {
        let func = class & FUNC_MASK;
        self.policy.on_invoke(func, now);
        let in_window = self.faults.in_disruption_window(now);
        let key = &self.route_keys[func as usize];
        let mut tail = Vec::new();
        if let Some(node) = self.sched.route_warm(&mut self.nodes, key, now) {
            let d = self.nodes[node].pool.dispatch_shared(key, func, now);
            debug_assert_ne!(d, crate::fnplat::Dispatch::Cold);
            tail.extend(
                retarget(&self.warm_steps, &self.nodes[node], self.disk_bw_bytes_per_s, 1.0),
            );
            let heat = if d == crate::fnplat::Dispatch::Specialized {
                // Runtime warm, function state cold: install it (S23).
                tail.extend(retarget(
                    &self.spec_steps,
                    &self.nodes[node],
                    self.disk_bw_bytes_per_s,
                    1.0,
                ));
                Heat::Specialized
            } else {
                Heat::Warm
            };
            tail.push(Step::pool(
                "fn-exec",
                self.nodes[node].cpu_pool,
                Dist::ms(self.exec_ms, 0.15),
            ));
            tail.push(Step::effect("release", TAG_RELEASE));
            self.placed.insert(req, Placed { node, heat, killed: false });
            if heat == Heat::Specialized {
                self.telemetry.on_spec();
            } else {
                self.telemetry.on_warm();
            }
            if self.sink.enabled() {
                let kind = if heat == Heat::Specialized { "spec" } else { "warm" };
                self.sink.begin(
                    now,
                    node as u32 + 1,
                    req,
                    &format!("{kind} f{func}"),
                    &[
                        ("func", func.to_string()),
                        ("attempt", attempt_of(class).to_string()),
                    ],
                );
            }
            if in_window {
                self.window_total += 1;
            } else {
                self.steady_total += 1;
            }
            self.mailbox.post(
                self.plan.shard_of(node),
                now,
                ShardMsg::Dispatched { cold: false, in_window },
            );
        } else {
            let placement =
                self.sched.place_cold(&mut self.nodes, &self.images[func as usize], rng);
            let Some(out) = placement else {
                // Whole cluster down: the gateway answers 503 and this
                // chain ends here (no placement, no latency sample).
                self.rejected += 1;
                self.telemetry.on_reject();
                self.mailbox.post(0, now, ShardMsg::Rejected);
                if self.sink.enabled() {
                    self.sink.instant(now, 0, "reject");
                }
                return tail;
            };
            let node = out.node;
            let d = self.nodes[node].pool.dispatch_shared(key, func, now);
            debug_assert_eq!(d, crate::fnplat::Dispatch::Cold);
            if out.fetch_bytes > 0 {
                let gbps = self.fabric_gbps / self.faults.fabric_slowdown_at(now);
                tail.push(transfer_step("image-pull", out.fetch_bytes, gbps));
            }
            tail.extend(self.cold_extra.iter().copied());
            // Post-restart straggler starts: the node's first cold starts
            // run slower until its caches re-warm.
            let mult = if now < self.nodes[node].straggle_until_ns {
                self.nodes[node].straggle_mult
            } else {
                1.0
            };
            tail.extend(
                retarget(&self.cold_steps, &self.nodes[node], self.disk_bw_bytes_per_s, mult),
            );
            tail.push(Step::pool(
                "fn-exec",
                self.nodes[node].cpu_pool,
                Dist::ms(self.exec_ms, 0.15),
            ));
            tail.push(Step::effect("release", TAG_RELEASE));
            self.placed.insert(req, Placed { node, heat: Heat::Cold, killed: false });
            self.telemetry.on_cold();
            if self.sink.enabled() {
                self.sink.begin(
                    now,
                    node as u32 + 1,
                    req,
                    &format!("cold f{func}"),
                    &[
                        ("func", func.to_string()),
                        ("attempt", attempt_of(class).to_string()),
                    ],
                );
            }
            if in_window {
                self.window_total += 1;
                self.window_cold += 1;
            } else {
                self.steady_total += 1;
                self.steady_cold += 1;
            }
            self.mailbox.post(
                self.plan.shard_of(node),
                now,
                ShardMsg::Dispatched { cold: true, in_window },
            );
        }
        tail
    }

    /// Canonical encoding of the domain's mutable state (S27) — the bytes
    /// the rolling state hash folds over, appended after the engine core.
    /// Every map is emitted in sorted key order so `HashMap` iteration
    /// order is unobservable, and the sharded accounting plane goes
    /// through its shard-count-invariant form
    /// ([`ShardMailbox::encode_canonical`] + the *merged* partial), so
    /// the hash chain is identical for every `shards` value.  Config-
    /// derived fields (steps, names, images, fault plan, load) are
    /// deliberately omitted: the resume path reconstructs them and the
    /// checkpoint fingerprint pins them.
    fn encode_state(&self, w: &mut Enc) {
        // detlint: allow(DL002) collected then sorted by request id below
        let mut placed: Vec<(&ReqId, &Placed)> = self.placed.iter().collect();
        placed.sort_unstable_by_key(|&(req, _)| *req);
        w.len(placed.len());
        for (req, p) in placed { // detlint: allow(DL002) the sorted Vec, not the map
            w.u32(*req);
            w.usize(p.node);
            w.u8(match p.heat {
                Heat::Warm => 0,
                Heat::Specialized => 1,
                Heat::Cold => 2,
            });
            w.bool(p.killed);
        }
        w.len(self.pending_prewarms.len());
        for &(func, node, delay_ns, keep_ns) in &self.pending_prewarms {
            w.u32(func);
            w.usize(node);
            w.u64(delay_ns);
            w.u64(keep_ns);
        }
        w.len(self.prewarm_keeps.len());
        for q in &self.prewarm_keeps {
            w.len(q.len());
            for b in q {
                w.u64(b.fire_at_ns);
                w.usize(b.node);
                w.u64(b.keep_ns);
            }
        }
        w.u64(self.prewarm_boots);
        // detlint: allow(DL002) collected then sorted by (class, spawn) key
        let mut origins: Vec<(&(u32, u64), &VecDeque<u64>)> = self.retry_origins.iter().collect();
        origins.sort_unstable_by_key(|&(key, _)| *key);
        w.len(origins.len());
        for (&(class, at), q) in origins {
            w.u32(class);
            w.u64(at);
            w.len(q.len());
            for &origin in q {
                w.u64(origin);
            }
        }
        w.usize(self.stream_next);
        w.u64(self.remaining);
        w.u64(self.injected);
        w.u64(self.served);
        w.u64(self.killed);
        w.u64(self.retries);
        w.u64(self.rejected);
        w.u64(self.warm_slots_lost);
        w.u64(self.crashes);
        w.u64(self.restarts);
        w.u64(self.window_cold);
        w.u64(self.window_total);
        w.u64(self.steady_cold);
        w.u64(self.steady_total);
        self.telemetry.encode(w);
        // Profile minus `wall_ns`: wall time is machine-dependent and
        // stamped after the run; the remaining counters are seed-pure.
        w.u64(self.profile.dispatch_decisions);
        w.u64(self.profile.pool_effects);
        w.u64(self.profile.fault_effects);
        w.u64(self.profile.completions);
        w.u64(self.profile.telemetry_samples);
        self.mailbox.encode_canonical(w);
        let mut merged = ShardPartial::default();
        for p in &self.partials {
            merged.merge(p);
        }
        merged.encode(w);
        self.cold_hist.encode(w);
        self.warm_hist.encode(w);
        self.spec_hist.encode(w);
        for v in [
            &self.latencies_ns,
            &self.cold_latencies_ns,
            &self.warm_latencies_ns,
            &self.spec_latencies_ns,
        ] {
            w.len(v.len());
            for &lat in v {
                w.u64(lat);
            }
        }
        w.len(self.nodes.len());
        for n in &self.nodes {
            n.encode(w);
        }
        self.sched.encode(w);
        self.policy.encode_state(w);
    }

    /// Restore supplement: the shard-count-*dependent* layout details a
    /// resume needs but the hash must not see — per-message mailbox queue
    /// indices and the per-shard partials (whose merge is in the hashed
    /// section).
    fn encode_supplement(&self, w: &mut Enc) {
        self.mailbox.encode_layout(w);
        w.len(self.partials.len());
        for p in &self.partials {
            p.encode(w);
        }
    }

    /// Inverse of [`Self::encode_state`] + [`Self::encode_supplement`]
    /// onto a freshly constructed domain of the same configuration.
    /// Rebuilds the scheduler indexes from the restored node state.
    fn restore_state(&mut self, r: &mut Dec, supp: &mut Dec) {
        self.placed.clear();
        for _ in 0..r.len() {
            let req = r.u32();
            let node = r.usize();
            let heat = match r.u8() {
                0 => Heat::Warm,
                1 => Heat::Specialized,
                2 => Heat::Cold,
                other => panic!("snapshot corrupt: Heat tag {other}"),
            };
            let killed = r.bool();
            self.placed.insert(req, Placed { node, heat, killed });
        }
        self.pending_prewarms.clear();
        for _ in 0..r.len() {
            self.pending_prewarms.push((r.u32(), r.usize(), r.u64(), r.u64()));
        }
        let nfuncs = r.len();
        assert_eq!(nfuncs, self.prewarm_keeps.len(), "snapshot function count mismatch");
        for q in &mut self.prewarm_keeps {
            q.clear();
            for _ in 0..r.len() {
                q.push_back(PrewarmBoot { fire_at_ns: r.u64(), node: r.usize(), keep_ns: r.u64() });
            }
        }
        self.prewarm_boots = r.u64();
        self.retry_origins.clear();
        for _ in 0..r.len() {
            let key = (r.u32(), r.u64());
            let mut q = VecDeque::new();
            for _ in 0..r.len() {
                q.push_back(r.u64());
            }
            self.retry_origins.insert(key, q);
        }
        self.stream_next = r.usize();
        self.remaining = r.u64();
        self.injected = r.u64();
        self.served = r.u64();
        self.killed = r.u64();
        self.retries = r.u64();
        self.rejected = r.u64();
        self.warm_slots_lost = r.u64();
        self.crashes = r.u64();
        self.restarts = r.u64();
        self.window_cold = r.u64();
        self.window_total = r.u64();
        self.steady_cold = r.u64();
        self.steady_total = r.u64();
        self.telemetry = Telemetry::decode(r);
        self.profile.dispatch_decisions = r.u64();
        self.profile.pool_effects = r.u64();
        self.profile.fault_effects = r.u64();
        self.profile.completions = r.u64();
        self.profile.telemetry_samples = r.u64();
        self.mailbox.restore(r, supp);
        let merged = ShardPartial::decode(r);
        let nparts = supp.len();
        assert_eq!(nparts, self.partials.len(), "snapshot shard count mismatch");
        for p in &mut self.partials {
            *p = ShardPartial::decode(supp);
        }
        if cfg!(debug_assertions) {
            let mut check = ShardPartial::default();
            for p in &self.partials {
                check.merge(p);
            }
            debug_assert_eq!(check, merged, "per-shard partials diverge from the hashed merge");
        }
        self.cold_hist = Histogram::decode(r);
        self.warm_hist = Histogram::decode(r);
        self.spec_hist = Histogram::decode(r);
        for v in [
            &mut self.latencies_ns,
            &mut self.cold_latencies_ns,
            &mut self.warm_latencies_ns,
            &mut self.spec_latencies_ns,
        ] {
            v.clear();
            for _ in 0..r.len() {
                v.push(r.u64());
            }
        }
        let nnodes = r.len();
        assert_eq!(nnodes, self.nodes.len(), "snapshot node count mismatch");
        for n in &mut self.nodes {
            n.restore(r);
        }
        self.sched.restore(r);
        self.policy.restore_state(r);
        // The routing indexes are rebuilt from restored pools/caches: a
        // (possibly tighter) verified superset, which cannot change any
        // placement decision — debug builds re-assert every pick against
        // the full linear scan.
        self.sched.attach(&self.nodes);
    }
}

impl Domain for PlatformSim<'_> {
    fn decide(&mut self, req: ReqId, class: u32, tag: u32, now: u64, rng: &mut Rng) -> Vec<Step> {
        debug_assert_eq!(tag, TAG_DISPATCH);
        self.tick_telemetry(now);
        self.profile.dispatch_decisions += 1;
        self.dispatch_tail(req, class, now, rng)
    }

    fn effect(&mut self, req: ReqId, class: u32, tag: u32, now: u64) {
        self.tick_telemetry(now);
        let func = class & FUNC_MASK;
        match tag {
            TAG_RELEASE => {
                self.profile.pool_effects += 1;
                let p = *self.placed.get(&req).expect("released request was placed");
                if p.killed {
                    // The executor died with its node: nothing to release
                    // into the pool, and the crash already reset the
                    // node's in-flight counter.
                    return;
                }
                let key = &self.route_keys[func as usize];
                match self.policy.on_idle(func, now) {
                    IdleAction::Retire => self.nodes[p.node].pool.retire(key),
                    IdleAction::KeepFor { keep_ns } => {
                        self.nodes[p.node].pool.release_shared_until(
                            key,
                            func,
                            now,
                            now.saturating_add(keep_ns),
                        );
                        // A degenerate window retired the executor
                        // instead; only a real release makes the node a
                        // warm-routing candidate.
                        if keep_ns > 0 {
                            self.sched.warm_added(key, p.node);
                        }
                    }
                    IdleAction::PrewarmAfter { delay_ns, keep_ns } => {
                        self.nodes[p.node].pool.retire(key);
                        self.pending_prewarms.push((func, p.node, delay_ns, keep_ns));
                    }
                }
                self.sched.complete(&mut self.nodes, p.node);
            }
            TAG_PREWARM => {
                self.profile.pool_effects += 1;
                // Match this boot to its scheduled keep window by fire
                // time: boots fire at exactly their scheduled instant.
                let hit = {
                    let q = &mut self.prewarm_keeps[func as usize];
                    q.iter()
                        .position(|b| b.fire_at_ns == now)
                        .and_then(|i| q.remove(i))
                };
                if let Some(boot) = hit {
                    let key = &self.route_keys[func as usize];
                    // Skip stale pre-warms: an arrival already repopulated
                    // the pool, the keep window degenerated, or the target
                    // node is down (nothing can boot on a dead node).
                    // Probe via warm_available (not idle_count) so an
                    // expired-but-unpurged slot doesn't mask a boot.
                    if boot.keep_ns > 0
                        && self.nodes[boot.node].up
                        && self.nodes[boot.node].pool.warm_available(key, now) == 0
                    {
                        self.prewarm_boots += 1;
                        self.mailbox.post(
                            self.plan.shard_of(boot.node),
                            now,
                            ShardMsg::PrewarmBoot,
                        );
                        if self.sink.enabled() {
                            self.sink.instant(now, boot.node as u32 + 1, "prewarm-boot");
                        }
                        self.nodes[boot.node].pool.prewarm_shared_until(
                            key,
                            func,
                            1,
                            now,
                            now.saturating_add(boot.keep_ns),
                        );
                        self.sched.warm_added(key, boot.node);
                    }
                }
            }
            TAG_CRASH => {
                // Node failure: down for routing, load counter reset, warm
                // pool drained, every in-flight request on it killed (the
                // kill is acted on when each pipeline unwinds — marking is
                // order-independent, so iteration order does not matter).
                let node = func as usize;
                self.crashes += 1;
                self.profile.fault_effects += 1;
                if self.sink.enabled() {
                    self.sink.instant(now, node as u32 + 1, "crash");
                }
                self.sched.node_down(&self.nodes[node]);
                self.nodes[node].up = false;
                self.nodes[node].inflight = 0;
                let drained = self.nodes[node].pool.crash(now);
                self.warm_slots_lost += drained;
                self.mailbox.post(
                    self.plan.shard_of(node),
                    now,
                    ShardMsg::Crashed { slots_lost: drained },
                );
                // detlint: allow(DL002) pure flag-marking; commutative per entry
                for p in self.placed.values_mut() {
                    if p.node == node {
                        p.killed = true;
                    }
                }
            }
            TAG_RESTART => {
                let node = func as usize;
                let f = self
                    .faults
                    .restart_fault(node, now)
                    .expect("restart matches a plan entry");
                self.restarts += 1;
                self.mailbox.post(self.plan.shard_of(node), now, ShardMsg::Restarted);
                self.profile.fault_effects += 1;
                if self.sink.enabled() {
                    self.sink.instant(now, node as u32 + 1, "restart");
                }
                let n = &mut self.nodes[node];
                n.up = true;
                if f.flush_cache {
                    // Node-local storage did not survive: every image
                    // must be pulled again.
                    n.cache = NodeCache::new(None);
                }
                n.straggle_until_ns = now.saturating_add(f.straggler_ns);
                n.straggle_mult = f.straggler_mult;
                self.sched.node_up(&self.nodes[node]);
            }
            other => debug_assert!(false, "unexpected effect tag {other}"),
        }
    }

    fn done(&mut self, req: ReqId, class: u32, start: u64, now: u64) -> Vec<Spawn> {
        self.tick_telemetry(now);
        self.profile.completions += 1;
        let mut spawns = Vec::new();
        for (func, node, delay_ns, keep_ns) in self.pending_prewarms.drain(..) {
            self.prewarm_keeps[func as usize].push_back(PrewarmBoot {
                fire_at_ns: now.saturating_add(delay_ns),
                node,
                keep_ns,
            });
            spawns.push(Spawn {
                delay_ns,
                class: func | CONTROL_BIT,
                steps: vec![Step::effect("prewarm-boot", TAG_PREWARM)],
            });
        }
        if class == FEED_CLASS {
            // Arrival feeder (streamed tenant loads): spawn the next
            // chunk of open-loop arrivals, then re-arm at the last
            // arrival just injected so the chunk after it is in the heap
            // before virtual time reaches it.  Live engine state stays
            // O(chunk + in-flight) instead of O(trace).
            let trace = self.stream.expect("feeder requires a streamed load");
            let start = self.stream_next;
            let end = (start + STREAM_CHUNK).min(trace.arrivals.len());
            for &(at, func) in &trace.arrivals[start..end] {
                spawns.push(Spawn {
                    delay_ns: at.saturating_sub(now),
                    class: func,
                    steps: self.head.clone(),
                });
            }
            if end > start && end < trace.arrivals.len() {
                spawns.push(Spawn {
                    delay_ns: trace.arrivals[end - 1].0.saturating_sub(now),
                    class: FEED_CLASS,
                    steps: Vec::new(),
                });
            }
            self.stream_next = end;
            return spawns;
        }
        if class & CONTROL_BIT == 0 {
            let attempt = attempt_of(class);
            if attempt == 0 {
                self.injected += 1;
                self.mailbox.post(0, now, ShardMsg::Injected);
            }
            // The chain's true start: attempt 0 starts the chain itself;
            // a retry inherits the origin stashed when it was spawned.
            let origin = if attempt == 0 {
                start
            } else {
                let key = (class, start);
                let popped = self
                    .retry_origins
                    .get_mut(&key)
                    .and_then(|q| q.pop_front())
                    .unwrap_or(start);
                if self.retry_origins.get(&key).is_some_and(|q| q.is_empty()) {
                    self.retry_origins.remove(&key);
                }
                popped
            };
            match self.placed.remove(&req) {
                Some(p) if p.killed => {
                    // The node died under this attempt.  The client saw
                    // its connection drop: retry after a back-off (the
                    // fresh attempt re-enters dispatch and lands on a
                    // surviving node), or give up once the budget is
                    // spent — either way the request is accounted for.
                    self.killed += 1;
                    self.mailbox.post(self.plan.shard_of(p.node), now, ShardMsg::Killed);
                    if self.sink.enabled() {
                        // Close the killed attempt's span where it opened.
                        self.sink.end(now, p.node as u32 + 1, req);
                    }
                    if attempt < self.faults.max_retries {
                        self.retries += 1;
                        self.telemetry.on_retry();
                        self.mailbox.post(0, now, ShardMsg::Retry);
                        if self.sink.enabled() {
                            self.sink.instant(now, 0, "retry");
                        }
                        let mut steps = Vec::with_capacity(self.head.len() + 1);
                        steps.push(Step::delay(
                            "client-retry-backoff",
                            Dist::Const(self.faults.retry_backoff_ns as f64),
                        ));
                        steps.extend(self.head.iter().copied());
                        let retry_class =
                            (class & FUNC_MASK) | ((attempt + 1) << ATTEMPT_SHIFT);
                        // The retry spawns at `now` (its back-off is a
                        // step, so it lands inside the chain's latency);
                        // hand it the chain origin under its spawn key.
                        self.retry_origins
                            .entry((retry_class, now))
                            .or_default()
                            .push_back(origin);
                        spawns.push(Spawn { delay_ns: 0, class: retry_class, steps });
                    } else {
                        self.rejected += 1;
                        self.telemetry.on_reject();
                        self.mailbox.post(0, now, ShardMsg::Rejected);
                        if self.sink.enabled() {
                            self.sink.instant(now, 0, "reject");
                        }
                    }
                }
                Some(p) => {
                    self.served += 1;
                    if self.sink.enabled() {
                        self.sink.end(now, p.node as u32 + 1, req);
                    }
                    let lat = now - origin;
                    self.nodes[p.node].hist.record_ns(lat);
                    match p.heat {
                        Heat::Cold => self.cold_hist.record_ns(lat),
                        Heat::Specialized => self.spec_hist.record_ns(lat),
                        Heat::Warm => self.warm_hist.record_ns(lat),
                    }
                    let heat = match p.heat {
                        Heat::Cold => HeatClass::Cold,
                        Heat::Specialized => HeatClass::Specialized,
                        Heat::Warm => HeatClass::Warm,
                    };
                    self.mailbox.post(
                        self.plan.shard_of(p.node),
                        now,
                        ShardMsg::Served { heat, lat_ns: lat },
                    );
                    if self.exact {
                        self.latencies_ns.push(lat);
                        match p.heat {
                            Heat::Cold => self.cold_latencies_ns.push(lat),
                            Heat::Specialized => self.spec_latencies_ns.push(lat),
                            Heat::Warm => self.warm_latencies_ns.push(lat),
                        }
                    }
                }
                // Rejected at dispatch (no node alive): counted there.
                None => {}
            }
            if attempt == 0 && self.remaining > 0 {
                self.remaining -= 1;
                spawns.push(Spawn {
                    delay_ns: self.gap_ns,
                    class,
                    steps: self.template.clone(),
                });
            }
        }
        spawns
    }

    fn observe_step(
        &mut self,
        req: ReqId,
        class: u32,
        tag: &'static str,
        start_ns: u64,
        end_ns: u64,
    ) {
        // Only user-request phases are traced (control chains carry no
        // lifecycle); before placement the phase ran on the frontend
        // (pid 0), after it on the placed node's process row.
        if class & CONTROL_BIT != 0 || !self.sink.enabled() {
            return;
        }
        let pid = self.placed.get(&req).map_or(0, |p| p.node as u32 + 1);
        self.sink.complete(start_ns, end_ns, pid, req, tag);
    }
}

/// Aggregated outcome of one platform run.
pub struct PlatformResult {
    /// User requests served (excludes pre-warm control requests).
    pub requests: u64,
    pub elapsed_ns: u64,
    /// Engine events processed over the whole run — divide by wall time
    /// for the simulator-throughput metric E15 reports.
    pub events: u64,
    /// All-request latency histogram (per-node histograms merged).
    pub hist: Histogram,
    pub cold_hist: Histogram,
    pub warm_hist: Histogram,
    /// Latencies of specialized claims (S23: runtime-warm slot, function
    /// state installed on claim).  Empty under the exclusive mode.
    pub spec_hist: Histogram,
    /// Per-node latency histograms (the merge sources), node order.
    pub node_hists: Vec<Histogram>,
    /// Raw samples — populated only with `exact_latencies` (debug/compat).
    pub latencies_ns: Vec<u64>,
    pub cold_latencies_ns: Vec<u64>,
    pub warm_latencies_ns: Vec<u64>,
    pub spec_latencies_ns: Vec<u64>,
    pub warm_hits: u64,
    /// Cross-function claims of shared warm slots; `warm_hits +
    /// specializations + cold_starts` covers every dispatch that reached
    /// a pool (`served + killed`).
    pub specializations: u64,
    pub cold_starts: u64,
    pub prewarm_boots: u64,
    pub expirations: u64,
    pub retirements: u64,
    pub idle_gb_seconds: f64,
    pub monitor_events: u64,
    // --- fault accounting (all zero when the fault plan is empty) ---
    /// User requests injected by the load (attempt 0 of every chain);
    /// always equals `served + rejected` — nothing is silently lost.
    pub injected: u64,
    /// Attempts that completed and returned a response.
    pub served: u64,
    /// Attempts killed by node crashes (each retried or rejected).
    pub killed: u64,
    /// Retry attempts spawned for killed requests.
    pub retries: u64,
    /// Chains abandoned (retries exhausted, or no node alive).
    pub rejected: u64,
    /// Idle warm executors destroyed by crashes.
    pub warm_slots_lost: u64,
    pub crashes: u64,
    pub restarts: u64,
    /// Dispatches (and the cold ones among them) inside disruption
    /// windows (crash .. restart + spike window) vs. everywhere else.
    pub window_cold: u64,
    pub window_total: u64,
    pub steady_cold: u64,
    pub steady_total: u64,
    /// Cross-node image distribution economics.
    pub transfers: u64,
    pub transferred_bytes: u64,
    pub footprint_bytes: u64,
    /// Nodes caching function 0's image at the end of the run.
    pub nodes_with_first_image: usize,
    /// Median connection-setup cost for the driver's frontend (reported
    /// separately, as in Table I); 0 when the run has no network path.
    pub conn_setup_ms: f64,
    // --- sharding (S26) ---
    /// Accounting shards the node set was partitioned across (clamped to
    /// the node count).  Every value yields a byte-identical report.
    pub shards: usize,
    /// Messages routed through the deterministic inter-shard mailbox.
    /// Independent of the shard count: posting happens per domain event.
    pub shard_msgs: u64,
    /// Virtual-time barriers at which the mailbox drained (including the
    /// final end-of-run drain).
    pub shard_barriers: u64,
    // --- observability (S25) ---
    /// Interval time-series; `None` unless the run sampled telemetry.
    pub telemetry: Option<TelemetrySeries>,
    /// Chrome `trace_event` JSON document; `None` unless tracing was on.
    /// Byte-identical per seed (timestamps are virtual time).
    pub trace_json: Option<String>,
    /// Trace events evicted by the ring buffer (0 when unbounded).
    pub trace_dropped: u64,
    /// Self-profile: per-phase callback counts, the exact engine event
    /// count (strictly compared by the bench gate), wall time and the
    /// machine-dependent `events/s` derived from it (informational only).
    pub profile: PhaseProfile,
    // --- checkpointing (S27) ---
    /// Final value of the rolling state-hash chain; `None` unless the run
    /// was armed (`state_hash`, a checkpoint path, or a resume).  Kept
    /// out of the report JSON — it pins *state*, the report pins output.
    pub state_hash: Option<u64>,
    /// Barrier folds the chain accumulated (resumed runs count the folds
    /// replayed from the checkpoint header, so the total matches an
    /// uninterrupted run).
    pub state_hash_folds: u64,
}

fn fraction(num: u64, den: u64) -> f64 {
    if den == 0 {
        0.0
    } else {
        num as f64 / den as f64
    }
}

impl PlatformResult {
    /// Fraction of dispatches that paid a full cold start (specialized
    /// claims count as non-cold: the runtime was already resident).
    pub fn cold_fraction(&self) -> f64 {
        fraction(self.cold_starts, self.cold_starts + self.warm_hits + self.specializations)
    }

    /// Cold fraction of dispatches inside disruption windows — the
    /// post-restart cold-burst spike a warm platform pays to rebuild its
    /// pools (compare against a dry-run baseline with the same windows).
    pub fn window_cold_fraction(&self) -> f64 {
        fraction(self.window_cold, self.window_total)
    }

    pub fn steady_cold_fraction(&self) -> f64 {
        fraction(self.steady_cold, self.steady_total)
    }

    /// Latency quantile in ms: exact (nearest rank) when raw samples were
    /// kept, streaming-histogram approximation (<5% error) otherwise.
    pub fn quantile_ms(&self, q: f64) -> f64 {
        quantile_of(&self.latencies_ns, &self.hist, q)
    }

    pub fn cold_quantile_ms(&self, q: f64) -> f64 {
        quantile_of(&self.cold_latencies_ns, &self.cold_hist, q)
    }

    pub fn warm_quantile_ms(&self, q: f64) -> f64 {
        quantile_of(&self.warm_latencies_ns, &self.warm_hist, q)
    }

    pub fn spec_quantile_ms(&self, q: f64) -> f64 {
        quantile_of(&self.spec_latencies_ns, &self.spec_hist, q)
    }
}

fn quantile_of(exact: &[u64], hist: &Histogram, q: f64) -> f64 {
    if exact.is_empty() {
        if hist.is_empty() {
            return f64::NAN;
        }
        return hist.quantile_ms(q);
    }
    exact_quantile_ms(exact, q)
}

/// Exact nearest-rank quantile over raw nanosecond samples, in ms — the
/// one implementation every preset reports through.
pub fn exact_quantile_ms(samples: &[u64], q: f64) -> f64 {
    if samples.is_empty() {
        return f64::NAN;
    }
    let mut s = samples.to_vec();
    s.sort_unstable();
    let idx = ((q.clamp(0.0, 1.0) * s.len() as f64).ceil() as usize).saturating_sub(1);
    s[idx.min(s.len() - 1)] as f64 / 1e6
}

/// Head-of-request steps up to (and including) the dispatch decision.
///
/// Gateway/agent CPU runs on the engine's own cores (the front-end box);
/// everything after placement runs on the chosen node's pools.  On
/// single-node presets this gives the front-end and the node separate
/// core budgets where the old `fnplat` wiring shared one pool — the
/// difference only shows as slightly less queuing past saturation
/// (parallelism ≫ cores), well inside every calibrated band, and is the
/// honest topology once the platform has more than one node.
fn head_steps(cfg: &PlatformConfig) -> Vec<Step> {
    match &cfg.path {
        RequestPath::Direct => vec![Step::decision("dispatch", TAG_DISPATCH)],
        RequestPath::Agent { client, server, include_conn_setup, placement, db } => {
            let mut v = Vec::new();
            if *include_conn_setup {
                v.extend(cfg.driver.frontend.connect_steps(*client, *server));
            }
            v.push(crate::net::rtt_step("req-resp-rtt", *client, *server));
            v.extend(placement.request_tax_steps());
            v.extend(crate::fnplat::agent_steps(*db));
            v.push(Step::decision("dispatch", TAG_DISPATCH));
            v
        }
    }
}

/// Replay `cfg.load` through `policy` over the configured node set.
pub fn run_platform(
    cfg: &PlatformConfig,
    policy: &mut dyn LifecyclePolicy,
    host: Host,
) -> PlatformResult {
    assert!(cfg.nodes >= 1, "need at least one node");
    assert!(cfg.nodes <= super::MAX_NODES, "at most {} nodes (engine pool ids)", super::MAX_NODES);
    assert!(cfg.functions >= 1, "need at least one function");
    assert!(cfg.functions <= FUNC_MASK, "function ids must fit the class low bits");
    if let super::SharingMode::PerRuntime { runtimes } = cfg.sharing {
        assert!(runtimes >= 1, "per-runtime sharing needs at least one runtime family");
    }
    assert!(cfg.shards >= 1, "need at least one accounting shard");
    cfg.faults.validate(cfg.nodes);
    let plan = ShardPlan::new(cfg.nodes, cfg.shards);

    let func_names: Vec<String> = (0..cfg.functions).map(|f| format!("f{f}")).collect();
    let route_keys: Vec<String> = func_names
        .iter()
        .enumerate()
        .map(|(f, name)| cfg.sharing.key_for(f as u32, name))
        .collect();
    let images: Vec<Image> = func_names
        .iter()
        .map(|n| Image::for_function(n, cfg.driver.tech))
        .collect();

    let (cold_extra, conn_setup_ms) = match &cfg.path {
        RequestPath::Direct => (Vec::new(), 0.0),
        RequestPath::Agent { client, server, placement, .. } => (
            placement.cold_tax_steps(),
            cfg.driver.frontend.nominal_setup_ms(*client, *server),
        ),
    };

    let sink: Box<dyn TraceSink> = if cfg.obs.trace {
        let windows = if cfg.obs.trace_window_only {
            cfg.faults.disruption_windows()
        } else {
            Vec::new()
        };
        Box::new(ChromeTraceSink::new(cfg.obs.trace_capacity, windows))
    } else {
        Box::new(NullSink)
    };

    let domain = PlatformSim {
        cold_extra,
        warm_steps: cfg.driver.warm_steps.clone(),
        cold_steps: cfg.driver.cold_steps.clone(),
        spec_steps: cfg.driver.specialize_steps.clone(),
        exec_ms: cfg.exec_ms,
        fabric_gbps: cfg.fabric_gbps,
        disk_bw_bytes_per_s: host.disk_bw_bytes_per_s,
        policy,
        sched: Scheduler::new(cfg.scheduler),
        nodes: Vec::new(),
        func_names,
        route_keys,
        images,
        faults: cfg.faults.clone(),
        head: Vec::new(),
        stream: None,
        stream_next: 0,
        template: Vec::new(),
        remaining: 0,
        gap_ns: 0,
        placed: HashMap::new(),
        pending_prewarms: Vec::new(),
        prewarm_keeps: (0..cfg.functions).map(|_| VecDeque::new()).collect(),
        prewarm_boots: 0,
        retry_origins: HashMap::new(),
        injected: 0,
        served: 0,
        killed: 0,
        retries: 0,
        rejected: 0,
        warm_slots_lost: 0,
        crashes: 0,
        restarts: 0,
        window_cold: 0,
        window_total: 0,
        steady_cold: 0,
        steady_total: 0,
        sink,
        telemetry: Telemetry::new(cfg.obs.telemetry_interval_ns),
        profile: PhaseProfile::default(),
        plan,
        mailbox: ShardMailbox::new(plan.shards(), DEFAULT_BARRIER_NS),
        partials: vec![ShardPartial::default(); plan.shards()],
        cold_hist: Histogram::new(),
        warm_hist: Histogram::new(),
        spec_hist: Histogram::new(),
        exact: cfg.exact_latencies,
        latencies_ns: Vec::new(),
        cold_latencies_ns: Vec::new(),
        warm_latencies_ns: Vec::new(),
        spec_latencies_ns: Vec::new(),
    };

    // The placement-only path leaves the engine's own cores unused
    // (everything runs through node pools); size them out of the way.
    let engine_host = match cfg.path {
        RequestPath::Direct => Host { cores: u32::MAX, disk_bw_bytes_per_s: host.disk_bw_bytes_per_s },
        RequestPath::Agent { .. } => host,
    };
    let mut e = Engine::new(domain, engine_host, cfg.seed);
    for id in 0..cfg.nodes {
        let mut node = NodeState::new(
            id,
            cfg.cores_per_node,
            cfg.mem_slots_per_node,
            cfg.warmup_keep_ns,
            cfg.mem_bytes_per_slot,
        );
        node.cpu_pool = e.add_pool(cfg.cores_per_node);
        let mut locks = [0u16; N_LOCKS];
        for (class, slot) in locks.iter_mut().enumerate() {
            // No startup pipeline holds the metadata-DB lock (it lives on
            // the non-retargeted agent path); sharing its slot with the
            // engine-serialization pool keeps the per-node pool count at
            // 7 while staying serializing if a future pipeline ever does
            // hold it.
            if class == crate::sim::LockClass::Db as usize {
                continue;
            }
            *slot = e.add_pool(1);
        }
        locks[crate::sim::LockClass::Db as usize] =
            locks[crate::sim::LockClass::DockerEngine as usize];
        node.lock_pools = locks;
        node.disk_pool = e.add_pool(1);
        e.domain.nodes.push(node);
    }
    match cfg.seeding {
        // FirstN(0) is honored: no pre-seeding, every first start pulls.
        ImageSeeding::FirstN(n) => {
            for img in &e.domain.images {
                for node in e.domain.nodes.iter_mut().take(n) {
                    let _ = node.cache.fetch(img);
                }
            }
        }
        ImageSeeding::RoundRobin => {
            let n_nodes = e.domain.nodes.len();
            for (f, img) in e.domain.images.iter().enumerate() {
                let _ = e.domain.nodes[f % n_nodes].cache.fetch(img);
            }
        }
    }
    // Seeding is done: build the scheduler's load/replica/warm indexes.
    // Everything after this point keeps them current through the
    // claim/complete/warm_added/node_down/node_up notifications.
    e.domain.sched.attach(&e.domain.nodes);

    // Pre-seed shared "universal" workers (S23): `universal_prewarm`
    // runtime-warm executors per shared bucket, spread round-robin over
    // nodes, owned by no function — every first claim pays the
    // specialization pipeline.  The exclusive mode has no shared buckets,
    // so this is a no-op there regardless of the configured count.
    if cfg.universal_prewarm > 0 {
        let keys = cfg.sharing.shared_keys(cfg.functions);
        let mut slot = 0usize;
        for key in &keys {
            for _ in 0..cfg.universal_prewarm {
                let node = slot % cfg.nodes;
                slot += 1;
                e.domain.nodes[node].pool.prewarm_shared_until(
                    key,
                    crate::fnplat::NO_OWNER,
                    1,
                    0,
                    cfg.warmup_keep_ns,
                );
                e.domain.sched.warm_added(key, node);
            }
        }
    }

    // Tracing: name the process rows and pre-draw the scheduled fault
    // windows as duration spans, so a Perfetto view shows the outages and
    // brown-outs the lifecycle events happened under.
    e.observe_steps = cfg.obs.trace;
    if e.domain.sink.enabled() {
        e.domain.sink.process_name(0, "frontend");
        for id in 0..cfg.nodes {
            e.domain.sink.process_name(id as u32 + 1, &format!("node {id}"));
        }
        if !cfg.faults.dry_run {
            for f in &cfg.faults.node_faults {
                if f.up_at_ns < u64::MAX {
                    let pid = f.node as u32 + 1;
                    e.domain.sink.complete(f.down_at_ns, f.up_at_ns, pid, 0, "outage");
                }
            }
            for f in &cfg.faults.fabric_faults {
                e.domain.sink.complete(f.from_ns, f.until_ns, 0, 0, "fabric-brownout");
            }
        }
    }

    let head = head_steps(cfg);
    e.domain.head = head.clone();
    // Weave the fault schedule into virtual time as zero-latency control
    // requests (dry-run plans classify windows but inject nothing).
    if !cfg.faults.dry_run {
        for f in &cfg.faults.node_faults {
            e.spawn_at(
                f.down_at_ns,
                f.node as u32 | CONTROL_BIT,
                vec![Step::effect("node-crash", TAG_CRASH)],
            );
            if f.up_at_ns < u64::MAX {
                e.spawn_at(
                    f.up_at_ns,
                    f.node as u32 | CONTROL_BIT,
                    vec![Step::effect("node-restart", TAG_RESTART)],
                );
            }
        }
    }
    #[allow(clippy::disallowed_methods)]
    let run_started = std::time::Instant::now(); // detlint: allow(DL001) informational events/s wall metric
    let budget: u64 = match &cfg.load {
        PlatformLoad::ClosedLoop { parallelism, total, prewarm, gap_ns } => {
            assert!(*parallelism as u64 <= *total);
            if *prewarm {
                // Measurement warmup holds function 0's state: claims by
                // function 0 are plain warm hits under every mode.
                let key = e.domain.route_keys[0].clone();
                e.domain.nodes[0].pool.prewarm_shared_until(
                    &key,
                    0,
                    *parallelism as u64,
                    0,
                    cfg.warmup_keep_ns,
                );
                e.domain.sched.warm_added(&key, 0);
            }
            e.domain.template = head.clone();
            e.domain.remaining = total - *parallelism as u64;
            e.domain.gap_ns = *gap_ns;
            for _ in 0..*parallelism {
                e.spawn_at(0, 0, head.clone());
            }
            total.saturating_mul(192).max(1 << 20)
        }
        PlatformLoad::OpenTrace(trace) => {
            for &t in &trace.arrivals_ns {
                e.spawn_at(t, 0, head.clone());
            }
            (trace.len() as u64).saturating_mul(192).max(1 << 20)
        }
        PlatformLoad::Tenants(tt) => {
            for &(at, func) in &tt.arrivals {
                e.spawn_at(at, func, head.clone());
            }
            (tt.len() as u64).saturating_mul(192).max(1 << 20)
        }
        PlatformLoad::TenantsStreamed(tt) => {
            e.domain.stream = Some(tt);
            e.spawn_at(0, FEED_CLASS, Vec::new());
            (tt.len() as u64).saturating_mul(192).max(1 << 20)
        }
        PlatformLoad::Burst { requests, burst_ms } => {
            let mut arrivals = Rng::new(cfg.seed ^ 0xA5A5);
            for _ in 0..*requests {
                let at = (arrivals.next_f64() * burst_ms * 1e6) as u64;
                e.spawn_at(at, 0, head.clone());
            }
            requests.saturating_mul(192).max(1 << 20)
        }
    };
    // S27: the rolling state hash and the checkpoint loop share one armed
    // path — any of the four knobs turns the plain `run` into a sequence
    // of `run_until` barrier legs with a hash fold at each.  The legs
    // process exactly the events the plain run would (the barrier peeks,
    // never pops), so an unarmed run is byte-identical to an armed one.
    let armed = cfg.state_hash
        || cfg.checkpoint_path.is_some()
        || cfg.resume_from.is_some()
        || cfg.checkpoint_every_ns > 0;
    let (state_hash, state_hash_folds) = if armed {
        let every = if cfg.checkpoint_every_ns > 0 {
            cfg.checkpoint_every_ns
        } else {
            DEFAULT_CHECKPOINT_NS
        };
        let (chain, folds) = run_checkpointed(&mut e, cfg, budget, every);
        (Some(chain), folds)
    } else {
        e.run(budget);
        (None, 0)
    };

    // Wall time spans load spawning + the engine run: machine dependent,
    // never rendered, informational-only in the compare gate.
    let wall_ns = run_started.elapsed().as_nanos() as u64;

    // S27 satellite: the cheapest engine invariants are always-on checked
    // errors at finalize, not debug-only hopes — a run that ends with a
    // misordered queue or undrained events must never produce a report.
    e.validate_queue();
    assert_eq!(e.pending_events(), 0, "run ended with events still queued — budget exhausted?");

    let now = e.now();
    let events = e.events_processed();
    let d = &mut e.domain;
    // Close out the observers before pool finalization mutates the
    // gauges they sample.
    let end_gauges = cluster_gauges(&d.nodes);
    let telemetry = std::mem::take(&mut d.telemetry).finish(now, &end_gauges);
    let trace_json = d.sink.take_trace_json();
    let trace_dropped = d.sink.dropped();
    let mut profile = d.profile;
    profile.engine_events = events;
    profile.telemetry_samples = telemetry.as_ref().map_or(0, |t| t.len() as u64);
    profile.wall_ns = wall_ns;
    // S26 finalize: land every queued mailbox message in its shard's
    // partial, then run the per-shard node teardown — each worker owns
    // one shard's contiguous node range, so with K > 1 (and the sweep
    // thread knob allowing it) the workers run concurrently on
    // `thread::scope`, the sweep-runner primitive.  The shard-order merge
    // below is exact-integer arithmetic throughout, which is what makes
    // the result bit-identical for every shard count, including K = 1.
    let mut partials = std::mem::take(&mut d.partials);
    d.mailbox.drain(&mut partials);
    {
        let mut chunks: Vec<(&mut ShardPartial, &mut [NodeState])> =
            Vec::with_capacity(partials.len());
        let mut rest: &mut [NodeState] = &mut d.nodes;
        for (shard, p) in partials.iter_mut().enumerate() {
            let (chunk, tail) = rest.split_at_mut(d.plan.range(shard).len());
            rest = tail;
            chunks.push((p, chunk));
        }
        let finalize_shard = |p: &mut ShardPartial, nodes: &mut [NodeState]| {
            for n in nodes {
                n.pool.finalize(now);
                p.hist.merge(&n.hist);
                p.idle_mem_byte_ns += n.pool.idle_mem_byte_ns;
                p.warm_hits += n.pool.warm_hits;
                p.specializations += n.pool.specializations;
                p.cold_starts += n.pool.cold_starts;
                p.expirations += n.pool.expirations;
                p.retirements += n.pool.retirements;
                p.monitor_events += n.pool.monitor_events;
            }
        };
        if chunks.len() > 1 && crate::experiments::sweep::sweep_threads(chunks.len()) > 1 {
            std::thread::scope(|s| {
                for (p, chunk) in chunks {
                    s.spawn(move || finalize_shard(p, chunk));
                }
            });
        } else {
            for (p, chunk) in chunks {
                finalize_shard(p, chunk);
            }
        }
    }
    let mut total = ShardPartial::default();
    for p in &partials {
        total.merge(p);
    }
    // S27 satellite: conservation laws promoted to always-on checked
    // errors — they cost a handful of integer compares per *run* and turn
    // lost-request bugs into hard failures in release builds too.
    assert_eq!(
        total.injected,
        total.served + total.rejected,
        "request conservation violated: injected != served + rejected"
    );
    assert_eq!(
        total.warm_hits + total.specializations + total.cold_starts,
        total.window_total + total.steady_total,
        "dispatch conservation violated: pool claims != dispatch decisions"
    );
    // Debug-parity oracle: the engine-global accounting retained on the
    // domain must agree with the message-driven shard merge exactly.
    debug_assert_eq!(total.injected, d.injected);
    debug_assert_eq!(total.served, d.served);
    debug_assert_eq!(total.killed, d.killed);
    debug_assert_eq!(total.retries, d.retries);
    debug_assert_eq!(total.rejected, d.rejected);
    debug_assert_eq!(total.crashes, d.crashes);
    debug_assert_eq!(total.restarts, d.restarts);
    debug_assert_eq!(total.prewarm_boots, d.prewarm_boots);
    debug_assert_eq!(total.warm_slots_lost, d.warm_slots_lost);
    debug_assert_eq!(
        (total.window_cold, total.window_total, total.steady_cold, total.steady_total),
        (d.window_cold, d.window_total, d.steady_cold, d.steady_total),
        "disruption-window split diverged from the shard merge"
    );
    debug_assert!(total.cold_hist == d.cold_hist, "cold-heat histogram diverged");
    debug_assert!(total.warm_hist == d.warm_hist, "warm-heat histogram diverged");
    debug_assert!(total.spec_hist == d.spec_hist, "spec-heat histogram diverged");
    let node_hists: Vec<Histogram> = d.nodes.iter().map(|n| n.hist.clone()).collect();
    let nodes_with_first = nodes_with_image(&d.nodes, &d.func_names[0]);

    PlatformResult {
        requests: total.hist.len(),
        elapsed_ns: now,
        events,
        hist: total.hist,
        cold_hist: total.cold_hist,
        warm_hist: total.warm_hist,
        spec_hist: total.spec_hist,
        node_hists,
        latencies_ns: std::mem::take(&mut d.latencies_ns),
        cold_latencies_ns: std::mem::take(&mut d.cold_latencies_ns),
        warm_latencies_ns: std::mem::take(&mut d.warm_latencies_ns),
        spec_latencies_ns: std::mem::take(&mut d.spec_latencies_ns),
        warm_hits: total.warm_hits,
        specializations: total.specializations,
        cold_starts: total.cold_starts,
        prewarm_boots: total.prewarm_boots,
        expirations: total.expirations,
        retirements: total.retirements,
        idle_gb_seconds: total.idle_mem_byte_ns as f64 / 1e9 / (1u64 << 30) as f64,
        monitor_events: total.monitor_events,
        injected: total.injected,
        served: total.served,
        killed: total.killed,
        retries: total.retries,
        rejected: total.rejected,
        warm_slots_lost: total.warm_slots_lost,
        crashes: total.crashes,
        restarts: total.restarts,
        window_cold: total.window_cold,
        window_total: total.window_total,
        steady_cold: total.steady_cold,
        steady_total: total.steady_total,
        transfers: d.sched.transfers,
        transferred_bytes: d.sched.transferred_bytes,
        footprint_bytes: footprint_bytes(&d.nodes),
        nodes_with_first_image: nodes_with_first,
        conn_setup_ms,
        shards: d.plan.shards(),
        shard_msgs: d.mailbox.posted(),
        shard_barriers: d.mailbox.barriers(),
        telemetry,
        trace_json,
        trace_dropped,
        profile,
        state_hash,
        state_hash_folds,
    }
}

/// The armed engine loop (S27): run to each virtual-time barrier, fold
/// the canonical state section into the rolling hash chain, and — when a
/// checkpoint path is set — persist the barrier atomically.  On resume,
/// the freshly constructed engine+domain are overwritten with the
/// snapshot before the first leg, and the chain/fold counters continue
/// from the header, so a killed run and an uninterrupted one finish with
/// identical chains and identical reports.
///
/// The checkpoint is written only for *mid-run* barriers (`more ==
/// true`): the final fold happens once the queue is drained, at an
/// arbitrary virtual time, and persisting it would make resume-after-
/// completion fold one extra link and diverge the chain.  Resuming a
/// completed run therefore replays the tail from the last mid-run
/// barrier — wasted work, never wrong answers.
fn run_checkpointed(
    e: &mut Engine<PlatformSim<'_>>,
    cfg: &PlatformConfig,
    budget: u64,
    every: u64,
) -> (u64, u64) {
    assert!(
        !cfg.obs.trace,
        "checkpointing/state-hash runs are incompatible with lifecycle tracing (S27): \
         the trace ring is not snapshotted"
    );
    let fingerprint = config_fingerprint(cfg);
    let mut chain = FNV_OFFSET;
    let mut folds: u64 = 0;
    let mut next_barrier = every;
    if let Some(path) = &cfg.resume_from {
        let ck = Checkpoint::read(path)
            .unwrap_or_else(|err| panic!("cannot resume from {path}: {err}"));
        assert_eq!(
            ck.fingerprint, fingerprint,
            "checkpoint {path} was written by a different configuration — refusing to resume"
        );
        assert_eq!(
            ck.every_ns, every,
            "checkpoint {path} used a different barrier cadence — the hash chain folds once \
             per barrier, so resume must match"
        );
        let mut r = Dec::new(&ck.state);
        let mut supp = Dec::new(&ck.supplement);
        e.restore_core(&mut r);
        e.domain.restore_state(&mut r, &mut supp);
        r.finish();
        supp.finish();
        chain = ck.chain;
        folds = ck.folds;
        next_barrier = ck.t_barrier_ns + every;
    }
    loop {
        let more = e.run_until(next_barrier, budget);
        let mut w = Enc::new();
        e.encode_core(&mut w);
        e.domain.encode_state(&mut w);
        chain = fold_chain(chain, &w.buf);
        folds += 1;
        if !more {
            break;
        }
        if let Some(path) = &cfg.checkpoint_path {
            let mut supp = Enc::new();
            e.domain.encode_supplement(&mut supp);
            let ck = Checkpoint {
                fingerprint,
                every_ns: every,
                t_barrier_ns: next_barrier,
                chain,
                folds,
                state: w.buf,
                supplement: supp.buf,
            };
            ck.write(path).unwrap_or_else(|err| panic!("cannot write checkpoint {path}: {err}"));
        }
        next_barrier += every;
    }
    (chain, folds)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fnplat::DriverKind;
    use crate::platform::faults::{chaos_plan, NodeFault};
    use crate::platform::DriverProfile;
    use crate::policy::{ColdOnlyPolicy, EwmaPredictive, FixedKeepAlive};
    use crate::workload::tenants::{TenantConfig, TenantTrace};

    const S: u64 = 1_000_000_000;

    fn tenant_cfg(driver: DriverKind, nodes: usize) -> (PlatformConfig, TenantTrace) {
        let trace = TenantTrace::generate(&TenantConfig {
            functions: 50,
            duration_s: 60.0,
            total_rps: 40.0,
            seed: 0x7E57,
            ..Default::default()
        });
        let cfg = PlatformConfig {
            load: PlatformLoad::Tenants(trace.clone()),
            functions: 50,
            nodes,
            ..PlatformConfig::single_node(DriverProfile::from_kind(driver), 24)
        };
        (cfg, trace)
    }

    #[test]
    fn cold_only_serves_everything_cold_with_zero_waste() {
        let (cfg, trace) = tenant_cfg(DriverKind::IncludeOsCold, 1);
        let r = run_platform(&cfg, &mut ColdOnlyPolicy, Host::default());
        let n = trace.len() as u64;
        assert_eq!(r.requests, n);
        assert_eq!(r.warm_hits, 0);
        assert_eq!(r.cold_starts, n);
        assert_eq!(r.retirements, n);
        assert_eq!(r.idle_gb_seconds, 0.0);
        assert_eq!(r.monitor_events, 0);
        assert_eq!(r.prewarm_boots, 0);
    }

    #[test]
    fn fixed_keepalive_gets_warm_hits_and_pays_waste() {
        let (cfg, _) = tenant_cfg(DriverKind::DockerWarm, 1);
        let r = run_platform(&cfg, &mut FixedKeepAlive::default(), Host::default());
        assert!(r.warm_hits > r.cold_starts, "head functions must reuse executors");
        assert!(r.idle_gb_seconds > 0.0);
        assert!(r.monitor_events > 0);
    }

    #[test]
    fn multi_node_conserves_requests_and_routes_warm() {
        for nodes in [2, 4, 8] {
            let (cfg, trace) = tenant_cfg(DriverKind::DockerWarm, nodes);
            let r = run_platform(&cfg, &mut FixedKeepAlive::default(), Host::default());
            assert_eq!(r.requests, trace.len() as u64, "{nodes} nodes");
            assert_eq!(r.cold_starts + r.warm_hits, r.requests);
            assert!(r.warm_hits > 0, "warm routing must find pooled executors");
            // Per-node histograms merge to the total.
            let per_node: u64 = r.node_hists.iter().map(|h| h.len()).sum();
            assert_eq!(per_node, r.requests);
        }
    }

    #[test]
    fn deterministic_per_seed_across_node_counts() {
        for nodes in [1, 4] {
            let run = || {
                let (cfg, _) = tenant_cfg(DriverKind::DockerWarm, nodes);
                let r = run_platform(&cfg, &mut FixedKeepAlive::default(), Host::default());
                (r.hist.quantile_ms(0.99), r.idle_gb_seconds, r.cold_starts, r.elapsed_ns)
            };
            assert_eq!(run(), run());
        }
    }

    #[test]
    fn crash_kills_in_flight_and_retries_conserve_requests() {
        let (mut cfg, trace) = tenant_cfg(DriverKind::DockerWarm, 2);
        cfg.faults = chaos_plan(2, 60 * S);
        let r = run_platform(&cfg, &mut FixedKeepAlive::default(), Host::default());
        assert_eq!(r.injected, trace.len() as u64);
        assert_eq!(r.injected, r.served + r.rejected, "no request silently lost");
        assert_eq!(r.rejected, 0, "node 0 survives, so every retry must land");
        assert_eq!(r.served, r.requests);
        assert_eq!((r.crashes, r.restarts), (2, 2));
        assert!(r.warm_slots_lost > 0, "fixed keep-alive had idle slots to lose");
        assert_eq!(r.killed, r.retries, "every kill retried within budget");
    }

    #[test]
    fn cold_only_has_no_state_to_lose() {
        let (mut cfg, _) = tenant_cfg(DriverKind::IncludeOsCold, 2);
        cfg.faults = chaos_plan(2, 60 * S);
        let r = run_platform(&cfg, &mut ColdOnlyPolicy, Host::default());
        assert_eq!(r.warm_slots_lost, 0);
        assert_eq!(r.idle_gb_seconds, 0.0);
        assert_eq!(r.injected, r.served + r.rejected);
        assert_eq!(r.rejected, 0);
        assert!(r.window_total > 0, "trace must hit the disruption windows");
        // Already all-cold: crashes cannot spike the cold fraction.
        assert_eq!(r.window_cold_fraction(), 1.0);
        assert_eq!(r.steady_cold_fraction(), 1.0);
    }

    #[test]
    fn whole_cluster_down_rejects_instead_of_losing_requests() {
        let (mut cfg, trace) = tenant_cfg(DriverKind::IncludeOsCold, 1);
        cfg.faults = FaultPlan {
            node_faults: vec![NodeFault {
                node: 0,
                down_at_ns: 10 * S,
                up_at_ns: u64::MAX, // never comes back
                flush_cache: false,
                straggler_mult: 1.0,
                straggler_ns: 0,
            }],
            ..FaultPlan::default()
        };
        let r = run_platform(&cfg, &mut ColdOnlyPolicy, Host::default());
        assert_eq!(r.injected, trace.len() as u64);
        assert_eq!(r.injected, r.served + r.rejected);
        assert!(r.rejected > 0 && r.served > 0);
        assert_eq!(r.requests, r.served);
    }

    #[test]
    fn dry_run_plan_is_observationally_pure() {
        let run = |faults: FaultPlan| {
            let (mut cfg, _) = tenant_cfg(DriverKind::DockerWarm, 4);
            cfg.exact_latencies = true;
            cfg.faults = faults;
            run_platform(&cfg, &mut FixedKeepAlive::default(), Host::default())
        };
        let clean = run(FaultPlan::default());
        let dry = run(chaos_plan(4, 60 * S).dry());
        assert_eq!(dry.latencies_ns, clean.latencies_ns);
        assert_eq!(dry.cold_starts, clean.cold_starts);
        assert_eq!(dry.idle_gb_seconds, clean.idle_gb_seconds);
        assert_eq!((dry.crashes, dry.killed), (0, 0));
        assert!(dry.window_total > 0, "windows must still classify");
        assert_eq!(clean.window_total, 0, "empty plan has no windows");
    }

    #[test]
    fn deterministic_under_faults() {
        let run = || {
            let (mut cfg, _) = tenant_cfg(DriverKind::DockerWarm, 4);
            cfg.faults = chaos_plan(4, 60 * S);
            let r = run_platform(&cfg, &mut FixedKeepAlive::default(), Host::default());
            (r.hist.quantile_ms(0.99), r.served, r.killed, r.retries, r.warm_slots_lost)
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn streamed_tenant_load_conserves_and_is_deterministic() {
        let run = || {
            let (mut cfg, trace) = tenant_cfg(DriverKind::DockerWarm, 4);
            cfg.load = PlatformLoad::TenantsStreamed(trace.clone());
            let r = run_platform(&cfg, &mut FixedKeepAlive::default(), Host::default());
            assert_eq!(r.requests, trace.len() as u64, "every streamed arrival served");
            assert_eq!(r.cold_starts + r.warm_hits, r.requests);
            assert!(r.warm_hits > 0);
            (r.hist.quantile_ms(0.99), r.idle_gb_seconds, r.cold_starts, r.elapsed_ns)
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn streamed_and_bulk_loads_agree_on_aggregates() {
        // Streaming changes only *when* arrivals enter the engine heap,
        // never which arrivals exist: request counts and pool accounting
        // identities match the up-front spawn exactly.
        let (cfg_bulk, trace) = tenant_cfg(DriverKind::IncludeOsCold, 2);
        let bulk = run_platform(&cfg_bulk, &mut ColdOnlyPolicy, Host::default());
        let (mut cfg_stream, _) = tenant_cfg(DriverKind::IncludeOsCold, 2);
        cfg_stream.load = PlatformLoad::TenantsStreamed(trace.clone());
        let stream = run_platform(&cfg_stream, &mut ColdOnlyPolicy, Host::default());
        assert_eq!(stream.requests, bulk.requests);
        assert_eq!(stream.cold_starts, bulk.cold_starts);
        assert_eq!(stream.retirements, bulk.retirements);
        assert_eq!(stream.idle_gb_seconds, 0.0);
    }

    #[test]
    fn exclusive_runs_never_specialize() {
        let (cfg, _) = tenant_cfg(DriverKind::DockerWarm, 2);
        let r = run_platform(&cfg, &mut FixedKeepAlive::default(), Host::default());
        assert_eq!(r.specializations, 0);
        assert!(r.spec_hist.is_empty());
        assert_eq!(r.warm_hits + r.cold_starts, r.requests);
    }

    #[test]
    fn universal_sharing_specializes_and_conserves() {
        use crate::platform::SharingMode;
        for mode in [SharingMode::PerRuntime { runtimes: 2 }, SharingMode::Promiscuous] {
            let (mut cfg, trace) = tenant_cfg(DriverKind::DockerWarm, 2);
            cfg.sharing = mode;
            cfg.universal_prewarm = 4;
            let r = run_platform(&cfg, &mut FixedKeepAlive::default(), Host::default());
            assert_eq!(r.requests, trace.len() as u64, "{mode:?}");
            assert_eq!(
                r.warm_hits + r.specializations + r.cold_starts,
                r.requests,
                "{mode:?}: every dispatch is warm, specialized, or cold"
            );
            assert!(r.specializations > 0, "{mode:?}: cross-function claims must happen");
        }
    }

    #[test]
    fn sharing_runs_are_deterministic_per_seed() {
        let run = || {
            let (mut cfg, _) = tenant_cfg(DriverKind::DockerWarm, 4);
            cfg.sharing = crate::platform::SharingMode::PerRuntime { runtimes: 3 };
            cfg.universal_prewarm = 2;
            let r = run_platform(&cfg, &mut FixedKeepAlive::default(), Host::default());
            (r.hist.quantile_ms(0.99), r.specializations, r.cold_starts, r.idle_gb_seconds)
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn specialization_costs_more_than_warm_less_than_cold() {
        // Two functions alternating on one promiscuous bucket: after the
        // single cold boot, every claim lands on the *other* function's
        // slot and pays the specialization pipeline — a latency class
        // strictly between the warm hit and the full cold start.
        let arrivals: Vec<(u64, u32)> =
            (1..200u64).map(|i| (i * S / 2, (i % 2) as u32)).collect();
        let trace = TenantTrace { functions: 2, arrivals };
        let mut cfg = PlatformConfig {
            load: PlatformLoad::Tenants(trace),
            functions: 2,
            ..PlatformConfig::single_node(
                crate::platform::DriverProfile::from_kind(DriverKind::DockerWarm),
                8,
            )
        };
        cfg.sharing = crate::platform::SharingMode::Promiscuous;
        cfg.exact_latencies = true;
        let r = run_platform(&cfg, &mut FixedKeepAlive::default(), Host::default());
        assert!(
            r.specializations > 100,
            "alternating claims must specialize: {}",
            r.specializations
        );
        let spec = r.spec_quantile_ms(0.5);
        let cold = r.cold_quantile_ms(0.5);
        assert!(spec > 4.0, "specialization must cost more than a warm hit: {spec}");
        assert!(spec < cold, "specialization must stay below a cold start: {spec} vs {cold}");
    }

    #[test]
    fn universal_prewarm_seeds_claimable_runtime_workers() {
        use crate::platform::SharingMode;
        let (mut cfg, _) = tenant_cfg(DriverKind::DockerWarm, 2);
        cfg.sharing = SharingMode::Promiscuous;
        cfg.universal_prewarm = 16;
        let seeded = run_platform(&cfg, &mut FixedKeepAlive::default(), Host::default());
        let (mut bare, _) = tenant_cfg(DriverKind::DockerWarm, 2);
        bare.sharing = SharingMode::Promiscuous;
        let unseeded = run_platform(&bare, &mut FixedKeepAlive::default(), Host::default());
        // Seeded universal workers absorb the ramp cold starts.
        assert!(
            seeded.cold_starts < unseeded.cold_starts,
            "seeded {} vs unseeded {}",
            seeded.cold_starts,
            unseeded.cold_starts
        );
    }

    #[test]
    fn histograms_match_exact_quantiles_within_bucket_error() {
        let (mut cfg, _) = tenant_cfg(DriverKind::IncludeOsCold, 2);
        cfg.exact_latencies = true;
        let r = run_platform(&cfg, &mut ColdOnlyPolicy, Host::default());
        for q in [0.5, 0.99] {
            let exact = r.quantile_ms(q); // exact path (raw samples kept)
            let approx = r.hist.quantile_ms(q);
            assert!(
                (approx / exact - 1.0).abs() < 0.06,
                "q{q}: hist {approx} vs exact {exact}"
            );
        }
    }

    /// Every scalar a report pins, flattened for exact comparison (S27:
    /// floats compared as bit patterns — byte-identical, not "close").
    fn report_blob(r: &PlatformResult) -> Vec<u64> {
        let mut v = vec![
            r.requests,
            r.elapsed_ns,
            r.events,
            r.warm_hits,
            r.specializations,
            r.cold_starts,
            r.prewarm_boots,
            r.expirations,
            r.retirements,
            r.monitor_events,
            r.injected,
            r.served,
            r.killed,
            r.retries,
            r.rejected,
            r.warm_slots_lost,
            r.crashes,
            r.restarts,
            r.window_cold,
            r.window_total,
            r.steady_cold,
            r.steady_total,
            r.transfers,
            r.transferred_bytes,
            r.footprint_bytes,
            r.nodes_with_first_image as u64,
            r.shard_msgs,
            r.shard_barriers,
            r.trace_dropped,
            r.idle_gb_seconds.to_bits(),
            r.conn_setup_ms.to_bits(),
            r.profile.dispatch_decisions,
            r.profile.pool_effects,
            r.profile.fault_effects,
            r.profile.completions,
            r.profile.engine_events,
        ];
        v.extend(&r.latencies_ns);
        v.extend(&r.cold_latencies_ns);
        v.extend(&r.warm_latencies_ns);
        v.extend(&r.spec_latencies_ns);
        v
    }

    fn assert_same_report(a: &PlatformResult, b: &PlatformResult) {
        assert_eq!(report_blob(a), report_blob(b));
        assert!(a.hist == b.hist, "all-request histogram diverged");
        assert!(a.cold_hist == b.cold_hist, "cold histogram diverged");
        assert!(a.warm_hist == b.warm_hist, "warm histogram diverged");
        assert!(a.spec_hist == b.spec_hist, "spec histogram diverged");
        assert!(a.node_hists == b.node_hists, "node histograms diverged");
        assert_eq!(a.state_hash, b.state_hash, "state-hash chain diverged");
        assert_eq!(a.state_hash_folds, b.state_hash_folds, "fold count diverged");
    }

    #[test]
    fn state_hash_chain_is_invariant_across_shard_counts() {
        // The chain folds only canonical (layout-free) sections, so every
        // shard count must walk the identical hash trajectory.
        let run = |shards: usize| {
            let (mut cfg, _) = tenant_cfg(DriverKind::DockerWarm, 8);
            cfg.shards = shards;
            cfg.state_hash = true;
            let r = run_platform(&cfg, &mut FixedKeepAlive::default(), Host::default());
            (r.state_hash.expect("armed run must produce a chain"), r.state_hash_folds)
        };
        let one = run(1);
        assert_eq!(one, run(2), "shards=2 diverged from the single-shard chain");
        assert_eq!(one, run(8), "shards=8 diverged from the single-shard chain");
        assert!(one.1 >= 2, "a 60s trace must cross several 10s barriers: {} folds", one.1);
    }

    #[test]
    fn state_hash_folding_is_observationally_pure() {
        // Arming the hash splits the run into barrier legs, but the legs
        // pop the identical event stream: no extra events, no RNG draws,
        // byte-identical outputs.  Unarmed runs report no chain at all.
        let base = || {
            let (mut cfg, _) = tenant_cfg(DriverKind::DockerWarm, 4);
            cfg.exact_latencies = true;
            cfg
        };
        let off = run_platform(&base(), &mut FixedKeepAlive::default(), Host::default());
        assert_eq!(off.state_hash, None);
        assert_eq!(off.state_hash_folds, 0);
        let mut armed = base();
        armed.state_hash = true;
        let on = run_platform(&armed, &mut FixedKeepAlive::default(), Host::default());
        assert!(on.state_hash.is_some());
        assert_eq!(report_blob(&off), report_blob(&on));
        assert!(off.hist == on.hist, "arming the state hash changed the latency histogram");
    }

    #[test]
    fn resume_from_any_barrier_is_byte_identical() {
        // The resume contract, end to end: run-to-completion vs
        // checkpoint-then-resume must agree on the full report *and* the
        // hash chain.  Varying the barrier cadence moves the on-disk
        // barrier — deterministically emulating kills at different points
        // — and the stateful EWMA policy exercises policy-state restore.
        let dir = std::env::temp_dir().join(format!("coldfaas-resume-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        for shards in [1usize, 8] {
            for every_s in [7u64, 23, 40] {
                let base = || {
                    let (mut cfg, _) = tenant_cfg(DriverKind::DockerWarm, 8);
                    cfg.shards = shards;
                    cfg.exact_latencies = true;
                    cfg.checkpoint_every_ns = every_s * S;
                    cfg
                };
                let reference = {
                    let cfg = base();
                    run_platform(&cfg, &mut EwmaPredictive::new(50), Host::default())
                };
                let path = dir
                    .join(format!("cell-{shards}-{every_s}.ckpt"))
                    .to_string_lossy()
                    .into_owned();
                let mut writer = base();
                writer.checkpoint_path = Some(path.clone());
                let written = run_platform(&writer, &mut EwmaPredictive::new(50), Host::default());
                // Writing checkpoints is as invisible as hashing alone.
                assert_same_report(&reference, &written);
                // The completed run leaves its last *mid-run* barrier on
                // disk; resuming replays the tail from there into a fresh
                // engine + domain + policy.
                let mut resumer = base();
                resumer.resume_from = Some(path);
                let resumed = run_platform(&resumer, &mut EwmaPredictive::new(50), Host::default());
                assert_same_report(&reference, &resumed);
            }
        }
    }
}
