//! Unified platform layer (S20): **one** DES wiring for every platform
//! experiment.
//!
//! Before this module the repo carried three near-duplicate wirings — the
//! Fn-platform scenario runner (`fnplat/sim.rs`, E4/E5/E9), the policy
//! lab (`policy/sim.rs`, E12), and the cluster burst rig
//! (`cluster/sim.rs`, E11) — which could not compose: the policy lab was
//! single-node, the cluster had no warm pool, and none shared load
//! generation.  `PlatformSim` subsumes all three: it owns N nodes (each
//! with a bounded core pool, per-lock-class pools, an image cache, and its
//! own per-slot-deadline [`WarmPool`](crate::fnplat::pool::WarmPool)), a
//! pluggable [`Scheduler`] (co-locate / spread / least-loaded /
//! pool-affinity), and a per-function
//! [`LifecyclePolicy`](crate::policy::LifecyclePolicy) driving every
//! node's pool.
//!
//! The historical experiment entrypoints survive as thin presets over
//! [`PlatformConfig`] (see [`presets`]) — and the layer is what makes
//! cluster-scale sweeps like E13 (`coldfaas fleet`) a configuration
//! instead of a fourth copy of the pipeline.

pub mod checkpoint;
pub mod faults;
pub mod node;
pub mod presets;
#[allow(clippy::disallowed_types)] // keyed warm/image indexes; iteration audited by detlint DL002
pub mod sched;
pub mod shard;
#[allow(clippy::disallowed_types)] // keyed placement/retry maps; iteration audited by detlint DL002
pub mod sim;

pub use checkpoint::{config_fingerprint, Checkpoint, DEFAULT_CHECKPOINT_NS};
pub use faults::{chaos_plan, FabricFault, FaultConfig, FaultPlan, NodeFault};
pub use node::NodeState;
pub use sched::{PlacementOutcome, SchedPolicy, Scheduler};
pub use shard::{HeatClass, ShardMailbox, ShardMsg, ShardPartial, ShardPlan};
pub use sim::{exact_quantile_ms, run_platform, PlatformResult, PlatformSim};

use crate::fnplat::{DbBackend, DriverKind, Placement};
use crate::net::{Frontend, Site};
use crate::obs::ObsConfig;
use crate::sim::Step;
use crate::virt::Tech;
use crate::workload::tenants::TenantTrace;
use crate::workload::traces::Trace;

/// Engine pool ids are `u16` and each node takes 7 pools (cores + one
/// per lock class + disk), so the hard ceiling is ~9 000 nodes; the cap
/// is held lower to keep obviously-misconfigured runs from allocating a
/// pool army by accident.  E15 "planet" runs at 256.
pub const MAX_NODES: usize = 1024;

/// An executor driver: the startup/warm-invoke pipelines the platform
/// retargets onto whichever node a request lands on.
#[derive(Clone, Debug)]
pub struct DriverProfile {
    pub name: &'static str,
    pub tech: Tech,
    /// Cold-start pipeline (technology phases, agent-side plumbing).
    pub cold_steps: Vec<Step>,
    /// Warm-invoke pipeline (empty for drivers with no warm path).
    pub warm_steps: Vec<Step>,
    /// Specialization pipeline (S23): runs after the warm steps when a
    /// claimed slot belongs to a different function — runtime warm,
    /// function state cold.  Only consulted under a shared
    /// [`SharingMode`]; E16 sweeps it as an explicit cost.
    pub specialize_steps: Vec<Step>,
    /// Connection-termination style of this driver's frontend (Table I's
    /// setup column); only consulted on network request paths.
    pub frontend: Frontend,
}

impl DriverProfile {
    /// The two Fn drivers the paper compares (§IV-A).
    pub fn from_kind(kind: DriverKind) -> DriverProfile {
        DriverProfile {
            name: match kind {
                DriverKind::DockerWarm => "fn-docker",
                DriverKind::IncludeOsCold => "fn-includeos",
            },
            tech: kind.tech(),
            cold_steps: kind.cold_start_steps(),
            warm_steps: kind.warm_invoke_steps(),
            specialize_steps: kind.specialize_steps(),
            frontend: match kind {
                DriverKind::DockerWarm => Frontend::FN_DOCKER,
                DriverKind::IncludeOsCold => Frontend::FN_INCLUDEOS,
            },
        }
    }

    /// A bare technology pipeline with no platform plumbing and no warm
    /// path (the cluster burst rig's executors).
    pub fn raw(tech: Tech) -> DriverProfile {
        DriverProfile {
            name: tech.name(),
            tech,
            cold_steps: tech.pipeline(),
            warm_steps: Vec::new(),
            specialize_steps: Vec::new(),
            frontend: Frontend::FN_DOCKER,
        }
    }
}

/// How warm slots are keyed for claiming (S23) — the platform dimension
/// behind "universal workers": runtime-keyed executors any compatible
/// function may claim, amortizing keep-alive waste across tenants.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SharingMode {
    /// One owner function per slot — the classic FaaS pool and the
    /// default.  Byte-identical to the pre-sharing platform.
    Exclusive,
    /// Slots pooled per language-runtime family; function `f` belongs to
    /// runtime `f % runtimes` (the same mapping
    /// [`crate::policy::UniversalPool`] sizes its targets by).
    PerRuntime { runtimes: u32 },
    /// One global bucket: any function can claim any warm slot.
    Promiscuous,
}

impl SharingMode {
    pub fn name(&self) -> String {
        match self {
            SharingMode::Exclusive => "exclusive".to_string(),
            SharingMode::PerRuntime { runtimes } => format!("runtime-{runtimes}"),
            SharingMode::Promiscuous => "promiscuous".to_string(),
        }
    }

    /// The sharing key function `func` routes, claims, and releases
    /// under (`func_name` is the function's own name, the exclusive key).
    pub fn key_for(&self, func: u32, func_name: &str) -> String {
        match self {
            SharingMode::Exclusive => func_name.to_string(),
            SharingMode::PerRuntime { runtimes } => format!("rt{}", func % (*runtimes).max(1)),
            SharingMode::Promiscuous => "shared".to_string(),
        }
    }

    /// The distinct shared bucket keys this mode pools under (empty for
    /// the exclusive mode — there is nothing to pre-seed universally).
    pub fn shared_keys(&self, functions: u32) -> Vec<String> {
        match self {
            SharingMode::Exclusive => Vec::new(),
            SharingMode::PerRuntime { runtimes } => {
                (0..(*runtimes).max(1).min(functions.max(1))).map(|r| format!("rt{r}")).collect()
            }
            SharingMode::Promiscuous => vec!["shared".to_string()],
        }
    }
}

/// How function images are pre-seeded onto node caches before the run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ImageSeeding {
    /// Every function's image on the first `n` nodes (the burst rig's
    /// "seeded nodes"; `FirstN(1)` is the single-node presets' default).
    FirstN(usize),
    /// Function `f` seeded on node `f % nodes` — each deployed function
    /// lives *somewhere*, as a registry push would leave a fleet.
    RoundRobin,
}

/// Request path in front of the dispatch decision.
#[derive(Clone, Copy, Debug)]
pub enum RequestPath {
    /// Placement only — no network, no gateway (the burst rig).
    Direct,
    /// Full gateway/agent path: optional TCP/TLS setup, client/server
    /// RTT, deployment taxes, HTTP parse + route + metadata-DB lookup.
    Agent {
        client: Site,
        server: Site,
        /// Include connection setup in the measured latency (Table I
        /// reports it as a separate column, so table runs disable it).
        include_conn_setup: bool,
        placement: Placement,
        db: DbBackend,
    },
}

/// Offered load shape.
#[derive(Clone, Debug)]
pub enum PlatformLoad {
    /// `hey`-style closed loop on function 0; `gap_ns` spaces successive
    /// requests per slot (forces cold starts past keep-alive windows).
    ClosedLoop { parallelism: u32, total: u64, prewarm: bool, gap_ns: u64 },
    /// Open-loop arrivals for function 0 from a single-tenant trace (E9).
    OpenTrace(Trace),
    /// Multi-tenant open-loop arrivals, `(at_ns, func)` (E12/E13).  Every
    /// arrival is spawned into the engine up front — simple, but the
    /// event heap and request table scale with the *whole trace*.
    Tenants(TenantTrace),
    /// The same arrivals, fed into the engine in chunks by a zero-cost
    /// control request as virtual time reaches them, so live engine state
    /// scales with in-flight requests instead of trace length (E15 replays
    /// millions of arrivals this way).  Chunk boundaries can reorder
    /// same-nanosecond ties differently than `Tenants`, so pinned presets
    /// keep the up-front variant.
    TenantsStreamed(TenantTrace),
    /// `requests` arrivals spread uniformly over `burst_ms` (E11).
    Burst { requests: u64, burst_ms: f64 },
}

/// Full configuration of one platform run.
#[derive(Clone, Debug)]
pub struct PlatformConfig {
    pub driver: DriverProfile,
    pub nodes: usize,
    pub cores_per_node: u32,
    /// Memory-bounded executor slots per node (co-location spills past
    /// this, Wang et al.).
    pub mem_slots_per_node: u32,
    pub scheduler: SchedPolicy,
    /// Distinct function ids the load may reference.
    pub functions: u32,
    /// Function-body execution cost (ms).
    pub exec_ms: f64,
    /// Resident bytes one retained executor holds while idle.
    pub mem_bytes_per_slot: u64,
    pub seeding: ImageSeeding,
    /// Node-interconnect bandwidth for image pulls (Gbps).
    pub fabric_gbps: f64,
    pub path: RequestPath,
    pub load: PlatformLoad,
    /// How warm slots are keyed for routing and claiming (S23): the
    /// default [`SharingMode::Exclusive`] is the classic per-function
    /// pool; the shared modes implement runtime-keyed universal workers
    /// whose cross-function claims pay the driver's specialization steps.
    pub sharing: SharingMode,
    /// Universal workers pre-seeded per shared bucket at t=0 (round-robin
    /// over nodes, retained until `warmup_keep_ns`, owned by no function).
    /// Ignored under the exclusive mode; 0 seeds nothing.
    pub universal_prewarm: u32,
    /// Teardown deadline for measurement-warmup slots (and the default
    /// pool timeout horizon).
    pub warmup_keep_ns: u64,
    /// Debug flag: also keep exact per-request samples (the hot path
    /// records into streaming histograms only).
    pub exact_latencies: bool,
    /// Fault schedule woven into the run (S21).  The default empty plan
    /// injects nothing and leaves the run byte-identical.
    pub faults: FaultPlan,
    /// Observability (S25): lifecycle tracing and interval telemetry.
    /// The default observes nothing and leaves the run byte-identical.
    pub obs: ObsConfig,
    /// Accounting shards (S26): nodes partition contiguously across this
    /// many shards, domain decisions route through the deterministic
    /// inter-shard mailbox, and per-shard partials merge into the report.
    /// Every value (clamped to the node count) produces a byte-identical
    /// result — pinned by the regression suite; 1 is the single-engine
    /// layout.
    pub shards: usize,
    /// Checkpointing (S27): snapshot the complete platform state every
    /// this many virtual nanoseconds (0 = default interval when a
    /// checkpoint path or the state hash arms the barrier loop).
    pub checkpoint_every_ns: u64,
    /// Write each barrier's snapshot to this file (atomic tmp+rename;
    /// each barrier overwrites the last).  `None` disables snapshots.
    pub checkpoint_path: Option<String>,
    /// Resume from this snapshot file instead of starting at t=0.  The
    /// resumed run is byte-identical to an uninterrupted one.
    pub resume_from: Option<String>,
    /// Fold a rolling FNV state hash over the same canonical encoding at
    /// every barrier, even when snapshots are off — a cheap corruption
    /// tripwire pinned by the regression suite.
    pub state_hash: bool,
    pub seed: u64,
}

impl PlatformConfig {
    /// A single-node lab deployment of `driver` — the shape E4/E5/E9/E12
    /// presets start from.
    pub fn single_node(driver: DriverProfile, cores: u32) -> PlatformConfig {
        let mem = driver.tech.warm_memory_bytes();
        PlatformConfig {
            driver,
            nodes: 1,
            cores_per_node: cores,
            mem_slots_per_node: cores.saturating_mul(8),
            scheduler: SchedPolicy::LeastLoaded,
            functions: 1,
            exec_ms: crate::fnplat::DEFAULT_EXEC_MS,
            mem_bytes_per_slot: mem,
            seeding: ImageSeeding::FirstN(1),
            fabric_gbps: 40.0,
            path: RequestPath::Agent {
                client: Site::LabStockholm,
                server: Site::LabStockholm,
                include_conn_setup: false,
                placement: Placement::LocalLab,
                db: DbBackend::Postgres,
            },
            load: PlatformLoad::ClosedLoop { parallelism: 1, total: 1, prewarm: false, gap_ns: 0 },
            sharing: SharingMode::Exclusive,
            universal_prewarm: 0,
            warmup_keep_ns: 30 * 1_000_000_000,
            exact_latencies: false,
            faults: FaultPlan::default(),
            obs: ObsConfig::default(),
            shards: 1,
            checkpoint_every_ns: 0,
            checkpoint_path: None,
            resume_from: None,
            state_hash: false,
            seed: 0xC01D,
        }
    }
}
