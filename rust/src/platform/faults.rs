//! Fault injection for the unified platform (S21): a deterministic,
//! seed-driven schedule of node crashes/restarts, image-cache flushes,
//! fabric brown-outs, and post-restart straggler starts.
//!
//! The paper's wedge is that a fleet with *no* warm state has nothing to
//! lose when nodes die: a cold-only unikernel platform degrades only by
//! the capacity it lost, while keep-alive platforms must rebuild pools
//! and prediction histories after every failure.  A [`FaultPlan`] makes
//! that claim measurable: [`super::sim::run_platform`] weaves the plan
//! into the event loop, so crashes kill in-flight requests, drain warm
//! pools, and (optionally) invalidate per-node image caches, with warm
//! routing and every scheduler routing around dead nodes.
//!
//! Plans are pure data.  They come from three places: hand-scripted
//! (the E14 `chaos` experiment uses [`chaos_plan`] so every cell sees
//! the same disruption), generated from MTTF/MTTR draws
//! ([`FaultPlan::generate`], the property-test path), or empty (the
//! default — every pre-existing preset runs byte-identically).

use crate::sim::Rng;

/// One node outage: the node crashes at `down_at_ns` (in-flight requests
/// on it are killed, its warm pool is drained) and restarts at
/// `up_at_ns` (`u64::MAX` = never comes back).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct NodeFault {
    pub node: usize,
    pub down_at_ns: u64,
    pub up_at_ns: u64,
    /// Restart with an empty image cache (node-local storage lost):
    /// every image must be pulled again.
    pub flush_cache: bool,
    /// Cold starts on the restarted node run `straggler_mult` x slower
    /// for `straggler_ns` after restart (cold page/dentry caches).
    pub straggler_mult: f64,
    pub straggler_ns: u64,
}

/// A fabric brown-out: image pulls in `[from_ns, until_ns)` see
/// `fabric_gbps / slowdown`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FabricFault {
    pub from_ns: u64,
    pub until_ns: u64,
    pub slowdown: f64,
}

/// A full fault schedule for one platform run.
///
/// The default plan is empty: no events are injected and every run is
/// byte-identical to the pre-fault-layer platform.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct FaultPlan {
    pub node_faults: Vec<NodeFault>,
    pub fabric_faults: Vec<FabricFault>,
    /// Client retries per killed request before the chain is reported
    /// rejected (0 = killed requests are rejected immediately).
    pub max_retries: u32,
    /// Client back-off before each retry attempt.
    pub retry_backoff_ns: u64,
    /// Disruption-window classification: a dispatch counts as "in the
    /// disruption window" from a node's crash until `spike_window_ns`
    /// past its restart (used for the post-restart cold-fraction spike
    /// metric; 0 disables the classification).
    pub spike_window_ns: u64,
    /// Observe-only plan: no crash/restart/fabric/straggler effects are
    /// applied, but window classification still runs — the baseline leg
    /// of a chaos comparison sees the exact same windows.
    pub dry_run: bool,
}

/// Parameters for [`FaultPlan::generate`]: per-node exponential
/// time-to-failure / time-to-repair draws over a fixed horizon.
#[derive(Clone, Debug)]
pub struct FaultConfig {
    pub nodes: usize,
    pub horizon_ns: u64,
    /// Mean time to failure per node.
    pub mttf_ns: u64,
    /// Mean time to repair per outage.
    pub mttr_ns: u64,
    pub flush_cache: bool,
    pub straggler_mult: f64,
    pub straggler_ns: u64,
    pub max_retries: u32,
    pub retry_backoff_ns: u64,
    pub spike_window_ns: u64,
    pub seed: u64,
}

impl FaultPlan {
    pub fn is_empty(&self) -> bool {
        self.node_faults.is_empty() && self.fabric_faults.is_empty()
    }

    /// The observe-only copy of this plan (same windows, no effects).
    pub fn dry(&self) -> FaultPlan {
        FaultPlan { dry_run: true, ..self.clone() }
    }

    /// Draw a plan from per-node exponential MTTF/MTTR streams.  Each
    /// node forks its own RNG stream, so the plan is independent of node
    /// count ordering and byte-stable per seed.
    pub fn generate(cfg: &FaultConfig) -> FaultPlan {
        assert!(cfg.nodes >= 1 && cfg.mttf_ns > 0 && cfg.mttr_ns > 0);
        let mut root = Rng::new(cfg.seed);
        let mut node_faults = Vec::new();
        for node in 0..cfg.nodes {
            let mut rng = root.fork(node as u64 + 1);
            let mut t = 0u64;
            loop {
                t = t.saturating_add(rng.exponential(cfg.mttf_ns as f64) as u64);
                if t >= cfg.horizon_ns {
                    break;
                }
                let repair = (rng.exponential(cfg.mttr_ns as f64) as u64).max(1_000_000);
                let up = t.saturating_add(repair).min(cfg.horizon_ns);
                node_faults.push(NodeFault {
                    node,
                    down_at_ns: t,
                    up_at_ns: up,
                    flush_cache: cfg.flush_cache,
                    straggler_mult: cfg.straggler_mult,
                    straggler_ns: cfg.straggler_ns,
                });
                t = up;
            }
        }
        FaultPlan {
            node_faults,
            fabric_faults: Vec::new(),
            max_retries: cfg.max_retries,
            retry_backoff_ns: cfg.retry_backoff_ns,
            spike_window_ns: cfg.spike_window_ns,
            dry_run: false,
        }
    }

    /// Panic early on malformed plans (out-of-range nodes, inverted or
    /// overlapping outages) instead of silently corrupting a run.
    pub fn validate(&self, nodes: usize) {
        // The attempt counter rides in bits 24..=30 of the request class.
        assert!(self.max_retries < 127, "retry budget must fit the class attempt bits");
        for f in &self.node_faults {
            assert!(f.node < nodes, "fault targets node {} of {nodes}", f.node);
            assert!(f.down_at_ns < f.up_at_ns, "outage must have positive length");
            assert!(f.straggler_mult >= 1.0, "straggler multiplier must be >= 1");
        }
        for f in &self.fabric_faults {
            assert!(f.from_ns < f.until_ns, "fabric window must have positive length");
            assert!(f.slowdown >= 1.0, "fabric slowdown must be >= 1");
        }
        for a in 0..nodes {
            let mut spans: Vec<(u64, u64)> = self
                .node_faults
                .iter()
                .filter(|f| f.node == a)
                .map(|f| (f.down_at_ns, f.up_at_ns))
                .collect();
            spans.sort_unstable();
            for w in spans.windows(2) {
                assert!(w[0].1 <= w[1].0, "node {a} outages overlap");
            }
        }
    }

    /// Fabric slowdown factor in effect at `now` (1.0 = nominal).
    pub fn fabric_slowdown_at(&self, now: u64) -> f64 {
        if self.dry_run {
            return 1.0;
        }
        self.fabric_faults
            .iter()
            .filter(|f| now >= f.from_ns && now < f.until_ns)
            .fold(1.0, |acc, f| acc.max(f.slowdown))
    }

    /// Is `now` inside any disruption window (crash .. restart +
    /// spike window)?  Classification only — also answered by dry-run
    /// plans, so a baseline leg bins its dispatches identically.
    pub fn in_disruption_window(&self, now: u64) -> bool {
        self.node_faults
            .iter()
            .any(|f| now >= f.down_at_ns && now < f.up_at_ns.saturating_add(self.spike_window_ns))
    }

    /// The plan entry whose restart fires on `node` at exactly `now`.
    pub fn restart_fault(&self, node: usize, now: u64) -> Option<NodeFault> {
        self.node_faults
            .iter()
            .copied()
            .find(|f| f.node == node && f.up_at_ns == now)
    }

    /// Every disruption window as a half-open `[start, end)` interval:
    /// each outage from crash through restart plus the spike window, and
    /// each fabric brown-out.  The `--trace-window` capture filter.
    pub fn disruption_windows(&self) -> Vec<(u64, u64)> {
        self.node_faults
            .iter()
            .map(|f| (f.down_at_ns, f.up_at_ns.saturating_add(self.spike_window_ns)))
            .chain(self.fabric_faults.iter().map(|f| (f.from_ns, f.until_ns)))
            .collect()
    }
}

const S: u64 = 1_000_000_000;

/// The scripted E14 disruption: two staggered single-node outages (cache
/// flushed, 2x straggler starts on the way back) plus one fabric
/// brown-out, all at fixed fractions of the horizon so every
/// driver x policy x scheduler cell faces the same failures.  Node 0
/// never crashes, so the cluster always has capacity and killed requests
/// can always be retried somewhere.
pub fn chaos_plan(nodes: usize, horizon_ns: u64) -> FaultPlan {
    assert!(nodes >= 2, "chaos plan needs a surviving node");
    let h = horizon_ns as f64;
    let outage = (((0.08 * h) as u64).max(5 * S)).min((0.15 * h) as u64);
    let straggle = ((0.15 * h) as u64).min(20 * S);
    let fault = |node: usize, at: f64| NodeFault {
        node,
        down_at_ns: (at * h) as u64,
        up_at_ns: (at * h) as u64 + outage,
        flush_cache: true,
        straggler_mult: 2.0,
        straggler_ns: straggle,
    };
    FaultPlan {
        node_faults: vec![fault(1, 0.35), fault(nodes - 1, 0.55)],
        fabric_faults: vec![FabricFault {
            from_ns: (0.70 * h) as u64,
            until_ns: (0.80 * h) as u64,
            slowdown: 8.0,
        }],
        max_retries: 3,
        retry_backoff_ns: 200 * 1_000_000,
        spike_window_ns: straggle,
        dry_run: false,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_plan_is_default() {
        let p = FaultPlan::default();
        assert!(p.is_empty());
        assert_eq!(p.fabric_slowdown_at(5 * S), 1.0);
        assert!(!p.in_disruption_window(5 * S));
        p.validate(4);
    }

    #[test]
    fn generate_is_deterministic_and_in_horizon() {
        let cfg = FaultConfig {
            nodes: 6,
            horizon_ns: 300 * S,
            mttf_ns: 120 * S,
            mttr_ns: 10 * S,
            flush_cache: true,
            straggler_mult: 2.0,
            straggler_ns: 10 * S,
            max_retries: 3,
            retry_backoff_ns: 100_000_000,
            spike_window_ns: 10 * S,
            seed: 0xFA17,
        };
        let a = FaultPlan::generate(&cfg);
        let b = FaultPlan::generate(&cfg);
        assert_eq!(a, b);
        a.validate(6);
        assert!(!a.is_empty(), "120 s MTTF over 6 nodes x 300 s should crash someone");
        for f in &a.node_faults {
            assert!(f.down_at_ns < 300 * S && f.up_at_ns <= 300 * S);
        }
        let c = FaultPlan::generate(&FaultConfig { seed: 0xFA18, ..cfg });
        assert_ne!(a, c, "different seed must draw a different schedule");
    }

    #[test]
    fn chaos_plan_is_valid_and_spares_node_zero() {
        for nodes in [2, 8, 16] {
            let p = chaos_plan(nodes, 120 * S);
            p.validate(nodes);
            assert_eq!(p.node_faults.len(), 2);
            assert!(p.node_faults.iter().all(|f| f.node != 0));
            assert!(p.max_retries > 0);
        }
    }

    #[test]
    fn windows_and_fabric_slowdown() {
        let p = chaos_plan(8, 100 * S);
        // First outage: down at 35 s for 8 s, spike window 15 s.
        assert!(!p.in_disruption_window(34 * S));
        assert!(p.in_disruption_window(36 * S));
        assert!(p.in_disruption_window(50 * S)); // post-restart spike
        assert!(!p.in_disruption_window(99 * S));
        assert_eq!(p.fabric_slowdown_at(75 * S), 8.0);
        assert_eq!(p.fabric_slowdown_at(50 * S), 1.0);
    }

    #[test]
    fn dry_run_keeps_windows_but_drops_effects() {
        let p = chaos_plan(8, 100 * S).dry();
        assert!(p.dry_run);
        assert!(p.in_disruption_window(36 * S), "classification must survive dry()");
        assert_eq!(p.fabric_slowdown_at(75 * S), 1.0, "effects must not");
    }

    #[test]
    fn restart_fault_matches_by_node_and_time() {
        let p = chaos_plan(8, 100 * S);
        let f = p.node_faults[0];
        assert_eq!(p.restart_fault(f.node, f.up_at_ns), Some(f));
        assert_eq!(p.restart_fault(0, f.up_at_ns), None);
        assert_eq!(p.restart_fault(f.node, f.up_at_ns + 1), None);
    }

    #[test]
    fn disruption_windows_cover_outages_and_brownouts() {
        let p = chaos_plan(8, 100 * S);
        let w = p.disruption_windows();
        assert_eq!(w.len(), 3, "two outages + one brown-out");
        // Every instant the window classifier flags lies inside some window.
        for t in (0..100).map(|s| s * S) {
            if p.in_disruption_window(t) {
                assert!(w.iter().any(|&(a, b)| t >= a && t < b));
            }
        }
        assert!(w.contains(&(70 * S, 80 * S)), "fabric brown-out window");
    }

    #[test]
    #[should_panic(expected = "overlap")]
    fn overlapping_outages_rejected() {
        let mut p = chaos_plan(4, 100 * S);
        p.node_faults.push(NodeFault { down_at_ns: 0, up_at_ns: 90 * S, ..p.node_faults[0] });
        p.validate(4);
    }
}
