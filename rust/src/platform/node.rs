//! Per-node state of the unified platform: the scheduler-visible load
//! counters plus the node-local resources every DES wiring used to carry
//! separately — a bounded core pool, one serializing pool per kernel-lock
//! class, the node's image cache, and its own per-slot-deadline
//! [`WarmPool`].

use crate::fnplat::pool::WarmPool;
use crate::image::NodeCache;
use crate::metrics::Histogram;
use crate::sim::snap::{Dec, Enc};
use crate::sim::N_LOCKS;

/// One cluster node.  The `cpu_pool` / `lock_pools` ids are engine pool
/// handles assigned by [`super::sim::run_platform`] at engine setup; the
/// placeholder value 0 is only valid in pure-logic unit tests that never
/// touch the engine.
pub struct NodeState {
    pub id: usize,   // detlint: allow(DL005) construction-time identity
    pub cores: u32,  // detlint: allow(DL005) config-derived constant
    /// Executor slots bounded by *memory*, not cores — Wang et al.: AWS
    /// co-locates a function's instances "roughly while they fit into the
    /// physical memory", far past the core count.  That gap (mem_slots >>
    /// cores) is exactly what makes co-located bursts queue on the CPU.
    pub mem_slots: u32, // detlint: allow(DL005) config-derived constant
    /// In-flight executors (warm-routed + cold-placed, decremented on
    /// release) — the scheduler's load signal.
    pub inflight: u32,
    /// False while the node is crashed (fault injection): warm routing
    /// and every cold-placement policy skip it until the restart fires.
    pub up: bool,
    /// Cold starts placed here before this instant run `straggle_mult` x
    /// slower (post-restart cold page/dentry caches); 0 = no straggling.
    pub straggle_until_ns: u64,
    pub straggle_mult: f64,
    pub cache: NodeCache,
    /// The node's warm-executor pool; lifecycle policies set per-slot
    /// teardown deadlines on it.
    pub pool: WarmPool,
    /// Engine pool id for this node's cores.
    pub cpu_pool: u16, // detlint: allow(DL005) engine-assigned at setup, not state
    /// Engine pool ids (one single-slot pool per [`crate::sim::LockClass`])
    /// so per-node kernel-lock contention serializes exactly like the
    /// engine-global lock queues did on a single host.  The `Db` slot
    /// aliases another pool: no startup pipeline holds the metadata-DB
    /// lock (it lives on the non-retargeted agent path), and skipping it
    /// keeps the per-node pool count at 7 — 256-node fleets fit easily in
    /// the engine's `u16` pool-id space.
    pub lock_pools: [u16; N_LOCKS], // detlint: allow(DL005) engine-assigned at setup
    /// Engine pool id for this node's local disk (single-slot FIFO —
    /// same serialization the engine's global disk gives one host).
    pub disk_pool: u16, // detlint: allow(DL005) engine-assigned at setup
    /// Streaming latency histogram of requests served by this node
    /// (merged across nodes at the end of a run).
    pub hist: Histogram,
}

impl NodeState {
    pub fn new(
        id: usize,
        cores: u32,
        mem_slots: u32,
        idle_timeout_ns: u64,
        mem_bytes_per_slot: u64,
    ) -> NodeState {
        NodeState {
            id,
            cores,
            mem_slots,
            inflight: 0,
            up: true,
            straggle_until_ns: 0,
            straggle_mult: 1.0,
            cache: NodeCache::new(None),
            pool: WarmPool::new(idle_timeout_ns, mem_bytes_per_slot),
            cpu_pool: 0,
            lock_pools: [0u16; N_LOCKS],
            disk_pool: 0,
            hist: Histogram::new(),
        }
    }

    /// Serialize the node's mutable state for a checkpoint (S27).  Config
    /// shape (id, cores, mem_slots) and the engine pool ids are rebuilt
    /// deterministically at engine setup and deliberately omitted.
    pub fn encode(&self, w: &mut Enc) {
        w.u32(self.inflight);
        w.bool(self.up);
        w.u64(self.straggle_until_ns);
        w.f64(self.straggle_mult);
        self.cache.encode(w);
        self.pool.encode(w);
        self.hist.encode(w);
    }

    /// Inverse of [`Self::encode`] onto a freshly constructed node.
    pub fn restore(&mut self, r: &mut Dec) {
        self.inflight = r.u32();
        self.up = r.bool();
        self.straggle_until_ns = r.u64();
        self.straggle_mult = r.f64();
        self.cache.restore(r);
        self.pool.restore(r);
        self.hist = Histogram::decode(r);
    }
}
