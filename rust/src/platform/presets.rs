//! The historical experiment entrypoints, rebuilt as thin presets over
//! [`PlatformConfig`] — what used to be three separate DES wirings
//! (`fnplat/sim.rs`, `policy/sim.rs`, `cluster/sim.rs`) is now three
//! configurations of [`run_platform`]:
//!
//! * [`Scenario`]/[`run_scenario`] — the Fn measurement scenarios
//!   (E4 Fig 4, E5 Table I, E9 waste): one node, one function, the
//!   classic pool timeout expressed as a `FixedKeepAlive` policy (and the
//!   cold-only unikernel driver as `ColdOnlyPolicy`);
//! * [`PolicyScenario`]/[`run_policy_scenario`] — the keep-alive policy
//!   lab (E12): one node, a multi-tenant trace, any lifecycle policy;
//! * [`ClusterConfig`]/[`run_burst`] — the burst scale-out rig (E11):
//!   N nodes, placement-only path, cold-only lifecycle.

use crate::fnplat::{DbBackend, DriverKind, Placement};
use crate::net::Site;
use crate::policy::{ColdOnlyPolicy, FixedKeepAlive, LifecyclePolicy};
use crate::sim::Host;
use crate::virt::Tech;
use crate::workload::tenants::TenantTrace;
use crate::workload::traces::Trace;

use super::sched::SchedPolicy;
use super::sim::{run_platform, PlatformResult};
use super::{DriverProfile, ImageSeeding, PlatformConfig, PlatformLoad, RequestPath};

// ---------------------------------------------------------------------
// E4/E5/E9: the Fn measurement scenarios
// ---------------------------------------------------------------------

/// Offered load shape of a measurement scenario.
#[derive(Clone, Debug)]
pub enum Load {
    /// `hey`-style closed loop; `gap_ns` spaces successive requests per
    /// slot (used to force cold starts past the idle timeout).
    ClosedLoop { parallelism: u32, total: u64, prewarm: bool, gap_ns: u64 },
    /// Open-loop arrivals from a trace (E9).
    OpenLoop(Trace),
}

/// A full platform measurement scenario.
#[derive(Clone, Debug)]
pub struct Scenario {
    pub driver: DriverKind,
    pub db: DbBackend,
    pub placement: Placement,
    pub client: Site,
    pub server: Site,
    /// Include TCP/TLS connection setup in the measured latency
    /// (Table I reports it as a separate column, so table runs disable it).
    pub include_conn_setup: bool,
    pub exec_ms: f64,
    pub idle_timeout_s: f64,
    pub load: Load,
    pub seed: u64,
}

impl Scenario {
    /// The paper's local-lab Fig 4 setup.
    pub fn local(driver: DriverKind, parallelism: u32, total: u64, prewarm: bool) -> Scenario {
        Scenario {
            driver,
            db: DbBackend::Postgres,
            placement: Placement::LocalLab,
            client: Site::LabStockholm,
            server: Site::LabStockholm,
            include_conn_setup: false,
            exec_ms: crate::fnplat::DEFAULT_EXEC_MS,
            idle_timeout_s: 30.0,
            load: Load::ClosedLoop { parallelism, total, prewarm, gap_ns: 0 },
            seed: 0xF16_4,
        }
    }

    /// The Table I cloud deployment (lab → AWS Stockholm, m5.metal).
    pub fn cloud(driver: DriverKind, total: u64, prewarm: bool, gap_ns: u64) -> Scenario {
        Scenario {
            driver,
            db: DbBackend::Postgres,
            placement: Placement::AwsMetal,
            client: Site::LabStockholm,
            server: Site::AwsStockholm,
            include_conn_setup: false,
            exec_ms: crate::fnplat::DEFAULT_EXEC_MS,
            idle_timeout_s: 30.0,
            load: Load::ClosedLoop { parallelism: 1, total, prewarm, gap_ns },
            seed: 0x7AB1E_1,
        }
    }

    fn platform_config(&self, host: Host) -> PlatformConfig {
        PlatformConfig {
            functions: 1,
            exec_ms: self.exec_ms,
            path: RequestPath::Agent {
                client: self.client,
                server: self.server,
                include_conn_setup: self.include_conn_setup,
                placement: self.placement,
                db: self.db,
            },
            load: match &self.load {
                Load::ClosedLoop { parallelism, total, prewarm, gap_ns } => {
                    PlatformLoad::ClosedLoop {
                        parallelism: *parallelism,
                        total: *total,
                        prewarm: *prewarm,
                        gap_ns: *gap_ns,
                    }
                }
                Load::OpenLoop(trace) => PlatformLoad::OpenTrace(trace.clone()),
            },
            warmup_keep_ns: (self.idle_timeout_s * 1e9) as u64,
            exact_latencies: true,
            seed: self.seed,
            ..PlatformConfig::single_node(DriverProfile::from_kind(self.driver), host.cores)
        }
    }
}

/// Aggregated outcome of one scenario run.
pub struct ScenarioResult {
    pub latencies_ns: Vec<u64>,
    pub cold_latencies_ns: Vec<u64>,
    pub warm_latencies_ns: Vec<u64>,
    pub elapsed_ns: u64,
    pub warm_hits: u64,
    pub cold_starts: u64,
    pub idle_gb_seconds: f64,
    pub monitor_events: u64,
    /// Median connection-setup cost for this scenario's frontend (reported
    /// separately, as in Table I).
    pub conn_setup_ms: f64,
}

pub fn run_scenario(sc: &Scenario, host: Host) -> ScenarioResult {
    let cfg = sc.platform_config(host);
    // The classic pool behaviour is a lifecycle policy: the Docker driver
    // retains every idle executor for the pool-wide timeout; the IncludeOS
    // driver exits on completion — no lifecycle management at all (§IV-A).
    let r = match sc.driver {
        DriverKind::IncludeOsCold => run_platform(&cfg, &mut ColdOnlyPolicy, host),
        DriverKind::DockerWarm => {
            let mut keep = FixedKeepAlive::new((sc.idle_timeout_s * 1e9) as u64);
            run_platform(&cfg, &mut keep, host)
        }
    };
    ScenarioResult {
        latencies_ns: r.latencies_ns,
        cold_latencies_ns: r.cold_latencies_ns,
        warm_latencies_ns: r.warm_latencies_ns,
        elapsed_ns: r.elapsed_ns,
        warm_hits: r.warm_hits,
        cold_starts: r.cold_starts,
        idle_gb_seconds: r.idle_gb_seconds,
        monitor_events: r.monitor_events,
        conn_setup_ms: r.conn_setup_ms,
    }
}

fn median_ms(v: &[u64]) -> f64 {
    if v.is_empty() {
        return f64::NAN;
    }
    let mut s = v.to_vec();
    s.sort_unstable();
    s[s.len() / 2] as f64 / 1e6
}

impl ScenarioResult {
    pub fn median_ms(&self) -> f64 {
        median_ms(&self.latencies_ns)
    }
    pub fn cold_median_ms(&self) -> f64 {
        median_ms(&self.cold_latencies_ns)
    }
    pub fn warm_median_ms(&self) -> f64 {
        median_ms(&self.warm_latencies_ns)
    }
}

// ---------------------------------------------------------------------
// E12: the keep-alive policy lab
// ---------------------------------------------------------------------

/// One cell of the policy lab: a driver serving a tenant trace under one
/// lifecycle policy.
#[derive(Clone, Debug)]
pub struct PolicyScenario {
    pub driver: DriverKind,
    pub trace: TenantTrace,
    /// Function-body execution cost (ms).
    pub exec_ms: f64,
    /// Resident bytes one retained executor holds while idle.  For the
    /// Docker driver this is the container's warm footprint; for the
    /// unikernel driver it models *hypothetically* pausing the unikernel
    /// instead of letting it exit (the lab's what-if; the real system
    /// exits, which is exactly the cold-only policy row).
    pub mem_bytes_per_slot: u64,
    pub seed: u64,
}

/// A retained (paused) IncludeOS unikernel would hold its guest memory:
/// ~2.5 MB image + boot heap.  The shipped system never retains one —
/// this powers the lab's what-if rows only.
pub const INCLUDEOS_PAUSED_BYTES: u64 = 6 << 20;

impl PolicyScenario {
    pub fn new(driver: DriverKind, trace: TenantTrace, seed: u64) -> PolicyScenario {
        let mem = match driver {
            DriverKind::DockerWarm => driver.tech().warm_memory_bytes(),
            DriverKind::IncludeOsCold => INCLUDEOS_PAUSED_BYTES,
        };
        PolicyScenario {
            driver,
            trace,
            exec_ms: crate::fnplat::DEFAULT_EXEC_MS,
            mem_bytes_per_slot: mem,
            seed,
        }
    }

    fn platform_config(&self, host: Host) -> PlatformConfig {
        PlatformConfig {
            functions: self.trace.functions,
            exec_ms: self.exec_ms,
            mem_bytes_per_slot: self.mem_bytes_per_slot,
            load: PlatformLoad::Tenants(self.trace.clone()),
            exact_latencies: true,
            seed: self.seed,
            ..PlatformConfig::single_node(DriverProfile::from_kind(self.driver), host.cores)
        }
    }
}

/// Aggregated outcome of one policy-lab cell.
#[derive(Clone, Debug)]
pub struct PolicyResult {
    pub latencies_ns: Vec<u64>,
    pub elapsed_ns: u64,
    pub cold_starts: u64,
    pub warm_hits: u64,
    pub prewarm_boots: u64,
    pub expirations: u64,
    pub retirements: u64,
    pub idle_gb_seconds: f64,
    pub monitor_events: u64,
}

impl PolicyResult {
    pub fn requests(&self) -> u64 {
        self.latencies_ns.len() as u64
    }

    pub fn cold_fraction(&self) -> f64 {
        let total = self.cold_starts + self.warm_hits;
        if total == 0 { 0.0 } else { self.cold_starts as f64 / total as f64 }
    }

    pub fn quantile_ms(&self, q: f64) -> f64 {
        super::sim::exact_quantile_ms(&self.latencies_ns, q)
    }
}

/// Replay `sc.trace` through `policy` on `host`.
pub fn run_policy_scenario(
    sc: &PolicyScenario,
    policy: &mut dyn LifecyclePolicy,
    host: Host,
) -> PolicyResult {
    let cfg = sc.platform_config(host);
    let r = run_platform(&cfg, policy, host);
    PolicyResult {
        latencies_ns: r.latencies_ns,
        elapsed_ns: r.elapsed_ns,
        cold_starts: r.cold_starts,
        warm_hits: r.warm_hits,
        prewarm_boots: r.prewarm_boots,
        expirations: r.expirations,
        retirements: r.retirements,
        idle_gb_seconds: r.idle_gb_seconds,
        monitor_events: r.monitor_events,
    }
}

// ---------------------------------------------------------------------
// E11: the burst scale-out rig
// ---------------------------------------------------------------------

#[derive(Clone, Debug)]
pub struct ClusterConfig {
    pub policy: SchedPolicy,
    pub nodes: usize,
    pub cores_per_node: u32,
    pub tech: Tech,
    /// Nodes pre-seeded with the image before the burst.
    pub seeded_nodes: usize,
    /// Burst: `requests` arrivals spread uniformly over `burst_ms`.
    pub requests: u64,
    pub burst_ms: f64,
    pub exec_ms: f64,
    pub seed: u64,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        ClusterConfig {
            policy: SchedPolicy::CoLocate,
            nodes: 8,
            cores_per_node: 8,
            tech: Tech::IncludeOsHvt,
            seeded_nodes: 1,
            // A sharp burst: 400 starts in 250 ms ≈ 1 600 starts/s, far
            // above one node's capacity but comfortably within the
            // cluster's — the regime where placement policy matters.
            requests: 400,
            burst_ms: 250.0,
            exec_ms: 1.0,
            seed: 0xC105_7E42,
        }
    }
}

pub struct BurstResult {
    pub policy: SchedPolicy,
    pub p50_ms: f64,
    pub p99_ms: f64,
    pub max_ms: f64,
    pub transfers: u64,
    pub transferred_mb: f64,
    pub footprint_mb: f64,
    pub nodes_with_image: usize,
    pub makespan_ms: f64,
}

/// Run the burst scale-out scenario under one placement policy.
pub fn run_burst(cfg: &ClusterConfig) -> BurstResult {
    let pcfg = PlatformConfig {
        driver: DriverProfile::raw(cfg.tech),
        nodes: cfg.nodes,
        cores_per_node: cfg.cores_per_node,
        mem_slots_per_node: cfg.cores_per_node.saturating_mul(8),
        scheduler: cfg.policy,
        functions: 1,
        exec_ms: cfg.exec_ms,
        mem_bytes_per_slot: cfg.tech.warm_memory_bytes(),
        seeding: ImageSeeding::FirstN(cfg.seeded_nodes.max(1)),
        fabric_gbps: 40.0,
        path: RequestPath::Direct,
        load: PlatformLoad::Burst { requests: cfg.requests, burst_ms: cfg.burst_ms },
        sharing: super::SharingMode::Exclusive,
        universal_prewarm: 0,
        warmup_keep_ns: 30 * 1_000_000_000,
        exact_latencies: true,
        faults: super::FaultPlan::default(),
        obs: crate::obs::ObsConfig::default(),
        shards: 1,
        checkpoint_every_ns: 0,
        checkpoint_path: None,
        resume_from: None,
        state_hash: false,
        seed: cfg.seed,
    };
    let r: PlatformResult =
        run_platform(&pcfg, &mut ColdOnlyPolicy, Host { cores: 24, disk_bw_bytes_per_s: 1.2e9 });
    let q = |f: f64| super::sim::exact_quantile_ms(&r.latencies_ns, f);
    BurstResult {
        policy: cfg.policy,
        p50_ms: q(0.5),
        p99_ms: q(0.99),
        max_ms: q(1.0),
        transfers: r.transfers,
        transferred_mb: r.transferred_bytes as f64 / 1e6,
        footprint_mb: r.footprint_bytes as f64 / 1e6,
        nodes_with_image: r.nodes_with_first_image,
        makespan_ms: r.elapsed_ns as f64 / 1e6,
    }
}

// ---------------------------------------------------------------------
// Migrated regression tests: the paper checks each deleted wiring carried
// ---------------------------------------------------------------------

#[cfg(test)]
mod scenario_tests {
    use super::*;

    #[test]
    fn local_includeos_cold_in_fig4_band() {
        // Fig 4: IncludeOS startup+execution ≈ 10–20 ms in the local lab.
        let sc = Scenario::local(DriverKind::IncludeOsCold, 5, 2000, false);
        let r = run_scenario(&sc, Host::default());
        let med = r.median_ms();
        assert!((10.0..20.0).contains(&med), "local includeos median {med}");
        assert_eq!(r.warm_hits, 0);
    }

    #[test]
    fn local_docker_warm_in_fig4_band() {
        // Fig 4: warm Go function ≈ 3–5 ms.
        let sc = Scenario::local(DriverKind::DockerWarm, 5, 2000, true);
        let r = run_scenario(&sc, Host::default());
        let med = r.warm_median_ms();
        assert!((3.0..5.5).contains(&med), "local warm docker median {med}");
    }

    #[test]
    fn cloud_cold_medians_near_table1() {
        // Table I: Fn IncludeOS 33.4 ms, Fn Docker 288.3 ms (cold).
        let sc = Scenario::cloud(DriverKind::IncludeOsCold, 800, false, 0);
        let inc = run_scenario(&sc, Host::default()).cold_median_ms();
        assert!((inc / 33.4 - 1.0).abs() < 0.25, "fn-includeos cold {inc}");

        // Space requests past the idle timeout so every start is cold.
        let sc = Scenario::cloud(DriverKind::DockerWarm, 300, false, 31_000_000_000);
        let dock = run_scenario(&sc, Host::default()).cold_median_ms();
        assert!((dock / 288.3 - 1.0).abs() < 0.25, "fn-docker cold {dock}");
    }

    #[test]
    fn cloud_warm_median_near_table1() {
        // Table I: Fn Docker warm 13.6 ms.
        let sc = Scenario::cloud(DriverKind::DockerWarm, 1500, true, 0);
        let r = run_scenario(&sc, Host::default());
        let warm = r.warm_median_ms();
        assert!((warm / 13.6 - 1.0).abs() < 0.25, "fn-docker warm {warm}");
    }

    #[test]
    fn includeos_wastes_nothing() {
        let sc = Scenario::local(DriverKind::IncludeOsCold, 2, 500, false);
        let r = run_scenario(&sc, Host::default());
        assert_eq!(r.idle_gb_seconds, 0.0);
        assert_eq!(r.monitor_events, 0);
    }

    #[test]
    fn docker_warm_pool_wastes_memory() {
        let sc = Scenario::local(DriverKind::DockerWarm, 2, 500, true);
        let r = run_scenario(&sc, Host::default());
        assert!(r.idle_gb_seconds > 0.0);
    }

    #[test]
    fn deterministic_scenarios() {
        let sc = Scenario::local(DriverKind::IncludeOsCold, 3, 300, false);
        let a = run_scenario(&sc, Host::default());
        let b = run_scenario(&sc, Host::default());
        assert_eq!(a.latencies_ns, b.latencies_ns);
    }
}

#[cfg(test)]
mod policy_tests {
    use super::*;
    use crate::policy::{EwmaPredictive, HistogramPrewarm};
    use crate::workload::tenants::TenantConfig;

    fn tiny_trace() -> TenantTrace {
        TenantTrace::generate(&TenantConfig {
            functions: 50,
            duration_s: 60.0,
            total_rps: 40.0,
            seed: 0x7E57,
            ..Default::default()
        })
    }

    #[test]
    fn cold_only_serves_everything_cold_with_zero_waste() {
        let trace = tiny_trace();
        let n = trace.len() as u64;
        let sc = PolicyScenario::new(DriverKind::IncludeOsCold, trace, 1);
        let mut p = ColdOnlyPolicy;
        let r = run_policy_scenario(&sc, &mut p, Host::default());
        assert_eq!(r.requests(), n);
        assert_eq!(r.warm_hits, 0);
        assert_eq!(r.cold_starts, n);
        assert_eq!(r.retirements, n);
        assert_eq!(r.idle_gb_seconds, 0.0);
        assert_eq!(r.monitor_events, 0);
        assert_eq!(r.prewarm_boots, 0);
    }

    #[test]
    fn fixed_keepalive_gets_warm_hits_and_pays_waste() {
        let sc = PolicyScenario::new(DriverKind::DockerWarm, tiny_trace(), 1);
        let mut p = FixedKeepAlive::default();
        let r = run_policy_scenario(&sc, &mut p, Host::default());
        assert!(r.warm_hits > r.cold_starts, "head functions must reuse executors");
        assert!(r.idle_gb_seconds > 0.0);
        assert!(r.monitor_events > 0);
    }

    #[test]
    fn warm_latency_below_cold_latency_docker() {
        let trace = tiny_trace();
        let cold = {
            let sc = PolicyScenario::new(DriverKind::DockerWarm, trace.clone(), 1);
            run_policy_scenario(&sc, &mut ColdOnlyPolicy, Host::default())
        };
        let warm = {
            let sc = PolicyScenario::new(DriverKind::DockerWarm, trace, 1);
            run_policy_scenario(&sc, &mut FixedKeepAlive::default(), Host::default())
        };
        assert!(
            warm.quantile_ms(0.5) < cold.quantile_ms(0.5) / 5.0,
            "warm p50 {} vs cold p50 {}",
            warm.quantile_ms(0.5),
            cold.quantile_ms(0.5)
        );
    }

    #[test]
    fn adaptive_policies_run_and_account_consistently() {
        let trace = tiny_trace();
        let n = trace.len() as u64;
        for policy in [true, false] {
            let sc = PolicyScenario::new(DriverKind::DockerWarm, trace.clone(), 1);
            let r = if policy {
                let mut p = HistogramPrewarm::new(sc.trace.functions);
                run_policy_scenario(&sc, &mut p, Host::default())
            } else {
                let mut p = EwmaPredictive::new(sc.trace.functions);
                run_policy_scenario(&sc, &mut p, Host::default())
            };
            assert_eq!(r.requests(), n);
            assert_eq!(r.cold_starts + r.warm_hits, n);
            assert!(r.idle_gb_seconds >= 0.0);
        }
    }

    #[test]
    fn prewarm_lands_ahead_of_a_metronome() {
        // One function, strict 90 s period: after the histogram fills, the
        // policy must pre-warm ahead of arrivals and serve them warm.
        let arrivals: Vec<(u64, u32)> =
            (1..30u64).map(|i| (i * 90 * 1_000_000_000, 0)).collect();
        let trace = TenantTrace { functions: 1, arrivals };
        let sc = PolicyScenario::new(DriverKind::DockerWarm, trace, 1);
        let mut p = HistogramPrewarm::new(1);
        let r = run_policy_scenario(&sc, &mut p, Host::default());
        assert!(r.prewarm_boots > 5, "prewarm boots {}", r.prewarm_boots);
        assert!(r.warm_hits > 10, "warm hits {}", r.warm_hits);
        // Pre-warming pays memory only around predicted arrivals — far
        // less than fixed keep-alive would (90 s idle per gap).
        let sc2 = PolicyScenario::new(DriverKind::DockerWarm, TenantTrace {
            functions: 1,
            arrivals: (1..30u64).map(|i| (i * 90 * 1_000_000_000, 0)).collect(),
        }, 1);
        let f = run_policy_scenario(&sc2, &mut FixedKeepAlive::default(), Host::default());
        assert!(
            r.idle_gb_seconds < f.idle_gb_seconds * 0.6,
            "prewarm waste {} vs fixed {}",
            r.idle_gb_seconds,
            f.idle_gb_seconds
        );
    }

    #[test]
    fn deterministic_per_seed() {
        let run = || {
            let sc = PolicyScenario::new(DriverKind::DockerWarm, tiny_trace(), 9);
            let mut p = EwmaPredictive::new(sc.trace.functions);
            run_policy_scenario(&sc, &mut p, Host::default())
        };
        let (a, b) = (run(), run());
        assert_eq!(a.latencies_ns, b.latencies_ns);
        assert_eq!(a.idle_gb_seconds, b.idle_gb_seconds);
        assert_eq!(a.prewarm_boots, b.prewarm_boots);
    }
}

#[cfg(test)]
mod burst_tests {
    use super::*;

    fn cfg(policy: SchedPolicy) -> ClusterConfig {
        ClusterConfig { policy, ..Default::default() }
    }

    #[test]
    fn colocation_inflates_burst_tails() {
        // Wang et al. / §IV: co-location hurts sudden scale-out.  With one
        // seeded node and a 400-request burst, packing onto the home node
        // must produce far worse tails than spreading.
        let colocate = run_burst(&cfg(SchedPolicy::CoLocate));
        let spread = run_burst(&cfg(SchedPolicy::LeastLoaded));
        assert!(
            colocate.p99_ms > 2.0 * spread.p99_ms,
            "colocate p99 {} vs spread p99 {}",
            colocate.p99_ms,
            spread.p99_ms
        );
    }

    #[test]
    fn spreading_unikernels_is_cheap() {
        // The paper's enabling economics: spreading a 2.5 MB IncludeOS
        // image to 8 nodes costs ~20 MB and sub-ms pulls...
        let uni = run_burst(&cfg(SchedPolicy::LeastLoaded));
        assert!(uni.footprint_mb < 25.0, "footprint {}", uni.footprint_mb);
        // ...while the same policy with Firecracker-sized images moves
        // 28x the bytes.
        let fc = run_burst(&ClusterConfig {
            policy: SchedPolicy::LeastLoaded,
            tech: Tech::Firecracker,
            ..Default::default()
        });
        assert!(fc.transferred_mb > 20.0 * uni.transferred_mb);
    }

    #[test]
    fn pool_affinity_without_replicas_behaves_like_colocation() {
        let loc = run_burst(&cfg(SchedPolicy::PoolAffinity));
        let spread = run_burst(&cfg(SchedPolicy::LeastLoaded));
        assert!(loc.p99_ms > spread.p99_ms, "{} vs {}", loc.p99_ms, spread.p99_ms);
        assert_eq!(loc.transfers, 0, "pool affinity never leaves the seeded node");
    }

    #[test]
    fn preseeding_all_nodes_fixes_pool_affinity() {
        let fixed = run_burst(&ClusterConfig {
            policy: SchedPolicy::PoolAffinity,
            seeded_nodes: 8,
            ..Default::default()
        });
        let spread = run_burst(&cfg(SchedPolicy::LeastLoaded));
        // With replicas everywhere pool affinity == least-loaded (± noise).
        assert!(fixed.p99_ms < 1.2 * spread.p99_ms);
    }

    #[test]
    fn deterministic() {
        let a = run_burst(&cfg(SchedPolicy::Spread));
        let b = run_burst(&cfg(SchedPolicy::Spread));
        assert_eq!(a.p99_ms, b.p99_ms);
        assert_eq!(a.transfers, b.transfers);
    }
}
