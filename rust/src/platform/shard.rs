//! Sharded platform accounting (S26): deterministic node partition,
//! ordered inter-shard mailbox, and mergeable per-shard result partials.
//!
//! The platform's event *spine* stays a single deterministic DES — step
//! durations sample an engine-global PRNG in global event order, so any
//! interleaving change would change the draws themselves.  What shards is
//! the **accounting plane**: nodes are partitioned contiguously across K
//! shards by [`ShardPlan`]; every domain decision that lands on a node
//! (dispatch, serve, kill, crash, restart, pre-warm boot) posts an
//! explicit [`ShardMsg`] into that node's shard queue in the
//! [`ShardMailbox`], stamped with the event's virtual time and a unique
//! serial; the mailbox drains at virtual-time barriers into per-shard
//! [`ShardPartial`] accumulators; and the final report is the shard-order
//! merge of those partials.  Node-finalization work (pool teardown,
//! histogram merging) runs **concurrently per shard** — each worker owns
//! a disjoint contiguous node range — on `std::thread::scope`, the same
//! primitive the sweep runner uses.
//!
//! The invariant everything hangs off: every quantity a partial carries
//! is an exact integer (counts, `u128` nanosecond sums), so applying
//! messages per shard and merging partials in shard order is associative
//! and commutative **bit-for-bit**.  That is what makes the merged report
//! byte-identical for every shard count, including K = 1 — pinned by the
//! regression suite and a property test, and re-checked in debug builds
//! where the legacy global counters are retained as a parity oracle.

use crate::metrics::Histogram;
use crate::sim::snap::{Dec, Enc};

/// Mailbox drain cadence: one barrier per virtual second.  Drain timing
/// is observationally pure (partials apply exact integer deltas), so the
/// cadence only bounds queued-message memory, never results.
pub const DEFAULT_BARRIER_NS: u64 = 1_000_000_000;

/// Contiguous partition of `nodes` across `shards` (clamped to
/// `[1, nodes]`): shard `i` owns `base + 1` nodes if `i < nodes % shards`
/// else `base`, where `base = nodes / shards`.  Contiguity keeps the
/// shard-order merge of per-node histograms identical to the node-order
/// fold of the single-engine path.
#[derive(Clone, Copy, Debug)]
pub struct ShardPlan {
    nodes: usize,
    shards: usize,
}

impl ShardPlan {
    pub fn new(nodes: usize, shards: usize) -> ShardPlan {
        assert!(nodes >= 1, "a shard plan needs at least one node");
        ShardPlan { nodes, shards: shards.clamp(1, nodes) }
    }

    pub fn shards(&self) -> usize {
        self.shards
    }

    pub fn nodes(&self) -> usize {
        self.nodes
    }

    /// The contiguous node range shard `shard` owns.
    pub fn range(&self, shard: usize) -> std::ops::Range<usize> {
        debug_assert!(shard < self.shards);
        let base = self.nodes / self.shards;
        let rem = self.nodes % self.shards;
        let start = shard * base + shard.min(rem);
        let len = base + usize::from(shard < rem);
        start..start + len
    }

    /// The shard owning `node` — the inverse of [`ShardPlan::range`].
    pub fn shard_of(&self, node: usize) -> usize {
        debug_assert!(node < self.nodes);
        let base = self.nodes / self.shards;
        let rem = self.nodes % self.shards;
        let big = rem * (base + 1);
        if node < big {
            node / (base + 1)
        } else {
            rem + (node - big) / base
        }
    }
}

/// Latency class of a served dispatch, as carried by [`ShardMsg::Served`]
/// (mirrors the platform's private dispatch-heat classification).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum HeatClass {
    Cold,
    Warm,
    /// Runtime-warm slot owned by another function (S23): paid the
    /// specialization pipeline.
    Specialized,
}

/// One cross-shard accounting message: a domain decision attributed to
/// the shard owning the node it landed on (gateway-scoped outcomes —
/// injections, retries, rejections — route to shard 0, the frontend's
/// home shard).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ShardMsg {
    /// Attempt 0 of a user chain completed its injection accounting.
    Injected,
    /// A dispatch decision placed an attempt on a node.
    Dispatched { cold: bool, in_window: bool },
    /// An attempt completed and returned a response.
    Served { heat: HeatClass, lat_ns: u64 },
    /// An attempt died with its crashed node.
    Killed,
    /// A retry attempt was spawned for a killed request.
    Retry,
    /// A chain was abandoned (cluster down, or retries exhausted).
    Rejected,
    /// A node crashed, destroying `slots_lost` idle warm executors.
    Crashed { slots_lost: u64 },
    /// A crashed node came back up.
    Restarted,
    /// A scheduled pre-warm boot fired and populated a pool.
    PrewarmBoot,
}

impl ShardMsg {
    /// Serialize for a checkpoint (S27), canonical tag order.
    pub fn encode(&self, w: &mut Enc) {
        match *self {
            ShardMsg::Injected => w.u8(0),
            ShardMsg::Dispatched { cold, in_window } => {
                w.u8(1);
                w.bool(cold);
                w.bool(in_window);
            }
            ShardMsg::Served { heat, lat_ns } => {
                w.u8(2);
                w.u8(match heat {
                    HeatClass::Cold => 0,
                    HeatClass::Warm => 1,
                    HeatClass::Specialized => 2,
                });
                w.u64(lat_ns);
            }
            ShardMsg::Killed => w.u8(3),
            ShardMsg::Retry => w.u8(4),
            ShardMsg::Rejected => w.u8(5),
            ShardMsg::Crashed { slots_lost } => {
                w.u8(6);
                w.u64(slots_lost);
            }
            ShardMsg::Restarted => w.u8(7),
            ShardMsg::PrewarmBoot => w.u8(8),
        }
    }

    pub fn decode(r: &mut Dec) -> ShardMsg {
        match r.u8() {
            0 => ShardMsg::Injected,
            1 => ShardMsg::Dispatched { cold: r.bool(), in_window: r.bool() },
            2 => {
                let heat = match r.u8() {
                    0 => HeatClass::Cold,
                    1 => HeatClass::Warm,
                    2 => HeatClass::Specialized,
                    other => panic!("snapshot corrupt: HeatClass tag {other}"),
                };
                ShardMsg::Served { heat, lat_ns: r.u64() }
            }
            3 => ShardMsg::Killed,
            4 => ShardMsg::Retry,
            5 => ShardMsg::Rejected,
            6 => ShardMsg::Crashed { slots_lost: r.u64() },
            7 => ShardMsg::Restarted,
            8 => ShardMsg::PrewarmBoot,
            other => panic!("snapshot corrupt: ShardMsg tag {other}"),
        }
    }
}

/// Per-shard accumulator: the message-driven counters plus the
/// node-derived fields the per-shard finalize pass fills in.  Every field
/// is an exact integer quantity (histogram sums are `u128` ns), so
/// [`ShardPartial::merge`] is associative and commutative bit-for-bit.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct ShardPartial {
    // --- message-driven (applied at mailbox drains) ---
    pub injected: u64,
    pub served: u64,
    pub killed: u64,
    pub retries: u64,
    pub rejected: u64,
    pub crashes: u64,
    pub restarts: u64,
    pub prewarm_boots: u64,
    pub warm_slots_lost: u64,
    pub window_cold: u64,
    pub window_total: u64,
    pub steady_cold: u64,
    pub steady_total: u64,
    pub cold_hist: Histogram,
    pub warm_hist: Histogram,
    pub spec_hist: Histogram,
    // --- node-derived (filled by the shard's finalize worker) ---
    pub hist: Histogram,
    pub idle_mem_byte_ns: u128,
    pub warm_hits: u64,
    pub specializations: u64,
    pub cold_starts: u64,
    pub expirations: u64,
    pub retirements: u64,
    pub monitor_events: u64,
}

impl ShardPartial {
    /// Apply one drained message to this shard's accumulator.
    pub fn apply(&mut self, msg: &ShardMsg) {
        match *msg {
            ShardMsg::Injected => self.injected += 1,
            ShardMsg::Dispatched { cold, in_window } => {
                if in_window {
                    self.window_total += 1;
                    self.window_cold += u64::from(cold);
                } else {
                    self.steady_total += 1;
                    self.steady_cold += u64::from(cold);
                }
            }
            ShardMsg::Served { heat, lat_ns } => {
                self.served += 1;
                match heat {
                    HeatClass::Cold => self.cold_hist.record_ns(lat_ns),
                    HeatClass::Warm => self.warm_hist.record_ns(lat_ns),
                    HeatClass::Specialized => self.spec_hist.record_ns(lat_ns),
                }
            }
            ShardMsg::Killed => self.killed += 1,
            ShardMsg::Retry => self.retries += 1,
            ShardMsg::Rejected => self.rejected += 1,
            ShardMsg::Crashed { slots_lost } => {
                self.crashes += 1;
                self.warm_slots_lost += slots_lost;
            }
            ShardMsg::Restarted => self.restarts += 1,
            ShardMsg::PrewarmBoot => self.prewarm_boots += 1,
        }
    }

    /// Fold another partial into this one.  Exact integer adds
    /// throughout: grouping and order cannot change the result.
    pub fn merge(&mut self, other: &ShardPartial) {
        self.injected += other.injected;
        self.served += other.served;
        self.killed += other.killed;
        self.retries += other.retries;
        self.rejected += other.rejected;
        self.crashes += other.crashes;
        self.restarts += other.restarts;
        self.prewarm_boots += other.prewarm_boots;
        self.warm_slots_lost += other.warm_slots_lost;
        self.window_cold += other.window_cold;
        self.window_total += other.window_total;
        self.steady_cold += other.steady_cold;
        self.steady_total += other.steady_total;
        self.cold_hist.merge(&other.cold_hist);
        self.warm_hist.merge(&other.warm_hist);
        self.spec_hist.merge(&other.spec_hist);
        self.hist.merge(&other.hist);
        self.idle_mem_byte_ns += other.idle_mem_byte_ns;
        self.warm_hits += other.warm_hits;
        self.specializations += other.specializations;
        self.cold_starts += other.cold_starts;
        self.expirations += other.expirations;
        self.retirements += other.retirements;
        self.monitor_events += other.monitor_events;
    }

    /// Serialize every field, declaration order (S27).
    pub fn encode(&self, w: &mut Enc) {
        w.u64(self.injected);
        w.u64(self.served);
        w.u64(self.killed);
        w.u64(self.retries);
        w.u64(self.rejected);
        w.u64(self.crashes);
        w.u64(self.restarts);
        w.u64(self.prewarm_boots);
        w.u64(self.warm_slots_lost);
        w.u64(self.window_cold);
        w.u64(self.window_total);
        w.u64(self.steady_cold);
        w.u64(self.steady_total);
        self.cold_hist.encode(w);
        self.warm_hist.encode(w);
        self.spec_hist.encode(w);
        self.hist.encode(w);
        w.u128(self.idle_mem_byte_ns);
        w.u64(self.warm_hits);
        w.u64(self.specializations);
        w.u64(self.cold_starts);
        w.u64(self.expirations);
        w.u64(self.retirements);
        w.u64(self.monitor_events);
    }

    pub fn decode(r: &mut Dec) -> ShardPartial {
        ShardPartial {
            injected: r.u64(),
            served: r.u64(),
            killed: r.u64(),
            retries: r.u64(),
            rejected: r.u64(),
            crashes: r.u64(),
            restarts: r.u64(),
            prewarm_boots: r.u64(),
            warm_slots_lost: r.u64(),
            window_cold: r.u64(),
            window_total: r.u64(),
            steady_cold: r.u64(),
            steady_total: r.u64(),
            cold_hist: Histogram::decode(r),
            warm_hist: Histogram::decode(r),
            spec_hist: Histogram::decode(r),
            hist: Histogram::decode(r),
            idle_mem_byte_ns: r.u128(),
            warm_hits: r.u64(),
            specializations: r.u64(),
            cold_starts: r.u64(),
            expirations: r.u64(),
            retirements: r.u64(),
            monitor_events: r.u64(),
        }
    }
}

/// Deterministic inter-shard mailbox: one `(t, seq, msg)` queue per
/// shard.  Posts carry the posting event's virtual time plus a unique
/// serial, and arrive in nondecreasing `(t, seq)` order (the event spine
/// is totally ordered), so each queue is sorted by construction — the
/// debug assert pins that.  Queues drain into [`ShardPartial`]s at
/// virtual-time barriers, bounding queued-message memory by the barrier
/// interval instead of the run length.
#[derive(Debug)]
pub struct ShardMailbox {
    queues: Vec<Vec<(u64, u64, ShardMsg)>>,
    seq: u64,
    barrier_ns: u64,
    next_barrier_ns: u64,
    posted: u64,
    barriers: u64,
}

impl ShardMailbox {
    pub fn new(shards: usize, barrier_ns: u64) -> ShardMailbox {
        assert!(shards >= 1, "mailbox needs at least one shard");
        assert!(barrier_ns >= 1, "barrier interval must be positive");
        ShardMailbox {
            queues: (0..shards).map(|_| Vec::new()).collect(),
            seq: 0,
            barrier_ns,
            next_barrier_ns: barrier_ns,
            posted: 0,
            barriers: 0,
        }
    }

    /// Messages posted over the mailbox's lifetime.
    pub fn posted(&self) -> u64 {
        self.posted
    }

    /// Barrier drains executed (including the final explicit drain).
    pub fn barriers(&self) -> u64 {
        self.barriers
    }

    /// Post a message to `shard`, stamped `(t, seq)` with a fresh serial.
    pub fn post(&mut self, shard: usize, t: u64, msg: ShardMsg) {
        self.seq += 1;
        let seq = self.seq;
        debug_assert!(
            !self.queues[shard].last().is_some_and(|&(lt, ls, _)| (lt, ls) >= (t, seq)),
            "mailbox posts must arrive in (t, seq) order"
        );
        self.queues[shard].push((t, seq, msg));
        self.posted += 1;
    }

    /// Drain every queue if virtual time has crossed the next barrier.
    pub fn maybe_drain(&mut self, now: u64, partials: &mut [ShardPartial]) {
        if now < self.next_barrier_ns {
            return;
        }
        // Land on the barrier after `now` (skip any starved intervals).
        self.next_barrier_ns = (now / self.barrier_ns + 1) * self.barrier_ns;
        self.drain(partials);
    }

    /// Apply every queued message to its shard's partial, in per-shard
    /// `(t, seq)` order, and clear the queues.
    pub fn drain(&mut self, partials: &mut [ShardPartial]) {
        debug_assert_eq!(partials.len(), self.queues.len());
        for (shard, queue) in self.queues.iter_mut().enumerate() {
            for (_, _, msg) in queue.drain(..) {
                partials[shard].apply(&msg);
            }
        }
        self.barriers += 1;
    }

    /// Canonical, **shard-count-invariant** encoding for the state-hash
    /// section (S27): counters plus the flat, seq-sorted multiset of
    /// undrained messages.  Which queue each message sits in is a
    /// K-dependent layout detail and deliberately unobservable here — it
    /// goes in [`Self::encode_layout`] instead, so the hash chain is
    /// identical for every shard count.
    pub fn encode_canonical(&self, w: &mut Enc) {
        w.u64(self.seq);
        w.u64(self.barrier_ns);
        w.u64(self.next_barrier_ns);
        w.u64(self.posted);
        w.u64(self.barriers);
        let msgs = self.sorted_msgs();
        w.len(msgs.len());
        for &(t, seq, msg, _) in &msgs {
            w.u64(t);
            w.u64(seq);
            msg.encode(w);
        }
    }

    /// Restore supplement: each message's queue index, in the same
    /// seq-sorted order as [`Self::encode_canonical`].  Never hashed.
    pub fn encode_layout(&self, w: &mut Enc) {
        let msgs = self.sorted_msgs();
        w.len(msgs.len());
        for &(_, _, _, shard) in &msgs {
            w.usize(shard);
        }
    }

    fn sorted_msgs(&self) -> Vec<(u64, u64, ShardMsg, usize)> {
        let mut msgs: Vec<(u64, u64, ShardMsg, usize)> = self
            .queues
            .iter()
            .enumerate()
            .flat_map(|(shard, q)| q.iter().map(move |&(t, seq, msg)| (t, seq, msg, shard)))
            .collect();
        msgs.sort_unstable_by_key(|&(_, seq, _, _)| seq);
        msgs
    }

    /// Inverse of [`Self::encode_canonical`] + [`Self::encode_layout`]
    /// onto a freshly constructed mailbox with the same shard count.
    pub fn restore(&mut self, r: &mut Dec, layout: &mut Dec) {
        self.seq = r.u64();
        self.barrier_ns = r.u64();
        self.next_barrier_ns = r.u64();
        self.posted = r.u64();
        self.barriers = r.u64();
        for q in &mut self.queues {
            q.clear();
        }
        let n = r.len();
        assert_eq!(n, layout.len(), "mailbox layout supplement out of sync with snapshot");
        for _ in 0..n {
            let t = r.u64();
            let seq = r.u64();
            let msg = ShardMsg::decode(r);
            let shard = layout.usize();
            assert!(shard < self.queues.len(), "snapshot corrupt: mailbox shard {shard}");
            // Pushing in global seq order keeps each queue seq-sorted.
            self.queues[shard].push((t, seq, msg));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plan_partitions_contiguously_and_inverts() {
        for nodes in [1usize, 2, 7, 64, 256, 1024] {
            for shards in [1usize, 2, 3, 5, 8, 1500] {
                let plan = ShardPlan::new(nodes, shards);
                assert!(plan.shards() >= 1 && plan.shards() <= nodes);
                let mut covered = 0usize;
                for s in 0..plan.shards() {
                    let r = plan.range(s);
                    assert_eq!(r.start, covered, "{nodes}x{shards} shard {s}");
                    for node in r.clone() {
                        assert_eq!(plan.shard_of(node), s, "{nodes}x{shards} node {node}");
                    }
                    covered = r.end;
                }
                assert_eq!(covered, nodes, "{nodes}x{shards} must cover all nodes");
            }
        }
    }

    #[test]
    fn plan_balances_within_one_node() {
        let plan = ShardPlan::new(10, 4);
        let sizes: Vec<usize> = (0..4).map(|s| plan.range(s).len()).collect();
        assert_eq!(sizes, vec![3, 3, 2, 2]);
    }

    #[test]
    fn mailbox_drains_messages_into_owning_shards() {
        let mut mb = ShardMailbox::new(3, 1_000);
        let mut parts = vec![ShardPartial::default(); 3];
        mb.post(0, 10, ShardMsg::Injected);
        mb.post(2, 10, ShardMsg::Dispatched { cold: true, in_window: false });
        mb.post(2, 20, ShardMsg::Served { heat: HeatClass::Cold, lat_ns: 5_000_000 });
        mb.post(1, 30, ShardMsg::Crashed { slots_lost: 7 });
        // Below the barrier: nothing drains.
        mb.maybe_drain(999, &mut parts);
        assert_eq!(parts[0].injected, 0);
        mb.maybe_drain(1_000, &mut parts);
        assert_eq!(parts[0].injected, 1);
        assert_eq!(parts[2].steady_total, 1);
        assert_eq!(parts[2].steady_cold, 1);
        assert_eq!(parts[2].served, 1);
        assert_eq!(parts[2].cold_hist.len(), 1);
        assert_eq!(parts[1].crashes, 1);
        assert_eq!(parts[1].warm_slots_lost, 7);
        assert_eq!(mb.posted(), 4);
        assert_eq!(mb.barriers(), 1);
        // Drained queues stay reusable and ordered.
        mb.post(0, 1_500, ShardMsg::Retry);
        mb.drain(&mut parts);
        assert_eq!(parts[0].retries, 1);
    }

    #[test]
    fn drain_timing_cannot_change_totals() {
        // The same message stream applied through one big drain vs. many
        // small ones must produce bit-identical partials: drains only
        // bound memory.
        let msgs = [
            (0usize, 5u64, ShardMsg::Injected),
            (1, 10, ShardMsg::Dispatched { cold: false, in_window: true }),
            (1, 15, ShardMsg::Served { heat: HeatClass::Warm, lat_ns: 2_000_000 }),
            (0, 2_500, ShardMsg::Rejected),
            (1, 3_000, ShardMsg::Served { heat: HeatClass::Specialized, lat_ns: 9_000_000 }),
        ];
        let mut eager_mb = ShardMailbox::new(2, 1_000);
        let mut eager = vec![ShardPartial::default(); 2];
        for &(shard, t, msg) in &msgs {
            eager_mb.post(shard, t, msg);
            eager_mb.maybe_drain(t, &mut eager);
        }
        eager_mb.drain(&mut eager);
        let mut lazy_mb = ShardMailbox::new(2, 1_000);
        let mut lazy = vec![ShardPartial::default(); 2];
        for &(shard, t, msg) in &msgs {
            lazy_mb.post(shard, t, msg);
        }
        lazy_mb.drain(&mut lazy);
        for (e, l) in eager.iter().zip(&lazy) {
            assert_eq!(e.injected, l.injected);
            assert_eq!(e.served, l.served);
            assert_eq!(e.rejected, l.rejected);
            assert_eq!(e.window_total, l.window_total);
            assert_eq!(e.warm_hist, l.warm_hist);
            assert_eq!(e.spec_hist, l.spec_hist);
        }
    }

    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "(t, seq) order")]
    fn out_of_order_post_is_rejected() {
        let mut mb = ShardMailbox::new(1, 1_000);
        mb.post(0, 100, ShardMsg::Injected);
        mb.post(0, 50, ShardMsg::Injected);
    }

    #[test]
    fn canonical_mailbox_encoding_is_shard_count_invariant() {
        // The same message stream posted under two different shard layouts
        // must hash-encode identically: queue placement is layout, not
        // state.
        let stream = [
            (10u64, ShardMsg::Injected),
            (20, ShardMsg::Dispatched { cold: true, in_window: true }),
            (25, ShardMsg::Served { heat: HeatClass::Specialized, lat_ns: 3_000_000 }),
            (40, ShardMsg::Crashed { slots_lost: 3 }),
            (41, ShardMsg::Restarted),
            (90, ShardMsg::PrewarmBoot),
        ];
        let mut one = ShardMailbox::new(1, 1_000);
        let mut four = ShardMailbox::new(4, 1_000);
        for (i, &(t, msg)) in stream.iter().enumerate() {
            one.post(0, t, msg);
            four.post(i % 4, t, msg);
        }
        let (mut w1, mut w4) = (Enc::new(), Enc::new());
        one.encode_canonical(&mut w1);
        four.encode_canonical(&mut w4);
        assert_eq!(w1.buf, w4.buf, "canonical encoding must not observe shard layout");
    }

    #[test]
    fn mailbox_restore_round_trips_and_preserves_drains() {
        let mut mb = ShardMailbox::new(3, 1_000);
        let mut parts = vec![ShardPartial::default(); 3];
        mb.post(1, 10, ShardMsg::Injected);
        mb.post(2, 20, ShardMsg::Served { heat: HeatClass::Warm, lat_ns: 7_000 });
        mb.maybe_drain(1_500, &mut parts);
        mb.post(0, 1_600, ShardMsg::Retry);
        mb.post(2, 1_700, ShardMsg::Rejected);

        let (mut canon, mut layout) = (Enc::new(), Enc::new());
        mb.encode_canonical(&mut canon);
        mb.encode_layout(&mut layout);

        let mut back = ShardMailbox::new(3, 1_000);
        let (mut cr, mut lr) = (Dec::new(&canon.buf), Dec::new(&layout.buf));
        back.restore(&mut cr, &mut lr);
        cr.finish();
        lr.finish();

        let mut canon2 = Enc::new();
        back.encode_canonical(&mut canon2);
        assert_eq!(canon.buf, canon2.buf, "restore must round-trip byte-exactly");

        // Draining both produces identical partial deltas, in the right
        // shard queues.
        let mut p1 = vec![ShardPartial::default(); 3];
        let mut p2 = vec![ShardPartial::default(); 3];
        mb.drain(&mut p1);
        back.drain(&mut p2);
        assert_eq!(p1, p2);
        assert_eq!(p1[0].retries, 1);
        assert_eq!(p1[2].rejected, 1);
        assert_eq!(mb.posted(), back.posted());
        assert_eq!(mb.barriers(), back.barriers());
    }

    #[test]
    fn partial_codec_round_trips_every_field() {
        let mut p = ShardPartial::default();
        for msg in [
            ShardMsg::Injected,
            ShardMsg::Dispatched { cold: true, in_window: false },
            ShardMsg::Served { heat: HeatClass::Cold, lat_ns: 9_000_000 },
            ShardMsg::Served { heat: HeatClass::Warm, lat_ns: 2_000_000 },
            ShardMsg::Killed,
            ShardMsg::Retry,
            ShardMsg::Rejected,
            ShardMsg::Crashed { slots_lost: 11 },
            ShardMsg::Restarted,
            ShardMsg::PrewarmBoot,
        ] {
            p.apply(&msg);
        }
        p.hist.record_ns(123_456);
        p.idle_mem_byte_ns = 1 << 80;
        p.warm_hits = 5;
        p.monitor_events = 9;
        let mut w = Enc::new();
        p.encode(&mut w);
        let mut r = Dec::new(&w.buf);
        let q = ShardPartial::decode(&mut r);
        r.finish();
        assert_eq!(p, q);
    }

    #[test]
    fn partial_merge_is_associative_and_commutative() {
        // Build three partials from disjoint slices of one deterministic
        // message stream, then merge in several groupings: all must be
        // bit-identical (every field is an exact integer).
        let mut x = 0x5EEDu64;
        let mut step = || {
            x = x.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            x
        };
        let mut parts = vec![ShardPartial::default(); 3];
        for i in 0..3_000u64 {
            let p = &mut parts[(i % 3) as usize];
            let msg = match step() % 7 {
                0 => ShardMsg::Injected,
                1 => ShardMsg::Dispatched { cold: step() % 2 == 0, in_window: step() % 3 == 0 },
                2 => ShardMsg::Served { heat: HeatClass::Warm, lat_ns: 1_000 + step() % 1_000_000_000 },
                3 => ShardMsg::Served { heat: HeatClass::Cold, lat_ns: 1_000 + step() % 4_000_000_000 },
                4 => ShardMsg::Killed,
                5 => ShardMsg::Crashed { slots_lost: step() % 50 },
                _ => ShardMsg::PrewarmBoot,
            };
            p.apply(&msg);
            p.hist.record_ns(1_000 + step() % 2_000_000_000);
            p.idle_mem_byte_ns += (step() % (1 << 40)) as u128;
            p.warm_hits += step() % 5;
        }
        let fold = |order: &[usize]| {
            let mut total = ShardPartial::default();
            for &i in order {
                total.merge(&parts[i]);
            }
            total
        };
        let a = fold(&[0, 1, 2]);
        let b = fold(&[2, 1, 0]);
        let mut c = ShardPartial::default();
        let mut right = ShardPartial::default();
        right.merge(&parts[1]);
        right.merge(&parts[2]);
        c.merge(&parts[0]);
        c.merge(&right);
        for t in [&b, &c] {
            assert_eq!(a.injected, t.injected);
            assert_eq!(a.served, t.served);
            assert_eq!(a.killed, t.killed);
            assert_eq!(a.crashes, t.crashes);
            assert_eq!(a.warm_slots_lost, t.warm_slots_lost);
            assert_eq!(a.window_cold, t.window_cold);
            assert_eq!(a.steady_total, t.steady_total);
            assert_eq!(a.prewarm_boots, t.prewarm_boots);
            assert_eq!(a.cold_hist, t.cold_hist);
            assert_eq!(a.warm_hist, t.warm_hist);
            assert_eq!(a.hist, t.hist);
            assert_eq!(a.idle_mem_byte_ns, t.idle_mem_byte_ns);
            assert_eq!(a.warm_hits, t.warm_hits);
        }
    }
}
