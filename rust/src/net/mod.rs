//! Network model (S5): geographic RTTs, TCP/TLS connection setup, and
//! link-bandwidth transfer costs, for the cloud experiments (Table I, E10).
//!
//! Table I's connection-setup column is mostly protocol arithmetic: a plain
//! TCP connect costs one RTT before the request can be sent, TLS 1.2 adds
//! two more round trips plus handshake crypto (§IV-B: "3 round-trips and
//! the computational costs").

use crate::sim::{Dist, Step, MS};

/// A measurement vantage point / deployment site.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Site {
    /// Ericsson lab, Stockholm (the paper's measurement point).
    LabStockholm,
    /// AWS eu-north-1 (Stockholm region) — where Fn + Lambda are deployed.
    AwsStockholm,
    /// Ericsson lab, Budapest (the distance experiment).
    LabBudapest,
    /// An EC2 instance inside the same AWS region.
    Ec2SameRegion,
}

/// Median round-trip time between two sites, in milliseconds.
///
/// Calibrated so Table I reproduces: lab→AWS-Stockholm plain TCP setup is
/// ~0.9–6.9 ms depending on the frontend, Lambda's TLS setup is ~50 ms,
/// and Budapest→Stockholm TLS grows to ~200 ms (§IV-B).
pub fn rtt_ms(a: Site, b: Site) -> f64 {
    use Site::*;
    if a == b {
        return 0.08; // intra-site/loopback-ish
    }
    match a.min_key(b) {
        (LabStockholm, AwsStockholm) => 0.8,
        (LabStockholm, LabBudapest) => 24.0,
        (AwsStockholm, LabBudapest) => 24.5,
        (LabStockholm, Ec2SameRegion) => 0.85,
        (AwsStockholm, Ec2SameRegion) => 0.25,
        (LabBudapest, Ec2SameRegion) => 24.5,
        _ => 1.0,
    }
}

impl Site {
    fn min_key(self, other: Site) -> (Site, Site) {
        if (self as u8) <= (other as u8) { (self, other) } else { (other, self) }
    }
}

/// Jitter sigma applied to each one-way hop.
const RTT_SIGMA: f64 = 0.08;

/// One network round trip as a simulation step.
pub fn rtt_step(tag: &'static str, a: Site, b: Site) -> Step {
    Step::delay(tag, Dist::ms(rtt_ms(a, b), RTT_SIGMA))
}

/// Frontend connection-termination style.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ConnKind {
    /// Plain TCP: one RTT (SYN/SYN-ACK) before the request flows.
    Tcp,
    /// TLS 1.2 over TCP through an API gateway: 3 RTTs + handshake crypto.
    Tls,
}

/// Server-side accept overhead (ms) — covers the frontend's listener,
/// e.g. Fn's HTTP server vs the hypervisor-host port proxying for the
/// IncludeOS deployment (Table I measures 0.9 vs 6.9 ms setup on the same
/// host pair: the difference is frontend accept-path work, not distance).
#[derive(Clone, Copy, Debug)]
pub struct Frontend {
    pub kind: ConnKind,
    pub accept_overhead_ms: f64,
}

impl Frontend {
    pub const FN_DOCKER: Frontend = Frontend { kind: ConnKind::Tcp, accept_overhead_ms: 0.1 };
    /// The prototype's IncludeOS frontend: extra accept-path cost from the
    /// qemu-free but unoptimized solo5 port forwarding on the metal host.
    pub const FN_INCLUDEOS: Frontend = Frontend { kind: ConnKind::Tcp, accept_overhead_ms: 5.2 };
    /// AWS API Gateway terminating TLS in front of Lambda.  Table I's
    /// 50.1 ms setup is far above 3 bare RTTs in-region: the bulk is the
    /// managed edge — DNS resolution, the edge-optimized endpoint hop, and
    /// the gateway's own TLS/session machinery — modeled as a flat accept
    /// overhead on top of the protocol round trips.
    pub const LAMBDA_API_GW: Frontend = Frontend { kind: ConnKind::Tls, accept_overhead_ms: 42.0 };
    /// The repo's own rebuilt gateway (S29) measured over loopback: plain
    /// TCP and a worker-pool accept path.  E18 `livecheck` uses this
    /// model's nominal setup as the per-request HTTP-overhead term when
    /// deriving the live-vs-sim tolerance bands (EXPERIMENTS.md
    /// "Simulation vs. live measurement").
    pub const LIVE_LOOPBACK: Frontend = Frontend { kind: ConnKind::Tcp, accept_overhead_ms: 0.05 };

    /// TLS handshake crypto cost (both sides), ms.
    const TLS_CRYPTO_MS: f64 = 3.0;

    /// Connection-setup steps from `client` to `server`.
    pub fn connect_steps(&self, client: Site, server: Site) -> Vec<Step> {
        let mut v = Vec::new();
        let rtts = match self.kind {
            ConnKind::Tcp => 1.0,
            ConnKind::Tls => 3.0,
        };
        v.push(Step::delay(
            "conn-rtts",
            Dist::ms(rtts * rtt_ms(client, server), RTT_SIGMA),
        ));
        if self.kind == ConnKind::Tls {
            v.push(Step::cpu("tls-crypto", Dist::ms(Self::TLS_CRYPTO_MS, 0.2)));
        }
        if self.accept_overhead_ms > 0.0 {
            v.push(Step::delay("accept-overhead", Dist::ms(self.accept_overhead_ms, 0.15)));
        }
        v
    }

    /// Nominal (median-sum) connection setup in ms, for checks.
    pub fn nominal_setup_ms(&self, client: Site, server: Site) -> f64 {
        self.connect_steps(client, server)
            .iter()
            .map(|s| s.dur.median_ns() / 1e6)
            .sum()
    }
}

/// Transfer time for `bytes` over a link of `gbps`, as a delay step.
pub fn transfer_step(tag: &'static str, bytes: u64, gbps: f64) -> Step {
    let ns = bytes as f64 * 8.0 / (gbps * 1e9) * 1e9;
    Step::delay(tag, Dist::Const(ns.max(0.001 * MS)))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rtt_symmetric() {
        assert_eq!(
            rtt_ms(Site::LabStockholm, Site::AwsStockholm),
            rtt_ms(Site::AwsStockholm, Site::LabStockholm)
        );
    }

    #[test]
    fn same_site_near_zero() {
        assert!(rtt_ms(Site::AwsStockholm, Site::AwsStockholm) < 0.1);
    }

    #[test]
    fn table1_connection_setups() {
        // Table I: Fn Docker 0.9, Fn IncludeOS 6.9, Lambda 50.1 ms (medians).
        let fd = Frontend::FN_DOCKER.nominal_setup_ms(Site::LabStockholm, Site::AwsStockholm);
        assert!((fd / 0.9 - 1.0).abs() < 0.25, "fn-docker setup {fd}");
        let fi = Frontend::FN_INCLUDEOS.nominal_setup_ms(Site::LabStockholm, Site::AwsStockholm);
        assert!((fi / 6.9 - 1.0).abs() < 0.25, "fn-includeos setup {fi}");
        // Lambda through the TLS API gateway: 50.1 ms (3 RTTs + crypto +
        // the managed-edge overhead).
        let la = Frontend::LAMBDA_API_GW.nominal_setup_ms(Site::LabStockholm, Site::AwsStockholm);
        assert!((la / 50.1 - 1.0).abs() < 0.25, "lambda setup {la}");
    }

    #[test]
    fn budapest_tls_setup_grows_with_distance() {
        // §IV-B: "up to around 200 ms if the Lambda function is called from
        // our lab in Budapest" — for the *full* call; setup alone must be
        // the dominant part of that (3 RTTs ≈ 74 ms + crypto + accept).
        let near = Frontend::LAMBDA_API_GW.nominal_setup_ms(Site::LabStockholm, Site::AwsStockholm);
        let far = Frontend::LAMBDA_API_GW.nominal_setup_ms(Site::LabBudapest, Site::AwsStockholm);
        // The distance term is the 3 extra RTTs (~71 ms Budapest).
        assert!(far - near > 50.0, "far {far} near {near}");
        assert!((90.0..140.0).contains(&far), "far setup {far}");
    }

    #[test]
    fn ec2_same_region_slightly_lower() {
        // §IV-B: EC2 in-region gives only slightly lower setup overhead.
        let lab = Frontend::LAMBDA_API_GW.nominal_setup_ms(Site::LabStockholm, Site::AwsStockholm);
        let ec2 = Frontend::LAMBDA_API_GW.nominal_setup_ms(Site::Ec2SameRegion, Site::AwsStockholm);
        assert!(ec2 < lab);
        assert!(ec2 > lab * 0.5, "should be 'only slightly lower': {ec2} vs {lab}");
    }

    #[test]
    fn live_loopback_setup_is_sub_millisecond() {
        // The live gateway's whole connection-setup model must stay well
        // under the warm-invoke pipeline (~1.8 ms docker), or the E18
        // band derivation would be dominated by its own overhead term.
        let lo = Frontend::LIVE_LOOPBACK.nominal_setup_ms(Site::LabStockholm, Site::LabStockholm);
        assert!(lo < 1.0, "loopback setup {lo}");
        assert!(lo > 0.0);
    }

    #[test]
    fn transfer_time_linear_in_bytes() {
        let s1 = transfer_step("t", 1_000_000, 40.0);
        let s2 = transfer_step("t", 2_000_000, 40.0);
        assert!((s2.dur.median_ns() / s1.dur.median_ns() - 2.0).abs() < 1e-9);
        // 1 MB over 40 Gbps = 0.2 ms
        assert!((s1.dur.median_ns() / 1e6 - 0.2).abs() < 0.01);
    }
}
