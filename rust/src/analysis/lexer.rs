//! Comment/string/raw-string-aware Rust tokenizer for `detlint` (S28).
//!
//! Deliberately tiny: the rule engine needs identifiers, punctuation and
//! line numbers — not a faithful grammar.  Literals are opaque (`Lit`),
//! lifetimes are distinguished from `char` literals so type positions
//! like `&'a HashMap<..>` stay walkable, and comments are captured on the
//! side (with their line numbers) because that is where `// detlint:
//! allow(..)` pragmas live.  The only compound punctuators emitted are
//! the four the rules look for or must not trip over: `::` `+=` `->`
//! `=>`; everything else is one token per character, which keeps
//! balanced-delimiter walks (`<>`, `()`, `[]`, `{}`) trivial.

/// Token class; rule patterns match on `Ident` text and `Punct` text.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TokKind {
    Ident,
    Punct,
    /// String / char / byte / numeric literal — contents never inspected.
    Lit,
    /// Lifetime (`'a`) — skippable in type positions.
    Life,
}

/// One token with its 1-based source line.
#[derive(Debug, Clone)]
pub struct Tok {
    pub line: u32,
    pub kind: TokKind,
    pub text: String,
}

impl Tok {
    /// Is this the identifier `s`?
    pub fn is_ident(&self, s: &str) -> bool {
        self.kind == TokKind::Ident && self.text == s
    }

    /// Is this the punctuator `s`?
    pub fn is_punct(&self, s: &str) -> bool {
        self.kind == TokKind::Punct && self.text == s
    }
}

/// Tokenized file: the token stream plus captured comments
/// (`(line, text)`, one entry per `//` comment and per block comment,
/// block comments attributed to their starting line).
pub struct Lexed {
    pub toks: Vec<Tok>,
    pub comments: Vec<(u32, String)>,
}

pub fn lex(src: &str) -> Lexed {
    let b: Vec<char> = src.chars().collect();
    let mut toks = Vec::new();
    let mut comments = Vec::new();
    let mut i = 0usize;
    let mut line: u32 = 1;
    let n = b.len();
    let ident_start = |c: char| c.is_ascii_alphabetic() || c == '_';
    let ident_cont = |c: char| c.is_ascii_alphanumeric() || c == '_';
    while i < n {
        let c = b[i];
        if c == '\n' {
            line += 1;
            i += 1;
        } else if c.is_whitespace() {
            i += 1;
        } else if c == '/' && i + 1 < n && b[i + 1] == '/' {
            let start = i;
            while i < n && b[i] != '\n' {
                i += 1;
            }
            comments.push((line, b[start..i].iter().collect()));
        } else if c == '/' && i + 1 < n && b[i + 1] == '*' {
            // Nested block comments, per the Rust grammar.
            let (start, start_line) = (i, line);
            let mut depth = 1;
            i += 2;
            while i < n && depth > 0 {
                if b[i] == '\n' {
                    line += 1;
                    i += 1;
                } else if b[i] == '/' && i + 1 < n && b[i + 1] == '*' {
                    depth += 1;
                    i += 2;
                } else if b[i] == '*' && i + 1 < n && b[i + 1] == '/' {
                    depth -= 1;
                    i += 2;
                } else {
                    i += 1;
                }
            }
            comments.push((start_line, b[start..i].iter().collect()));
        } else if is_raw_string_start(&b, i) {
            // r"..." / r#"..."# / br#"..."# — no escapes, ends at `"` +
            // the same number of `#`s.
            let start_line = line;
            while b[i] != '#' && b[i] != '"' {
                i += 1; // consume the r / br prefix
            }
            let mut hashes = 0;
            while i < n && b[i] == '#' {
                hashes += 1;
                i += 1;
            }
            i += 1; // opening quote
            loop {
                if i >= n {
                    break;
                }
                if b[i] == '\n' {
                    line += 1;
                    i += 1;
                } else if b[i] == '"'
                    && b[i + 1..].iter().take_while(|&&h| h == '#').count() >= hashes
                {
                    i += 1 + hashes;
                    break;
                } else {
                    i += 1;
                }
            }
            toks.push(Tok { line: start_line, kind: TokKind::Lit, text: String::new() });
        } else if c == '"' || (c == 'b' && i + 1 < n && b[i + 1] == '"') {
            let start_line = line;
            i += if c == 'b' { 2 } else { 1 };
            while i < n {
                match b[i] {
                    '\\' => i += 2,
                    '"' => {
                        i += 1;
                        break;
                    }
                    '\n' => {
                        line += 1;
                        i += 1;
                    }
                    _ => i += 1,
                }
            }
            toks.push(Tok { line: start_line, kind: TokKind::Lit, text: String::new() });
        } else if c == '\'' || (c == 'b' && i + 1 < n && b[i + 1] == '\'') {
            let q = if c == 'b' { i + 1 } else { i };
            // Lifetime iff `'` + ident run NOT closed by another `'`
            // (`'a` vs `'a'`); byte literals `b'..'` are always chars.
            let mut j = q + 1;
            if c != 'b' && j < n && ident_start(b[j]) {
                while j < n && ident_cont(b[j]) {
                    j += 1;
                }
                if j >= n || b[j] != '\'' {
                    toks.push(Tok {
                        line,
                        kind: TokKind::Life,
                        text: b[q..j].iter().collect(),
                    });
                    i = j;
                    continue;
                }
            }
            // Char literal: consume one (possibly escaped) char + quote.
            i = q + 1;
            while i < n {
                match b[i] {
                    '\\' => i += 2,
                    '\'' => {
                        i += 1;
                        break;
                    }
                    _ => i += 1,
                }
            }
            toks.push(Tok { line, kind: TokKind::Lit, text: String::new() });
        } else if ident_start(c) {
            let start = i;
            while i < n && ident_cont(b[i]) {
                i += 1;
            }
            toks.push(Tok { line, kind: TokKind::Ident, text: b[start..i].iter().collect() });
        } else if c.is_ascii_digit() {
            // Opaque numeric literal; `1.5`, `1_000u64`, `0x1f` all fold
            // into one token, and `8..10` leaves `..` alone.
            while i < n && (ident_cont(b[i])) {
                i += 1;
            }
            if i + 1 < n && b[i] == '.' && b[i + 1].is_ascii_digit() {
                i += 1;
                while i < n && ident_cont(b[i]) {
                    i += 1;
                }
            }
            toks.push(Tok { line, kind: TokKind::Lit, text: String::new() });
        } else {
            let two: String = b[i..(i + 2).min(n)].iter().collect();
            if matches!(two.as_str(), "::" | "+=" | "->" | "=>") {
                toks.push(Tok { line, kind: TokKind::Punct, text: two });
                i += 2;
            } else {
                toks.push(Tok { line, kind: TokKind::Punct, text: c.to_string() });
                i += 1;
            }
        }
    }
    Lexed { toks, comments }
}

/// Does a raw (byte) string literal start at `i`?  (`r"`, `r#`, `br"`,
/// `br#` — with any number of `#`s before the quote.)
fn is_raw_string_start(b: &[char], i: usize) -> bool {
    let mut j = i;
    if j < b.len() && b[j] == 'b' {
        j += 1;
    }
    if j >= b.len() || b[j] != 'r' {
        return false;
    }
    j += 1;
    while j < b.len() && b[j] == '#' {
        j += 1;
    }
    j < b.len() && b[j] == '"' && j > i + usize::from(b[i] == 'b')
}
