//! `detlint` — the in-repo determinism auditor (S28).
//!
//! Every claim this reproduction makes (the cold-only frontier, chaos
//! conservation, S27's "resume is invisible") rests on byte-identical
//! determinism, defended dynamically by report pins and hash chains.
//! This module defends it *statically*: a std-only analyzer over
//! `rust/src/**.rs` whose findings fail `cargo test -q` (via
//! `tests/detlint.rs`) and the CI `lint` job (via `coldfaas lint`).
//!
//! Rules:
//!
//! | code  | contract |
//! |-------|----------|
//! | DL001 | no `Instant::now` / `SystemTime` / `thread::sleep` outside `obs/profile.rs` and `gateway/` (wall-clock islands go through the committed allowlist) |
//! | DL002 | no iteration over `HashMap`/`HashSet` (`.iter()`, `.keys()`, `.values()`, `.drain()`, `for … in &map`, …) in the deterministic core (`sim/`, `platform/`, `fnplat/`, `policy/`, `metrics/`, `experiments/`, `image/`, `lambda/`); keyed lookup stays legal, ordered traversal must go through `BTreeMap` or an explicit sort |
//! | DL003 | no `unwrap_or(` / `unwrap_or_default(` on `parse()` results — the lenient-CLI bug class the strict `cli.rs` getters removed |
//! | DL004 | no `debug_assert!` whose argument mutates (`+=`, `.push(`, `.insert(`, `.pop(`) — debug/release behavior divergence |
//! | DL005 | snapshot-codec completeness: every named field of a struct with `Enc`/`Dec` codec fns in the same file must appear in at least one codec body, or carry a justified pragma — the drift that corrupts `CFAASCK1` resumes invisibly |
//!
//! Suppression: `// detlint: allow(DL002) <why>` on the finding's line
//! or the line directly above silences that code there; whole-subtree
//! wall-clock islands live in the committed `rust/detlint.allow`
//! (`<code> <path-prefix> <justification>` per line).

pub mod lexer;

use std::collections::BTreeSet;
use std::path::Path;

use lexer::{lex, Lexed, Tok, TokKind};

/// One rule violation.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Finding {
    pub code: &'static str,
    /// Path relative to the crate root, forward slashes (`src/...`).
    pub file: String,
    /// 1-based source line.
    pub line: u32,
    pub msg: String,
}

/// Result of linting a tree: surviving findings plus scan statistics.
#[derive(Debug, Default)]
pub struct LintReport {
    pub findings: Vec<Finding>,
    pub files: usize,
    /// Findings silenced by a pragma or an allowlist entry.
    pub suppressed: usize,
}

/// The committed allowlist: `(code, path-prefix)` pairs, one per line of
/// `rust/detlint.allow`, each carrying a mandatory justification.
#[derive(Debug, Default)]
pub struct Allowlist {
    entries: Vec<(String, String)>,
}

impl Allowlist {
    /// Parse the allowlist format: `#` comments and blank lines skipped;
    /// otherwise `<code> <path-prefix> <justification...>` — a line
    /// without a justification is an error (allows must say why).
    pub fn parse(text: &str) -> Result<Allowlist, String> {
        let mut entries = Vec::new();
        for (i, raw) in text.lines().enumerate() {
            let line = raw.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let mut parts = line.split_whitespace();
            let code = parts.next().unwrap_or_default();
            let prefix = parts.next().unwrap_or_default();
            if !code.starts_with("DL") || prefix.is_empty() || parts.next().is_none() {
                return Err(format!(
                    "detlint.allow:{}: want `<code> <path-prefix> <justification>`, got `{line}`",
                    i + 1
                ));
            }
            entries.push((code.to_string(), prefix.to_string()));
        }
        Ok(Allowlist { entries })
    }

    pub fn allows(&self, code: &str, file: &str) -> bool {
        self.entries.iter().any(|(c, p)| c == code && file.starts_with(p.as_str()))
    }
}

/// Lint one file's source.  `rel_path` is crate-root-relative
/// (`src/platform/sim.rs`); it selects which rules apply and how
/// findings are labeled.  Pragmas in `src` and `allow` entries are
/// applied; suppressed findings are counted, not returned.
pub fn lint_source(rel_path: &str, src: &str, allow: &Allowlist) -> (Vec<Finding>, usize) {
    let lx = lex(src);
    let mut raw = Vec::new();
    rule_wall_clock(rel_path, &lx.toks, &mut raw);
    rule_hash_iteration(rel_path, &lx.toks, &mut raw);
    rule_lenient_parse(rel_path, &lx.toks, &mut raw);
    rule_mutating_debug_assert(rel_path, &lx.toks, &mut raw);
    rule_codec_completeness(rel_path, &lx.toks, &mut raw);
    let pragmas = collect_pragmas(&lx);
    let mut kept = Vec::new();
    let mut suppressed = 0;
    for f in raw {
        let pragma_hit = pragmas
            .iter()
            .any(|(l, c)| c == f.code && (*l == f.line || *l + 1 == f.line));
        if pragma_hit || allow.allows(f.code, rel_path) {
            suppressed += 1;
        } else {
            kept.push(f);
        }
    }
    kept.sort_by(|a, b| (a.line, a.code).cmp(&(b.line, b.code)));
    (kept, suppressed)
}

/// Lint every `.rs` file under `<root>/src`, honoring
/// `<root>/detlint.allow` when present.  Deterministic: files are walked
/// in sorted order, findings sorted by (file, line, code).
pub fn lint_tree(root: &Path) -> Result<LintReport, String> {
    let allow = match std::fs::read_to_string(root.join("detlint.allow")) {
        Ok(text) => Allowlist::parse(&text)?,
        Err(_) => Allowlist::default(),
    };
    let src_root = root.join("src");
    let mut files = Vec::new();
    walk(&src_root, &mut files).map_err(|e| format!("walk {}: {e}", src_root.display()))?;
    files.sort();
    let mut report = LintReport::default();
    for path in &files {
        let rel = path
            .strip_prefix(root)
            .unwrap_or(path)
            .to_string_lossy()
            .replace('\\', "/");
        let src = std::fs::read_to_string(path).map_err(|e| format!("read {rel}: {e}"))?;
        let (mut findings, suppressed) = lint_source(&rel, &src, &allow);
        report.findings.append(&mut findings);
        report.suppressed += suppressed;
        report.files += 1;
    }
    Ok(report)
}

fn walk(dir: &Path, out: &mut Vec<std::path::PathBuf>) -> std::io::Result<()> {
    for entry in std::fs::read_dir(dir)? {
        let path = entry?.path();
        if path.is_dir() {
            walk(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

/// Render findings as `file:line: code: msg` lines plus a summary.
pub fn render_text(report: &LintReport) -> String {
    let mut s = String::new();
    for f in &report.findings {
        s.push_str(&format!("{}:{}: {}: {}\n", f.file, f.line, f.code, f.msg));
    }
    s.push_str(&format!(
        "detlint: {} finding(s), {} suppressed, {} file(s) scanned\n",
        report.findings.len(),
        report.suppressed,
        report.files
    ));
    s
}

/// Machine-readable report (the CI `lint` job uploads this).
pub fn render_json(report: &LintReport) -> String {
    let mut s = String::from("{\n  \"findings\": [");
    for (i, f) in report.findings.iter().enumerate() {
        if i > 0 {
            s.push(',');
        }
        s.push_str(&format!(
            "\n    {{\"file\": \"{}\", \"line\": {}, \"code\": \"{}\", \"msg\": \"{}\"}}",
            esc(&f.file),
            f.line,
            f.code,
            esc(&f.msg)
        ));
    }
    if !report.findings.is_empty() {
        s.push_str("\n  ");
    }
    s.push_str(&format!(
        "],\n  \"count\": {},\n  \"suppressed\": {},\n  \"files\": {}\n}}\n",
        report.findings.len(),
        report.suppressed,
        report.files
    ));
    s
}

fn esc(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"")
}

/// `// detlint: allow(DL001, DL002) why` → one `(line, code)` per code.
fn collect_pragmas(lx: &Lexed) -> Vec<(u32, String)> {
    let mut out = Vec::new();
    for (line, text) in &lx.comments {
        let Some(at) = text.find("detlint: allow(") else { continue };
        let rest = &text[at + "detlint: allow(".len()..];
        let Some(end) = rest.find(')') else { continue };
        for code in rest[..end].split(',') {
            out.push((*line, code.trim().to_string()));
        }
    }
    out
}

// ---------------------------------------------------------------- DL001

/// DL001: wall-clock reads in deterministic code.  `obs/profile.rs` and
/// `gateway/` are the rule's built-in islands; every other island (the
/// live exec/coordinator/runtime stack, the CLI binary, testkit) must be
/// named in `detlint.allow` with a justification.
fn rule_wall_clock(path: &str, t: &[Tok], out: &mut Vec<Finding>) {
    if path.starts_with("src/obs/profile.rs") || path.starts_with("src/gateway/") {
        return;
    }
    let finding = |tok: &Tok, what: &str| Finding {
        code: "DL001",
        file: path.to_string(),
        line: tok.line,
        msg: format!(
            "{what} in deterministic code — virtual time only; wall-clock islands \
             need a detlint.allow entry or a justified pragma"
        ),
    };
    for i in 0..t.len() {
        if t[i].is_ident("Instant")
            && t.get(i + 1).is_some_and(|x| x.is_punct("::"))
            && t.get(i + 2).is_some_and(|x| x.is_ident("now"))
        {
            out.push(finding(&t[i], "`Instant::now`"));
        }
        if t[i].is_ident("thread")
            && t.get(i + 1).is_some_and(|x| x.is_punct("::"))
            && t.get(i + 2).is_some_and(|x| x.is_ident("sleep"))
        {
            out.push(finding(&t[i], "`thread::sleep`"));
        }
        if t[i].is_ident("SystemTime") {
            out.push(finding(&t[i], "`SystemTime`"));
        }
    }
}

// ---------------------------------------------------------------- DL002

const DL002_DIRS: &[&str] = &[
    "src/sim/",
    "src/platform/",
    "src/fnplat/",
    "src/policy/",
    "src/metrics/",
    "src/experiments/",
    "src/image/",
    "src/lambda/",
];

const ITER_METHODS: &[&str] =
    &["iter", "iter_mut", "into_iter", "keys", "values", "values_mut", "drain", "retain"];

/// DL002: iteration over `HashMap`/`HashSet` in the deterministic core.
/// Std hash iteration order is per-instance random (`RandomState`); one
/// such loop in a merge or encode path breaks byte-identity silently.
/// Tracks every identifier *declared* with a hash-table type in this
/// file (field, `let`, or parameter) and flags iterator-method calls and
/// `for … in` loops over them.  Keyed access never matches.
fn rule_hash_iteration(path: &str, t: &[Tok], out: &mut Vec<Finding>) {
    if !DL002_DIRS.iter().any(|d| path.starts_with(d)) {
        return;
    }
    // Pass 1: names bound to HashMap/HashSet.
    let mut tracked: BTreeSet<&str> = BTreeSet::new();
    for i in 0..t.len() {
        if !(t[i].is_ident("HashMap") || t[i].is_ident("HashSet")) {
            continue;
        }
        // Walk back over the `std::collections::` path prefix …
        let mut j = i;
        while j >= 2 && t[j - 1].is_punct("::") && t[j - 2].kind == TokKind::Ident {
            j -= 2;
        }
        if j == 0 {
            continue;
        }
        // … and over `&`, `mut`, lifetimes in the type position.
        let mut p = j - 1;
        while p > 0
            && (t[p].is_punct("&") || t[p].is_ident("mut") || t[p].kind == TokKind::Life)
        {
            p -= 1;
        }
        // `name: HashMap<..>` (field / let / param) or `name = HashMap::new()`.
        if (t[p].is_punct(":") || t[p].is_punct("=")) && p >= 1 && t[p - 1].kind == TokKind::Ident
        {
            tracked.insert(&t[p - 1].text);
        }
    }
    if tracked.is_empty() {
        return;
    }
    // Pass 2: iteration over a tracked name.
    for i in 0..t.len() {
        // `name.iter()` / `self.name.keys()` / …
        if t[i].kind == TokKind::Ident
            && ITER_METHODS.contains(&t[i].text.as_str())
            && t.get(i + 1).is_some_and(|x| x.is_punct("("))
            && i >= 2
            && t[i - 1].is_punct(".")
            && t[i - 2].kind == TokKind::Ident
            && tracked.contains(t[i - 2].text.as_str())
        {
            out.push(Finding {
                code: "DL002",
                file: path.to_string(),
                line: t[i].line,
                msg: format!(
                    "`.{}()` on hash-table `{}` — iteration order is nondeterministic; \
                     use BTreeMap/BTreeSet or collect + sort",
                    t[i].text,
                    t[i - 2].text
                ),
            });
        }
        // `for x in &name` / `for x in name` (not followed by `.`: the
        // method form above already covers chained calls).
        if t[i].is_ident("in") && in_for_header(t, i) {
            let mut k = i + 1;
            while t.get(k).is_some_and(|x| x.is_punct("&") || x.is_ident("mut")) {
                k += 1;
            }
            if t.get(k).is_some_and(|x| x.is_ident("self"))
                && t.get(k + 1).is_some_and(|x| x.is_punct("."))
            {
                k += 2;
            }
            if let Some(name) = t.get(k) {
                if name.kind == TokKind::Ident
                    && tracked.contains(name.text.as_str())
                    && !t.get(k + 1).is_some_and(|x| x.is_punct("."))
                {
                    out.push(Finding {
                        code: "DL002",
                        file: path.to_string(),
                        line: name.line,
                        msg: format!(
                            "`for … in` over hash-table `{}` — iteration order is \
                             nondeterministic; use BTreeMap/BTreeSet or collect + sort",
                            name.text
                        ),
                    });
                }
            }
        }
    }
}

/// Is the `in` at `t[i]` part of a `for` loop header?  (Walk back to the
/// nearest `for`, stopping at statement/block boundaries.)
fn in_for_header(t: &[Tok], i: usize) -> bool {
    let mut j = i;
    while j > 0 {
        j -= 1;
        if t[j].is_ident("for") {
            return true;
        }
        if t[j].is_punct(";") || t[j].is_punct("{") || t[j].is_punct("}") {
            return false;
        }
    }
    false
}

// ---------------------------------------------------------------- DL003

/// DL003: `parse().unwrap_or(..)` / `parse().unwrap_or_default()` —
/// malformed input silently becomes a default instead of an error (the
/// bug class the strict `cli.rs` getters exist to remove).  Turbofish
/// (`parse::<u64>()`) is handled.
fn rule_lenient_parse(path: &str, t: &[Tok], out: &mut Vec<Finding>) {
    for i in 0..t.len() {
        if !t[i].is_ident("parse") {
            continue;
        }
        let mut j = i + 1;
        if t.get(j).is_some_and(|x| x.is_punct("::"))
            && t.get(j + 1).is_some_and(|x| x.is_punct("<"))
        {
            let mut depth = 1;
            j += 2;
            while depth > 0 && j < t.len() {
                if t[j].is_punct("<") {
                    depth += 1;
                } else if t[j].is_punct(">") {
                    depth -= 1;
                }
                j += 1;
            }
        }
        if !(t.get(j).is_some_and(|x| x.is_punct("("))
            && t.get(j + 1).is_some_and(|x| x.is_punct(")"))
            && t.get(j + 2).is_some_and(|x| x.is_punct(".")))
        {
            continue;
        }
        if let Some(m) = t.get(j + 3) {
            if m.is_ident("unwrap_or") || m.is_ident("unwrap_or_default") {
                out.push(Finding {
                    code: "DL003",
                    file: path.to_string(),
                    line: t[i].line,
                    msg: format!(
                        "`parse().{}(..)` swallows malformed input — propagate the \
                         error (`?`, `map_err`) or reject explicitly",
                        m.text
                    ),
                });
            }
        }
    }
}

// ---------------------------------------------------------------- DL004

/// DL004: `debug_assert!` whose argument mutates state — the assert
/// compiles out in release builds, so debug and release runs diverge
/// (the determinism bug that never reproduces in CI).
fn rule_mutating_debug_assert(path: &str, t: &[Tok], out: &mut Vec<Finding>) {
    for i in 0..t.len() {
        let name = &t[i];
        if !(name.is_ident("debug_assert")
            || name.is_ident("debug_assert_eq")
            || name.is_ident("debug_assert_ne"))
        {
            continue;
        }
        if !(t.get(i + 1).is_some_and(|x| x.is_punct("!"))
            && t.get(i + 2).is_some_and(|x| x.is_punct("(")))
        {
            continue;
        }
        let mut depth = 1;
        let mut j = i + 3;
        while depth > 0 && j < t.len() {
            if t[j].is_punct("(") {
                depth += 1;
            } else if t[j].is_punct(")") {
                depth -= 1;
                if depth == 0 {
                    break;
                }
            }
            let mutating = t[j].is_punct("+=")
                || (t[j].is_punct(".")
                    && t.get(j + 1).is_some_and(|x| {
                        x.is_ident("push") || x.is_ident("insert") || x.is_ident("pop")
                    })
                    && t.get(j + 2).is_some_and(|x| x.is_punct("(")));
            if mutating {
                out.push(Finding {
                    code: "DL004",
                    file: path.to_string(),
                    line: name.line,
                    msg: format!(
                        "`{}!` argument mutates state — it compiles out in release \
                         builds; hoist the mutation out of the assert",
                        name.text
                    ),
                });
                break;
            }
            j += 1;
        }
    }
}

// ---------------------------------------------------------------- DL005

/// DL005: snapshot-codec completeness.  A *codec fn* is any fn inside an
/// `impl Type` block whose parameter list mentions `Enc` or `Dec` (this
/// uniformly catches `encode_state`/`restore_state`, `encode`/`decode`,
/// `encode_canonical`/`encode_layout`, …).  For every struct defined in
/// the same file as ≥1 of its codec fns, every named field must appear
/// as an identifier in at least one codec body — a field added to the
/// struct but not to the codec is exactly the drift that corrupts a
/// `CFAASCK1` resume invisibly.  Deliberately unencoded fields (config-
/// derived, rebuilt on attach) carry a justified
/// `// detlint: allow(DL005)` on their line.
/// `(field name, declaration line)` pairs of one struct.
type Fields = Vec<(String, u32)>;

fn rule_codec_completeness(path: &str, t: &[Tok], out: &mut Vec<Finding>) {
    // Pass 1: struct definitions → (name, [(field, line)]).
    let mut structs: Vec<(String, Fields)> = Vec::new();
    let mut i = 0;
    while i < t.len() {
        if t[i].is_ident("struct") && t.get(i + 1).map(|x| x.kind) == Some(TokKind::Ident) {
            let name = t[i + 1].text.clone();
            let mut j = i + 2;
            // Skip generics / where clause up to the body (or `;` / `(`
            // for unit and tuple structs, which have no named fields).
            let mut angle = 0i32;
            while j < t.len() {
                if t[j].is_punct("<") {
                    angle += 1;
                } else if t[j].is_punct(">") {
                    angle -= 1;
                } else if angle == 0
                    && (t[j].is_punct("{") || t[j].is_punct(";") || t[j].is_punct("("))
                {
                    break;
                }
                j += 1;
            }
            if t.get(j).is_some_and(|x| x.is_punct("{")) {
                if let Some((fields, end)) = parse_fields(t, j + 1) {
                    structs.push((name, fields));
                    i = end;
                    continue;
                }
            }
        }
        i += 1;
    }
    if structs.is_empty() {
        return;
    }
    // Pass 2: impl blocks → union of identifiers in codec-fn bodies,
    // per target type.
    let mut codec_ids: Vec<(String, BTreeSet<String>)> = Vec::new();
    let mut i = 0;
    while i < t.len() {
        if !(t[i].is_ident("impl") && impl_is_item(t, i)) {
            i += 1;
            continue;
        }
        let mut j = i + 1;
        if t.get(j).is_some_and(|x| x.is_punct("<")) {
            j = skip_angles(t, j);
        }
        let first = read_type_path(t, &mut j);
        let target = if t.get(j).is_some_and(|x| x.is_ident("for")) {
            j += 1;
            while t.get(j).is_some_and(|x| x.is_punct("&") || x.kind == TokKind::Life) {
                j += 1;
            }
            read_type_path(t, &mut j)
        } else {
            first
        };
        // Skip any where clause, find the body.
        while j < t.len() && !t[j].is_punct("{") {
            j += 1;
        }
        let Some(target) = target else {
            i = j + 1;
            continue;
        };
        let body_end = skip_balanced(t, j, "{", "}");
        let mut k = j + 1;
        let mut ids = BTreeSet::new();
        let mut any_codec = false;
        while k < body_end {
            if !t[k].is_ident("fn") {
                k += 1;
                continue;
            }
            let mut p = k + 2; // past `fn name`
            if t.get(p).is_some_and(|x| x.is_punct("<")) {
                p = skip_angles(t, p);
            }
            if !t.get(p).is_some_and(|x| x.is_punct("(")) {
                k = p;
                continue;
            }
            let params_end = skip_balanced(t, p, "(", ")");
            let is_codec = t[p..params_end]
                .iter()
                .any(|x| x.is_ident("Enc") || x.is_ident("Dec"));
            let mut b = params_end;
            while b < body_end && !t[b].is_punct("{") && !t[b].is_punct(";") {
                b += 1;
            }
            if t.get(b).is_some_and(|x| x.is_punct("{")) {
                let fn_end = skip_balanced(t, b, "{", "}");
                if is_codec {
                    any_codec = true;
                    for x in &t[b..fn_end] {
                        if x.kind == TokKind::Ident {
                            ids.insert(x.text.clone());
                        }
                    }
                }
                k = fn_end + 1;
            } else {
                k = b + 1;
            }
        }
        if any_codec {
            match codec_ids.iter_mut().find(|(n, _)| *n == target) {
                Some((_, set)) => set.append(&mut ids),
                None => codec_ids.push((target, ids)),
            }
        }
        i = body_end + 1;
    }
    // Pass 3: cross-reference.
    for (name, fields) in &structs {
        let Some((_, ids)) = codec_ids.iter().find(|(n, _)| n == name) else { continue };
        for (field, line) in fields {
            if !ids.contains(field) {
                out.push(Finding {
                    code: "DL005",
                    file: path.to_string(),
                    line: *line,
                    msg: format!(
                        "field `{field}` of snapshotted struct `{name}` appears in no \
                         Enc/Dec codec fn — encode it, or justify why resume can \
                         rebuild it"
                    ),
                });
            }
        }
    }
}

/// Named fields of a struct body starting just past `{`; returns the
/// fields and the index of the closing `}`.  `None` on anything the
/// walker does not understand (bail without findings rather than
/// misattribute).
fn parse_fields(t: &[Tok], mut i: usize) -> Option<(Fields, usize)> {
    let mut fields = Vec::new();
    loop {
        while t.get(i).is_some_and(|x| x.is_punct("#")) {
            i = skip_balanced(t, i + 1, "[", "]") + 1;
        }
        if t.get(i).is_some_and(|x| x.is_punct("}")) {
            return Some((fields, i));
        }
        if t.get(i).is_some_and(|x| x.is_ident("pub")) {
            i += 1;
            if t.get(i).is_some_and(|x| x.is_punct("(")) {
                i = skip_balanced(t, i, "(", ")") + 1;
            }
        }
        let name = t.get(i)?;
        if name.kind != TokKind::Ident || !t.get(i + 1).is_some_and(|x| x.is_punct(":")) {
            return None;
        }
        fields.push((name.text.clone(), name.line));
        // Skip the type up to the field separator.
        let (mut angle, mut paren, mut brack) = (0i32, 0i32, 0i32);
        i += 2;
        loop {
            let x = t.get(i)?;
            if x.is_punct("<") {
                angle += 1;
            } else if x.is_punct(">") {
                angle -= 1;
            } else if x.is_punct("(") {
                paren += 1;
            } else if x.is_punct(")") {
                paren -= 1;
            } else if x.is_punct("[") {
                brack += 1;
            } else if x.is_punct("]") {
                brack -= 1;
            } else if angle == 0 && paren == 0 && brack == 0 {
                if x.is_punct(",") {
                    i += 1;
                    break;
                }
                if x.is_punct("}") {
                    return Some((fields, i));
                }
            }
            i += 1;
        }
    }
}

/// Is the `impl` at `t[i]` an item (an impl block), not an `impl Trait`
/// type position (`-> impl Fn()`, `x: impl Into<..>`)?
fn impl_is_item(t: &[Tok], i: usize) -> bool {
    match i.checked_sub(1).and_then(|p| t.get(p)) {
        None => true,
        Some(prev) => {
            prev.is_punct("}") || prev.is_punct("{") || prev.is_punct(";") || prev.is_punct("]")
        }
    }
}

/// Last identifier of a `path::To::Type<..>` at `*j`; advances past it.
fn read_type_path(t: &[Tok], j: &mut usize) -> Option<String> {
    let mut last = None;
    while let Some(x) = t.get(*j) {
        if x.kind == TokKind::Ident && !x.is_ident("for") {
            last = Some(x.text.clone());
            *j += 1;
            if t.get(*j).is_some_and(|p| p.is_punct("::")) {
                *j += 1;
                continue;
            }
            if t.get(*j).is_some_and(|p| p.is_punct("<")) {
                *j = skip_angles(t, *j);
            }
            break;
        }
        break;
    }
    last
}

/// Skip a balanced `<...>` starting at the `<` at `i`; returns the index
/// just past the matching `>`.
fn skip_angles(t: &[Tok], i: usize) -> usize {
    let mut depth = 0i32;
    let mut j = i;
    while j < t.len() {
        if t[j].is_punct("<") {
            depth += 1;
        } else if t[j].is_punct(">") {
            depth -= 1;
            if depth == 0 {
                return j + 1;
            }
        }
        j += 1;
    }
    j
}

/// Index of the token closing the balanced `open`/`close` pair whose
/// opener sits at `i` (returns `t.len()` if unbalanced).
fn skip_balanced(t: &[Tok], i: usize, open: &str, close: &str) -> usize {
    let mut depth = 0i32;
    let mut j = i;
    while j < t.len() {
        if t[j].is_punct(open) {
            depth += 1;
        } else if t[j].is_punct(close) {
            depth -= 1;
            if depth == 0 {
                return j;
            }
        }
        j += 1;
    }
    j
}
