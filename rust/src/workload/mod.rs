//! Load generation (S12): the paper's `hey`-style closed-loop benchmark in
//! virtual time, plus the measurement-rig composition of §III-B — a
//! CppCMS-like gateway (multi-process accept + 20 worker threads) in front
//! of whichever startup technology is being measured.

pub mod tenants;
pub mod traces;

use crate::metrics::Recorder;
use crate::sim::{Dist, Domain, Engine, Host, ReqId, Spawn, Step};

/// §III-B: CppCMS gateway worker threads.
pub const GATEWAY_WORKERS: u32 = 20;
/// §III-E: /noop gateway overhead ≈ 0.7 ms at low load.  The worker-thread
/// hold time is the bottleneck constant: 20 workers × 0.55 ms caps the
/// gateway at ~36 k rps, which is what makes /noop grow past 20 parallel.
pub const GATEWAY_WORKER_MS: f64 = 0.55;
pub const GATEWAY_CPU_MS: f64 = 0.15;
/// Dedicated 40 Gbps lab link: sub-ms RTT between load generator and host.
pub const LAB_RTT_MS: f64 = 0.15;

/// Closed-loop domain: keeps `parallelism` requests in flight until
/// `total` have completed, recording each latency under a label.
struct HeyDomain {
    template: Vec<Step>,
    remaining: u64,
    latencies_ns: Vec<u64>,
}

impl Domain for HeyDomain {
    fn done(&mut self, _req: ReqId, class: u32, start: u64, now: u64) -> Vec<Spawn> {
        self.latencies_ns.push(now - start);
        if self.remaining > 0 {
            self.remaining -= 1;
            vec![Spawn { delay_ns: 0, class, steps: self.template.clone() }]
        } else {
            Vec::new()
        }
    }
}

/// Result of one closed-loop run.
pub struct RunResult {
    pub latencies_ns: Vec<u64>,
    /// Virtual makespan of the whole run.
    pub elapsed_ns: u64,
    /// Completed requests per second of virtual time.
    pub throughput_rps: f64,
}

/// Run `total` requests of `pipeline` with `parallelism` in flight on
/// `host`.  Mirrors `hey -n total -c parallelism`.
pub fn run_closed_loop(
    pipeline: Vec<Step>,
    parallelism: u32,
    total: u64,
    host: Host,
    seed: u64,
) -> RunResult {
    assert!(parallelism as u64 <= total, "parallelism exceeds total requests");
    let domain = HeyDomain {
        template: pipeline.clone(),
        remaining: total - parallelism as u64,
        latencies_ns: Vec::with_capacity(total as usize),
    };
    let mut e = Engine::new(domain, host, seed);
    for _ in 0..parallelism {
        e.spawn_at(0, 0, pipeline.clone());
    }
    // Generous backstop: ~32 events per request covers the longest pipeline.
    e.run(total.saturating_mul(64).max(1 << 20));
    let elapsed_ns = e.now();
    let n = e.domain.latencies_ns.len() as f64;
    RunResult {
        latencies_ns: std::mem::take(&mut e.domain.latencies_ns),
        elapsed_ns,
        throughput_rps: if elapsed_ns == 0 { 0.0 } else { n / (elapsed_ns as f64 / 1e9) },
    }
}

/// The §III-B measurement pipeline: lab RTT + gateway (worker pool + CPU)
/// wrapped around the startup phases under test.  `pool_id` must come from
/// the same engine the pipeline will run on, so this variant takes the
/// engine and seeds it directly.
pub fn run_gateway_front(
    startup: Vec<Step>,
    parallelism: u32,
    total: u64,
    host: Host,
    seed: u64,
) -> RunResult {
    assert!(parallelism as u64 <= total);
    let domain = HeyDomain {
        template: Vec::new(), // filled below once the pool id exists
        remaining: total - parallelism as u64,
        latencies_ns: Vec::with_capacity(total as usize),
    };
    let mut e = Engine::new(domain, host, seed);
    let gw = e.add_pool(GATEWAY_WORKERS);
    let mut pipeline = vec![
        Step::delay("net-rtt", Dist::ms(LAB_RTT_MS, 0.10)),
        Step::pool("gateway-worker", gw, Dist::ms(GATEWAY_WORKER_MS, 0.20)),
        Step::cpu("gateway-dispatch", Dist::ms(GATEWAY_CPU_MS, 0.20)),
    ];
    pipeline.extend(startup);
    e.domain.template = pipeline.clone();
    for _ in 0..parallelism {
        e.spawn_at(0, 0, pipeline.clone());
    }
    e.run(total.saturating_mul(64).max(1 << 20));
    let elapsed_ns = e.now();
    let n = e.domain.latencies_ns.len() as f64;
    RunResult {
        latencies_ns: std::mem::take(&mut e.domain.latencies_ns),
        elapsed_ns,
        throughput_rps: if elapsed_ns == 0 { 0.0 } else { n / (elapsed_ns as f64 / 1e9) },
    }
}

/// Record a run's latencies into a recorder under `label`.
pub fn record(rec: &mut Recorder, label: &str, result: &RunResult) {
    for &ns in &result.latencies_ns {
        rec.record_ns(label, ns);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::Dist;

    fn const_pipeline(ms: f64) -> Vec<Step> {
        vec![Step::delay("d", Dist::const_ms(ms))]
    }

    #[test]
    fn completes_exactly_total() {
        let r = run_closed_loop(const_pipeline(1.0), 4, 100, Host::default(), 1);
        assert_eq!(r.latencies_ns.len(), 100);
    }

    #[test]
    fn throughput_scales_with_parallelism_for_delay() {
        // Pure-delay pipeline: no contention, so X = parallelism / latency.
        let r1 = run_closed_loop(const_pipeline(10.0), 1, 200, Host::default(), 1);
        let r4 = run_closed_loop(const_pipeline(10.0), 4, 200, Host::default(), 1);
        assert!((r1.throughput_rps - 100.0).abs() < 2.0, "{}", r1.throughput_rps);
        assert!((r4.throughput_rps - 400.0).abs() < 10.0, "{}", r4.throughput_rps);
    }

    #[test]
    fn parallelism_must_not_exceed_total() {
        let result = std::panic::catch_unwind(|| {
            run_closed_loop(const_pipeline(1.0), 10, 5, Host::default(), 1)
        });
        assert!(result.is_err());
    }

    #[test]
    fn deterministic_given_seed() {
        let a = run_closed_loop(const_pipeline(2.0), 2, 50, Host::default(), 7);
        let b = run_closed_loop(const_pipeline(2.0), 2, 50, Host::default(), 7);
        assert_eq!(a.latencies_ns, b.latencies_ns);
    }

    #[test]
    fn gateway_noop_overhead_near_paper() {
        // §III-E: /noop ≈ 0.7 ms at low load, grows considerably > 20 parallel.
        let low = run_gateway_front(Vec::new(), 5, 2000, Host::default(), 3);
        let mut rec = Recorder::new();
        record(&mut rec, "noop", &low);
        let p50 = rec.quantile("noop", 0.5).unwrap();
        assert!((0.5..1.2).contains(&p50), "noop p50 {p50} ms");

        let over = run_gateway_front(Vec::new(), 40, 2000, Host::default(), 3);
        let mut rec40 = Recorder::new();
        record(&mut rec40, "noop", &over);
        let p50_40 = rec40.quantile("noop", 0.5).unwrap();
        assert!(p50_40 > 1.2 * p50, "overload should inflate noop: {p50_40} vs {p50}");
    }
}
