//! Multi-tenant arrival traces (S18): thousands of functions with
//! Zipf-distributed popularity, diurnal load swings, and per-function
//! burstiness — the workload shape production FaaS platforms actually
//! schedule, layered on the same deterministic primitives as
//! [`super::traces`].
//!
//! Azure-trace-style structure, synthesized: a few head functions carry
//! most of the traffic (Zipf), mid-tail functions arrive every few
//! seconds to minutes, and the long tail is invoked rarely enough that
//! any fixed keep-alive window is pure waste.  Experiment E12 replays
//! these traces through the lifecycle-policy lab.

use crate::sim::Rng;

/// Configuration for a multi-tenant trace.
#[derive(Clone, Debug)]
pub struct TenantConfig {
    /// Number of distinct functions (tenants), N >= 1.
    pub functions: u32,
    /// Trace horizon in (virtual) seconds.
    pub duration_s: f64,
    /// Aggregate mean arrival rate across all functions (req/s).
    pub total_rps: f64,
    /// Zipf popularity exponent (~1.1 matches measured FaaS skew).
    pub zipf_exponent: f64,
    /// Diurnal modulation depth in [0, 1): per-function rate swings by
    /// `±depth` over one virtual day.
    pub diurnal_depth: f64,
    /// Virtual day length in seconds (compressed for simulation).
    pub diurnal_period_s: f64,
    /// Fraction of functions with on/off bursty arrivals instead of
    /// (modulated) Poisson.
    pub bursty_fraction: f64,
    pub seed: u64,
}

impl Default for TenantConfig {
    fn default() -> Self {
        TenantConfig {
            functions: 1000,
            duration_s: 300.0,
            total_rps: 200.0,
            zipf_exponent: 1.1,
            diurnal_depth: 0.6,
            diurnal_period_s: 240.0,
            bursty_fraction: 0.2,
            seed: 0xE12,
        }
    }
}

/// A generated multi-tenant trace: `(arrival_ns, function_id)` pairs
/// sorted by time.
#[derive(Clone, Debug)]
pub struct TenantTrace {
    pub functions: u32,
    pub arrivals: Vec<(u64, u32)>,
}

/// Normalized Zipf weights over `n` ranks with exponent `s`.
pub fn zipf_weights(n: u32, s: f64) -> Vec<f64> {
    let raw: Vec<f64> = (1..=n as u64).map(|i| (i as f64).powf(-s)).collect();
    let z: f64 = raw.iter().sum();
    raw.into_iter().map(|w| w / z).collect()
}

impl TenantTrace {
    /// Generate a trace deterministically from `cfg.seed`.  Each function
    /// draws from its own forked RNG stream, so the result is independent
    /// of generation order and stable across refactors.
    pub fn generate(cfg: &TenantConfig) -> TenantTrace {
        assert!(cfg.functions >= 1, "need at least one function");
        assert!(cfg.total_rps > 0.0 && cfg.duration_s > 0.0);
        assert!((0.0..1.0).contains(&cfg.diurnal_depth));
        let weights = zipf_weights(cfg.functions, cfg.zipf_exponent);
        let horizon_ns = cfg.duration_s * 1e9;
        // Every k-th function is bursty (deterministic assignment);
        // fraction 0 disables burstiness entirely.
        let bursty_every = if cfg.bursty_fraction <= 0.0 {
            0
        } else {
            ((1.0 / cfg.bursty_fraction).round() as u32).max(1)
        };

        let mut arrivals: Vec<(u64, u32)> = Vec::new();
        for func in 0..cfg.functions {
            let rate = cfg.total_rps * weights[func as usize];
            if rate <= 0.0 {
                continue;
            }
            let mut rng =
                Rng::new(cfg.seed ^ (func as u64 + 1).wrapping_mul(0x9E3779B97F4A7C15));
            if bursty_every > 0 && func % bursty_every == 0 {
                Self::gen_bursty(func, rate, horizon_ns, &mut rng, &mut arrivals);
            } else {
                Self::gen_diurnal_poisson(func, rate, cfg, horizon_ns, &mut rng, &mut arrivals);
            }
        }
        arrivals.sort_unstable();
        TenantTrace { functions: cfg.functions, arrivals }
    }

    /// Nonhomogeneous Poisson via thinning: candidate arrivals at the peak
    /// rate, accepted with probability rate(t)/peak — preserves the mean
    /// rate while the instantaneous rate follows the diurnal curve.
    fn gen_diurnal_poisson(
        func: u32,
        rate: f64,
        cfg: &TenantConfig,
        horizon_ns: f64,
        rng: &mut Rng,
        out: &mut Vec<(u64, u32)>,
    ) {
        let peak = rate * (1.0 + cfg.diurnal_depth);
        let mean_gap = 1e9 / peak;
        // Per-function phase: tenants live in different timezones.
        let phase = rng.next_f64() * std::f64::consts::TAU;
        let omega = std::f64::consts::TAU / (cfg.diurnal_period_s * 1e9);
        let mut t = 0.0f64;
        loop {
            t += rng.exponential(mean_gap);
            if t >= horizon_ns {
                break;
            }
            let inst = rate * (1.0 + cfg.diurnal_depth * (omega * t + phase).sin());
            if rng.next_f64() * peak < inst {
                out.push((t as u64, func));
            }
        }
    }

    /// On/off bursts preserving the requested mean rate: Poisson at an
    /// elevated in-burst rate during on-periods, silence during off-periods.
    fn gen_bursty(
        func: u32,
        rate: f64,
        horizon_ns: f64,
        rng: &mut Rng,
        out: &mut Vec<(u64, u32)>,
    ) {
        let on_mean_ns = 3.0e9;
        let off_mean_ns = 27.0e9;
        let duty = on_mean_ns / (on_mean_ns + off_mean_ns);
        let burst_rate = rate / duty;
        let mean_gap = 1e9 / burst_rate;
        let mut t = 0.0f64;
        loop {
            let on_end = (t + rng.exponential(on_mean_ns)).min(horizon_ns);
            let mut a = t;
            loop {
                a += rng.exponential(mean_gap);
                if a >= on_end {
                    break;
                }
                out.push((a as u64, func));
            }
            t = on_end + rng.exponential(off_mean_ns);
            if t >= horizon_ns {
                break;
            }
        }
    }

    pub fn len(&self) -> usize {
        self.arrivals.len()
    }

    pub fn is_empty(&self) -> bool {
        self.arrivals.is_empty()
    }

    /// Mean aggregate arrival rate over the trace span (req/s).
    pub fn mean_rate_rps(&self) -> f64 {
        if self.arrivals.len() < 2 {
            return 0.0;
        }
        let span = (self.arrivals.last().unwrap().0 - self.arrivals[0].0) as f64 / 1e9;
        if span == 0.0 { 0.0 } else { (self.arrivals.len() - 1) as f64 / span }
    }

    /// Invocation count per function id.
    pub fn per_function_counts(&self) -> Vec<u64> {
        let mut counts = vec![0u64; self.functions as usize];
        for &(_, f) in &self.arrivals {
            counts[f as usize] += 1;
        }
        counts
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> TenantConfig {
        TenantConfig {
            functions: 200,
            duration_s: 120.0,
            total_rps: 60.0,
            // Whole diurnal periods and no bursts: the thinning mean is
            // phase-independent, so the rate check below is tight.
            diurnal_period_s: 60.0,
            bursty_fraction: 0.0,
            ..Default::default()
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let a = TenantTrace::generate(&small());
        let b = TenantTrace::generate(&small());
        assert_eq!(a.arrivals, b.arrivals);
        let c = TenantTrace::generate(&TenantConfig { seed: 7, ..small() });
        assert_ne!(a.arrivals, c.arrivals);
    }

    #[test]
    fn sorted_and_bounded() {
        let t = TenantTrace::generate(&small());
        assert!(t.arrivals.windows(2).all(|w| w[0] <= w[1]));
        let horizon = (small().duration_s * 1e9) as u64;
        assert!(t.arrivals.iter().all(|&(at, f)| at < horizon && f < 200));
    }

    #[test]
    fn aggregate_rate_near_target() {
        let cfg = small();
        let t = TenantTrace::generate(&cfg);
        let want = cfg.total_rps * cfg.duration_s;
        let got = t.len() as f64;
        assert!(
            (got / want - 1.0).abs() < 0.2,
            "arrivals {got} vs expected {want}"
        );
    }

    #[test]
    fn zipf_mass_ordering() {
        let t = TenantTrace::generate(&small());
        let counts = t.per_function_counts();
        // Head decile must far outweigh the tail half.
        let head: u64 = counts[..20].iter().sum();
        let tail: u64 = counts[100..].iter().sum();
        assert!(head > 3 * tail.max(1), "head {head} vs tail {tail}");
        // Rank-1 is the single most invoked function (statistically safe
        // at this rate split: rank-1 carries ~18% of all traffic).
        let max = *counts.iter().max().unwrap();
        assert_eq!(counts[0], max, "rank-1 must dominate: {:?}", &counts[..5]);
    }

    #[test]
    fn zipf_weights_normalized_and_decreasing() {
        let w = zipf_weights(1000, 1.1);
        assert!((w.iter().sum::<f64>() - 1.0).abs() < 1e-9);
        assert!(w.windows(2).all(|p| p[0] > p[1]));
    }

    #[test]
    fn bursty_functions_have_long_gaps() {
        let cfg = TenantConfig {
            functions: 10,
            duration_s: 300.0,
            total_rps: 50.0,
            bursty_fraction: 0.1, // exactly function 0
            ..Default::default()
        };
        let t = TenantTrace::generate(&cfg);
        let f0: Vec<u64> =
            t.arrivals.iter().filter(|&&(_, f)| f == 0).map(|&(at, _)| at).collect();
        assert!(f0.len() > 50, "head function must fire: {}", f0.len());
        // Off-periods (mean 27 s) dwarf the in-burst gaps.
        let max_gap = f0.windows(2).map(|w| w[1] - w[0]).max().unwrap();
        assert!(max_gap > 5_000_000_000, "max gap {max_gap} ns");
    }

    #[test]
    fn scales_to_production_function_counts() {
        let cfg = TenantConfig {
            functions: 2000,
            duration_s: 60.0,
            total_rps: 300.0,
            ..Default::default()
        };
        let t = TenantTrace::generate(&cfg);
        assert!(t.len() > 10_000);
        let nonzero = t.per_function_counts().iter().filter(|&&c| c > 0).count();
        assert!(nonzero > 200, "tail must be populated: {nonzero}");
    }
}
