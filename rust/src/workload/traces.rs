//! Open-loop arrival traces for the resource-waste experiment (E9):
//! Poisson and bursty (on/off) request processes, generated deterministically.

use crate::sim::Rng;

/// An arrival trace: absolute request times in nanoseconds, sorted.
#[derive(Clone, Debug)]
pub struct Trace {
    pub arrivals_ns: Vec<u64>,
}

impl Trace {
    /// Poisson arrivals at `rate_rps` for `duration_s` seconds.
    pub fn poisson(rate_rps: f64, duration_s: f64, seed: u64) -> Trace {
        assert!(rate_rps > 0.0);
        let mut rng = Rng::new(seed);
        let mut t = 0.0f64;
        let horizon = duration_s * 1e9;
        let mean_gap = 1e9 / rate_rps;
        let mut arrivals = Vec::new();
        loop {
            t += rng.exponential(mean_gap);
            if t >= horizon {
                break;
            }
            arrivals.push(t as u64);
        }
        Trace { arrivals_ns: arrivals }
    }

    /// Bursty on/off trace: Poisson at `burst_rps` during on-periods,
    /// silent during off-periods (both exponentially distributed).
    pub fn bursty(
        burst_rps: f64,
        on_mean_s: f64,
        off_mean_s: f64,
        duration_s: f64,
        seed: u64,
    ) -> Trace {
        let mut rng = Rng::new(seed);
        let horizon = duration_s * 1e9;
        let mut arrivals = Vec::new();
        let mut t = 0.0f64;
        loop {
            // On period.
            let on_end = t + rng.exponential(on_mean_s * 1e9);
            let mean_gap = 1e9 / burst_rps;
            let mut a = t;
            loop {
                a += rng.exponential(mean_gap);
                if a >= on_end || a >= horizon {
                    break;
                }
                arrivals.push(a as u64);
            }
            t = on_end + rng.exponential(off_mean_s * 1e9);
            if t >= horizon {
                break;
            }
        }
        arrivals.sort_unstable();
        Trace { arrivals_ns: arrivals }
    }

    pub fn len(&self) -> usize {
        self.arrivals_ns.len()
    }

    pub fn is_empty(&self) -> bool {
        self.arrivals_ns.is_empty()
    }

    /// Mean arrival rate over the trace span (requests/second).
    pub fn mean_rate_rps(&self) -> f64 {
        if self.arrivals_ns.len() < 2 {
            return 0.0;
        }
        let span = (*self.arrivals_ns.last().unwrap() - self.arrivals_ns[0]) as f64 / 1e9;
        if span == 0.0 { 0.0 } else { (self.arrivals_ns.len() - 1) as f64 / span }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_rate_converges() {
        let t = Trace::poisson(100.0, 100.0, 1);
        let rate = t.mean_rate_rps();
        assert!((rate / 100.0 - 1.0).abs() < 0.05, "rate {rate}");
    }

    #[test]
    fn poisson_sorted_and_bounded() {
        let t = Trace::poisson(50.0, 10.0, 2);
        assert!(t.arrivals_ns.windows(2).all(|w| w[0] <= w[1]));
        assert!(*t.arrivals_ns.last().unwrap() < 10_000_000_000);
    }

    #[test]
    fn bursty_has_gaps() {
        let t = Trace::bursty(200.0, 1.0, 5.0, 120.0, 3);
        assert!(!t.is_empty());
        // There must exist inter-arrival gaps far above the in-burst mean
        // (5 ms): that's what makes the warm-pool idle-timeout tradeoff real.
        let max_gap = t
            .arrivals_ns
            .windows(2)
            .map(|w| w[1] - w[0])
            .max()
            .unwrap();
        assert!(max_gap > 1_000_000_000, "max gap {max_gap} ns");
    }

    #[test]
    fn deterministic() {
        assert_eq!(
            Trace::poisson(10.0, 5.0, 9).arrivals_ns,
            Trace::poisson(10.0, 5.0, 9).arrivals_ns
        );
        assert_eq!(
            Trace::bursty(50.0, 1.0, 4.0, 60.0, 9).arrivals_ns,
            Trace::bursty(50.0, 1.0, 4.0, 60.0, 9).arrivals_ns
        );
        assert_ne!(
            Trace::poisson(10.0, 5.0, 9).arrivals_ns,
            Trace::poisson(10.0, 5.0, 10).arrivals_ns
        );
    }

    #[test]
    fn bursty_duty_cycle_mean_rate() {
        // 100 rps in-burst, 2 s on / 8 s off => 20% duty => ~20 rps mean.
        let t = Trace::bursty(100.0, 2.0, 8.0, 1200.0, 11);
        let mean = t.len() as f64 / 1200.0;
        assert!((mean / 20.0 - 1.0).abs() < 0.25, "duty-cycle mean rate {mean}");
    }

    #[test]
    fn bursty_in_burst_rate_matches_burst_rps() {
        // Gaps inside a burst follow the in-burst rate: the median
        // inter-arrival must sit near 1/burst_rps, far below the mean
        // implied by the duty cycle.
        let t = Trace::bursty(200.0, 2.0, 20.0, 600.0, 12);
        let mut gaps: Vec<u64> =
            t.arrivals_ns.windows(2).map(|w| w[1] - w[0]).collect();
        gaps.sort_unstable();
        let median_gap = gaps[gaps.len() / 2] as f64;
        let in_burst_gap = 1e9 / 200.0;
        assert!(
            median_gap < 3.0 * in_burst_gap,
            "median gap {median_gap} ns vs in-burst {in_burst_gap} ns"
        );
    }
}
