//! Executor lifecycle policies (S17): the keep-alive policy lab.
//!
//! The paper argues that a cold-only unikernel platform can *delete* the
//! keep-alive machinery real FaaS platforms run.  This module makes that
//! claim measurable by implementing the machinery: a [`LifecyclePolicy`]
//! observes per-function invocation history and decides, every time an
//! executor goes idle, whether to retain it, tear it down, or tear it down
//! and pre-warm a fresh one ahead of the predicted next arrival.
//!
//! Four policies span the design space the literature actually occupies:
//!
//! * [`ColdOnlyPolicy`] — the paper: never retain anything;
//! * [`FixedKeepAlive`] — the commercial default (a fixed idle window,
//!   10 minutes on the big public clouds);
//! * [`HistogramPrewarm`] — the hybrid-histogram policy family (per-
//!   function inter-arrival histograms choosing a keep-alive window and a
//!   pre-warm point, à la Shahrad et al.'s production policy);
//! * [`EwmaPredictive`] — inter-arrival forecasting via an exponentially
//!   weighted moving average + variance, standing in for learned
//!   predictors (transformer/LSTM cold-start forecasters).
//!
//! A fifth family, [`UniversalPool`] (S23), drives *shared* runtime-keyed
//! pools — universal workers any function of the runtime may claim — and
//! only makes sense together with a shared
//! [`SharingMode`](crate::platform::SharingMode); experiment E16 sweeps it.
//!
//! Policies are pure observers/deciders: the pool mechanics stay in
//! [`crate::fnplat::pool::WarmPool`] (per-slot deadlines), and the DES
//! wiring that replays a multi-tenant trace through a policy lives in
//! [`sim`].  Experiment E12 ([`crate::experiments::policies`]) sweeps
//! policy x driver and reports the latency-vs-idle-waste frontier.

pub mod ewma;
pub mod histogram;
pub mod universal;

/// The DES wiring moved into the unified [`crate::platform`] layer; this
/// alias keeps the historical `policy::sim` paths working.
pub mod sim {
    pub use crate::platform::presets::{run_policy_scenario, PolicyResult, PolicyScenario};
}

pub use ewma::EwmaPredictive;
pub use histogram::HistogramPrewarm;
pub use sim::{run_policy_scenario, PolicyResult, PolicyScenario};
pub use universal::UniversalPool;

use crate::sim::snap::{Dec, Enc};

/// What to do with an executor that just went idle.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum IdleAction {
    /// Tear the executor down now (nothing stays resident).
    Retire,
    /// Keep the executor warm for `keep_ns` from now.
    KeepFor { keep_ns: u64 },
    /// Tear down now, then boot a fresh warm executor `delay_ns` from now
    /// and retain it for `keep_ns` once booted (predictive pre-warming:
    /// skip the idle gap, be warm just before the forecast arrival).
    PrewarmAfter { delay_ns: u64, keep_ns: u64 },
}

/// A per-function executor lifecycle policy.
///
/// Functions are dense `u32` ids (multi-tenant traces run thousands of
/// them); implementations size their state from `n_funcs` at construction.
pub trait LifecyclePolicy {
    /// Display name, including the parameters that matter (report labels).
    fn name(&self) -> String;

    /// Observe an invocation of `func` arriving at `now_ns`.
    fn on_invoke(&mut self, func: u32, now_ns: u64);

    /// An executor for `func` finished serving at `now_ns`: decide its
    /// fate.
    fn on_idle(&mut self, func: u32, now_ns: u64) -> IdleAction;

    /// Serialize mutable policy state for a checkpoint (S27).  Stateless
    /// policies — the default — write nothing; stateful ones must write
    /// every field their decisions read, in a canonical order.
    fn encode_state(&self, _w: &mut Enc) {}

    /// Restore state written by [`Self::encode_state`] into a freshly
    /// constructed policy of the same shape.
    fn restore_state(&mut self, _r: &mut Dec) {}
}

/// The paper's lifecycle: every executor exits on completion.  No state,
/// no monitoring, no waste — and every start is cold.
#[derive(Clone, Debug, Default)]
pub struct ColdOnlyPolicy;

impl LifecyclePolicy for ColdOnlyPolicy {
    fn name(&self) -> String {
        "cold-only".to_string()
    }

    fn on_invoke(&mut self, _func: u32, _now_ns: u64) {}

    fn on_idle(&mut self, _func: u32, _now_ns: u64) -> IdleAction {
        IdleAction::Retire
    }
}

/// The commercial default: retain every idle executor for a fixed window.
#[derive(Clone, Debug)]
pub struct FixedKeepAlive {
    pub keep_ns: u64,
}

impl FixedKeepAlive {
    /// The 10-minute window the large public platforms default to.
    pub const DEFAULT_KEEP_NS: u64 = 600 * 1_000_000_000;

    pub fn new(keep_ns: u64) -> FixedKeepAlive {
        FixedKeepAlive { keep_ns }
    }
}

impl Default for FixedKeepAlive {
    fn default() -> Self {
        FixedKeepAlive::new(Self::DEFAULT_KEEP_NS)
    }
}

impl LifecyclePolicy for FixedKeepAlive {
    fn name(&self) -> String {
        format!("fixed-{}s", self.keep_ns / 1_000_000_000)
    }

    fn on_invoke(&mut self, _func: u32, _now_ns: u64) {}

    fn on_idle(&mut self, _func: u32, _now_ns: u64) -> IdleAction {
        IdleAction::KeepFor { keep_ns: self.keep_ns }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const S: u64 = 1_000_000_000;

    #[test]
    fn cold_only_always_retires() {
        let mut p = ColdOnlyPolicy;
        for t in 0..100u64 {
            p.on_invoke(t as u32 % 7, t * S);
            assert_eq!(p.on_idle(t as u32 % 7, t * S), IdleAction::Retire);
        }
        assert_eq!(p.name(), "cold-only");
    }

    #[test]
    fn fixed_keeps_for_configured_window() {
        let mut p = FixedKeepAlive::new(30 * S);
        p.on_invoke(0, 0);
        assert_eq!(p.on_idle(0, S), IdleAction::KeepFor { keep_ns: 30 * S });
        assert_eq!(p.name(), "fixed-30s");
    }

    #[test]
    fn fixed_default_is_ten_minutes() {
        assert_eq!(FixedKeepAlive::default().keep_ns, 600 * S);
    }
}
