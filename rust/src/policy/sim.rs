//! DES wiring for the policy lab: replay a multi-tenant trace through a
//! [`LifecyclePolicy`] driving the per-slot-deadline
//! [`WarmPool`](crate::fnplat::pool::WarmPool), over either Fn driver.
//!
//! Request pipeline per arrival (same request-path model as
//! [`crate::fnplat::sim`], local lab): RTT -> gateway/agent/DB -> dispatch
//! decision -> warm-invoke or cold-start pipeline -> execution -> release
//! decision.  On release the policy picks Retire / KeepFor / PrewarmAfter;
//! pre-warms are injected back into virtual time as zero-latency control
//! requests whose only step is a pool effect at the scheduled boot time.

use crate::fnplat::pool::{Dispatch, WarmPool};
use crate::fnplat::{agent_steps, exec_step, DbBackend, DriverKind};
use crate::net::{rtt_step, Site};
use crate::sim::{Domain, Engine, Host, ReqId, Rng, Spawn, Step};
use crate::workload::tenants::TenantTrace;

use super::{IdleAction, LifecyclePolicy};

const TAG_DISPATCH: u32 = 1;
const TAG_RELEASE: u32 = 2;
const TAG_PREWARM: u32 = 3;

/// High bit of the request class marks policy control requests (pre-warm
/// boots) rather than user invocations.
const CONTROL_BIT: u32 = 1 << 31;

/// One cell of the policy lab: a driver serving a tenant trace under one
/// lifecycle policy.
#[derive(Clone, Debug)]
pub struct PolicyScenario {
    pub driver: DriverKind,
    pub trace: TenantTrace,
    /// Function-body execution cost (ms).
    pub exec_ms: f64,
    /// Resident bytes one retained executor holds while idle.  For the
    /// Docker driver this is the container's warm footprint; for the
    /// unikernel driver it models *hypothetically* pausing the unikernel
    /// instead of letting it exit (the lab's what-if; the real system
    /// exits, which is exactly the cold-only policy row).
    pub mem_bytes_per_slot: u64,
    pub seed: u64,
}

impl PolicyScenario {
    pub fn new(driver: DriverKind, trace: TenantTrace, seed: u64) -> PolicyScenario {
        let mem = match driver {
            DriverKind::DockerWarm => driver.tech().warm_memory_bytes(),
            // A retained (paused) IncludeOS unikernel would hold its guest
            // memory: ~2.5 MB image + boot heap.  The shipped system never
            // retains one — this powers the lab's what-if rows only.
            DriverKind::IncludeOsCold => 6 << 20,
        };
        PolicyScenario {
            driver,
            trace,
            exec_ms: crate::fnplat::DEFAULT_EXEC_MS,
            mem_bytes_per_slot: mem,
            seed,
        }
    }

    fn head_steps(&self) -> Vec<Step> {
        let mut v = vec![rtt_step("req-resp-rtt", Site::LabStockholm, Site::LabStockholm)];
        v.extend(agent_steps(DbBackend::Postgres));
        v.push(Step::decision("dispatch", TAG_DISPATCH));
        v
    }
}

struct PolicyDomain<'a> {
    driver: DriverKind,
    exec_ms: f64,
    policy: &'a mut dyn LifecyclePolicy,
    pool: WarmPool,
    /// Pool keys per function id (the pool is string-keyed).
    func_names: Vec<String>,
    /// Pre-warms decided during the current request's release effect,
    /// drained into spawns when the request completes.
    pending_prewarms: Vec<(u32, u64, u64)>, // (func, delay_ns, keep_ns)
    /// Keep windows for in-flight pre-warm control requests, per function,
    /// keyed by absolute boot time (boots may fire out of schedule order
    /// when forecast delays differ).
    prewarm_keeps: Vec<std::collections::VecDeque<(u64, u64)>>, // (fire_at_ns, keep_ns)
    prewarm_boots: u64,
    latencies_ns: Vec<u64>,
    cold_served: u64,
    warm_served: u64,
}

impl PolicyDomain<'_> {
    fn dispatch_tail(&mut self, func: u32, now: u64) -> Vec<Step> {
        self.policy.on_invoke(func, now);
        let mut tail = Vec::new();
        match self.pool.dispatch(&self.func_names[func as usize], now) {
            Dispatch::Warm => {
                self.warm_served += 1;
                tail.extend(self.driver.warm_invoke_steps());
            }
            Dispatch::Cold => {
                self.cold_served += 1;
                tail.extend(self.driver.cold_start_steps());
            }
        }
        tail.push(exec_step(self.exec_ms));
        tail.push(Step::effect("release", TAG_RELEASE));
        tail
    }
}

impl Domain for PolicyDomain<'_> {
    fn decide(&mut self, _req: ReqId, class: u32, tag: u32, now: u64, _rng: &mut Rng) -> Vec<Step> {
        debug_assert_eq!(tag, TAG_DISPATCH);
        self.dispatch_tail(class, now)
    }

    fn effect(&mut self, _req: ReqId, class: u32, tag: u32, now: u64) {
        let func = class & !CONTROL_BIT;
        match tag {
            TAG_RELEASE => match self.policy.on_idle(func, now) {
                IdleAction::Retire => self.pool.retire(&self.func_names[func as usize]),
                IdleAction::KeepFor { keep_ns } => self.pool.release_until(
                    &self.func_names[func as usize],
                    now,
                    now.saturating_add(keep_ns),
                ),
                IdleAction::PrewarmAfter { delay_ns, keep_ns } => {
                    self.pool.retire(&self.func_names[func as usize]);
                    self.pending_prewarms.push((func, delay_ns, keep_ns));
                }
            },
            TAG_PREWARM => {
                // Match this boot to its scheduled keep window by fire
                // time: boots fire at exactly their scheduled instant.
                let q = &mut self.prewarm_keeps[func as usize];
                let keep = q
                    .iter()
                    .position(|&(fire_at, _)| fire_at == now)
                    .and_then(|i| q.remove(i))
                    .map(|(_, keep)| keep)
                    .unwrap_or(0);
                // Skip stale pre-warms: an arrival already repopulated the
                // pool, or the keep window degenerated.
                if keep > 0 && self.pool.idle_count(&self.func_names[func as usize]) == 0 {
                    self.prewarm_boots += 1;
                    self.pool.prewarm_until(
                        &self.func_names[func as usize],
                        1,
                        now,
                        now.saturating_add(keep),
                    );
                }
            }
            other => debug_assert!(false, "unexpected effect tag {other}"),
        }
    }

    fn done(&mut self, _req: ReqId, class: u32, start: u64, now: u64) -> Vec<Spawn> {
        let mut spawns = Vec::new();
        for (func, delay_ns, keep_ns) in self.pending_prewarms.drain(..) {
            self.prewarm_keeps[func as usize].push_back((now.saturating_add(delay_ns), keep_ns));
            spawns.push(Spawn {
                delay_ns,
                class: func | CONTROL_BIT,
                steps: vec![Step::effect("prewarm-boot", TAG_PREWARM)],
            });
        }
        if class & CONTROL_BIT == 0 {
            self.latencies_ns.push(now - start);
        }
        spawns
    }
}

/// Aggregated outcome of one policy-lab cell.
#[derive(Clone, Debug)]
pub struct PolicyResult {
    pub latencies_ns: Vec<u64>,
    pub elapsed_ns: u64,
    pub cold_starts: u64,
    pub warm_hits: u64,
    pub prewarm_boots: u64,
    pub expirations: u64,
    pub retirements: u64,
    pub idle_gb_seconds: f64,
    pub monitor_events: u64,
}

impl PolicyResult {
    pub fn requests(&self) -> u64 {
        self.latencies_ns.len() as u64
    }

    pub fn cold_fraction(&self) -> f64 {
        let total = self.cold_starts + self.warm_hits;
        if total == 0 { 0.0 } else { self.cold_starts as f64 / total as f64 }
    }

    pub fn quantile_ms(&self, q: f64) -> f64 {
        if self.latencies_ns.is_empty() {
            return f64::NAN;
        }
        let mut s = self.latencies_ns.clone();
        s.sort_unstable();
        let idx = ((q * s.len() as f64).ceil() as usize).saturating_sub(1);
        s[idx.min(s.len() - 1)] as f64 / 1e6
    }
}

/// Replay `sc.trace` through `policy` on `host`.
pub fn run_policy_scenario(
    sc: &PolicyScenario,
    policy: &mut dyn LifecyclePolicy,
    host: Host,
) -> PolicyResult {
    let n_funcs = sc.trace.functions;
    let domain = PolicyDomain {
        driver: sc.driver,
        exec_ms: sc.exec_ms,
        policy,
        // The pool-wide timeout is irrelevant here (every release carries a
        // per-slot deadline), but keep it sane for the classic entrypoints.
        pool: WarmPool::new(30 * 1_000_000_000, sc.mem_bytes_per_slot),
        func_names: (0..n_funcs).map(|f| format!("f{f}")).collect(),
        pending_prewarms: Vec::new(),
        prewarm_keeps: (0..n_funcs).map(|_| std::collections::VecDeque::new()).collect(),
        prewarm_boots: 0,
        latencies_ns: Vec::with_capacity(sc.trace.len()),
        cold_served: 0,
        warm_served: 0,
    };
    let mut e = Engine::new(domain, host, sc.seed);
    let head = sc.head_steps();
    for &(at, func) in &sc.trace.arrivals {
        e.spawn_at(at, func, head.clone());
    }
    e.run((sc.trace.len() as u64).saturating_mul(128).max(1 << 20));
    let now = e.now();
    e.domain.pool.finalize(now);
    PolicyResult {
        latencies_ns: std::mem::take(&mut e.domain.latencies_ns),
        elapsed_ns: now,
        cold_starts: e.domain.cold_served,
        warm_hits: e.domain.warm_served,
        prewarm_boots: e.domain.prewarm_boots,
        expirations: e.domain.pool.expirations,
        retirements: e.domain.pool.retirements,
        idle_gb_seconds: e.domain.pool.idle_gb_seconds(),
        monitor_events: e.domain.pool.monitor_events,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::policy::{ColdOnlyPolicy, EwmaPredictive, FixedKeepAlive, HistogramPrewarm};
    use crate::workload::tenants::{TenantConfig, TenantTrace};

    fn tiny_trace() -> TenantTrace {
        TenantTrace::generate(&TenantConfig {
            functions: 50,
            duration_s: 60.0,
            total_rps: 40.0,
            seed: 0x7E57,
            ..Default::default()
        })
    }

    #[test]
    fn cold_only_serves_everything_cold_with_zero_waste() {
        let trace = tiny_trace();
        let n = trace.len() as u64;
        let sc = PolicyScenario::new(DriverKind::IncludeOsCold, trace, 1);
        let mut p = ColdOnlyPolicy;
        let r = run_policy_scenario(&sc, &mut p, Host::default());
        assert_eq!(r.requests(), n);
        assert_eq!(r.warm_hits, 0);
        assert_eq!(r.cold_starts, n);
        assert_eq!(r.retirements, n);
        assert_eq!(r.idle_gb_seconds, 0.0);
        assert_eq!(r.monitor_events, 0);
        assert_eq!(r.prewarm_boots, 0);
    }

    #[test]
    fn fixed_keepalive_gets_warm_hits_and_pays_waste() {
        let sc = PolicyScenario::new(DriverKind::DockerWarm, tiny_trace(), 1);
        let mut p = FixedKeepAlive::default();
        let r = run_policy_scenario(&sc, &mut p, Host::default());
        assert!(r.warm_hits > r.cold_starts, "head functions must reuse executors");
        assert!(r.idle_gb_seconds > 0.0);
        assert!(r.monitor_events > 0);
    }

    #[test]
    fn warm_latency_below_cold_latency_docker() {
        let trace = tiny_trace();
        let cold = {
            let sc = PolicyScenario::new(DriverKind::DockerWarm, trace.clone(), 1);
            run_policy_scenario(&sc, &mut ColdOnlyPolicy, Host::default())
        };
        let warm = {
            let sc = PolicyScenario::new(DriverKind::DockerWarm, trace, 1);
            run_policy_scenario(&sc, &mut FixedKeepAlive::default(), Host::default())
        };
        assert!(
            warm.quantile_ms(0.5) < cold.quantile_ms(0.5) / 5.0,
            "warm p50 {} vs cold p50 {}",
            warm.quantile_ms(0.5),
            cold.quantile_ms(0.5)
        );
    }

    #[test]
    fn adaptive_policies_run_and_account_consistently() {
        let trace = tiny_trace();
        let n = trace.len() as u64;
        for policy in [true, false] {
            let sc = PolicyScenario::new(DriverKind::DockerWarm, trace.clone(), 1);
            let r = if policy {
                let mut p = HistogramPrewarm::new(sc.trace.functions);
                run_policy_scenario(&sc, &mut p, Host::default())
            } else {
                let mut p = EwmaPredictive::new(sc.trace.functions);
                run_policy_scenario(&sc, &mut p, Host::default())
            };
            assert_eq!(r.requests(), n);
            assert_eq!(r.cold_starts + r.warm_hits, n);
            assert!(r.idle_gb_seconds >= 0.0);
        }
    }

    #[test]
    fn prewarm_lands_ahead_of_a_metronome() {
        // One function, strict 90 s period: after the histogram fills, the
        // policy must pre-warm ahead of arrivals and serve them warm.
        let arrivals: Vec<(u64, u32)> =
            (1..30u64).map(|i| (i * 90 * 1_000_000_000, 0)).collect();
        let trace = TenantTrace { functions: 1, arrivals };
        let sc = PolicyScenario::new(DriverKind::DockerWarm, trace, 1);
        let mut p = HistogramPrewarm::new(1);
        let r = run_policy_scenario(&sc, &mut p, Host::default());
        assert!(r.prewarm_boots > 5, "prewarm boots {}", r.prewarm_boots);
        assert!(r.warm_hits > 10, "warm hits {}", r.warm_hits);
        // Pre-warming pays memory only around predicted arrivals — far
        // less than fixed keep-alive would (90 s idle per gap).
        let sc2 = PolicyScenario::new(DriverKind::DockerWarm, TenantTrace {
            functions: 1,
            arrivals: (1..30u64).map(|i| (i * 90 * 1_000_000_000, 0)).collect(),
        }, 1);
        let f = run_policy_scenario(&sc2, &mut FixedKeepAlive::default(), Host::default());
        assert!(
            r.idle_gb_seconds < f.idle_gb_seconds * 0.6,
            "prewarm waste {} vs fixed {}",
            r.idle_gb_seconds,
            f.idle_gb_seconds
        );
    }

    #[test]
    fn deterministic_per_seed() {
        let run = || {
            let sc = PolicyScenario::new(DriverKind::DockerWarm, tiny_trace(), 9);
            let mut p = EwmaPredictive::new(sc.trace.functions);
            run_policy_scenario(&sc, &mut p, Host::default())
        };
        let (a, b) = (run(), run());
        assert_eq!(a.latencies_ns, b.latencies_ns);
        assert_eq!(a.idle_gb_seconds, b.idle_gb_seconds);
        assert_eq!(a.prewarm_boots, b.prewarm_boots);
    }
}
