//! Hybrid-histogram lifecycle policy: per-function log-bucket histograms
//! over inter-arrival times pick a keep-alive window (tail percentile) and
//! a pre-warm point (head percentile) — the production policy family
//! Shahrad et al. shipped, reproduced over this repo's substrate.
//!
//! Per function the policy tracks the distribution of gaps between
//! invocations.  Once enough gaps are observed:
//!
//! * if the head percentile (p5) of the gap distribution is *short*, the
//!   next invocation usually lands soon — keep the executor warm until a
//!   margin past the tail percentile (p99);
//! * if even the head percentile is long, idling through the gap is pure
//!   waste — tear down now, pre-warm just before the head percentile, and
//!   retain the pre-warmed executor through the tail percentile window.
//!
//! Until enough history exists the policy falls back to a short bootstrap
//! keep-alive (observation mode).

use crate::metrics::Histogram;
use crate::sim::snap::{Dec, Enc};

use super::{IdleAction, LifecyclePolicy};

const NS_PER_MS: f64 = 1e6;

/// Hybrid histogram keep-alive/pre-warm policy.
pub struct HistogramPrewarm {
    hists: Vec<Histogram>,
    last_invoke_ns: Vec<Option<u64>>,
    /// Keep-alive while a function has too little history to classify.
    pub bootstrap_keep_ns: u64, // detlint: allow(DL005) config-derived constant
    /// Hard cap on any keep-alive window (the commercial default).
    pub max_keep_ns: u64, // detlint: allow(DL005) config-derived constant
    /// Pre-warm (rather than keep) only when the head-percentile gap
    /// exceeds this — short gaps make teardown+reboot churn pointless.
    pub prewarm_threshold_ns: u64, // detlint: allow(DL005) config-derived constant
    /// Gap observations required before the histogram drives decisions.
    pub min_samples: u64, // detlint: allow(DL005) config-derived constant
}

impl HistogramPrewarm {
    /// Head/tail margins of the hybrid policy: pre-warm at 85% of the head
    /// percentile, keep until 115% of the tail percentile.
    const HEAD_MARGIN: f64 = 0.85;
    const TAIL_MARGIN: f64 = 1.15;

    pub fn new(n_funcs: u32) -> HistogramPrewarm {
        HistogramPrewarm {
            hists: (0..n_funcs).map(|_| Histogram::new()).collect(),
            last_invoke_ns: vec![None; n_funcs as usize],
            bootstrap_keep_ns: 120 * 1_000_000_000,
            max_keep_ns: super::FixedKeepAlive::DEFAULT_KEEP_NS,
            prewarm_threshold_ns: 60 * 1_000_000_000,
            min_samples: 8,
        }
    }

    fn quantile_ns(&self, func: u32, q: f64) -> u64 {
        (self.hists[func as usize].quantile_ms(q) * NS_PER_MS) as u64
    }
}

impl LifecyclePolicy for HistogramPrewarm {
    fn name(&self) -> String {
        "histogram".to_string()
    }

    fn on_invoke(&mut self, func: u32, now_ns: u64) {
        let f = func as usize;
        if let Some(prev) = self.last_invoke_ns[f] {
            self.hists[f].record_ns(now_ns.saturating_sub(prev));
        }
        self.last_invoke_ns[f] = Some(now_ns);
    }

    fn on_idle(&mut self, func: u32, _now_ns: u64) -> IdleAction {
        if self.hists[func as usize].len() < self.min_samples {
            return IdleAction::KeepFor { keep_ns: self.bootstrap_keep_ns.min(self.max_keep_ns) };
        }
        let head = self.quantile_ns(func, 0.05);
        let tail = self.quantile_ns(func, 0.99);
        // Retain-until edge of the hybrid window, *uncapped*: a pre-warm
        // window's far edge must cover the forecast arrival even when it
        // lies beyond max_keep — only the window's LENGTH is capped
        // (tail >= head, so the length 1.15*tail - 0.85*head is > 0).
        let tail_edge = (tail as f64 * Self::TAIL_MARGIN) as u64;
        if head > self.prewarm_threshold_ns {
            // Reliably long gaps: skip the idle stretch, be warm in time.
            let delay = (head as f64 * Self::HEAD_MARGIN) as u64;
            let keep = tail_edge.saturating_sub(delay).clamp(1, self.max_keep_ns);
            IdleAction::PrewarmAfter { delay_ns: delay, keep_ns: keep }
        } else {
            IdleAction::KeepFor { keep_ns: tail_edge.clamp(1, self.max_keep_ns) }
        }
    }

    fn encode_state(&self, w: &mut Enc) {
        w.len(self.hists.len());
        for i in 0..self.hists.len() {
            self.hists[i].encode(w);
            match self.last_invoke_ns[i] {
                Some(t) => {
                    w.bool(true);
                    w.u64(t);
                }
                None => w.bool(false),
            }
        }
    }

    fn restore_state(&mut self, r: &mut Dec) {
        let n = r.len();
        assert_eq!(n, self.hists.len(), "histogram policy state size mismatch — config drift?");
        for i in 0..n {
            self.hists[i] = Histogram::decode(r);
            self.last_invoke_ns[i] = if r.bool() { Some(r.u64()) } else { None };
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const S: u64 = 1_000_000_000;

    #[test]
    fn bootstrap_keep_until_enough_history() {
        let mut p = HistogramPrewarm::new(4);
        p.on_invoke(0, 0);
        match p.on_idle(0, S) {
            IdleAction::KeepFor { keep_ns } => assert_eq!(keep_ns, p.bootstrap_keep_ns),
            other => panic!("expected bootstrap keep, got {other:?}"),
        }
    }

    #[test]
    fn tight_periodic_function_gets_short_keep() {
        let mut p = HistogramPrewarm::new(1);
        // Metronome at 2 s gaps: p99 ~ 2 s, so keep ~ 2.3 s, not 10 min.
        for i in 0..50u64 {
            p.on_invoke(0, i * 2 * S);
        }
        match p.on_idle(0, 100 * S) {
            IdleAction::KeepFor { keep_ns } => {
                assert!(
                    keep_ns > S && keep_ns < 5 * S,
                    "periodic keep should hug the gap: {keep_ns}"
                );
            }
            other => panic!("2 s gaps are below the prewarm threshold: {other:?}"),
        }
    }

    #[test]
    fn slow_periodic_function_prewarms() {
        let mut p = HistogramPrewarm::new(1);
        // Metronome at 5 min gaps: even p5 is far beyond the threshold.
        for i in 0..20u64 {
            p.on_invoke(0, i * 300 * S);
        }
        match p.on_idle(0, 6000 * S) {
            IdleAction::PrewarmAfter { delay_ns, keep_ns } => {
                // Pre-warm before the gap elapses, keep through the tail.
                assert!(delay_ns > 120 * S && delay_ns < 300 * S, "delay {delay_ns}");
                assert!(keep_ns >= 1, "keep {keep_ns}");
                assert!(delay_ns + keep_ns >= 290 * S, "window must cover the gap");
            }
            other => panic!("5 min gaps should prewarm: {other:?}"),
        }
    }

    #[test]
    fn keep_never_exceeds_cap() {
        let mut p = HistogramPrewarm::new(1);
        p.prewarm_threshold_ns = u64::MAX; // force KeepFor
        for i in 0..30u64 {
            p.on_invoke(0, i * 2000 * S); // 33 min gaps
        }
        match p.on_idle(0, 100_000 * S) {
            IdleAction::KeepFor { keep_ns } => assert!(keep_ns <= p.max_keep_ns),
            other => panic!("forced keep, got {other:?}"),
        }
    }

    #[test]
    fn state_round_trip_preserves_decisions() {
        let mut p = HistogramPrewarm::new(2);
        for i in 0..50u64 {
            p.on_invoke(0, i * 2 * S);
            p.on_invoke(1, i * 310 * S);
        }
        let mut w = Enc::new();
        p.encode_state(&mut w);

        let mut q = HistogramPrewarm::new(2);
        let mut r = Dec::new(&w.buf);
        q.restore_state(&mut r);
        r.finish();

        let mut w2 = Enc::new();
        q.encode_state(&mut w2);
        assert_eq!(w.buf, w2.buf, "restore must round-trip byte-exactly");
        assert_eq!(p.on_idle(0, 200 * S), q.on_idle(0, 200 * S));
        assert_eq!(p.on_idle(1, 16_000 * S), q.on_idle(1, 16_000 * S));
    }

    #[test]
    fn per_function_state_is_isolated() {
        let mut p = HistogramPrewarm::new(2);
        for i in 0..50u64 {
            p.on_invoke(0, i * 2 * S);
        }
        // Function 1 has no history: still in bootstrap.
        match p.on_idle(1, 100 * S) {
            IdleAction::KeepFor { keep_ns } => assert_eq!(keep_ns, p.bootstrap_keep_ns),
            other => panic!("func 1 must bootstrap: {other:?}"),
        }
    }
}
