//! Universal-pool lifecycle policy (S23): size a *shared* runtime-keyed
//! warm pool instead of per-function keep-alive windows.
//!
//! The strongest keep-alive counter-proposal to the paper's cold-only
//! platform is not a smarter per-function window but *sharing*: pool
//! warm executors per language runtime ("universal workers") so one idle
//! worker serves any function of that runtime, amortizing keep-alive
//! waste across the whole tenant population.  This policy drives such a
//! pool: it tracks a per-runtime EWMA of the arrival rate and keeps each
//! idle worker just long enough that, at the observed rate, about
//! `target_per_runtime` workers sit warm per runtime bucket —
//! Little's-law sizing with EWMA resizing, instead of the fixed
//! 10-minute-per-function window of [`super::FixedKeepAlive`].
//!
//! Functions hash onto runtimes as `func % runtimes` — the same mapping
//! [`crate::platform::SharingMode::PerRuntime`] keys slots by, so the
//! policy's sizing and the platform's routing agree on which bucket a
//! worker amortizes over.  With `runtimes == 1` the policy sizes one
//! global bucket (the promiscuous mode).

use super::{IdleAction, LifecyclePolicy};
use crate::sim::snap::{Dec, Enc};

/// Per-runtime target-size keep-alive with EWMA rate tracking.
#[derive(Clone, Debug)]
pub struct UniversalPool {
    runtimes: u32, // detlint: allow(DL005) config-derived constant
    /// Idle universal workers to aim for per runtime bucket.
    pub target_per_runtime: f64, // detlint: allow(DL005) config-derived constant
    /// Keep-window clamp: the floor keeps quiet ramps from thrashing,
    /// the ceiling bounds waste for near-dead runtimes.
    pub min_keep_ns: u64, // detlint: allow(DL005) config-derived constant
    pub max_keep_ns: u64, // detlint: allow(DL005) config-derived constant
    /// EWMA smoothing factor for the inter-arrival gap estimate.
    pub alpha: f64, // detlint: allow(DL005) config-derived constant
    /// Last arrival per runtime (`u64::MAX` = none seen yet).
    last_arrival_ns: Vec<u64>,
    /// EWMA inter-arrival gap per runtime (0 = no estimate yet).
    ewma_gap_ns: Vec<f64>,
}

const S: u64 = 1_000_000_000;

impl UniversalPool {
    /// Defaults: 60 s..600 s keep clamp, alpha 0.2.
    pub fn new(runtimes: u32, target_per_runtime: f64) -> UniversalPool {
        let r = runtimes.max(1);
        UniversalPool {
            runtimes: r,
            target_per_runtime: target_per_runtime.max(1.0),
            min_keep_ns: 60 * S,
            max_keep_ns: 600 * S,
            alpha: 0.2,
            last_arrival_ns: vec![u64::MAX; r as usize],
            ewma_gap_ns: vec![0.0; r as usize],
        }
    }

    fn runtime_of(&self, func: u32) -> usize {
        (func % self.runtimes) as usize
    }

    /// Current keep window for one runtime: `target x mean gap`, so the
    /// expected idle population sits near the target (each idle worker
    /// survives ~`target` arrivals' worth of time before expiring).
    fn keep_ns(&self, rt: usize) -> u64 {
        let gap = self.ewma_gap_ns[rt];
        if gap <= 0.0 {
            // No rate estimate yet: hold the floor window.
            return self.min_keep_ns;
        }
        let keep = self.target_per_runtime * gap;
        (keep as u64).clamp(self.min_keep_ns, self.max_keep_ns)
    }
}

impl LifecyclePolicy for UniversalPool {
    fn name(&self) -> String {
        format!("universal-t{:.0}", self.target_per_runtime)
    }

    fn on_invoke(&mut self, func: u32, now_ns: u64) {
        let rt = self.runtime_of(func);
        let last = self.last_arrival_ns[rt];
        if last != u64::MAX && now_ns > last {
            let gap = (now_ns - last) as f64;
            let prev = self.ewma_gap_ns[rt];
            self.ewma_gap_ns[rt] =
                if prev <= 0.0 { gap } else { self.alpha * gap + (1.0 - self.alpha) * prev };
        }
        self.last_arrival_ns[rt] = now_ns;
    }

    fn on_idle(&mut self, func: u32, _now_ns: u64) -> IdleAction {
        let rt = self.runtime_of(func);
        IdleAction::KeepFor { keep_ns: self.keep_ns(rt) }
    }

    fn encode_state(&self, w: &mut Enc) {
        w.len(self.last_arrival_ns.len());
        for i in 0..self.last_arrival_ns.len() {
            w.u64(self.last_arrival_ns[i]);
            w.f64(self.ewma_gap_ns[i]);
        }
    }

    fn restore_state(&mut self, r: &mut Dec) {
        let n = r.len();
        assert_eq!(
            n,
            self.last_arrival_ns.len(),
            "universal policy state size mismatch — config drift?"
        );
        for i in 0..n {
            self.last_arrival_ns[i] = r.u64();
            self.ewma_gap_ns[i] = r.f64();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn keeps_floor_window_before_any_rate_estimate() {
        let mut p = UniversalPool::new(4, 8.0);
        assert_eq!(p.on_idle(3, 0), IdleAction::KeepFor { keep_ns: 60 * S });
        assert_eq!(p.name(), "universal-t8");
    }

    #[test]
    fn ewma_rate_shrinks_the_window_under_load() {
        let mut p = UniversalPool::new(1, 8.0);
        p.min_keep_ns = 0; // expose the raw sizing
        // 10 arrivals/s: gap 100 ms, keep = 8 x 100 ms = 800 ms.
        for i in 1..50u64 {
            p.on_invoke(0, i * S / 10);
        }
        let IdleAction::KeepFor { keep_ns } = p.on_idle(0, 5 * S) else {
            panic!("universal pool always retains")
        };
        assert!(
            (keep_ns as f64 - 0.8e9).abs() < 0.2e9,
            "keep {} vs expected ~0.8 s",
            keep_ns
        );
    }

    #[test]
    fn quiet_runtimes_are_clamped_at_the_ceiling() {
        let mut p = UniversalPool::new(2, 8.0);
        // One arrival every 1000 s on runtime 0: 8 x 1000 s >> ceiling.
        p.on_invoke(0, 0);
        p.on_invoke(0, 1000 * S);
        assert_eq!(p.on_idle(0, 1000 * S), IdleAction::KeepFor { keep_ns: 600 * S });
        // Runtime 1 never saw an arrival: still on the floor.
        assert_eq!(p.on_idle(1, 1000 * S), IdleAction::KeepFor { keep_ns: 60 * S });
    }

    #[test]
    fn state_round_trip_preserves_rate_estimates() {
        let mut p = UniversalPool::new(3, 8.0);
        for i in 1..40u64 {
            p.on_invoke((i % 5) as u32, i * S / 4);
        }
        let mut w = Enc::new();
        p.encode_state(&mut w);

        let mut q = UniversalPool::new(3, 8.0);
        let mut r = Dec::new(&w.buf);
        q.restore_state(&mut r);
        r.finish();

        let mut w2 = Enc::new();
        q.encode_state(&mut w2);
        assert_eq!(w.buf, w2.buf, "restore must round-trip byte-exactly");
        for rt in 0..3u32 {
            assert_eq!(p.on_idle(rt, 40 * S), q.on_idle(rt, 40 * S));
        }
    }

    #[test]
    fn functions_hash_onto_runtime_buckets() {
        let mut p = UniversalPool::new(4, 8.0);
        p.min_keep_ns = 0;
        // Functions 1 and 5 share runtime 1: their arrivals feed one EWMA.
        p.on_invoke(1, 0);
        p.on_invoke(5, S);
        let IdleAction::KeepFor { keep_ns } = p.on_idle(9, S) else {
            panic!("universal pool always retains")
        };
        // 1 s gap x target 8 = 8 s for every function of runtime 1.
        assert!((keep_ns as f64 - 8e9).abs() < 1e6, "keep {keep_ns}");
    }
}
