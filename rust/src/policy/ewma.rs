//! EWMA inter-arrival forecasting policy: a cheap online stand-in for the
//! learned cold-start predictors in the literature (transformer/LSTM
//! arrival forecasters).  Per function it maintains exponentially weighted
//! estimates of the inter-arrival mean and variance; on idle it either
//! retains the executor through the forecast gap (plus an uncertainty
//! margin) or, when the forecast gap is long and confident, tears down and
//! pre-warms just ahead of the predicted arrival.

use super::{IdleAction, LifecyclePolicy};
use crate::sim::snap::{Dec, Enc};

/// EWMA arrival-forecast keep-alive/pre-warm policy.
pub struct EwmaPredictive {
    /// EWMA of the inter-arrival gap (ns).
    mean_ns: Vec<f64>,
    /// EWMA of the squared deviation (ns^2).
    var_ns2: Vec<f64>,
    last_invoke_ns: Vec<Option<u64>>,
    samples: Vec<u32>,
    /// Smoothing factor for mean and variance updates.
    pub alpha: f64, // detlint: allow(DL005) config-derived constant
    /// Keep-alive while a function has too little history to forecast.
    pub bootstrap_keep_ns: u64, // detlint: allow(DL005) config-derived constant
    /// Hard cap on any keep-alive window.
    pub max_keep_ns: u64, // detlint: allow(DL005) config-derived constant
    /// Pre-warm (rather than keep) only for forecast gaps beyond this.
    pub prewarm_threshold_ns: u64, // detlint: allow(DL005) config-derived constant
    /// Gap observations required before the forecast drives decisions.
    pub min_samples: u32, // detlint: allow(DL005) config-derived constant
}

impl EwmaPredictive {
    /// Coefficient-of-variation bound under which a long forecast gap is
    /// trusted enough to pre-warm instead of retaining.
    const PREDICTABLE_CV: f64 = 0.5;

    pub fn new(n_funcs: u32) -> EwmaPredictive {
        EwmaPredictive {
            mean_ns: vec![0.0; n_funcs as usize],
            var_ns2: vec![0.0; n_funcs as usize],
            last_invoke_ns: vec![None; n_funcs as usize],
            samples: vec![0; n_funcs as usize],
            alpha: 0.2,
            bootstrap_keep_ns: 120 * 1_000_000_000,
            max_keep_ns: super::FixedKeepAlive::DEFAULT_KEEP_NS,
            prewarm_threshold_ns: 60 * 1_000_000_000,
            min_samples: 4,
        }
    }

    fn sigma_ns(&self, f: usize) -> f64 {
        self.var_ns2[f].max(0.0).sqrt()
    }
}

impl LifecyclePolicy for EwmaPredictive {
    fn name(&self) -> String {
        "ewma".to_string()
    }

    fn on_invoke(&mut self, func: u32, now_ns: u64) {
        let f = func as usize;
        if let Some(prev) = self.last_invoke_ns[f] {
            let gap = now_ns.saturating_sub(prev) as f64;
            if self.samples[f] == 0 {
                self.mean_ns[f] = gap;
            } else {
                let dev = gap - self.mean_ns[f];
                self.mean_ns[f] += self.alpha * dev;
                self.var_ns2[f] = (1.0 - self.alpha) * (self.var_ns2[f] + self.alpha * dev * dev);
            }
            self.samples[f] = self.samples[f].saturating_add(1);
        }
        self.last_invoke_ns[f] = Some(now_ns);
    }

    fn on_idle(&mut self, func: u32, _now_ns: u64) -> IdleAction {
        let f = func as usize;
        if self.samples[f] < self.min_samples {
            return IdleAction::KeepFor { keep_ns: self.bootstrap_keep_ns.min(self.max_keep_ns) };
        }
        let mean = self.mean_ns[f];
        let sigma = self.sigma_ns(f);
        // Far edge of the retention window: forecast gap + 2-sigma margin.
        // Uncapped here — a pre-warm window must cover the forecast arrival
        // even beyond max_keep; only the window LENGTH is capped below.
        let keep_edge = (mean + 2.0 * sigma).max(0.0) as u64;
        if mean > self.prewarm_threshold_ns as f64 && sigma < Self::PREDICTABLE_CV * mean {
            // Long, confident gap: idle through it cold, warm up just
            // before the forecast arrival (2 sigma early).  The window
            // spans [delay, keep_edge], which is always non-empty.
            let delay = ((mean - 2.0 * sigma).max(0.0) * 0.95) as u64;
            let keep = keep_edge.saturating_sub(delay).clamp(1, self.max_keep_ns);
            IdleAction::PrewarmAfter { delay_ns: delay, keep_ns: keep }
        } else {
            IdleAction::KeepFor { keep_ns: keep_edge.clamp(1, self.max_keep_ns) }
        }
    }

    fn encode_state(&self, w: &mut Enc) {
        w.len(self.mean_ns.len());
        for i in 0..self.mean_ns.len() {
            w.f64(self.mean_ns[i]);
            w.f64(self.var_ns2[i]);
            match self.last_invoke_ns[i] {
                Some(t) => {
                    w.bool(true);
                    w.u64(t);
                }
                None => w.bool(false),
            }
            w.u32(self.samples[i]);
        }
    }

    fn restore_state(&mut self, r: &mut Dec) {
        let n = r.len();
        assert_eq!(n, self.mean_ns.len(), "ewma policy state size mismatch — config drift?");
        for i in 0..n {
            self.mean_ns[i] = r.f64();
            self.var_ns2[i] = r.f64();
            self.last_invoke_ns[i] = if r.bool() { Some(r.u64()) } else { None };
            self.samples[i] = r.u32();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const S: u64 = 1_000_000_000;

    #[test]
    fn bootstrap_before_enough_samples() {
        let mut p = EwmaPredictive::new(1);
        p.on_invoke(0, 0);
        p.on_invoke(0, 5 * S);
        match p.on_idle(0, 6 * S) {
            IdleAction::KeepFor { keep_ns } => assert_eq!(keep_ns, p.bootstrap_keep_ns),
            other => panic!("expected bootstrap, got {other:?}"),
        }
    }

    #[test]
    fn steady_short_gaps_keep_near_mean() {
        let mut p = EwmaPredictive::new(1);
        for i in 0..40u64 {
            p.on_invoke(0, i * 3 * S);
        }
        match p.on_idle(0, 200 * S) {
            IdleAction::KeepFor { keep_ns } => {
                // Constant 3 s gaps: sigma -> 0, keep ~ mean.
                assert!(
                    (2 * S..=6 * S).contains(&keep_ns),
                    "keep should track the 3 s gap: {keep_ns}"
                );
            }
            other => panic!("short gaps must retain: {other:?}"),
        }
    }

    #[test]
    fn long_confident_gaps_prewarm() {
        let mut p = EwmaPredictive::new(1);
        for i in 0..20u64 {
            p.on_invoke(0, i * 240 * S); // steady 4 min gaps
        }
        match p.on_idle(0, 5000 * S) {
            IdleAction::PrewarmAfter { delay_ns, keep_ns } => {
                assert!(delay_ns > 150 * S && delay_ns < 240 * S, "delay {delay_ns}");
                assert!(delay_ns + keep_ns >= 235 * S, "window must cover the forecast");
            }
            other => panic!("long steady gaps should prewarm: {other:?}"),
        }
    }

    #[test]
    fn erratic_long_gaps_do_not_prewarm() {
        let mut p = EwmaPredictive::new(1);
        // Alternating 30 s / 600 s gaps: high variance, no confident
        // forecast -> retain (capped), don't gamble on a prewarm point.
        let mut t = 0u64;
        for i in 0..40u64 {
            t += if i % 2 == 0 { 30 * S } else { 600 * S };
            p.on_invoke(0, t);
        }
        match p.on_idle(0, t + S) {
            IdleAction::KeepFor { keep_ns } => assert!(keep_ns <= p.max_keep_ns),
            other => panic!("erratic gaps must not prewarm: {other:?}"),
        }
    }

    #[test]
    fn state_round_trip_preserves_forecasts() {
        let mut p = EwmaPredictive::new(3);
        let mut t = 0u64;
        for i in 0..30u64 {
            t += (i % 5 + 1) * S;
            p.on_invoke((i % 3) as u32, t);
        }
        let mut w = Enc::new();
        p.encode_state(&mut w);

        let mut q = EwmaPredictive::new(3);
        let mut r = Dec::new(&w.buf);
        q.restore_state(&mut r);
        r.finish();

        let mut w2 = Enc::new();
        q.encode_state(&mut w2);
        assert_eq!(w.buf, w2.buf, "restore must round-trip byte-exactly");
        // Identical further history drives identical decisions.
        for pol in [&mut p, &mut q] {
            pol.on_invoke(1, t + 7 * S);
        }
        assert_eq!(p.on_idle(0, t + 8 * S), q.on_idle(0, t + 8 * S));
        assert_eq!(p.on_idle(1, t + 8 * S), q.on_idle(1, t + 8 * S));
        assert_eq!(p.on_idle(2, t + 8 * S), q.on_idle(2, t + 8 * S));
    }

    #[test]
    fn mean_tracks_rate_changes() {
        let mut p = EwmaPredictive::new(1);
        let mut t = 0u64;
        for _ in 0..30 {
            t += 10 * S;
            p.on_invoke(0, t);
        }
        let slow = p.mean_ns[0];
        for _ in 0..30 {
            t += S;
            p.on_invoke(0, t);
        }
        assert!(p.mean_ns[0] < slow / 3.0, "EWMA must adapt downward");
    }
}
