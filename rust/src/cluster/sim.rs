//! DES wiring for the cluster (E11): the burst scale-out experiment.
//!
//! Per-node contention is expressed with engine *pools*: each node gets a
//! core pool and a KVM-lock pool, and the technology's startup pipeline is
//! re-targeted onto the chosen node's pools at placement time.  Image
//! cache misses insert a transfer delay (40 Gbps fabric) before the start.

use crate::image::Image;
use crate::net::transfer_step;
use crate::sim::{Dist, Domain, Engine, Host, LockClass, ReqId, Rng, Spawn, Step, StepKind};
use crate::virt::Tech;

use super::{Policy, Scheduler};

const TAG_PLACE: u32 = 10;
const TAG_COMPLETE: u32 = 11;

#[derive(Clone, Debug)]
pub struct ClusterConfig {
    pub policy: Policy,
    pub nodes: usize,
    pub cores_per_node: u32,
    pub tech: Tech,
    /// Nodes pre-seeded with the image before the burst.
    pub seeded_nodes: usize,
    /// Burst: `requests` arrivals spread uniformly over `burst_ms`.
    pub requests: u64,
    pub burst_ms: f64,
    pub exec_ms: f64,
    pub seed: u64,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        ClusterConfig {
            policy: Policy::CoLocate,
            nodes: 8,
            cores_per_node: 8,
            tech: Tech::IncludeOsHvt,
            seeded_nodes: 1,
            // A sharp burst: 400 starts in 250 ms ≈ 1 600 starts/s, far
            // above one node's capacity but comfortably within the
            // cluster's — the regime where placement policy matters.
            requests: 400,
            burst_ms: 250.0,
            exec_ms: 1.0,
            seed: 0xC105_7E42,
        }
    }
}

/// Retarget a technology pipeline onto one node's pools: CPU phases use
/// the node's core pool, KVM-lock phases its per-node lock pool; global
/// kernel-lock classes other than KVM stay node-local too (pool of 1).
fn instantiate(steps: &[Step], cpu_pool: u8, lock_pool: u8) -> Vec<Step> {
    steps
        .iter()
        .map(|s| match s.kind {
            StepKind::Cpu => Step::pool(s.tag, cpu_pool, s.dur),
            StepKind::Lock(_) => Step::pool(s.tag, lock_pool, s.dur),
            _ => *s,
        })
        .collect()
}

struct ClusterDomain {
    sched: Scheduler,
    img: Image,
    tech: Tech,
    exec_ms: f64,
    cpu_pools: Vec<u8>,
    lock_pools: Vec<u8>,
    /// node chosen per request (for the Complete effect).
    placed: std::collections::HashMap<ReqId, usize>,
    latencies_ns: Vec<u64>,
}

impl Domain for ClusterDomain {
    fn decide(&mut self, req: ReqId, _c: u32, tag: u32, _now: u64, rng: &mut Rng) -> Vec<Step> {
        debug_assert_eq!(tag, TAG_PLACE);
        let outcome = self.sched.place(&self.img, rng);
        self.placed.insert(req, outcome.node);
        let mut steps = Vec::new();
        if outcome.fetch_bytes > 0 {
            steps.push(transfer_step("image-pull", outcome.fetch_bytes, 40.0));
        }
        steps.extend(instantiate(
            &self.tech.pipeline(),
            self.cpu_pools[outcome.node],
            self.lock_pools[outcome.node],
        ));
        steps.push(Step::pool("fn-exec", self.cpu_pools[outcome.node], Dist::ms(self.exec_ms, 0.15)));
        steps.push(Step::effect("complete", TAG_COMPLETE));
        steps
    }

    fn effect(&mut self, req: ReqId, _c: u32, tag: u32, _now: u64) {
        debug_assert_eq!(tag, TAG_COMPLETE);
        if let Some(node) = self.placed.remove(&req) {
            self.sched.complete(node);
        }
    }

    fn done(&mut self, _req: ReqId, _c: u32, start: u64, now: u64) -> Vec<Spawn> {
        self.latencies_ns.push(now - start);
        Vec::new()
    }
}

pub struct BurstResult {
    pub policy: Policy,
    pub p50_ms: f64,
    pub p99_ms: f64,
    pub max_ms: f64,
    pub transfers: u64,
    pub transferred_mb: f64,
    pub footprint_mb: f64,
    pub nodes_with_image: usize,
    pub makespan_ms: f64,
}

/// Run the burst scale-out scenario under one placement policy.
pub fn run_burst(cfg: &ClusterConfig) -> BurstResult {
    let img = Image::for_function("f", cfg.tech);
    let mut sched = Scheduler::new(cfg.policy, cfg.nodes, cfg.cores_per_node);
    sched.seed_image(&img, cfg.seeded_nodes.max(1));

    let domain = ClusterDomain {
        sched,
        img,
        tech: cfg.tech,
        exec_ms: cfg.exec_ms,
        cpu_pools: Vec::new(),
        lock_pools: Vec::new(),
        placed: Default::default(),
        latencies_ns: Vec::new(),
    };
    // The engine's own host cores are unused (everything goes through
    // pools); size them so they are never the constraint.
    let mut e = Engine::new(domain, Host { cores: u32::MAX, disk_bw_bytes_per_s: 1.2e9 }, cfg.seed);
    for _ in 0..cfg.nodes {
        let cpu = e.add_pool(cfg.cores_per_node);
        let lock = e.add_pool(1);
        e.domain.cpu_pools.push(cpu);
        e.domain.lock_pools.push(lock);
    }
    let head = vec![Step::decision("place", TAG_PLACE)];
    let mut rng = Rng::new(cfg.seed ^ 0xA5A5);
    for _ in 0..cfg.requests {
        let at = (rng.next_f64() * cfg.burst_ms * 1e6) as u64;
        e.spawn_at(at, 0, head.clone());
    }
    e.run(cfg.requests * 96 + (1 << 16));

    let mut lat = e.domain.latencies_ns.clone();
    lat.sort_unstable();
    let q = |f: f64| lat[((f * lat.len() as f64) as usize).min(lat.len() - 1)] as f64 / 1e6;
    BurstResult {
        policy: cfg.policy,
        p50_ms: q(0.5),
        p99_ms: q(0.99),
        max_ms: *lat.last().unwrap() as f64 / 1e6,
        transfers: e.domain.sched.transfers,
        transferred_mb: e.domain.sched.transferred_bytes as f64 / 1e6,
        footprint_mb: e.domain.sched.footprint_bytes() as f64 / 1e6,
        nodes_with_image: e.domain.sched.nodes_with_image("f"),
        makespan_ms: e.now() as f64 / 1e6,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(policy: Policy) -> ClusterConfig {
        ClusterConfig { policy, ..Default::default() }
    }

    #[test]
    fn colocation_inflates_burst_tails() {
        // Wang et al. / §IV: co-location hurts sudden scale-out.  With one
        // seeded node and a 400-request burst, packing onto the home node
        // must produce far worse tails than spreading.
        let colocate = run_burst(&cfg(Policy::CoLocate));
        let spread = run_burst(&cfg(Policy::LeastLoaded));
        assert!(
            colocate.p99_ms > 2.0 * spread.p99_ms,
            "colocate p99 {} vs spread p99 {}",
            colocate.p99_ms,
            spread.p99_ms
        );
    }

    #[test]
    fn spreading_unikernels_is_cheap() {
        // The paper's enabling economics: spreading a 2.5 MB IncludeOS
        // image to 8 nodes costs ~20 MB and sub-ms pulls...
        let uni = run_burst(&cfg(Policy::LeastLoaded));
        assert!(uni.footprint_mb < 25.0, "footprint {}", uni.footprint_mb);
        // ...while the same policy with Firecracker-sized images moves
        // 28x the bytes.
        let fc = run_burst(&ClusterConfig {
            policy: Policy::LeastLoaded,
            tech: crate::virt::Tech::Firecracker,
            ..Default::default()
        });
        assert!(fc.transferred_mb > 20.0 * uni.transferred_mb);
    }

    #[test]
    fn locality_without_replicas_behaves_like_colocation() {
        let loc = run_burst(&cfg(Policy::Locality));
        let spread = run_burst(&cfg(Policy::LeastLoaded));
        assert!(loc.p99_ms > spread.p99_ms, "{} vs {}", loc.p99_ms, spread.p99_ms);
        assert_eq!(loc.transfers, 0, "locality never leaves the seeded node");
    }

    #[test]
    fn preseeding_all_nodes_fixes_locality() {
        let fixed = run_burst(&ClusterConfig {
            policy: Policy::Locality,
            seeded_nodes: 8,
            ..Default::default()
        });
        let spread = run_burst(&cfg(Policy::LeastLoaded));
        // With replicas everywhere locality == least-loaded (± noise).
        assert!(fixed.p99_ms < 1.2 * spread.p99_ms);
    }

    #[test]
    fn deterministic() {
        let a = run_burst(&cfg(Policy::Random));
        let b = run_burst(&cfg(Policy::Random));
        assert_eq!(a.p99_ms, b.p99_ms);
        assert_eq!(a.transfers, b.transfers);
    }
}
