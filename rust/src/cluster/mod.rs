//! Multi-node cluster manager (extension of §IV-C and the Wang et al.
//! co-location observation the paper cites).
//!
//! The paper's single-host prototype leaves two cluster-level questions
//! open, both of which it calls out: (1) function images must be
//! distributed to every node that may receive a request, and (2) AWS
//! *co-locates* a function's executors on one machine, which "influences
//! startup times when sudden scale-out is required".  This module builds
//! the cluster substrate: N nodes with per-node image caches and per-node
//! contention, a pluggable placement policy, and the burst scale-out
//! experiment (E11) comparing co-location against spreading — showing why
//! the unikernel's 2.5 MB image makes spread placement affordable.

pub mod sim;

pub use sim::{run_burst, BurstResult, ClusterConfig};

use crate::image::{Image, NodeCache};
use crate::sim::Rng;

/// Placement policy for new executor starts.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Policy {
    /// Pack onto the node already running this function until its cores
    /// saturate (AWS-like co-location per Wang et al.).
    CoLocate,
    /// Uniform random over all nodes.
    Random,
    /// Fewest in-flight starts first (power of all choices).
    LeastLoaded,
    /// Least-loaded among nodes that already cache the image; fall back
    /// to least-loaded overall (pays a transfer) if none do.
    Locality,
}

impl Policy {
    pub const ALL: [Policy; 4] =
        [Policy::CoLocate, Policy::Random, Policy::LeastLoaded, Policy::Locality];

    pub fn name(&self) -> &'static str {
        match self {
            Policy::CoLocate => "co-locate",
            Policy::Random => "random",
            Policy::LeastLoaded => "least-loaded",
            Policy::Locality => "locality",
        }
    }
}

/// One cluster node's scheduler-visible state.
pub struct Node {
    pub id: usize,
    pub cores: u32,
    /// Executor slots bounded by *memory*, not cores — Wang et al.: AWS
    /// co-locates a function's instances "roughly while they fit into the
    /// physical memory", far past the core count.  That gap (mem_slots >>
    /// cores) is exactly what makes co-located bursts queue on the CPU.
    pub mem_slots: u32,
    pub inflight: u32,
    pub cache: NodeCache,
}

/// The cluster scheduler: placement decisions + image-distribution
/// bookkeeping.  Pure logic; the DES wiring lives in [`sim`].
pub struct Scheduler {
    pub policy: Policy,
    pub nodes: Vec<Node>,
    pub transfers: u64,
    pub transferred_bytes: u64,
}

/// Outcome of one placement: the chosen node and the bytes that must be
/// pulled before the start can proceed (0 on cache hit).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct PlacementOutcome {
    pub node: usize,
    pub fetch_bytes: u64,
}

impl Scheduler {
    pub fn new(policy: Policy, n_nodes: usize, cores_per_node: u32) -> Scheduler {
        // Default memory headroom: 8 executors per core (128 MB functions
        // on a host with a few GB per core).
        Self::with_mem_slots(policy, n_nodes, cores_per_node, cores_per_node * 8)
    }

    pub fn with_mem_slots(
        policy: Policy,
        n_nodes: usize,
        cores_per_node: u32,
        mem_slots: u32,
    ) -> Scheduler {
        Scheduler {
            policy,
            nodes: (0..n_nodes)
                .map(|id| Node {
                    id,
                    cores: cores_per_node,
                    mem_slots,
                    inflight: 0,
                    cache: NodeCache::new(None),
                })
                .collect(),
            transfers: 0,
            transferred_bytes: 0,
        }
    }

    /// Pre-seed the image on the first `n` nodes.
    pub fn seed_image(&mut self, img: &Image, n: usize) {
        for node in self.nodes.iter_mut().take(n) {
            let _ = node.cache.fetch(img);
        }
    }

    /// Total bytes resident across all node caches.
    pub fn footprint_bytes(&self) -> u64 {
        self.nodes.iter().map(|n| n.cache.used_bytes()).sum()
    }

    fn least_loaded<'a>(&self, candidates: impl Iterator<Item = &'a Node>) -> Option<usize> {
        candidates.min_by_key(|n| (n.inflight, n.id)).map(|n| n.id)
    }

    /// Place one start for `img`; updates in-flight counts and caches.
    pub fn place(&mut self, img: &Image, rng: &mut Rng) -> PlacementOutcome {
        let id = match self.policy {
            Policy::Random => rng.below(self.nodes.len() as u64) as usize,
            Policy::LeastLoaded => self.least_loaded(self.nodes.iter()).unwrap(),
            Policy::Locality => self
                .least_loaded(self.nodes.iter().filter(|n| n.cache.contains(&img.name)))
                .unwrap_or_else(|| self.least_loaded(self.nodes.iter()).unwrap()),
            Policy::CoLocate => {
                // Stay on the cached node while executors still *fit in
                // memory* (Wang et al.), even far past the core count —
                // then spill to the least-loaded node overall.
                let home = self
                    .nodes
                    .iter()
                    .filter(|n| n.cache.contains(&img.name) && n.inflight < n.mem_slots)
                    .map(|n| n.id)
                    .next();
                home.unwrap_or_else(|| self.least_loaded(self.nodes.iter()).unwrap())
            }
        };
        let node = &mut self.nodes[id];
        node.inflight += 1;
        let fetch_bytes = match node.cache.fetch(img) {
            Ok(Some(bytes)) => {
                self.transfers += 1;
                self.transferred_bytes += bytes;
                bytes
            }
            _ => 0,
        };
        PlacementOutcome { node: id, fetch_bytes }
    }

    pub fn complete(&mut self, node: usize) {
        let n = &mut self.nodes[node];
        debug_assert!(n.inflight > 0);
        n.inflight -= 1;
    }

    /// How many distinct nodes ended up caching the image.
    pub fn nodes_with_image(&self, name: &str) -> usize {
        self.nodes.iter().filter(|n| n.cache.contains(name)).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::virt::Tech;

    fn img() -> Image {
        Image::for_function("f", Tech::IncludeOsHvt)
    }

    fn sched(policy: Policy) -> Scheduler {
        let mut s = Scheduler::new(policy, 4, 2);
        s.seed_image(&img(), 1); // image starts on node 0 only
        s
    }

    #[test]
    fn colocate_packs_past_core_count_until_memory() {
        let mut s = sched(Policy::CoLocate); // 2 cores, 16 mem slots
        let mut rng = Rng::new(1);
        // Keeps packing node 0 well beyond its 2 cores (the Wang et al.
        // behaviour that inflates scale-out startup latency)...
        for _ in 0..16 {
            assert_eq!(s.place(&img(), &mut rng).node, 0);
        }
        // ...and only spills once memory slots are exhausted.
        let spill = s.place(&img(), &mut rng);
        assert_ne!(spill.node, 0);
        assert_eq!(spill.fetch_bytes, img().bytes);
    }

    #[test]
    fn locality_prefers_cached_nodes() {
        let mut s = sched(Policy::Locality);
        let mut rng = Rng::new(2);
        for _ in 0..5 {
            // With only node 0 cached, locality keeps hitting node 0 even
            // as load builds (that is its weakness under bursts).
            assert_eq!(s.place(&img(), &mut rng).node, 0);
        }
        assert_eq!(s.transfers, 0);
    }

    #[test]
    fn least_loaded_spreads_and_transfers() {
        let mut s = sched(Policy::LeastLoaded);
        let mut rng = Rng::new(3);
        let nodes: Vec<usize> = (0..4).map(|_| s.place(&img(), &mut rng).node).collect();
        let mut sorted = nodes.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, vec![0, 1, 2, 3], "{nodes:?}");
        assert_eq!(s.transfers, 3); // 3 cache misses
        assert_eq!(s.nodes_with_image("f"), 4);
    }

    #[test]
    fn complete_releases_load() {
        let mut s = sched(Policy::LeastLoaded);
        let mut rng = Rng::new(4);
        let p = s.place(&img(), &mut rng);
        s.complete(p.node);
        assert_eq!(s.nodes[p.node].inflight, 0);
    }

    #[test]
    fn footprint_counts_all_copies() {
        let mut s = sched(Policy::LeastLoaded);
        let mut rng = Rng::new(5);
        for _ in 0..4 {
            s.place(&img(), &mut rng);
        }
        assert_eq!(s.footprint_bytes(), 4 * img().bytes);
    }

    #[test]
    fn random_is_deterministic_per_seed() {
        let run = |seed| {
            let mut s = sched(Policy::Random);
            let mut rng = Rng::new(seed);
            (0..10).map(|_| s.place(&img(), &mut rng).node).collect::<Vec<_>>()
        };
        assert_eq!(run(7), run(7));
        assert_ne!(run(7), run(8));
    }
}
