//! Multi-node cluster substrate (S19) — now a façade over the unified
//! [`crate::platform`] layer.
//!
//! The placement policies, per-node image caches, and the burst
//! scale-out rig (E11) all live in `platform` since the three DES
//! wirings were collapsed; this module re-exports the historical names
//! so existing call sites and docs keep working.

/// Historical alias for the burst-rig wiring.
pub mod sim {
    pub use crate::platform::presets::{run_burst, BurstResult, ClusterConfig};
}

pub use crate::platform::sched::{PlacementOutcome, SchedPolicy as Policy, Scheduler};
pub use sim::{run_burst, BurstResult, ClusterConfig};
