//! Fn-like FaaS platform (S7): the paper's prototype system.
//!
//! The Fn server decomposes into *gateway*, *agent*, and *driver* (§IV-A).
//! We model both drivers the paper compares:
//!
//! * [`DriverKind::DockerWarm`] — the stock Fn path: containers created
//!   through the Docker engine, wrapped by an FDK speaking HTTP over a
//!   unix socket, kept warm in a paused state until an idle timeout
//!   (requires the [`pool::WarmPool`] machinery, per-function monitoring,
//!   and routing to warm executors);
//! * [`DriverKind::IncludeOsCold`] — the paper's contribution: every
//!   request boots a fresh IncludeOS unikernel via solo5-hvt, speaks
//!   stdin/stdout (no FDK), and the unikernel exits on completion — no
//!   lifecycle management at all.

#[allow(clippy::disallowed_types)] // keyed idle/slot maps; iteration audited by detlint DL002
pub mod pool;

/// The DES wiring moved into the unified [`crate::platform`] layer; this
/// alias keeps the historical `fnplat::sim` paths working.
pub mod sim {
    pub use crate::platform::presets::{run_scenario, Load, Scenario, ScenarioResult};
}

pub use pool::{ColdOnly, Dispatch, WarmPool, NO_OWNER};
pub use sim::{run_scenario, Scenario, ScenarioResult};

use crate::sim::{Dist, LockClass, Step};
use crate::virt::Tech;

/// Metadata database backing the Fn server (§IV-B: "we used Postgres ...
/// as we got significant performance improvements compared to the default
/// sqlite option").  sqlite's single writer is a global lock; Postgres
/// costs a bit more CPU per query but doesn't serialize the agent.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DbBackend {
    Sqlite,
    Postgres,
}

impl DbBackend {
    pub fn lookup_steps(&self) -> Vec<Step> {
        match self {
            DbBackend::Sqlite => vec![Step::lock(
                "db-sqlite",
                LockClass::Db,
                Dist::ms(1.1, 0.3),
            )],
            DbBackend::Postgres => vec![
                Step::delay("db-pg-rtt", Dist::ms(0.25, 0.15)),
                Step::cpu("db-pg-query", Dist::ms(0.35, 0.2)),
            ],
        }
    }

    pub fn nominal_ms(&self) -> f64 {
        self.lookup_steps().iter().map(|s| s.dur.median_ns() / 1e6).sum()
    }
}

/// Function runtime driver.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DriverKind {
    /// Docker containers + FDK, kept warm (pause/unpause) until timeout.
    DockerWarm,
    /// IncludeOS unikernel per request over solo5-hvt; exits after reply.
    IncludeOsCold,
}

impl DriverKind {
    pub fn tech(&self) -> Tech {
        match self {
            DriverKind::DockerWarm => Tech::DockerRunc,
            DriverKind::IncludeOsCold => Tech::IncludeOsHvt,
        }
    }

    /// Cold-start pipeline *inside Fn* (Table I: 288.3 ms for Fn Docker —
    /// lower than the 450 ms CLI path because the agent hits the engine
    /// API directly with a prepared config; 33.4 ms for Fn IncludeOS).
    pub fn cold_start_steps(&self) -> Vec<Step> {
        match self {
            DriverKind::DockerWarm => {
                let mut v = vec![
                    Step::lock("engine-serial", LockClass::DockerEngine, Dist::ms(125.0, 0.3)),
                    Step::cpu("containerd", Dist::ms(18.0, 0.12)),
                    Step::cpu("shim-spawn", Dist::ms(14.0, 0.12)),
                    Step::lock("overlay2-mount", LockClass::Mount, Dist::ms(28.0, 0.25)),
                    Step::disk("layer-setup", 4 * 1024 * 1024),
                ];
                v.extend(crate::virt::profiles::namespace_phases(1.0));
                v.extend([
                    Step::cpu("exec-init", Dist::ms(28.0, 0.12)),
                    Step::cpu("fdk-boot", Dist::ms(12.0, 0.12)),
                ]);
                v
            }
            DriverKind::IncludeOsCold => {
                let mut v = Tech::IncludeOsHvt.pipeline();
                // stdio plumbing to the fresh unikernel (no FDK, §IV-A).
                v.push(Step::cpu("stdio-attach", Dist::ms(0.8, 0.2)));
                v
            }
        }
    }

    /// Warm-invoke pipeline (only meaningful for the Docker driver):
    /// unpause the paused container and cross the FDK's unix-socket HTTP hop.
    pub fn warm_invoke_steps(&self) -> Vec<Step> {
        match self {
            DriverKind::DockerWarm => vec![
                Step::cpu("unpause", Dist::ms(1.2, 0.2)),
                Step::cpu("fdk-http-hop", Dist::ms(0.6, 0.2)),
            ],
            DriverKind::IncludeOsCold => Vec::new(),
        }
    }

    /// Specialization pipeline (S23): claim a runtime-warm *universal*
    /// executor that lacks this function's state and install it — the
    /// function-level tail of the cold pipeline, without the engine/
    /// sandbox boot the warm claim already skipped.  Runs after the warm
    /// steps, before execution; a new latency component strictly between
    /// warm and cold.
    pub fn specialize_steps(&self) -> Vec<Step> {
        match self {
            // Spawn the function process inside the already-running
            // container and redo the FDK handshake (same phases as the
            // cold pipeline's tail).
            DriverKind::DockerWarm => vec![
                Step::cpu("exec-init", Dist::ms(28.0, 0.12)),
                Step::cpu("fdk-boot", Dist::ms(12.0, 0.12)),
            ],
            // The shipped unikernel exits on completion, so sharing is a
            // lab what-if (like the E12 paused-unikernel rows): claiming
            // a hypothetically paused image re-attaches stdio.
            DriverKind::IncludeOsCold => vec![Step::cpu("stdio-attach", Dist::ms(0.8, 0.2))],
        }
    }

    pub fn nominal_cold_ms(&self) -> f64 {
        self.cold_start_steps().iter().map(|s| s.dur.median_ns() / 1e6).sum()
    }
}

/// Where the Fn server runs, and what per-request overheads that implies.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Placement {
    /// The paper's local lab machines (Fig 4).
    LocalLab,
    /// AWS m5.metal in eu-north-1 (Table I): EBS-backed storage and the
    /// busier metal host add measurable per-request and per-start cost.
    AwsMetal,
}

impl Placement {
    /// Extra per-request latency on the cloud host (request path through
    /// the busier m5.metal + Postgres-on-box deployment).
    pub fn request_tax_steps(&self) -> Vec<Step> {
        match self {
            Placement::LocalLab => Vec::new(),
            Placement::AwsMetal => vec![Step::delay("cloud-host-tax", Dist::ms(8.5, 0.25))],
        }
    }

    /// Extra per-cold-start cost on the cloud host (EBS-backed image I/O).
    pub fn cold_tax_steps(&self) -> Vec<Step> {
        match self {
            Placement::LocalLab => Vec::new(),
            Placement::AwsMetal => vec![Step::delay("ebs-image-io", Dist::ms(9.0, 0.3))],
        }
    }
}

/// Fn gateway + agent request-path steps shared by both drivers.
pub fn agent_steps(db: DbBackend) -> Vec<Step> {
    let mut v = vec![
        Step::cpu("http-parse", Dist::ms(0.35, 0.2)),
        Step::cpu("agent-route", Dist::ms(0.55, 0.2)),
    ];
    v.extend(db.lookup_steps());
    v
}

/// Function-body execution cost (ms) for the deployed test function.
/// The DES uses a constant measured from the live PJRT runtime (see
/// `runtime::measured_exec_ms`); the default mirrors the paper's Go echo.
pub const DEFAULT_EXEC_MS: f64 = 0.8;

pub fn exec_step(exec_ms: f64) -> Step {
    Step::cpu("fn-exec", Dist::ms(exec_ms, 0.15))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fn_docker_cold_near_table1() {
        // Table I: 288.3 ms total; subtract request-path + taxes ≈ 270 here.
        let ms = DriverKind::DockerWarm.nominal_cold_ms();
        assert!((240.0..285.0).contains(&ms), "fn docker cold {ms}");
    }

    #[test]
    fn fn_includeos_cold_order_of_magnitude_faster() {
        let d = DriverKind::DockerWarm.nominal_cold_ms();
        let i = DriverKind::IncludeOsCold.nominal_cold_ms();
        assert!(d / i > 10.0, "docker {d} vs includeos {i}");
    }

    #[test]
    fn includeos_has_no_warm_path() {
        assert!(DriverKind::IncludeOsCold.warm_invoke_steps().is_empty());
        assert!(!DriverKind::DockerWarm.warm_invoke_steps().is_empty());
    }

    #[test]
    fn specialization_cost_sits_between_warm_and_cold() {
        let sum_ms =
            |steps: Vec<Step>| -> f64 { steps.iter().map(|s| s.dur.median_ns() / 1e6).sum() };
        for d in [DriverKind::DockerWarm, DriverKind::IncludeOsCold] {
            let warm = sum_ms(d.warm_invoke_steps());
            let spec = sum_ms(d.specialize_steps());
            let cold = d.nominal_cold_ms();
            assert!(spec > 0.0, "{d:?} must price specialization");
            assert!(warm + spec < cold, "{d:?}: warm {warm} + spec {spec} !< cold {cold}");
        }
    }

    #[test]
    fn postgres_beats_sqlite_under_no_contention_is_false() {
        // Single-shot sqlite is *cheaper*; the win is concurrency (no
        // global write lock).  That's exactly why the paper saw gains only
        // under load — asserted end-to-end in the db ablation bench.
        assert!(DbBackend::Sqlite.nominal_ms() > DbBackend::Postgres.nominal_ms() * 0.5);
    }

    #[test]
    fn cloud_taxes_only_on_aws() {
        assert!(Placement::LocalLab.request_tax_steps().is_empty());
        assert!(Placement::LocalLab.cold_tax_steps().is_empty());
        assert_eq!(Placement::AwsMetal.request_tax_steps().len(), 1);
    }
}
