//! Warm-executor pool bookkeeping — the machinery the paper argues a
//! cold-only platform can delete (§I, §IV).
//!
//! Pure logic (no simulator dependency): used by both the DES experiments
//! and the live coordinator.  Tracks, per function, the idle warm
//! executors, their idle-timeout expiry, and the headline waste metric —
//! **idle memory-seconds** — plus the monitoring-event count that stands
//! for the per-function load-tracking complexity of warm platforms.

use std::collections::{HashMap, VecDeque};

#[derive(Clone, Copy, Debug)]
struct WarmSlot {
    idle_since_ns: u64,
    /// Absolute teardown deadline.  The classic pool sets this to
    /// `idle_since + idle_timeout`; lifecycle policies ([`crate::policy`])
    /// pick a per-release deadline instead.
    expires_at_ns: u64,
}

/// Outcome of a dispatch attempt.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dispatch {
    /// A warm executor was claimed (unpause + reuse path).
    Warm,
    /// No warm executor: a cold start is required.
    Cold,
}

#[derive(Clone, Debug)]
pub struct WarmPool {
    /// Idle timeout before a warm executor is torn down.
    pub idle_timeout_ns: u64,
    /// Resident bytes one warm executor holds while idle.
    pub mem_bytes_per_slot: u64,
    /// Liveness-poll period for idle executors (monitoring complexity).
    pub poll_period_ns: u64,
    idle: HashMap<String, VecDeque<WarmSlot>>,
    /// Total executors alive (idle + busy) per function.
    alive: HashMap<String, u64>,
    // --- accounting ---
    pub idle_mem_byte_ns: u128,
    pub monitor_events: u64,
    pub warm_hits: u64,
    pub cold_starts: u64,
    pub expirations: u64,
    /// Executors torn down immediately after serving (cold-only policies).
    pub retirements: u64,
    /// Idle executors destroyed by node crashes (fault injection).
    pub crash_drains: u64,
}

impl WarmPool {
    pub fn new(idle_timeout_ns: u64, mem_bytes_per_slot: u64) -> WarmPool {
        WarmPool {
            idle_timeout_ns,
            mem_bytes_per_slot,
            poll_period_ns: 1_000_000_000, // 1 s liveness poll
            idle: HashMap::new(),
            alive: HashMap::new(),
            idle_mem_byte_ns: 0,
            monitor_events: 0,
            warm_hits: 0,
            cold_starts: 0,
            expirations: 0,
            retirements: 0,
            crash_drains: 0,
        }
    }

    fn account_idle(&mut self, idle_ns: u64) {
        self.idle_mem_byte_ns += idle_ns as u128 * self.mem_bytes_per_slot as u128;
        self.monitor_events += idle_ns / self.poll_period_ns;
    }

    /// Drop idle slots whose deadline has passed by `now`.  Deadlines are
    /// per-slot (policies may vary them release to release), so this scans
    /// the whole queue rather than popping an ordered front.
    fn expire(&mut self, func: &str, now: u64) {
        let Some(q) = self.idle.get_mut(func) else { return };
        let mut charges: Vec<u64> = Vec::new();
        let mut i = 0;
        while i < q.len() {
            if q[i].expires_at_ns <= now {
                let s = q.remove(i).expect("index in range");
                charges.push(s.expires_at_ns.saturating_sub(s.idle_since_ns));
            } else {
                i += 1;
            }
        }
        if !charges.is_empty() {
            self.expirations += charges.len() as u64;
            let a = self.alive.get_mut(func).expect("alive entry");
            *a -= (charges.len() as u64).min(*a);
            for c in charges {
                self.account_idle(c);
            }
        }
    }

    /// Try to claim a warm executor for `func` at `now`.
    pub fn dispatch(&mut self, func: &str, now: u64) -> Dispatch {
        self.expire(func, now);
        let slot = self.idle.get_mut(func).and_then(|q| q.pop_back());
        match slot {
            Some(s) => {
                // LIFO claim (most recently idle): matches Fn's behaviour
                // and maximizes expiry of the cold tail.
                self.account_idle(now - s.idle_since_ns);
                self.warm_hits += 1;
                Dispatch::Warm
            }
            None => {
                self.cold_starts += 1;
                *self.alive.entry(func.to_string()).or_insert(0) += 1;
                Dispatch::Cold
            }
        }
    }

    /// Return an executor to the idle pool after it served a request,
    /// retained until the pool-wide idle timeout.
    pub fn release(&mut self, func: &str, now: u64) {
        let expires = now.saturating_add(self.idle_timeout_ns);
        self.release_until(func, now, expires);
    }

    /// Return an executor to the idle pool with an explicit teardown
    /// deadline (lifecycle-policy path: the deadline is per release).
    pub fn release_until(&mut self, func: &str, now: u64, expires_at_ns: u64) {
        self.idle
            .entry(func.to_string())
            .or_default()
            .push_back(WarmSlot { idle_since_ns: now, expires_at_ns });
    }

    /// Tear an executor down immediately after it served (the cold-only
    /// lifecycle): nothing idles, nothing is charged.
    pub fn retire(&mut self, func: &str) {
        if let Some(a) = self.alive.get_mut(func) {
            *a = a.saturating_sub(1);
        }
        self.retirements += 1;
    }

    /// Pre-create `n` warm executors (measurement warmup), retained until
    /// the pool-wide idle timeout.
    pub fn prewarm(&mut self, func: &str, n: u64, now: u64) {
        let expires = now.saturating_add(self.idle_timeout_ns);
        self.prewarm_until(func, n, now, expires);
    }

    /// Pre-create `n` warm executors with an explicit teardown deadline
    /// (predictive-prewarm policies).
    pub fn prewarm_until(&mut self, func: &str, n: u64, now: u64, expires_at_ns: u64) {
        *self.alive.entry(func.to_string()).or_insert(0) += n;
        let q = self.idle.entry(func.to_string()).or_default();
        for _ in 0..n {
            q.push_back(WarmSlot { idle_since_ns: now, expires_at_ns });
        }
    }

    pub fn idle_count(&self, func: &str) -> usize {
        self.idle.get(func).map_or(0, |q| q.len())
    }

    /// Idle warm executors still live at `now` (expires stale slots first).
    /// Used by the platform router to decide warm routing before claiming.
    pub fn warm_available(&mut self, func: &str, now: u64) -> usize {
        self.expire(func, now);
        self.idle_count(func)
    }

    pub fn alive_count(&self, func: &str) -> u64 {
        self.alive.get(func).copied().unwrap_or(0)
    }

    /// Account all still-idle slots up to `now` (end of run).
    pub fn finalize(&mut self, now: u64) {
        let funcs: Vec<String> = self.idle.keys().cloned().collect();
        for f in funcs {
            self.expire(&f, now);
            if let Some(q) = self.idle.get_mut(&f) {
                let slots: Vec<WarmSlot> = q.drain(..).collect();
                for s in slots {
                    let idle_ns = now.min(s.expires_at_ns).saturating_sub(s.idle_since_ns);
                    self.account_idle(idle_ns);
                }
            }
        }
    }

    /// Account every remaining idle slot up to its *full* deadline: after
    /// the measurement ends the platform will keep it resident until expiry
    /// regardless (how AWS's ~27 min keep-alive turns one invocation into
    /// hundreds of GB·s of waste).
    pub fn finalize_expiring(&mut self) {
        let funcs: Vec<String> = self.idle.keys().cloned().collect();
        for f in funcs {
            if let Some(q) = self.idle.get_mut(&f) {
                let slots: Vec<WarmSlot> = q.drain(..).collect();
                let n = slots.len() as u64;
                self.expirations += n;
                if let Some(a) = self.alive.get_mut(&f) {
                    *a -= n.min(*a);
                }
                for s in slots {
                    self.account_idle(s.expires_at_ns.saturating_sub(s.idle_since_ns));
                }
            }
        }
    }

    /// The node under this pool crashed at `now`: every idle executor
    /// dies with it.  Idle time actually accrued up to the crash is still
    /// charged (the memory *was* resident), the slots count as
    /// crash-drained rather than expired, and the alive counts reset —
    /// after a restart the platform has no warm state here to route to.
    /// Returns the number of warm slots destroyed.
    pub fn crash(&mut self, now: u64) -> u64 {
        let funcs: Vec<String> = self.idle.keys().cloned().collect();
        let mut dropped = 0u64;
        for f in funcs {
            if let Some(q) = self.idle.get_mut(&f) {
                let slots: Vec<WarmSlot> = q.drain(..).collect();
                dropped += slots.len() as u64;
                for s in slots {
                    let idle_ns = now.min(s.expires_at_ns).saturating_sub(s.idle_since_ns);
                    self.account_idle(idle_ns);
                }
            }
        }
        // Busy executors die too (their in-flight requests are killed by
        // the caller); nothing survives on the node.
        self.alive.clear();
        self.crash_drains += dropped;
        dropped
    }

    /// Headline waste metric in gigabyte-seconds.
    pub fn idle_gb_seconds(&self) -> f64 {
        self.idle_mem_byte_ns as f64 / 1e9 / (1u64 << 30) as f64
    }
}

/// A cold-only "pool" for symmetry: every dispatch is cold, nothing is
/// retained, waste is identically zero (the unikernel exits on completion).
#[derive(Clone, Debug, Default)]
pub struct ColdOnly {
    pub starts: u64,
}

impl ColdOnly {
    pub fn dispatch(&mut self) -> Dispatch {
        self.starts += 1;
        Dispatch::Cold
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const S: u64 = 1_000_000_000;

    fn pool() -> WarmPool {
        WarmPool::new(30 * S, 16 << 20) // 30 s timeout, 16 MiB per slot
    }

    #[test]
    fn first_dispatch_is_cold() {
        let mut p = pool();
        assert_eq!(p.dispatch("f", 0), Dispatch::Cold);
        assert_eq!(p.cold_starts, 1);
    }

    #[test]
    fn release_then_dispatch_is_warm() {
        let mut p = pool();
        assert_eq!(p.dispatch("f", 0), Dispatch::Cold);
        p.release("f", 5 * S);
        assert_eq!(p.dispatch("f", 6 * S), Dispatch::Warm);
        assert_eq!(p.warm_hits, 1);
        // 1 s idle at 16 MiB accounted.
        assert_eq!(p.idle_mem_byte_ns, (1 * S) as u128 * (16 << 20) as u128);
    }

    #[test]
    fn timeout_expires_warm_slot() {
        let mut p = pool();
        p.dispatch("f", 0);
        p.release("f", 0);
        // 31 s later: slot expired, dispatch is cold again.
        assert_eq!(p.dispatch("f", 31 * S), Dispatch::Cold);
        assert_eq!(p.expirations, 1);
        // Expired slot wasted exactly `timeout` of memory time.
        assert_eq!(p.idle_mem_byte_ns, (30 * S) as u128 * (16 << 20) as u128);
    }

    #[test]
    fn per_function_isolation() {
        let mut p = pool();
        p.dispatch("f", 0);
        p.release("f", 0);
        assert_eq!(p.dispatch("g", 1), Dispatch::Cold);
        assert_eq!(p.dispatch("f", 1), Dispatch::Warm);
    }

    #[test]
    fn lifo_claim_lets_tail_expire() {
        let mut p = pool();
        p.prewarm("f", 2, 0);
        // Claim at t=1s takes the most recent; the other keeps aging.
        assert_eq!(p.dispatch("f", S), Dispatch::Warm);
        p.release("f", 2 * S);
        assert_eq!(p.idle_count("f"), 2);
        // At t=35s the t=0 slot expired; one release-refreshed slot left.
        p.expire("f", 35 * S);
        assert_eq!(p.idle_count("f"), 0); // 2s + 30s = 32s < 35s: both gone
        assert_eq!(p.expirations, 2);
    }

    #[test]
    fn prewarm_counts_alive() {
        let mut p = pool();
        p.prewarm("f", 10, 0);
        assert_eq!(p.alive_count("f"), 10);
        assert_eq!(p.idle_count("f"), 10);
    }

    #[test]
    fn monitor_events_grow_with_idle_time() {
        let mut p = pool();
        p.dispatch("f", 0);
        p.release("f", 0);
        p.dispatch("f", 10 * S); // 10 s idle => 10 poll events
        assert_eq!(p.monitor_events, 10);
    }

    #[test]
    fn finalize_accounts_remaining_idle() {
        let mut p = pool();
        p.dispatch("f", 0);
        p.release("f", 0);
        p.finalize(5 * S);
        assert_eq!(p.idle_mem_byte_ns, (5 * S) as u128 * (16 << 20) as u128);
    }

    #[test]
    fn finalize_caps_at_timeout() {
        let mut p = pool();
        p.dispatch("f", 0);
        p.release("f", 0);
        p.finalize(500 * S);
        // Slot would have expired at 30 s: waste capped there.
        assert_eq!(p.idle_mem_byte_ns, (30 * S) as u128 * (16 << 20) as u128);
    }

    #[test]
    fn release_until_overrides_pool_timeout() {
        let mut p = pool(); // pool-wide timeout is 30 s
        p.dispatch("f", 0);
        // Policy keeps this slot only 2 s.
        p.release_until("f", 0, 2 * S);
        assert_eq!(p.dispatch("f", 3 * S), Dispatch::Cold);
        assert_eq!(p.expirations, 1);
        assert_eq!(p.idle_mem_byte_ns, (2 * S) as u128 * (16 << 20) as u128);
    }

    #[test]
    fn per_slot_deadlines_expire_out_of_order() {
        let mut p = pool();
        p.dispatch("f", 0);
        p.dispatch("f", 0);
        // Older release has the *longer* deadline: the scan must still
        // expire the younger slot first.
        p.release_until("f", 0, 100 * S);
        p.release_until("f", 1 * S, 5 * S);
        p.expire("f", 6 * S);
        assert_eq!(p.idle_count("f"), 1);
        assert_eq!(p.expirations, 1);
        // Expired slot idled from 1 s to its 5 s deadline.
        assert_eq!(p.idle_mem_byte_ns, (4 * S) as u128 * (16 << 20) as u128);
    }

    #[test]
    fn retire_drops_executor_without_idle_charge() {
        let mut p = pool();
        p.dispatch("f", 0);
        assert_eq!(p.alive_count("f"), 1);
        p.retire("f");
        assert_eq!(p.alive_count("f"), 0);
        assert_eq!(p.retirements, 1);
        assert_eq!(p.idle_mem_byte_ns, 0);
        assert_eq!(p.dispatch("f", 5 * S), Dispatch::Cold);
    }

    #[test]
    fn prewarm_until_claim_before_deadline_is_warm() {
        let mut p = pool();
        p.prewarm_until("f", 1, 10 * S, 20 * S);
        assert_eq!(p.dispatch("f", 15 * S), Dispatch::Warm);
        assert_eq!(p.idle_mem_byte_ns, (5 * S) as u128 * (16 << 20) as u128);
        p.prewarm_until("f", 1, 30 * S, 40 * S);
        assert_eq!(p.dispatch("f", 41 * S), Dispatch::Cold);
        assert_eq!(p.expirations, 1);
    }

    #[test]
    fn finalize_caps_at_per_slot_deadline() {
        let mut p = pool();
        p.dispatch("f", 0);
        p.release_until("f", 0, 7 * S);
        let mut q = p.clone();
        // Finalize before the deadline: charge only elapsed idle time.
        q.finalize(3 * S);
        assert_eq!(q.idle_mem_byte_ns, (3 * S) as u128 * (16 << 20) as u128);
        // Finalize after: charge up to the deadline, not the wall clock.
        p.finalize(500 * S);
        assert_eq!(p.idle_mem_byte_ns, (7 * S) as u128 * (16 << 20) as u128);
    }

    #[test]
    fn warm_available_expires_before_counting() {
        let mut p = pool();
        p.dispatch("f", 0);
        p.release_until("f", 0, 5 * S);
        assert_eq!(p.warm_available("f", 3 * S), 1);
        assert_eq!(p.warm_available("f", 6 * S), 0);
        assert_eq!(p.expirations, 1);
    }

    #[test]
    fn crash_drains_idle_slots_and_charges_accrued_time() {
        let mut p = pool();
        p.prewarm("f", 2, 0);
        p.dispatch("g", 0);
        p.release("g", 0);
        assert_eq!(p.crash(5 * S), 3);
        assert_eq!(p.crash_drains, 3);
        assert_eq!(p.idle_count("f") + p.idle_count("g"), 0);
        assert_eq!(p.alive_count("f") + p.alive_count("g"), 0);
        // Each slot idled 5 s before the crash; no expiration recorded.
        assert_eq!(p.idle_mem_byte_ns, 3 * (5 * S) as u128 * (16 << 20) as u128);
        assert_eq!(p.expirations, 0);
        // Everything after the crash starts cold.
        assert_eq!(p.dispatch("f", 6 * S), Dispatch::Cold);
    }

    #[test]
    fn cold_only_never_warm_and_zero_waste() {
        let mut c = ColdOnly::default();
        for _ in 0..100 {
            assert_eq!(c.dispatch(), Dispatch::Cold);
        }
        assert_eq!(c.starts, 100);
    }

    #[test]
    fn idle_gb_seconds_units() {
        let mut p = WarmPool::new(3600 * S, 1 << 30); // 1 GiB slots
        p.dispatch("f", 0);
        p.release("f", 0);
        p.dispatch("f", 10 * S);
        assert!((p.idle_gb_seconds() - 10.0).abs() < 1e-9);
    }
}
