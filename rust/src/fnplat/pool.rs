//! Warm-executor pool bookkeeping — the machinery the paper argues a
//! cold-only platform can delete (§I, §IV).
//!
//! Pure logic (no simulator dependency): used by both the DES experiments
//! and the live coordinator.  Tracks, per **sharing key**, the idle warm
//! executors, their idle-timeout expiry, and the headline waste metric —
//! **idle memory-seconds** — plus the monitoring-event count that stands
//! for the per-function load-tracking complexity of warm platforms.
//!
//! A sharing key (S23) is the string slots are pooled and claimed under.
//! The classic per-function pool uses the function name itself — that is
//! what every legacy wrapper ([`WarmPool::dispatch`],
//! [`WarmPool::release_until`], …) does — while the universal-worker
//! modes pool slots under a runtime key any compatible function may
//! claim.  Each slot remembers the *owner* function that released it:
//! claiming a slot whose owner matches is a plain warm hit, claiming one
//! released by a different function is a [`Dispatch::Specialized`] claim
//! (runtime warm, function state cold — the caller pays the driver's
//! specialization pipeline).  A claim never crosses sharing keys.
//!
//! Slots are kept in two orders at once: a LIFO claim order (dispatch
//! takes the most recently idled executor, matching Fn) and a
//! deadline-ordered min-heap for expiry — so `warm_available`/`dispatch`
//! do O(log n) amortized work instead of the remove-in-place scan the
//! pool used to run over the whole queue on every call.  A claimed slot
//! leaves a stale heap entry behind; expiry skips those lazily.  The
//! observable accounting (which slot expires, when it is charged, every
//! counter) is identical to the scan implementation: charges depend only
//! on each slot's `(idle_since, expires_at)` pair, never on when the
//! purge happens to run.

use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap};

use crate::sim::snap::{Dec, Enc};

/// Owner tag for slots that belong to no particular function: everything
/// released through the legacy per-function wrappers (whose bucket *is*
/// the function, so every claim matches trivially) and runtime-level
/// universal pre-warms (no function state installed yet — any keyed
/// claim of such a slot is a specialization).
pub const NO_OWNER: u32 = u32::MAX;

#[derive(Clone, Copy, Debug)]
struct WarmSlot {
    idle_since_ns: u64,
    /// Absolute teardown deadline.  The classic pool sets this to
    /// `idle_since + idle_timeout`; lifecycle policies ([`crate::policy`])
    /// pick a per-release deadline instead.
    expires_at_ns: u64,
    /// Function whose state the idle executor holds ([`NO_OWNER`] when
    /// none): decides warm-vs-specialized at claim time.
    owner: u32,
}

/// Pool-wide idle-slot storage, struct-of-arrays with generational
/// handles (S26).  A handle packs `(generation << 32) | index`; removing
/// a slot bumps its generation, so every handle left behind in a LIFO
/// stack or deadline heap becomes a tombstone detectable in O(1) — the
/// role the per-key `HashMap<serial, WarmSlot>` membership check used to
/// play, without the hashing or the per-key allocation.  Freed indices
/// recycle through a free list, bounding the arena by peak idle
/// occupancy.
#[derive(Clone, Debug, Default)]
struct SlotArena {
    idle_since_ns: Vec<u64>,
    expires_at_ns: Vec<u64>,
    owner: Vec<u32>,
    gen: Vec<u32>,
    free: Vec<u32>,
}

impl SlotArena {
    fn alloc(&mut self, slot: WarmSlot) -> u64 {
        let idx = if let Some(idx) = self.free.pop() {
            let i = idx as usize;
            self.idle_since_ns[i] = slot.idle_since_ns;
            self.expires_at_ns[i] = slot.expires_at_ns;
            self.owner[i] = slot.owner;
            idx
        } else {
            self.idle_since_ns.push(slot.idle_since_ns);
            self.expires_at_ns.push(slot.expires_at_ns);
            self.owner.push(slot.owner);
            self.gen.push(0);
            (self.idle_since_ns.len() - 1) as u32
        };
        ((self.gen[idx as usize] as u64) << 32) | idx as u64
    }

    fn is_live(&self, handle: u64) -> bool {
        let idx = handle as u32 as usize;
        (handle >> 32) as u32 == self.gen[idx]
    }

    fn owner_of(&self, handle: u64) -> u32 {
        debug_assert!(self.is_live(handle));
        self.owner[handle as u32 as usize]
    }

    /// Claim/expire a slot: returns its fields and tombstones the handle
    /// (generation bump), or `None` if the handle was already stale.
    fn remove(&mut self, handle: u64) -> Option<WarmSlot> {
        if !self.is_live(handle) {
            return None;
        }
        let i = handle as u32 as usize;
        self.gen[i] = self.gen[i].wrapping_add(1);
        self.free.push(handle as u32);
        Some(WarmSlot {
            idle_since_ns: self.idle_since_ns[i],
            expires_at_ns: self.expires_at_ns[i],
            owner: self.owner[i],
        })
    }
}

/// Idle slots of one sharing key: claim order (LIFO, newest at the
/// back), deadline order for expiry, and the live-slot count.  Both
/// orders hold arena handles; entries whose handle went stale (claimed
/// or expired elsewhere) are skipped lazily via the generation check.
#[derive(Clone, Debug, Default)]
struct FuncSlots {
    lifo: Vec<u64>,
    by_deadline: BinaryHeap<Reverse<(u64, u64)>>,
    live: usize,
}

impl FuncSlots {
    /// Drop stale lifo entries once they dominate the vector, so a
    /// long-lived function cannot accumulate unbounded tombstones.
    fn compact(&mut self, arena: &SlotArena) {
        if self.lifo.len() > 4 * self.live + 16 {
            self.lifo.retain(|&h| arena.is_live(h));
        }
    }
}

/// Outcome of a dispatch attempt.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dispatch {
    /// A warm executor holding this function's state was claimed
    /// (unpause + reuse path).
    Warm,
    /// A runtime-compatible warm executor was claimed, but it belongs to
    /// a different function (or to none): the runtime is warm, the
    /// function state is cold — the caller pays the specialization
    /// pipeline, between warm and cold (S23).
    Specialized,
    /// No warm executor: a cold start is required.
    Cold,
}

#[derive(Clone, Debug)]
pub struct WarmPool {
    /// Idle timeout before a warm executor is torn down.
    pub idle_timeout_ns: u64,
    /// Resident bytes one warm executor holds while idle.
    pub mem_bytes_per_slot: u64,
    /// Liveness-poll period for idle executors (monitoring complexity).
    pub poll_period_ns: u64,
    /// Idle slots per sharing key (the function name in the classic
    /// exclusive pool).  Orders only — the slot fields live in `slots`.
    idle: HashMap<String, FuncSlots>,
    /// Pool-wide SoA slot storage (S26), shared across sharing keys.
    slots: SlotArena,
    /// Total executors alive (idle + busy) per sharing key.
    alive: HashMap<String, u64>,
    /// Idle warm executors currently enqueued across all keys (gauge for
    /// telemetry; a slot counts as live until a claim, expiry sweep, or
    /// drain removes it).
    idle_live: u64,
    // --- accounting ---
    pub idle_mem_byte_ns: u128,
    /// Liveness polls the platform would have issued against idle warm
    /// executors (`idle time / poll period`) — the paper's "extensive
    /// monitoring requirements" priced as a count.  This is platform
    /// *work the warm pool causes*, distinct from both the engine's event
    /// count and the telemetry layer's own sample count (S25): cold-only
    /// presets keep it at zero because nothing ever idles.
    pub monitor_events: u64,
    pub warm_hits: u64,
    /// Claims of a runtime-warm slot owned by a different function
    /// (universal-worker sharing): `warm_hits + specializations +
    /// cold_starts` equals the number of dispatches.
    pub specializations: u64,
    pub cold_starts: u64,
    pub expirations: u64,
    /// Executors torn down immediately after serving (cold-only policies).
    pub retirements: u64,
    /// Idle executors destroyed by node crashes (fault injection).
    pub crash_drains: u64,
}

impl WarmPool {
    pub fn new(idle_timeout_ns: u64, mem_bytes_per_slot: u64) -> WarmPool {
        WarmPool {
            idle_timeout_ns,
            mem_bytes_per_slot,
            poll_period_ns: 1_000_000_000, // 1 s liveness poll
            idle: HashMap::new(),
            slots: SlotArena::default(),
            alive: HashMap::new(),
            idle_live: 0,
            idle_mem_byte_ns: 0,
            monitor_events: 0,
            warm_hits: 0,
            specializations: 0,
            cold_starts: 0,
            expirations: 0,
            retirements: 0,
            crash_drains: 0,
        }
    }

    fn account_idle(&mut self, idle_ns: u64) {
        self.idle_mem_byte_ns += idle_ns as u128 * self.mem_bytes_per_slot as u128;
        self.monitor_events += idle_ns / self.poll_period_ns;
    }

    fn insert_slot(&mut self, func: &str, slot: WarmSlot) {
        let handle = self.slots.alloc(slot);
        self.idle_live += 1;
        let fs = self.idle.entry(func.to_string()).or_default();
        fs.lifo.push(handle);
        fs.by_deadline.push(Reverse((slot.expires_at_ns, handle)));
        fs.live += 1;
    }

    /// Drop idle slots whose deadline has passed by `now`: pop the
    /// deadline heap until its head is still live, skipping entries whose
    /// slot was already claimed.
    fn expire(&mut self, func: &str, now: u64) {
        let Some(fs) = self.idle.get_mut(func) else { return };
        let arena = &mut self.slots;
        let mut charges: Vec<u64> = Vec::new();
        while let Some(&Reverse((expires_at_ns, handle))) = fs.by_deadline.peek() {
            if expires_at_ns > now {
                break;
            }
            fs.by_deadline.pop();
            if let Some(s) = arena.remove(handle) {
                charges.push(s.expires_at_ns.saturating_sub(s.idle_since_ns));
            }
        }
        if !charges.is_empty() {
            fs.live -= charges.len();
            fs.compact(arena);
            self.idle_live -= charges.len() as u64;
            self.expirations += charges.len() as u64;
            let a = self.alive.get_mut(func).expect("alive entry");
            *a -= (charges.len() as u64).min(*a);
            for c in charges {
                self.account_idle(c);
            }
        }
    }

    /// Try to claim a warm executor for `func` at `now` (the classic
    /// exclusive pool: the sharing key *is* the function, so a claim is
    /// always a plain warm hit).
    pub fn dispatch(&mut self, func: &str, now: u64) -> Dispatch {
        self.dispatch_shared(func, NO_OWNER, now)
    }

    /// Try to claim a warm executor from the `key` bucket on behalf of
    /// function `owner` at `now`.  A claim whose slot owner matches is a
    /// warm hit; a mismatch is a [`Dispatch::Specialized`] claim (the
    /// runtime is warm, the function state is not).  The bucket is
    /// searched **owner-first**: a slot already holding this function's
    /// state is claimed (newest first) before any foreign slot — a real
    /// universal-worker runtime never pays specialization while a free
    /// matching worker idles — and only then does the newest foreign
    /// slot get claimed and specialized.  Claims never cross sharing
    /// keys: an empty bucket is a cold start no matter how warm the
    /// other buckets are.
    pub fn dispatch_shared(&mut self, key: &str, owner: u32, now: u64) -> Dispatch {
        self.expire(key, now);
        // LIFO claim (most recently idle): matches Fn's behaviour and
        // maximizes expiry of the cold tail.  Pops stale handles as it
        // walks down.
        let arena = &mut self.slots;
        let slot = self.idle.get_mut(key).and_then(|fs| {
            // Drop stale tombstones off the top of the claim stack.
            while let Some(&top) = fs.lifo.last() {
                if arena.is_live(top) {
                    break;
                }
                fs.lifo.pop();
            }
            let &top = fs.lifo.last()?;
            // In the exclusive pool every slot matches the claimant, so
            // this is the plain LIFO pop, bit for bit.
            if arena.owner_of(top) == owner {
                fs.lifo.pop();
                fs.live -= 1;
                return arena.remove(top);
            }
            let own = fs
                .lifo
                .iter()
                .rev()
                .find(|&&h| arena.is_live(h) && arena.owner_of(h) == owner)
                .copied();
            match own {
                // Mid-stack same-owner claim: the lifo entry stays
                // behind as a lazy tombstone (compacted like every other
                // stale entry).
                Some(h) => {
                    let claimed = arena.remove(h);
                    fs.live -= 1;
                    fs.compact(arena);
                    claimed
                }
                // No slot holds this function's state: claim the newest
                // runtime-warm worker and pay specialization.
                None => {
                    fs.lifo.pop();
                    fs.live -= 1;
                    arena.remove(top)
                }
            }
        });
        match slot {
            Some(s) => {
                self.idle_live -= 1;
                self.account_idle(now - s.idle_since_ns);
                if s.owner == owner {
                    self.warm_hits += 1;
                    Dispatch::Warm
                } else {
                    self.specializations += 1;
                    Dispatch::Specialized
                }
            }
            None => {
                self.cold_starts += 1;
                *self.alive.entry(key.to_string()).or_insert(0) += 1;
                Dispatch::Cold
            }
        }
    }

    /// Return an executor to the idle pool after it served a request,
    /// retained until the pool-wide idle timeout.
    pub fn release(&mut self, func: &str, now: u64) {
        let expires = now.saturating_add(self.idle_timeout_ns);
        self.release_until(func, now, expires);
    }

    /// Return an executor to the idle pool with an explicit teardown
    /// deadline (lifecycle-policy path: the deadline is per release).  A
    /// deadline at or before `now` means the slot is dead on arrival:
    /// retire the executor immediately instead of enqueuing a slot that
    /// would count a spurious expiration with zero idle charge.
    pub fn release_until(&mut self, func: &str, now: u64, expires_at_ns: u64) {
        self.release_shared_until(func, NO_OWNER, now, expires_at_ns);
    }

    /// Return function `owner`'s executor to the `key` bucket with an
    /// explicit teardown deadline: the slot keeps `owner`'s state, so a
    /// later same-owner claim is warm while any other claim specializes.
    pub fn release_shared_until(&mut self, key: &str, owner: u32, now: u64, expires_at_ns: u64) {
        if expires_at_ns <= now {
            self.retire(key);
            return;
        }
        self.insert_slot(key, WarmSlot { idle_since_ns: now, expires_at_ns, owner });
    }

    /// Tear an executor down immediately after it served (the cold-only
    /// lifecycle): nothing idles, nothing is charged.  Only a real
    /// teardown counts: with no live executor there is nothing to retire.
    /// Keyed like everything else: the exclusive pool passes the function
    /// name, the sharing modes their runtime key.
    pub fn retire(&mut self, func: &str) {
        let alive = self.alive.get_mut(func).filter(|a| **a > 0);
        debug_assert!(alive.is_some(), "retire('{func}') without a live executor");
        if let Some(a) = alive {
            *a -= 1;
            self.retirements += 1;
        }
    }

    /// Pre-create `n` warm executors (measurement warmup), retained until
    /// the pool-wide idle timeout.
    pub fn prewarm(&mut self, func: &str, n: u64, now: u64) {
        let expires = now.saturating_add(self.idle_timeout_ns);
        self.prewarm_until(func, n, now, expires);
    }

    /// Pre-create `n` warm executors with an explicit teardown deadline
    /// (predictive-prewarm policies).
    pub fn prewarm_until(&mut self, func: &str, n: u64, now: u64, expires_at_ns: u64) {
        self.prewarm_shared_until(func, NO_OWNER, n, now, expires_at_ns);
    }

    /// Pre-create `n` warm executors in the `key` bucket holding
    /// `owner`'s function state ([`NO_OWNER`] for runtime-level universal
    /// workers that any function must specialize before use).
    pub fn prewarm_shared_until(
        &mut self,
        key: &str,
        owner: u32,
        n: u64,
        now: u64,
        expires_at_ns: u64,
    ) {
        *self.alive.entry(key.to_string()).or_insert(0) += n;
        for _ in 0..n {
            self.insert_slot(key, WarmSlot { idle_since_ns: now, expires_at_ns, owner });
        }
    }

    pub fn idle_count(&self, func: &str) -> usize {
        self.idle.get(func).map_or(0, |fs| fs.live)
    }

    /// Idle warm executors still live at `now` (expires stale slots first).
    /// Used by the platform router to decide warm routing before claiming.
    pub fn warm_available(&mut self, func: &str, now: u64) -> usize {
        self.expire(func, now);
        self.idle_count(func)
    }

    /// Sharing keys (function names in the exclusive pool) that may still
    /// hold idle slots (a superset: keys survive until the map entry is
    /// dropped).  Lets the platform's warm index seed its candidate sets
    /// from a pre-populated pool.
    pub fn warm_funcs(&self) -> impl Iterator<Item = &str> {
        // detlint: allow(DL002) superset iterator; consumer inserts into BTreeSets
        self.idle.iter().filter(|(_, fs)| fs.live > 0).map(|(k, _)| k.as_str())
    }

    pub fn alive_count(&self, func: &str) -> u64 {
        self.alive.get(func).copied().unwrap_or(0)
    }

    /// Drain every live slot of one key out of the arena, clearing both
    /// orders.  The LIFO stack is a superset of the live set (claims
    /// leave tombstones, never drop live handles), so removing each
    /// still-live handle visits every slot exactly once.
    fn drain_key(fs: &mut FuncSlots, arena: &mut SlotArena) -> Vec<WarmSlot> {
        let slots: Vec<WarmSlot> =
            fs.lifo.drain(..).filter_map(|h| arena.remove(h)).collect();
        debug_assert_eq!(slots.len(), fs.live, "live count matches drained slots");
        fs.by_deadline.clear();
        fs.live = 0;
        slots
    }

    /// Account all still-idle slots up to `now` (end of run).
    pub fn finalize(&mut self, now: u64) {
        // detlint: allow(DL002) per-key drains commute (integer adds only)
        let funcs: Vec<String> = self.idle.keys().cloned().collect();
        for f in funcs {
            self.expire(&f, now);
            if let Some(fs) = self.idle.get_mut(&f) {
                let slots = Self::drain_key(fs, &mut self.slots);
                self.idle_live -= slots.len() as u64;
                for s in slots {
                    let idle_ns = now.min(s.expires_at_ns).saturating_sub(s.idle_since_ns);
                    self.account_idle(idle_ns);
                }
            }
        }
    }

    /// Account every remaining idle slot up to its *full* deadline: after
    /// the measurement ends the platform will keep it resident until expiry
    /// regardless (how AWS's ~27 min keep-alive turns one invocation into
    /// hundreds of GB·s of waste).
    pub fn finalize_expiring(&mut self) {
        // detlint: allow(DL002) per-key drains commute (integer adds only)
        let funcs: Vec<String> = self.idle.keys().cloned().collect();
        for f in funcs {
            if let Some(fs) = self.idle.get_mut(&f) {
                let slots = Self::drain_key(fs, &mut self.slots);
                let n = slots.len() as u64;
                self.idle_live -= n;
                self.expirations += n;
                if let Some(a) = self.alive.get_mut(&f) {
                    *a -= n.min(*a);
                }
                for s in slots {
                    self.account_idle(s.expires_at_ns.saturating_sub(s.idle_since_ns));
                }
            }
        }
    }

    /// The node under this pool crashed at `now`: every idle executor
    /// dies with it.  Idle time actually accrued up to the crash is still
    /// charged (the memory *was* resident), the slots count as
    /// crash-drained rather than expired, and the alive counts reset —
    /// after a restart the platform has no warm state here to route to.
    /// Returns the number of warm slots destroyed.
    pub fn crash(&mut self, now: u64) -> u64 {
        // detlint: allow(DL002) per-key drains commute (integer adds only)
        let funcs: Vec<String> = self.idle.keys().cloned().collect();
        let mut dropped = 0u64;
        for f in funcs {
            if let Some(fs) = self.idle.get_mut(&f) {
                let slots = Self::drain_key(fs, &mut self.slots);
                dropped += slots.len() as u64;
                for s in slots {
                    let idle_ns = now.min(s.expires_at_ns).saturating_sub(s.idle_since_ns);
                    self.account_idle(idle_ns);
                }
            }
        }
        // Busy executors die too (their in-flight requests are killed by
        // the caller); nothing survives on the node.
        self.alive.clear();
        self.idle_live = 0;
        self.crash_drains += dropped;
        dropped
    }

    /// Snapshot codec (S27).  Canonical, layout-free form: per sharing
    /// key (sorted), the *live* slots in LIFO claim order — tombstoned
    /// handles, heap layout, and arena slot numbering are unobservable
    /// and omitted — plus the alive counts and accounting counters.
    /// Keys with no live slot and no alive executor are dropped (both
    /// maps are presence-supersets; absence is observationally
    /// identical), so a restored pool re-encodes to the same bytes.
    pub fn encode(&self, w: &mut Enc) {
        w.u64(self.idle_timeout_ns);
        w.u64(self.mem_bytes_per_slot);
        w.u64(self.poll_period_ns);
        let mut keyed: Vec<(&String, &FuncSlots)> =
            self.idle.iter().filter(|(_, fs)| fs.live > 0).collect(); // detlint: allow(DL002) sorted next
        keyed.sort_unstable_by_key(|&(k, _)| k);
        w.len(keyed.len());
        for (key, fs) in keyed {
            w.str(key);
            w.len(fs.live);
            let mut seen = 0usize;
            for &h in fs.lifo.iter().filter(|&&h| self.slots.is_live(h)) {
                let i = h as u32 as usize;
                w.u64(self.slots.idle_since_ns[i]);
                w.u64(self.slots.expires_at_ns[i]);
                w.u32(self.slots.owner[i]);
                seen += 1;
            }
            assert_eq!(seen, fs.live, "pool live count out of sync with arena for '{key}'");
        }
        let mut alive: Vec<(&String, u64)> = self
            .alive
            .iter() // detlint: allow(DL002) collected then sorted below
            .filter(|(k, &c)| c > 0 || self.idle.get(*k).is_some_and(|fs| fs.live > 0))
            .map(|(k, &c)| (k, c))
            .collect();
        alive.sort_unstable();
        w.len(alive.len());
        for (k, c) in alive { // detlint: allow(DL002) the sorted Vec, not the map
            w.str(k);
            w.u64(c);
        }
        w.u64(self.idle_live);
        w.u128(self.idle_mem_byte_ns);
        w.u64(self.monitor_events);
        w.u64(self.warm_hits);
        w.u64(self.specializations);
        w.u64(self.cold_starts);
        w.u64(self.expirations);
        w.u64(self.retirements);
        w.u64(self.crash_drains);
    }

    /// Inverse of [`Self::encode`]: rebuilds the arena with fresh
    /// handles.  Handle values and heap layout differ from the
    /// snapshotted pool, but neither is observable — claims walk the
    /// LIFO order restored here, stale entries are skipped lazily on
    /// both sides, and equal-deadline expiry ties commute in the
    /// accounting (charges depend only on each slot's own fields).
    pub fn restore(&mut self, r: &mut Dec) {
        self.idle_timeout_ns = r.u64();
        self.mem_bytes_per_slot = r.u64();
        self.poll_period_ns = r.u64();
        self.idle.clear();
        self.slots = SlotArena::default();
        let nkeys = r.len();
        for _ in 0..nkeys {
            let key = r.str();
            let nslots = r.len();
            let fs = self.idle.entry(key).or_default();
            for _ in 0..nslots {
                let slot =
                    WarmSlot { idle_since_ns: r.u64(), expires_at_ns: r.u64(), owner: r.u32() };
                let handle = self.slots.alloc(slot);
                fs.lifo.push(handle);
                fs.by_deadline.push(Reverse((slot.expires_at_ns, handle)));
                fs.live += 1;
            }
        }
        self.alive.clear();
        let nalive = r.len();
        for _ in 0..nalive {
            let k = r.str();
            let c = r.u64();
            self.alive.insert(k, c);
        }
        self.idle_live = r.u64();
        self.idle_mem_byte_ns = r.u128();
        self.monitor_events = r.u64();
        self.warm_hits = r.u64();
        self.specializations = r.u64();
        self.cold_starts = r.u64();
        self.expirations = r.u64();
        self.retirements = r.u64();
        self.crash_drains = r.u64();
    }

    /// Idle warm executors currently enqueued across all sharing keys —
    /// the telemetry pool-occupancy gauge.  Includes slots whose deadline
    /// has passed but which no claim or sweep has purged yet (expiry is
    /// lazy; the accounting charges them identically either way).
    pub fn idle_live(&self) -> u64 {
        self.idle_live
    }

    /// Resident bytes the currently idle executors hold.
    pub fn idle_bytes(&self) -> u64 {
        self.idle_live.saturating_mul(self.mem_bytes_per_slot)
    }

    /// Headline waste metric in gigabyte-seconds.
    pub fn idle_gb_seconds(&self) -> f64 {
        self.idle_mem_byte_ns as f64 / 1e9 / (1u64 << 30) as f64
    }
}

/// A cold-only "pool" for symmetry: every dispatch is cold, nothing is
/// retained, waste is identically zero (the unikernel exits on completion).
#[derive(Clone, Debug, Default)]
pub struct ColdOnly {
    pub starts: u64,
}

impl ColdOnly {
    pub fn dispatch(&mut self) -> Dispatch {
        self.starts += 1;
        Dispatch::Cold
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const S: u64 = 1_000_000_000;

    fn pool() -> WarmPool {
        WarmPool::new(30 * S, 16 << 20) // 30 s timeout, 16 MiB per slot
    }

    #[test]
    fn first_dispatch_is_cold() {
        let mut p = pool();
        assert_eq!(p.dispatch("f", 0), Dispatch::Cold);
        assert_eq!(p.cold_starts, 1);
    }

    #[test]
    fn release_then_dispatch_is_warm() {
        let mut p = pool();
        assert_eq!(p.dispatch("f", 0), Dispatch::Cold);
        p.release("f", 5 * S);
        assert_eq!(p.dispatch("f", 6 * S), Dispatch::Warm);
        assert_eq!(p.warm_hits, 1);
        // 1 s idle at 16 MiB accounted.
        assert_eq!(p.idle_mem_byte_ns, (1 * S) as u128 * (16 << 20) as u128);
    }

    #[test]
    fn timeout_expires_warm_slot() {
        let mut p = pool();
        p.dispatch("f", 0);
        p.release("f", 0);
        // 31 s later: slot expired, dispatch is cold again.
        assert_eq!(p.dispatch("f", 31 * S), Dispatch::Cold);
        assert_eq!(p.expirations, 1);
        // Expired slot wasted exactly `timeout` of memory time.
        assert_eq!(p.idle_mem_byte_ns, (30 * S) as u128 * (16 << 20) as u128);
    }

    #[test]
    fn per_function_isolation() {
        let mut p = pool();
        p.dispatch("f", 0);
        p.release("f", 0);
        assert_eq!(p.dispatch("g", 1), Dispatch::Cold);
        assert_eq!(p.dispatch("f", 1), Dispatch::Warm);
    }

    #[test]
    fn lifo_claim_lets_tail_expire() {
        let mut p = pool();
        p.prewarm("f", 2, 0);
        // Claim at t=1s takes the most recent; the other keeps aging.
        assert_eq!(p.dispatch("f", S), Dispatch::Warm);
        p.release("f", 2 * S);
        assert_eq!(p.idle_count("f"), 2);
        // At t=35s the t=0 slot expired; one release-refreshed slot left.
        p.expire("f", 35 * S);
        assert_eq!(p.idle_count("f"), 0); // 2s + 30s = 32s < 35s: both gone
        assert_eq!(p.expirations, 2);
    }

    #[test]
    fn prewarm_counts_alive() {
        let mut p = pool();
        p.prewarm("f", 10, 0);
        assert_eq!(p.alive_count("f"), 10);
        assert_eq!(p.idle_count("f"), 10);
    }

    #[test]
    fn monitor_events_grow_with_idle_time() {
        let mut p = pool();
        p.dispatch("f", 0);
        p.release("f", 0);
        p.dispatch("f", 10 * S); // 10 s idle => 10 poll events
        assert_eq!(p.monitor_events, 10);
    }

    #[test]
    fn finalize_accounts_remaining_idle() {
        let mut p = pool();
        p.dispatch("f", 0);
        p.release("f", 0);
        p.finalize(5 * S);
        assert_eq!(p.idle_mem_byte_ns, (5 * S) as u128 * (16 << 20) as u128);
    }

    #[test]
    fn finalize_caps_at_timeout() {
        let mut p = pool();
        p.dispatch("f", 0);
        p.release("f", 0);
        p.finalize(500 * S);
        // Slot would have expired at 30 s: waste capped there.
        assert_eq!(p.idle_mem_byte_ns, (30 * S) as u128 * (16 << 20) as u128);
    }

    #[test]
    fn release_until_overrides_pool_timeout() {
        let mut p = pool(); // pool-wide timeout is 30 s
        p.dispatch("f", 0);
        // Policy keeps this slot only 2 s.
        p.release_until("f", 0, 2 * S);
        assert_eq!(p.dispatch("f", 3 * S), Dispatch::Cold);
        assert_eq!(p.expirations, 1);
        assert_eq!(p.idle_mem_byte_ns, (2 * S) as u128 * (16 << 20) as u128);
    }

    #[test]
    fn per_slot_deadlines_expire_out_of_order() {
        let mut p = pool();
        p.dispatch("f", 0);
        p.dispatch("f", 0);
        // Older release has the *longer* deadline: expiry is deadline-
        // ordered, so the younger slot still goes first.
        p.release_until("f", 0, 100 * S);
        p.release_until("f", 1 * S, 5 * S);
        p.expire("f", 6 * S);
        assert_eq!(p.idle_count("f"), 1);
        assert_eq!(p.expirations, 1);
        // Expired slot idled from 1 s to its 5 s deadline.
        assert_eq!(p.idle_mem_byte_ns, (4 * S) as u128 * (16 << 20) as u128);
    }

    #[test]
    fn retire_drops_executor_without_idle_charge() {
        let mut p = pool();
        p.dispatch("f", 0);
        assert_eq!(p.alive_count("f"), 1);
        p.retire("f");
        assert_eq!(p.alive_count("f"), 0);
        assert_eq!(p.retirements, 1);
        assert_eq!(p.idle_mem_byte_ns, 0);
        assert_eq!(p.dispatch("f", 5 * S), Dispatch::Cold);
    }

    #[test]
    fn retire_without_alive_executor_is_not_a_teardown() {
        // Retiring a function that has no live executor is a caller bug:
        // debug builds flag it, release builds refuse to count it (the
        // old code bumped `retirements` and masked the alive underflow
        // with saturating_sub).
        let mut p = pool();
        if cfg!(debug_assertions) {
            let boom = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                p.retire("ghost");
            }));
            assert!(boom.is_err(), "debug builds must flag the phantom retire");
        } else {
            p.retire("ghost");
            assert_eq!(p.retirements, 0, "no executor existed, nothing was torn down");
            assert_eq!(p.alive_count("ghost"), 0);
        }
    }

    #[test]
    fn retire_counts_only_real_teardowns() {
        let mut p = pool();
        p.dispatch("f", 0); // alive = 1
        p.retire("f"); // real teardown
        assert_eq!((p.retirements, p.alive_count("f")), (1, 0));
    }

    #[test]
    fn release_at_or_past_deadline_retires_immediately() {
        // A keep window that already closed (expires <= now) must not
        // enqueue a dead slot: the old code later counted it as a
        // spurious expiration with zero idle charge.
        let mut p = pool();
        p.dispatch("f", 10 * S); // alive = 1
        p.release_until("f", 10 * S, 10 * S); // degenerate window
        assert_eq!(p.idle_count("f"), 0);
        assert_eq!(p.retirements, 1, "dead-on-arrival slot is a retirement");
        assert_eq!(p.alive_count("f"), 0);
        p.finalize(100 * S);
        assert_eq!(p.expirations, 0, "nothing was ever idle, nothing expires");
        assert_eq!(p.idle_mem_byte_ns, 0);

        let mut q = pool();
        q.dispatch("f", 10 * S);
        q.release_until("f", 10 * S, 5 * S); // deadline in the past
        assert_eq!((q.idle_count("f"), q.retirements, q.expirations), (0, 1, 0));
    }

    #[test]
    fn prewarm_until_claim_before_deadline_is_warm() {
        let mut p = pool();
        p.prewarm_until("f", 1, 10 * S, 20 * S);
        assert_eq!(p.dispatch("f", 15 * S), Dispatch::Warm);
        assert_eq!(p.idle_mem_byte_ns, (5 * S) as u128 * (16 << 20) as u128);
        p.prewarm_until("f", 1, 30 * S, 40 * S);
        assert_eq!(p.dispatch("f", 41 * S), Dispatch::Cold);
        assert_eq!(p.expirations, 1);
    }

    #[test]
    fn finalize_caps_at_per_slot_deadline() {
        let mut p = pool();
        p.dispatch("f", 0);
        p.release_until("f", 0, 7 * S);
        let mut q = p.clone();
        // Finalize before the deadline: charge only elapsed idle time.
        q.finalize(3 * S);
        assert_eq!(q.idle_mem_byte_ns, (3 * S) as u128 * (16 << 20) as u128);
        // Finalize after: charge up to the deadline, not the wall clock.
        p.finalize(500 * S);
        assert_eq!(p.idle_mem_byte_ns, (7 * S) as u128 * (16 << 20) as u128);
    }

    #[test]
    fn warm_available_expires_before_counting() {
        let mut p = pool();
        p.dispatch("f", 0);
        p.release_until("f", 0, 5 * S);
        assert_eq!(p.warm_available("f", 3 * S), 1);
        assert_eq!(p.warm_available("f", 6 * S), 0);
        assert_eq!(p.expirations, 1);
    }

    #[test]
    fn crash_drains_idle_slots_and_charges_accrued_time() {
        let mut p = pool();
        p.prewarm("f", 2, 0);
        p.dispatch("g", 0);
        p.release("g", 0);
        assert_eq!(p.crash(5 * S), 3);
        assert_eq!(p.crash_drains, 3);
        assert_eq!(p.idle_count("f") + p.idle_count("g"), 0);
        assert_eq!(p.alive_count("f") + p.alive_count("g"), 0);
        // Each slot idled 5 s before the crash; no expiration recorded.
        assert_eq!(p.idle_mem_byte_ns, 3 * (5 * S) as u128 * (16 << 20) as u128);
        assert_eq!(p.expirations, 0);
        // Everything after the crash starts cold.
        assert_eq!(p.dispatch("f", 6 * S), Dispatch::Cold);
    }

    #[test]
    fn heavy_churn_stays_consistent_and_bounded() {
        // Many release/expire/claim rounds: the lazy heap + LIFO stay in
        // agreement with the counters, and stale lifo entries are
        // compacted instead of accumulating forever.
        let mut p = pool();
        let mut now = 0u64;
        for round in 0..2_000u64 {
            p.dispatch("f", now); // cold or warm, either way alive >= 1
            // Short deadline every other round so half the slots expire.
            let keep = if round % 2 == 0 { S / 2 } else { 20 * S };
            p.release_until("f", now, now + keep);
            now += S;
        }
        {
            let fs = p.idle.get("f").expect("func entry");
            assert!(
                fs.lifo.len() <= 4 * fs.live + 64,
                "tombstones must be compacted: {} stale-ish entries over {} live slots",
                fs.lifo.len(),
                fs.live
            );
        }
        p.finalize(now + 100 * S);
        assert_eq!(p.warm_hits + p.cold_starts, 2_000);
        let fs = p.idle.get("f").expect("func entry");
        assert_eq!(fs.live, 0, "finalize drains all live slots");
        // The arena recycles: its capacity is bounded by peak idle
        // occupancy, not by total slot churn.
        assert!(
            p.slots.gen.len() <= 64,
            "arena must recycle freed indices, holds {}",
            p.slots.gen.len()
        );
    }

    #[test]
    fn shared_claim_by_owner_is_warm_by_other_is_specialized() {
        let mut p = pool();
        // f7 releases into the runtime bucket; f7 reclaims warm, f9 pays
        // a specialization, an empty bucket is cold.
        assert_eq!(p.dispatch_shared("rt0", 7, 0), Dispatch::Cold);
        p.release_shared_until("rt0", 7, S, 20 * S);
        assert_eq!(p.dispatch_shared("rt0", 7, 2 * S), Dispatch::Warm);
        p.release_shared_until("rt0", 7, 3 * S, 20 * S);
        assert_eq!(p.dispatch_shared("rt0", 9, 4 * S), Dispatch::Specialized);
        assert_eq!((p.warm_hits, p.specializations, p.cold_starts), (1, 1, 1));
        // Idle time is charged on specialized claims exactly like warm ones.
        assert_eq!(p.idle_mem_byte_ns, (2 * S) as u128 * (16 << 20) as u128);
    }

    #[test]
    fn shared_claims_never_cross_sharing_keys() {
        let mut p = pool();
        p.prewarm_shared_until("rt0", NO_OWNER, 3, 0, 100 * S);
        // rt1 is empty: every claim there is cold, however warm rt0 is.
        assert_eq!(p.dispatch_shared("rt1", 1, S), Dispatch::Cold);
        assert_eq!(p.idle_count("rt0"), 3);
        assert_eq!(p.idle_count("rt1"), 0);
        // And the rt0 workers are claimable only via rt0.
        assert_eq!(p.dispatch_shared("rt0", 1, S), Dispatch::Specialized);
    }

    #[test]
    fn shared_claim_prefers_own_slot_over_newer_foreign_one() {
        let mut p = pool();
        p.dispatch_shared("rt0", 4, 0); // cold
        p.dispatch_shared("rt0", 8, 0); // cold
        p.release_shared_until("rt0", 4, S, 50 * S); // older slot: f4's state
        p.release_shared_until("rt0", 8, 2 * S, 50 * S); // newest: f8's state
        // f4 claims its own (older) slot instead of specializing on f8's.
        assert_eq!(p.dispatch_shared("rt0", 4, 3 * S), Dispatch::Warm);
        // The claimed slot idled 1 s..3 s: 2 s charged.
        assert_eq!(p.idle_mem_byte_ns, (2 * S) as u128 * (16 << 20) as u128);
        // f8's newer slot survived for f8's own warm hit.
        assert_eq!(p.dispatch_shared("rt0", 8, 4 * S), Dispatch::Warm);
        assert_eq!((p.warm_hits, p.specializations, p.cold_starts), (2, 0, 2));
    }

    #[test]
    fn universal_prewarm_claims_are_specializations() {
        let mut p = pool();
        p.prewarm_shared_until("rt0", NO_OWNER, 1, 0, 50 * S);
        // A universal worker has no function state: first claim pays.
        assert_eq!(p.dispatch_shared("rt0", 3, S), Dispatch::Specialized);
        // Once f3 releases it back, f3's next claim is a plain warm hit.
        p.release_shared_until("rt0", 3, 2 * S, 50 * S);
        assert_eq!(p.dispatch_shared("rt0", 3, 3 * S), Dispatch::Warm);
        assert_eq!((p.warm_hits, p.specializations, p.cold_starts), (1, 1, 0));
    }

    #[test]
    fn shared_dispatch_accounting_identity_holds() {
        let mut p = pool();
        let mut dispatches = 0u64;
        let mut now = 0;
        for i in 0..200u32 {
            let d = p.dispatch_shared("rt0", i % 5, now);
            dispatches += 1;
            if d == Dispatch::Cold && i % 3 == 0 {
                p.retire("rt0");
            } else {
                p.release_shared_until("rt0", i % 5, now, now + 2 * S);
            }
            now += S / 2;
        }
        assert_eq!(p.warm_hits + p.specializations + p.cold_starts, dispatches);
    }

    #[test]
    fn legacy_wrappers_stay_exclusive_and_warm() {
        // The per-function wrappers pool under the function name with no
        // owner: claims always match, so nothing ever specializes — the
        // pre-sharing pool behaviour, bit for bit.
        let mut p = pool();
        p.dispatch("f", 0);
        p.release("f", S);
        assert_eq!(p.dispatch("f", 2 * S), Dispatch::Warm);
        p.prewarm("f", 1, 3 * S);
        assert_eq!(p.dispatch("f", 4 * S), Dispatch::Warm);
        assert_eq!(p.specializations, 0);
    }

    #[test]
    fn cold_only_never_warm_and_zero_waste() {
        let mut c = ColdOnly::default();
        for _ in 0..100 {
            assert_eq!(c.dispatch(), Dispatch::Cold);
        }
        assert_eq!(c.starts, 100);
    }

    #[test]
    fn idle_live_gauge_tracks_claims_expiry_and_drains() {
        let mut p = pool();
        assert_eq!((p.idle_live(), p.idle_bytes()), (0, 0));
        p.prewarm("f", 3, 0);
        p.dispatch("g", 0);
        p.release("g", 0);
        assert_eq!(p.idle_live(), 4);
        assert_eq!(p.idle_bytes(), 4 * (16 << 20));
        p.dispatch("f", S); // claim drops one
        assert_eq!(p.idle_live(), 3);
        p.expire("f", 31 * S); // prewarmed pair expires
        assert_eq!(p.idle_live(), 1);
        assert_eq!(p.crash(40 * S), 1); // the g slot is lazily live until drained
        assert_eq!(p.idle_live(), 0);

        let mut q = pool();
        q.prewarm("f", 2, 0);
        q.finalize(5 * S);
        assert_eq!(q.idle_live(), 0, "finalize drains the gauge");
        let mut r = pool();
        r.prewarm("f", 2, 0);
        r.finalize_expiring();
        assert_eq!(r.idle_live(), 0, "finalize_expiring drains the gauge");
    }

    #[test]
    fn snapshot_restore_is_canonical_and_behaviour_preserving() {
        // Build a pool with claims (tombstones in the LIFO + heap),
        // shared keys, prewarms, and mixed deadlines.
        let mut p = pool();
        p.prewarm_shared_until("rt0", NO_OWNER, 3, 0, 100 * S);
        assert_eq!(p.dispatch_shared("rt0", 7, S), Dispatch::Specialized);
        p.release_shared_until("rt0", 7, 2 * S, 40 * S);
        p.dispatch("f", 2 * S);
        p.release_until("f", 3 * S, 9 * S);
        p.dispatch("g", 3 * S);
        p.retire("g");
        let mut w = Enc::new();
        p.encode(&mut w);
        let mut q = WarmPool::new(0, 0);
        let mut r = Dec::new(&w.buf);
        q.restore(&mut r);
        r.finish();
        // Canonical: the restored pool re-encodes byte-identically even
        // though its arena handles and heap layout differ.
        let mut w2 = Enc::new();
        q.encode(&mut w2);
        assert_eq!(w.buf, w2.buf, "restore must round-trip byte-exactly");
        // Behaviour: drive both pools through the same schedule and
        // compare every observable.
        for pool_ in [&mut p, &mut q] {
            assert_eq!(pool_.dispatch_shared("rt0", 7, 4 * S), Dispatch::Warm);
            assert_eq!(pool_.dispatch_shared("rt0", 9, 5 * S), Dispatch::Specialized);
            assert_eq!(pool_.dispatch("f", 10 * S), Dispatch::Cold); // 9s deadline passed
            pool_.finalize(20 * S);
        }
        assert_eq!(p.idle_mem_byte_ns, q.idle_mem_byte_ns);
        assert_eq!(
            (p.warm_hits, p.specializations, p.cold_starts, p.expirations, p.retirements),
            (q.warm_hits, q.specializations, q.cold_starts, q.expirations, q.retirements)
        );
        assert_eq!(p.monitor_events, q.monitor_events);
        assert_eq!(p.idle_live(), q.idle_live());
    }

    #[test]
    fn idle_gb_seconds_units() {
        let mut p = WarmPool::new(3600 * S, 1 << 30); // 1 GiB slots
        p.dispatch("f", 0);
        p.release("f", 0);
        p.dispatch("f", 10 * S);
        assert!((p.idle_gb_seconds() - 10.0).abs() < 1e-9);
    }
}
