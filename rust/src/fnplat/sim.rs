//! DES wiring for the Fn platform: a [`Domain`] that dispatches requests
//! through the gateway/agent/driver pipeline, consulting the warm pool
//! at virtual-dispatch time (E4 Fig 4, E5 Table I, E9 waste).

use super::pool::{Dispatch, WarmPool};
use super::{agent_steps, exec_step, DbBackend, DriverKind, Placement};
use crate::net::{rtt_step, Frontend, Site};
use crate::sim::{Domain, Engine, Host, ReqId, Rng, Spawn, Step};
use crate::workload::traces::Trace;

const TAG_DISPATCH: u32 = 1;
const TAG_RELEASE: u32 = 2;

/// Offered load shape.
#[derive(Clone, Debug)]
pub enum Load {
    /// `hey`-style closed loop; `gap_ns` spaces successive requests per
    /// slot (used to force cold starts past the idle timeout).
    ClosedLoop { parallelism: u32, total: u64, prewarm: bool, gap_ns: u64 },
    /// Open-loop arrivals from a trace (E9).
    OpenLoop(Trace),
}

/// A full platform measurement scenario.
#[derive(Clone, Debug)]
pub struct Scenario {
    pub driver: DriverKind,
    pub db: DbBackend,
    pub placement: Placement,
    pub client: Site,
    pub server: Site,
    /// Include TCP/TLS connection setup in the measured latency
    /// (Table I reports it as a separate column, so table runs disable it).
    pub include_conn_setup: bool,
    pub exec_ms: f64,
    pub idle_timeout_s: f64,
    pub load: Load,
    pub seed: u64,
}

impl Scenario {
    /// The paper's local-lab Fig 4 setup.
    pub fn local(driver: DriverKind, parallelism: u32, total: u64, prewarm: bool) -> Scenario {
        Scenario {
            driver,
            db: DbBackend::Postgres,
            placement: Placement::LocalLab,
            client: Site::LabStockholm,
            server: Site::LabStockholm,
            include_conn_setup: false,
            exec_ms: super::DEFAULT_EXEC_MS,
            idle_timeout_s: 30.0,
            load: Load::ClosedLoop { parallelism, total, prewarm, gap_ns: 0 },
            seed: 0xF16_4,
        }
    }

    /// The Table I cloud deployment (lab → AWS Stockholm, m5.metal).
    pub fn cloud(driver: DriverKind, total: u64, prewarm: bool, gap_ns: u64) -> Scenario {
        Scenario {
            driver,
            db: DbBackend::Postgres,
            placement: Placement::AwsMetal,
            client: Site::LabStockholm,
            server: Site::AwsStockholm,
            include_conn_setup: false,
            exec_ms: super::DEFAULT_EXEC_MS,
            idle_timeout_s: 30.0,
            load: Load::ClosedLoop { parallelism: 1, total, prewarm, gap_ns },
            seed: 0x7AB1E_1,
        }
    }

    fn frontend(&self) -> Frontend {
        match self.driver {
            DriverKind::DockerWarm => Frontend::FN_DOCKER,
            DriverKind::IncludeOsCold => Frontend::FN_INCLUDEOS,
        }
    }

    /// Request-path steps up to (and including) the dispatch decision.
    fn head_steps(&self) -> Vec<Step> {
        let mut v = Vec::new();
        if self.include_conn_setup {
            v.extend(self.frontend().connect_steps(self.client, self.server));
        }
        v.push(rtt_step("req-resp-rtt", self.client, self.server));
        v.extend(self.placement.request_tax_steps());
        v.extend(agent_steps(self.db));
        v.push(Step::decision("dispatch", TAG_DISPATCH));
        v
    }
}

/// The Fn platform as a simulation domain.
pub struct FnDomain {
    scenario: Scenario,
    pub pool: WarmPool,
    template: Vec<Step>,
    remaining: u64,
    gap_ns: u64,
    pub latencies_ns: Vec<u64>,
    pub cold_latencies_ns: Vec<u64>,
    pub warm_latencies_ns: Vec<u64>,
    /// Requests currently on a cold path (set at decide, cleared at done).
    cold_inflight: std::collections::HashSet<ReqId>,
}

const FUNC: &str = "f";

impl FnDomain {
    fn dispatch_tail(&mut self, req: ReqId, now: u64) -> Vec<Step> {
        let s = &self.scenario;
        let mut tail = Vec::new();
        match s.driver {
            DriverKind::IncludeOsCold => {
                // Always cold; the unikernel exits after the reply: no
                // release, no pool, no lifecycle management (§IV-A).
                tail.extend(s.placement.cold_tax_steps());
                tail.extend(s.driver.cold_start_steps());
                tail.push(exec_step(s.exec_ms));
                self.cold_inflight.insert(req);
            }
            DriverKind::DockerWarm => match self.pool.dispatch(FUNC, now) {
                Dispatch::Warm => {
                    tail.extend(s.driver.warm_invoke_steps());
                    tail.push(exec_step(s.exec_ms));
                    tail.push(Step::effect("release", TAG_RELEASE));
                }
                Dispatch::Cold => {
                    tail.extend(s.placement.cold_tax_steps());
                    tail.extend(s.driver.cold_start_steps());
                    tail.push(exec_step(s.exec_ms));
                    tail.push(Step::effect("release", TAG_RELEASE));
                    self.cold_inflight.insert(req);
                }
            },
        }
        tail
    }
}

impl Domain for FnDomain {
    fn decide(&mut self, req: ReqId, _class: u32, tag: u32, now: u64, _rng: &mut Rng) -> Vec<Step> {
        debug_assert_eq!(tag, TAG_DISPATCH);
        self.dispatch_tail(req, now)
    }

    fn effect(&mut self, _req: ReqId, _class: u32, tag: u32, now: u64) {
        debug_assert_eq!(tag, TAG_RELEASE);
        self.pool.release(FUNC, now);
    }

    fn done(&mut self, req: ReqId, class: u32, start: u64, now: u64) -> Vec<Spawn> {
        let lat = now - start;
        self.latencies_ns.push(lat);
        if self.cold_inflight.remove(&req) {
            self.cold_latencies_ns.push(lat);
        } else {
            self.warm_latencies_ns.push(lat);
        }
        if self.remaining > 0 {
            self.remaining -= 1;
            vec![Spawn { delay_ns: self.gap_ns, class, steps: self.template.clone() }]
        } else {
            Vec::new()
        }
    }
}

/// Aggregated outcome of one scenario run.
pub struct ScenarioResult {
    pub latencies_ns: Vec<u64>,
    pub cold_latencies_ns: Vec<u64>,
    pub warm_latencies_ns: Vec<u64>,
    pub elapsed_ns: u64,
    pub warm_hits: u64,
    pub cold_starts: u64,
    pub idle_gb_seconds: f64,
    pub monitor_events: u64,
    /// Median connection-setup cost for this scenario's frontend (reported
    /// separately, as in Table I).
    pub conn_setup_ms: f64,
}

pub fn run_scenario(sc: &Scenario, host: Host) -> ScenarioResult {
    let timeout_ns = (sc.idle_timeout_s * 1e9) as u64;
    let mem = sc.driver.tech().warm_memory_bytes();
    let domain = FnDomain {
        scenario: sc.clone(),
        pool: WarmPool::new(timeout_ns, mem),
        template: Vec::new(),
        remaining: 0,
        gap_ns: 0,
        latencies_ns: Vec::new(),
        cold_latencies_ns: Vec::new(),
        warm_latencies_ns: Vec::new(),
        cold_inflight: std::collections::HashSet::new(),
    };
    let mut e = Engine::new(domain, host, sc.seed);
    let head = sc.head_steps();
    e.domain.template = head.clone();

    match &sc.load {
        Load::ClosedLoop { parallelism, total, prewarm, gap_ns } => {
            assert!(*parallelism as u64 <= *total);
            if *prewarm {
                e.domain.pool.prewarm(FUNC, *parallelism as u64, 0);
            }
            e.domain.remaining = total - *parallelism as u64;
            e.domain.gap_ns = *gap_ns;
            for _ in 0..*parallelism {
                e.spawn_at(0, 0, head.clone());
            }
            e.run(total.saturating_mul(96).max(1 << 20));
        }
        Load::OpenLoop(trace) => {
            for &t in &trace.arrivals_ns {
                e.spawn_at(t, 0, head.clone());
            }
            e.run((trace.len() as u64).saturating_mul(96).max(1 << 20));
        }
    }

    let now = e.now();
    e.domain.pool.finalize(now);
    let conn = sc.frontend().nominal_setup_ms(sc.client, sc.server);
    let cold_starts = e.domain.pool.cold_starts
        + if sc.driver == DriverKind::IncludeOsCold {
            e.domain.cold_latencies_ns.len() as u64
        } else {
            0
        };
    ScenarioResult {
        latencies_ns: std::mem::take(&mut e.domain.latencies_ns),
        cold_latencies_ns: std::mem::take(&mut e.domain.cold_latencies_ns),
        warm_latencies_ns: std::mem::take(&mut e.domain.warm_latencies_ns),
        elapsed_ns: now,
        warm_hits: e.domain.pool.warm_hits,
        cold_starts,
        idle_gb_seconds: e.domain.pool.idle_gb_seconds(),
        monitor_events: e.domain.pool.monitor_events,
        conn_setup_ms: conn,
    }
}

fn median_ms(v: &[u64]) -> f64 {
    if v.is_empty() {
        return f64::NAN;
    }
    let mut s = v.to_vec();
    s.sort_unstable();
    s[s.len() / 2] as f64 / 1e6
}

impl ScenarioResult {
    pub fn median_ms(&self) -> f64 {
        median_ms(&self.latencies_ns)
    }
    pub fn cold_median_ms(&self) -> f64 {
        median_ms(&self.cold_latencies_ns)
    }
    pub fn warm_median_ms(&self) -> f64 {
        median_ms(&self.warm_latencies_ns)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn local_includeos_cold_in_fig4_band() {
        // Fig 4: IncludeOS startup+execution ≈ 10–20 ms in the local lab.
        let sc = Scenario::local(DriverKind::IncludeOsCold, 5, 2000, false);
        let r = run_scenario(&sc, Host::default());
        let med = r.median_ms();
        assert!((10.0..20.0).contains(&med), "local includeos median {med}");
        assert_eq!(r.warm_hits, 0);
    }

    #[test]
    fn local_docker_warm_in_fig4_band() {
        // Fig 4: warm Go function ≈ 3–5 ms.
        let sc = Scenario::local(DriverKind::DockerWarm, 5, 2000, true);
        let r = run_scenario(&sc, Host::default());
        let med = r.warm_median_ms();
        assert!((3.0..5.5).contains(&med), "local warm docker median {med}");
    }

    #[test]
    fn cloud_cold_medians_near_table1() {
        // Table I: Fn IncludeOS 33.4 ms, Fn Docker 288.3 ms (cold).
        let sc = Scenario::cloud(DriverKind::IncludeOsCold, 800, false, 0);
        let inc = run_scenario(&sc, Host::default()).cold_median_ms();
        assert!((inc / 33.4 - 1.0).abs() < 0.25, "fn-includeos cold {inc}");

        // Space requests past the idle timeout so every start is cold.
        let sc = Scenario::cloud(DriverKind::DockerWarm, 300, false, 31_000_000_000);
        let dock = run_scenario(&sc, Host::default()).cold_median_ms();
        assert!((dock / 288.3 - 1.0).abs() < 0.25, "fn-docker cold {dock}");
    }

    #[test]
    fn cloud_warm_median_near_table1() {
        // Table I: Fn Docker warm 13.6 ms.
        let sc = Scenario::cloud(DriverKind::DockerWarm, 1500, true, 0);
        let r = run_scenario(&sc, Host::default());
        let warm = r.warm_median_ms();
        assert!((warm / 13.6 - 1.0).abs() < 0.25, "fn-docker warm {warm}");
    }

    #[test]
    fn includeos_wastes_nothing() {
        let sc = Scenario::local(DriverKind::IncludeOsCold, 2, 500, false);
        let r = run_scenario(&sc, Host::default());
        assert_eq!(r.idle_gb_seconds, 0.0);
        assert_eq!(r.monitor_events, 0);
    }

    #[test]
    fn docker_warm_pool_wastes_memory() {
        let sc = Scenario::local(DriverKind::DockerWarm, 2, 500, true);
        let r = run_scenario(&sc, Host::default());
        assert!(r.idle_gb_seconds > 0.0);
    }

    #[test]
    fn deterministic_scenarios() {
        let sc = Scenario::local(DriverKind::IncludeOsCold, 3, 300, false);
        let a = run_scenario(&sc, Host::default());
        let b = run_scenario(&sc, Host::default());
        assert_eq!(a.latencies_ns, b.latencies_ns);
    }
}
