//! Report rendering (S15): ASCII tables/series for every regenerated
//! figure, plus paper-vs-measured tolerance checks.  The [`compare`]
//! submodule (S24) is the bench-regression gate that diffs two
//! machine-readable reports.

pub mod compare;

use crate::metrics::BoxStats;

/// One paper-vs-measured comparison point.
#[derive(Clone, Debug)]
pub struct Check {
    pub label: String,
    pub metric: &'static str,
    pub got: f64,
    pub want: f64,
    /// Fractional tolerance; e.g. 0.25 = ±25 %.
    pub tol: f64,
}

impl Check {
    pub fn pass(&self) -> bool {
        if self.want == 0.0 {
            return self.got.abs() <= self.tol;
        }
        (self.got / self.want - 1.0).abs() <= self.tol
    }

    pub fn row(&self) -> String {
        format!(
            "{:<38} {:<12} paper={:>9.1}  measured={:>9.1}  ({:+6.1}%)  {}",
            self.label,
            self.metric,
            self.want,
            self.got,
            (self.got / self.want - 1.0) * 100.0,
            if self.pass() { "PASS" } else { "MISS" }
        )
    }
}

/// A lower/upper band check (for "8–15 ms"-style paper statements).
#[derive(Clone, Debug)]
pub struct BandCheck {
    pub label: String,
    pub metric: &'static str,
    pub got: f64,
    pub lo: f64,
    pub hi: f64,
}

impl BandCheck {
    pub fn pass(&self) -> bool {
        (self.lo..=self.hi).contains(&self.got)
    }

    pub fn row(&self) -> String {
        format!(
            "{:<38} {:<12} band=[{:>7.1},{:>7.1}]  measured={:>9.1}  {}",
            self.label,
            self.metric,
            self.lo,
            self.hi,
            self.got,
            if self.pass() { "PASS" } else { "MISS" }
        )
    }
}

/// A rendered experiment: measured series + checks + free-form notes.
pub struct Report {
    pub title: String,
    pub series: Vec<(String, BoxStats)>,
    pub checks: Vec<Check>,
    pub bands: Vec<BandCheck>,
    pub notes: Vec<String>,
}

impl Report {
    pub fn new(title: &str) -> Report {
        Report {
            title: title.to_string(),
            series: Vec::new(),
            checks: Vec::new(),
            bands: Vec::new(),
            notes: Vec::new(),
        }
    }

    pub fn add_series(&mut self, label: &str, stats: BoxStats) {
        self.series.push((label.to_string(), stats));
    }

    pub fn check(&mut self, label: &str, metric: &'static str, got: f64, want: f64, tol: f64) {
        self.checks.push(Check { label: label.to_string(), metric, got, want, tol });
    }

    pub fn band(&mut self, label: &str, metric: &'static str, got: f64, lo: f64, hi: f64) {
        self.bands.push(BandCheck { label: label.to_string(), metric, got, lo, hi });
    }

    pub fn note(&mut self, s: impl Into<String>) {
        self.notes.push(s.into());
    }

    pub fn all_pass(&self) -> bool {
        self.checks.iter().all(|c| c.pass()) && self.bands.iter().all(|b| b.pass())
    }

    pub fn failures(&self) -> Vec<String> {
        self.checks
            .iter()
            .filter(|c| !c.pass())
            .map(|c| c.row())
            .chain(self.bands.iter().filter(|b| !b.pass()).map(|b| b.row()))
            .collect()
    }

    /// Machine-readable form of the report (hand-rolled JSON: the offline
    /// registry has no serde).  `id` is the experiment name the CLI ran,
    /// `wall_s` the wall-clock regeneration time — together with the rows
    /// and checks this is what bench trajectory files (`BENCH_*.json`)
    /// record.
    pub fn to_json(&self, id: &str, wall_s: f64) -> String {
        let mut out = String::from("{");
        out.push_str(&format!("\"id\":{},", json_str(id)));
        out.push_str(&format!("\"title\":{},", json_str(&self.title)));
        out.push_str(&format!("\"wall_s\":{},", json_num(wall_s)));
        out.push_str(&format!("\"all_pass\":{},", self.all_pass()));
        out.push_str("\"series\":[");
        for (i, (label, s)) in self.series.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(&format!(
                "{{\"label\":{},\"n\":{},\"p1\":{},\"p25\":{},\"p50\":{},\"p75\":{},\"p99\":{},\"mean\":{},\"max\":{}}}",
                json_str(label),
                s.n,
                json_num(s.p1),
                json_num(s.p25),
                json_num(s.p50),
                json_num(s.p75),
                json_num(s.p99),
                json_num(s.mean),
                json_num(s.max)
            ));
        }
        out.push_str("],\"checks\":[");
        for (i, c) in self.checks.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(&format!(
                "{{\"label\":{},\"metric\":{},\"paper\":{},\"measured\":{},\"tol\":{},\"pass\":{}}}",
                json_str(&c.label),
                json_str(c.metric),
                json_num(c.want),
                json_num(c.got),
                json_num(c.tol),
                c.pass()
            ));
        }
        out.push_str("],\"bands\":[");
        for (i, b) in self.bands.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(&format!(
                "{{\"label\":{},\"metric\":{},\"lo\":{},\"hi\":{},\"measured\":{},\"pass\":{}}}",
                json_str(&b.label),
                json_str(b.metric),
                json_num(b.lo),
                json_num(b.hi),
                json_num(b.got),
                b.pass()
            ));
        }
        out.push_str("],\"notes\":[");
        for (i, n) in self.notes.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(&json_str(n));
        }
        out.push_str("]}");
        out
    }

    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!("\n=== {} ===\n", self.title));
        if !self.series.is_empty() {
            out.push_str("\n  measured latency (ms):\n");
            for (label, s) in &self.series {
                out.push_str(&format!("  {:<40} {}\n", label, s.row()));
            }
        }
        if !self.checks.is_empty() || !self.bands.is_empty() {
            out.push_str("\n  paper-vs-measured:\n");
            for c in &self.checks {
                out.push_str(&format!("  {}\n", c.row()));
            }
            for b in &self.bands {
                out.push_str(&format!("  {}\n", b.row()));
            }
        }
        for n in &self.notes {
            out.push_str(&format!("  note: {n}\n"));
        }
        let verdict = if self.all_pass() { "ALL CHECKS PASS" } else { "SOME CHECKS MISS" };
        out.push_str(&format!("  -> {verdict}\n"));
        out
    }
}

/// JSON string literal with the escapes the report text can contain.
pub fn json_str(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for ch in s.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

/// JSON number: finite floats verbatim, non-finite as null (JSON has no
/// NaN/Infinity literals).
pub fn json_num(v: f64) -> String {
    if v.is_finite() {
        format!("{v}")
    } else {
        "null".to_string()
    }
}

/// Wrap per-experiment JSON reports into one machine-readable document.
pub fn json_document(entries: &[String], total_wall_s: f64) -> String {
    format!(
        "{{\"generator\":\"coldfaas\",\"total_wall_s\":{},\"experiments\":[{}]}}\n",
        json_num(total_wall_s),
        entries.join(",")
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stats() -> BoxStats {
        BoxStats { n: 10, p1: 1.0, p25: 2.0, p50: 3.0, p75: 4.0, p99: 5.0, mean: 3.0, max: 6.0 }
    }

    #[test]
    fn check_tolerance_boundaries() {
        let c = Check { label: "x".into(), metric: "p50", got: 124.9, want: 100.0, tol: 0.25 };
        assert!(c.pass());
        let c2 = Check { label: "x".into(), metric: "p50", got: 126.0, want: 100.0, tol: 0.25 };
        assert!(!c2.pass());
    }

    #[test]
    fn band_check_inclusive() {
        let b = BandCheck { label: "x".into(), metric: "p50", got: 8.0, lo: 8.0, hi: 15.0 };
        assert!(b.pass());
        let b2 = BandCheck { label: "x".into(), metric: "p50", got: 15.01, lo: 8.0, hi: 15.0 };
        assert!(!b2.pass());
    }

    #[test]
    fn json_escapes_and_structure() {
        let mut r = Report::new("t \"quoted\"\nline");
        r.add_series("s", stats());
        r.check("a", "p50", 100.0, 100.0, 0.1);
        r.band("b", "ms", f64::NAN, 0.0, f64::INFINITY);
        r.note("n1");
        let j = r.to_json("fig1", 1.5);
        assert!(j.starts_with('{') && j.ends_with('}'));
        assert!(j.contains("\"id\":\"fig1\""));
        assert!(j.contains("\\\"quoted\\\"\\nline"));
        assert!(j.contains("\"measured\":null"), "non-finite must be null: {j}");
        assert!(j.contains("\"hi\":null"));
        assert!(j.contains("\"all_pass\":false"));
        assert!(j.contains("\"p50\":3"));
        // No raw control characters or bare NaN/inf tokens survive.
        assert!(!j.contains('\n'));
        assert!(!j.contains("NaN") && !j.contains("inf"));
        let doc = json_document(&[j.clone(), j], 3.0);
        assert!(doc.contains("\"experiments\":[{"));
        assert!(doc.contains("},{"));
    }

    #[test]
    fn report_verdict_and_render() {
        let mut r = Report::new("t");
        r.add_series("s", stats());
        r.check("a", "p50", 100.0, 100.0, 0.1);
        assert!(r.all_pass());
        assert!(r.render().contains("ALL CHECKS PASS"));
        r.check("b", "p50", 200.0, 100.0, 0.1);
        assert!(!r.all_pass());
        assert_eq!(r.failures().len(), 1);
    }
}
