//! Report rendering (S15): ASCII tables/series for every regenerated
//! figure, plus paper-vs-measured tolerance checks.  The [`compare`]
//! submodule (S24) is the bench-regression gate that diffs two
//! machine-readable reports.

pub mod compare;

use crate::metrics::BoxStats;

/// One paper-vs-measured comparison point.
#[derive(Clone, Debug)]
pub struct Check {
    pub label: String,
    pub metric: &'static str,
    pub got: f64,
    pub want: f64,
    /// Fractional tolerance; e.g. 0.25 = ±25 %.
    pub tol: f64,
}

impl Check {
    pub fn pass(&self) -> bool {
        if self.want == 0.0 {
            return self.got.abs() <= self.tol;
        }
        (self.got / self.want - 1.0).abs() <= self.tol
    }

    pub fn row(&self) -> String {
        format!(
            "{:<38} {:<12} paper={:>9.1}  measured={:>9.1}  ({:+6.1}%)  {}",
            self.label,
            self.metric,
            self.want,
            self.got,
            (self.got / self.want - 1.0) * 100.0,
            if self.pass() { "PASS" } else { "MISS" }
        )
    }
}

/// A lower/upper band check (for "8–15 ms"-style paper statements).
#[derive(Clone, Debug)]
pub struct BandCheck {
    pub label: String,
    pub metric: &'static str,
    pub got: f64,
    pub lo: f64,
    pub hi: f64,
}

impl BandCheck {
    pub fn pass(&self) -> bool {
        (self.lo..=self.hi).contains(&self.got)
    }

    pub fn row(&self) -> String {
        format!(
            "{:<38} {:<12} band=[{:>7.1},{:>7.1}]  measured={:>9.1}  {}",
            self.label,
            self.metric,
            self.lo,
            self.hi,
            self.got,
            if self.pass() { "PASS" } else { "MISS" }
        )
    }
}

/// One interval time-series (S25): a telemetry column sampled at a fixed
/// virtual-time interval, rendered as a sparkline row and exported with a
/// summary (n/mean/max/last) the bench gate can band.
#[derive(Clone, Debug)]
pub struct TimeSeriesOut {
    pub label: String,
    /// Sampling interval in virtual seconds.
    pub interval_s: f64,
    pub points: Vec<f64>,
}

/// A rendered experiment: measured series + checks + free-form notes.
pub struct Report {
    pub title: String,
    pub series: Vec<(String, BoxStats)>,
    pub checks: Vec<Check>,
    pub bands: Vec<BandCheck>,
    pub notes: Vec<String>,
    /// Interval time-series (S25); empty unless telemetry ran.
    pub timeseries: Vec<TimeSeriesOut>,
    /// Total engine events processed — deterministic per seed, compared
    /// *strictly* by the bench gate when both sides carry it.
    pub events: Option<u64>,
    /// Simulator throughput (wall-clock): JSON-only and informational,
    /// never rendered and never gated.
    pub events_per_s: Option<f64>,
}

impl Report {
    pub fn new(title: &str) -> Report {
        Report {
            title: title.to_string(),
            series: Vec::new(),
            checks: Vec::new(),
            bands: Vec::new(),
            notes: Vec::new(),
            timeseries: Vec::new(),
            events: None,
            events_per_s: None,
        }
    }

    pub fn add_series(&mut self, label: &str, stats: BoxStats) {
        self.series.push((label.to_string(), stats));
    }

    pub fn add_timeseries(&mut self, label: &str, interval_s: f64, points: &[f64]) {
        self.timeseries.push(TimeSeriesOut {
            label: label.to_string(),
            interval_s,
            points: points.to_vec(),
        });
    }

    /// Record the run's self-profile (S25).  `events` is virtual-time
    /// deterministic; `events_per_s` is wall-clock and stays JSON-only.
    pub fn set_profile(&mut self, events: u64, events_per_s: f64) {
        self.events = Some(events);
        self.events_per_s = Some(events_per_s);
    }

    pub fn check(&mut self, label: &str, metric: &'static str, got: f64, want: f64, tol: f64) {
        self.checks.push(Check { label: label.to_string(), metric, got, want, tol });
    }

    pub fn band(&mut self, label: &str, metric: &'static str, got: f64, lo: f64, hi: f64) {
        self.bands.push(BandCheck { label: label.to_string(), metric, got, lo, hi });
    }

    pub fn note(&mut self, s: impl Into<String>) {
        self.notes.push(s.into());
    }

    pub fn all_pass(&self) -> bool {
        self.checks.iter().all(|c| c.pass()) && self.bands.iter().all(|b| b.pass())
    }

    pub fn failures(&self) -> Vec<String> {
        self.checks
            .iter()
            .filter(|c| !c.pass())
            .map(|c| c.row())
            .chain(self.bands.iter().filter(|b| !b.pass()).map(|b| b.row()))
            .collect()
    }

    /// Machine-readable form of the report (hand-rolled JSON: the offline
    /// registry has no serde).  `id` is the experiment name the CLI ran,
    /// `wall_s` the wall-clock regeneration time — together with the rows
    /// and checks this is what bench trajectory files (`BENCH_*.json`)
    /// record.
    pub fn to_json(&self, id: &str, wall_s: f64) -> String {
        let mut out = String::from("{");
        out.push_str(&format!("\"id\":{},", json_str(id)));
        out.push_str(&format!("\"title\":{},", json_str(&self.title)));
        out.push_str(&format!("\"wall_s\":{},", json_num(wall_s)));
        out.push_str(&format!("\"all_pass\":{},", self.all_pass()));
        out.push_str("\"series\":[");
        for (i, (label, s)) in self.series.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(&format!(
                "{{\"label\":{},\"n\":{},\"p1\":{},\"p25\":{},\"p50\":{},\"p75\":{},\"p99\":{},\"mean\":{},\"max\":{}}}",
                json_str(label),
                s.n,
                json_num(s.p1),
                json_num(s.p25),
                json_num(s.p50),
                json_num(s.p75),
                json_num(s.p99),
                json_num(s.mean),
                json_num(s.max)
            ));
        }
        out.push_str("],\"checks\":[");
        for (i, c) in self.checks.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(&format!(
                "{{\"label\":{},\"metric\":{},\"paper\":{},\"measured\":{},\"tol\":{},\"pass\":{}}}",
                json_str(&c.label),
                json_str(c.metric),
                json_num(c.want),
                json_num(c.got),
                json_num(c.tol),
                c.pass()
            ));
        }
        out.push_str("],\"bands\":[");
        for (i, b) in self.bands.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(&format!(
                "{{\"label\":{},\"metric\":{},\"lo\":{},\"hi\":{},\"measured\":{},\"pass\":{}}}",
                json_str(&b.label),
                json_str(b.metric),
                json_num(b.lo),
                json_num(b.hi),
                json_num(b.got),
                b.pass()
            ));
        }
        out.push_str("],\"timeseries\":[");
        for (i, t) in self.timeseries.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            let max = t.points.iter().copied().fold(0.0_f64, f64::max);
            let mean = if t.points.is_empty() {
                0.0
            } else {
                t.points.iter().sum::<f64>() / t.points.len() as f64
            };
            let last = t.points.last().copied().unwrap_or(0.0);
            let points = t.points.iter().map(|v| json_num(*v)).collect::<Vec<_>>().join(",");
            out.push_str(&format!(
                "{{\"label\":{},\"interval_s\":{},\"n\":{},\"mean\":{},\"max\":{},\"last\":{},\"points\":[{points}]}}",
                json_str(&t.label),
                json_num(t.interval_s),
                t.points.len(),
                json_num(mean),
                json_num(max),
                json_num(last)
            ));
        }
        out.push_str("],\"notes\":[");
        for (i, n) in self.notes.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(&json_str(n));
        }
        out.push(']');
        if let Some(ev) = self.events {
            out.push_str(&format!(",\"events\":{ev}"));
        }
        if let Some(eps) = self.events_per_s {
            out.push_str(&format!(",\"events_per_s\":{}", json_num(eps)));
        }
        out.push('}');
        out
    }

    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!("\n=== {} ===\n", self.title));
        if !self.series.is_empty() {
            out.push_str("\n  measured latency (ms):\n");
            for (label, s) in &self.series {
                out.push_str(&format!("  {:<40} {}\n", label, s.row()));
            }
        }
        if !self.checks.is_empty() || !self.bands.is_empty() {
            out.push_str("\n  paper-vs-measured:\n");
            for c in &self.checks {
                out.push_str(&format!("  {}\n", c.row()));
            }
            for b in &self.bands {
                out.push_str(&format!("  {}\n", b.row()));
            }
        }
        if !self.timeseries.is_empty() {
            out.push_str("\n  interval time-series:\n");
            for t in &self.timeseries {
                let max = t.points.iter().copied().fold(0.0_f64, f64::max);
                out.push_str(&format!(
                    "  {:<28} |{}| n={} max={:.3} ({:.0}s/interval)\n",
                    t.label,
                    sparkline(&t.points),
                    t.points.len(),
                    max,
                    t.interval_s
                ));
            }
        }
        if let Some(ev) = self.events {
            // Deterministic per seed: safe to render.  events/s is
            // wall-clock and deliberately stays out of the render.
            out.push_str(&format!("  simulator events: {ev}\n"));
        }
        for n in &self.notes {
            out.push_str(&format!("  note: {n}\n"));
        }
        let verdict = if self.all_pass() { "ALL CHECKS PASS" } else { "SOME CHECKS MISS" };
        out.push_str(&format!("  -> {verdict}\n"));
        out
    }
}

/// Eight-level unicode sparkline, scaled to the series max.  All-zero
/// (or empty) series render flat; negatives clamp to the floor glyph.
pub fn sparkline(points: &[f64]) -> String {
    const BARS: [char; 8] = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];
    let max = points.iter().copied().filter(|v| v.is_finite()).fold(0.0_f64, f64::max);
    points
        .iter()
        .map(|&v| {
            if max <= 0.0 || !v.is_finite() || v <= 0.0 {
                BARS[0]
            } else {
                BARS[((v / max * 7.0).round() as usize).min(7)]
            }
        })
        .collect()
}

/// JSON string literal with the escapes the report text can contain.
pub fn json_str(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for ch in s.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

/// JSON number: finite floats verbatim, non-finite as null (JSON has no
/// NaN/Infinity literals).
pub fn json_num(v: f64) -> String {
    if v.is_finite() {
        format!("{v}")
    } else {
        "null".to_string()
    }
}

/// Wrap per-experiment JSON reports into one machine-readable document.
pub fn json_document(entries: &[String], total_wall_s: f64) -> String {
    format!(
        "{{\"generator\":\"coldfaas\",\"total_wall_s\":{},\"experiments\":[{}]}}\n",
        json_num(total_wall_s),
        entries.join(",")
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stats() -> BoxStats {
        BoxStats { n: 10, p1: 1.0, p25: 2.0, p50: 3.0, p75: 4.0, p99: 5.0, mean: 3.0, max: 6.0 }
    }

    #[test]
    fn check_tolerance_boundaries() {
        let c = Check { label: "x".into(), metric: "p50", got: 124.9, want: 100.0, tol: 0.25 };
        assert!(c.pass());
        let c2 = Check { label: "x".into(), metric: "p50", got: 126.0, want: 100.0, tol: 0.25 };
        assert!(!c2.pass());
    }

    #[test]
    fn band_check_inclusive() {
        let b = BandCheck { label: "x".into(), metric: "p50", got: 8.0, lo: 8.0, hi: 15.0 };
        assert!(b.pass());
        let b2 = BandCheck { label: "x".into(), metric: "p50", got: 15.01, lo: 8.0, hi: 15.0 };
        assert!(!b2.pass());
    }

    #[test]
    fn json_escapes_and_structure() {
        let mut r = Report::new("t \"quoted\"\nline");
        r.add_series("s", stats());
        r.check("a", "p50", 100.0, 100.0, 0.1);
        r.band("b", "ms", f64::NAN, 0.0, f64::INFINITY);
        r.note("n1");
        let j = r.to_json("fig1", 1.5);
        assert!(j.starts_with('{') && j.ends_with('}'));
        assert!(j.contains("\"id\":\"fig1\""));
        assert!(j.contains("\\\"quoted\\\"\\nline"));
        assert!(j.contains("\"measured\":null"), "non-finite must be null: {j}");
        assert!(j.contains("\"hi\":null"));
        assert!(j.contains("\"all_pass\":false"));
        assert!(j.contains("\"p50\":3"));
        // No raw control characters or bare NaN/inf tokens survive.
        assert!(!j.contains('\n'));
        assert!(!j.contains("NaN") && !j.contains("inf"));
        let doc = json_document(&[j.clone(), j], 3.0);
        assert!(doc.contains("\"experiments\":[{"));
        assert!(doc.contains("},{"));
    }

    #[test]
    fn sparkline_buckets_scale_to_max() {
        assert_eq!(sparkline(&[]), "");
        assert_eq!(sparkline(&[0.0, 0.0]), "▁▁");
        let s = sparkline(&[0.0, 4.0, 8.0]);
        assert_eq!(s.chars().count(), 3);
        assert!(s.starts_with('▁') && s.ends_with('█'), "{s}");
        // Negatives and non-finite values clamp to the floor glyph.
        assert_eq!(sparkline(&[-1.0, f64::NAN, 1.0]), "▁▁█");
    }

    #[test]
    fn timeseries_and_profile_serialize_and_render() {
        let mut r = Report::new("t");
        r.add_timeseries("cold fraction", 30.0, &[0.5, 0.25, 0.0]);
        r.set_profile(1234, 56789.5);
        let j = r.to_json("e14", 1.0);
        assert!(j.contains("\"timeseries\":[{\"label\":\"cold fraction\""), "{j}");
        assert!(j.contains("\"interval_s\":30"));
        assert!(j.contains("\"n\":3") && j.contains("\"max\":0.5") && j.contains("\"last\":0"));
        assert!(j.contains("\"mean\":0.25"));
        assert!(j.contains("\"points\":[0.5,0.25,0]"));
        assert!(j.contains("\"events\":1234"));
        assert!(j.contains("\"events_per_s\":56789.5"));
        let rendered = r.render();
        assert!(rendered.contains("interval time-series:"));
        assert!(rendered.contains("cold fraction"));
        assert!(rendered.contains("simulator events: 1234"));
        // Wall-clock throughput must never reach the rendered report.
        assert!(!rendered.contains("56789"));
        // A report without profile/telemetry renders and serializes as before.
        let bare = Report::new("t").to_json("x", 0.0);
        assert!(bare.contains("\"timeseries\":[]"));
        assert!(!bare.contains("\"events\""));
    }

    #[test]
    fn report_verdict_and_render() {
        let mut r = Report::new("t");
        r.add_series("s", stats());
        r.check("a", "p50", 100.0, 100.0, 0.1);
        assert!(r.all_pass());
        assert!(r.render().contains("ALL CHECKS PASS"));
        r.check("b", "p50", 200.0, 100.0, 0.1);
        assert!(!r.all_pass());
        assert_eq!(r.failures().len(), 1);
    }
}
