//! Report rendering (S15): ASCII tables/series for every regenerated
//! figure, plus paper-vs-measured tolerance checks.

use crate::metrics::BoxStats;

/// One paper-vs-measured comparison point.
#[derive(Clone, Debug)]
pub struct Check {
    pub label: String,
    pub metric: &'static str,
    pub got: f64,
    pub want: f64,
    /// Fractional tolerance; e.g. 0.25 = ±25 %.
    pub tol: f64,
}

impl Check {
    pub fn pass(&self) -> bool {
        if self.want == 0.0 {
            return self.got.abs() <= self.tol;
        }
        (self.got / self.want - 1.0).abs() <= self.tol
    }

    pub fn row(&self) -> String {
        format!(
            "{:<38} {:<12} paper={:>9.1}  measured={:>9.1}  ({:+6.1}%)  {}",
            self.label,
            self.metric,
            self.want,
            self.got,
            (self.got / self.want - 1.0) * 100.0,
            if self.pass() { "PASS" } else { "MISS" }
        )
    }
}

/// A lower/upper band check (for "8–15 ms"-style paper statements).
#[derive(Clone, Debug)]
pub struct BandCheck {
    pub label: String,
    pub metric: &'static str,
    pub got: f64,
    pub lo: f64,
    pub hi: f64,
}

impl BandCheck {
    pub fn pass(&self) -> bool {
        (self.lo..=self.hi).contains(&self.got)
    }

    pub fn row(&self) -> String {
        format!(
            "{:<38} {:<12} band=[{:>7.1},{:>7.1}]  measured={:>9.1}  {}",
            self.label,
            self.metric,
            self.lo,
            self.hi,
            self.got,
            if self.pass() { "PASS" } else { "MISS" }
        )
    }
}

/// A rendered experiment: measured series + checks + free-form notes.
pub struct Report {
    pub title: String,
    pub series: Vec<(String, BoxStats)>,
    pub checks: Vec<Check>,
    pub bands: Vec<BandCheck>,
    pub notes: Vec<String>,
}

impl Report {
    pub fn new(title: &str) -> Report {
        Report {
            title: title.to_string(),
            series: Vec::new(),
            checks: Vec::new(),
            bands: Vec::new(),
            notes: Vec::new(),
        }
    }

    pub fn add_series(&mut self, label: &str, stats: BoxStats) {
        self.series.push((label.to_string(), stats));
    }

    pub fn check(&mut self, label: &str, metric: &'static str, got: f64, want: f64, tol: f64) {
        self.checks.push(Check { label: label.to_string(), metric, got, want, tol });
    }

    pub fn band(&mut self, label: &str, metric: &'static str, got: f64, lo: f64, hi: f64) {
        self.bands.push(BandCheck { label: label.to_string(), metric, got, lo, hi });
    }

    pub fn note(&mut self, s: impl Into<String>) {
        self.notes.push(s.into());
    }

    pub fn all_pass(&self) -> bool {
        self.checks.iter().all(|c| c.pass()) && self.bands.iter().all(|b| b.pass())
    }

    pub fn failures(&self) -> Vec<String> {
        self.checks
            .iter()
            .filter(|c| !c.pass())
            .map(|c| c.row())
            .chain(self.bands.iter().filter(|b| !b.pass()).map(|b| b.row()))
            .collect()
    }

    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!("\n=== {} ===\n", self.title));
        if !self.series.is_empty() {
            out.push_str("\n  measured latency (ms):\n");
            for (label, s) in &self.series {
                out.push_str(&format!("  {:<40} {}\n", label, s.row()));
            }
        }
        if !self.checks.is_empty() || !self.bands.is_empty() {
            out.push_str("\n  paper-vs-measured:\n");
            for c in &self.checks {
                out.push_str(&format!("  {}\n", c.row()));
            }
            for b in &self.bands {
                out.push_str(&format!("  {}\n", b.row()));
            }
        }
        for n in &self.notes {
            out.push_str(&format!("  note: {n}\n"));
        }
        let verdict = if self.all_pass() { "ALL CHECKS PASS" } else { "SOME CHECKS MISS" };
        out.push_str(&format!("  -> {verdict}\n"));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stats() -> BoxStats {
        BoxStats { n: 10, p1: 1.0, p25: 2.0, p50: 3.0, p75: 4.0, p99: 5.0, mean: 3.0, max: 6.0 }
    }

    #[test]
    fn check_tolerance_boundaries() {
        let c = Check { label: "x".into(), metric: "p50", got: 124.9, want: 100.0, tol: 0.25 };
        assert!(c.pass());
        let c2 = Check { label: "x".into(), metric: "p50", got: 126.0, want: 100.0, tol: 0.25 };
        assert!(!c2.pass());
    }

    #[test]
    fn band_check_inclusive() {
        let b = BandCheck { label: "x".into(), metric: "p50", got: 8.0, lo: 8.0, hi: 15.0 };
        assert!(b.pass());
        let b2 = BandCheck { label: "x".into(), metric: "p50", got: 15.01, lo: 8.0, hi: 15.0 };
        assert!(!b2.pass());
    }

    #[test]
    fn report_verdict_and_render() {
        let mut r = Report::new("t");
        r.add_series("s", stats());
        r.check("a", "p50", 100.0, 100.0, 0.1);
        assert!(r.all_pass());
        assert!(r.render().contains("ALL CHECKS PASS"));
        r.check("b", "p50", 200.0, 100.0, 0.1);
        assert!(!r.all_pass());
        assert_eq!(r.failures().len(), 1);
    }
}
