//! Bench-regression gate (S24): compare a freshly generated
//! `BENCH_*.json` report against a committed baseline, tolerance-aware.
//!
//! CI has always uploaded machine-readable reports; this module is what
//! finally *reads* them.  The rules:
//!
//! * **paper-check booleans are exact** — a `pass` flag or `all_pass`
//!   verdict that differs from the baseline is drift in either
//!   direction (a newly-passing check means the baseline is stale);
//! * **latency/waste metrics are banded** — every `measured` value and
//!   series quantile must sit within a configurable relative tolerance
//!   of the baseline (exact-zero baselines must stay zero: the
//!   zero-waste claims are identities, not measurements);
//! * **wall-clock numbers are informational or loosely gated** —
//!   `wall_s` and `total_wall_s` depend on the machine, so they are
//!   reported but never gate; `events/s` (simulator throughput, the S26
//!   headline) *is* gated, one-sidedly: a run that falls more than
//!   [`EVENTS_PER_S_TOL`] below the committed baseline is a hot-path
//!   regression, while speedups and small jitter are informational.
//!   Throughput baselines must therefore come from the runner class
//!   that gates them (CI regenerates via `make baselines` on its own
//!   hardware);
//! * **live-plane rows are verdict-only** — E18 `livecheck` measures the
//!   real serving stack, so every row whose label or metric starts with
//!   `live` carries wall-clock noise in its values.  The band *verdict*
//!   (pass boolean) still compares exactly — the tolerance bands already
//!   encode how much live jitter is acceptable — but the measured values
//!   and series quantiles are informational.  The sim leg of the same
//!   report has no `live` prefix and gates at full strength.
//!
//! A baseline whose top level carries `"bootstrap": true` is a committed
//! placeholder (no toolchain was available to generate real numbers):
//! the library reports it as a pass with a notice, and the CLI's
//! `--deny-bootstrap` flag — which CI passes on every gate — turns that
//! into a hard failure, so an unarmed gate can never rot silently.
//! Regenerate via `make baselines` (or commit CI's bench-quick-report
//! artifact) to arm it.  The DES itself is deterministic per seed in
//! virtual time, so once a real baseline is committed the gate is
//! tight: any measured drift is a code change.

use std::collections::BTreeMap;

use crate::runtime::Json;

/// Default relative tolerance for banded metrics (±10 %).
pub const DEFAULT_TOL: f64 = 0.10;

/// Regression tolerance for `events/s` throughput metrics (S26): the
/// run may fall up to 50 % below the committed baseline before the gate
/// fires.  Wide because wall-clock throughput is machine- and
/// load-dependent even on one runner class; one-sided because a
/// *faster* simulator is never a regression.
pub const EVENTS_PER_S_TOL: f64 = 0.5;

/// Outcome of one document comparison.
pub struct Comparison {
    /// Gate-failing findings (empty == pass).
    pub drifts: Vec<String>,
    /// Informational notes (wall-clock deltas, bootstrap notice, …).
    pub infos: Vec<String>,
    /// The baseline was a bootstrap placeholder: nothing was compared.
    pub bootstrap: bool,
}

impl Comparison {
    pub fn ok(&self) -> bool {
        self.drifts.is_empty()
    }

    pub fn render(&self, tol: f64) -> String {
        let mut out = String::new();
        for d in &self.drifts {
            out.push_str(&format!("  drift: {d}\n"));
        }
        for i in &self.infos {
            out.push_str(&format!("  info:  {i}\n"));
        }
        let verdict = if self.bootstrap {
            "BOOTSTRAP BASELINE (gate not armed)".to_string()
        } else if self.ok() {
            format!("BASELINE MATCH (metrics within ±{:.0}%)", tol * 100.0)
        } else {
            format!("BENCH DRIFT ({} finding(s))", self.drifts.len())
        };
        out.push_str(&format!("  -> {verdict}\n"));
        out
    }
}

fn as_bool(v: &Json) -> Option<bool> {
    match v {
        Json::Bool(b) => Some(*b),
        _ => None,
    }
}

fn field_str<'a>(obj: &'a Json, key: &str) -> &'a str {
    obj.get(key).and_then(Json::as_str).unwrap_or("")
}

/// Numeric field; `None` for absent or `null` (the JSON writer emits
/// `null` for non-finite values).
fn field_num(obj: &Json, key: &str) -> Option<f64> {
    obj.get(key).and_then(Json::as_f64)
}

/// Simulator-throughput metrics: wall-clock-dependent, so they gate
/// one-sidedly via [`gate_throughput`] instead of the symmetric band.
fn throughput(metric: &str) -> bool {
    metric.contains("events/s")
}

/// Live-plane rows (E18 `livecheck`): measured on the real serving
/// stack, so values are wall-clock noise and only the verdict gates.
/// Keyed on the `live` prefix the livecheck report puts on every
/// live-leg label and metric.
fn live_plane(label: &str, metric: &str) -> bool {
    label.starts_with("live") || metric.starts_with("live")
}

/// One-sided throughput gate: drift only when the run falls more than
/// [`EVENTS_PER_S_TOL`] below the baseline; at or above the floor
/// (including speedups) the delta is informational.
fn gate_throughput(cmp: &mut Comparison, ctx: &str, run: Option<f64>, base: Option<f64>) {
    match (run, base) {
        (None, None) => {}
        (Some(r), Some(b)) => {
            if r < b * (1.0 - EVENTS_PER_S_TOL) {
                cmp.drifts.push(format!(
                    "{ctx}: events/s {r:.0} vs baseline {b:.0} ({:+.1}%, regression floor \
                     -{:.0}%)",
                    (r / b - 1.0) * 100.0,
                    EVENTS_PER_S_TOL * 100.0
                ));
            } else {
                cmp.infos
                    .push(format!("{ctx}: events/s {r:.0} vs baseline {b:.0} (within floor)"));
            }
        }
        (r, b) => {
            cmp.drifts
                .push(format!("{ctx}: events/s {r:?} vs baseline {b:?} (null-ness differs)"));
        }
    }
}

/// A report sub-array (`checks` / `bands` / `series`), empty if absent.
fn arr<'a>(exp: &'a Json, key: &str) -> &'a [Json] {
    exp.get(key).and_then(Json::as_arr).unwrap_or(&[])
}

/// One banded numeric comparison; pushes a drift line on violation.
fn compare_num(
    drifts: &mut Vec<String>,
    ctx: &str,
    field: &str,
    run: Option<f64>,
    base: Option<f64>,
    tol: f64,
) {
    match (run, base) {
        (None, None) => {}
        (Some(r), Some(b)) => {
            let within = if b == 0.0 { r.abs() <= 1e-9 } else { (r / b - 1.0).abs() <= tol };
            if !within {
                drifts.push(format!(
                    "{ctx}: {field} {r} vs baseline {b} ({:+.1}%, tol ±{:.0}%)",
                    if b == 0.0 { f64::INFINITY } else { (r / b - 1.0) * 100.0 },
                    tol * 100.0
                ));
            }
        }
        (r, b) => {
            drifts.push(format!("{ctx}: {field} {r:?} vs baseline {b:?} (null-ness differs)"));
        }
    }
}

/// Exact boolean comparison; a flip in either direction is drift.
fn compare_pass(drifts: &mut Vec<String>, ctx: &str, run: Option<bool>, base: Option<bool>) {
    if run != base {
        drifts.push(format!("{ctx}: pass {run:?} vs baseline {base:?} (must match exactly)"));
    }
}

/// Index an array of labelled objects by `(label, metric)`.
fn by_label<'a>(items: &'a [Json], metric_key: &str) -> BTreeMap<(String, String), &'a Json> {
    items
        .iter()
        .map(|it| {
            ((field_str(it, "label").to_string(), field_str(it, metric_key).to_string()), it)
        })
        .collect()
}

fn compare_labelled(
    cmp: &mut Comparison,
    id: &str,
    kind: &str,
    run_items: &[Json],
    base_items: &[Json],
    fields: &[&str],
    tol: f64,
) {
    // `series` and `timeseries` rows are keyed by label alone and carry
    // no pass boolean; `checks`/`bands` key by (label, metric).
    let by_label_only = kind == "series" || kind == "timeseries";
    let metric_key = if by_label_only { "" } else { "metric" };
    let run_map = by_label(run_items, metric_key);
    let base_map = by_label(base_items, metric_key);
    // Duplicate (label, metric) entries would shadow each other in the
    // maps and hide drift behind the survivor: refuse to gate them.
    if run_map.len() != run_items.len() || base_map.len() != base_items.len() {
        cmp.drifts.push(format!(
            "{id}/{kind}: duplicate (label, metric) entries (run {}/{}, baseline {}/{}) — \
             shadowed entries cannot be gated",
            run_map.len(),
            run_items.len(),
            base_map.len(),
            base_items.len()
        ));
    }
    for (key, base_it) in &base_map {
        let ctx = format!("{id}/{kind} '{}'", key.0);
        let Some(run_it) = run_map.get(key) else {
            cmp.drifts.push(format!("{ctx}: missing from run"));
            continue;
        };
        if !by_label_only {
            compare_pass(
                &mut cmp.drifts,
                &ctx,
                run_it.get("pass").and_then(as_bool),
                base_it.get("pass").and_then(as_bool),
            );
            if live_plane(&key.0, &key.1) {
                // E18: the verdict (compared above) is the gate; the
                // measured value is live wall-clock noise.
                if let (Some(r), Some(b)) =
                    (field_num(run_it, "measured"), field_num(base_it, "measured"))
                {
                    cmp.infos.push(format!(
                        "{ctx}: live-plane measured {r:.3} vs baseline {b:.3} (verdict-only)"
                    ));
                }
                continue;
            }
            if throughput(&key.1) {
                // The band's edges are configuration and compare
                // symmetrically; the measured value is wall-clock
                // throughput and gates one-sidedly.
                for f in fields.iter().filter(|f| **f != "measured") {
                    compare_num(
                        &mut cmp.drifts,
                        &ctx,
                        f,
                        field_num(run_it, f),
                        field_num(base_it, f),
                        tol,
                    );
                }
                gate_throughput(
                    cmp,
                    &ctx,
                    field_num(run_it, "measured"),
                    field_num(base_it, "measured"),
                );
                continue;
            }
        } else if live_plane(&key.0, &key.1) {
            // Live-plane series carry measured-latency quantiles with no
            // pass boolean of their own: nothing to gate.
            cmp.infos.push(format!("{ctx}: live-plane series (informational, not gated)"));
            continue;
        }
        for f in fields {
            compare_num(&mut cmp.drifts, &ctx, f, field_num(run_it, f), field_num(base_it, f), tol);
        }
    }
    for key in run_map.keys() {
        if !base_map.contains_key(key) {
            cmp.drifts.push(format!(
                "{id}/{kind} '{}': not in baseline (refresh baselines)",
                key.0
            ));
        }
    }
}

/// Compare two `BENCH_*.json` documents (run vs committed baseline).
/// `Err` means a document could not be parsed at all; a parsed-but-
/// drifting run comes back as `Ok` with findings.
pub fn compare_documents(run: &str, baseline: &str, tol: f64) -> Result<Comparison, String> {
    let run = Json::parse(run).map_err(|e| format!("run report: {e}"))?;
    let base = Json::parse(baseline).map_err(|e| format!("baseline report: {e}"))?;
    let mut cmp = Comparison { drifts: Vec::new(), infos: Vec::new(), bootstrap: false };

    if base.get("bootstrap").and_then(as_bool) == Some(true) {
        cmp.bootstrap = true;
        cmp.infos.push(
            "baseline is a bootstrap placeholder — regenerate with `make baselines` \
             and commit rust/baselines/ to arm the gate"
                .to_string(),
        );
        return Ok(cmp);
    }

    if field_str(&run, "generator") != field_str(&base, "generator") {
        cmp.drifts.push(format!(
            "generator '{}' vs baseline '{}'",
            field_str(&run, "generator"),
            field_str(&base, "generator")
        ));
    }
    if let (Some(r), Some(b)) = (field_num(&run, "total_wall_s"), field_num(&base, "total_wall_s"))
    {
        cmp.infos.push(format!("total_wall_s {r:.1} vs baseline {b:.1} (informational)"));
    }

    let run_exps: &[Json] = run.get("experiments").and_then(Json::as_arr).unwrap_or_default();
    let base_exps = base
        .get("experiments")
        .and_then(Json::as_arr)
        .ok_or("baseline report: missing 'experiments' array")?;

    let run_by_id: BTreeMap<&str, &Json> =
        run_exps.iter().map(|e| (field_str(e, "id"), e)).collect();
    for base_exp in base_exps {
        let id = field_str(base_exp, "id");
        let Some(run_exp) = run_by_id.get(id) else {
            cmp.drifts.push(format!("experiment '{id}': missing from run"));
            continue;
        };
        compare_pass(
            &mut cmp.drifts,
            &format!("{id}/all_pass"),
            run_exp.get("all_pass").and_then(as_bool),
            base_exp.get("all_pass").and_then(as_bool),
        );
        compare_labelled(
            &mut cmp,
            id,
            "checks",
            arr(run_exp, "checks"),
            arr(base_exp, "checks"),
            &["paper", "measured", "tol"],
            tol,
        );
        compare_labelled(
            &mut cmp,
            id,
            "bands",
            arr(run_exp, "bands"),
            arr(base_exp, "bands"),
            &["lo", "hi", "measured"],
            tol,
        );
        compare_labelled(
            &mut cmp,
            id,
            "series",
            arr(run_exp, "series"),
            arr(base_exp, "series"),
            &["n", "p1", "p25", "p50", "p75", "p99", "mean", "max"],
            tol,
        );
        compare_labelled(
            &mut cmp,
            id,
            "timeseries",
            arr(run_exp, "timeseries"),
            arr(base_exp, "timeseries"),
            &["interval_s", "n", "mean", "max", "last"],
            tol,
        );
        // S25 self-profile: engine event counts are deterministic in
        // virtual time, so they compare *exactly* — any delta is a code
        // change, not noise.  `events_per_s` is wall-clock: it gates
        // one-sidedly within the throughput floor (S26).
        compare_num(
            &mut cmp.drifts,
            &format!("{id}/profile"),
            "events",
            field_num(run_exp, "events"),
            field_num(base_exp, "events"),
            0.0,
        );
        gate_throughput(
            &mut cmp,
            &format!("{id}/profile"),
            field_num(run_exp, "events_per_s"),
            field_num(base_exp, "events_per_s"),
        );
    }
    let base_ids: Vec<&str> = base_exps.iter().map(|e| field_str(e, "id")).collect();
    for e in run_exps {
        let id = field_str(e, "id");
        if !base_ids.contains(&id) {
            cmp.drifts.push(format!("experiment '{id}': not in baseline (refresh baselines)"));
        }
    }
    Ok(cmp)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn doc(measured: f64, pass: bool, p99: f64) -> String {
        format!(
            "{{\"generator\":\"coldfaas\",\"total_wall_s\":1.5,\"experiments\":[\
             {{\"id\":\"fig9\",\"title\":\"t\",\"wall_s\":0.5,\"all_pass\":{pass},\
             \"series\":[{{\"label\":\"s\",\"n\":10,\"p1\":1,\"p25\":2,\"p50\":3,\
             \"p75\":4,\"p99\":{p99},\"mean\":3,\"max\":6}}],\
             \"checks\":[{{\"label\":\"a\",\"metric\":\"p50\",\"paper\":10,\
             \"measured\":{measured},\"tol\":0.25,\"pass\":{pass}}}],\
             \"bands\":[{{\"label\":\"tp\",\"metric\":\"events/s\",\"lo\":1,\
             \"hi\":null,\"measured\":12345,\"pass\":true}}],\"notes\":[\"n\"]}}]}}"
        )
    }

    #[test]
    fn identical_documents_match() {
        let a = doc(10.0, true, 5.0);
        let cmp = compare_documents(&a, &a, DEFAULT_TOL).unwrap();
        assert!(cmp.ok(), "{:?}", cmp.drifts);
        assert!(!cmp.bootstrap);
        assert!(cmp.render(DEFAULT_TOL).contains("BASELINE MATCH"));
    }

    #[test]
    fn metrics_gate_within_tolerance_only() {
        let base = doc(10.0, true, 5.0);
        // +5% on a checked metric: inside the ±10% band.
        let near = doc(10.5, true, 5.0);
        assert!(compare_documents(&near, &base, DEFAULT_TOL).unwrap().ok());
        // +50%: drift.
        let far = doc(15.0, true, 5.0);
        let cmp = compare_documents(&far, &base, DEFAULT_TOL).unwrap();
        assert!(!cmp.ok());
        assert!(cmp.drifts[0].contains("fig9/checks 'a'"), "{:?}", cmp.drifts);
        // Series quantiles gate the same way.
        let p99 = doc(10.0, true, 9.0);
        assert!(!compare_documents(&p99, &base, DEFAULT_TOL).unwrap().ok());
    }

    #[test]
    fn pass_booleans_are_exact_in_both_directions() {
        let base = doc(10.0, true, 5.0);
        let fail = doc(10.0, false, 5.0);
        assert!(!compare_documents(&fail, &base, DEFAULT_TOL).unwrap().ok());
        // A newly-passing check is drift too: the baseline is stale.
        assert!(!compare_documents(&base, &fail, DEFAULT_TOL).unwrap().ok());
    }

    #[test]
    fn events_per_second_bands_gate_one_sided() {
        let base = doc(10.0, true, 5.0);
        // A faster simulator is never a regression: wildly higher
        // throughput stays informational.
        let fast = base.replace("\"measured\":12345", "\"measured\":99999999");
        let cmp = compare_documents(&fast, &base, DEFAULT_TOL).unwrap();
        assert!(cmp.ok(), "{:?}", cmp.drifts);
        assert!(cmp.infos.iter().any(|i| i.contains("events/s")), "{:?}", cmp.infos);
        // Losing half the throughput (more than EVENTS_PER_S_TOL below
        // the baseline) is a hot-path regression and gates.
        let slow = base.replace("\"measured\":12345", "\"measured\":100");
        let cmp = compare_documents(&slow, &base, DEFAULT_TOL).unwrap();
        assert!(!cmp.ok());
        assert!(
            cmp.drifts.iter().any(|d| d.contains("regression floor")),
            "{:?}",
            cmp.drifts
        );
        // Just inside the floor: still a pass.
        let edge = base.replace("\"measured\":12345", "\"measured\":6500");
        assert!(compare_documents(&edge, &base, DEFAULT_TOL).unwrap().ok());
    }

    fn livecheck_doc(p50: f64, measured: f64, pass: bool) -> String {
        format!(
            "{{\"generator\":\"coldfaas\",\"total_wall_s\":9.0,\"experiments\":[\
             {{\"id\":\"livecheck_quick\",\"title\":\"E18\",\"wall_s\":8.5,\"all_pass\":true,\
             \"series\":[\
             {{\"label\":\"sim warm latency (ms)\",\"n\":100,\"p1\":1,\"p25\":2,\"p50\":{p50},\
             \"p75\":4,\"p99\":5,\"mean\":3,\"max\":6}},\
             {{\"label\":\"live warm latency (modeled ms)\",\"n\":90,\"p1\":1,\"p25\":2,\
             \"p50\":{measured},\"p75\":40,\"p99\":80,\"mean\":20,\"max\":90}}],\
             \"checks\":[],\
             \"bands\":[{{\"label\":\"live warm p50 vs sim p50\",\"metric\":\"live ms\",\
             \"lo\":0.5,\"hi\":10.0,\"measured\":{measured},\"pass\":{pass}}}],\
             \"notes\":[]}}]}}"
        )
    }

    #[test]
    fn live_plane_rows_gate_on_verdict_only() {
        let base = livecheck_doc(3.0, 2.5, true);
        // Wildly different live measurements — but the band verdict and
        // the sim-side series agree, so the gate stays green and the
        // delta is informational.
        let jittery = livecheck_doc(3.0, 9.5, true);
        let cmp = compare_documents(&jittery, &base, DEFAULT_TOL).unwrap();
        assert!(cmp.ok(), "{:?}", cmp.drifts);
        assert!(
            cmp.infos.iter().any(|i| i.contains("verdict-only")),
            "{:?}",
            cmp.infos
        );
        assert!(
            cmp.infos.iter().any(|i| i.contains("live-plane series")),
            "{:?}",
            cmp.infos
        );
    }

    #[test]
    fn live_plane_verdict_flips_still_gate() {
        let base = livecheck_doc(3.0, 2.5, true);
        let failed = livecheck_doc(3.0, 2.5, false);
        let cmp = compare_documents(&failed, &base, DEFAULT_TOL).unwrap();
        assert!(!cmp.ok());
        assert!(
            cmp.drifts.iter().any(|d| d.contains("live warm p50 vs sim p50")),
            "{:?}",
            cmp.drifts
        );
    }

    #[test]
    fn sim_side_of_a_livecheck_report_gates_at_full_strength() {
        let base = livecheck_doc(3.0, 2.5, true);
        // The sim leg is deterministic: a drifted sim p50 gates even
        // though it sits in the same report as the live rows.
        let drifted = livecheck_doc(6.0, 2.5, true);
        let cmp = compare_documents(&drifted, &base, DEFAULT_TOL).unwrap();
        assert!(!cmp.ok());
        assert!(
            cmp.drifts.iter().any(|d| d.contains("sim warm latency")),
            "{:?}",
            cmp.drifts
        );
    }

    fn doc_with_profile(events: u64, eps: f64, ts_max: f64) -> String {
        format!(
            "{{\"generator\":\"coldfaas\",\"total_wall_s\":1.5,\"experiments\":[\
             {{\"id\":\"e14\",\"title\":\"t\",\"wall_s\":0.5,\"all_pass\":true,\
             \"series\":[],\"checks\":[],\"bands\":[],\
             \"timeseries\":[{{\"label\":\"cold fraction\",\"interval_s\":30,\
             \"n\":4,\"mean\":0.5,\"max\":{ts_max},\"last\":0.25,\
             \"points\":[1,0.5,0.25,0.25]}}],\
             \"notes\":[],\"events\":{events},\"events_per_s\":{eps}}}]}}"
        )
    }

    #[test]
    fn engine_event_counts_compare_exactly() {
        let base = doc_with_profile(1000, 5e6, 1.0);
        assert!(compare_documents(&base, &base, DEFAULT_TOL).unwrap().ok());
        // One event of drift — far inside the ±10% band — still gates.
        let off = doc_with_profile(1001, 5e6, 1.0);
        let cmp = compare_documents(&off, &base, DEFAULT_TOL).unwrap();
        assert!(!cmp.ok());
        assert!(cmp.drifts.iter().any(|d| d.contains("e14/profile")), "{:?}", cmp.drifts);
    }

    #[test]
    fn profile_events_per_s_gates_regressions_only() {
        let base = doc_with_profile(1000, 5e6, 1.0);
        // Collapsed throughput (1e3 vs 5e6) gates…
        let slow = doc_with_profile(1000, 1e3, 1.0);
        let cmp = compare_documents(&slow, &base, DEFAULT_TOL).unwrap();
        assert!(!cmp.ok());
        assert!(
            cmp.drifts.iter().any(|d| d.contains("e14/profile") && d.contains("events/s")),
            "{:?}",
            cmp.drifts
        );
        // …while a faster run and mild jitter stay informational.
        for eps in [1e9, 3e6] {
            let ok = doc_with_profile(1000, eps, 1.0);
            let cmp = compare_documents(&ok, &base, DEFAULT_TOL).unwrap();
            assert!(cmp.ok(), "eps {eps}: {:?}", cmp.drifts);
            assert!(cmp.infos.iter().any(|i| i.contains("events/s")), "{:?}", cmp.infos);
        }
    }

    #[test]
    fn timeseries_summaries_gate_like_series() {
        let base = doc_with_profile(1000, 5e6, 1.0);
        let drifted = doc_with_profile(1000, 5e6, 2.0);
        let cmp = compare_documents(&drifted, &base, DEFAULT_TOL).unwrap();
        assert!(!cmp.ok());
        assert!(
            cmp.drifts.iter().any(|d| d.contains("timeseries 'cold fraction'")),
            "{:?}",
            cmp.drifts
        );
    }

    #[test]
    fn wall_times_never_gate() {
        let base = doc(10.0, true, 5.0);
        let slow = base.replace("\"total_wall_s\":1.5", "\"total_wall_s\":900");
        let cmp = compare_documents(&slow, &base, DEFAULT_TOL).unwrap();
        assert!(cmp.ok(), "{:?}", cmp.drifts);
        assert!(cmp.infos.iter().any(|i| i.contains("total_wall_s")));
    }

    #[test]
    fn missing_and_extra_experiments_are_drift() {
        let base = doc(10.0, true, 5.0);
        let none = "{\"generator\":\"coldfaas\",\"total_wall_s\":1,\"experiments\":[]}";
        let cmp = compare_documents(none, &base, DEFAULT_TOL).unwrap();
        assert!(cmp.drifts.iter().any(|d| d.contains("missing from run")), "{:?}", cmp.drifts);
        let cmp = compare_documents(&base, none, DEFAULT_TOL).unwrap();
        assert!(cmp.drifts.iter().any(|d| d.contains("not in baseline")), "{:?}", cmp.drifts);
    }

    #[test]
    fn zero_baselines_must_stay_zero() {
        let base = doc(0.0, true, 5.0);
        let drifted = doc(0.001, true, 5.0);
        assert!(compare_documents(&base, &base, DEFAULT_TOL).unwrap().ok());
        assert!(!compare_documents(&drifted, &base, DEFAULT_TOL).unwrap().ok());
    }

    #[test]
    fn bootstrap_baseline_passes_with_notice() {
        let run = doc(10.0, true, 5.0);
        let boot = "{\"generator\":\"coldfaas\",\"bootstrap\":true,\"experiments\":[]}";
        let cmp = compare_documents(&run, boot, DEFAULT_TOL).unwrap();
        assert!(cmp.ok() && cmp.bootstrap);
        assert!(cmp.render(DEFAULT_TOL).contains("BOOTSTRAP"));
    }

    #[test]
    fn unparseable_documents_are_hard_errors() {
        assert!(compare_documents("nope", &doc(1.0, true, 5.0), DEFAULT_TOL).is_err());
        assert!(compare_documents(&doc(1.0, true, 5.0), "{", DEFAULT_TOL).is_err());
    }

    #[test]
    fn duplicate_labels_are_refused_not_shadowed() {
        // Two checks sharing (label, metric) would shadow each other in
        // the comparison maps; the gate must flag them instead of
        // silently comparing only the survivor.
        let base = doc(10.0, true, 5.0);
        let dup = base.replace(
            "\"checks\":[{\"label\":\"a\"",
            "\"checks\":[{\"label\":\"a\",\"metric\":\"p50\",\"paper\":1,\
             \"measured\":99,\"tol\":0.1,\"pass\":true},{\"label\":\"a\"",
        );
        let cmp = compare_documents(&dup, &base, DEFAULT_TOL).unwrap();
        assert!(
            cmp.drifts.iter().any(|d| d.contains("duplicate (label, metric)")),
            "{:?}",
            cmp.drifts
        );
    }

    #[test]
    fn null_measured_values_compare_by_nullness() {
        let base = doc(10.0, true, 5.0);
        let nulled = base.replace("\"measured\":10,", "\"measured\":null,");
        assert!(!compare_documents(&nulled, &base, DEFAULT_TOL).unwrap().ok());
        assert!(compare_documents(&nulled, &nulled, DEFAULT_TOL).unwrap().ok());
    }
}
