//! Phase-pipeline definitions per technology, calibrated to §III.
//!
//! Calibration sources (all from the paper):
//!   * §III-C: Alpine via Docker CLI ≈ 650 ms interactive, 450 ms daemon;
//!     bare runc ≈ 150 ms; Docker's namespace configs add ≈ 100 ms, with
//!     networking the largest, then mount and IPC; the rest of the Docker
//!     overhead is gRPC hops through the stack plus the storage driver.
//!   * Fig 1: gVisor < runc ≈ Firecracker ≪ Kata (2.2 s median, 3.3 s p99
//!     at 40 parallel); all scale fairly to 20, degrade past 24 cores.
//!   * Fig 2: Docker hides OCI differences; > 10 s at 40 parallel.
//!   * Fig 3: Go process fastest; Python interpreter tens of ms, +80 ms
//!     with scipy; solo5-spt ≈ process; IncludeOS hvt 8–15 ms moderate load.
//!   * §II-A: fork() 55–500 µs.
//!
//! Contention knobs: serialized (lock) phase totals determine the closed-
//! loop saturation point.  With N in flight and serialized demand D, the
//! steady-state median ≈ N·D once N·D exceeds the nominal latency — that is
//! how Docker's ~250 ms of daemon+kernel serialization becomes > 10 s at
//! N = 40 while runc's ~12 ms stays in the hundreds.

use super::Tech;
use crate::sim::{Dist, LockClass, Step};

/// sigma for CPU-bound phases (tight, mild tail).
const S_CPU: f64 = 0.12;
/// sigma for kernel-lock phases (fatter tail: contended kernel work).
const S_LOCK: f64 = 0.25;
/// sigma for the Docker daemon's internal serialization (fattest tail).
const S_ENGINE: f64 = 0.30;

fn cpu(tag: &'static str, ms: f64) -> Step {
    Step::cpu(tag, Dist::ms(ms, S_CPU))
}

fn lock(tag: &'static str, class: LockClass, ms: f64) -> Step {
    Step::lock(tag, class, Dist::ms(ms, S_LOCK))
}

// ---------------------------------------------------------------------------
// Shared fragments
// ---------------------------------------------------------------------------

/// Namespace setup a runc-style runtime performs (§III-C: networking is the
/// largest overhead, then mount, then IPC).  `scale` lets Docker's fuller
/// namespace config (≈ +100 ms total vs basic runc) reuse the fragment.
pub fn namespace_phases(scale: f64) -> Vec<Step> {
    vec![
        lock("netns-create", LockClass::Netns, 8.0 * scale),
        cpu("net-config", 18.0 * scale),
        lock("mountns", LockClass::Mount, 3.0 * scale),
        lock("ipcns", LockClass::Ipc, 1.0 * scale),
        cpu("cgroups", 10.0 * scale),
    ]
}

/// Bare-runc core: OCI config parse, rootfs pivot, init exec.
fn runc_core() -> Vec<Step> {
    let mut v = vec![
        cpu("oci-config", 10.0),
        Step::disk("rootfs-stat", 512 * 1024),
        cpu("rootfs-pivot", 25.0),
    ];
    v.extend(namespace_phases(1.0));
    v.extend([cpu("exec-init", 45.0), cpu("app-main", 30.0)]);
    v
}

/// Docker stack above the OCI runtime: gRPC through CLI→engine→containerd→
/// shim, engine-internal serialization, and the overlay2 storage driver.
fn docker_stack(interactive: bool) -> Vec<Step> {
    let mut v = vec![
        cpu("cli-grpc", 10.0),
        Step::lock("engine-serial", LockClass::DockerEngine, Dist::ms(255.0, S_ENGINE)),
        cpu("engine-prep", 20.0),
        cpu("containerd", 20.0),
        cpu("shim-spawn", 15.0),
        lock("overlay2-mount", LockClass::Mount, 40.0),
        Step::disk("layer-setup", 4 * 1024 * 1024),
    ];
    // Docker's fuller namespace config adds ≈ 100 ms over basic runc
    // (§III-C); modeled as a second pass at 0.9 scale on top of runc's own.
    v.extend(namespace_phases(0.9));
    if interactive {
        v.push(cpu("attach-tty", 200.0));
    }
    v
}

// ---------------------------------------------------------------------------
// Per-technology pipelines
// ---------------------------------------------------------------------------

pub fn pipeline(t: Tech) -> Vec<Step> {
    match t {
        // §II-A + Fig 3: fork+exec of a compiled binary.
        Tech::Process => vec![
            Step::cpu("fork", Dist::Uniform { lo_ns: 55.0 * 1e3, hi_ns: 500.0 * 1e3 }),
            cpu("exec-load", 1.2),
        ],
        // Fig 3: interpreter boot dominates.
        Tech::PythonProcess => vec![
            Step::cpu("fork", Dist::Uniform { lo_ns: 55.0 * 1e3, hi_ns: 500.0 * 1e3 }),
            cpu("interp-boot", 22.0),
            cpu("stdlib-import", 12.0),
        ],
        // §III-E: importing scipy adds ≈ 80 ms.
        Tech::PythonScipy => {
            let mut v = pipeline(Tech::PythonProcess);
            v.push(cpu("scipy-import", 80.0));
            v
        }
        // §III-C: ≈ 150 ms with the most basic config.
        Tech::Runc => runc_core(),
        // Fig 1: better than runc — user-space kernel, thin host-ns work.
        Tech::Gvisor => vec![
            cpu("runsc-setup", 18.0),
            cpu("sentry-boot", 48.0),
            cpu("gofer-start", 22.0),
            lock("netns-create", LockClass::Netns, 6.0),
            lock("mountns", LockClass::Mount, 2.0),
            cpu("app-main", 12.0),
        ],
        // Fig 1: QEMU-KVM per container; omitted from the overload plot
        // because its median hits 2.2 s (p99 3.3 s) at 40 parallel.
        Tech::Kata => vec![
            cpu("qemu-spawn", 110.0),
            // QEMU's KVM VM + vhost + memory-region setup holds kvm_lock
            // far longer than Firecracker's minimal device model — this one
            // class is what saturates Kata at 40 parallel (2.2 s median).
            Step::lock("kvm-create", LockClass::Kvm, Dist::ms(54.0, 0.35)),
            cpu("guest-kernel-boot", 330.0),
            cpu("kata-agent", 110.0),
            lock("virtiofs-mount", LockClass::Mount, 15.0),
            lock("netns-create", LockClass::Netns, 10.0),
            cpu("app-main", 50.0),
        ],
        // Fig 1: comparable to OCI runtimes; cannot beat runc/gvisor.
        Tech::Firecracker => vec![
            cpu("api-config", 15.0),
            lock("kvm-create", LockClass::Kvm, 8.0),
            Step::disk("rootfs-attach", 2 * 1024 * 1024),
            cpu("kernel-boot", 72.0),
            cpu("app-main", 25.0),
        ],
        // §III-C: 450 ms daemon mode.
        Tech::DockerRunc => {
            let mut v = docker_stack(false);
            v.extend(runc_core());
            v
        }
        // §III-C: 650 ms interactive.
        Tech::DockerRuncInteractive => {
            let mut v = docker_stack(true);
            v.extend(runc_core());
            v
        }
        // Fig 2: Docker layers hide the runtime difference.
        Tech::DockerGvisor => {
            let mut v = docker_stack(false);
            v.extend(pipeline(Tech::Gvisor));
            v
        }
        Tech::DockerKata => {
            let mut v = docker_stack(false);
            v.extend(pipeline(Tech::Kata));
            v
        }
        // Fig 3 + [17]: seccomp process tender, essentially process speed;
        // the measured app is solo5's bare test binary (no IncludeOS libs).
        Tech::Solo5Spt => vec![
            cpu("spt-tender", 0.7),
            cpu("seccomp-install", 0.3),
            cpu("unikernel-boot", 0.8),
        ],
        // Fig 3: 8–15 ms under moderate load.
        Tech::IncludeOsHvt => vec![
            cpu("hvt-tender", 2.0),
            lock("kvm-create", LockClass::Kvm, 1.2),
            cpu("guest-mem-setup", 2.5),
            cpu("unikernel-boot", 5.0),
        ],
    }
}

/// §II-C: on-disk image sizes.
pub fn image_bytes(t: Tech) -> u64 {
    match t {
        Tech::Process => 2_000_000,                    // static Go binary
        Tech::PythonProcess | Tech::PythonScipy => 6_000_000, // alpine+python layers
        Tech::Runc | Tech::Gvisor | Tech::DockerRunc | Tech::DockerGvisor
        | Tech::DockerRuncInteractive => 6_000_000,    // base Alpine ≈ 6 MB
        Tech::Kata | Tech::DockerKata => 45_000_000,   // guest kernel+initrd+alpine
        Tech::Firecracker => 70_000_000,               // 20 MB kernel + 50 MB rootfs
        Tech::Solo5Spt => 200_000,                     // solo5 example ≈ 200 kB
        Tech::IncludeOsHvt => 2_500_000,               // IncludeOS echo ≈ 2.5 MB
    }
}

/// Resident memory a *warm* (idle) executor reserves; §IV argues this is
/// pure waste.  Unikernels exit after each request — nothing stays warm.
pub fn warm_memory_bytes(t: Tech) -> u64 {
    match t {
        Tech::Process => 4 << 20,
        Tech::PythonProcess => 30 << 20,
        Tech::PythonScipy => 110 << 20,
        Tech::Runc | Tech::DockerRunc | Tech::DockerRuncInteractive => 16 << 20,
        Tech::Gvisor | Tech::DockerGvisor => 40 << 20,
        Tech::Kata | Tech::DockerKata => 128 << 20,
        Tech::Firecracker => 128 << 20,
        Tech::Solo5Spt | Tech::IncludeOsHvt => 0,
    }
}

/// Serialized (lock-held) milliseconds in a pipeline — the closed-loop
/// saturation constant the calibration tests reason about.
pub fn serialized_ms(t: Tech) -> f64 {
    pipeline(t)
        .iter()
        .filter(|s| matches!(s.kind, crate::sim::StepKind::Lock(_)))
        .map(|s| s.dur.median_ns() / 1e6)
        .sum()
}

/// Serialized milliseconds of the single *worst* lock class — different
/// classes pipeline against each other, so the closed-loop saturation
/// median at N in flight is ≈ N × this value once saturated.
pub fn bottleneck_serialized_ms(t: Tech) -> f64 {
    let mut per_class = [0.0f64; crate::sim::N_LOCKS];
    for s in pipeline(t) {
        if let crate::sim::StepKind::Lock(c) = s.kind {
            per_class[c as usize] += s.dur.median_ns() / 1e6;
        }
    }
    per_class.iter().cloned().fold(0.0, f64::max)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Saturation medians ≈ N × bottleneck-lock demand must land in the
    /// paper's reported overload bands at N = 40.
    #[test]
    fn overload_serialization_budgets() {
        // Docker > 10 s at 40 (§III-D): needs ≥ 250 ms on one lock class.
        assert!(bottleneck_serialized_ms(Tech::DockerRunc) >= 250.0);
        // Kata 2.2 s median at 40: ≈ 55 ms on its kvm lock.
        let kata = bottleneck_serialized_ms(Tech::Kata);
        assert!((40.0 * kata - 2200.0).abs() < 300.0, "kata serial {kata} ms");
        // OCI runtimes must stay "fairly well" at 20: N·D ≤ ~1.6× nominal.
        for t in [Tech::Runc, Tech::Gvisor, Tech::Firecracker] {
            let nd = 20.0 * bottleneck_serialized_ms(t);
            assert!(
                nd <= 1.6 * t.nominal_startup_ms(),
                "{}: 20-parallel lock demand {nd:.0} ms vs nominal {:.0} ms",
                t.name(),
                t.nominal_startup_ms()
            );
        }
    }

    #[test]
    fn python_scipy_adds_80ms() {
        let d = Tech::PythonScipy.nominal_startup_ms() - Tech::PythonProcess.nominal_startup_ms();
        assert!((d - 80.0).abs() < 1.0, "scipy delta {d} ms");
    }

    #[test]
    fn docker_hides_runtime_differences() {
        // Fig 2 finding: relative spread under Docker ≪ spread at OCI level.
        let oci_spread = Tech::Kata.nominal_startup_ms() / Tech::Gvisor.nominal_startup_ms();
        let docker_spread =
            Tech::DockerKata.nominal_startup_ms() / Tech::DockerGvisor.nominal_startup_ms();
        assert!(docker_spread < oci_spread * 0.55);
    }

    #[test]
    fn fork_within_paper_band() {
        // §II-A: 55–500 µs.
        let p = pipeline(Tech::Process);
        match p[0].dur {
            Dist::Uniform { lo_ns, hi_ns } => {
                assert_eq!(lo_ns, 55_000.0);
                assert_eq!(hi_ns, 500_000.0);
            }
            _ => panic!("fork should be uniform"),
        }
    }

    #[test]
    fn docker_namespace_overhead_bounded() {
        // §III-C: Docker's extra namespace configs cost well under the
        // engine/storage overhead but are a visible chunk (tens of ms).
        let extra: f64 = namespace_phases(0.9).iter().map(|s| s.dur.median_ns() / 1e6).sum();
        assert!((30.0..100.0).contains(&extra), "ns overhead {extra}");
        // Full docker-vs-runc gap: the paper's 450 − 150 = 300 ms plus the
        // daemon serialization needed for the 40-parallel >10 s finding.
        let gap = Tech::DockerRunc.nominal_startup_ms() - Tech::Runc.nominal_startup_ms();
        assert!((300.0..460.0).contains(&gap), "docker-runc gap {gap}");
    }
}
