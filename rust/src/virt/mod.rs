//! Virtualization substrate models (S3): every startup technology the
//! paper measures, expressed as a phase pipeline over the host model.
//!
//! Each technology is a `Vec<Step>` — CPU-bound phases contend for the
//! 24-core pool, kernel-global phases (netns/rtnl, mount-table,
//! KVM-creation, docker-engine serialization) hold a serializing lock, and
//! image reads go through the FIFO disk.  Phase medians are calibrated to
//! the paper's §III measurements at parallelism 1; everything the paper
//! reports at higher parallelism (the knee beyond 24 cores, Kata's 2.2 s
//! median / 3.3 s p99 at 40, Docker's >10 s) must *emerge* from contention,
//! and the calibration tests assert that it does.

pub mod profiles;

use crate::sim::Step;

/// Every startup technology measured in Figs 1–3.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Tech {
    /// Compiled binary fork+exec (the Go echo app of Fig 3).
    Process,
    /// CPython interpreter start, no libraries.
    PythonProcess,
    /// CPython + heavy module import (`scipy`, §III-E: +80 ms).
    PythonScipy,
    /// Bare OCI runc with basic config (§III-C: ~150 ms).
    Runc,
    /// gVisor runsc under OCI (Fig 1: better than runc).
    Gvisor,
    /// Kata Containers: QEMU-KVM micro-VM per container (Fig 1: slowest).
    Kata,
    /// Firecracker micro-VM (Fig 1: comparable to OCI runtimes).
    Firecracker,
    /// Full Docker stack over runc, daemon (non-interactive) mode (§III-C: ~450 ms).
    DockerRunc,
    /// Full Docker stack over runsc.
    DockerGvisor,
    /// Full Docker stack over Kata.
    DockerKata,
    /// Docker CLI interactive mode (§III-C: ~650 ms).
    DockerRuncInteractive,
    /// solo5 sandboxed-process tender, bare test app (Fig 3: ~process speed).
    Solo5Spt,
    /// IncludeOS unikernel on solo5 hvt over KVM (Fig 3: 8–15 ms).
    IncludeOsHvt,
}

impl Tech {
    pub const ALL: [Tech; 13] = [
        Tech::Process,
        Tech::PythonProcess,
        Tech::PythonScipy,
        Tech::Runc,
        Tech::Gvisor,
        Tech::Kata,
        Tech::Firecracker,
        Tech::DockerRunc,
        Tech::DockerGvisor,
        Tech::DockerKata,
        Tech::DockerRuncInteractive,
        Tech::Solo5Spt,
        Tech::IncludeOsHvt,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            Tech::Process => "process",
            Tech::PythonProcess => "python",
            Tech::PythonScipy => "python+scipy",
            Tech::Runc => "runc",
            Tech::Gvisor => "gvisor",
            Tech::Kata => "kata",
            Tech::Firecracker => "firecracker",
            Tech::DockerRunc => "docker-runc",
            Tech::DockerGvisor => "docker-gvisor",
            Tech::DockerKata => "docker-kata",
            Tech::DockerRuncInteractive => "docker-runc-interactive",
            Tech::Solo5Spt => "solo5-spt",
            Tech::IncludeOsHvt => "includeos-hvt",
        }
    }

    pub fn from_name(s: &str) -> Option<Tech> {
        Tech::ALL.iter().copied().find(|t| t.name() == s)
    }

    /// Startup phase pipeline for one executor of this technology.
    pub fn pipeline(&self) -> Vec<Step> {
        profiles::pipeline(*self)
    }

    /// On-disk image size in bytes (§II-C).
    pub fn image_bytes(&self) -> u64 {
        profiles::image_bytes(*self)
    }

    /// Sum of pipeline medians (the no-contention startup median, ms).
    pub fn nominal_startup_ms(&self) -> f64 {
        self.pipeline()
            .iter()
            .map(|s| s.dur.median_ns() / 1e6)
            .sum()
    }

    /// Idle memory held by a *warm* executor of this technology (bytes).
    /// Used by the resource-waste experiment (E9).
    pub fn warm_memory_bytes(&self) -> u64 {
        profiles::warm_memory_bytes(*self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn names_round_trip() {
        for t in Tech::ALL {
            assert_eq!(Tech::from_name(t.name()), Some(t));
        }
        assert_eq!(Tech::from_name("nope"), None);
    }

    #[test]
    fn pipelines_nonempty() {
        for t in Tech::ALL {
            assert!(!t.pipeline().is_empty(), "{t:?} has no phases");
        }
    }

    /// §III conclusions as orderings — these must hold *structurally*.
    #[test]
    fn paper_startup_ordering() {
        let ms = |t: Tech| t.nominal_startup_ms();
        // Fig 3: spt fastest VM-ish option, ~process speed; hvt ~10 ms.
        assert!(ms(Tech::Process) < ms(Tech::IncludeOsHvt));
        assert!(ms(Tech::Solo5Spt) < ms(Tech::IncludeOsHvt));
        assert!(ms(Tech::IncludeOsHvt) < 20.0);
        // Fig 1: gvisor < runc ~ firecracker << kata.
        assert!(ms(Tech::Gvisor) < ms(Tech::Runc));
        assert!(ms(Tech::Kata) > 2.0 * ms(Tech::Runc));
        // §III-C: bare runc ~150, docker daemon ~450, interactive ~650.
        assert!(ms(Tech::Runc) < ms(Tech::DockerRunc));
        assert!(ms(Tech::DockerRunc) < ms(Tech::DockerRuncInteractive));
        // unikernel an order of magnitude under any container path.
        assert!(10.0 * ms(Tech::IncludeOsHvt) < ms(Tech::DockerRunc));
    }

    /// §III-C text: paper-reported single-start medians, ±25 %.
    #[test]
    fn paper_absolute_medians() {
        let check = |t: Tech, want: f64| {
            let got = t.nominal_startup_ms();
            assert!(
                (got / want - 1.0).abs() < 0.25,
                "{}: nominal {got:.1} ms vs paper {want} ms",
                t.name()
            );
        };
        check(Tech::Runc, 150.0);
        check(Tech::DockerRunc, 450.0);
        check(Tech::DockerRuncInteractive, 650.0);
        check(Tech::IncludeOsHvt, 11.0); // Fig 3: 8–15 ms band
    }

    /// §II-C image sizes.
    #[test]
    fn image_size_ladder() {
        assert!(Tech::Solo5Spt.image_bytes() < Tech::IncludeOsHvt.image_bytes());
        assert!(Tech::IncludeOsHvt.image_bytes() < Tech::DockerRunc.image_bytes());
        assert!(Tech::DockerRunc.image_bytes() < Tech::Firecracker.image_bytes());
        assert_eq!(Tech::IncludeOsHvt.image_bytes(), 2_500_000); // ~2.5 MB echo server
    }

    #[test]
    fn warm_memory_zero_only_for_exiting_unikernel() {
        // Cold-only unikernels exit after execution: nothing stays resident.
        assert_eq!(Tech::IncludeOsHvt.warm_memory_bytes(), 0);
        assert!(Tech::DockerRunc.warm_memory_bytes() > 0);
    }
}
