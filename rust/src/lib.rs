//! # coldfaas
//!
//! A cold-start-only FaaS platform with unikernel-style executors —
//! a full-system reproduction of Géhberger & Kovács, *"Cooling Down FaaS:
//! Towards Getting Rid of Warm Starts"* (2022).
//!
//! The crate has two halves that share one set of substrate models:
//!
//! * a **discrete-event simulation** stack ([`sim`], [`virt`], [`net`],
//!   [`workload`], [`fnplat`], [`lambda`], [`policy`], and the unified
//!   [`platform`] layer every experiment is a configuration of) that
//!   regenerates every figure and table of the paper's evaluation in
//!   virtual time — plus the keep-alive policy lab (E12), the
//!   cluster-scale fleet sweep (E13), the fault-injection chaos sweep
//!   (E14), and the 256-node planet sweep (E15) that quantify the
//!   cold-only thesis against the lifecycle policies real platforms run,
//!   in failure, in calm, and at fleet scale — plus the universal-worker
//!   sharing sweep (E16) that prices the strongest keep-alive
//!   counter-proposal, runtime-keyed shared warm pools, against going
//!   cold-only — and
//! * a **live serving** stack ([`gateway`], [`coordinator`], [`exec`],
//!   [`runtime`], [`live`]) — a real HTTP control plane whose executors
//!   run AOT-compiled JAX/Pallas functions through PJRT (python never on
//!   the request path), with the same startup models applied in real
//!   time.  The [`live`] module mirrors the DES warm-pool semantics over
//!   real sockets, and experiment E18 (`livecheck`) cross-validates the
//!   two planes against each other.
//!
//! See `DESIGN.md` for the system inventory and the per-experiment index,
//! and `EXPERIMENTS.md` for paper-vs-measured results.

// Clippy's `disallowed_methods` / `disallowed_types` lists (clippy.toml)
// mirror detlint DL001/DL002 (DESIGN.md S28).  Modules below that carry an
// allow are the live-serving / tooling half of the crate (wall-clock reads
// on purpose) or keyed-HashMap holders whose *iteration* detlint DL002
// still audits; everything else stays clippy-enforced natively.
pub mod analysis;
pub mod cli;
pub mod cluster;
#[allow(clippy::disallowed_methods)] // live control plane: real request timing
pub mod coordinator;
#[allow(clippy::disallowed_methods)] // live executor: real boot/teardown timing
pub mod exec;
pub mod experiments;
#[allow(clippy::disallowed_methods)] // live HTTP plane: socket deadlines (DL001 island)
pub mod gateway;
pub mod fnplat;
#[allow(clippy::disallowed_types)] // keyed image registry; iteration audited by DL002
pub mod image;
pub mod lambda;
#[allow(clippy::disallowed_methods)] // simulation-mirroring live platform: modeled clock + scaled sleeps
pub mod live;
pub mod metrics;
pub mod net;
pub mod obs;
pub mod platform;
pub mod policy;
pub mod report;
#[allow(clippy::disallowed_methods, clippy::disallowed_types)] // PJRT: real compile/exec medians
pub mod runtime;
pub mod sim;
#[allow(clippy::disallowed_methods)] // test scaffolding: polling with real deadlines
pub mod testkit;
pub mod virt;
pub mod workload;
